//! Table 4: area breakdown of the FGMP datapath and PPU (5 nm
//! post-synthesis component figures), with the derived overhead ratios and
//! the PPU amortization analysis of §5.4.3.
//!
//!     cargo bench --bench table4_area

use fgmp::hwsim::area::AreaModel;
use fgmp::hwsim::datapath::DatapathConfig;
use fgmp::hwsim::ppu::{ppu_balance, ppu_energy_per_op_fj};
use fgmp::hwsim::energy::EnergyModel;

fn main() {
    let a = AreaModel::default();
    println!("== Table 4: area breakdown (um^2, 16 lanes, BS=16) ==");
    println!("{:<22} {:>10}", "configuration", "area");
    for (name, v) in [
        ("FP8 Datapath", a.fp8_datapath),
        ("NVFP4 Datapath", a.nvfp4_datapath),
        ("FP8/NVFP4 Datapath", a.fp8_nvfp4_datapath),
        ("NVFP4/FP8 Datapath", a.nvfp4_fp8_datapath),
        ("FGMP Datapath", a.fgmp_datapath),
        ("FGMP PPU", a.fgmp_ppu),
    ] {
        println!("{name:<22} {v:>10.0}");
    }
    println!("\nderived:");
    println!("  FGMP vs FP8-only     : {:.2}x (paper: 3.5x)", a.overhead_vs_fp8());
    println!("  FGMP vs coarse MP    : {:.2}x (paper: 2.2x)", a.overhead_vs_coarse());
    println!("  PPU vs FGMP datapath : {:.0}% (paper: 85%)", a.ppu_overhead() * 100.0);

    println!("\n== PPU amortization (4096^3 matmul, 16-lane PEs) ==");
    println!("{:>6} {:>14} {:>12} {:>10} {:>12}", "PEs", "datapath cyc", "PPU cyc", "balanced", "PPU area %");
    for pes in [16, 64, 128, 256, 512] {
        let cfg = DatapathConfig { lanes: 16, pes, freq_ghz: 1.0 };
        let b = ppu_balance(&cfg, 4096, 4096, 4096, 1);
        println!("{:>6} {:>14} {:>12} {:>10} {:>11.2}%",
                 pes, b.datapath_cycles, b.ppu_cycles, b.balanced,
                 a.ppu_overhead_amortized(pes) * 100.0);
    }
    let em = EnergyModel::default();
    println!("\nPPU energy: {:.1} pJ/block -> {:.2} fJ/op at K=4096 (paper: 0.20 fJ/op, <1%)",
             em.e_ppu_block, ppu_energy_per_op_fj(em.e_ppu_block, 4096));
}
