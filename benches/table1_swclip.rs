//! Table 1: sensitivity-weighted clipping in the *weight-only* FP4 regime
//! (activations stay BF16 — quantized weights flow through the unquantized
//! fwd_ref graph). Llama-2-7B/13B map to tiny-llama / tiny-llama-l.
//!
//!     cargo bench --bench table1_swclip

use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, QuantizedModel, RatioSpec};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(8);
    let rt = Runtime::cpu()?;

    println!("== Table 1: weight-only FP4 ± SW-Clip (BF16 activations) ==");
    println!("{:<22} {:>12} {:>14}", "weight precision", "tiny-llama", "tiny-llama-l");
    let mut rows = vec![vec![], vec![], vec![]];
    for model in ["tiny-llama", "tiny-llama-l"] {
        let ev = Evaluator::load(&rt, &artifacts, model)?;
        let bf16 = ev.perplexity(
            &QuantConfig { ratio: RatioSpec::Bf16, ..QuantConfig::fgmp(0.0) }, None, batches)?;
        rows[0].push(bf16.ppl);
        for (i, clip) in [(1, false), (2, true)] {
            let cfg = QuantConfig { sw_clip: clip, ..QuantConfig::all_fp4() };
            let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
            let rep = ev.perplexity_weight_only(&qm, batches)?;
            rows[i].push(rep.ppl);
        }
    }
    for (label, row) in [("BF16", &rows[0]), ("FP4", &rows[1]), ("FP4 (w/ SW-Clip)", &rows[2])] {
        print!("{label:<22}");
        for v in row {
            print!(" {v:>12.4}");
        }
        println!();
    }
    println!("\nexpected shape (paper): FP4 above BF16; SW-Clip strictly between.");
    Ok(())
}
