//! Microbenchmarks for the L3 hot paths: blocked matmul kernels, codecs,
//! impact scoring, threshold calibration, SW-Clip, packing, and the hwsim
//! costing pipeline. These drive the §Perf iteration loop in
//! EXPERIMENTS.md (in-repo bench harness; DESIGN.md §Deps).
//!
//!     cargo bench --bench hotpath
//!
//! Budget per bench is overridable with `FGMP_BENCH_BUDGET_MS` (CI uses a
//! short budget); results are also written to `BENCH_micro.json` in the
//! shared `util::bench` suite format.

use fgmp::benchsuite::{keep, kernel_benches};
use fgmp::policy::{block_impact_scores, threshold_for_fp4_fraction};
use fgmp::quant::{quant_e2m1, quant_e4m3, sw_clip_tensor, FgmpTensor, Precision};
use fgmp::util::bench::{bench, black_box, budget_from_env, BenchSuite};
use fgmp::util::Rng;

fn main() {
    let budget = budget_from_env(400);
    let mut suite = BenchSuite::new("micro");
    let mut rng = Rng::new(42);
    println!("== hotpath microbenchmarks (in-repo harness, budget {budget:?}) ==");

    // --- shared kernel comparisons (one definition: fgmp::benchsuite) ---
    kernel_benches(&mut suite, budget);

    // --- scalar codec reductions (historic micro anchors) ---
    let xs = rng.normal_vec(1 << 16, 8.0);
    let r = bench("quant_e4m3_64k", Some(xs.len() as u64), budget, || {
        xs.iter().map(|&x| quant_e4m3(black_box(x))).sum::<f32>()
    });
    keep(&mut suite, r);
    let r = bench("quant_e2m1_64k", Some(xs.len() as u64), budget, || {
        xs.iter().map(|&x| quant_e2m1(black_box(x))).sum::<f32>()
    });
    keep(&mut suite, r);

    // --- policy scoring + threshold ---
    let k = 1024;
    let rows = 512;
    let data = rng.normal_vec(rows * k, 4.0);
    let cw: Vec<f32> = (0..k).map(|_| rng.f32().abs() + 0.01).collect();
    let r = bench("impact_scores_512x1024", Some((rows * k) as u64), budget, || {
        block_impact_scores(black_box(&data), k, &cw, None)
    });
    keep(&mut suite, r);
    let scores = block_impact_scores(&data, k, &cw, None);
    let r = bench("threshold_percentile_32k", Some(scores.len() as u64), budget, || {
        threshold_for_fp4_fraction(black_box(&scores), 0.7)
    });
    keep(&mut suite, r);

    // --- packing + clipping ---
    let rows = 256;
    let data = rng.normal_vec(rows * k, 4.0);
    let fisher: Vec<f32> = (0..rows * k).map(|_| rng.f32().abs() + 1e-4).collect();
    let prec: Vec<Precision> = (0..rows * k / 16)
        .map(|i| if i % 10 < 3 { Precision::Fp8 } else { Precision::Fp4 })
        .collect();
    let r = bench("pack_256x1024", Some((rows * k) as u64), budget, || {
        FgmpTensor::pack(&[rows, k], black_box(&data), &prec, None)
    });
    keep(&mut suite, r);
    let packed = FgmpTensor::pack(&[rows, k], &data, &prec, None);
    let r = bench("unpack_256x1024", Some((rows * k) as u64), budget, || {
        black_box(&packed).unpack()
    });
    keep(&mut suite, r);
    let r = bench("sw_clip_256x1024", Some((rows * k) as u64), budget, || {
        sw_clip_tensor(black_box(&data), &fisher)
    });
    keep(&mut suite, r);

    // --- hwsim costing ---
    use fgmp::hwsim::energy::EnergyModel;
    use fgmp::hwsim::layerprof::{model_energy_clustered, LayerProfile};
    use fgmp::hwsim::DatapathConfig;
    let profiles: Vec<LayerProfile> = (0..128)
        .map(|i| LayerProfile {
            name: format!("l{i}"),
            layer: i,
            kind: "fc1".into(),
            m: 4096,
            k: 4096,
            n: 4096,
            weight_fp8: (i as f64 * 0.37).fract() * 0.4,
            act_fp8: (i as f64 * 0.61).fract() * 0.4,
        })
        .collect();
    let dp = DatapathConfig::default();
    let em = EnergyModel::default();
    let r = bench("model_energy_clustered_128x100", None, budget, || {
        model_energy_clustered(&dp, &em, black_box(&profiles), 100)
    });
    keep(&mut suite, r);

    // --- end-to-end offline quantization (if artifacts exist) ---
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if let Ok(arts) = fgmp::model::ModelArtifacts::load(format!("{artifacts}/tiny-llama")) {
        let cfg = fgmp::model::QuantConfig::fgmp(0.7);
        let r = bench("quantize_tiny_llama_full", None, budget, || {
            fgmp::model::QuantizedModel::quantize(black_box(&arts), &cfg).unwrap()
        });
        keep(&mut suite, r);
        let cfg_noclip = fgmp::model::QuantConfig { sw_clip: false, ..cfg };
        let r = bench("quantize_tiny_llama_noclip", None, budget, || {
            fgmp::model::QuantizedModel::quantize(black_box(&arts), &cfg_noclip).unwrap()
        });
        keep(&mut suite, r);
    } else {
        println!("(artifacts not found — skipping end-to-end quantize bench)");
    }

    let out_dir = std::env::var("FGMP_BENCH_OUT").unwrap_or_else(|_| ".".into());
    match suite.write(&out_dir) {
        Ok(path) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write BENCH_micro.json: {e}"),
    }
}
