//! Fig. 8: weight-memory savings for FGMP at 70% / 90% FP4, with the
//! payload / microscale / metadata breakdown, against BF16 and FP8.
//! Reported both for the tiny models (exact, from the real packed tensors)
//! and analytically for the Llama-2-7B shape the paper uses.
//!
//!     cargo bench --bench fig8_memory

use fgmp::hwsim::memory::{fgmp_footprint, flat_footprint, nvfp4_footprint, MemoryReport};
use fgmp::model::{ModelArtifacts, QuantConfig, QuantizedModel};

fn print_row(label: &str, m: &MemoryReport, base: &MemoryReport) {
    println!(
        "{:<18} {:>10.3} {:>9.1}% {:>12.3} {:>9.3} {:>9.3}",
        label,
        m.total_mib(),
        (1.0 - m.total_bits() as f64 / base.total_bits() as f64) * 100.0,
        m.payload_bits as f64 / 8.0 / 1024.0 / 1024.0,
        m.scale_bits as f64 / 8.0 / 1024.0 / 1024.0,
        m.meta_bits as f64 / 8.0 / 1024.0 / 1024.0,
    );
}

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());

    // Exact, from the real packed model.
    let arts = ModelArtifacts::load(format!("{artifacts}/tiny-llama"))?;
    println!("== Fig. 8 (tiny-llama, measured from packed tensors) ==");
    println!("{:<18} {:>10} {:>10} {:>12} {:>9} {:>9}",
             "config", "MiB", "vs FP8", "payload", "scales", "meta");
    let elements = arts.manifest.quantized_elements();
    let fp8 = flat_footprint(elements, 8);
    print_row("BF16", &flat_footprint(elements, 16), &fp8);
    print_row("FP8", &fp8, &fp8);
    for fp4 in [0.7, 0.9] {
        let cfg = QuantConfig::fgmp(fp4);
        let qm = QuantizedModel::quantize(&arts, &cfg)?;
        let mut rep = MemoryReport::default();
        for l in &qm.linears {
            let (p, s, m) = l.packed.footprint_bits();
            rep.payload_bits += p as u64;
            rep.scale_bits += s as u64;
            rep.meta_bits += m as u64;
            rep.elements += (l.packed.n_blocks * 16) as u64;
        }
        print_row(&format!("FGMP {:.0}% FP4", fp4 * 100.0), &rep, &fp8);
    }
    print_row("NVFP4", &nvfp4_footprint(elements), &fp8);

    // Analytical at the paper's Llama-2-7B linear-layer element count.
    println!("\n== Fig. 8 (Llama-2-7B shape, analytical) ==");
    let n7b: u64 = 32 * (4096 * 3 * 4096 + 4096 * 4096 + 4096 * 11008 * 2 + 11008 * 4096) as u64;
    let fp8 = flat_footprint(n7b, 8);
    println!("{:<18} {:>10} {:>10} {:>12} {:>9} {:>9}",
             "config", "MiB", "vs FP8", "payload", "scales", "meta");
    print_row("BF16", &flat_footprint(n7b, 16), &fp8);
    print_row("FP8", &fp8, &fp8);
    print_row("FGMP 70% FP4", &fgmp_footprint(n7b, 0.30), &fp8);
    print_row("FGMP 90% FP4", &fgmp_footprint(n7b, 0.10), &fp8);
    print_row("NVFP4", &nvfp4_footprint(n7b), &fp8);
    println!("\nexpected (paper §5.4.1): 30% savings at 70% FP4, 39% at 90% FP4.");

    // Whole-inference view: weight savings in the presence of a BF16 KV
    // cache (the paper's Fig. 1 assumes 4K context; its footnote notes KV
    // stays unquantized in FGMP's scope).
    use fgmp::hwsim::kvcache::{extra_context_tokens, inference_memory_report, KvModelDims};
    let dims = KvModelDims::llama2_7b();
    println!("\n== whole-inference memory (7B, FGMP 70% FP4 + BF16 KV cache) ==");
    println!("{:>9} {:>12} {:>12} {:>9} {:>16}", "context", "FGMP GiB", "FP8 GiB", "savings", "extra ctx tokens");
    for ctx in [0u64, 2048, 4096, 8192, 32768] {
        let (fgmp_m, base_m, s) = inference_memory_report(&dims, 0.30, ctx);
        println!("{:>9} {:>12.3} {:>12.3} {:>8.1}% {:>16}",
                 ctx, fgmp_m.total_gib(), base_m.total_gib(), s * 100.0,
                 extra_context_tokens(&dims, 0.30, ctx));
    }
    println!("(weight-only savings dilute as the BF16 KV cache grows; the freed");
    println!(" memory buys ~3.7k extra context tokens at the 7B shape)");
    Ok(())
}
