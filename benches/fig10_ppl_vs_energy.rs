//! Fig. 10: perplexity vs normalized inference energy for tiny-llama as the
//! FP8 block budget varies, with the FP4/FP8 single-format endpoints — the
//! paper's headline "<1% ppl degradation at 14% energy savings" trade-off
//! curve.
//!
//!     cargo bench --bench fig10_ppl_vs_energy

use fgmp::eval::sweep::{format_rows, run_sweep};
use fgmp::eval::Evaluator;
use fgmp::model::QuantConfig;
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(8);
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;

    let mut configs = vec![QuantConfig::all_fp8()];
    for fp4 in [0.1, 0.3, 0.5, 0.7, 0.8, 0.9, 0.95] {
        configs.push(QuantConfig::fgmp(fp4));
    }
    configs.push(QuantConfig::all_fp4());

    let rows = run_sweep(&ev, &configs, batches)?;
    println!("== Fig. 10: perplexity vs normalized energy (tiny-llama) ==");
    print!("{}", format_rows(&rows));

    // The headline row: largest energy savings with <1% ppl degradation
    // relative to all-FP8.
    let fp8_ppl = rows[0].ppl;
    let best = rows
        .iter()
        .filter(|r| r.ppl <= fp8_ppl * 1.01 && r.energy_norm.is_finite() && r.label != "FP8/fisher")
        .min_by(|a, b| a.energy_norm.partial_cmp(&b.energy_norm).unwrap());
    if let Some(b) = best {
        println!("\nheadline: '{}' attains {:.1}% energy savings with {:+.2}% ppl vs FP8",
                 b.label, (1.0 - b.energy_norm) * 100.0, (b.ppl / fp8_ppl - 1.0) * 100.0);
        println!("(paper: 14% energy savings at <1% perplexity degradation)");
    }
    Ok(())
}
