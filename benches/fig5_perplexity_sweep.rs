//! Fig. 5: perplexity vs %FP8 blocks for every model family, with and
//! without sensitivity-weighted clipping.
//!
//!     cargo bench --bench fig5_perplexity_sweep
//!     FGMP_MODELS=tiny-llama FGMP_BATCHES=4 cargo bench --bench fig5_perplexity_sweep

use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, QuantizedModel, RatioSpec};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    let models = std::env::var("FGMP_MODELS")
        .unwrap_or_else(|_| "tiny-llama,tiny-gpt,tiny-nemotron".into());
    let rt = Runtime::cpu()?;

    println!("== Fig. 5: ppl vs %FP8, per model, ±SW-Clip ==");
    for model in models.split(',') {
        let ev = Evaluator::load(&rt, &artifacts, model)?;
        let bf16 = ev.perplexity(
            &QuantConfig { ratio: RatioSpec::Bf16, ..QuantConfig::fgmp(0.0) }, None, batches)?;
        println!("\n[{model}]  BF16 ppl {:.4}", bf16.ppl);
        println!("{:>8} {:>12} {:>12}", "%FP8", "ppl(clip)", "ppl(noclip)");
        for fp8_pct in [0.0, 10.0, 30.0, 70.0, 90.0, 100.0] {
            let fp4 = 1.0 - fp8_pct / 100.0;
            let mut row = format!("{fp8_pct:>7.0}%");
            for clip in [true, false] {
                let cfg = QuantConfig { sw_clip: clip, ..QuantConfig::fgmp(fp4) };
                let cfg = match fp4 {
                    f if f >= 1.0 => QuantConfig { ratio: RatioSpec::AllFp4, ..cfg },
                    f if f <= 0.0 => QuantConfig { ratio: RatioSpec::AllFp8, ..cfg },
                    _ => cfg,
                };
                let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
                let rep = ev.perplexity(&cfg, Some(&qm), batches)?;
                row.push_str(&format!(" {:>12.4}", rep.ppl));
            }
            println!("{row}");
        }
    }
    println!("\nexpected shape (paper): ppl falls monotonically toward FP8; the");
    println!("clip column is at or below the noclip column, most visibly at high %FP4.");
    Ok(())
}
