//! Tables 2–3: downstream-task accuracy (synthetic MMLU + lm-eval-harness
//! stand-ins) per model per precision configuration.
//!
//!     cargo bench --bench table23_downstream
//!     FGMP_MODELS=tiny-llama FGMP_ITEMS=32 cargo bench --bench table23_downstream

use fgmp::eval::tasks::{score_suite, TaskSuite};
use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, QuantizedModel};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let max_items: usize = std::env::var("FGMP_ITEMS").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(16);
    let models = std::env::var("FGMP_MODELS")
        .unwrap_or_else(|_| "tiny-llama".into());
    let rt = Runtime::cpu()?;

    let mut suites: Vec<TaskSuite> = std::fs::read_dir(format!("{artifacts}/tasks"))?
        .filter_map(|e| e.ok())
        .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
        .map(|e| TaskSuite::load(e.path()))
        .collect::<fgmp::Result<_>>()?;
    suites.sort_by(|a, b| a.name.cmp(&b.name));

    let configs: Vec<(String, QuantConfig)> = vec![
        ("BF16".into(), QuantConfig { ratio: fgmp::model::RatioSpec::Bf16, ..QuantConfig::fgmp(0.0) }),
        ("FP8".into(), QuantConfig::all_fp8()),
        ("FP4".into(), QuantConfig::all_fp4()),
        ("90% FP4".into(), QuantConfig::fgmp(0.9)),
        ("70% FP4".into(), QuantConfig::fgmp(0.7)),
    ];

    for model in models.split(',') {
        let ev = Evaluator::load(&rt, &artifacts, model)?;
        println!("\n== Tables 2-3: {model} (accuracy, {max_items} items/suite; FGMP_ITEMS, FGMP_MODELS env to widen) ==");
        print!("{:<12}", "precision");
        for s in &suites {
            print!(" {:>16}", s.name);
        }
        println!(" {:>8}", "average");
        for (label, cfg) in &configs {
            print!("{label:<12}");
            let is_bf16 = matches!(cfg.ratio, fgmp::model::RatioSpec::Bf16);
            let (exe, tail) = if is_bf16 {
                (&ev.fwd_ref, ev.ref_arg_tail()?)
            } else {
                let qm = QuantizedModel::quantize(&ev.arts, cfg)?;
                (&ev.fwd_quant, ev.quant_arg_tail(cfg, &qm)?)
            };
            let mut total = 0.0;
            for s in &suites {
                let acc = score_suite(exe, &tail, s, ev.batch, ev.seq, max_items)?;
                total += acc;
                print!(" {acc:>16.3}");
            }
            println!(" {:>8.3}", total / suites.len() as f64);
        }
    }
    println!("\nexpected shape (paper): FGMP 70%/90% rows recover most of the");
    println!("FP8->FP4 accuracy drop (58-89% less degradation on MMLU).");
    Ok(())
}
