//! Fig. 7: percentage of blocks retained in FP8 per layer and projection
//! kind (QKV / O / FC1 / FC2) for weights and activations at 90% FP4 with
//! the global threshold — the paper's evidence that a single threshold
//! adapts the FP8 budget to layer sensitivity.
//!
//!     cargo bench --bench fig7_layer_profile

use std::collections::BTreeMap;

use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, QuantizedModel};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;

    let cfg = QuantConfig::fgmp(0.9);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
    let rep = ev.perplexity(&cfg, Some(&qm), batches)?;

    println!("== Fig. 7: %FP8 blocks per layer @ 90% FP4 (tiny-llama) ==");
    println!("{:<18} {:>10} {:>10}", "linear", "weights", "acts");
    let mut by_kind: BTreeMap<String, (Vec<f64>, Vec<f64>)> = BTreeMap::new();
    for (i, l) in qm.linears.iter().enumerate() {
        let spec = &ev.arts.manifest.linears[i];
        let w = l.packed.fp8_fraction() * 100.0;
        let a = rep.act_fp8[i] * 100.0;
        println!("{:<18} {:>9.2}% {:>9.2}%", l.name, w, a);
        let e = by_kind.entry(spec.kind.clone()).or_default();
        e.0.push(w);
        e.1.push(a);
    }
    println!("\n{:<10} {:>12} {:>12} {:>14} {:>14}", "kind", "W mean%", "A mean%", "W spread(pp)", "A spread(pp)");
    for (kind, (w, a)) in &by_kind {
        let mean = |v: &Vec<f64>| v.iter().sum::<f64>() / v.len() as f64;
        let spread = |v: &Vec<f64>| {
            v.iter().cloned().fold(f64::MIN, f64::max) - v.iter().cloned().fold(f64::MAX, f64::min)
        };
        println!("{:<10} {:>11.2}% {:>11.2}% {:>14.2} {:>14.2}",
                 kind, mean(w), mean(a), spread(w), spread(a));
    }
    println!("\nexpected shape (paper): per-layer fractions differ widely from the");
    println!("global 10% average — the spread columns are far from zero.");
    Ok(())
}
