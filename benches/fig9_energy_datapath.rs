//! Fig. 9: energy efficiency of the FGMP datapath as a function of the
//! weight/activation FP8 block proportions, plus the four single-format
//! reference points (the labelled boxes in the paper's figure).
//!
//!     cargo bench --bench fig9_energy_datapath

use fgmp::hwsim::datapath::{simulate_matmul, simulate_single_format, DatapathConfig, MatmulJob};
use fgmp::hwsim::energy::{DotUnit, EnergyModel};

fn main() {
    let cfg = DatapathConfig::default();
    let em = EnergyModel::default();
    let base = MatmulJob { m: 1024, k: 1024, n: 1024, weight_fp8: 1.0, act_fp8: 1.0 };

    let fp8_ref = simulate_single_format(&cfg, &em, &base, DotUnit::Fp8Fp8);
    let norm = |pj: f64| pj / fp8_ref.dot_energy_pj;

    println!("== Fig. 9: single-format reference points (energy / FP8 energy) ==");
    for (name, unit) in [
        ("FP8 x FP8", DotUnit::Fp8Fp8),
        ("NVFP4 x NVFP4", DotUnit::Fp4Fp4),
        ("FP4w x FP8a", DotUnit::Fp4Fp8),
        ("FP8w x FP4a", DotUnit::Fp8Fp4),
    ] {
        let r = simulate_single_format(&cfg, &em, &base, unit);
        println!("  {:<14} {:>6.3}  (savings {:>5.1}%)", name, norm(r.dot_energy_pj),
                 (1.0 - norm(r.dot_energy_pj)) * 100.0);
    }

    println!("\n== Fig. 9 surface: normalized FGMP dot-product energy ==");
    print!("{:>8}", "W\\A fp8");
    for a in (0..=10).map(|i| i as f64 / 10.0) {
        print!(" {:>6.0}%", a * 100.0);
    }
    println!();
    for w in (0..=10).map(|i| i as f64 / 10.0) {
        print!("{:>7.0}%", w * 100.0);
        for a in (0..=10).map(|i| i as f64 / 10.0) {
            let job = MatmulJob { weight_fp8: w, act_fp8: a, ..base.clone() };
            let r = simulate_matmul(&cfg, &em, &job, false);
            print!(" {:>7.3}", norm(r.dot_energy_pj));
        }
        println!();
    }
    println!("\nexpected (paper §5.4.2): NVFP4 33% below FP8; mixed units 16–17%");
    println!("below; the 100%/100% FGMP corner slightly ABOVE 1.0 (mux tax).");
}
