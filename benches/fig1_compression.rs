//! Fig. 1: perplexity degradation vs compression rate for Llama-2-7B
//! (tiny-llama stand-in). Regenerates the FGMP points (70/80/90% FP4), the
//! microscale all-NVFP4 point, and the all-FP8 reference — the paper's
//! claim is that FGMP dominates the single-format points on this plane.
//!
//!     cargo bench --bench fig1_compression

use fgmp::eval::sweep::{format_rows, run_sweep};
use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, RatioSpec};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(8);
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;

    let configs = vec![
        QuantConfig { ratio: RatioSpec::Bf16, ..QuantConfig::fgmp(0.0) },
        QuantConfig::all_fp8(),
        QuantConfig::fgmp(0.7),
        QuantConfig::fgmp(0.8),
        QuantConfig::fgmp(0.9),
        QuantConfig::all_fp4(), // the "µscale" NVFP4 comparator
    ];
    let rows = run_sweep(&ev, &configs, batches)?;
    println!("== Fig. 1: perplexity degradation vs compression rate (tiny-llama) ==");
    print!("{}", format_rows(&rows));
    println!("\nexpected shape (paper): FGMP rows sit below the all-FP4 row in");
    println!("dPPL at strictly higher compression than all-FP8.");
    Ok(())
}
