//! Fig. 6: policy ablation on tiny-llama — FGMP (Fisher, global threshold,
//! clip) vs Quantization-Error / Output-Error baselines (per-layer
//! thresholds, as in the paper) and the FGMP variants without the global
//! threshold and/or clipping.
//!
//!     cargo bench --bench fig6_policy_ablation

use fgmp::eval::Evaluator;
use fgmp::model::{QuantConfig, QuantizedModel, RatioSpec};
use fgmp::policy::{Policy, ThresholdMode};
use fgmp::runtime::Runtime;

fn main() -> fgmp::Result<()> {
    let artifacts = std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let batches: usize = std::env::var("FGMP_BATCHES").ok()
        .and_then(|v| v.parse().ok()).unwrap_or(4);
    let rt = Runtime::cpu()?;
    let ev = Evaluator::load(&rt, &artifacts, "tiny-llama")?;

    let variants: Vec<(&str, Policy, ThresholdMode, bool)> = vec![
        ("FGMP (ours)", Policy::Fisher, ThresholdMode::Global, true),
        ("FGMP w/o clip", Policy::Fisher, ThresholdMode::Global, false),
        ("FGMP w/o global/clip", Policy::Fisher, ThresholdMode::Local, false),
        ("Quantization Error", Policy::QuantError, ThresholdMode::Local, false),
        ("Output Error", Policy::OutputError, ThresholdMode::Local, false),
    ];

    println!("== Fig. 6: perplexity by policy, tiny-llama ==");
    print!("{:>8}", "%FP8");
    for (name, ..) in &variants {
        print!(" {name:>22}");
    }
    println!();
    for fp8_pct in [5.0, 10.0, 20.0, 30.0, 50.0] {
        let fp4 = 1.0 - fp8_pct / 100.0;
        print!("{fp8_pct:>7.0}%");
        for (_, pol, mode, clip) in &variants {
            let cfg = QuantConfig {
                ratio: RatioSpec::Fp4Fraction(fp4),
                policy: *pol,
                threshold_mode: *mode,
                sw_clip: *clip,
            };
            let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
            let rep = ev.perplexity(&cfg, Some(&qm), batches)?;
            print!(" {:>22.4}", rep.ppl);
        }
        println!();
    }
    println!("\nexpected shape (paper): the FGMP column dominates (lowest ppl),");
    println!("with the gap widening at small %FP8; QE/OE trail.");
    Ok(())
}
