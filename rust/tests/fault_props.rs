//! Fault-injection properties over the engines (`util::faults` armed):
//! typed worker-panic recovery, mid-roll pool exhaustion restoring the
//! books bit-identically, injected decode failures leaving sessions
//! stepable, and preempt/resume streams staying bit-exact — the
//! engine-level halves of the coordinator's chaos story.
//!
//! The failpoint registry is process-global, so every test here serializes
//! on one lock and disarms on every exit path (a drop guard), keeping each
//! test's seeded schedule deterministic.

use std::sync::{Mutex, MutexGuard};

use fgmp::eval::Evaluator;
use fgmp::model::{KvPrecision, QuantConfig, QuantizedModel};
use fgmp::runtime::{
    build_engine, ArgValue, Engine, EngineError, EngineOptions, ExecSpec, GraphKind,
    InferenceEngine, Runtime, Session,
};
use fgmp::util::faults;

/// Serializes fault tests: the registry is process-global, and an armed
/// schedule must never leak into a concurrently running test.
static LOCK: Mutex<()> = Mutex::new(());

/// Hold the registry for one test; disarm on drop (even under panic).
struct FaultScope {
    _guard: MutexGuard<'static, ()>,
}

impl FaultScope {
    fn acquire() -> Self {
        let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        faults::disarm();
        FaultScope { _guard: guard }
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        faults::disarm();
    }
}

struct Harness {
    rt: Runtime,
    tail: Vec<ArgValue>,
    logits: ExecSpec,
    stream: Vec<i32>,
}

fn harness(name: &str) -> Harness {
    let dir = std::env::temp_dir().join(format!("fgmp_fault_props_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");
    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let logits = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);
    let stream = ev.test_stream.clone();
    Harness { rt, tail, logits, stream }
}

/// Greedy stream of `n` tokens from a fresh session over `prompt`.
fn run_stream<E: InferenceEngine + ?Sized>(engine: &E, prompt: &[i32], n: usize) -> Vec<i32> {
    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];
    while produced.len() < n {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.push(sess.next_token());
    }
    produced
}

/// A tensor-parallel worker panic surfaces as the typed
/// [`EngineError::WorkerFailed`] (never an unwinding process), the failed
/// step restores every shard, and retrying once the fault clears continues
/// the exact reference stream.
#[test]
fn fault_worker_panic_recovers_cleanly() {
    let _scope = FaultScope::acquire();
    let h = harness("worker_panic");
    let opts = EngineOptions::default().kv(KvPrecision::Fp16).workers(2);
    let boxed = build_engine(&h.rt, &h.logits, h.tail.clone(), opts).unwrap();
    let engine = boxed.as_ref();
    let prompt = &h.stream[..12];
    let want = 6usize;
    let expected = run_stream(engine, prompt, want);

    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];

    faults::arm(0xFA17);
    faults::set(faults::WORKER_PANIC, 1.0);
    let before_tokens = sess.tokens.clone();
    let before_cached = sess.cached_tokens();
    let err = {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap_err()
    };
    match EngineError::classify(&err) {
        Some(EngineError::WorkerFailed { .. }) => {}
        other => panic!("expected WorkerFailed, got {other:?} ({err})"),
    }
    assert!(EngineError::is_transient(&err));
    // The failed step restored the session: same context, same cache —
    // and a panicked prefill types identically (with nothing to restore).
    assert_eq!(sess.tokens, before_tokens);
    assert_eq!(sess.cached_tokens(), before_cached);
    let perr = engine.prefill(&h.stream[64..70]).unwrap_err();
    assert!(EngineError::is_transient(&perr), "panicked prefill must be typed: {perr}");
    faults::disarm();

    while produced.len() < want {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.push(sess.next_token());
    }
    assert_eq!(produced, expected, "retried stream must be bit-exact");
}

/// Mid-roll pool exhaustion (injected at the page-allocation seam) leaves
/// the pool's books and the session's cache bit-identical to the pre-step
/// state, and the retried step continues the exact uninterrupted stream.
#[test]
fn fault_midroll_exhaustion_restores_books() {
    let _scope = FaultScope::acquire();
    let h = harness("midroll");
    let opts = EngineOptions::default().kv(KvPrecision::Fp16).pages(Some(96));
    let engine = Engine::with_options(&h.rt, &h.logits, h.tail.clone(), opts).unwrap();
    let max_seq = engine.arch().max_seq;
    let prompt = &h.stream[..max_seq];
    let want = 6usize;

    // Uninterrupted reference: the very first decode step must roll, since
    // prefill filled the cache to the boundary.
    let reference = {
        let opts = EngineOptions::default().kv(KvPrecision::Fp16).pages(Some(96));
        let eng = Engine::with_options(&h.rt, &h.logits, h.tail.clone(), opts).unwrap();
        run_stream(&eng, prompt, want)
    };

    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];
    assert_eq!(sess.cached_tokens(), max_seq, "prefill must reach the roll boundary");

    let before = engine.pool_stats().unwrap();
    let before_tokens = sess.tokens.clone();
    let next = sess.next_token();
    faults::arm(0x60AF);
    faults::set(faults::KV_ALLOC, 1.0);
    let err = {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap_err()
    };
    assert!(EngineError::is_exhausted(&err), "injected alloc failure must be typed: {err}");
    faults::disarm();

    // Books restored bit-identically: same pages in use, same logical
    // pages, same session context — the failed roll leaked nothing.
    let after = engine.pool_stats().unwrap();
    assert_eq!(after.in_use_pages, before.in_use_pages, "failed roll leaked pages");
    assert_eq!(after.logical_pages, before.logical_pages);
    assert_eq!(sess.tokens, before_tokens);
    assert_eq!(sess.cached_tokens(), max_seq);
    assert_eq!(sess.next_token(), next, "logits disturbed by the failed roll");

    while produced.len() < want {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.push(sess.next_token());
    }
    assert_eq!(produced, reference, "post-failure stream must be bit-exact");
}

/// An injected decode failure fails the *step*, not the sessions: every
/// session in the batch stays stepable and the retried steps continue the
/// exact reference streams.
#[test]
fn fault_decode_step_failure_leaves_sessions_stepable() {
    let _scope = FaultScope::acquire();
    let h = harness("decode_fail");
    let engine = Engine::new(&h.rt, &h.logits, h.tail.clone(), KvPrecision::Fp16).unwrap();
    let want = 5usize;
    let prompts: Vec<Vec<i32>> = (0..3).map(|i| h.stream[i * 24..i * 24 + 8].to_vec()).collect();
    let expected: Vec<Vec<i32>> = prompts.iter().map(|p| run_stream(&engine, p, want)).collect();

    let mut sessions = engine.prefill_batch(&prompts).unwrap();
    let mut produced: Vec<Vec<i32>> = sessions.iter().map(|s| vec![s.next_token()]).collect();

    faults::arm(0xDECD);
    faults::set(faults::ENGINE_DECODE, 1.0);
    let err = {
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        engine.decode_step(&mut refs).unwrap_err()
    };
    assert_eq!(
        EngineError::classify(&err),
        Some(EngineError::Injected { point: faults::ENGINE_DECODE })
    );
    assert_eq!(faults::fires(faults::ENGINE_DECODE), 1);
    faults::disarm();

    while produced[0].len() < want {
        let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
        engine.decode_step(&mut refs).unwrap();
        for (s, p) in sessions.iter().zip(produced.iter_mut()) {
            p.push(s.next_token());
        }
    }
    assert_eq!(produced, expected, "streams must survive an injected step failure");
}

/// The coordinator's preempt/resume contract at the engine level: drop a
/// live session mid-stream and re-prefill its tokens plus the one
/// produced-but-unconsumed token — roll-normalized exactly the way the
/// server's `preempt_youngest` does — and the greedy stream continues
/// bit-exactly, with and without prefix-index donation, including across
/// the roll boundary.
#[test]
fn preempt_resume_stream_is_bit_exact() {
    let _scope = FaultScope::acquire();
    let h = harness("preempt_resume");
    for share in [false, true] {
        let opts = EngineOptions::default().kv(KvPrecision::Fp16).prefix_share(share);
        let engine = Engine::with_options(&h.rt, &h.logits, h.tail.clone(), opts).unwrap();
        let max_seq = engine.arch().max_seq;
        // A near-boundary prompt so the stream crosses a roll mid-flight.
        let prompt = &h.stream[..max_seq - 2];
        let want = 8usize;
        let reference = {
            let opts = EngineOptions::default().kv(KvPrecision::Fp16).prefix_share(share);
            let eng = Engine::with_options(&h.rt, &h.logits, h.tail.clone(), opts).unwrap();
            run_stream(&eng, prompt, want)
        };
        for preempt_after in [1usize, 4] {
            let mut sess = engine.prefill(prompt).unwrap();
            let mut produced = vec![sess.next_token()];
            while produced.len() < preempt_after {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap();
                produced.push(sess.next_token());
            }
            // Preempt: donate (prefix engines keep the computed pages
            // alive under the index), rebuild the resume context, drop the
            // session — its pages return to the pool.
            let donated = engine.preempt_donate(&sess);
            assert_eq!(donated, share, "donation requires the prefix index");
            let mut resume = sess.tokens.clone();
            if resume.len() >= max_seq {
                let keep = (max_seq / 2).max(1);
                resume.drain(..resume.len() - keep);
            }
            resume.push(*produced.last().unwrap());
            drop(sess);

            let mut sess = engine.prefill(&resume).unwrap();
            produced.push(sess.next_token());
            while produced.len() < want {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap();
                produced.push(sess.next_token());
            }
            assert_eq!(
                produced, reference,
                "share={share} preempt_after={preempt_after}: resumed stream diverged"
            );
        }
    }
}
