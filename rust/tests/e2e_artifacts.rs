//! Integration tests over the real artifacts (skipped with a notice when
//! `make artifacts` hasn't run): the python→rust interchange, the full
//! quantization pipeline, and the PJRT evaluation path.

use fgmp::eval::Evaluator;
use fgmp::io::TensorFile;
use fgmp::model::{ModelArtifacts, QuantConfig, QuantizedModel, RatioSpec};
use fgmp::policy::{Policy, ThresholdMode};
use fgmp::runtime::Runtime;

fn artifacts_dir() -> Option<std::path::PathBuf> {
    let dir = std::path::PathBuf::from(
        std::env::var("FGMP_ARTIFACTS").unwrap_or_else(|_| {
            format!("{}/artifacts", env!("CARGO_MANIFEST_DIR"))
        }),
    );
    if dir.join("tiny-llama/manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("NOTE: artifacts missing at {dir:?} — run `make artifacts`; skipping");
        None
    }
}

#[test]
fn tensorfile_reads_python_written_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let tf = TensorFile::load(dir.join("tiny-llama/weights.fgtn")).unwrap();
    assert!(tf.contains("embed"));
    let embed = tf.get("embed").unwrap();
    assert_eq!(embed.shape, vec![512, 256]);
    // re-write and re-read: byte-stable container
    let tmp = std::env::temp_dir().join("fgmp_rt_weights.fgtn");
    tf.save(&tmp).unwrap();
    let back = TensorFile::load(&tmp).unwrap();
    assert_eq!(back.names, tf.names);
    assert_eq!(back.get("embed").unwrap(), embed);
}

#[test]
fn corpus_splits_present_and_sane() {
    let Some(dir) = artifacts_dir() else { return };
    let corpus = TensorFile::load(dir.join("corpus.fgtn")).unwrap();
    for split in ["train", "valid", "test"] {
        let s = corpus.get(split).unwrap().as_i32().unwrap();
        assert!(s.len() >= 4096, "{split} too short");
        assert!(s.iter().all(|&t| (0..512).contains(&t)), "{split} token range");
    }
}

#[test]
fn quantize_pipeline_hits_target_fractions() {
    let Some(dir) = artifacts_dir() else { return };
    let arts = ModelArtifacts::load(dir.join("tiny-llama")).unwrap();
    for fp4 in [0.3, 0.7, 0.9] {
        let qm = QuantizedModel::quantize(&arts, &QuantConfig::fgmp(fp4)).unwrap();
        let got = 1.0 - qm.weight_fp8_fraction();
        assert!((got - fp4).abs() < 0.02, "target {fp4}, got {got}");
    }
}

#[test]
fn swclip_reduces_weight_roundtrip_error() {
    let Some(dir) = artifacts_dir() else { return };
    let arts = ModelArtifacts::load(dir.join("tiny-llama")).unwrap();
    let clip = QuantizedModel::quantize(&arts, &QuantConfig::fgmp(1.0)).unwrap();
    let noclip = QuantizedModel::quantize(
        &arts,
        &QuantConfig { sw_clip: false, ..QuantConfig::fgmp(1.0) },
    )
    .unwrap();
    // Fisher-weighted total error must not increase with clipping.
    let mut err_clip = 0.0f64;
    let mut err_noclip = 0.0f64;
    for (lc, ln) in clip.linears.iter().zip(&noclip.linears) {
        let spec = arts.manifest.linear(&lc.name).unwrap();
        let w = arts.weights.get(&format!("{}.w", lc.name)).unwrap().as_f32().unwrap();
        let f = arts.fisher_w.get(&format!("{}.w.fisher", lc.name)).unwrap().as_f32().unwrap();
        for ki in 0..spec.k_in {
            for ni in 0..spec.n_out {
                let idx = ki * spec.n_out + ni;
                let d1 = (lc.dequant[idx] - w[idx]) as f64;
                let d2 = (ln.dequant[idx] - w[idx]) as f64;
                err_clip += f[idx] as f64 * d1 * d1;
                err_noclip += f[idx] as f64 * d2 * d2;
            }
        }
    }
    assert!(err_clip <= err_noclip * (1.0 + 1e-9),
            "SW-Clip error {err_clip} vs dynamic-max {err_noclip}");
}

#[test]
fn pjrt_eval_ordering_fp8_fgmp_fp4() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();

    let fp8 = QuantConfig::all_fp8();
    let q8 = QuantizedModel::quantize(&ev.arts, &fp8).unwrap();
    let p8 = ev.perplexity(&fp8, Some(&q8), 2).unwrap();

    let fp4 = QuantConfig::all_fp4();
    let q4 = QuantizedModel::quantize(&ev.arts, &fp4).unwrap();
    let p4 = ev.perplexity(&fp4, Some(&q4), 2).unwrap();

    let mixed = QuantConfig::fgmp(0.7);
    let qmix = QuantizedModel::quantize(&ev.arts, &mixed).unwrap();
    let pm = ev.perplexity(&mixed, Some(&qmix), 2).unwrap();

    let bf16 = QuantConfig { ratio: RatioSpec::Bf16, policy: Policy::Fisher,
                             threshold_mode: ThresholdMode::Global, sw_clip: false };
    let pb = ev.perplexity(&bf16, None, 2).unwrap();

    // sanity: all finite and in a plausible band for the trained model
    for (name, p) in [("bf16", &pb), ("fp8", &p8), ("fgmp", &pm), ("fp4", &p4)] {
        assert!(p.ppl.is_finite() && p.ppl > 1.0 && p.ppl < 200.0, "{name} ppl {}", p.ppl);
    }
    // the paper's ordering: FP4-only degrades most; FGMP sits at or below
    // the midpoint toward FP8.
    assert!(p4.ppl >= p8.ppl - 1e-6, "fp4 {} vs fp8 {}", p4.ppl, p8.ppl);
    assert!(pm.ppl <= p4.ppl + 1e-6, "fgmp {} vs fp4 {}", pm.ppl, p4.ppl);
    // PPU fractions behave
    assert!(p8.mean_act_fp8() > 0.99);
    assert!(p4.mean_act_fp8() < 0.01);
    let f = pm.mean_act_fp8();
    assert!(f > 0.05 && f < 0.75, "mixed act fp8 fraction {f}");
}

#[test]
fn weight_only_path_matches_ref_graph() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    // all-FP8 weight-only should be extremely close to BF16 on a tiny model
    let q8 = QuantizedModel::quantize(&ev.arts, &QuantConfig::all_fp8()).unwrap();
    let wo = ev.perplexity_weight_only(&q8, 2).unwrap();
    let bf16 = QuantConfig { ratio: RatioSpec::Bf16, policy: Policy::Fisher,
                             threshold_mode: ThresholdMode::Global, sw_clip: false };
    let pb = ev.perplexity(&bf16, None, 2).unwrap();
    assert!((wo.ppl - pb.ppl).abs() / pb.ppl < 0.02,
            "weight-only FP8 {} vs BF16 {}", wo.ppl, pb.ppl);
}
