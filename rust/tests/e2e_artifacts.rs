//! Always-on end-to-end integration suite over synthetic artifacts.
//!
//! Each test binary synthesizes its artifact set once (deterministic, a few
//! seconds) into a temp dir — no Python, no PJRT, no network. The numeric
//! margins below were calibrated against an independent numpy mirror of the
//! native runtime (same RNG streams, same quantization lattices), so they
//! hold with wide slack: e.g. the FP4-vs-FP8 L1 nll distortion ratio
//! measures ≈3× where we assert >1×.

use std::path::PathBuf;
use std::sync::OnceLock;

use fgmp::eval::Evaluator;
use fgmp::io::{synth, TensorFile};
use fgmp::model::{ModelArtifacts, QuantConfig, QuantizedModel, RatioSpec};
use fgmp::policy::{Policy, ThresholdMode};
use fgmp::runtime::Runtime;

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join("fgmp_e2e_synth_artifacts");
        // Rebuild from scratch so stale layouts never leak across versions.
        let _ = std::fs::remove_dir_all(&dir);
        synth::build_default(&dir).expect("synthesize artifacts");
        dir
    })
}

fn bf16_config() -> QuantConfig {
    QuantConfig {
        ratio: RatioSpec::Bf16,
        policy: Policy::Fisher,
        threshold_mode: ThresholdMode::Global,
        sw_clip: false,
    }
}

#[test]
fn tensorfile_roundtrips_synthetic_weights() {
    let dir = artifacts_dir();
    let tf = TensorFile::load(dir.join("tiny-llama/weights.fgtn")).unwrap();
    assert!(tf.contains("embed"));
    let embed = tf.get("embed").unwrap();
    assert_eq!(embed.shape, vec![synth::VOCAB, 96]);
    // re-write and re-read: byte-stable container
    let tmp = std::env::temp_dir().join("fgmp_rt_weights.fgtn");
    tf.save(&tmp).unwrap();
    let back = TensorFile::load(&tmp).unwrap();
    assert_eq!(back.names, tf.names);
    assert_eq!(back.get("embed").unwrap(), embed);
}

#[test]
fn corpus_splits_present_and_sane() {
    let dir = artifacts_dir();
    let corpus = TensorFile::load(dir.join("corpus.fgtn")).unwrap();
    for split in ["train", "valid", "test"] {
        let s = corpus.get(split).unwrap().as_i32().unwrap();
        assert!(s.len() >= 4096, "{split} too short");
        assert!(
            s.iter().all(|&t| (0..synth::VOCAB as i32).contains(&t)),
            "{split} token range"
        );
    }
}

#[test]
fn quantize_pipeline_hits_target_fractions() {
    let dir = artifacts_dir();
    let arts = ModelArtifacts::load(dir.join("tiny-llama")).unwrap();
    for fp4 in [0.3, 0.7, 0.9] {
        let qm = QuantizedModel::quantize(&arts, &QuantConfig::fgmp(fp4)).unwrap();
        let got = 1.0 - qm.weight_fp8_fraction();
        assert!((got - fp4).abs() < 0.02, "target {fp4}, got {got}");
    }
}

#[test]
fn swclip_reduces_weight_roundtrip_error() {
    let dir = artifacts_dir();
    let arts = ModelArtifacts::load(dir.join("tiny-llama")).unwrap();
    let clip = QuantizedModel::quantize(&arts, &QuantConfig::fgmp(1.0)).unwrap();
    let noclip = QuantizedModel::quantize(
        &arts,
        &QuantConfig { sw_clip: false, ..QuantConfig::fgmp(1.0) },
    )
    .unwrap();
    // Fisher-weighted total error must not increase with clipping (SW-Clip
    // optimizes exactly this objective per block).
    let mut err_clip = 0.0f64;
    let mut err_noclip = 0.0f64;
    for (lc, ln) in clip.linears.iter().zip(&noclip.linears) {
        let spec = arts.manifest.linear(&lc.name).unwrap();
        let w = arts.weights.get(&format!("{}.w", lc.name)).unwrap().as_f32().unwrap();
        let f = arts.fisher_w.get(&format!("{}.w.fisher", lc.name)).unwrap().as_f32().unwrap();
        // On-demand materialization — no resident dequant copy anymore.
        let lcd = lc.dequant();
        let lnd = ln.dequant();
        for ki in 0..spec.k_in {
            for ni in 0..spec.n_out {
                let idx = ki * spec.n_out + ni;
                let d1 = (lcd[idx] - w[idx]) as f64;
                let d2 = (lnd[idx] - w[idx]) as f64;
                err_clip += f[idx] as f64 * d1 * d1;
                err_noclip += f[idx] as f64 * d2 * d2;
            }
        }
    }
    assert!(err_clip <= err_noclip * (1.0 + 1e-9),
            "SW-Clip error {err_clip} vs dynamic-max {err_noclip}");
}

#[test]
fn native_eval_quant_configs_end_to_end() {
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();

    let pb = ev.perplexity(&bf16_config(), None, 2).unwrap();

    let fp8 = QuantConfig::all_fp8();
    let q8 = QuantizedModel::quantize(&ev.arts, &fp8).unwrap();
    let p8 = ev.perplexity(&fp8, Some(&q8), 2).unwrap();

    let fp4 = QuantConfig::all_fp4();
    let q4 = QuantizedModel::quantize(&ev.arts, &fp4).unwrap();
    let p4 = ev.perplexity(&fp4, Some(&q4), 2).unwrap();

    let mixed = QuantConfig::fgmp(0.7);
    let qmix = QuantizedModel::quantize(&ev.arts, &mixed).unwrap();
    let pm = ev.perplexity(&mixed, Some(&qmix), 2).unwrap();

    // Sanity: finite, plausible perplexities (untrained synthetic model sits
    // near the vocab size; mirror measures ≈272 for V=256).
    for (name, p) in [("bf16", &pb), ("fp8", &p8), ("fgmp", &pm), ("fp4", &p4)] {
        assert!(p.ppl.is_finite() && p.ppl > 1.0 && p.ppl < 1e4, "{name} ppl {}", p.ppl);
        assert_eq!(p.batches, 2, "{name} batches");
    }

    // PPU counters: the −1 / +inf sentinel thresholds are exact extremes.
    assert_eq!(p8.mean_act_fp8(), 1.0, "all-FP8 PPU fraction");
    assert_eq!(p4.mean_act_fp8(), 0.0, "all-FP4 PPU fraction");
    // Mixed: the calibrated global threshold realizes a mid-range fraction
    // (mirror: ≈0.42 at the 70% FP4 operating point).
    let f = pm.mean_act_fp8();
    assert!(f > 0.05 && f < 0.8, "mixed act fp8 fraction {f}");

    // Distortion ordering: FP4's nll perturbation vs the BF16 reference
    // dominates FP8's (mirror ratio ≈3×; assert >1×). Summed L1 over the
    // same deterministic windows.
    let d8 = (p8.nll_sum - pb.nll_sum).abs();
    let d4 = (p4.nll_sum - pb.nll_sum).abs();
    assert!(
        d4 > d8,
        "FP4 distortion {d4} should exceed FP8 distortion {d8}"
    );
    // Seed-calibrated ordering with wide slack vs cross-impl noise
    // (mirror: fp4 273.0 vs fp8 274.2 → margin ≈1.2, noise ≈0.05).
    assert!(p4.ppl > p8.ppl - 0.5, "fp4 {} vs fp8 {}", p4.ppl, p8.ppl);
}

#[test]
fn weight_only_path_matches_ref_graph() {
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    // all-FP8 weight-only should sit very close to BF16 (mirror: 0.24% off)
    let q8 = QuantizedModel::quantize(&ev.arts, &QuantConfig::all_fp8()).unwrap();
    let wo = ev.perplexity_weight_only(&q8, 2).unwrap();
    let pb = ev.perplexity(&bf16_config(), None, 2).unwrap();
    assert!((wo.ppl - pb.ppl).abs() / pb.ppl < 0.05,
            "weight-only FP8 {} vs BF16 {}", wo.ppl, pb.ppl);
}

#[test]
fn logits_graph_serves_generation_shapes() {
    use fgmp::runtime::{ExecSpec, GraphKind};
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let exe = rt
        .load_spec(&ExecSpec::new(dir, "tiny-llama", GraphKind::LogitsQuant))
        .unwrap();
    let (b, s) = (ev.batch, ev.seq);
    let tokens: Vec<i32> = ev.eval_windows(1)[0].clone();
    let mut args = vec![fgmp::runtime::ArgValue::I32 { shape: vec![b, s], data: tokens }];
    args.extend(tail.iter().cloned());
    let out = exe.run(&args).unwrap();
    assert_eq!(out.len(), 1);
    assert_eq!(out[0].len(), b * ev.arts.manifest.vocab);
    assert!(out[0].iter().all(|v| v.is_finite()));
}

#[test]
fn coordinator_serves_score_requests_natively() {
    use fgmp::coordinator::{BatchPolicy, Request, RequestKind, Server, ServerConfig};
    use fgmp::runtime::{ExecSpec, GraphKind};

    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy::default(),
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: fgmp::model::KvPrecision::Fp8,
        decode_batch: 4,
        kv_pages: None,
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
        deadline_ms: None,
        promote_after_ms: 0,
    };
    let fwd = ExecSpec::new(dir, "tiny-llama", GraphKind::FwdQuant);
    let logits = ExecSpec::new(dir, "tiny-llama", GraphKind::LogitsQuant);
    let server = Server::start(scfg, fwd, tail.clone(), logits, tail).unwrap();

    let windows = ev.eval_windows(2);
    let seq = ev.seq;
    let mut rxs = Vec::new();
    let mut id = 0u64;
    for w in &windows {
        for row in w.chunks_exact(seq) {
            let (req, rx) = Request::new(
                id,
                RequestKind::Score { tokens: row.to_vec(), mask: vec![1.0; seq] },
            );
            id += 1;
            server.router.submit(req).unwrap();
            rxs.push(rx);
        }
    }
    // one generation request rides along
    let (gr, grx) = Request::new(
        10_000,
        RequestKind::Generate { prompt: windows[0][..8].to_vec(), n_tokens: 3 },
    );
    server.router.submit(gr).unwrap();

    let mut toks = 0.0f64;
    let mut nll = 0.0f64;
    for rx in rxs {
        let resp = rx.recv().expect("score response");
        let (s_nll, s_tok) = resp.nll.expect("nll present");
        nll += s_nll;
        toks += s_tok;
    }
    let gen = grx.recv().expect("gen response");
    let produced = gen.generated.expect("tokens generated");
    assert_eq!(produced.len(), 3);
    assert!(produced.iter().all(|&t| (0..synth::VOCAB as i32).contains(&t)));

    assert_eq!(toks as usize, id as usize * (seq - 1));
    let ppl = (nll / toks).exp();
    assert!(ppl.is_finite() && ppl > 1.0 && ppl < 1e4, "served ppl {ppl}");

    let snap = server.metrics.snapshot();
    assert_eq!(snap.requests, id);
    assert!(snap.energy_fp8_j > 0.0 && snap.energy_j > 0.0);
    assert!(snap.energy_savings > 0.0, "mixed precision must save energy");
    // The generation rode the continuous-batching decode loop: 3 tokens =
    // prefill + 2 batched steps, with TTFT recorded.
    assert!(snap.decode_steps >= 2, "decode steps {}", snap.decode_steps);
    assert!(snap.mean_decode_occupancy > 0.0);
    assert_eq!(snap.generated_tokens, 3);
    server.shutdown();
}

#[test]
fn sweep_rows_are_coherent() {
    use fgmp::eval::run_sweep;
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let configs = vec![bf16_config(), QuantConfig::all_fp8(), QuantConfig::fgmp(0.7)];
    let rows = run_sweep(&ev, &configs, 1).unwrap();
    assert_eq!(rows.len(), 3);
    assert_eq!(rows[0].label, "BF16");
    for r in &rows[1..] {
        assert!(r.ppl.is_finite());
        assert!(r.energy_norm.is_finite() && r.energy_norm > 0.0);
        assert!(r.weight_bits_per_elem > 4.0 && r.weight_bits_per_elem <= 8.1);
        assert!(r.compression_rate > 1.0);
    }
    // the mixed row compresses harder than the all-FP8 row
    assert!(rows[2].weight_bits_per_elem < rows[1].weight_bits_per_elem);
    assert!(rows[2].energy_norm < 1.0 && rows[1].energy_norm > 1.0);
}

#[test]
fn task_suites_score_through_native_graphs() {
    use fgmp::eval::tasks::{score_suite, TaskSuite};
    let dir = artifacts_dir();
    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let suite = TaskSuite::load(dir.join("tasks/cloze_hard.json")).unwrap();
    let cfg = QuantConfig::all_fp8();
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let acc = score_suite(&ev.fwd_quant, &tail, &suite, ev.batch, ev.seq, 8).unwrap();
    assert!((0.0..=1.0).contains(&acc), "accuracy {acc}");
}

/// The d_model=512 perf-scale preset, end to end: synthesize artifacts,
/// load an `Evaluator`, quantize, and score perplexity through the native
/// fwd_quant graph. Gated behind `FGMP_E2E_LARGE=1` (the CI release job
/// sets it) so the default `cargo test -q` stays fast.
#[test]
fn large_preset_round_trips_through_evaluator() {
    if std::env::var("FGMP_E2E_LARGE").is_err() {
        eprintln!("skipping large-preset e2e (set FGMP_E2E_LARGE=1 to run)");
        return;
    }
    let dir = std::env::temp_dir().join("fgmp_e2e_large_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    synth::ensure_model(&dir, "small-llama", 42).expect("synthesize small-llama artifacts");

    let rt = Runtime::cpu().unwrap();
    let ev = Evaluator::load(&rt, &dir, "small-llama").unwrap();
    assert_eq!(ev.arts.manifest.param_shapes["embed"], vec![synth::VOCAB, 512]);
    assert_eq!(ev.arts.manifest.num_linears, 16, "4 layers x 4 linears");

    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let w8 = qm.weight_fp8_fraction();
    assert!((w8 - 0.3).abs() < 0.02, "weight FP8 fraction {w8} off 0.30 target");

    let rep = ev.perplexity(&cfg, Some(&qm), 2).unwrap();
    assert!(rep.ppl.is_finite() && rep.ppl > 1.0 && rep.ppl < 1e4, "ppl {}", rep.ppl);
    assert!(rep.tokens > 0.0);
    assert!(rep.act_fp8.iter().all(|&f| (0.0..=1.0).contains(&f)));

    // The quantized graph must actually diverge from the BF16 reference
    // (same windows, different numerics) while staying in a sane band.
    let bf16 = ev.perplexity(&bf16_config(), None, 2).unwrap();
    assert!(bf16.ppl.is_finite() && bf16.ppl > 1.0);
    assert_ne!(bf16.nll_sum, rep.nll_sum);
}

/// Generation e2e at the d_model=512 perf-scale preset: batched KV-cached
/// decode through the stateful Engine, FP8 cache, deterministic across
/// runs and bit-identical between batched and solo decode. Gated behind
/// `FGMP_E2E_LARGE=1` like the evaluator round-trip above.
#[test]
fn large_preset_generates_through_engine() {
    use fgmp::model::KvPrecision;
    use fgmp::runtime::{Engine, ExecSpec, GraphKind, Runtime};

    if std::env::var("FGMP_E2E_LARGE").is_err() {
        eprintln!("skipping large-preset generation e2e (set FGMP_E2E_LARGE=1 to run)");
        return;
    }
    // Own directory: the evaluator round-trip test rebuilds its dir from
    // scratch and tests run concurrently.
    let dir = std::env::temp_dir().join("fgmp_e2e_large_gen_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    synth::ensure_model(&dir, "small-llama", 42).expect("synthesize small-llama artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "small-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let spec = ExecSpec::new(&dir, "small-llama", GraphKind::LogitsQuant);
    let engine = Engine::new(&rt, &spec, tail, KvPrecision::Fp8).unwrap();
    assert!(engine.is_cached());

    let n_tokens = 8usize;
    let prompts: Vec<Vec<i32>> =
        (0..2).map(|i| ev.test_stream[i * 32..i * 32 + 16].to_vec()).collect();

    // Batched decode across both sessions.
    let mut sessions: Vec<_> = prompts.iter().map(|p| engine.prefill(p).unwrap()).collect();
    let mut produced: Vec<Vec<i32>> = sessions.iter().map(|s| vec![s.next_token()]).collect();
    for _ in 1..n_tokens {
        let mut refs: Vec<&mut fgmp::runtime::Session> = sessions.iter_mut().collect();
        let step = engine.decode_step(&mut refs).unwrap();
        assert_eq!(step.rows, 2);
        assert!(step.kv_tokens > 0);
        for (p, s) in produced.iter_mut().zip(&sessions) {
            p.push(s.next_token());
        }
    }
    for (p, prompt) in produced.iter().zip(&prompts) {
        assert_eq!(p.len(), n_tokens);
        assert!(p.iter().all(|&t| (0..synth::VOCAB as i32).contains(&t)), "tokens in vocab");
        // Solo decode of the same prompt must match bit-for-bit.
        let mut sess = engine.prefill(prompt).unwrap();
        let mut solo = vec![sess.next_token()];
        while solo.len() < n_tokens {
            let mut refs = [&mut sess];
            engine.decode_step(&mut refs).unwrap();
            solo.push(sess.next_token());
        }
        assert_eq!(&solo, p, "batched vs solo stream");
    }
    // The FP8 cache physically holds half the bits of an FP16 cache.
    let arch = ev.arts.manifest.arch().unwrap();
    let toks = (16 + n_tokens - 1) as u64;
    let want = 8 * 2 * arch.n_layers as u64 * toks * arch.d_model as u64;
    assert_eq!(sessions[0].kv_bits(), want);
}
