//! Property tests pinning the blocked/SIMD kernels to their scalar
//! references — bit-exactly, because every fast kernel preserves its
//! reference's per-output accumulation order (ascending-K for `x·w`,
//! lane-interleaved for dot products), and the branch-free quantizer
//! lanes share the reference lattice.

use fgmp::model::forward::{fgmp_matmul, fgmp_matmul_packed};
use fgmp::policy::impact_score_block;
use fgmp::quant::fp4::quant_e2m1_slice;
use fgmp::quant::fp8::quant_e4m3_slice;
use fgmp::quant::nvfp4::nvfp4_roundtrip_block;
use fgmp::quant::{nvfp4_roundtrip, nvfp4_scale, quant_e2m1, quant_e4m3};
use fgmp::quant::{FgmpTensor, PackedPanels, Precision};
use fgmp::util::kernels;
use fgmp::util::kernels::MatmulScratch;
use fgmp::util::Rng;
use fgmp::BLOCK;

/// Shapes deliberately off the MR/NR/LANES grids: odd m, k, n, tiny and
/// tile-straddling sizes, plus one aligned shape as control.
const SHAPES: &[(usize, usize, usize)] = &[
    (1, 1, 1),
    (1, 7, 3),
    (3, 5, 9),
    (4, 8, 8),     // exactly one MR x NR tile
    (5, 9, 17),    // one past every tile edge
    (7, 33, 31),
    (13, 100, 29),
    (16, 64, 48),  // aligned control
    (31, 127, 65),
    (6, 512, 19),  // deep-K odd-N (the LM-head-ish regime)
];

#[test]
fn blocked_matmul_matches_scalar_bit_exactly() {
    let mut rng = Rng::new(0xB10C);
    for &(m, k, n) in SHAPES {
        let x = rng.normal_vec(m * k, 2.0);
        let w = rng.normal_vec(k * n, 0.5);
        let blocked = kernels::matmul(&x, &w, m, k, n);
        let scalar = kernels::matmul_scalar(&x, &w, m, k, n);
        assert_eq!(blocked.len(), m * n);
        // Bit-exact: same per-output ascending-K accumulation order.
        for (i, (a, b)) in blocked.iter().zip(&scalar).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "({m},{k},{n}) elem {i}: {a} vs {b}");
        }
    }
}

#[test]
fn blocked_matmul_exact_even_with_zeros_and_denormals() {
    // Zeros everywhere (the old kernel special-cased them) and tiny values.
    let mut rng = Rng::new(77);
    let (m, k, n) = (9, 21, 13);
    let x: Vec<f32> = (0..m * k)
        .map(|i| if i % 3 == 0 { 0.0 } else { rng.normal() as f32 * 1e-40 })
        .collect();
    let w = rng.normal_vec(k * n, 1.0);
    let blocked = kernels::matmul(&x, &w, m, k, n);
    let scalar = kernels::matmul_scalar(&x, &w, m, k, n);
    for (a, b) in blocked.iter().zip(&scalar) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

#[test]
fn transposed_matmul_matches_scalar_bit_exactly() {
    let mut rng = Rng::new(0x7A);
    for &(m, k, n) in SHAPES {
        let x = rng.normal_vec(m * k, 2.0);
        let wt = rng.normal_vec(n * k, 0.5);
        let fast = kernels::matmul_transposed(&x, &wt, m, k, n);
        let scalar = kernels::matmul_transposed_scalar(&x, &wt, m, k, n);
        for (i, (a, b)) in fast.iter().zip(&scalar).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "({m},{k},{n}) elem {i}");
        }
    }
}

#[test]
fn fgmp_matmul_matches_scalar_reference_pipeline() {
    // End-to-end: the tiled PPU-quantize + blocked multiply must equal a
    // hand-rolled scalar pipeline (per-block impact score, per-branch
    // round-trip, scalar matmul) — value-exact under f32 ==.
    let mut rng = Rng::new(0xF6);
    for &(m, kb, n) in &[(3usize, 1usize, 5usize), (5, 2, 9), (8, 4, 17), (13, 3, 8)] {
        let k = kb * BLOCK;
        let x = rng.normal_vec(m * k, 2.0);
        let w = rng.normal_vec(k * n, 0.3);
        let cw: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
        // A mid-range threshold so both branches execute.
        let scores: Vec<f64> = (0..m)
            .flat_map(|mi| {
                (0..kb).map(move |bi| (mi * k + bi * BLOCK, bi * BLOCK)).collect::<Vec<_>>()
            })
            .map(|(off, coff)| impact_score_block(&x[off..off + BLOCK], &cw[coff..coff + BLOCK]))
            .collect();
        let mut sorted = scores.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let threshold = sorted[sorted.len() / 2] as f32;

        let scratch = MatmulScratch::new();
        let (got, frac) = fgmp_matmul(&x, &w, m, k, n, &cw, threshold, &scratch);

        // Scalar reference pipeline.
        let mut xq = vec![0.0f32; m * k];
        let mut n_fp8 = 0usize;
        for mi in 0..m {
            for bi in 0..kb {
                let off = mi * k + bi * BLOCK;
                let xb = &x[off..off + BLOCK];
                let cb = &cw[bi * BLOCK..(bi + 1) * BLOCK];
                if impact_score_block(xb, cb) > threshold as f64 {
                    n_fp8 += 1;
                    for (o, &v) in xq[off..off + BLOCK].iter_mut().zip(xb) {
                        *o = quant_e4m3(v);
                    }
                } else {
                    let absmax = xb.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                    nvfp4_roundtrip_block(xb, nvfp4_scale(absmax), &mut xq[off..off + BLOCK]);
                }
            }
        }
        let want = kernels::matmul_scalar(&xq, &w, m, k, n);
        assert_eq!(got, want, "({m},{k},{n})");
        let want_frac = n_fp8 as f32 / (m * kb) as f32;
        assert_eq!(frac, want_frac);
        assert!(frac > 0.0 && frac < 1.0, "median threshold must split blocks, got {frac}");
    }
}

/// Pack a dense `(K, N)` weight into the k-panelized execution layout with
/// a deterministic mixed precision assignment (plus all-FP8 / all-FP4
/// extremes via `mode`), returning the panels and their dequantized copy.
fn panelize(w: &[f32], k: usize, n: usize, mode: usize, salt: usize) -> (PackedPanels, Vec<f32>) {
    assert_eq!(k % BLOCK, 0);
    let kb = k / BLOCK;
    // Transposed (N, K) layout — blocks contiguous along K, as the offline
    // pipeline packs weights.
    let mut data_t = vec![0.0f32; k * n];
    for ki in 0..k {
        for ni in 0..n {
            data_t[ni * k + ki] = w[ki * n + ni];
        }
    }
    let prec: Vec<Precision> = (0..n * kb)
        .map(|i| match mode {
            0 => Precision::Fp8,
            1 => Precision::Fp4,
            _ => {
                if (i * 7 + salt) % 3 == 0 {
                    Precision::Fp8
                } else {
                    Precision::Fp4
                }
            }
        })
        .collect();
    let t = FgmpTensor::pack(&[n, k], &data_t, &prec, None);
    let p = PackedPanels::from_tensor(&t, kernels::NR);
    let deq = p.unpack_kn();
    (p, deq)
}

/// K must tile into 16-blocks; N runs off the NR grid (odd widths, < NR,
/// NR-aligned control) — including an fc2-like deep-K shape where K is the
/// d_ff axis and N the model width (the transpose-free path).
const PACKED_SHAPES: &[(usize, usize, usize)] = &[
    (1, 16, 1),
    (3, 16, 5),
    (5, 32, 9),
    (4, 48, 8),
    (7, 64, 17),
    (13, 80, 29),
    (6, 96, 32),  // fc2-like: K = d_ff (96) down to N = d_model (32)
    (9, 512, 19), // deep-K odd-N
];

#[test]
fn packed_matmul_matches_scalar_on_dequantized_weights() {
    // The packed kernel (in-register block decode) and its scalar sibling
    // must both equal the dense scalar matmul over the dequantized copy —
    // bit-for-bit, over all assignment modes.
    let mut rng = Rng::new(0xACED);
    for &(m, k, n) in PACKED_SHAPES {
        for mode in 0..3usize {
            let x = rng.normal_vec(m * k, 2.0);
            let w = rng.normal_vec(k * n, 0.3);
            let (panels, deq) = panelize(&w, k, n, mode, m + n);
            let want = kernels::matmul_scalar(&x, &deq, m, k, n);
            let fast = kernels::matmul_packed(&x, &panels, m);
            let scalar = kernels::matmul_packed_scalar(&x, &panels, m);
            for (i, ((a, b), c)) in fast.iter().zip(&want).zip(&scalar).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(), "({m},{k},{n}) mode {mode} elem {i} fast");
                assert_eq!(c.to_bits(), b.to_bits(), "({m},{k},{n}) mode {mode} elem {i} scalar");
            }
        }
    }
}

#[test]
fn fgmp_matmul_packed_matches_dense_pipeline_bit_exact() {
    // The full FGMP datapath (PPU activation quantize + multiply) off the
    // packed bits equals the dequant-f32 path: same outputs, same FP8
    // fractions, bit-for-bit.
    let mut rng = Rng::new(0x9A7);
    let scratch = MatmulScratch::new();
    for &(m, k, n) in &[(3usize, 32usize, 5usize), (8, 64, 17), (13, 48, 8), (5, 96, 32)] {
        let x = rng.normal_vec(m * k, 2.0);
        let w = rng.normal_vec(k * n, 0.3);
        let cw: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
        let (panels, deq) = panelize(&w, k, n, 2, k);
        // A threshold that splits blocks (reuse a mid-range score).
        let threshold = 1e-4f32;
        let (want, want_frac) = fgmp_matmul(&x, &deq, m, k, n, &cw, threshold, &scratch);
        let (got, got_frac) = fgmp_matmul_packed(&x, &panels, m, &cw, threshold, &scratch);
        assert_eq!(got_frac, want_frac, "({m},{k},{n}) fp8 fraction");
        for (i, (a, b)) in got.iter().zip(&want).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "({m},{k},{n}) elem {i}");
        }
    }
}

/// Split `rows` into page-sized row counts (`page` rows each, partial tail).
fn page_spans(rows: usize, page: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut left = rows;
    while left > 0 {
        let take = page.min(left);
        out.push(take);
        left -= take;
    }
    out
}

/// Slice a flat `rows x d` buffer into page spans of the given row counts.
fn split_pages<'a, T>(flat: &'a [T], d: usize, spans: &[usize]) -> Vec<&'a [T]> {
    let mut out = Vec::new();
    let mut off = 0usize;
    for &s in spans {
        out.push(&flat[off * d..(off + s) * d]);
        off += s;
    }
    out
}

/// Scalar attention reference over contiguous f32 KV rows, replicating
/// `model::forward::attend_row`'s accumulation order exactly: ascending-j
/// sequential score dots with a running max, stable softmax, then the
/// ascending-j weighted value sum with the `p == 0.0` skip.
#[allow(clippy::too_many_arguments)]
fn attend_flat(
    qr: &[f32],
    kf: &[f32],
    vf: &[f32],
    len: usize,
    d: usize,
    hi: usize,
    dh: usize,
    scale: f32,
) -> Vec<f32> {
    let mut sc = vec![0.0f32; len];
    let mut mx = f32::NEG_INFINITY;
    for (j, scj) in sc.iter_mut().enumerate() {
        let kr = &kf[j * d + hi * dh..j * d + (hi + 1) * dh];
        let mut dot = 0.0f32;
        for (a, b) in qr.iter().zip(kr) {
            dot += a * b;
        }
        *scj = dot * scale;
        mx = mx.max(*scj);
    }
    let mut z = 0.0f32;
    for scj in sc.iter_mut() {
        *scj = (*scj - mx).exp();
        z += *scj;
    }
    let mut or = vec![0.0f32; dh];
    for (j, &scj) in sc.iter().enumerate() {
        let p = scj / z;
        if p == 0.0 {
            continue;
        }
        let vr = &vf[j * d + hi * dh..j * d + (hi + 1) * dh];
        for (a, &vv) in or.iter_mut().zip(vr) {
            *a += p * vv;
        }
    }
    or
}

/// Head-count / head-dim / cached-row shape classes for the attend kernels,
/// plus the page splits each is exercised under: one flat span (the
/// contiguous-cache case), 7-row pages (every span edge lands mid-head-row
/// grid), and 16-row pages (the real `PAGE_TOKENS`, partial tail).
const ATTEND_SHAPES: &[(usize, usize, usize)] =
    &[(1, 8, 1), (2, 8, 5), (2, 4, 16), (4, 16, 23), (3, 8, 40)];

#[test]
fn attend_row_f32_pages_matches_gather_then_attend_bit_exact() {
    let mut rng = Rng::new(0xA77E);
    for &(nh, dh, rows) in ATTEND_SHAPES {
        let d = nh * dh;
        let kf = rng.normal_vec(rows * d, 1.0);
        let vf = rng.normal_vec(rows * d, 1.0);
        let scale = 1.0 / (dh as f32).sqrt();
        for spans in [vec![rows], page_spans(rows, 7), page_spans(rows, 16)] {
            let k_pages = split_pages(&kf, d, &spans);
            let v_pages = split_pages(&vf, d, &spans);
            // Full window and a shorter live prefix (pages hold more rows
            // than the kernel may read — the mid-decode shape).
            for len in [rows, (rows + 1) / 2] {
                for hi in 0..nh {
                    let qr = rng.normal_vec(dh, 1.0);
                    let want = attend_flat(&qr, &kf, &vf, len, d, hi, dh, scale);
                    let mut sc = vec![0.0f32; len];
                    let mut or = vec![0.0f32; dh];
                    kernels::attend_row_f32_pages(
                        &qr, &k_pages, &v_pages, len, d, hi, dh, scale, &mut sc, &mut or,
                    );
                    for (i, (a, b)) in or.iter().zip(&want).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "(nh={nh},dh={dh},rows={rows}) spans {spans:?} len {len} head {hi} elem {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn attend_row_e4m3_pages_matches_materialize_then_attend_bit_exact() {
    use fgmp::quant::fp8::encode_e4m3;

    let mut rng = Rng::new(0xE433);
    for &(nh, dh, rows) in ATTEND_SHAPES {
        let d = nh * dh;
        let kb: Vec<u8> = rng.normal_vec(rows * d, 2.0).iter().map(|&v| encode_e4m3(v)).collect();
        let vb: Vec<u8> = rng.normal_vec(rows * d, 2.0).iter().map(|&v| encode_e4m3(v)).collect();
        let scale = 1.0 / (dh as f32).sqrt();
        for spans in [vec![rows], page_spans(rows, 7), page_spans(rows, 16)] {
            let k_pages = split_pages(&kb, d, &spans);
            let v_pages = split_pages(&vb, d, &spans);
            // The yesterday-path reference: materialize the bytes to f32
            // through the same decode table, then attend over the copy.
            let mut kf = Vec::new();
            let mut vf = Vec::new();
            kernels::gather_e4m3_pages(&k_pages, &mut kf);
            kernels::gather_e4m3_pages(&v_pages, &mut vf);
            for len in [rows, (rows + 1) / 2] {
                for hi in 0..nh {
                    let qr = rng.normal_vec(dh, 1.0);
                    let want = attend_flat(&qr, &kf, &vf, len, d, hi, dh, scale);
                    let mut sc = vec![0.0f32; len];
                    let mut or = vec![0.0f32; dh];
                    kernels::attend_row_e4m3_pages(
                        &qr, &k_pages, &v_pages, len, d, hi, dh, scale, &mut sc, &mut or,
                    );
                    for (i, (a, b)) in or.iter().zip(&want).enumerate() {
                        assert_eq!(
                            a.to_bits(),
                            b.to_bits(),
                            "(nh={nh},dh={dh},rows={rows}) spans {spans:?} len {len} head {hi} elem {i}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn quant_slices_match_scalar_codecs() {
    let mut rng = Rng::new(0x5E3D);
    // Random magnitudes spanning every binade both formats touch, plus
    // exact grid/tie points and the zero/subnormal edges.
    let mut xs: Vec<f32> = Vec::new();
    for _ in 0..20_000 {
        xs.push((rng.normal() as f32) * 10f32.powf((rng.f32() - 0.5) * 12.0));
    }
    xs.extend([
        0.0,
        -0.0,
        1.0625,
        1.1875,
        0.25,
        0.75,
        2.5,
        3.5,
        5.0,
        -5.0,
        6.0,
        7.0,
        448.0,
        449.0,
        -449.0,
        1e9,
        -1e9,
        1e-9,
        0.015625,
        0.001953125,
        0.5,
        f32::INFINITY,
        f32::NEG_INFINITY,
    ]);
    let mut out = vec![0.0f32; xs.len()];
    quant_e4m3_slice(&xs, &mut out);
    for (&x, &q) in xs.iter().zip(&out) {
        assert_eq!(q, quant_e4m3(x), "e4m3({x})");
    }
    quant_e2m1_slice(&xs, &mut out);
    for (&x, &q) in xs.iter().zip(&out) {
        assert_eq!(q, quant_e2m1(x), "e2m1({x})");
    }
}

#[test]
fn nvfp4_roundtrip_matches_manual_blocks() {
    let mut rng = Rng::new(4242);
    let x = rng.normal_vec(BLOCK * 33, 5.0);
    let mut fast = vec![0.0f32; x.len()];
    let scales = nvfp4_roundtrip(&x, &mut fast);
    assert_eq!(scales.len(), 33);
    for (bi, xb) in x.chunks_exact(BLOCK).enumerate() {
        let absmax = xb.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = nvfp4_scale(absmax);
        assert_eq!(scales[bi], s, "block {bi} scale");
        for (j, &v) in xb.iter().enumerate() {
            let want = if s > 0.0 { quant_e2m1(v / s) * s } else { 0.0 };
            assert_eq!(fast[bi * BLOCK + j], want, "block {bi} elem {j}");
        }
    }
}
