//! Seeded chaos soak over the serving stack: with every failpoint armed at
//! realistic probabilities (allocation failures, worker panics, injected
//! prefill/decode faults, slow steps), the coordinator must answer every
//! request exactly once — each either a bit-exact stream or a typed
//! rejection — and the engine's KV-page books must reconcile to zero after
//! an adversarial session workload.
//!
//! The failpoint registry is process-global, so both tests serialize on one
//! lock and disarm on every exit path (a drop guard).

use std::sync::{Mutex, MutexGuard};
use std::time::Duration;

use fgmp::eval::Evaluator;
use fgmp::model::{KvPrecision, QuantConfig, QuantizedModel};
use fgmp::runtime::{Engine, EngineError, EngineOptions, ExecSpec, GraphKind, Runtime, Session};
use fgmp::util::{faults, Rng};

static LOCK: Mutex<()> = Mutex::new(());

/// Hold the registry for one test; disarm on drop (even under panic).
struct FaultScope {
    _guard: MutexGuard<'static, ()>,
}

impl FaultScope {
    fn acquire() -> Self {
        let guard = LOCK.lock().unwrap_or_else(|e| e.into_inner());
        faults::disarm();
        FaultScope { _guard: guard }
    }
}

impl Drop for FaultScope {
    fn drop(&mut self) {
        faults::disarm();
    }
}

/// The coordinator under sustained seeded chaos: allocation failures,
/// worker panics, injected prefill/decode faults, and slow steps all armed
/// at once over a 2-worker sharded engine. Every request gets exactly one
/// answer; every answered stream is bit-exact against a clean
/// single-engine reference; every non-answer is a typed rejection; the
/// fault counters land in the metrics.
#[test]
fn chaos_soak_serves_every_stream_bit_exact() {
    use fgmp::coordinator::{BatchPolicy, Request, RequestKind, Server, ServerConfig};

    let _scope = FaultScope::acquire();
    let dir = std::env::temp_dir().join("fgmp_chaos_soak_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);

    // Clean reference streams, computed before any fault is armed. The
    // sharded engine is bit-identical to the single-worker engine, so one
    // clean Engine stands for the chaos target's healthy behavior.
    let engine = Engine::new(&rt, &logits_spec, tail.clone(), KvPrecision::Fp16).unwrap();
    let mut rng = Rng::new(0xC4A05);
    let cases: Vec<(Vec<i32>, usize)> = (0..24)
        .map(|i| {
            let off = i * 32;
            let len = 6 + rng.below(9);
            let n_tokens = 3 + rng.below(6);
            (ev.test_stream[off..off + len].to_vec(), n_tokens)
        })
        .collect();
    let expected: Vec<Vec<i32>> = cases
        .iter()
        .map(|(prompt, n)| {
            let mut sess = engine.prefill(prompt).unwrap();
            let mut produced = vec![sess.next_token()];
            while produced.len() < *n {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap();
                produced.push(sess.next_token());
            }
            produced
        })
        .collect();

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: KvPrecision::Fp16,
        decode_batch: 4,
        kv_pages: None,
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 2,
        spec: None,
        prefix_share: false,
        deadline_ms: None,
        promote_after_ms: 20,
    };
    let fwd_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::FwdQuant);
    let server = Server::start(scfg, fwd_spec, tail.clone(), logits_spec, tail).unwrap();

    // Arm the full failpoint menu only once the server is up, so the soak
    // exercises steady-state serving rather than startup.
    faults::arm(0x50AC);
    faults::set(faults::KV_ALLOC, 0.02);
    faults::set(faults::WORKER_PANIC, 0.08);
    faults::set(faults::ENGINE_PREFILL, 0.1);
    faults::set(faults::ENGINE_DECODE, 0.1);
    faults::set(faults::ENGINE_SLOW, 0.2);

    let mut rxs = Vec::new();
    for (id, (prompt, n_tokens)) in cases.iter().enumerate() {
        let (req, resp_rx) = Request::new(
            id as u64,
            RequestKind::Generate { prompt: prompt.clone(), n_tokens: *n_tokens },
        );
        server.router.submit(req).unwrap();
        rxs.push(resp_rx);
    }

    let mut served = 0usize;
    for (i, resp_rx) in rxs.into_iter().enumerate() {
        let resp = resp_rx.recv_timeout(Duration::from_secs(120)).expect("soak stalled");
        assert!(
            resp.generated.is_some() != resp.rejection.is_some(),
            "request {i}: exactly one of stream / typed rejection"
        );
        if let Some(got) = resp.generated {
            assert_eq!(got, expected[i], "request {i}: stream perturbed by chaos");
            served += 1;
        }
        // Exactly-once: the response channel must never fire twice.
        assert!(resp_rx.recv().is_err(), "request {i}: answered more than once");
    }
    assert!(served > 0, "chaos drowned every request");

    let snap = server.metrics.snapshot();
    assert!(snap.faults_injected > 0, "failpoints never fired");
    assert!(snap.batch_retries > 0, "injected step faults must surface as retries");
    assert!(snap.worker_failures > 0, "worker panics must surface typed");
    server.shutdown();
}

/// Engine-level chaos: a seeded adversarial workload (prefill / batch
/// decode / retire, with allocation + forward failpoints armed) over a
/// deliberately tight pool. Every error stays typed, failed operations
/// leak nothing, and once the sessions drop the pool's books reconcile to
/// exactly zero pages in use.
#[test]
fn chaos_engine_pool_reconciles_to_zero() {
    let _scope = FaultScope::acquire();
    let dir = std::env::temp_dir().join("fgmp_chaos_pool_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);
    let stream = ev.test_stream.clone();

    // 48 pages holds at most two mid-size sessions: organic exhaustion is
    // part of the workload, on top of the injected failures.
    let opts = EngineOptions::default().kv(KvPrecision::Fp16).pages(Some(48));
    let engine = Engine::with_options(&rt, &logits_spec, tail, opts).unwrap();

    faults::arm(0x9001);
    faults::set(faults::KV_ALLOC, 0.05);
    faults::set(faults::ENGINE_PREFILL, 0.05);
    faults::set(faults::ENGINE_DECODE, 0.05);

    let mut rng = Rng::new(0xD15C0);
    let mut sessions: Vec<Session> = Vec::new();
    for round in 0..60 {
        if sessions.len() < 3 {
            let off = rng.below(stream.len() - 80);
            let len = 16 + rng.below(64);
            match engine.prefill(&stream[off..off + len]) {
                Ok(sess) => sessions.push(sess),
                Err(e) => {
                    assert!(
                        EngineError::classify(&e).is_some(),
                        "round {round}: untyped prefill error: {e}"
                    );
                }
            }
        }
        if !sessions.is_empty() {
            let step = {
                let mut refs: Vec<&mut Session> = sessions.iter_mut().collect();
                engine.decode_step(&mut refs)
            };
            if let Err(e) = step {
                let classified = EngineError::classify(&e);
                assert!(classified.is_some(), "round {round}: untyped decode error: {e}");
                if EngineError::is_exhausted(&e) {
                    // Shed load the way the coordinator would: retire the
                    // newest session and let its pages return.
                    sessions.pop();
                }
            }
        }
        if !sessions.is_empty() && rng.f64() < 0.2 {
            sessions.remove(0);
        }
    }
    sessions.clear();
    faults::disarm();

    let stats = engine.pool_stats().unwrap();
    assert_eq!(stats.in_use_pages, 0, "chaos workload leaked pages");
    assert_eq!(stats.logical_pages, 0, "chaos workload leaked logical pages");
    assert!(faults::injected() > 0, "failpoints never fired");
}
