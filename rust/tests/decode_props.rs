//! Parity properties of the KV-cached prefill/decode path against the
//! full-sequence forward — the contract the stateful `Engine` sessions
//! stand on:
//!
//!  * `forward_prefill + N × forward_step` equals full-sequence `forward`
//!    last-position logits **bit-for-bit** with an FP16 KV cache, over odd
//!    sequence lengths, with and without the PPU activation quantizer;
//!  * batched decode steps equal single-session steps bit-for-bit (so
//!    continuous batching cannot change any request's token stream);
//!  * with an FP8 KV cache the divergence stays within the documented
//!    tolerance: relative L2 error of the last-position logits ≤ 0.15 on
//!    the tiny test models. Only K/V pass through the E4M3 round-trip
//!    (≲6% per-element relative error, 3 mantissa bits), queries, weights
//!    and the MLP stay exact, and the residual stream dilutes the
//!    attention-side error — so the observed divergence is percent-level;
//!    the bound is deliberately slack, the *existence* of a bound (plus
//!    non-zero divergence) is the property.
//!
//! Plus engine-level checks over synthetic artifacts: the cached engine's
//! greedy stream equals an independent full-recompute oracle, the windowed
//! fallback reproduces the legacy zero-padded window semantics, and
//! rolling re-prefill keeps sessions decoding past `max_seq`.

use std::collections::HashMap;
use std::path::PathBuf;
use std::sync::OnceLock;

use fgmp::model::forward::{
    forward, forward_prefill, forward_step, forward_step_batch, Act, ModelArch, NormKind,
    PosKind, QuantInputs,
};
use fgmp::model::kv::{KvPrecision, KvState};
use fgmp::util::Rng;

fn arch_rope() -> ModelArch {
    ModelArch {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        act: Act::SwiGlu,
        norm: NormKind::Rms,
        pos: PosKind::Rope,
        max_seq: 32,
    }
}

fn arch_learned() -> ModelArch {
    ModelArch {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 48,
        act: Act::Gelu,
        norm: NormKind::LayerNorm,
        pos: PosKind::Learned,
        max_seq: 32,
    }
}

fn random_params(arch: &ModelArch, seed: u64) -> Vec<(String, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    arch.param_names()
        .iter()
        .map(|n| {
            let len: usize = arch.param_shape(n).iter().product();
            let data = if n.contains("norm") && !n.ends_with(".b") {
                vec![1.0f32; len]
            } else if n.ends_with(".b") {
                vec![0.0f32; len]
            } else {
                rng.normal_vec(len, 0.05)
            };
            (n.clone(), data)
        })
        .collect()
}

fn param_map(params: &[(String, Vec<f32>)]) -> HashMap<&str, &[f32]> {
    params.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect()
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

/// prefill(s0) + N steps == full forward over s0+N tokens, last-position
/// logits, bit-for-bit with FP16 KV — odd lengths and splits, both arch
/// families (RoPE/RMS/SwiGLU and learned-pos/LayerNorm/GELU).
#[test]
fn prefill_plus_steps_match_full_forward_bit_exact() {
    let mut rng = Rng::new(0xDEC0);
    for (ai, arch) in [arch_rope(), arch_learned()].iter().enumerate() {
        let params = random_params(arch, 100 + ai as u64);
        let pm = param_map(&params);
        for &(s0, n) in &[(1usize, 0usize), (1, 2), (3, 4), (5, 2), (7, 6), (9, 0), (4, 9)] {
            let s = s0 + n;
            let tokens = random_tokens(&mut rng, s, arch.vocab);
            let full = forward(arch, &pm, &tokens, 1, s, None, None, true).unwrap();

            let mut kv = KvState::new(arch, KvPrecision::Fp16);
            let mut out = forward_prefill(arch, &pm, &tokens[..s0], None, &mut kv).unwrap();
            assert_eq!(kv.len(), s0);
            for j in 0..n {
                out = forward_step(arch, &pm, tokens[s0 + j], &mut kv, None).unwrap();
            }
            assert_eq!(kv.len(), s);
            assert_bits_eq(&out.logits, &full.logits, &format!("arch {ai} s0={s0} n={n}"));
        }
    }
}

/// Same parity under the PPU activation quantizer (per-row quantization is
/// position-independent, so the cached path must stay bit-exact), with the
/// realized FP8 fractions hitting the sentinel extremes per linear.
#[test]
fn quantized_prefill_plus_steps_match_quantized_forward() {
    let mut rng = Rng::new(0xDEC1);
    let arch = arch_rope();
    let params = random_params(&arch, 7);
    let pm = param_map(&params);
    let linears = arch.linears();
    let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
    // Alternate the sentinel thresholds so both PPU branches execute.
    let thresholds: Vec<f32> = (0..linears.len())
        .map(|i| if i % 2 == 0 { -1.0 } else { f32::INFINITY })
        .collect();
    let q = QuantInputs { act_weights: awr, thresholds: &thresholds };

    for &(s0, n) in &[(1usize, 3usize), (5, 4), (8, 5)] {
        let s = s0 + n;
        let tokens = random_tokens(&mut rng, s, arch.vocab);
        let full = forward(&arch, &pm, &tokens, 1, s, Some(&q), None, true).unwrap();

        let mut kv = KvState::new(&arch, KvPrecision::Fp16);
        let mut out = forward_prefill(&arch, &pm, &tokens[..s0], Some(&q), &mut kv).unwrap();
        for j in 0..n {
            out = forward_step(&arch, &pm, tokens[s0 + j], &mut kv, Some(&q)).unwrap();
        }
        assert_bits_eq(&out.logits, &full.logits, &format!("quant s0={s0} n={n}"));
        // The step's fracs are over the final token's rows only.
        assert_eq!(out.act_fp8.len(), linears.len());
        for (i, &f) in out.act_fp8.iter().enumerate() {
            assert_eq!(f, if i % 2 == 0 { 1.0 } else { 0.0 }, "linear {i} frac");
        }
    }
}

/// Batched decode over sessions at *different* positions equals stepping
/// each session alone, bit-for-bit — continuous batching cannot perturb
/// any request's stream.
#[test]
fn batched_step_equals_single_steps_bit_exact() {
    let mut rng = Rng::new(0xDEC2);
    let arch = arch_rope();
    let params = random_params(&arch, 21);
    let pm = param_map(&params);

    let prompts: Vec<Vec<i32>> = [3usize, 7, 5]
        .iter()
        .map(|&len| random_tokens(&mut rng, len, arch.vocab))
        .collect();
    let steps: Vec<i32> = random_tokens(&mut rng, prompts.len(), arch.vocab);

    // Individually.
    let mut single_logits = Vec::new();
    for (p, &t) in prompts.iter().zip(&steps) {
        let mut kv = KvState::new(&arch, KvPrecision::Fp16);
        forward_prefill(&arch, &pm, p, None, &mut kv).unwrap();
        let out = forward_step(&arch, &pm, t, &mut kv, None).unwrap();
        single_logits.push(out.logits);
    }

    // Batched, same prompts.
    let mut kvs_owned: Vec<KvState> = prompts
        .iter()
        .map(|p| {
            let mut kv = KvState::new(&arch, KvPrecision::Fp16);
            forward_prefill(&arch, &pm, p, None, &mut kv).unwrap();
            kv
        })
        .collect();
    let mut kvs: Vec<&mut KvState> = kvs_owned.iter_mut().collect();
    let out = forward_step_batch(&arch, &pm, &steps, &mut kvs, None).unwrap();
    let v = arch.vocab;
    for (i, single) in single_logits.iter().enumerate() {
        assert_bits_eq(&out.logits[i * v..(i + 1) * v], single, &format!("session {i}"));
    }
}

/// FP8 KV cache: logits diverge from the FP16 path (quantization engaged)
/// but stay within the documented tolerance — relative L2 ≤ 0.15 on the
/// tiny models (see the module doc for why the real divergence is
/// percent-level and the bound slack).
#[test]
fn fp8_kv_within_documented_tolerance() {
    let mut rng = Rng::new(0xDEC3);
    for (ai, arch) in [arch_rope(), arch_learned()].iter().enumerate() {
        let params = random_params(arch, 300 + ai as u64);
        let pm = param_map(&params);
        for &(s0, n) in &[(5usize, 4usize), (9, 8)] {
            let s = s0 + n;
            let tokens = random_tokens(&mut rng, s, arch.vocab);
            let full = forward(arch, &pm, &tokens, 1, s, None, None, true).unwrap();

            let mut kv = KvState::new(arch, KvPrecision::Fp8);
            let mut out = forward_prefill(arch, &pm, &tokens[..s0], None, &mut kv).unwrap();
            for j in 0..n {
                out = forward_step(arch, &pm, tokens[s0 + j], &mut kv, None).unwrap();
            }
            assert!(out.logits.iter().all(|v| v.is_finite()));
            let mut d2 = 0.0f64;
            let mut r2 = 0.0f64;
            for (a, b) in out.logits.iter().zip(&full.logits) {
                d2 += ((a - b) as f64).powi(2);
                r2 += (*b as f64).powi(2);
            }
            let rel = (d2 / r2.max(1e-30)).sqrt();
            assert!(rel < 0.15, "arch {ai} s0={s0} n={n}: FP8-KV rel L2 {rel}");
            assert!(d2 > 0.0, "arch {ai}: FP8 cache should actually perturb");
            // Half the FP16 cache's bits for the same token count.
            let want_bits = 8 * 2 * arch.n_layers as u64 * s as u64 * arch.d_model as u64;
            assert_eq!(kv.stored_bits(), want_bits);
        }
    }
}

/// Guard rails: stepping a full cache errors (the Engine rolls before this
/// can happen), prefill needs an empty cache and a non-empty prompt.
#[test]
fn cache_capacity_and_misuse_errors() {
    let mut arch = arch_rope();
    arch.max_seq = 4;
    let params = random_params(&arch, 5);
    let pm = param_map(&params);
    let tokens = [1i32, 2, 3, 4];

    let mut kv = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &tokens, None, &mut kv).unwrap();
    assert_eq!(kv.len(), 4);
    assert!(forward_step(&arch, &pm, 1, &mut kv, None).is_err(), "full cache must refuse");
    assert!(forward_prefill(&arch, &pm, &tokens, None, &mut kv).is_err(), "non-empty cache");

    let mut fresh = KvState::new(&arch, KvPrecision::Fp16);
    assert!(forward_prefill(&arch, &pm, &[], None, &mut fresh).is_err(), "empty prompt");
    assert!(forward_step(&arch, &pm, 1, &mut fresh, None).is_err(), "step before prefill");
    assert!(
        forward_prefill(&arch, &pm, &[1; 5], None, &mut fresh).is_err(),
        "prompt past max_seq"
    );
}

// ---------------------------------------------------------------------------
// Engine-level checks over synthetic artifacts
// ---------------------------------------------------------------------------

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join("fgmp_decode_props_artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");
        dir
    })
}

struct EngineFixture {
    ev: fgmp::eval::Evaluator,
    spec: fgmp::runtime::ExecSpec,
    tail: Vec<fgmp::runtime::ArgValue>,
    rt: fgmp::runtime::Runtime,
}

fn engine_fixture() -> EngineFixture {
    use fgmp::model::{QuantConfig, QuantizedModel};
    use fgmp::runtime::{ExecSpec, GraphKind, Runtime};
    let dir = artifacts_dir();
    let rt = Runtime::native();
    let ev = fgmp::eval::Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let spec = ExecSpec::new(dir, "tiny-llama", GraphKind::LogitsQuant);
    EngineFixture { ev, spec, tail, rt }
}

/// Greedy-decode `n` tokens from a prepared engine.
fn greedy(engine: &fgmp::runtime::Engine, prompt: &[i32], n: usize) -> Vec<i32> {
    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];
    while produced.len() < n {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.push(sess.next_token());
    }
    produced.truncate(n);
    produced
}

/// The cached engine's greedy stream equals an independent full-recompute
/// oracle: model-level `forward` over the growing unpadded context with
/// the same quant inputs and argmax tie rule.
#[test]
fn engine_cached_greedy_matches_full_recompute_oracle() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp16).unwrap();
    assert!(engine.is_cached(), "native backend must take the cached path");

    let man = &fx.ev.arts.manifest;
    let arch = man.arch().unwrap();
    // Rebuild the oracle's param map + quant inputs from the same tail.
    let np = man.param_names.len();
    let params: Vec<(&str, &[f32])> = man
        .param_names
        .iter()
        .enumerate()
        .map(|(i, n)| (n.as_str(), fx.tail[i].as_f32().unwrap()))
        .collect();
    let pm: HashMap<&str, &[f32]> = params.iter().cloned().collect();
    let aw: Vec<&[f32]> =
        (0..man.num_linears).map(|i| fx.tail[np + i].as_f32().unwrap()).collect();
    let thresholds = fx.tail[np + man.num_linears].as_f32().unwrap();
    let q = QuantInputs { act_weights: aw, thresholds };

    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let n = 6usize;
    let got = greedy(&engine, &prompt, n);

    let mut ctx = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..n {
        let s = ctx.len();
        let out = forward(&arch, &pm, &ctx, 1, s, Some(&q), None, true).unwrap();
        let next = out
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        want.push(next);
        ctx.push(next);
    }
    assert_eq!(got, want, "cached engine vs unpadded full-recompute oracle");
}

/// The windowed fallback reproduces the legacy zero-padded fixed-window
/// semantics exactly (same graph, same right-aligned packing).
#[test]
fn engine_windowed_matches_legacy_padded_window() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new_windowed(&fx.rt, &fx.spec, fx.tail.clone()).unwrap();
    assert!(!engine.is_cached());

    let (b, s) = (fx.ev.batch, fx.ev.seq);
    let exe = fx.rt.load_spec(&fx.spec).unwrap();
    let prompt: Vec<i32> = fx.ev.test_stream[16..24].to_vec();
    let n = 5usize;
    let got = greedy(&engine, &prompt, n);

    // Legacy loop (pre-Engine generate_worker semantics).
    let mut ctx = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..n {
        let mut tokens = vec![0i32; b * s];
        let start = ctx.len().saturating_sub(s);
        let window = &ctx[start..];
        let off = s - window.len();
        tokens[off..s].copy_from_slice(window);
        let mut args =
            vec![fgmp::runtime::ArgValue::I32 { shape: vec![b, s], data: tokens }];
        args.extend(fx.tail.iter().cloned());
        let out = exe.run(&args).unwrap();
        let vocab = out[0].len() / b;
        let next = out[0][..vocab]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        want.push(next);
        ctx.push(next);
    }
    assert_eq!(got, want, "windowed engine vs legacy padded-window loop");
}

/// Rolling re-prefill: a session decodes far past `max_seq` without error,
/// its cache stays bounded, and every token is in-vocab.
#[test]
fn engine_rolls_past_max_seq() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp8).unwrap();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let n = arch.max_seq + 10;
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let got = greedy(&engine, &prompt, n);
    assert_eq!(got.len(), n);
    assert!(got.iter().all(|&t| (t as usize) < arch.vocab));
}
