//! Parity properties of the KV-cached prefill/decode path against the
//! full-sequence forward — the contract the stateful `Engine` sessions
//! stand on:
//!
//!  * `forward_prefill + N × forward_step` equals full-sequence `forward`
//!    last-position logits **bit-for-bit** with an FP16 KV cache, over odd
//!    sequence lengths, with and without the PPU activation quantizer;
//!  * batched decode steps equal single-session steps bit-for-bit (so
//!    continuous batching cannot change any request's token stream);
//!  * with an FP8 KV cache the divergence stays within the documented
//!    tolerance: relative L2 error of the last-position logits ≤ 0.15 on
//!    the tiny test models. Only K/V pass through the E4M3 round-trip
//!    (≲6% per-element relative error, 3 mantissa bits), queries, weights
//!    and the MLP stay exact, and the residual stream dilutes the
//!    attention-side error — so the observed divergence is percent-level;
//!    the bound is deliberately slack, the *existence* of a bound (plus
//!    non-zero divergence) is the property.
//!
//! Plus engine-level checks over synthetic artifacts: the cached engine's
//! greedy stream equals an independent full-recompute oracle, the windowed
//! fallback reproduces the legacy zero-padded window semantics, and
//! rolling re-prefill keeps sessions decoding past `max_seq`.

use std::path::PathBuf;
use std::sync::OnceLock;

use fgmp::model::forward::{
    forward, forward_prefill, forward_prefill_batch, forward_step, forward_step_batch, Act,
    ModelArch, NormKind, Params, PosKind, QuantInputs,
};
use fgmp::model::kv::{KvPool, KvPoolExhausted, KvPrecision, KvState, PAGE_TOKENS};
use fgmp::util::Rng;

fn arch_rope() -> ModelArch {
    ModelArch {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 2,
        d_ff: 32,
        act: Act::SwiGlu,
        norm: NormKind::Rms,
        pos: PosKind::Rope,
        max_seq: 32,
    }
}

fn arch_learned() -> ModelArch {
    ModelArch {
        vocab: 32,
        d_model: 32,
        n_layers: 2,
        n_heads: 4,
        d_ff: 48,
        act: Act::Gelu,
        norm: NormKind::LayerNorm,
        pos: PosKind::Learned,
        max_seq: 32,
    }
}

fn random_params(arch: &ModelArch, seed: u64) -> Vec<(String, Vec<f32>)> {
    let mut rng = Rng::new(seed);
    arch.param_names()
        .iter()
        .map(|n| {
            let len: usize = arch.param_shape(n).iter().product();
            let data = if n.contains("norm") && !n.ends_with(".b") {
                vec![1.0f32; len]
            } else if n.ends_with(".b") {
                vec![0.0f32; len]
            } else {
                rng.normal_vec(len, 0.05)
            };
            (n.clone(), data)
        })
        .collect()
}

fn param_map(params: &[(String, Vec<f32>)]) -> Params<'_> {
    Params::from_dense(params.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect())
}

/// Build a forward-pass [`Params`] view from an engine argument tail:
/// packed weights stay packed (the execution format), everything else is
/// dense — exactly how `NativeGraph::run` consumes the same tail.
fn params_from_tail<'a>(
    names: &'a [String],
    tail: &'a [fgmp::runtime::ArgValue],
) -> Params<'a> {
    let mut pm = Params::new();
    for (i, n) in names.iter().enumerate() {
        match &tail[i] {
            fgmp::runtime::ArgValue::PackedW { panels, .. } => pm.insert_packed(n, panels),
            other => pm.insert_dense(n, other.as_f32().unwrap()),
        }
    }
    pm
}

fn random_tokens(rng: &mut Rng, n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(vocab) as i32).collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

/// prefill(s0) + N steps == full forward over s0+N tokens, last-position
/// logits, bit-for-bit with FP16 KV — odd lengths and splits, both arch
/// families (RoPE/RMS/SwiGLU and learned-pos/LayerNorm/GELU).
#[test]
fn prefill_plus_steps_match_full_forward_bit_exact() {
    let mut rng = Rng::new(0xDEC0);
    for (ai, arch) in [arch_rope(), arch_learned()].iter().enumerate() {
        let params = random_params(arch, 100 + ai as u64);
        let pm = param_map(&params);
        for &(s0, n) in &[(1usize, 0usize), (1, 2), (3, 4), (5, 2), (7, 6), (9, 0), (4, 9)] {
            let s = s0 + n;
            let tokens = random_tokens(&mut rng, s, arch.vocab);
            let full = forward(arch, &pm, &tokens, 1, s, None, None, true).unwrap();

            let mut kv = KvState::new(arch, KvPrecision::Fp16);
            let mut out = forward_prefill(arch, &pm, &tokens[..s0], None, &mut kv).unwrap();
            assert_eq!(kv.len(), s0);
            for j in 0..n {
                out = forward_step(arch, &pm, tokens[s0 + j], &mut kv, None).unwrap();
            }
            assert_eq!(kv.len(), s);
            assert_bits_eq(&out.logits, &full.logits, &format!("arch {ai} s0={s0} n={n}"));
        }
    }
}

/// Same parity under the PPU activation quantizer (per-row quantization is
/// position-independent, so the cached path must stay bit-exact), with the
/// realized FP8 fractions hitting the sentinel extremes per linear.
#[test]
fn quantized_prefill_plus_steps_match_quantized_forward() {
    let mut rng = Rng::new(0xDEC1);
    let arch = arch_rope();
    let params = random_params(&arch, 7);
    let pm = param_map(&params);
    let linears = arch.linears();
    let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
    // Alternate the sentinel thresholds so both PPU branches execute.
    let thresholds: Vec<f32> = (0..linears.len())
        .map(|i| if i % 2 == 0 { -1.0 } else { f32::INFINITY })
        .collect();
    let q = QuantInputs { act_weights: awr, thresholds: &thresholds, attn_threshold: None };

    for &(s0, n) in &[(1usize, 3usize), (5, 4), (8, 5)] {
        let s = s0 + n;
        let tokens = random_tokens(&mut rng, s, arch.vocab);
        let full = forward(&arch, &pm, &tokens, 1, s, Some(&q), None, true).unwrap();

        let mut kv = KvState::new(&arch, KvPrecision::Fp16);
        let mut out = forward_prefill(&arch, &pm, &tokens[..s0], Some(&q), &mut kv).unwrap();
        for j in 0..n {
            out = forward_step(&arch, &pm, tokens[s0 + j], &mut kv, Some(&q)).unwrap();
        }
        assert_bits_eq(&out.logits, &full.logits, &format!("quant s0={s0} n={n}"));
        // The step's fracs are over the final token's rows only.
        assert_eq!(out.act_fp8.len(), linears.len());
        for (i, &f) in out.act_fp8.iter().enumerate() {
            assert_eq!(f, if i % 2 == 0 { 1.0 } else { 0.0 }, "linear {i} frac");
        }
    }
}

/// Batched decode over sessions at *different* positions equals stepping
/// each session alone, bit-for-bit — continuous batching cannot perturb
/// any request's stream.
#[test]
fn batched_step_equals_single_steps_bit_exact() {
    let mut rng = Rng::new(0xDEC2);
    let arch = arch_rope();
    let params = random_params(&arch, 21);
    let pm = param_map(&params);

    let prompts: Vec<Vec<i32>> = [3usize, 7, 5]
        .iter()
        .map(|&len| random_tokens(&mut rng, len, arch.vocab))
        .collect();
    let steps: Vec<i32> = random_tokens(&mut rng, prompts.len(), arch.vocab);

    // Individually.
    let mut single_logits = Vec::new();
    for (p, &t) in prompts.iter().zip(&steps) {
        let mut kv = KvState::new(&arch, KvPrecision::Fp16);
        forward_prefill(&arch, &pm, p, None, &mut kv).unwrap();
        let out = forward_step(&arch, &pm, t, &mut kv, None).unwrap();
        single_logits.push(out.logits);
    }

    // Batched, same prompts.
    let mut kvs_owned: Vec<KvState> = prompts
        .iter()
        .map(|p| {
            let mut kv = KvState::new(&arch, KvPrecision::Fp16);
            forward_prefill(&arch, &pm, p, None, &mut kv).unwrap();
            kv
        })
        .collect();
    let mut kvs: Vec<&mut KvState> = kvs_owned.iter_mut().collect();
    let out = forward_step_batch(&arch, &pm, &steps, &mut kvs, None).unwrap();
    let v = arch.vocab;
    for (i, single) in single_logits.iter().enumerate() {
        assert_bits_eq(&out.logits[i * v..(i + 1) * v], single, &format!("session {i}"));
    }
}

/// FP8 KV cache: logits diverge from the FP16 path (quantization engaged)
/// but stay within the documented tolerance — relative L2 ≤ 0.15 on the
/// tiny models (see the module doc for why the real divergence is
/// percent-level and the bound slack).
#[test]
fn fp8_kv_within_documented_tolerance() {
    let mut rng = Rng::new(0xDEC3);
    for (ai, arch) in [arch_rope(), arch_learned()].iter().enumerate() {
        let params = random_params(arch, 300 + ai as u64);
        let pm = param_map(&params);
        for &(s0, n) in &[(5usize, 4usize), (9, 8)] {
            let s = s0 + n;
            let tokens = random_tokens(&mut rng, s, arch.vocab);
            let full = forward(arch, &pm, &tokens, 1, s, None, None, true).unwrap();

            let mut kv = KvState::new(arch, KvPrecision::Fp8);
            let mut out = forward_prefill(arch, &pm, &tokens[..s0], None, &mut kv).unwrap();
            for j in 0..n {
                out = forward_step(arch, &pm, tokens[s0 + j], &mut kv, None).unwrap();
            }
            assert!(out.logits.iter().all(|v| v.is_finite()));
            let mut d2 = 0.0f64;
            let mut r2 = 0.0f64;
            for (a, b) in out.logits.iter().zip(&full.logits) {
                d2 += ((a - b) as f64).powi(2);
                r2 += (*b as f64).powi(2);
            }
            let rel = (d2 / r2.max(1e-30)).sqrt();
            assert!(rel < 0.15, "arch {ai} s0={s0} n={n}: FP8-KV rel L2 {rel}");
            assert!(d2 > 0.0, "arch {ai}: FP8 cache should actually perturb");
            // Half the FP16 cache's bits for the same token count.
            let want_bits = 8 * 2 * arch.n_layers as u64 * s as u64 * arch.d_model as u64;
            assert_eq!(kv.stored_bits(), want_bits);
        }
    }
}

/// **Acceptance criterion:** FP16 *paged* decode is bit-for-bit identical
/// to the contiguous KV path — prefill plus every step, across page
/// boundaries, both arch families. The paged read is a pure gather of the
/// same f32 rows, so attention consumes identical inputs in identical
/// order.
#[test]
fn paged_fp16_decode_is_bit_exact_vs_contiguous() {
    let mut rng = Rng::new(0xDEC4);
    for (ai, arch) in [arch_rope(), arch_learned()].iter().enumerate() {
        let params = random_params(arch, 400 + ai as u64);
        let pm = param_map(&params);
        let pool = KvPool::new(arch, KvPrecision::Fp16, 64);
        // Splits that stay inside a page, end exactly on a boundary, and
        // cross it mid-stream (max_seq = 32 bounds s0 + n).
        for &(s0, n) in &[(1usize, 3usize), (5, 4), (PAGE_TOKENS, 3), (PAGE_TOKENS - 1, 5)] {
            let tokens = random_tokens(&mut rng, s0 + n, arch.vocab);
            let mut flat = KvState::new(arch, KvPrecision::Fp16);
            let mut paged = KvState::new_paged(arch, &pool);
            let out_f = forward_prefill(arch, &pm, &tokens[..s0], None, &mut flat).unwrap();
            let out_p = forward_prefill(arch, &pm, &tokens[..s0], None, &mut paged).unwrap();
            assert_bits_eq(&out_p.logits, &out_f.logits, &format!("arch {ai} prefill s0={s0}"));
            for j in 0..n {
                let of = forward_step(arch, &pm, tokens[s0 + j], &mut flat, None).unwrap();
                let op = forward_step(arch, &pm, tokens[s0 + j], &mut paged, None).unwrap();
                assert_bits_eq(&op.logits, &of.logits, &format!("arch {ai} s0={s0} step {j}"));
            }
            assert_eq!(paged.len(), flat.len());
            assert_eq!(paged.stored_bits(), flat.stored_bits());
        }
        assert_eq!(pool.stats().in_use_pages, 0, "arch {ai}: all pages recycled");
    }
}

/// Paged FP8 stores the same E4M3 bytes as the flat FP8 cache and decodes
/// them through the same lattice, so the two are bit-exact against each
/// other — and both stay within the documented tolerance of the fp32
/// oracle (rel L2 ≤ 0.15, same bound as `fp8_kv_within_documented_tolerance`).
#[test]
fn paged_fp8_matches_flat_fp8_bit_exact_and_oracle_within_tolerance() {
    let mut rng = Rng::new(0xDEC5);
    let arch = arch_rope();
    let params = random_params(&arch, 410);
    let pm = param_map(&params);
    let pool = KvPool::new(&arch, KvPrecision::Fp8, 64);
    let (s0, n) = (9usize, 8usize); // crosses the first page boundary
    let s = s0 + n;
    let tokens = random_tokens(&mut rng, s, arch.vocab);
    let full = forward(&arch, &pm, &tokens, 1, s, None, None, true).unwrap();

    let mut flat = KvState::new(&arch, KvPrecision::Fp8);
    let mut paged = KvState::new_paged(&arch, &pool);
    let mut out_f = forward_prefill(&arch, &pm, &tokens[..s0], None, &mut flat).unwrap();
    let mut out_p = forward_prefill(&arch, &pm, &tokens[..s0], None, &mut paged).unwrap();
    for j in 0..n {
        out_f = forward_step(&arch, &pm, tokens[s0 + j], &mut flat, None).unwrap();
        out_p = forward_step(&arch, &pm, tokens[s0 + j], &mut paged, None).unwrap();
    }
    assert_bits_eq(&out_p.logits, &out_f.logits, "paged FP8 vs flat FP8");
    let mut d2 = 0.0f64;
    let mut r2 = 0.0f64;
    for (a, b) in out_p.logits.iter().zip(&full.logits) {
        d2 += ((a - b) as f64).powi(2);
        r2 += (*b as f64).powi(2);
    }
    let rel = (d2 / r2.max(1e-30)).sqrt();
    assert!(rel < 0.15, "paged FP8-KV rel L2 {rel}");
    assert!(d2 > 0.0, "FP8 paging should still quantize");
}

/// **Acceptance criterion:** admission allocates proportionally to tokens
/// actually cached — never a window-sized buffer. Construction is free,
/// prefill of `t` tokens holds exactly `pages_for_session(layers, t)`
/// pages, and retirement returns them all.
#[test]
fn paged_prefill_allocates_proportional_to_tokens_not_window() {
    let arch = arch_rope(); // max_seq 32: a full window would be 2 pages/buf
    let params = random_params(&arch, 77);
    let pm = param_map(&params);
    let pool = KvPool::new(&arch, KvPrecision::Fp16, 64);
    let mut kv = KvState::new_paged(&arch, &pool);
    assert_eq!(pool.stats().in_use_pages, 0, "construction must allocate nothing");
    let tokens: Vec<i32> = (0..5).collect();
    forward_prefill(&arch, &pm, &tokens, None, &mut kv).unwrap();
    assert_eq!(kv.kv_pages(), KvPool::pages_for_session(arch.n_layers, 5));
    assert_eq!(pool.stats().in_use_pages, kv.kv_pages());
    assert!(
        kv.kv_pages() < KvPool::pages_for_session(arch.n_layers, arch.max_seq),
        "5-token admission must cost less than the max window"
    );
    drop(kv);
    assert_eq!(pool.stats().free_pages, 64, "retirement returns every page");
}

/// Batched prefill equals sequential prefills bit-for-bit — mixed prompt
/// lengths, with and without the PPU quantizer — and decode continues
/// identically from the batched caches.
#[test]
fn batched_prefill_matches_sequential_bit_exact() {
    let mut rng = Rng::new(0xDEC6);
    let arch = arch_rope();
    let params = random_params(&arch, 501);
    let pm = param_map(&params);
    let linears = arch.linears();
    let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
    let thresholds: Vec<f32> = (0..linears.len())
        .map(|i| if i % 2 == 0 { -1.0 } else { f32::INFINITY })
        .collect();
    let q = QuantInputs { act_weights: awr, thresholds: &thresholds, attn_threshold: None };

    for quant in [None, Some(&q)] {
        let lens = [3usize, PAGE_TOKENS, 7, 1];
        let prompts: Vec<Vec<i32>> =
            lens.iter().map(|&l| random_tokens(&mut rng, l, arch.vocab)).collect();

        // Sequential oracle over flat caches.
        let mut want_logits = Vec::new();
        let mut flat_kvs = Vec::new();
        for p in &prompts {
            let mut kv = KvState::new(&arch, KvPrecision::Fp16);
            let out = forward_prefill(&arch, &pm, p, quant, &mut kv).unwrap();
            want_logits.push(out.logits);
            flat_kvs.push(kv);
        }

        // One batched forward into paged caches.
        let pool = KvPool::new(&arch, KvPrecision::Fp16, 64);
        let mut kvs: Vec<KvState> =
            prompts.iter().map(|_| KvState::new_paged(&arch, &pool)).collect();
        let pviews: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let out = {
            let mut refs: Vec<&mut KvState> = kvs.iter_mut().collect();
            forward_prefill_batch(&arch, &pm, &pviews, quant, &mut refs).unwrap()
        };
        let v = arch.vocab;
        for (i, want) in want_logits.iter().enumerate() {
            assert_bits_eq(
                &out.logits[i * v..(i + 1) * v],
                want,
                &format!("prompt {i} (quant {})", quant.is_some()),
            );
        }
        for (kv, p) in kvs.iter().zip(&prompts) {
            assert_eq!(kv.len(), p.len());
        }

        // Decode continues bit-identically from either prefill.
        let steps: Vec<i32> = random_tokens(&mut rng, prompts.len(), arch.vocab);
        let mut flat_refs: Vec<&mut KvState> = flat_kvs.iter_mut().collect();
        let of = forward_step_batch(&arch, &pm, &steps, &mut flat_refs, quant).unwrap();
        let mut paged_refs: Vec<&mut KvState> = kvs.iter_mut().collect();
        let op = forward_step_batch(&arch, &pm, &steps, &mut paged_refs, quant).unwrap();
        assert_bits_eq(&op.logits, &of.logits, "post-prefill batched step");
    }
}

/// Pool exhaustion is a *typed*, compute-free, all-or-nothing failure: a
/// too-big prefill leaves the cache empty and the pool untouched, and a
/// starved decode step leaves every session's cache intact.
#[test]
fn pool_exhaustion_is_typed_and_spends_no_compute() {
    let mut rng = Rng::new(0xDEC7);
    let arch = arch_rope();
    let params = random_params(&arch, 88);
    let pm = param_map(&params);

    // One token needs 2·n_layers = 4 pages; give the pool 3.
    let pool = KvPool::new(&arch, KvPrecision::Fp16, 3);
    let mut kv = KvState::new_paged(&arch, &pool);
    let err = forward_prefill(&arch, &pm, &[1, 2, 3], None, &mut kv).unwrap_err();
    assert!(err.downcast_ref::<KvPoolExhausted>().is_some(), "untyped: {err}");
    assert!(kv.is_empty(), "failed prefill must cache nothing");
    assert_eq!(pool.stats().in_use_pages, 0);
    assert_eq!(pool.stats().exhausted_events, 1);

    // Fill exactly one page per buffer, then starve the boundary step.
    let pool2 = KvPool::new(&arch, KvPrecision::Fp16, 4);
    let mut kv2 = KvState::new_paged(&arch, &pool2);
    let prompt = random_tokens(&mut rng, PAGE_TOKENS, arch.vocab);
    let pre = forward_prefill(&arch, &pm, &prompt, None, &mut kv2).unwrap();
    let err = forward_step(&arch, &pm, 1, &mut kv2, None).unwrap_err();
    assert!(err.downcast_ref::<KvPoolExhausted>().is_some(), "untyped: {err}");
    assert_eq!(kv2.len(), PAGE_TOKENS, "failed step must leave the cache intact");
    // The session still decodes correctly once capacity appears elsewhere
    // (here: nothing to free, so just re-verify the cache is coherent by
    // re-running the last-position logits from scratch).
    let full = forward(&arch, &pm, &prompt, 1, prompt.len(), None, None, true).unwrap();
    assert_bits_eq(&pre.logits, &full.logits, "cache coherent after failed step");
}

/// Guard rails: stepping a full cache errors (the Engine rolls before this
/// can happen), prefill needs an empty cache and a non-empty prompt.
#[test]
fn cache_capacity_and_misuse_errors() {
    let mut arch = arch_rope();
    arch.max_seq = 4;
    let params = random_params(&arch, 5);
    let pm = param_map(&params);
    let tokens = [1i32, 2, 3, 4];

    let mut kv = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &tokens, None, &mut kv).unwrap();
    assert_eq!(kv.len(), 4);
    assert!(forward_step(&arch, &pm, 1, &mut kv, None).is_err(), "full cache must refuse");
    assert!(forward_prefill(&arch, &pm, &tokens, None, &mut kv).is_err(), "non-empty cache");

    let mut fresh = KvState::new(&arch, KvPrecision::Fp16);
    assert!(forward_prefill(&arch, &pm, &[], None, &mut fresh).is_err(), "empty prompt");
    assert!(forward_step(&arch, &pm, 1, &mut fresh, None).is_err(), "step before prefill");
    assert!(
        forward_prefill(&arch, &pm, &[1; 5], None, &mut fresh).is_err(),
        "prompt past max_seq"
    );
}

// ---------------------------------------------------------------------------
// Engine-level checks over synthetic artifacts
// ---------------------------------------------------------------------------

fn artifacts_dir() -> &'static PathBuf {
    static DIR: OnceLock<PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        let dir = std::env::temp_dir().join("fgmp_decode_props_artifacts");
        let _ = std::fs::remove_dir_all(&dir);
        fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");
        dir
    })
}

struct EngineFixture {
    ev: fgmp::eval::Evaluator,
    spec: fgmp::runtime::ExecSpec,
    tail: Vec<fgmp::runtime::ArgValue>,
    rt: fgmp::runtime::Runtime,
}

fn engine_fixture() -> EngineFixture {
    use fgmp::model::{QuantConfig, QuantizedModel};
    use fgmp::runtime::{ExecSpec, GraphKind, Runtime};
    let dir = artifacts_dir();
    let rt = Runtime::native();
    let ev = fgmp::eval::Evaluator::load(&rt, dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let spec = ExecSpec::new(dir, "tiny-llama", GraphKind::LogitsQuant);
    EngineFixture { ev, spec, tail, rt }
}

/// Greedy-decode `n` tokens from a prepared engine (any implementation of
/// the shared engine surface — the single-worker `Engine` coerces).
fn greedy(engine: &dyn fgmp::runtime::InferenceEngine, prompt: &[i32], n: usize) -> Vec<i32> {
    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];
    while produced.len() < n {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.push(sess.next_token());
    }
    produced.truncate(n);
    produced
}

/// The cached engine's greedy stream equals an independent full-recompute
/// oracle: model-level `forward` over the growing unpadded context with
/// the same quant inputs and argmax tie rule.
#[test]
fn engine_cached_greedy_matches_full_recompute_oracle() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp16).unwrap();
    assert!(engine.is_cached(), "native backend must take the cached path");

    let man = &fx.ev.arts.manifest;
    let arch = man.arch().unwrap();
    // Rebuild the oracle's param map + quant inputs from the same tail
    // (weights stay in the packed execution format on both sides).
    let np = man.param_names.len();
    let pm = params_from_tail(&man.param_names, &fx.tail);
    let aw: Vec<&[f32]> =
        (0..man.num_linears).map(|i| fx.tail[np + i].as_f32().unwrap()).collect();
    let thresholds = fx.tail[np + man.num_linears].as_f32().unwrap();
    let q = QuantInputs { act_weights: aw, thresholds, attn_threshold: None };

    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let n = 6usize;
    let got = greedy(&engine, &prompt, n);

    let mut ctx = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..n {
        let s = ctx.len();
        let out = forward(&arch, &pm, &ctx, 1, s, Some(&q), None, true).unwrap();
        let next = out
            .logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        want.push(next);
        ctx.push(next);
    }
    assert_eq!(got, want, "cached engine vs unpadded full-recompute oracle");
}

/// The windowed fallback reproduces the legacy zero-padded fixed-window
/// semantics exactly (same graph, same right-aligned packing).
#[test]
fn engine_windowed_matches_legacy_padded_window() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new_windowed(&fx.rt, &fx.spec, fx.tail.clone()).unwrap();
    assert!(!engine.is_cached());

    let (b, s) = (fx.ev.batch, fx.ev.seq);
    let exe = fx.rt.load_spec(&fx.spec).unwrap();
    let prompt: Vec<i32> = fx.ev.test_stream[16..24].to_vec();
    let n = 5usize;
    let got = greedy(&engine, &prompt, n);

    // Legacy loop (pre-Engine generate_worker semantics).
    let mut ctx = prompt.clone();
    let mut want = Vec::new();
    for _ in 0..n {
        let mut tokens = vec![0i32; b * s];
        let start = ctx.len().saturating_sub(s);
        let window = &ctx[start..];
        let off = s - window.len();
        tokens[off..s].copy_from_slice(window);
        let mut args =
            vec![fgmp::runtime::ArgValue::I32 { shape: vec![b, s], data: tokens }];
        args.extend(fx.tail.iter().cloned());
        let out = exe.run(&args).unwrap();
        let vocab = out[0].len() / b;
        let next = out[0][..vocab]
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap();
        want.push(next);
        ctx.push(next);
    }
    assert_eq!(got, want, "windowed engine vs legacy padded-window loop");
}

/// Rolling re-prefill: a session decodes far past `max_seq` without error,
/// its cache stays bounded, and every token is in-vocab.
#[test]
fn engine_rolls_past_max_seq() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp8).unwrap();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let n = arch.max_seq + 10;
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let got = greedy(&engine, &prompt, n);
    assert_eq!(got.len(), n);
    assert!(got.iter().all(|&t| (t as usize) < arch.vocab));
}

/// `Engine::prefill_batch` returns sessions bit-identical to serial
/// `Engine::prefill`, every session draws its pages from the engine's
/// shared pool proportionally to its prompt, and retirement (dropping the
/// sessions) returns every page to the free list.
#[test]
fn engine_prefill_batch_matches_serial_and_recycles_pages() {
    let fx = engine_fixture();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp16).unwrap();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let prompts: Vec<Vec<i32>> = [5usize, 17, 9, 1]
        .iter()
        .enumerate()
        .map(|(i, &len)| fx.ev.test_stream[i * 24..i * 24 + len].to_vec())
        .collect();

    let serial: Vec<fgmp::runtime::Session> =
        prompts.iter().map(|p| engine.prefill(p).unwrap()).collect();
    let batch = engine.prefill_batch(&prompts).unwrap();
    assert_eq!(batch.len(), prompts.len());
    for (i, (s, b)) in serial.iter().zip(&batch).enumerate() {
        assert_eq!(s.tokens, b.tokens, "session {i} context");
        assert_bits_eq(&b.last_logits, &s.last_logits, &format!("session {i} logits"));
        assert_eq!(s.cached_tokens(), b.cached_tokens());
        assert_eq!(
            b.kv_pages(),
            fgmp::model::KvPool::pages_for_session(arch.n_layers, prompts[i].len()),
            "session {i} pages proportional to its prompt"
        );
    }
    let stats = engine.pool_stats().expect("cached engine has a pool");
    let held: usize = serial.iter().chain(batch.iter()).map(|s| s.kv_pages()).sum();
    assert_eq!(stats.in_use_pages, held, "pool accounting matches sessions");
    drop(serial);
    drop(batch);
    assert_eq!(engine.pool_stats().unwrap().in_use_pages, 0, "retirement recycles");
}

/// Engine-level backpressure: a pool sized for exactly one worst-case
/// session admits one, refuses the next over-budget prefill with the typed
/// error, admits it after retirement frees the pages — and rolling keeps a
/// long-running session inside the same bound, so decode never starves.
#[test]
fn engine_pool_backpressure_and_roll_stay_within_bound() {
    use fgmp::runtime::EngineOptions;
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let per_session = KvPool::pages_for_session(arch.n_layers, arch.max_seq);
    let opts = EngineOptions {
        kv: KvPrecision::Fp16,
        kv_pages: Some(per_session),
        ..EngineOptions::default()
    };
    let engine =
        fgmp::runtime::Engine::with_options(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    assert_eq!(engine.max_live_sessions(), 1);
    assert_eq!(engine.kv_pages_per_session(), per_session);

    let short: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let long: Vec<i32> = fx.ev.test_stream[..arch.max_seq - 8].to_vec();
    let held = engine.prefill(&short).unwrap();
    let err = engine.prefill(&long).unwrap_err();
    assert!(err.downcast_ref::<KvPoolExhausted>().is_some(), "untyped backpressure: {err}");
    drop(held); // retire → pages free
    let mut sess = engine.prefill(&long).unwrap();
    assert_eq!(sess.cached_tokens(), long.len());

    // Decode across the roll boundary: the worst-case bound means the pool
    // never starves mid-stream, and the roll returns pages.
    for _ in 0..20 {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
    }
    let stats = engine.pool_stats().unwrap();
    assert!(stats.in_use_pages <= per_session);
    assert_eq!(stats.in_use_pages, sess.kv_pages());
    assert!(sess.cached_tokens() > 0);
}

/// The packed execution path is bit-exact against the dequant-f32 path:
/// an engine fed the packed tail and an engine fed the same tail with
/// every packed weight materialized to dense f32 produce identical
/// prefill logits and greedy decode streams — and only the packed engine
/// holds packed (sub-f32) resident weight bytes.
#[test]
fn engine_packed_tail_matches_dense_materialized_tail() {
    use fgmp::runtime::ArgValue;
    let fx = engine_fixture();
    // The quantized tail carries packed weights.
    assert!(
        fx.tail.iter().any(|a| matches!(a, ArgValue::PackedW { .. })),
        "quant_arg_tail should carry packed weights"
    );
    let dense_tail: Vec<ArgValue> = fx
        .tail
        .iter()
        .map(|a| match a {
            ArgValue::PackedW { shape, panels } => {
                ArgValue::F32 { shape: shape.clone(), data: panels.unpack_kn() }
            }
            other => other.clone(),
        })
        .collect();

    let packed_eng =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp16).unwrap();
    let dense_eng =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, dense_tail, KvPrecision::Fp16).unwrap();

    let wm = packed_eng.weight_memory();
    assert!(wm.linears > 0, "packed engine should count packed linears");
    assert!(
        (wm.packed_bytes as f64) < 0.25 * wm.f32_equiv_bytes as f64,
        "resident packed bytes {} vs f32 {}",
        wm.packed_bytes,
        wm.f32_equiv_bytes
    );
    assert_eq!(dense_eng.weight_memory().linears, 0, "dense engine holds no packed linears");

    let prompt: Vec<i32> = fx.ev.test_stream[4..15].to_vec();
    let sp = packed_eng.prefill(&prompt).unwrap();
    let sd = dense_eng.prefill(&prompt).unwrap();
    assert_bits_eq(&sp.last_logits, &sd.last_logits, "packed vs dense prefill logits");

    let n = 7usize;
    assert_eq!(
        greedy(&packed_eng, &prompt, n),
        greedy(&dense_eng, &prompt, n),
        "packed vs dense greedy stream"
    );
}

// ---------------------------------------------------------------------------
// Roll semantics and the attention-input PPU
// ---------------------------------------------------------------------------

fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap()
}

fn rel_l2(a: &[f32], b: &[f32]) -> f64 {
    assert_eq!(a.len(), b.len());
    let mut d2 = 0.0f64;
    let mut r2 = 0.0f64;
    for (x, y) in a.iter().zip(b) {
        d2 += ((x - y) as f64).powi(2);
        r2 += (*y as f64).powi(2);
    }
    (d2 / r2.max(1e-30)).sqrt()
}

/// Rolling past `max_seq` is storage-layout invariant: driving the same
/// greedy stream across engine-style rolls (rebuild the cache from the
/// trailing half window, discard the re-prefill logits) produces
/// bit-identical token streams from a flat and a paged KV cache — FP16
/// and FP8.
#[test]
fn rolled_greedy_stream_is_storage_layout_invariant() {
    let mut rng = Rng::new(0xDEC8);
    let arch = arch_rope(); // max_seq 32
    let params = random_params(&arch, 911);
    let pm = param_map(&params);
    let w = (arch.max_seq / 2).max(1);
    let total = arch.max_seq + 12; // guarantees at least one roll
    let prompt = random_tokens(&mut rng, 6, arch.vocab);

    for prec in [KvPrecision::Fp16, KvPrecision::Fp8] {
        let pool = KvPool::new(&arch, prec, 64);
        let fresh_kv = |paged: bool| {
            if paged { KvState::new_paged(&arch, &pool) } else { KvState::new(&arch, prec) }
        };
        let mut streams: Vec<Vec<i32>> = Vec::new();
        for paged in [false, true] {
            let mut kv = fresh_kv(paged);
            let mut logits =
                forward_prefill(&arch, &pm, &prompt, None, &mut kv).unwrap().logits;
            let mut tokens = prompt.clone();
            let mut produced = Vec::new();
            let mut rolls = 0usize;
            while produced.len() < total {
                if kv.len() >= arch.max_seq {
                    // Engine roll semantics: rebuild from the trailing
                    // half window, re-prefill logits discarded.
                    let kept = tokens[tokens.len() - w..].to_vec();
                    kv = fresh_kv(paged);
                    forward_prefill(&arch, &pm, &kept, None, &mut kv).unwrap();
                    tokens = kept;
                    rolls += 1;
                }
                let t = argmax(&logits);
                produced.push(t);
                tokens.push(t);
                logits = forward_step(&arch, &pm, t, &mut kv, None).unwrap().logits;
            }
            assert!(rolls >= 1, "{prec:?} paged={paged}: stream must cross a roll");
            streams.push(produced);
        }
        assert_eq!(streams[0], streams[1], "{prec:?}: flat vs paged rolled stream");
    }
}

/// A session forced past `max_seq` (rolled) continues bit-identically to
/// a fresh session prefilled on exactly the kept window — FP16 and FP8
/// KV. The roll discards the re-prefill logits and keeps decoding from
/// the pre-roll ones, so the fresh session is handed those before
/// stepping; from there both token streams and logits must agree
/// bit-for-bit. The step's `kv_bits_per_value` reports the cache's
/// nominal width when the attention PPU is off.
#[test]
fn engine_rolled_session_matches_fresh_prefill_on_kept_window() {
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let w = (arch.max_seq / 2).max(1);
    for kv in [KvPrecision::Fp16, KvPrecision::Fp8] {
        let engine =
            fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), kv).unwrap();
        // Prefill just short of the window, then decode until the cache is
        // exactly full: the next step must roll.
        let prompt: Vec<i32> = fx.ev.test_stream[..arch.max_seq - 3].to_vec();
        let mut sess = engine.prefill(&prompt).unwrap();
        while sess.cached_tokens() < arch.max_seq {
            let mut refs = [&mut sess];
            engine.decode_step(&mut refs).unwrap();
        }
        let kept = sess.tokens[sess.tokens.len() - w..].to_vec();
        let mut fresh = engine.prefill(&kept).unwrap();
        fresh.last_logits = sess.last_logits.clone();
        let want_bits = if kv == KvPrecision::Fp16 { 16.0 } else { 8.0 };
        for step in 0..6 {
            let out = {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap()
            };
            if step == 0 {
                // The first step performs the roll: the cache shrinks to
                // the kept window plus the token just consumed.
                assert_eq!(sess.cached_tokens(), w + 1, "{kv:?}: roll window");
            }
            assert_eq!(out.rows, 1);
            assert_eq!(out.kv_tokens, sess.cached_tokens() as u64);
            assert_eq!(out.kv_bits_per_value, want_bits, "{kv:?}: nominal pricing");
            {
                let mut refs = [&mut fresh];
                engine.decode_step(&mut refs).unwrap();
            }
            assert_bits_eq(
                &sess.last_logits,
                &fresh.last_logits,
                &format!("{kv:?} step {step}: logits"),
            );
        }
        assert_eq!(sess.tokens, fresh.tokens, "{kv:?}: rolled vs fresh token stream");
    }
}

/// The batched ragged re-prefill that services rolls: two sessions
/// hitting `max_seq` in the same decode batch (alongside one mid-stream
/// session that does not roll) step bit-identically to the same sessions
/// stepped alone.
#[test]
fn engine_batched_roll_matches_serial_roll_bit_exact() {
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let engine =
        fgmp::runtime::Engine::new(&fx.rt, &fx.spec, fx.tail.clone(), KvPrecision::Fp16)
            .unwrap();
    // Full-window prompts sit exactly at the roll boundary.
    let full_a: Vec<i32> = fx.ev.test_stream[..arch.max_seq].to_vec();
    let full_b: Vec<i32> = fx.ev.test_stream[64..64 + arch.max_seq].to_vec();
    let mid: Vec<i32> = fx.ev.test_stream[32..48].to_vec();

    // Prefill is deterministic, so two sessions prefilled on the same
    // prompt are bit-identical twins.
    let mut a1 = engine.prefill(&full_a).unwrap();
    let mut a2 = engine.prefill(&full_a).unwrap();
    let mut b1 = engine.prefill(&full_b).unwrap();
    let mut b2 = engine.prefill(&full_b).unwrap();
    let mut m1 = engine.prefill(&mid).unwrap();
    let mut m2 = engine.prefill(&mid).unwrap();

    for step in 0..4 {
        {
            let mut refs = [&mut a1, &mut m1, &mut b1];
            engine.decode_step(&mut refs).unwrap();
        }
        for s in [&mut a2, &mut m2, &mut b2] {
            let mut refs = [s];
            engine.decode_step(&mut refs).unwrap();
        }
        for (name, x, y) in [("a", &a1, &a2), ("m", &m1, &m2), ("b", &b1, &b2)] {
            assert_eq!(x.tokens, y.tokens, "{name} step {step}: tokens");
            assert_bits_eq(&x.last_logits, &y.last_logits, &format!("{name} step {step}"));
        }
    }
    // The rolled sessions stayed inside the window bound.
    assert!(a1.cached_tokens() <= arch.max_seq);
    assert!(b1.cached_tokens() <= arch.max_seq);
}

/// The attention-input PPU knob: threshold −1 routes every Q/K/V block
/// through the FP8 branch — logits stay within the documented FP8
/// tolerance of the knob-off run and the realized mix prices the cache
/// at exactly 8 bits/value; threshold +∞ routes everything through NVFP4
/// and prices it at 4.5625; a `d_model` that doesn't tile into 16-element
/// blocks is rejected before any compute.
#[test]
fn attention_ppu_prices_kv_at_realized_mix_within_tolerance() {
    use fgmp::model::kv::{FP8_BITS_PER_VALUE, NVFP4_BITS_PER_VALUE};
    let mut rng = Rng::new(0xDEC9);
    let arch = arch_rope(); // d_model 32 = two 16-element blocks per row
    let params = random_params(&arch, 777);
    let pm = param_map(&params);
    let linears = arch.linears();
    let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let thresholds = vec![-1.0f32; linears.len()]; // linear PPU pinned all-FP8
    let (s0, n) = (6usize, 5usize);
    let tokens = random_tokens(&mut rng, s0 + n, arch.vocab);

    let run = |attn: Option<f32>| {
        let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
        let q = QuantInputs { act_weights: awr, thresholds: &thresholds, attn_threshold: attn };
        let mut kv = KvState::new(&arch, KvPrecision::Fp16);
        let mut out = forward_prefill(&arch, &pm, &tokens[..s0], Some(&q), &mut kv).unwrap();
        for j in 0..n {
            out = forward_step(&arch, &pm, tokens[s0 + j], &mut kv, Some(&q)).unwrap();
        }
        (out.logits, kv.effective_kv_bits(), kv.stored_bits())
    };

    let (base, base_bits, base_stored) = run(None);
    assert_eq!(base_bits, 16.0, "knob off: nominal FP16 pricing");

    let (hi, hi_bits, hi_stored) = run(Some(-1.0));
    assert_eq!(hi_bits, FP8_BITS_PER_VALUE, "all-high mix prices at 8 bits/value");
    assert_eq!(hi_stored, base_stored, "the PPU reprices traffic, not the store layout");
    let rel = rel_l2(&hi, &base);
    assert!(rel < 0.15, "all-FP8 attention inputs rel L2 {rel}");
    assert!(rel > 0.0, "the attention PPU should actually perturb");

    let (lo, lo_bits, _) = run(Some(f32::INFINITY));
    assert_eq!(lo_bits, NVFP4_BITS_PER_VALUE, "all-low mix prices at 4.5625 bits/value");
    assert!(lo.iter().all(|v| v.is_finite()));
    assert!(rel_l2(&lo, &base) > 0.0, "the NVFP4 branch should actually perturb");

    // d_model 24 does not tile into 16-element blocks: rejected up front.
    let bad = ModelArch { d_model: 24, ..arch_rope() };
    let bparams = random_params(&bad, 778);
    let bpm = param_map(&bparams);
    let blin = bad.linears();
    let baw: Vec<Vec<f32>> = blin.iter().map(|l| vec![1.0f32; l.k_in]).collect();
    let bawr: Vec<&[f32]> = baw.iter().map(|v| v.as_slice()).collect();
    let bthr = vec![-1.0f32; blin.len()];
    let bq = QuantInputs { act_weights: bawr, thresholds: &bthr, attn_threshold: Some(-1.0) };
    let mut bkv = KvState::new(&bad, KvPrecision::Fp16);
    let err = forward_prefill(&bad, &bpm, &tokens[..s0], Some(&bq), &mut bkv).unwrap_err();
    assert!(err.to_string().contains("attention PPU"), "shape gate: {err}");
    assert!(bkv.is_empty(), "shape gate must fire before any compute");
}

// ---------------------------------------------------------------------------
// Sharded-engine parity (tensor parallelism)
// ---------------------------------------------------------------------------

/// **Acceptance criterion:** the tensor-parallel sharded engine is
/// bit-for-bit identical to the single-worker engine — batched prefill
/// logits, every decode step's logits, and the realized activation FP8
/// fractions — across worker counts {1, 2, 4} × KV {FP16, FP8} ×
/// {attn-PPU off, on}. Worker 4 exceeds tiny-llama's 3 heads, so the
/// empty-tail-shard path is exercised too. Metrics that are *derived*
/// (`kv_bits_per_value`) agree to FP summation order; everything the token
/// stream depends on agrees exactly.
#[test]
fn sharded_engine_matches_single_worker_bit_exact() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let d_model = fx.ev.arts.manifest.arch().unwrap().d_model;
    let prompts: Vec<Vec<i32>> = [5usize, 17, 9]
        .iter()
        .enumerate()
        .map(|(i, &len)| fx.ev.test_stream[i * 24..i * 24 + len].to_vec())
        .collect();
    let steps = 5usize;

    for kv in [KvPrecision::Fp16, KvPrecision::Fp8] {
        for attn in [None, Some(0.5f32)] {
            let base = EngineOptions::default().kv(kv).attn(attn);
            let single = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), base).unwrap();
            assert_eq!(single.workers(), 1);

            // Oracle: batched prefill + `steps` batched decode steps.
            let mut oracle = single.prefill_batch(&prompts).unwrap();
            let prefill_logits: Vec<Vec<f32>> =
                oracle.iter().map(|s| s.last_logits.clone()).collect();
            let mut step_logits: Vec<Vec<Vec<f32>>> = Vec::new();
            let mut step_outs = Vec::new();
            for _ in 0..steps {
                let out = {
                    let mut refs: Vec<&mut fgmp::runtime::Session> =
                        oracle.iter_mut().collect();
                    single.decode_step(&mut refs).unwrap()
                };
                step_logits.push(oracle.iter().map(|s| s.last_logits.clone()).collect());
                step_outs.push(out);
            }
            let oracle_kv_bits: u64 = oracle.iter().map(|s| s.kv_bits()).sum();

            for world in [1usize, 2, 4] {
                let tag = format!("{kv:?} attn={attn:?} w{world}");
                let eng =
                    build_engine(&fx.rt, &fx.spec, fx.tail.clone(), base.workers(world))
                        .unwrap();
                assert_eq!(eng.workers(), world, "{tag}");
                assert!(eng.is_cached(), "{tag}");
                assert_eq!(eng.kv_precision(), kv, "{tag}");
                let mut sessions = eng.prefill_batch(&prompts).unwrap();
                for (i, (s, want)) in sessions.iter().zip(&prefill_logits).enumerate() {
                    assert_eq!(s.tokens, oracle_tokens_at(&oracle, i, steps), "{tag} ctx {i}");
                    assert_bits_eq(&s.last_logits, want, &format!("{tag} prefill {i}"));
                }
                for step in 0..steps {
                    let out = {
                        let mut refs: Vec<&mut fgmp::runtime::Session> =
                            sessions.iter_mut().collect();
                        eng.decode_step(&mut refs).unwrap()
                    };
                    for (i, want) in step_logits[step].iter().enumerate() {
                        assert_bits_eq(
                            &sessions[i].last_logits,
                            want,
                            &format!("{tag} step {step} session {i}"),
                        );
                    }
                    let o = &step_outs[step];
                    assert_eq!(out.rows, o.rows, "{tag} step {step}");
                    assert_eq!(out.kv_tokens, o.kv_tokens, "{tag} step {step}");
                    assert_eq!(out.act_fp8, o.act_fp8, "{tag} step {step} act fracs");
                    // Worker widths tile d_model and the width-weighted mix
                    // reproduces the single-engine token-weighted bits (up
                    // to FP summation order).
                    let wsum: usize = out.kv_mix.iter().map(|(w, _)| *w).sum();
                    assert_eq!(wsum, d_model, "{tag} step {step} mix widths");
                    let rebuilt: f64 = out
                        .kv_mix
                        .iter()
                        .map(|&(w, b)| b * w as f64 / d_model as f64)
                        .sum();
                    assert!(
                        (rebuilt - o.kv_bits_per_value).abs()
                            <= 1e-9 * o.kv_bits_per_value.max(1.0),
                        "{tag} step {step}: mix {rebuilt} vs {}",
                        o.kv_bits_per_value
                    );
                }
                // Same context and same physical cache bits, sharded or not.
                for (i, s) in sessions.iter().enumerate() {
                    assert_eq!(s.tokens, oracle[i].tokens, "{tag} final ctx {i}");
                    assert_eq!(s.cached_tokens(), oracle[i].cached_tokens(), "{tag} {i}");
                }
                let shard_kv_bits: u64 = sessions.iter().map(|s| s.kv_bits()).sum();
                assert_eq!(shard_kv_bits, oracle_kv_bits, "{tag} stored bits");
            }
        }
    }
}

/// Context snapshot helper for the parity test: the oracle sessions have
/// already decoded `steps` tokens, so a freshly prefilled session's context
/// must equal the oracle's context minus those trailing tokens.
fn oracle_tokens_at(oracle: &[fgmp::runtime::Session], i: usize, steps: usize) -> Vec<i32> {
    let t = &oracle[i].tokens;
    t[..t.len() - steps].to_vec()
}

/// Greedy decode streams are identical through the sharded engine — across
/// the rolling re-prefill boundary, so the windowed-roll path is sharded
/// correctly too (FP8 KV, the precision where any divergence would show).
#[test]
fn sharded_greedy_stream_matches_single_worker_across_roll() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let n = arch.max_seq + 10; // crosses at least one roll
    let opts = EngineOptions::default().kv(KvPrecision::Fp8);
    let single = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    let sharded = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts.workers(2)).unwrap();
    let want = greedy(single.as_ref(), &prompt, n);
    let got = greedy(sharded.as_ref(), &prompt, n);
    assert_eq!(got, want, "sharded greedy stream vs single worker across roll");
}

/// Sharded pool accounting: per-worker pools have the same page capacity
/// and the same per-session page usage as the single engine's pool (page
/// geometry depends on layers/tokens, not row width), sessions report
/// pages summed across shards, and a session prefilled on one engine kind
/// is rejected by the other's decode step.
#[test]
fn sharded_pool_accounting_and_session_validation() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let opts = EngineOptions::default().kv(KvPrecision::Fp16);
    let single = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    let sharded = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts.workers(2)).unwrap();
    assert_eq!(sharded.kv_pages_per_session(), single.kv_pages_per_session());
    assert_eq!(sharded.max_live_sessions(), single.max_live_sessions());
    assert_eq!(
        sharded.kv_pages_worst_for(10, 20),
        single.kv_pages_worst_for(10, 20)
    );
    let stats_s = single.pool_stats().unwrap();
    let stats_t = sharded.pool_stats().unwrap();
    assert_eq!(stats_t.total_pages, stats_s.total_pages, "same per-pool capacity");

    let prompt: Vec<i32> = fx.ev.test_stream[..9].to_vec();
    let mut a = single.prefill(&prompt).unwrap();
    let mut b = sharded.prefill(&prompt).unwrap();
    // Each worker pool mirrors the single pool's usage; the session's own
    // page count sums across its two shards.
    assert_eq!(sharded.pool_stats().unwrap().in_use_pages, a.kv_pages());
    assert_eq!(b.kv_pages(), 2 * a.kv_pages());
    assert_eq!(b.cached_tokens(), a.cached_tokens());

    // Cross-engine sessions are rejected up front, tokens untouched.
    let before = b.tokens.clone();
    {
        let mut refs = [&mut b];
        assert!(single.decode_step(&mut refs).is_err(), "sharded session on Engine");
    }
    assert_eq!(b.tokens, before);
    let before = a.tokens.clone();
    {
        let mut refs = [&mut a];
        assert!(sharded.decode_step(&mut refs).is_err(), "Engine session on sharded");
    }
    assert_eq!(a.tokens, before);

    // Retirement returns every page to every worker pool.
    drop(b);
    assert_eq!(sharded.pool_stats().unwrap().in_use_pages, 0);
}

/// `EngineOptions::attn_threshold` threads the attention PPU into the
/// serving path: the decode step's `kv_bits_per_value` reports the
/// realized FGMP mix of the stored cache instead of the nominal width,
/// which is what the serve energy report prices KV reads at.
#[test]
fn engine_attn_ppu_reports_realized_kv_mix() {
    use fgmp::model::kv::{FP8_BITS_PER_VALUE, NVFP4_BITS_PER_VALUE};
    use fgmp::runtime::EngineOptions;
    let fx = engine_fixture();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    for (thr, want) in [
        (None, 8.0), // nominal FP8, knob off
        (Some(-1.0), FP8_BITS_PER_VALUE),
        (Some(f32::INFINITY), NVFP4_BITS_PER_VALUE),
    ] {
        let opts = EngineOptions {
            kv: KvPrecision::Fp8,
            attn_threshold: thr,
            ..EngineOptions::default()
        };
        let engine =
            fgmp::runtime::Engine::with_options(&fx.rt, &fx.spec, fx.tail.clone(), opts)
                .unwrap();
        let mut sess = engine.prefill(&prompt).unwrap();
        let out = {
            let mut refs = [&mut sess];
            engine.decode_step(&mut refs).unwrap()
        };
        assert_eq!(out.rows, 1);
        assert_eq!(out.kv_tokens, (prompt.len() + 1) as u64, "thr {thr:?}");
        assert_eq!(out.kv_bits_per_value, want, "thr {thr:?}");
        assert!(sess.last_logits.iter().all(|v| v.is_finite()), "thr {thr:?}");
    }
}

// ---------------------------------------------------------------------------
// Speculative decoding: SpecEngine streams, fork/rollback, accept accounting
// ---------------------------------------------------------------------------

/// Greedy-decode `n` tokens from an engine that may be speculative. A spec
/// round queues the extra accepted tokens on the session, and the stream
/// contract is to emit them (in order) *before* the argmax of the
/// post-round logits. On a non-speculative engine the drain is empty and
/// this reproduces [`greedy`] exactly.
fn greedy_spec(
    engine: &dyn fgmp::runtime::InferenceEngine,
    prompt: &[i32],
    n: usize,
) -> Vec<i32> {
    let mut sess = engine.prefill(prompt).unwrap();
    let mut produced = vec![sess.next_token()];
    while produced.len() < n {
        let mut refs = [&mut sess];
        engine.decode_step(&mut refs).unwrap();
        produced.extend(sess.take_accepted());
        produced.push(sess.next_token());
    }
    produced.truncate(n);
    produced
}

/// The speculative greedy stream is bit-exact against the non-speculative
/// engine for every chain length × KV precision × worker count. The draft
/// runs through a lossy all-NVFP4 weight view, so the *accept rate* varies
/// — but verification always replays the chain through the target weights,
/// so the emitted stream must never diverge.
#[test]
fn spec_greedy_stream_bit_exact_vs_plain_engine() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let n = 40usize; // spec rounds only; the roll boundary has its own test
    for kv in [KvPrecision::Fp16, KvPrecision::Fp8] {
        let opts = EngineOptions::default().kv(kv);
        let plain = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
        assert_eq!(plain.spec_k(), None, "kv {kv:?}: plain engine reports no chain");
        let want = greedy(plain.as_ref(), &prompt, n);
        for workers in [1usize, 2] {
            for k in [2usize, 4, 8] {
                let eng = build_engine(
                    &fx.rt,
                    &fx.spec,
                    fx.tail.clone(),
                    opts.workers(workers).spec(Some(k)),
                )
                .unwrap();
                assert_eq!(eng.spec_k(), Some(k), "kv {kv:?} workers {workers} k {k}");
                assert!(
                    eng.spec_draft_bytes().unwrap() > 0,
                    "kv {kv:?} workers {workers} k {k}: draft view must be resident"
                );
                let got = greedy_spec(eng.as_ref(), &prompt, n);
                assert_eq!(got, want, "spec stream kv {kv:?} workers {workers} k {k}");
            }
        }
    }
}

/// Near `max_seq` a spec round cannot fit `k` new cache rows and falls back
/// to the plain step, which owns the rolling re-prefill; the stream must
/// stay bit-exact across that hand-off and pick speculation back up on the
/// shrunk post-roll cache. FP8 KV — the precision where divergence shows.
#[test]
fn spec_greedy_stream_bit_exact_across_roll() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let n = arch.max_seq + 10; // crosses at least one roll
    let opts = EngineOptions::default().kv(KvPrecision::Fp8);
    let plain = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    let want = greedy(plain.as_ref(), &prompt, n);
    for workers in [1usize, 2] {
        let eng = build_engine(
            &fx.rt,
            &fx.spec,
            fx.tail.clone(),
            opts.workers(workers).spec(Some(4)),
        )
        .unwrap();
        let got = greedy_spec(eng.as_ref(), &prompt, n);
        assert_eq!(got, want, "spec stream across roll, workers {workers}");
    }
}

/// `Session::fork` + decode-on-the-fork + drop leaves the parent
/// bit-identical — context, logits, stored cache bits, page count — and
/// returns every draft page to the pool; the parent's subsequent stream
/// matches a control session that was never forked. Covers both KV
/// precisions × both engine kinds (one shared pool vs per-worker pools).
#[test]
fn spec_fork_decode_drop_leaves_parent_untouched() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    for kv in [KvPrecision::Fp16, KvPrecision::Fp8] {
        for workers in [1usize, 2] {
            let tag = format!("kv {kv:?} workers {workers}");
            let opts = EngineOptions::default().kv(kv).workers(workers);
            let eng = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
            let mut control = eng.prefill(&prompt).unwrap();
            let mut parent = eng.prefill(&prompt).unwrap();
            let base_pages = eng.pool_stats().unwrap().in_use_pages;
            let tokens0 = parent.tokens.clone();
            let logits0 = parent.last_logits.clone();
            let (bits0, pages0) = (parent.kv_bits(), parent.kv_pages());
            {
                let mut fork = parent.fork().unwrap();
                assert_eq!(fork.tokens, tokens0, "{tag}: fork copies the context");
                assert_eq!(fork.kv_bits(), bits0, "{tag}: fork copies the cache");
                let forked = eng.pool_stats().unwrap();
                assert_eq!(
                    forked.in_use_pages, base_pages,
                    "{tag}: COW fork allocates no unique pages"
                );
                assert!(
                    forked.logical_pages > forked.in_use_pages,
                    "{tag}: fork shares its parent's pages"
                );
                for _ in 0..3 {
                    let mut refs = [&mut fork];
                    eng.decode_step(&mut refs).unwrap();
                }
                assert_eq!(
                    fork.cached_tokens(),
                    parent.cached_tokens() + 3,
                    "{tag}: fork grows independently"
                );
                let diverged = eng.pool_stats().unwrap();
                assert!(
                    diverged.in_use_pages > base_pages,
                    "{tag}: divergence pays for the fork's private pages"
                );
                assert!(diverged.cow_copies > 0, "{tag}: the shared tail was copy-on-written");
            }
            assert_eq!(
                eng.pool_stats().unwrap().in_use_pages,
                base_pages,
                "{tag}: dropped fork returns every page"
            );
            assert_eq!(parent.tokens, tokens0, "{tag}: parent context untouched");
            assert_bits_eq(&parent.last_logits, &logits0, &format!("{tag}: parent logits"));
            assert_eq!(parent.kv_bits(), bits0, "{tag}: parent cache bits untouched");
            assert_eq!(parent.kv_pages(), pages0, "{tag}: parent pages untouched");
            for step in 0..4 {
                {
                    let mut refs = [&mut control];
                    eng.decode_step(&mut refs).unwrap();
                }
                {
                    let mut refs = [&mut parent];
                    eng.decode_step(&mut refs).unwrap();
                }
                assert_bits_eq(
                    &parent.last_logits,
                    &control.last_logits,
                    &format!("{tag}: post-fork stream step {step}"),
                );
            }
        }
    }
}

/// Accept-rate bookkeeping: per-round `StepOut::{drafted, accepted}` sum to
/// the session's lifetime totals, the queued accepted tokens drain exactly
/// `accepted` per round, and steps/context advance by `1 + accepted` per
/// round. Far from `max_seq` (48 cached tokens max here, window 128) no
/// round may silently fall back to the plain step with a healthy pool.
#[test]
fn spec_step_accounting_matches_session_totals() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let prompt: Vec<i32> = fx.ev.test_stream[..8].to_vec();
    let k = 4usize;
    let opts = EngineOptions::default().kv(KvPrecision::Fp8).spec(Some(k));
    let eng = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    let mut sess = eng.prefill(&prompt).unwrap();
    assert_eq!(sess.spec_drafted_total, 0);
    assert!(sess.take_accepted().is_empty(), "nothing queued after prefill");

    let rounds = 10usize;
    let (mut drafted, mut accepted) = (0u64, 0u64);
    for round in 0..rounds {
        let out = {
            let mut refs = [&mut sess];
            eng.decode_step(&mut refs).unwrap()
        };
        assert_eq!(out.rows, 1, "round {round}");
        assert_eq!(out.drafted, (k - 1) as u64, "round {round}: full chain drafted");
        assert!(out.accepted <= out.drafted, "round {round}");
        let queued = sess.take_accepted();
        assert_eq!(queued.len() as u64, out.accepted, "round {round}: queue drains");
        drafted += out.drafted;
        accepted += out.accepted;
    }
    assert_eq!(sess.spec_drafted_total, drafted, "lifetime drafted total");
    assert_eq!(sess.spec_accepted_total, accepted, "lifetime accepted total");
    assert_eq!(sess.steps as u64, rounds as u64 + accepted, "steps per round");
    assert_eq!(
        sess.tokens.len() as u64,
        prompt.len() as u64 + rounds as u64 + accepted,
        "each round consumes 1 + accepted tokens"
    );
}

// ---------------------------------------------------------------------------
// Copy-on-write fork accounting and prefix-sharing admission
// ---------------------------------------------------------------------------

/// Reconcile the pool's books against the page tables of the live caches:
/// unique + free partitions the arena exactly, and the logical count is
/// the sum of every holder's page-table length (no index in these tests).
fn assert_pool_reconciles(pool: &KvPool, states: &[&KvState], what: &str) {
    let s = pool.stats();
    assert_eq!(s.in_use_pages + s.free_pages, s.total_pages, "{what}: arena partition");
    let logical: usize = states.iter().map(|kv| kv.kv_pages()).sum();
    assert_eq!(s.logical_pages, logical, "{what}: logical = sum of page tables");
    assert!(s.in_use_pages <= s.logical_pages, "{what}: every unique page has a holder");
    assert!(s.peak_in_use >= s.in_use_pages, "{what}: peak watermark");
}

/// **Acceptance criterion:** a COW fork is a page-table copy — it
/// allocates no unique pages — and a write into the shared partial tail
/// clones exactly that page per buffer, leaving the parent byte-identical:
/// after the child diverges, the parent's next step is bit-equal to a
/// flat never-forked oracle. Forking at an exact page boundary shares
/// only full pages, so divergence allocates fresh pages with zero copies.
#[test]
fn cow_fork_shares_pages_and_write_copies_only_the_divergent_tail() {
    let mut rng = Rng::new(0xC0C0);
    let arch = arch_rope();
    let bufs = 2 * arch.n_layers; // one K and one V buffer per layer
    let params = random_params(&arch, 610);
    let pm = param_map(&params);
    let tokens = random_tokens(&mut rng, PAGE_TOKENS + 5, arch.vocab); // partial tail page
    let (t, u) = (3i32, 7i32);

    // Flat oracle: same prefill, the parent's next token.
    let mut flat = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &tokens, None, &mut flat).unwrap();
    let want = forward_step(&arch, &pm, t, &mut flat, None).unwrap().logits;

    let pool = KvPool::new(&arch, KvPrecision::Fp16, 64);
    let mut parent = KvState::new_paged(&arch, &pool);
    forward_prefill(&arch, &pm, &tokens, None, &mut parent).unwrap();
    let base = pool.stats();
    assert_eq!(base.logical_pages, base.in_use_pages, "no sharing before the fork");

    let mut child = parent.fork().unwrap();
    let s = pool.stats();
    assert_eq!(s.in_use_pages, base.in_use_pages, "fork allocates no unique pages");
    assert_eq!(s.logical_pages, 2 * base.in_use_pages, "fork doubles the logical count");
    assert!(s.sharing_factor() > 1.99, "everything is shared right after the fork");
    assert_eq!(s.cow_copies, 0);

    // The child diverges on a different token: only the partially-filled
    // tail page of each buffer is writable-shared, so exactly `bufs`
    // pages are copy-on-written.
    forward_step(&arch, &pm, u, &mut child, None).unwrap();
    let s = pool.stats();
    assert_eq!(s.cow_copies, bufs as u64, "one COW per K/V buffer tail");
    assert_eq!(s.in_use_pages, base.in_use_pages + bufs, "divergence cost = tail pages");

    // The parent's tail is unique again: its own step appends in place
    // and its logits match the never-forked flat oracle bit-for-bit.
    let got = forward_step(&arch, &pm, t, &mut parent, None).unwrap().logits;
    assert_bits_eq(&got, &want, "parent stream after the child diverged");
    assert_eq!(pool.stats().cow_copies, bufs as u64, "parent pays no further COW");

    // Boundary fork: every shared page is full, so divergence allocates
    // fresh pages and never copies payloads.
    let pool2 = KvPool::new(&arch, KvPrecision::Fp16, 64);
    let mut parent2 = KvState::new_paged(&arch, &pool2);
    forward_prefill(&arch, &pm, &tokens[..PAGE_TOKENS], None, &mut parent2).unwrap();
    let base2 = pool2.stats();
    let mut child2 = parent2.fork().unwrap();
    forward_step(&arch, &pm, u, &mut child2, None).unwrap();
    let s2 = pool2.stats();
    assert_eq!(s2.cow_copies, 0, "full shared pages are never rewritten");
    assert_eq!(s2.in_use_pages, base2.in_use_pages + bufs, "fresh pages, not copies");
}

/// Exhaustion charges **unique** pages only: with a pool sized exactly
/// for the parent, the deep `fork_copy` fails while the COW `fork`
/// succeeds for free; pool pressure surfaces at divergence (typed, before
/// compute, both caches intact), and dropping the fork un-shares the
/// parent's tail so decode resumes bit-exactly with zero free pages.
#[test]
fn cow_exhaustion_charges_unique_pages_only() {
    let mut rng = Rng::new(0xC0C1);
    let arch = arch_rope();
    let params = random_params(&arch, 611);
    let pm = param_map(&params);
    let tokens = random_tokens(&mut rng, 5, arch.vocab);

    // Flat oracle for the post-drop resume step.
    let mut flat = KvState::new(&arch, KvPrecision::Fp16);
    forward_prefill(&arch, &pm, &tokens, None, &mut flat).unwrap();
    let want = forward_step(&arch, &pm, 9, &mut flat, None).unwrap().logits;

    let per = KvPool::pages_for_session(arch.n_layers, tokens.len());
    let pool = KvPool::new(&arch, KvPrecision::Fp16, per);
    let mut parent = KvState::new_paged(&arch, &pool);
    forward_prefill(&arch, &pm, &tokens, None, &mut parent).unwrap();
    assert_eq!(pool.stats().free_pages, 0, "pool sized exactly for the parent");

    assert!(parent.fork_copy().is_err(), "a deep copy needs a full second page set");
    assert_eq!(pool.stats().exhausted_events, 1);
    let mut child = parent.fork().unwrap(); // the COW fork needs nothing
    assert_eq!(pool.stats().logical_pages, 2 * per);

    // Divergence needs a COW page neither side has: typed, all-or-nothing.
    let err = forward_step(&arch, &pm, 9, &mut child, None).unwrap_err();
    assert!(err.downcast_ref::<KvPoolExhausted>().is_some(), "untyped: {err}");
    assert_eq!(child.len(), tokens.len(), "failed divergence leaves the child intact");
    let s = pool.stats();
    assert_eq!((s.in_use_pages, s.cow_copies), (per, 0), "no partial COW state");
    assert_eq!(s.exhausted_events, 2);

    // Retiring the fork un-shares the tail: the parent appends in place.
    drop(child);
    assert_eq!(pool.stats().logical_pages, per);
    let got = forward_step(&arch, &pm, 9, &mut parent, None).unwrap().logits;
    assert_bits_eq(&got, &want, "parent resumes bit-exactly at zero free pages");
}

/// Pool accounting reconciles with the live page tables across every
/// phase of a fork's life: fork → divergence (COW) → growth across a page
/// boundary → truncate back into the shared prefix → drops in both
/// orders. `truncate` frees the fork's private pages and releases its
/// references on shared ones; a unique page frees only when every holder
/// lets go.
#[test]
fn cow_accounting_reconciles_across_fork_write_truncate_drop() {
    let mut rng = Rng::new(0xC0C2);
    let arch = arch_rope();
    let bufs = 2 * arch.n_layers;
    let params = random_params(&arch, 612);
    let pm = param_map(&params);
    let pool = KvPool::new(&arch, KvPrecision::Fp8, 64);
    let tokens = random_tokens(&mut rng, PAGE_TOKENS + 5, arch.vocab);

    let mut parent = KvState::new_paged(&arch, &pool);
    forward_prefill(&arch, &pm, &tokens, None, &mut parent).unwrap();
    assert_pool_reconciles(&pool, &[&parent], "after prefill");

    let mut child = parent.fork().unwrap();
    assert_pool_reconciles(&pool, &[&parent, &child], "after fork");

    // Diverge, then grow the child across the next page boundary.
    let steps = 2 * PAGE_TOKENS - child.len() + 1;
    for i in 0..steps {
        forward_step(&arch, &pm, (i % arch.vocab) as i32, &mut child, None).unwrap();
    }
    assert_eq!(child.len(), 2 * PAGE_TOKENS + 1);
    let s = pool.stats();
    assert_eq!(s.cow_copies, bufs as u64, "only the shared tail page was copied");
    assert_pool_reconciles(&pool, &[&parent, &child], "after divergence");

    // Truncate the child back into the shared prefix: its COW'd and
    // fresh pages free, its references on shared pages drop, and the
    // parent keeps every one of its own pages alive.
    let in_use_before = pool.stats().in_use_pages;
    child.truncate(PAGE_TOKENS);
    assert_eq!(child.kv_pages(), bufs, "one shared page per buffer survives");
    let s = pool.stats();
    assert_eq!(s.in_use_pages, in_use_before - 2 * bufs, "COW'd + fresh pages freed");
    assert_pool_reconciles(&pool, &[&parent, &child], "after truncate");

    // The parent drops first: its privately-held tail frees, but the
    // pages the child still references stay unique-held.
    drop(parent);
    let s = pool.stats();
    assert_eq!(s.in_use_pages, bufs, "the child keeps the shared prefix alive");
    assert_pool_reconciles(&pool, &[&child], "after parent drop");

    drop(child);
    let s = pool.stats();
    assert_eq!((s.in_use_pages, s.logical_pages, s.free_pages), (0, 0, 64));
    assert_eq!(s.peak_in_use, 4 * bufs, "high-water mark from the diverged phase");
}

/// **Acceptance criterion:** prefix-shared prefill is bit-exact vs the
/// plain engine — a full hit, a cap-limited partial hit, and misses, over
/// FP16 and FP8 KV with and without the attention PPU — and decode
/// continues bit-identically from the mapped caches. The index's
/// hit/miss/reuse counters match the traffic exactly.
#[test]
fn prefix_prefill_bit_exact_vs_plain_engine() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let stream = &fx.ev.test_stream;
    let prefix: Vec<i32> = stream[..3 * PAGE_TOKENS].to_vec();
    let mut p1 = prefix.clone();
    p1.extend_from_slice(&stream[100..104]); // miss; registers the 3-chunk prefix
    let mut p2 = prefix.clone();
    p2.extend_from_slice(&stream[110..118]); // full hit: 48 mapped, 8 extended
    let mut p3 = stream[..2 * PAGE_TOKENS].to_vec();
    p3.extend_from_slice(&stream[120..136]); // 48 tokens: the lookup cap maps 32
    let p4: Vec<i32> = stream[60..75].to_vec(); // sub-page prompt: a miss
    let prompts = [p1, p2, p3, p4];

    for kv in [KvPrecision::Fp16, KvPrecision::Fp8] {
        for attn in [None, Some(0.5f32)] {
            let tag = format!("{kv:?} attn={attn:?}");
            let base = EngineOptions::default().kv(kv).attn(attn);
            let plain = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), base).unwrap();
            let shared =
                build_engine(&fx.rt, &fx.spec, fx.tail.clone(), base.prefix_share(true))
                    .unwrap();
            assert!(plain.prefix_stats().is_none(), "{tag}: plain engine has no index");

            // Serial prefills: later prompts hit what earlier ones registered.
            let mut want: Vec<fgmp::runtime::Session> = Vec::new();
            let mut got: Vec<fgmp::runtime::Session> = Vec::new();
            for p in &prompts {
                want.push(plain.prefill(p).unwrap());
                got.push(shared.prefill(p).unwrap());
            }
            for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                assert_eq!(g.tokens, w.tokens, "{tag} prompt {i}: context");
                assert_bits_eq(&g.last_logits, &w.last_logits, &format!("{tag} prompt {i}"));
                assert_eq!(g.kv_bits(), w.kv_bits(), "{tag} prompt {i}: stored cache");
                assert_eq!(g.cached_tokens(), w.cached_tokens(), "{tag} prompt {i}");
            }
            let ps = shared.prefix_stats().unwrap();
            assert_eq!((ps.hits, ps.misses), (2, 2), "{tag}: p2/p3 hit, p1/p4 miss");
            assert_eq!(ps.tokens_reused, (5 * PAGE_TOKENS) as u64, "{tag}: 48 + 32 reused");
            assert!(ps.pages_held > 0, "{tag}: the index holds the registered chunks");
            assert!(
                shared.pool_stats().unwrap().sharing_factor() > 1.0,
                "{tag}: mapped pages are shared"
            );

            // Decode continues bit-identically from the mapped caches.
            for step in 0..4 {
                let ow = {
                    let mut refs: Vec<&mut fgmp::runtime::Session> =
                        want.iter_mut().collect();
                    plain.decode_step(&mut refs).unwrap()
                };
                let og = {
                    let mut refs: Vec<&mut fgmp::runtime::Session> =
                        got.iter_mut().collect();
                    shared.decode_step(&mut refs).unwrap()
                };
                assert_eq!((og.rows, og.kv_tokens), (ow.rows, ow.kv_tokens), "{tag} {step}");
                if attn.is_none() {
                    assert_eq!(og.kv_bits_per_value, ow.kv_bits_per_value, "{tag} {step}");
                }
                for (i, (w, g)) in want.iter().zip(&got).enumerate() {
                    assert_eq!(g.tokens, w.tokens, "{tag} step {step} prompt {i}: tokens");
                    assert_bits_eq(
                        &g.last_logits,
                        &w.last_logits,
                        &format!("{tag} step {step} prompt {i}"),
                    );
                }
            }
        }
    }
}

/// **Acceptance criterion:** prefix sharing multiplies live-session
/// capacity: a pool that holds exactly two private 52-token sessions
/// serves five shared-prefix sessions (one full prefill + four mapped
/// suffixes) at a sharing factor ≥ 2, keeps decoding at zero free pages
/// (appends land in private tails), and at retirement only the index's
/// own references keep prefix pages unique-held.
#[test]
fn prefix_sharing_multiplies_live_sessions_over_fixed_pool() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let bufs = 2 * arch.n_layers;
    let stream = &fx.ev.test_stream;
    // 48-token shared prefix + 4-token private suffix = 4 pages per buffer.
    let prompts: Vec<Vec<i32>> = (0..5)
        .map(|i| {
            let mut p = stream[..3 * PAGE_TOKENS].to_vec();
            p.extend_from_slice(&stream[64 + 4 * i..64 + 4 * i + 4]);
            p
        })
        .collect();
    let per_private = 4 * bufs; // one session's cost without sharing
    let pool_pages = 2 * per_private;

    // The plain engine fits exactly two such sessions.
    let opts = EngineOptions::default().kv(KvPrecision::Fp8).pages(Some(pool_pages));
    let plain = build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts).unwrap();
    let _a = plain.prefill(&prompts[0]).unwrap();
    let _b = plain.prefill(&prompts[1]).unwrap();
    let err = plain.prefill(&prompts[2]).unwrap_err();
    assert!(err.downcast_ref::<KvPoolExhausted>().is_some(), "untyped: {err}");

    // The shared engine fits five into the same pool: 16 + 4 × 4 pages.
    let shared =
        build_engine(&fx.rt, &fx.spec, fx.tail.clone(), opts.prefix_share(true)).unwrap();
    let mut sessions: Vec<fgmp::runtime::Session> =
        prompts.iter().map(|p| shared.prefill(p).unwrap()).collect();
    let s = shared.pool_stats().unwrap();
    assert_eq!(s.in_use_pages, pool_pages, "five sessions exactly fill the pool");
    assert_eq!(s.free_pages, 0);
    assert!(s.sharing_factor() >= 2.0, "factor {:.2} < 2", s.sharing_factor());
    assert!(s.deduped_bytes() > 0);
    assert_eq!(shared.prefix_stats().unwrap().pages_held, 3 * bufs, "3 chunks x buffers");

    // Decode at zero free pages: every append lands in a private tail.
    {
        let mut refs: Vec<&mut fgmp::runtime::Session> = sessions.iter_mut().collect();
        shared.decode_step(&mut refs).unwrap();
    }
    for (i, sess) in sessions.iter().enumerate() {
        assert_eq!(sess.cached_tokens(), prompts[i].len() + 1, "session {i} advanced");
    }

    // Retirement: only the index's references survive.
    drop(sessions);
    let s = shared.pool_stats().unwrap();
    assert_eq!(s.in_use_pages, 3 * bufs, "the index holds the shared prefix only");
    assert_eq!(s.logical_pages, 3 * bufs);
}

/// The prompt-aware admission bound discounts exactly the whole pages the
/// index already holds for a prompt's registered prefix — and nothing on
/// an empty index or for unrelated prompts.
#[test]
fn prefix_admission_bound_discounts_indexed_pages() {
    use fgmp::runtime::{build_engine, EngineOptions};
    let fx = engine_fixture();
    let arch = fx.ev.arts.manifest.arch().unwrap();
    let engine = build_engine(
        &fx.rt,
        &fx.spec,
        fx.tail.clone(),
        EngineOptions::default().prefix_share(true),
    )
    .unwrap();
    let prompt: Vec<i32> = fx.ev.test_stream[..3 * PAGE_TOKENS + 4].to_vec();
    let want = 10usize;
    let base = engine.kv_pages_worst_for(prompt.len(), want);
    assert_eq!(
        engine.kv_pages_worst_for_prompt(&prompt, want),
        base,
        "empty index: the length-based bound"
    );
    let _held = engine.prefill(&prompt).unwrap();
    assert_eq!(
        engine.kv_pages_worst_for_prompt(&prompt, want),
        base - 2 * arch.n_layers * 3,
        "three registered chunks discounted"
    );
    let mut stranger = prompt.clone();
    stranger[0] ^= 1; // first chunk can no longer match the registered trie
    assert_eq!(
        engine.kv_pages_worst_for_prompt(&stranger, want),
        base,
        "no discount for unrelated prompts"
    );
}
