//! Property tests on the quantization and policy invariants (DESIGN.md §7),
//! seeded-RNG harness over many random cases.

use fgmp::policy::{assign_tensor, block_impact_scores, percentile, threshold_for_fp4_fraction};
use fgmp::quant::nvfp4::{nvfp4_roundtrip, nvfp4_roundtrip_block};
use fgmp::quant::{
    fp4::{decode_e2m1, encode_e2m1},
    fp8::{decode_e4m3, encode_e4m3},
    nvfp4_scale, quant_e2m1, quant_e4m3, sw_clip_block, FgmpTensor, PackedPanels, Precision,
};
use fgmp::util::Rng;
use fgmp::BLOCK;

#[test]
fn nvfp4_roundtrip_idempotent() {
    // NVFP4 idempotence, stated precisely: re-round-tripping the output
    // *with the block's scale held* is exactly the identity — the values sit
    // on the scaled E2M1 lattice. With dynamic-max re-derivation the scale
    // itself can legitimately shrink when the block max rounded down (the
    // output's absmax is smaller), so full dynamic double-round-trips are
    // only identical when the re-derived scale matches; both facets are
    // pinned here.
    let mut rng = Rng::new(0x1DE4);
    let mut rederived_mismatch = 0usize;
    let n_blocks = 20_000usize;
    for _ in 0..n_blocks {
        let mag = 10f64.powf(rng.f64() * 4.0 - 2.0);
        let x: Vec<f32> = (0..BLOCK).map(|_| (rng.normal() * mag) as f32).collect();
        let mut once = vec![0.0f32; BLOCK];
        let s1 = nvfp4_roundtrip(&x, &mut once)[0];
        // scale-held second pass: exact fixed point
        let mut held = vec![0.0f32; BLOCK];
        nvfp4_roundtrip_block(&once, s1, &mut held);
        assert_eq!(held, once, "scale-held roundtrip must be identity");
        // dynamic second pass: identity exactly when the scale re-derives
        let mut twice = vec![0.0f32; BLOCK];
        let s2 = nvfp4_roundtrip(&once, &mut twice)[0];
        if s2 == s1 {
            assert_eq!(twice, once, "same-scale dynamic roundtrip must be identity");
        } else {
            rederived_mismatch += 1;
        }
    }
    // Scale re-derivation drift is a rare corner (≈0.4% measured), not the norm.
    assert!(
        rederived_mismatch < n_blocks / 20,
        "scale drift on {rederived_mismatch}/{n_blocks} blocks"
    );
}

#[test]
fn fgmp_tensor_pack_unpack_matches_reference_codecs() {
    // Pack/unpack round-trip across random mixed FP4/FP8 block patterns:
    // every FP8 block must decode to the e4m3 round-trip of its input and
    // every FP4 block to the dynamic-max NVFP4 round-trip — bit-exact.
    let mut rng = Rng::new(0xFACC);
    for case in 0..40 {
        let blocks = 1 + rng.below(80);
        let mag = 10f64.powf(rng.f64() * 3.0 - 1.0);
        let data: Vec<f32> =
            (0..blocks * BLOCK).map(|_| (rng.normal() * mag) as f32).collect();
        let prec: Vec<Precision> = (0..blocks)
            .map(|_| if rng.f64() < 0.5 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[blocks, BLOCK], &data, &prec, None);
        assert_eq!(t.n_fp8, prec.iter().filter(|p| **p == Precision::Fp8).count());
        let back = t.unpack();
        for (bi, p) in prec.iter().enumerate() {
            let x = &data[bi * BLOCK..(bi + 1) * BLOCK];
            let got = &back[bi * BLOCK..(bi + 1) * BLOCK];
            match p {
                Precision::Fp8 => {
                    for (g, &v) in got.iter().zip(x) {
                        assert_eq!(*g, quant_e4m3(v), "case {case} block {bi} fp8");
                    }
                }
                Precision::Fp4 => {
                    let mut want = vec![0.0f32; BLOCK];
                    nvfp4_roundtrip(x, &mut want);
                    assert_eq!(got, &want[..], "case {case} block {bi} fp4");
                }
            }
        }
    }
}

#[test]
fn assign_fp8_fraction_monotone_in_threshold() {
    // assign_tensor's fp8_fraction is non-increasing in the threshold,
    // across random tensors and random threshold ladders.
    let mut rng = Rng::new(0x30_0703);
    for case in 0..20 {
        let k = BLOCK * (1 + rng.below(8));
        let rows = 1 + rng.below(32);
        let data: Vec<f32> = (0..rows * k).map(|_| (rng.normal() * 4.0) as f32).collect();
        let cw: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
        let mut thresholds: Vec<f64> = (0..12).map(|_| rng.f64() * 1e-1).collect();
        thresholds.push(f64::NEG_INFINITY);
        thresholds.push(f64::INFINITY);
        thresholds.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let mut last = f64::INFINITY;
        for &t in &thresholds {
            let a = assign_tensor(&data, k, &cw, None, t);
            assert!(
                a.fp8_fraction <= last + 1e-12,
                "case {case}: fraction rose from {last} to {} at t={t}",
                a.fp8_fraction
            );
            last = a.fp8_fraction;
        }
        assert_eq!(last, 0.0, "infinite threshold leaves no FP8 blocks");
    }
}

#[test]
fn codec_roundtrip_idempotent_random() {
    let mut rng = Rng::new(1);
    for _ in 0..20_000 {
        let x = (rng.normal() * 10f64.powf(rng.f64() * 6.0 - 3.0)) as f32;
        let q8 = quant_e4m3(x);
        assert_eq!(quant_e4m3(q8), q8, "e4m3 idempotent at {x}");
        let q4 = quant_e2m1(x);
        assert_eq!(quant_e2m1(q4), q4, "e2m1 idempotent at {x}");
        // encode/decode agrees with the round-trip
        assert_eq!(decode_e4m3(encode_e4m3(x)), q8, "e4m3 codec at {x}");
        assert_eq!(decode_e2m1(encode_e2m1(x)), q4, "e2m1 codec at {x}");
    }
}

#[test]
fn codec_monotone_random_pairs() {
    let mut rng = Rng::new(2);
    for _ in 0..20_000 {
        let a = (rng.normal() * 50.0) as f32;
        let b = (rng.normal() * 50.0) as f32;
        let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
        assert!(quant_e4m3(lo) <= quant_e4m3(hi), "e4m3 monotone {lo} {hi}");
        assert!(quant_e2m1(lo) <= quant_e2m1(hi), "e2m1 monotone {lo} {hi}");
    }
}

#[test]
fn nvfp4_error_bounded_by_half_quantum() {
    // |x - Q(x)| <= scale * 1.0 (half the largest E2M1 gap, which is 2).
    let mut rng = Rng::new(3);
    for _ in 0..2000 {
        let scale_mag = 10f64.powf(rng.f64() * 4.0 - 2.0);
        let x: Vec<f32> = (0..BLOCK).map(|_| (rng.normal() * scale_mag) as f32).collect();
        let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s = nvfp4_scale(absmax);
        let mut out = [0.0f32; BLOCK];
        nvfp4_roundtrip_block(&x, s, &mut out);
        for (a, b) in x.iter().zip(&out) {
            // elements can exceed 6*s slightly when the scale rounds down;
            // those saturate, bounded by absmax - 6s + s.
            let bound = s * 1.0 + (absmax - 6.0 * s).max(0.0) + 1e-6;
            assert!((a - b).abs() <= bound, "err {} vs bound {bound}", (a - b).abs());
        }
    }
}

#[test]
fn pack_unpack_pack_byte_identical_with_same_scales() {
    // Re-packing the dequantized values with the *same* per-block scales
    // must be byte-identical (dequantized values sit exactly on the scaled
    // E2M1 / E4M3 lattices). Dynamic-max re-derivation may legitimately
    // pick a different scale when the block max rounded down, so the
    // invariant is stated with explicit scales.
    let mut rng = Rng::new(4);
    for _ in 0..50 {
        let blocks = 4 + rng.below(60);
        let data: Vec<f32> = (0..blocks * BLOCK).map(|_| (rng.normal() * 5.0) as f32).collect();
        let prec: Vec<Precision> = (0..blocks)
            .map(|_| if rng.f64() < 0.3 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t1 = FgmpTensor::pack(&[blocks, BLOCK], &data, &prec, None);
        let deq = t1.unpack();
        let scales1: Vec<f32> = t1.scales.iter().map(|&b| decode_e4m3(b)).collect();
        let t2 = FgmpTensor::pack(&[blocks, BLOCK], &deq, &prec, Some(&scales1));
        assert_eq!(t1.payload, t2.payload, "payload stable");
        assert_eq!(t1.scales, t2.scales, "scales stable");
        assert_eq!(t1.meta, t2.meta, "metadata stable");
        // and the values themselves are a fixed point under re-unpacking
        assert_eq!(deq, t2.unpack(), "values stable");
    }
}

#[test]
fn panel_pack_unpack_roundtrip_random_shapes() {
    // The k-panelized execution layout is a pure byte reordering of the
    // storage tensor: over random odd (N, K) shapes, panel widths, mixed
    // assignments (incl. clip scales) and both all-FP8/all-FP4 extremes,
    // unpack_kn must equal the transposed FgmpTensor::unpack bit-for-bit,
    // with byte/scale/meta counts conserved.
    let mut rng = Rng::new(0x9A17);
    for trial in 0..60 {
        let n = 1 + rng.below(40);
        let kb = 1 + rng.below(6);
        let k = kb * BLOCK;
        let nr = [4usize, 8, 8, 8, 16][rng.below(5)];
        let data: Vec<f32> = (0..n * k).map(|_| (rng.normal() * 4.0) as f32).collect();
        let prec: Vec<Precision> = (0..n * kb)
            .map(|_| match trial % 3 {
                0 => {
                    if rng.f64() < 0.3 {
                        Precision::Fp8
                    } else {
                        Precision::Fp4
                    }
                }
                1 => Precision::Fp8,
                _ => Precision::Fp4,
            })
            .collect();
        let n_fp4 = prec.iter().filter(|&&p| p == Precision::Fp4).count();
        let clip: Option<Vec<f32>> = if trial % 2 == 0 {
            Some((0..n_fp4).map(|_| 0.125 + rng.f32()).collect())
        } else {
            None
        };
        let t = FgmpTensor::pack(&[n, k], &data, &prec, clip.as_deref());
        let p = PackedPanels::from_tensor(&t, nr);
        assert_eq!(p.n_blocks, t.n_blocks);
        assert_eq!(p.n_fp8, t.n_fp8);
        assert_eq!(p.payload.len(), t.payload.len(), "payload bytes conserved");
        assert_eq!(p.scales.len(), t.scales.len(), "scale bytes conserved");
        assert_eq!(p.n_panels(), n.div_ceil(nr));
        let deq_nk = t.unpack();
        let deq_kn = p.unpack_kn();
        for ni in 0..n {
            for ki in 0..k {
                assert_eq!(
                    deq_kn[ki * n + ni].to_bits(),
                    deq_nk[ni * k + ki].to_bits(),
                    "trial {trial} (n={n},k={k},nr={nr}) elem ({ni},{ki})"
                );
            }
        }
        // Resident accounting: the packed bytes match the storage-format
        // footprint (payload+scales+meta) plus the small panel tables.
        let (pb, sb, mb) = t.footprint_bits();
        let format_bytes = pb / 8 + sb / 8 + mb.div_ceil(8);
        assert!(p.resident_bytes() >= format_bytes);
        assert!(p.resident_bytes() <= format_bytes + 7 + 3 * 8 * p.n_panels());
    }
}

#[test]
fn swclip_never_worse_random() {
    let mut rng = Rng::new(5);
    for _ in 0..500 {
        let x: Vec<f32> = (0..BLOCK).map(|_| (rng.normal() * 4.0) as f32).collect();
        let g2: Vec<f32> = (0..BLOCK).map(|_| rng.f32() + 1e-3).collect();
        let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
        let s_dyn = nvfp4_scale(absmax);
        let (s_best, e_best) = sw_clip_block(&x, &g2);
        let mut out = [0.0f32; BLOCK];
        nvfp4_roundtrip_block(&x, s_dyn, &mut out);
        let e_dyn: f64 = x
            .iter()
            .zip(out.iter())
            .zip(&g2)
            .map(|((&v, &q), &g)| g as f64 * ((q - v) as f64).powi(2))
            .sum();
        assert!(e_best <= e_dyn + 1e-12);
        assert!(s_best <= s_dyn);
    }
}

#[test]
fn achieved_fp4_fraction_tracks_target() {
    let mut rng = Rng::new(6);
    let k = 256;
    let rows = 64;
    let data: Vec<f32> = (0..rows * k).map(|_| (rng.normal() * 3.0) as f32).collect();
    let cw: Vec<f32> = (0..k).map(|_| rng.f32() + 0.01).collect();
    let scores = block_impact_scores(&data, k, &cw, None);
    for target in [0.1, 0.3, 0.5, 0.7, 0.9] {
        let t = threshold_for_fp4_fraction(&scores, target);
        let a = assign_tensor(&data, k, &cw, None, t);
        let fp4 = 1.0 - a.fp8_fraction;
        assert!((fp4 - target).abs() < 0.03, "target {target}, got {fp4}");
    }
}

#[test]
fn percentile_bounds_and_monotonicity() {
    let mut rng = Rng::new(7);
    for _ in 0..50 {
        let n = 2 + rng.below(500);
        let v: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut last = f64::NEG_INFINITY;
        for i in 0..=10 {
            let q = i as f64 / 10.0;
            let p = percentile(&v, q);
            assert!(p >= last - 1e-12, "monotone in q");
            last = p;
            let lo = v.iter().cloned().fold(f64::MAX, f64::min);
            let hi = v.iter().cloned().fold(f64::MIN, f64::max);
            assert!(p >= lo && p <= hi, "within data range");
        }
    }
}

#[test]
fn global_threshold_shifts_budget_to_sensitive_tensors() {
    // Two tensors with very different sensitivity: a global threshold must
    // give the sensitive one a (much) larger FP8 share — the paper's Fig. 7
    // mechanism.
    let mut rng = Rng::new(8);
    let k = 128;
    let rows = 64;
    let data_a: Vec<f32> = (0..rows * k).map(|_| (rng.normal() * 3.0) as f32).collect();
    let data_b = data_a.clone();
    let cw_hi: Vec<f32> = (0..k).map(|_| rng.f32() * 10.0 + 5.0).collect();
    let cw_lo: Vec<f32> = cw_hi.iter().map(|v| v * 1e-3).collect();
    let mut all = block_impact_scores(&data_a, k, &cw_hi, None);
    all.extend(block_impact_scores(&data_b, k, &cw_lo, None));
    let t = threshold_for_fp4_fraction(&all, 0.5);
    let a = assign_tensor(&data_a, k, &cw_hi, None, t);
    let b = assign_tensor(&data_b, k, &cw_lo, None, t);
    assert!(a.fp8_fraction > 0.9, "sensitive tensor keeps FP8: {}", a.fp8_fraction);
    assert!(b.fp8_fraction < 0.1, "insensitive tensor goes FP4: {}", b.fp8_fraction);
}
