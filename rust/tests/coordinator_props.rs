//! Property tests for the coordinator invariants (DESIGN.md §7), using the
//! in-repo seeded-RNG harness (offline build: no proptest; many random
//! scenarios per property instead).

use std::time::Duration;

use fgmp::coordinator::{BatchPolicy, Batcher, Request, RequestKind, Router};
use fgmp::util::Rng;

fn score_req(id: u64) -> (Request, std::sync::mpsc::Receiver<fgmp::coordinator::Response>) {
    Request::new(id, RequestKind::Score { tokens: vec![id as i32], mask: vec![1.0] })
}

/// Batcher: over many random (queue depth, max_batch, arrival pattern)
/// scenarios — every request appears exactly once, order preserved, and no
/// batch exceeds max_batch.
#[test]
fn batcher_conserves_and_orders_requests() {
    let mut rng = Rng::new(0xBA7C4);
    for case in 0..50 {
        let n = 1 + rng.below(60) as u64;
        let max_batch = 1 + rng.below(12);
        let (tx, rx) = std::sync::mpsc::sync_channel(n as usize + 1);
        let mut keep = Vec::new();
        for id in 0..n {
            let (req, r) = score_req(id);
            keep.push(r);
            tx.send(req).unwrap();
        }
        drop(tx);
        let mut batcher = Batcher::new(
            BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
            rx,
        );
        let mut seen = Vec::new();
        while let Some(batch) = batcher.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= max_batch,
                    "case {case}: batch size {} vs max {max_batch}", batch.len());
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "case {case}: order/conservation");
    }
}

/// Router: requests land in exactly one queue, by kind, order preserved
/// per queue, across random interleavings.
#[test]
fn router_partitions_by_kind() {
    let mut rng = Rng::new(0x707E5);
    for case in 0..50 {
        let n = 1 + rng.below(100) as u64;
        let (router, score_rx, gen_rx) = Router::new(n as usize + 1);
        let mut want_score = Vec::new();
        let mut want_gen = Vec::new();
        for id in 0..n {
            if rng.f64() < 0.6 {
                let (req, _rx) = score_req(id);
                router.submit(req).unwrap();
                want_score.push(id);
            } else {
                let (req, _rx) =
                    Request::new(id, RequestKind::Generate { prompt: vec![1], n_tokens: 1 });
                router.submit(req).unwrap();
                want_gen.push(id);
            }
        }
        drop(router);
        let got_score: Vec<u64> = score_rx.iter().map(|r| r.id).collect();
        let got_gen: Vec<u64> = gen_rx.iter().map(|r| r.id).collect();
        assert_eq!(got_score, want_score, "case {case}");
        assert_eq!(got_gen, want_gen, "case {case}");
        assert_eq!(got_score.len() + got_gen.len(), n as usize);
    }
}

/// Batcher under concurrent production: with a slow producer the batcher
/// still terminates and conserves requests (no loss under timeout flushes).
#[test]
fn batcher_with_live_producer_conserves() {
    for seed in 0..8u64 {
        let (tx, rx) = std::sync::mpsc::sync_channel(128);
        let n = 40u64;
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            for id in 0..n {
                let (req, _rx) = score_req(id);
                tx.send(req).unwrap();
                if rng.f64() < 0.3 {
                    std::thread::sleep(Duration::from_micros(rng.below(500) as u64));
                }
            }
        });
        let mut batcher = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut seen = Vec::new();
        while let Some(batch) = batcher.next_batch() {
            seen.extend(batch.iter().map(|r| r.id));
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "seed {seed}");
    }
}

/// Admission liveness: as long as the decode loop calls
/// `drain_ready_capped` between steps with free capacity, no waiting
/// request is starved past its deadline — pickup latency stays bounded by
/// ~(max_wait + one simulated step), never unbounded.
#[test]
fn drain_ready_never_starves_waiting_requests() {
    use std::time::Instant;
    let (tx, rx) = std::sync::mpsc::sync_channel(64);
    let n = 30u64;
    let producer = std::thread::spawn(move || {
        let mut rng = Rng::new(0x57A2);
        let mut submitted = Vec::new();
        for id in 0..n {
            let (req, _rx) = score_req(id);
            submitted.push((id, Instant::now()));
            tx.send(req).unwrap();
            std::thread::sleep(Duration::from_micros(200 + rng.below(800) as u64));
        }
        submitted
    });

    let max_wait = Duration::from_millis(10);
    let step = Duration::from_millis(1);
    let mut batcher = Batcher::new(BatchPolicy { max_batch: 4, max_wait }, rx);
    // The continuous-batching shape: one blocking first batch, then a
    // busy "decode" loop that drains between steps.
    let mut picked: Vec<(u64, Instant)> = Vec::new();
    let mut live = 0usize;
    let cap = 4usize;
    match batcher.next_batch() {
        Some(batch) => {
            live += batch.len().min(2);
            picked.extend(batch.iter().map(|r| (r.id, Instant::now())));
        }
        None => unreachable!("producer still running"),
    }
    while picked.len() < n as usize {
        std::thread::sleep(step); // one decode step
        let mut admitted = Vec::new();
        batcher.drain_ready_capped(&mut admitted, cap.saturating_sub(live));
        picked.extend(admitted.iter().map(|r| (r.id, Instant::now())));
        // retire someone occasionally so capacity keeps opening
        live = live.saturating_sub(1);
    }
    let submitted = producer.join().unwrap();
    // Deadline + generous CI scheduling slack: the property is that waits
    // are *bounded* (starvation would grow with queue position).
    let bound = max_wait + Duration::from_millis(200);
    for ((id_s, t_s), (id_p, t_p)) in submitted.iter().zip(&picked) {
        assert_eq!(id_s, id_p, "FIFO admission order");
        let waited = t_p.duration_since(*t_s);
        assert!(waited < bound, "req {id_s} waited {waited:?} (bound {bound:?})");
    }
}

/// Continuous batching preserves per-request token streams: requests with
/// different prompts and budgets, admitted and retired at different times
/// while sharing batched decode steps, each produce exactly the stream a
/// dedicated single-session engine produces for their prompt (the decode
/// batch is bit-exact per row, so interleaving must be invisible).
#[test]
fn continuous_batching_preserves_per_request_streams() {
    use fgmp::coordinator::{Server, ServerConfig};
    use fgmp::eval::Evaluator;
    use fgmp::model::{KvPrecision, QuantConfig, QuantizedModel};
    use fgmp::runtime::{Engine, ExecSpec, GraphKind, Runtime};

    let dir = std::env::temp_dir().join("fgmp_coordinator_props_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);

    // Reference streams from a dedicated single-session engine.
    let engine = Engine::new(&rt, &logits_spec, tail.clone(), KvPrecision::Fp16).unwrap();
    let mut rng = Rng::new(0xC0B5);
    let cases: Vec<(Vec<i32>, usize)> = (0..10)
        .map(|i| {
            let off = i * 16;
            let len = 4 + rng.below(8);
            let n_tokens = 1 + rng.below(6);
            (ev.test_stream[off..off + len].to_vec(), n_tokens)
        })
        .collect();
    let expected: Vec<Vec<i32>> = cases
        .iter()
        .map(|(prompt, n)| {
            let mut sess = engine.prefill(prompt).unwrap();
            let mut produced = vec![sess.next_token()];
            while produced.len() < *n {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap();
                produced.push(sess.next_token());
            }
            produced.truncate(*n);
            produced
        })
        .collect();

    // A small decode batch forces queueing, mid-flight admission, and
    // staggered retirement across the 10 requests.
    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: KvPrecision::Fp16,
        decode_batch: 3,
        kv_pages: None,
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
        deadline_ms: None,
        promote_after_ms: 0,
    };
    let fwd_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::FwdQuant);
    let server = Server::start(scfg, fwd_spec, tail.clone(), logits_spec, tail).unwrap();

    let mut rxs = Vec::new();
    for (id, (prompt, n_tokens)) in cases.iter().enumerate() {
        let (req, resp_rx) = Request::new(
            id as u64,
            RequestKind::Generate { prompt: prompt.clone(), n_tokens: *n_tokens },
        );
        server.router.submit(req).unwrap();
        rxs.push(resp_rx);
        if id % 3 == 1 {
            std::thread::sleep(Duration::from_millis(2)); // stagger admission
        }
    }
    for (i, resp_rx) in rxs.into_iter().enumerate() {
        let resp = resp_rx.recv().expect("generate response");
        let got = resp.generated.expect("tokens generated");
        assert_eq!(got, expected[i], "request {i}: stream perturbed by batching");
    }
    let snap = server.metrics.snapshot();
    assert!(snap.decode_steps > 0, "decode loop must have stepped");
    assert!(snap.mean_decode_occupancy > 0.0);
    assert!(snap.ttft_p50_ms >= 0.0);
    assert_eq!(
        snap.generated_tokens,
        cases.iter().map(|(_, n)| *n as u64).sum::<u64>()
    );
    server.shutdown();
}

/// Out-of-pages backpressure: with a KV pool sized for exactly two
/// worst-case sessions and more requests than that in flight, the decode
/// loop must *defer* admissions (never fail them), keep admission FIFO, and
/// still produce every request's exact single-session stream once
/// retirement frees pages. Earlier-submitted requests finish no later than
/// requests two pool-generations behind them.
#[test]
fn pool_backpressure_defers_admissions_and_preserves_streams() {
    use fgmp::coordinator::{Server, ServerConfig};
    use fgmp::eval::Evaluator;
    use fgmp::model::{KvPool, KvPrecision, QuantConfig, QuantizedModel};
    use fgmp::runtime::{Engine, ExecSpec, GraphKind, Runtime};

    let dir = std::env::temp_dir().join("fgmp_coordinator_pool_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let arch = ev.arts.manifest.arch().unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);

    // Room for exactly 2 worst-case *requests* (prompt 6 + 4 generated
    // tokens → `pages_for_session(layers, 10)` committed each by the
    // admission budget), but a decode batch of 4: admission is
    // pool-budget-bound, not batch-bound.
    let n_tokens = 4usize;
    let per_request = KvPool::pages_for_session(arch.n_layers, 6 + n_tokens);
    let kv_pages = 2 * per_request;

    // Reference streams from a dedicated single-session engine.
    let engine = Engine::new(&rt, &logits_spec, tail.clone(), KvPrecision::Fp16).unwrap();
    let cases: Vec<Vec<i32>> =
        (0..8).map(|i| ev.test_stream[i * 20..i * 20 + 6].to_vec()).collect();
    let expected: Vec<Vec<i32>> = cases
        .iter()
        .map(|prompt| {
            let mut sess = engine.prefill(prompt).unwrap();
            let mut produced = vec![sess.next_token()];
            while produced.len() < n_tokens {
                let mut refs = [&mut sess];
                engine.decode_step(&mut refs).unwrap();
                produced.push(sess.next_token());
            }
            produced.truncate(n_tokens);
            produced
        })
        .collect();

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: KvPrecision::Fp16,
        decode_batch: 4,
        kv_pages: Some(kv_pages),
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
        deadline_ms: None,
        promote_after_ms: 0,
    };
    let fwd_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::FwdQuant);
    let server = Server::start(scfg, fwd_spec, tail.clone(), logits_spec, tail).unwrap();

    // Submit everything up front so the pool bound must bite.
    let mut rxs = Vec::new();
    for (id, prompt) in cases.iter().enumerate() {
        let (req, resp_rx) = Request::new(
            id as u64,
            RequestKind::Generate { prompt: prompt.clone(), n_tokens },
        );
        server.router.submit(req).unwrap();
        rxs.push(resp_rx);
    }
    let mut latencies = Vec::new();
    for (i, resp_rx) in rxs.into_iter().enumerate() {
        let resp = resp_rx.recv().expect("generate response");
        let got = resp.generated.unwrap_or_else(|| panic!("request {i} failed under backpressure"));
        assert_eq!(got, expected[i], "request {i}: stream perturbed by deferral");
        latencies.push(resp.latency);
    }
    // FIFO deferral ordering: with equal budgets and 2 slots, the first
    // pair must complete well before the last pair (which waits out three
    // pool generations).
    let first = latencies[0].max(latencies[1]);
    let last = latencies[6].min(latencies[7]);
    assert!(
        first <= last,
        "deferral reordered completion: first pair {first:?} vs last pair {last:?}"
    );

    let snap = server.metrics.snapshot();
    assert!(snap.deferred_admissions > 0, "the pool bound never bit");
    assert_eq!(snap.kv_pool_pages, kv_pages as u64);
    assert!(snap.kv_pool_peak_pages <= snap.kv_pool_pages);
    assert!(snap.kv_pool_occupancy > 0.0 && snap.kv_pool_occupancy <= 1.0);
    assert!(snap.kv_page_fill > 0.0 && snap.kv_page_fill <= 1.0);
    assert_eq!(
        snap.generated_tokens,
        (cases.len() * n_tokens) as u64,
        "every deferred request still completed in full"
    );
    server.shutdown();
}

/// Tensor-parallel KV energy pricing: over random shard splits and
/// per-worker precision mixes, `decode_step_energy_tp`
/// (1) reduces *exactly* to `decode_step_energy` for a single full-width
///     entry,
/// (2) equals the sum of per-worker prices (each worker billed at its own
///     stored width × its own realized bits), and
/// (3) strictly under-prices vs the buggy average-then-multiply formula
///     whenever wide shards quantized harder than narrow ones (and
///     over-prices in the mirror case) — the misprice the per-worker sum
///     exists to fix.
#[test]
fn decode_step_energy_tp_prices_each_shard_at_its_own_width() {
    use fgmp::coordinator::{decode_step_energy, decode_step_energy_tp};
    use fgmp::hwsim::kvcache::KvModelDims;
    use fgmp::hwsim::EnergyModel;

    let em = EnergyModel::default();
    let mut rng = Rng::new(0xE4E26);
    for case in 0..200 {
        let n_layers = 1 + rng.below(6);
        let world = 1 + rng.below(4);
        // Worker widths tile d_model in 16-wide blocks, like panel shards.
        let widths: Vec<usize> = (0..world).map(|_| 16 * (1 + rng.below(8))).collect();
        let d_model: usize = widths.iter().sum();
        let dims = KvModelDims { n_layers, d_model, weight_elements: 0 };
        let kv_tokens = 1 + rng.below(500) as u64;
        let mix: Vec<(usize, f64)> =
            widths.iter().map(|&w| (w, 4.0 + 12.0 * rng.f64())).collect();

        // (1) Single entry at full width reduces exactly.
        let bits0 = mix[0].1;
        let (a, a8) =
            decode_step_energy_tp(&[], &[], 1, &dims, kv_tokens, &[(d_model, bits0)], &em);
        let (b, b8) = decode_step_energy(&[], &[], 1, &dims, kv_tokens, bits0, &em);
        assert_eq!(a.to_bits(), b.to_bits(), "case {case}: single-entry fgmp");
        assert_eq!(a8.to_bits(), b8.to_bits(), "case {case}: single-entry baseline");

        // (2) Multi-entry = Σ_w price(width_w, bits_w); baselines agree
        // (the all-FP8 comparison point reads one full-width 16-bit cache).
        let (tp, tp8) = decode_step_energy_tp(&[], &[], 1, &dims, kv_tokens, &mix, &em);
        let want: f64 = mix
            .iter()
            .map(|&(w, bits)| {
                let wdims = KvModelDims { d_model: w, ..dims.clone() };
                decode_step_energy(&[], &[], 1, &wdims, kv_tokens, bits, &em).0
            })
            .sum();
        assert!(
            (tp - want).abs() <= 1e-9 * want.max(1.0),
            "case {case}: per-worker sum {want} vs tp {tp}"
        );
        assert_eq!(tp8.to_bits(), b8.to_bits(), "case {case}: shared baseline");

        // (3) The average-then-multiply formula misprices by exactly
        // (mean − width-weighted-mean) × total cache values × e_kv_bit,
        // up to per-term u64 truncation in `kv_cache_bits` — so averaging
        // is only correct when all shards share one mix or one width.
        let mean_bits: f64 = mix.iter().map(|&(_, b)| b).sum::<f64>() / mix.len() as f64;
        let weighted: f64 =
            mix.iter().map(|&(w, b)| b * w as f64).sum::<f64>() / d_model as f64;
        let (avg, _) = decode_step_energy(&[], &[], 1, &dims, kv_tokens, mean_bits, &em);
        let values = (2 * n_layers as u64 * kv_tokens * d_model as u64) as f64;
        let expected_delta = (mean_bits - weighted) * values * em.e_kv_bit;
        let tol = (world as f64 + 2.0) * em.e_kv_bit + 1e-9 * expected_delta.abs();
        assert!(
            ((avg - tp) - expected_delta).abs() <= tol,
            "case {case}: misprice {} vs expected {expected_delta}",
            avg - tp
        );
    }
}

/// Typed deadlines: with `deadline_ms = 0` every generation request has
/// already expired by the time the decode loop sees it, so each one must
/// be answered exactly once with [`Rejection::DeadlineExceeded`] — never a
/// silent drop, never an untyped failure — and the rejection counter
/// reconciles with the submissions.
#[test]
fn zero_deadline_rejects_every_generation_typed() {
    use fgmp::coordinator::{Rejection, Server, ServerConfig};
    use fgmp::eval::Evaluator;
    use fgmp::model::{KvPrecision, QuantConfig, QuantizedModel};
    use fgmp::runtime::{ExecSpec, GraphKind, Runtime};

    let dir = std::env::temp_dir().join("fgmp_coordinator_deadline_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: KvPrecision::Fp16,
        decode_batch: 4,
        kv_pages: None,
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
        deadline_ms: Some(0),
        promote_after_ms: 250,
    };
    let fwd_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::FwdQuant);
    let server = Server::start(scfg, fwd_spec, tail.clone(), logits_spec, tail).unwrap();

    let mut rxs = Vec::new();
    for id in 0..6u64 {
        let (req, rx) = Request::new(
            id,
            RequestKind::Generate { prompt: ev.test_stream[..6].to_vec(), n_tokens: 4 },
        );
        server.router.submit(req).unwrap();
        rxs.push(rx);
    }
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv().expect("typed response");
        assert_eq!(resp.rejection, Some(Rejection::DeadlineExceeded), "request {i}");
        assert!(resp.generated.is_none(), "request {i} generated past its deadline");
    }
    let snap = server.metrics.snapshot();
    assert_eq!(snap.deadline_rejections, 6);
    assert_eq!(snap.generated_tokens, 0);
    server.shutdown();
}

/// Starvation bound under bypass (`promote_after_ms > 0`): a big request
/// whose worst case needs the whole pool is submitted early, while a
/// producer keeps feeding small requests that are allowed to bypass a
/// young deferred head. Without the age-based promotion bound the small
/// traffic would keep the pool busy and starve the big request forever;
/// with it, admission reverts to strict head-of-line once the head ages —
/// preempting live sessions under sustained pressure — so the big request
/// completes while small traffic is still arriving, and every stream
/// (including any preempted-and-resumed one) stays bit-exact.
#[test]
fn aged_deferred_head_is_not_starved_by_bypass() {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Arc;

    use fgmp::coordinator::{Server, ServerConfig};
    use fgmp::eval::Evaluator;
    use fgmp::model::{KvPool, KvPrecision, QuantConfig, QuantizedModel};
    use fgmp::runtime::{Engine, ExecSpec, GraphKind, Runtime};

    let dir = std::env::temp_dir().join("fgmp_coordinator_aging_artifacts");
    let _ = std::fs::remove_dir_all(&dir);
    fgmp::io::synth::ensure_model(&dir, "tiny-llama", 42).expect("synthesize artifacts");

    let rt = Runtime::native();
    let ev = Evaluator::load(&rt, &dir, "tiny-llama").unwrap();
    let arch = ev.arts.manifest.arch().unwrap();
    let cfg = QuantConfig::fgmp(0.7);
    let qm = QuantizedModel::quantize(&ev.arts, &cfg).unwrap();
    let tail = ev.quant_arg_tail(&cfg, &qm).unwrap();
    let shapes = qm.layer_profiles(&ev.arts.manifest, ev.batch * ev.seq, &[]);
    let logits_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::LogitsQuant);

    // The pool holds exactly two small requests; the big request's worst
    // case is the whole pool, so it can only ever run alone.
    let n_tokens = 4usize;
    let small_prompt: Vec<i32> = ev.test_stream[..6].to_vec();
    let big_prompt: Vec<i32> = ev.test_stream[32..52].to_vec();
    let per_small = KvPool::pages_for_session(arch.n_layers, small_prompt.len() + n_tokens);
    let kv_pages = 2 * per_small;
    assert_eq!(
        KvPool::pages_for_session(arch.n_layers, big_prompt.len() + n_tokens),
        kv_pages,
        "the big request must need the whole pool"
    );

    // Reference streams from a dedicated single-session engine.
    let engine = Engine::new(&rt, &logits_spec, tail.clone(), KvPrecision::Fp16).unwrap();
    let stream_for = |prompt: &[i32]| -> Vec<i32> {
        let mut sess = engine.prefill(prompt).unwrap();
        let mut produced = vec![sess.next_token()];
        while produced.len() < n_tokens {
            let mut refs = [&mut sess];
            engine.decode_step(&mut refs).unwrap();
            produced.push(sess.next_token());
        }
        produced
    };
    let small_expected = stream_for(&small_prompt);
    let big_expected = stream_for(&big_prompt);

    let scfg = ServerConfig {
        batch: ev.batch,
        seq: ev.seq,
        policy: BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
        layer_shapes: shapes,
        queue_depth: 64,
        kv_precision: KvPrecision::Fp16,
        decode_batch: 3,
        kv_pages: Some(kv_pages),
        energy: fgmp::hwsim::EnergyModel::default(),
        attn_threshold: None,
        workers: 1,
        spec: None,
        prefix_share: false,
        deadline_ms: None,
        promote_after_ms: 25,
    };
    let fwd_spec = ExecSpec::new(&dir, "tiny-llama", GraphKind::FwdQuant);
    let server = Server::start(scfg, fwd_spec, tail.clone(), logits_spec, tail).unwrap();

    // One small leads (so the pool is busy), then the big request.
    let (req, small0_rx) =
        Request::new(0, RequestKind::Generate { prompt: small_prompt.clone(), n_tokens });
    server.router.submit(req).unwrap();
    let (req, big_rx) =
        Request::new(1, RequestKind::Generate { prompt: big_prompt.clone(), n_tokens });
    server.router.submit(req).unwrap();

    // A producer keeps small traffic flowing until the big one completes:
    // bypass alone (no promotion bound) would starve it indefinitely.
    let stop = Arc::new(AtomicBool::new(false));
    let producer = {
        let (router, stop) = (server.router.clone(), stop.clone());
        let prompt = small_prompt.clone();
        std::thread::spawn(move || {
            let mut rxs = Vec::new();
            let mut id = 1000u64;
            while !stop.load(Ordering::Relaxed) && rxs.len() < 4000 {
                let (req, rx) =
                    Request::new(id, RequestKind::Generate { prompt: prompt.clone(), n_tokens });
                if router.submit(req).is_err() {
                    break;
                }
                id += 1;
                rxs.push(rx);
                std::thread::sleep(Duration::from_micros(500));
            }
            rxs
        })
    };

    let big = big_rx
        .recv_timeout(Duration::from_secs(60))
        .expect("aged big request must not be starved by small-request bypass");
    stop.store(true, Ordering::Relaxed);
    assert_eq!(big.generated.as_deref(), Some(&big_expected[..]), "big stream bit-exact");
    assert_eq!(big.rejection, None);

    let small0 = small0_rx.recv().expect("leading small response");
    assert_eq!(small0.generated.as_deref(), Some(&small_expected[..]));
    for (i, rx) in producer.join().unwrap().into_iter().enumerate() {
        let resp = rx.recv().expect("small response");
        assert_eq!(
            resp.generated.as_deref(),
            Some(&small_expected[..]),
            "small {i}: stream perturbed by preemption/resume"
        );
    }
    let snap = server.metrics.snapshot();
    assert!(snap.deferred_admissions > 0, "the big request never waited");
    assert!(snap.preempt_resumes <= snap.preemptions, "resumes cannot exceed preemptions");
    server.shutdown();
}

/// Metrics accounting: sums of random batch records reconcile exactly.
#[test]
fn metrics_reconcile_random_streams() {
    let mut rng = Rng::new(0x3E7);
    for _ in 0..20 {
        let m = fgmp::coordinator::Metrics::new();
        let batches = 1 + rng.below(30);
        let (mut rows, mut toks, mut e, mut e8) = (0u64, 0.0f64, 0.0f64, 0.0f64);
        for _ in 0..batches {
            let r = 1 + rng.below(8);
            let t = rng.f64() * 1000.0;
            let lats: Vec<Duration> =
                (0..r).map(|_| Duration::from_micros(rng.below(10_000) as u64)).collect();
            let (be, be8) = (rng.f64() * 100.0, rng.f64() * 100.0 + 100.0);
            m.record_batch(r, 8, t, &lats, Duration::from_millis(1), be, be8);
            rows += r as u64;
            toks += t;
            e += be;
            e8 += be8;
        }
        let s = m.snapshot();
        assert_eq!(s.requests, rows);
        assert_eq!(s.batches, batches as u64);
        assert!((s.tokens_scored - toks).abs() < 1e-6);
        assert!((s.energy_savings - (1.0 - e / e8)).abs() < 1e-9);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
    }
}
