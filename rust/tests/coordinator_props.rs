//! Property tests for the coordinator invariants (DESIGN.md §7), using the
//! in-repo seeded-RNG harness (offline build: no proptest; many random
//! scenarios per property instead).

use std::time::Duration;

use fgmp::coordinator::{BatchPolicy, Batcher, Request, RequestKind, Router};
use fgmp::util::Rng;

fn score_req(id: u64) -> (Request, std::sync::mpsc::Receiver<fgmp::coordinator::Response>) {
    Request::new(id, RequestKind::Score { tokens: vec![id as i32], mask: vec![1.0] })
}

/// Batcher: over many random (queue depth, max_batch, arrival pattern)
/// scenarios — every request appears exactly once, order preserved, and no
/// batch exceeds max_batch.
#[test]
fn batcher_conserves_and_orders_requests() {
    let mut rng = Rng::new(0xBA7C4);
    for case in 0..50 {
        let n = 1 + rng.below(60) as u64;
        let max_batch = 1 + rng.below(12);
        let (tx, rx) = std::sync::mpsc::sync_channel(n as usize + 1);
        let mut keep = Vec::new();
        for id in 0..n {
            let (req, r) = score_req(id);
            keep.push(r);
            tx.send(req).unwrap();
        }
        drop(tx);
        let mut batcher = Batcher::new(
            BatchPolicy { max_batch, max_wait: Duration::from_millis(1) },
            rx,
        );
        let mut seen = Vec::new();
        while let Some(batch) = batcher.next_batch() {
            assert!(!batch.is_empty() && batch.len() <= max_batch,
                    "case {case}: batch size {} vs max {max_batch}", batch.len());
            seen.extend(batch.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "case {case}: order/conservation");
    }
}

/// Router: requests land in exactly one queue, by kind, order preserved
/// per queue, across random interleavings.
#[test]
fn router_partitions_by_kind() {
    let mut rng = Rng::new(0x707E5);
    for case in 0..50 {
        let n = 1 + rng.below(100) as u64;
        let (router, score_rx, gen_rx) = Router::new(n as usize + 1);
        let mut want_score = Vec::new();
        let mut want_gen = Vec::new();
        for id in 0..n {
            if rng.f64() < 0.6 {
                let (req, _rx) = score_req(id);
                router.submit(req).unwrap();
                want_score.push(id);
            } else {
                let (req, _rx) =
                    Request::new(id, RequestKind::Generate { prompt: vec![1], n_tokens: 1 });
                router.submit(req).unwrap();
                want_gen.push(id);
            }
        }
        drop(router);
        let got_score: Vec<u64> = score_rx.iter().map(|r| r.id).collect();
        let got_gen: Vec<u64> = gen_rx.iter().map(|r| r.id).collect();
        assert_eq!(got_score, want_score, "case {case}");
        assert_eq!(got_gen, want_gen, "case {case}");
        assert_eq!(got_score.len() + got_gen.len(), n as usize);
    }
}

/// Batcher under concurrent production: with a slow producer the batcher
/// still terminates and conserves requests (no loss under timeout flushes).
#[test]
fn batcher_with_live_producer_conserves() {
    for seed in 0..8u64 {
        let (tx, rx) = std::sync::mpsc::sync_channel(128);
        let n = 40u64;
        let producer = std::thread::spawn(move || {
            let mut rng = Rng::new(seed);
            for id in 0..n {
                let (req, _rx) = score_req(id);
                tx.send(req).unwrap();
                if rng.f64() < 0.3 {
                    std::thread::sleep(Duration::from_micros(rng.below(500) as u64));
                }
            }
        });
        let mut batcher = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(2) },
            rx,
        );
        let mut seen = Vec::new();
        while let Some(batch) = batcher.next_batch() {
            seen.extend(batch.iter().map(|r| r.id));
        }
        producer.join().unwrap();
        assert_eq!(seen, (0..n).collect::<Vec<_>>(), "seed {seed}");
    }
}

/// Metrics accounting: sums of random batch records reconcile exactly.
#[test]
fn metrics_reconcile_random_streams() {
    let mut rng = Rng::new(0x3E7);
    for _ in 0..20 {
        let m = fgmp::coordinator::Metrics::new();
        let batches = 1 + rng.below(30);
        let (mut rows, mut toks, mut e, mut e8) = (0u64, 0.0f64, 0.0f64, 0.0f64);
        for _ in 0..batches {
            let r = 1 + rng.below(8);
            let t = rng.f64() * 1000.0;
            let lats: Vec<Duration> =
                (0..r).map(|_| Duration::from_micros(rng.below(10_000) as u64)).collect();
            let (be, be8) = (rng.f64() * 100.0, rng.f64() * 100.0 + 100.0);
            m.record_batch(r, 8, t, &lats, Duration::from_millis(1), be, be8);
            rows += r as u64;
            toks += t;
            e += be;
            e8 += be8;
        }
        let s = m.snapshot();
        assert_eq!(s.requests, rows);
        assert_eq!(s.batches, batches as u64);
        assert!((s.tokens_scored - toks).abs() < 1e-6);
        assert!((s.energy_savings - (1.0 - e / e8)).abs() < 1e-9);
        assert!(s.p50_ms <= s.p95_ms && s.p95_ms <= s.p99_ms);
    }
}
