//! Integration-level anchors for published-number claims and concurrency
//! invariants that the rest of the stack silently leans on.

use fgmp::hwsim::datapath::DatapathConfig;
use fgmp::hwsim::energy::EnergyModel;
use fgmp::hwsim::ppu::{ppu_balance, ppu_energy_per_op_fj};
use fgmp::util::par_map;

#[test]
fn ppu_balance_paper_anchor_4096_cubed() {
    // Paper §5.4.3: a 4096³ matmul with 16-lane PEs keeps one PPU busy
    // exactly at the 256-PE point — balanced at 256, stalling at 512,
    // restored with a second PPU.
    let cfg = DatapathConfig { lanes: 16, pes: 256, freq_ghz: 1.0 };
    let b = ppu_balance(&cfg, 4096, 4096, 4096, 1);
    assert!(b.balanced, "256 PEs per PPU must not stall");
    assert_eq!(b.max_pes_per_ppu, 256);
    assert_eq!(b.datapath_cycles, b.ppu_cycles, "equality at the balance point");

    let over = DatapathConfig { lanes: 16, pes: 512, freq_ghz: 1.0 };
    assert!(!ppu_balance(&over, 4096, 4096, 4096, 1).balanced);
    assert!(ppu_balance(&over, 4096, 4096, 4096, 2).balanced);
}

#[test]
fn ppu_amortization_paper_anchor() {
    // Paper §5.4.2: 25.7 pJ per output block amortizes to ≈0.20 fJ/op at
    // K = 4096, improving with deeper reductions.
    let em = EnergyModel::default();
    let fj = ppu_energy_per_op_fj(em.e_ppu_block, 4096);
    assert!((fj - 0.196).abs() < 0.01, "got {fj}");
    assert!(ppu_energy_per_op_fj(em.e_ppu_block, 8192) < fj);
}

#[test]
fn par_map_preserves_input_order_with_oversubscription() {
    // n far above the worker count: results must still land in input order
    // (the quantization pipeline and the native matmul both depend on it).
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4);
    let n = workers * 64 + 7;
    let items: Vec<usize> = (0..n).collect();
    let out = par_map(&items, |&x| {
        // stagger completion so late-index items often finish first
        if x % workers == 0 {
            std::thread::sleep(std::time::Duration::from_micros(200));
        }
        x * 3 + 1
    });
    assert_eq!(out.len(), n);
    for (i, &v) in out.iter().enumerate() {
        assert_eq!(v, i * 3 + 1, "slot {i}");
    }
}

#[test]
fn par_map_nested_inside_par_map_is_safe() {
    // The native forward calls par_map from within par_map'd work items
    // (e.g. matmul inside a layer loop driven by tests running in threads);
    // nested scoped pools must not deadlock or reorder.
    let outer: Vec<usize> = (0..8).collect();
    let out = par_map(&outer, |&o| {
        let inner: Vec<usize> = (0..50).collect();
        par_map(&inner, |&i| o * 100 + i).iter().sum::<usize>()
    });
    for (o, &s) in out.iter().enumerate() {
        assert_eq!(s, o * 100 * 50 + (0..50).sum::<usize>());
    }
}
