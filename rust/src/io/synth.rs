//! Deterministic synthetic-artifact builder: everything `make artifacts`
//! used to require Python for, generated natively from a seeded RNG.
//!
//! For a model preset this writes, under `artifacts/<model>/`:
//!   * `manifest.json` — shapes, linear inventory, graph signatures, and the
//!     `arch` section the native runtime executes from
//!   * `weights.fgtn` — scaled-normal initialized parameters
//!   * `fisher_w.fgtn` — synthetic per-element weight Fisher (positive,
//!     |w|²-correlated, with per-layer sensitivity spread)
//!   * `act_fisher.fgtn` — synthetic per-channel activation Fisher
//!     (heavy-tailed across channels and layers)
//!   * `act_msq.fgtn` — *measured* mean-square of each linear's input over
//!     the calibration batches
//!   * `act_score_quantiles.fgtn` — per-policy global + per-linear quantile
//!     tables of the activation impact scores, *measured* by running the
//!     native forward on calibration batches (mirrors compile/calibrate.py)
//! and, shared at the artifacts root:
//!   * `corpus.fgtn` — train/valid/test streams of a first-order Markov
//!     language with Zipfian unigrams and heterogeneous per-state entropy
//!   * `tasks/*.json` — 4-way cloze suites (easy + hard distractors)
//!
//! Scale is deliberately small (a few seconds of CPU for the full set) —
//! these artifacts exist so the crate's tests, benches, examples, and CLI
//! run hermetically; the Python pipeline remains available for full-size
//! runs behind the `pjrt` feature.

use std::collections::BTreeMap;
use std::path::Path;

use crate::io::tensorfile::{Tensor, TensorFile};
use crate::model::forward::{forward, Act, ModelArch, NormKind, PosKind};
use crate::policy::baselines::oe_weighting_for_acts;
use crate::policy::block_impact_scores;
use crate::policy::threshold::percentile_sorted;
use crate::util::{Json, Rng};
use crate::Result;

/// Tokens reserved as sentence delimiter.
const BOS: i32 = 0;
/// Sparse out-degree per Markov state.
const SUCC: usize = 16;
/// Shared corpus vocabulary (all presets use it).
pub const VOCAB: usize = 256;

/// Everything needed to synthesize one model's artifacts.
#[derive(Debug, Clone)]
pub struct SynthConfig {
    pub model: String,
    pub arch: ModelArch,
    pub seed: u64,
    pub batch: usize,
    pub seq: usize,
    pub calib_batches: usize,
}

impl SynthConfig {
    /// Named presets mirroring the python model families at test scale.
    pub fn preset(model: &str, seed: u64) -> Result<SynthConfig> {
        let arch = match model {
            "tiny-llama" => ModelArch {
                vocab: VOCAB,
                d_model: 96,
                n_layers: 2,
                n_heads: 3,
                d_ff: 256,
                act: Act::SwiGlu,
                norm: NormKind::Rms,
                pos: PosKind::Rope,
                max_seq: 128,
            },
            "tiny-llama-l" => ModelArch {
                vocab: VOCAB,
                d_model: 128,
                n_layers: 3,
                n_heads: 4,
                d_ff: 320,
                act: Act::SwiGlu,
                norm: NormKind::Rms,
                pos: PosKind::Rope,
                max_seq: 128,
            },
            "tiny-gpt" => ModelArch {
                vocab: VOCAB,
                d_model: 64,
                n_layers: 2,
                n_heads: 2,
                d_ff: 128,
                act: Act::Gelu,
                norm: NormKind::LayerNorm,
                pos: PosKind::Learned,
                max_seq: 128,
            },
            "tiny-gpt-l" => ModelArch {
                vocab: VOCAB,
                d_model: 96,
                n_layers: 3,
                n_heads: 3,
                d_ff: 192,
                act: Act::Gelu,
                norm: NormKind::LayerNorm,
                pos: PosKind::Learned,
                max_seq: 128,
            },
            "tiny-nemotron" => ModelArch {
                vocab: VOCAB,
                d_model: 80,
                n_layers: 2,
                n_heads: 5,
                d_ff: 160,
                act: Act::Relu2,
                norm: NormKind::Rms,
                pos: PosKind::Rope,
                max_seq: 128,
            },
            // The perf-scale preset: d_model ≥ 512 with more layers, the
            // shape class the blocked matmul kernels exist for. Artifact
            // synthesis runs full calibration forwards at this size, so it
            // is only built on demand (`fgmp bench --preset`, the
            // FGMP_E2E_LARGE release suite) — never by `build_default`.
            "small-llama" => ModelArch {
                vocab: VOCAB,
                d_model: 512,
                n_layers: 4,
                n_heads: 8,
                d_ff: 1536,
                act: Act::SwiGlu,
                norm: NormKind::Rms,
                pos: PosKind::Rope,
                max_seq: 128,
            },
            other => anyhow::bail!(
                "no synthetic preset for model '{other}' \
                 (have tiny-llama, tiny-llama-l, tiny-gpt, tiny-gpt-l, tiny-nemotron, \
                  small-llama)"
            ),
        };
        Ok(SynthConfig {
            model: model.to_string(),
            arch,
            seed,
            batch: 4,
            seq: 64,
            calib_batches: 4,
        })
    }
}

/// Build the shared corpus + tasks (if absent) and one model's artifacts
/// (if absent). Returns true when anything was written.
pub fn ensure_model(artifacts: &Path, model: &str, seed: u64) -> Result<bool> {
    let mut wrote = false;
    if !artifacts.join("corpus.fgtn").exists() {
        build_corpus(artifacts)?;
        wrote = true;
    }
    // Probe the *last*-written suite so an interrupted build self-repairs
    // (build_tasks writes cloze_easy.json first, cloze_hard.json last).
    if !artifacts.join("tasks").join("cloze_hard.json").exists() {
        build_tasks(artifacts, seed)?;
        wrote = true;
    }
    if !artifacts.join(model).join("manifest.json").exists() {
        let cfg = SynthConfig::preset(model, seed)?;
        build_model(artifacts, &cfg)?;
        wrote = true;
    }
    Ok(wrote)
}

/// Build the default test set: corpus + tasks + tiny-llama.
pub fn build_default(artifacts: &Path) -> Result<()> {
    ensure_model(artifacts, "tiny-llama", 42)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Corpus
// ---------------------------------------------------------------------------

/// First-order Markov language with Zipfian unigram bias and per-state
/// entropy spread (the heterogeneity the sensitivity policies feed on).
pub struct Markov {
    vocab: usize,
    succ: Vec<[i32; SUCC]>,
    cum: Vec<[f32; SUCC]>,
}

impl Markov {
    pub fn new(vocab: usize, rng: &mut Rng) -> Markov {
        // Zipf cumulative over non-BOS tokens for successor candidate draws.
        let mut zipf = Vec::with_capacity(vocab - 1);
        let mut total = 0.0f64;
        for r in 1..vocab {
            total += 1.0 / (r as f64).powf(1.05);
            zipf.push(total);
        }
        let draw_zipf = |rng: &mut Rng| -> i32 {
            let u = rng.f64() * total;
            let mut lo = 0usize;
            let mut hi = zipf.len() - 1;
            while lo < hi {
                let mid = (lo + hi) / 2;
                if zipf[mid] < u {
                    lo = mid + 1;
                } else {
                    hi = mid;
                }
            }
            (lo + 1) as i32
        };

        let mut succ = Vec::with_capacity(vocab);
        let mut cum = Vec::with_capacity(vocab);
        for _ in 0..vocab {
            let mut cand = [0i32; SUCC];
            let mut n = 0usize;
            let mut attempts = 0usize;
            while n < SUCC && attempts < SUCC * 20 {
                attempts += 1;
                let c = draw_zipf(rng);
                if !cand[..n].contains(&c) {
                    cand[n] = c;
                    n += 1;
                }
            }
            let mut fill = 1i32;
            while n < SUCC {
                if !cand[..n].contains(&fill) {
                    cand[n] = fill;
                    n += 1;
                }
                fill += 1;
            }
            // Heavy-tailed transition weights: some states near-deterministic,
            // others near-uniform.
            let sigma = 0.3 + 2.7 * rng.f64();
            let mut w = [0.0f32; SUCC];
            let mut t = 0.0f32;
            for wi in w.iter_mut() {
                *wi = (rng.normal() * sigma).exp() as f32;
                t += *wi;
            }
            let mut c = [0.0f32; SUCC];
            let mut acc = 0.0f32;
            for i in 0..SUCC {
                acc += w[i] / t;
                c[i] = acc;
            }
            c[SUCC - 1] = 1.0;
            succ.push(cand);
            cum.push(c);
        }
        Markov { vocab, succ, cum }
    }

    /// Sample a BOS-delimited token stream.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> Vec<i32> {
        let mut out = Vec::with_capacity(n);
        let mut state = BOS as usize;
        let mut remaining = 0usize;
        for _ in 0..n {
            if remaining == 0 {
                out.push(BOS);
                state = BOS as usize;
                remaining = 4 + rng.below(40);
                continue;
            }
            let u = rng.f32();
            let c = &self.cum[state];
            let mut j = 0usize;
            while j + 1 < SUCC && c[j] < u {
                j += 1;
            }
            state = self.succ[state][j] as usize;
            debug_assert!(state > 0 && state < self.vocab);
            out.push(state as i32);
            remaining -= 1;
        }
        out
    }
}

/// Write `corpus.fgtn` with train/valid/test splits (disjoint RNG streams).
pub fn build_corpus(artifacts: &Path) -> Result<()> {
    std::fs::create_dir_all(artifacts)?;
    let mut structure_rng = Rng::new(0xC0_0051);
    let markov = Markov::new(VOCAB, &mut structure_rng);
    let mut tf = TensorFile::new();
    for (name, n, seed) in
        [("train", 65_536usize, 1u64), ("valid", 8_192, 2), ("test", 16_384, 3)]
    {
        let mut rng = Rng::new(seed);
        let stream = markov.sample(n, &mut rng);
        tf.insert(name, Tensor::i32(vec![n], stream));
    }
    tf.save(artifacts.join("corpus.fgtn"))?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Shared-prefix traffic
// ---------------------------------------------------------------------------

/// Deterministic shared-prefix generation traffic: `n_prefixes` synthetic
/// "system prompts" of `prefix_len` tokens (distinct Markov walks off
/// `seed`) shared round-robin across `n_prompts` requests, each appending
/// its own `suffix_len`-token Markov "user turn". This is the workload
/// prefix caching feeds on — many sessions whose KV pages agree for the
/// first `prefix_len` tokens and diverge after — so the serve smoke and
/// the benchsuite capacity bench can demonstrate the refcounted-COW
/// sharing factor (`--shared-prefix` / `--prefix-tokens` on `fgmp serve`).
pub fn shared_prefix_prompts(
    seed: u64,
    n_prompts: usize,
    n_prefixes: usize,
    prefix_len: usize,
    suffix_len: usize,
) -> Vec<Vec<i32>> {
    let mut structure_rng = Rng::new(0xC0_0051);
    let markov = Markov::new(VOCAB, &mut structure_rng);
    let n_prefixes = n_prefixes.max(1);
    let prefixes: Vec<Vec<i32>> = (0..n_prefixes)
        .map(|i| {
            let mut rng = Rng::new(seed ^ (0x5151 + i as u64));
            markov.sample(prefix_len, &mut rng)
        })
        .collect();
    (0..n_prompts)
        .map(|j| {
            let mut p = prefixes[j % n_prefixes].clone();
            let mut rng = Rng::new(seed ^ 0xD1F ^ ((j as u64) << 16));
            p.extend(markov.sample(suffix_len, &mut rng));
            p
        })
        .collect()
}

// ---------------------------------------------------------------------------
// Tasks
// ---------------------------------------------------------------------------

/// Write the 4-way cloze suites under `tasks/` (easy + hard distractors),
/// mirroring `data.py::make_cloze_suite` at test scale.
pub fn build_tasks(artifacts: &Path, seed: u64) -> Result<()> {
    let tasks_dir = artifacts.join("tasks");
    std::fs::create_dir_all(&tasks_dir)?;
    let mut structure_rng = Rng::new(0xC0_0051);
    let markov = Markov::new(VOCAB, &mut structure_rng);
    let mut stream_rng = Rng::new(4);
    let stream = markov.sample(16_384, &mut stream_rng);

    for (name, hard) in [("cloze_easy", false), ("cloze_hard", true)] {
        let mut rng = Rng::new(seed ^ if hard { 0xBAD } else { 0x600D });
        let (ctx_len, cont_len, n_items) = (16usize, 8usize, 32usize);
        let span = stream.len() - ctx_len - cont_len - 1;
        let mut items = Vec::with_capacity(n_items);
        for _ in 0..n_items {
            let i = rng.below(span);
            let ctx = &stream[i..i + ctx_len];
            let truth = stream[i + ctx_len..i + ctx_len + cont_len].to_vec();
            let mut opts: Vec<Vec<i32>> = vec![truth.clone()];
            for _ in 0..3 {
                if hard {
                    // Corrupt ~2 tokens of the truth: off-manifold but close.
                    let mut cont = truth.clone();
                    let mut flipped = false;
                    for c in cont.iter_mut() {
                        if rng.f64() < 2.0 / cont_len as f64 {
                            *c = (1 + rng.below(VOCAB - 1)) as i32;
                            flipped = true;
                        }
                    }
                    if !flipped {
                        let j = rng.below(cont_len);
                        cont[j] = (1 + rng.below(VOCAB - 1)) as i32;
                    }
                    opts.push(cont);
                } else {
                    // A Markov walk from an unrelated random state.
                    let mut walk_rng = rng.split();
                    let mut w = markov.sample(cont_len + 8, &mut walk_rng);
                    w.retain(|&t| t != BOS);
                    w.truncate(cont_len);
                    while w.len() < cont_len {
                        w.push((1 + rng.below(VOCAB - 1)) as i32);
                    }
                    opts.push(w);
                }
            }
            // Shuffle options; record where the truth landed.
            let mut order = [0usize, 1, 2, 3];
            for j in (1..4).rev() {
                order.swap(j, rng.below(j + 1));
            }
            let answer = order.iter().position(|&o| o == 0).unwrap();
            let item = Json::Obj(BTreeMap::from([
                ("context".to_string(), json_i32(ctx)),
                (
                    "options".to_string(),
                    Json::Arr(order.iter().map(|&o| json_i32(&opts[o])).collect()),
                ),
                ("answer".to_string(), Json::Num(answer as f64)),
            ]));
            items.push(item);
        }
        let suite = Json::Obj(BTreeMap::from([
            ("name".to_string(), Json::Str(name.to_string())),
            ("ctx_len".to_string(), Json::Num(ctx_len as f64)),
            ("cont_len".to_string(), Json::Num(cont_len as f64)),
            ("items".to_string(), Json::Arr(items)),
        ]));
        std::fs::write(tasks_dir.join(format!("{name}.json")), suite.to_string())?;
    }
    Ok(())
}

fn json_i32(v: &[i32]) -> Json {
    Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect())
}

fn json_strs(v: &[String]) -> Json {
    Json::Arr(v.iter().map(|s| Json::Str(s.clone())).collect())
}

// ---------------------------------------------------------------------------
// Model artifacts
// ---------------------------------------------------------------------------

/// Build one model's full artifact directory.
pub fn build_model(artifacts: &Path, cfg: &SynthConfig) -> Result<()> {
    let arch = &cfg.arch;
    anyhow::ensure!(arch.vocab == VOCAB, "presets share the corpus vocabulary");
    let mdir = artifacts.join(&cfg.model);
    std::fs::create_dir_all(&mdir)?;

    // --- weights (scaled-normal init, model.py::init_params style) ---
    let mut rng = Rng::new(cfg.seed);
    let resid = 1.0 / (2.0 * arch.n_layers as f32).sqrt();
    let mut weights = TensorFile::new();
    for name in arch.param_names() {
        let shape = arch.param_shape(&name);
        let len: usize = shape.iter().product();
        let data = if name.ends_with(".b") {
            vec![0.0f32; len]
        } else if name.ends_with("norm1") || name.ends_with("norm2") || name == "final_norm" {
            vec![1.0f32; len]
        } else if name.ends_with(".w") {
            let r = if name.contains("o_proj") || name.contains("fc2") { resid } else { 1.0 };
            let std = 0.05 * r * (256.0 / shape[0] as f32).sqrt();
            rng.normal_vec(len, std)
        } else {
            // embeddings — a little hotter than python's 0.02 so the tied
            // logits carry visible structure at this tiny scale
            rng.normal_vec(len, 0.05)
        };
        weights.insert(&name, Tensor::f32(shape, data));
    }
    weights.save(mdir.join("weights.fgtn"))?;

    let linears = arch.linears();

    // --- synthetic weight Fisher: positive, |w|²-correlated, with a
    //     per-layer sensitivity spread so the global threshold has work ---
    let mut fisher_rng = Rng::new(cfg.seed ^ 0xF15E);
    let mut fisher_w = TensorFile::new();
    for spec in &linears {
        let w = weights.get(&format!("{}.w", spec.name))?.as_f32()?;
        let lambda = (fisher_rng.normal() * 1.2).exp() as f32;
        let data: Vec<f32> = w
            .iter()
            .map(|&v| lambda * (v * v + 1e-6) * (fisher_rng.normal() * 0.5).exp() as f32)
            .collect();
        fisher_w.insert(
            &format!("{}.w.fisher", spec.name),
            Tensor::f32(vec![spec.k_in, spec.n_out], data),
        );
    }
    fisher_w.save(mdir.join("fisher_w.fgtn"))?;

    // --- synthetic per-channel activation Fisher (heavy-tailed) ---
    let mut act_rng = Rng::new(cfg.seed ^ 0xAC7);
    let mut act_fisher = TensorFile::new();
    let mut act_fisher_vecs: Vec<Vec<f32>> = Vec::with_capacity(linears.len());
    for spec in &linears {
        let lambda = (act_rng.normal() * 1.2).exp() as f32;
        let data: Vec<f32> =
            (0..spec.k_in).map(|_| lambda * (act_rng.normal() * 1.5).exp() as f32).collect();
        act_fisher.insert(&spec.name, Tensor::f32(vec![spec.k_in], data.clone()));
        act_fisher_vecs.push(data);
    }
    act_fisher.save(mdir.join("act_fisher.fgtn"))?;

    // --- calibration: run the native forward, capture every linear input ---
    let corpus = TensorFile::load(artifacts.join("corpus.fgtn"))?;
    let train = corpus.get("train")?.as_i32()?;
    let pnames = arch.param_names();
    let mut params = crate::model::forward::Params::new();
    for n in &pnames {
        params.insert_dense(n.as_str(), weights.get(n)?.as_f32()?);
    }
    let mut calib_rng = Rng::new(cfg.seed ^ 0xCA11B);
    let (b, s) = (cfg.batch, cfg.seq);
    let span = train.len() - s - 1;
    let mut captures: Vec<Vec<f32>> = vec![Vec::new(); linears.len()];
    for _ in 0..cfg.calib_batches {
        let mut tokens = Vec::with_capacity(b * s);
        for _ in 0..b {
            let off = calib_rng.below(span);
            tokens.extend_from_slice(&train[off..off + s]);
        }
        let mut caps: Vec<Vec<f32>> = Vec::new();
        forward(arch, &params, &tokens, b, s, None, Some(&mut caps), false)?;
        for (acc, c) in captures.iter_mut().zip(caps) {
            acc.extend_from_slice(&c);
        }
    }

    // --- measured act_msq + per-policy impact-score quantile tables ---
    let mut act_msq = TensorFile::new();
    let mut quantiles = TensorFile::new();
    let qs: Vec<f64> = (1..100).map(|i| i as f64 / 100.0).collect();
    let mut per_policy_local: BTreeMap<&str, Vec<Vec<f32>>> = BTreeMap::new();
    let mut per_policy_global: BTreeMap<&str, Vec<f64>> = BTreeMap::new();
    for (i, spec) in linears.iter().enumerate() {
        let h = &captures[i];
        let k = spec.k_in;
        let rows = h.len() / k;
        anyhow::ensure!(rows > 0, "no calibration captures for {}", spec.name);
        let mut msq = vec![0.0f32; k];
        for r in 0..rows {
            for (m, &v) in msq.iter_mut().zip(&h[r * k..(r + 1) * k]) {
                *m += v * v;
            }
        }
        for m in msq.iter_mut() {
            *m /= rows as f32;
        }
        act_msq.insert(&spec.name, Tensor::f32(vec![k], msq.clone()));

        let w = weights.get(&format!("{}.w", spec.name))?.as_f32()?;
        let oe = oe_weighting_for_acts(w, k, spec.n_out);
        let ones = vec![1.0f32; k];
        for (pol, cw) in
            [("fisher", &act_fisher_vecs[i]), ("qe", &ones), ("oe", &oe)]
        {
            let mut scores = block_impact_scores(h, k, cw, None);
            scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let local: Vec<f32> =
                qs.iter().map(|&q| percentile_sorted(&scores, q) as f32).collect();
            per_policy_local.entry(pol).or_default().push(local);
            per_policy_global.entry(pol).or_default().extend(scores);
        }
    }
    for (pol, all_scores) in per_policy_global.iter_mut() {
        all_scores.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let global: Vec<f32> =
            qs.iter().map(|&q| percentile_sorted(all_scores, q) as f32).collect();
        quantiles.insert(&format!("{pol}.global"), Tensor::f32(vec![99], global));
        let local = &per_policy_local[pol];
        let flat: Vec<f32> = local.iter().flatten().copied().collect();
        quantiles.insert(
            &format!("{pol}.local"),
            Tensor::f32(vec![linears.len(), 99], flat),
        );
    }
    act_msq.save(mdir.join("act_msq.fgtn"))?;
    quantiles.save(mdir.join("act_score_quantiles.fgtn"))?;

    // --- manifest (incl. the arch section + graph signatures) ---
    let manifest = manifest_json(cfg, &linears);
    std::fs::write(mdir.join("manifest.json"), manifest.to_string())?;
    Ok(())
}

fn manifest_json(cfg: &SynthConfig, linears: &[crate::io::LinearSpec]) -> Json {
    let arch = &cfg.arch;
    let pnames = arch.param_names();
    let mut shapes = BTreeMap::new();
    for n in &pnames {
        shapes.insert(
            n.clone(),
            Json::Arr(arch.param_shape(n).iter().map(|&d| Json::Num(d as f64)).collect()),
        );
    }
    let lin_arr = Json::Arr(
        linears
            .iter()
            .map(|l| {
                Json::Obj(BTreeMap::from([
                    ("name".to_string(), Json::Str(l.name.clone())),
                    ("layer".to_string(), Json::Num(l.layer as f64)),
                    ("kind".to_string(), Json::Str(l.kind.clone())),
                    ("k_in".to_string(), Json::Num(l.k_in as f64)),
                    ("n_out".to_string(), Json::Num(l.n_out as f64)),
                ]))
            })
            .collect(),
    );
    let aw_args: Vec<String> =
        linears.iter().map(|l| format!("act_weight:{}", l.name)).collect();
    let graph = |args: Vec<String>, outputs: Vec<String>| {
        Json::Obj(BTreeMap::from([
            ("args".to_string(), json_strs(&args)),
            ("outputs".to_string(), json_strs(&outputs)),
        ]))
    };
    let mut fq_args = vec!["tokens".to_string(), "mask".to_string()];
    fq_args.extend(pnames.clone());
    fq_args.extend(aw_args.clone());
    fq_args.push("thresholds".to_string());
    let mut fr_args = vec!["tokens".to_string(), "mask".to_string()];
    fr_args.extend(pnames.clone());
    let mut lg_args = vec!["tokens".to_string()];
    lg_args.extend(pnames.clone());
    lg_args.extend(aw_args);
    lg_args.push("thresholds".to_string());
    let graphs = Json::Obj(BTreeMap::from([
        (
            "fwd_quant".to_string(),
            graph(
                fq_args,
                vec!["nll_sum[B]".into(), "ntok[B]".into(), "fp8_frac[NL]".into()],
            ),
        ),
        (
            "fwd_ref".to_string(),
            graph(fr_args, vec!["nll_sum[B]".into(), "ntok[B]".into()]),
        ),
        (
            "logits_quant".to_string(),
            graph(lg_args, vec!["last_logits[B,V]".into()]),
        ),
    ]));
    Json::Obj(BTreeMap::from([
        ("name".to_string(), Json::Str(cfg.model.clone())),
        ("batch".to_string(), Json::Num(cfg.batch as f64)),
        ("seq".to_string(), Json::Num(cfg.seq as f64)),
        ("vocab".to_string(), Json::Num(arch.vocab as f64)),
        ("num_linears".to_string(), Json::Num(linears.len() as f64)),
        ("param_names".to_string(), json_strs(&pnames)),
        ("param_shapes".to_string(), Json::Obj(shapes)),
        ("linears".to_string(), lin_arr),
        ("graphs".to_string(), graphs),
        ("arch".to_string(), arch.to_json()),
    ]))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("fgmp_synth_{name}"));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn corpus_is_deterministic_and_in_vocab() {
        let d = tmp("corpus");
        build_corpus(&d).unwrap();
        let c1 = TensorFile::load(d.join("corpus.fgtn")).unwrap();
        build_corpus(&d).unwrap();
        let c2 = TensorFile::load(d.join("corpus.fgtn")).unwrap();
        for split in ["train", "valid", "test"] {
            let s1 = c1.get(split).unwrap().as_i32().unwrap();
            assert_eq!(s1, c2.get(split).unwrap().as_i32().unwrap(), "{split} deterministic");
            assert!(s1.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
            assert!(s1.contains(&BOS));
        }
    }

    #[test]
    fn markov_has_structure() {
        // Per-state successor sets are sparse: the conditional distribution
        // after a fixed token concentrates on ≤ SUCC values.
        let mut rng = Rng::new(0xC0_0051);
        let markov = Markov::new(VOCAB, &mut rng);
        let mut srng = Rng::new(9);
        let stream = markov.sample(20_000, &mut srng);
        // Probe the most frequent non-BOS token: it recurs thousands of
        // times, so its observed successor set is well sampled.
        let mut counts = vec![0usize; VOCAB];
        for &t in &stream {
            counts[t as usize] += 1;
        }
        let probe = (1..VOCAB).max_by_key(|&t| counts[t]).unwrap() as i32;
        let mut nexts = std::collections::BTreeSet::new();
        for w in stream.windows(2) {
            if w[0] == probe && w[1] != BOS {
                nexts.insert(w[1]);
            }
        }
        assert!(!nexts.is_empty() && nexts.len() <= SUCC, "got {} successors", nexts.len());
    }

    #[test]
    fn shared_prefix_traffic_is_deterministic_and_round_robin() {
        let a = shared_prefix_prompts(7, 8, 2, 32, 8);
        let b = shared_prefix_prompts(7, 8, 2, 32, 8);
        assert_eq!(a, b, "same seed → same traffic");
        assert_eq!(a.len(), 8);
        for p in &a {
            assert_eq!(p.len(), 40);
            assert!(p.iter().all(|&t| (0..VOCAB as i32).contains(&t)));
        }
        // Round-robin prefixes: prompts j and j+2 share the whole 32-token
        // system prompt (two whole 16-token KV pages), adjacent prompts do
        // not, and every request's user suffix is its own.
        assert_eq!(&a[0][..32], &a[2][..32]);
        assert_eq!(&a[1][..32], &a[3][..32]);
        assert_ne!(&a[0][..32], &a[1][..32]);
        assert_ne!(&a[0][32..], &a[2][32..]);
    }

    #[test]
    fn unknown_preset_errors() {
        assert!(SynthConfig::preset("mega-llama", 1).is_err());
    }

    #[test]
    fn large_preset_is_block_aligned_at_scale() {
        let cfg = SynthConfig::preset("small-llama", 1).unwrap();
        assert_eq!(cfg.arch.d_model, 512);
        assert_eq!(cfg.arch.n_layers, 4);
        assert_eq!(cfg.arch.fc1_out(), 2 * 1536);
        assert!(cfg.arch.linears().iter().all(|l| l.k_in % crate::BLOCK == 0));
    }

    #[test]
    fn tasks_have_valid_answers() {
        let d = tmp("tasks");
        build_tasks(&d, 7).unwrap();
        for name in ["cloze_easy", "cloze_hard"] {
            let s = crate::eval::tasks::TaskSuite::load(
                d.join("tasks").join(format!("{name}.json")),
            )
            .unwrap();
            assert_eq!(s.items.len(), 32);
            for it in &s.items {
                assert_eq!(it.options.len(), 4);
                assert!(it.answer < 4);
                assert_eq!(it.context.len(), s.ctx_len);
                assert!(it.options.iter().all(|o| o.len() == s.cont_len));
            }
        }
    }
}
