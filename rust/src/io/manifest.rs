//! The model manifest written by `python -m compile.aot`: parameter order
//! (= HLO argument order), linear-layer inventory, and graph signatures.

use std::collections::HashMap;
use std::path::Path;

use anyhow::Context;

use crate::model::forward::ModelArch;
use crate::util::Json;
use crate::Result;

/// One linear layer: the unit of FGMP quantization and hwsim costing.
#[derive(Debug, Clone)]
pub struct LinearSpec {
    pub name: String,
    pub layer: usize,
    pub kind: String,
    pub k_in: usize,
    pub n_out: usize,
}

/// Signature of one exported graph.
#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub args: Vec<String>,
    pub outputs: Vec<String>,
}

/// manifest.json, one per model directory under artifacts/.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub name: String,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
    pub num_linears: usize,
    pub param_names: Vec<String>,
    pub param_shapes: HashMap<String, Vec<usize>>,
    pub linears: Vec<LinearSpec>,
    pub graphs: HashMap<String, GraphSpec>,
    /// Architecture descriptor for the native runtime; absent in manifests
    /// exported before it existed (then [`Manifest::arch`] infers one).
    pub arch: Option<ModelArch>,
}

impl Manifest {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        Self::from_json(&text).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let param_names: Vec<String> = v
            .get("param_names")?
            .as_arr()?
            .iter()
            .map(|x| Ok(x.as_str()?.to_string()))
            .collect::<Result<_>>()?;
        let mut param_shapes = HashMap::new();
        for (k, shape) in v.get("param_shapes")?.as_obj()? {
            param_shapes.insert(k.clone(), shape.usize_vec()?);
        }
        let linears: Vec<LinearSpec> = v
            .get("linears")?
            .as_arr()?
            .iter()
            .map(|l| {
                Ok(LinearSpec {
                    name: l.get("name")?.as_str()?.to_string(),
                    layer: l.get("layer")?.as_usize()?,
                    kind: l.get("kind")?.as_str()?.to_string(),
                    k_in: l.get("k_in")?.as_usize()?,
                    n_out: l.get("n_out")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;
        let mut graphs = HashMap::new();
        for (k, g) in v.get("graphs")?.as_obj()? {
            let strs = |key: &str| -> Result<Vec<String>> {
                g.get(key)?
                    .as_arr()?
                    .iter()
                    .map(|x| Ok(x.as_str()?.to_string()))
                    .collect()
            };
            graphs.insert(k.clone(), GraphSpec { args: strs("args")?, outputs: strs("outputs")? });
        }
        let arch = match v.opt("arch") {
            Some(a) => Some(ModelArch::from_json(a)?),
            None => None,
        };
        Ok(Manifest {
            name: v.get("name")?.as_str()?.to_string(),
            batch: v.get("batch")?.as_usize()?,
            seq: v.get("seq")?.as_usize()?,
            vocab: v.get("vocab")?.as_usize()?,
            num_linears: v.get("num_linears")?.as_usize()?,
            param_names,
            param_shapes,
            linears,
            graphs,
            arch,
        })
    }

    /// The architecture for the native runtime: the recorded `arch` section
    /// when present, else a best-effort reconstruction from shapes.
    pub fn arch(&self) -> Result<ModelArch> {
        match &self.arch {
            Some(a) => Ok(a.clone()),
            None => ModelArch::infer(self),
        }
    }

    /// Weight-matrix parameter names (the FGMP-quantized subset), in
    /// inventory order.
    pub fn weight_names(&self) -> Vec<String> {
        self.linears.iter().map(|l| format!("{}.w", l.name)).collect()
    }

    pub fn linear(&self, name: &str) -> Result<&LinearSpec> {
        self.linears
            .iter()
            .find(|l| l.name == name)
            .ok_or_else(|| anyhow::anyhow!("linear '{name}' not in manifest"))
    }

    /// Total quantized weight elements (for the memory model).
    pub fn quantized_elements(&self) -> u64 {
        self.linears.iter().map(|l| (l.k_in * l.n_out) as u64).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Manifest {
        Manifest::from_json(
            r#"{
            "name": "m", "batch": 8, "seq": 128, "vocab": 512,
            "num_linears": 1,
            "param_names": ["embed", "blk0.qkv_proj.w"],
            "param_shapes": {"embed": [512, 64], "blk0.qkv_proj.w": [64, 192]},
            "linears": [{"name": "blk0.qkv_proj", "layer": 0, "kind": "qkv_proj",
                          "k_in": 64, "n_out": 192}],
            "graphs": {"fwd_ref": {"args": ["tokens"], "outputs": ["nll"]}}
        }"#,
        )
        .unwrap()
    }

    #[test]
    fn parses_and_derives() {
        let m = sample();
        assert_eq!(m.weight_names(), vec!["blk0.qkv_proj.w"]);
        assert_eq!(m.quantized_elements(), 64 * 192);
        assert!(m.linear("blk0.qkv_proj").is_ok());
        assert!(m.linear("nope").is_err());
        assert_eq!(m.param_shapes["embed"], vec![512, 64]);
        assert_eq!(m.graphs["fwd_ref"].args, vec!["tokens"]);
    }
}
