//! Artifact IO: the FGTN tensor container (python ⇄ rust interchange) and
//! the model manifest produced by `python -m compile.aot`.

pub mod manifest;
pub mod tensorfile;

pub use manifest::{LinearSpec, Manifest};
pub use tensorfile::{Tensor, TensorData, TensorFile};
