//! Artifact IO: the FGTN tensor container (python ⇄ rust interchange), the
//! model manifest, and the deterministic synthetic-artifact builder that
//! replaces the Python `make artifacts` pipeline for hermetic runs.

pub mod manifest;
pub mod synth;
pub mod tensorfile;

pub use manifest::{LinearSpec, Manifest};
pub use tensorfile::{Tensor, TensorData, TensorFile};
