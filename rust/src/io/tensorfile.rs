//! FGTN tensor-container codec — lock-step with python/compile/tensorio.py.
//!
//! Layout (little-endian): magic "FGTN", u32 version, u32 count, then per
//! tensor: u16 name-len + utf-8 name, u8 dtype (0=f32, 1=i32, 2=u8), u8
//! ndim, u64 dims, row-major payload.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;

use anyhow::{bail, ensure, Context};

use crate::Result;

const MAGIC: &[u8; 4] = b"FGTN";
const VERSION: u32 = 1;

/// Tensor payload variants.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
    fn code(&self) -> u8 {
        match self {
            TensorData::F32(_) => 0,
            TensorData::I32(_) => 1,
            TensorData::U8(_) => 2,
        }
    }
}

/// A named, shaped tensor.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl Tensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::F32(data) }
    }
    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Tensor { shape, data: TensorData::I32(data) }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            _ => bail!("tensor is not f32"),
        }
    }
    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            _ => bail!("tensor is not i32"),
        }
    }
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// An ordered collection of named tensors (insertion order preserved on
/// write; lookups via the index map).
#[derive(Debug, Default, Clone)]
pub struct TensorFile {
    pub names: Vec<String>,
    map: BTreeMap<String, Tensor>,
}

impl TensorFile {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn insert(&mut self, name: &str, t: Tensor) {
        if !self.map.contains_key(name) {
            self.names.push(name.to_string());
        }
        self.map.insert(name.to_string(), t);
    }

    pub fn get(&self, name: &str) -> Result<&Tensor> {
        self.map
            .get(name)
            .ok_or_else(|| anyhow::anyhow!("tensor '{name}' not found (have: {:?})", self.names))
    }

    pub fn contains(&self, name: &str) -> bool {
        self.map.contains_key(name)
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }
    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let bytes = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        Self::from_bytes(&bytes).with_context(|| format!("parsing {}", path.display()))
    }

    pub fn from_bytes(mut b: &[u8]) -> Result<Self> {
        let mut magic = [0u8; 4];
        b.read_exact(&mut magic)?;
        ensure!(&magic == MAGIC, "bad magic {:?}", magic);
        let version = read_u32(&mut b)?;
        ensure!(version == VERSION, "unsupported FGTN version {version}");
        let count = read_u32(&mut b)? as usize;
        let mut out = TensorFile::new();
        for _ in 0..count {
            let nlen = read_u16(&mut b)? as usize;
            let mut nb = vec![0u8; nlen];
            b.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let mut hdr = [0u8; 2];
            b.read_exact(&mut hdr)?;
            let (code, ndim) = (hdr[0], hdr[1] as usize);
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut b)? as usize);
            }
            let n: usize = shape.iter().product();
            let data = match code {
                0 => {
                    let mut raw = vec![0u8; n * 4];
                    b.read_exact(&mut raw)?;
                    TensorData::F32(
                        raw.chunks_exact(4)
                            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                1 => {
                    let mut raw = vec![0u8; n * 4];
                    b.read_exact(&mut raw)?;
                    TensorData::I32(
                        raw.chunks_exact(4)
                            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                            .collect(),
                    )
                }
                2 => {
                    let mut raw = vec![0u8; n];
                    b.read_exact(&mut raw)?;
                    TensorData::U8(raw)
                }
                c => bail!("unknown dtype code {c}"),
            };
            out.insert(&name, Tensor { shape, data });
        }
        Ok(out)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut buf = Vec::new();
        buf.write_all(MAGIC)?;
        buf.extend((VERSION).to_le_bytes());
        buf.extend((self.names.len() as u32).to_le_bytes());
        for name in &self.names {
            let t = &self.map[name];
            buf.extend((name.len() as u16).to_le_bytes());
            buf.extend(name.as_bytes());
            buf.push(t.data.code());
            buf.push(t.shape.len() as u8);
            for &d in &t.shape {
                buf.extend((d as u64).to_le_bytes());
            }
            match &t.data {
                TensorData::F32(v) => {
                    for x in v {
                        buf.extend(x.to_le_bytes());
                    }
                }
                TensorData::I32(v) => {
                    for x in v {
                        buf.extend(x.to_le_bytes());
                    }
                }
                TensorData::U8(v) => buf.extend_from_slice(v),
            }
        }
        std::fs::write(path.as_ref(), buf)
            .with_context(|| format!("writing {}", path.as_ref().display()))?;
        Ok(())
    }
}

fn read_u16(b: &mut &[u8]) -> Result<u16> {
    let mut x = [0u8; 2];
    b.read_exact(&mut x)?;
    Ok(u16::from_le_bytes(x))
}
fn read_u32(b: &mut &[u8]) -> Result<u32> {
    let mut x = [0u8; 4];
    b.read_exact(&mut x)?;
    Ok(u32::from_le_bytes(x))
}
fn read_u64(b: &mut &[u8]) -> Result<u64> {
    let mut x = [0u8; 8];
    b.read_exact(&mut x)?;
    Ok(u64::from_le_bytes(x))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut tf = TensorFile::new();
        tf.insert("a", Tensor::f32(vec![2, 3], vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]));
        tf.insert("b", Tensor::i32(vec![4], vec![-1, 0, 1, 2]));
        tf.insert("c", Tensor { shape: vec![3], data: TensorData::U8(vec![7, 8, 9]) });
        let dir = std::env::temp_dir().join("fgtn_test_rt");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.fgtn");
        tf.save(&p).unwrap();
        let back = TensorFile::load(&p).unwrap();
        assert_eq!(back.names, tf.names);
        assert_eq!(back.get("a").unwrap(), tf.get("a").unwrap());
        assert_eq!(back.get("b").unwrap(), tf.get("b").unwrap());
        assert_eq!(back.get("c").unwrap(), tf.get("c").unwrap());
    }

    #[test]
    fn bad_magic() {
        assert!(TensorFile::from_bytes(b"NOPE\x01\x00\x00\x00\x00\x00\x00\x00").is_err());
    }

    #[test]
    fn missing_tensor_error_lists_names() {
        let mut tf = TensorFile::new();
        tf.insert("x", Tensor::f32(vec![1], vec![0.5]));
        let err = tf.get("y").unwrap_err().to_string();
        assert!(err.contains("y") && err.contains("x"));
    }

    #[test]
    fn scalarish_shapes() {
        let mut tf = TensorFile::new();
        tf.insert("s", Tensor::f32(vec![1], vec![3.5]));
        let bytes_path = std::env::temp_dir().join("fgtn_test_scalar.fgtn");
        tf.save(&bytes_path).unwrap();
        let back = TensorFile::load(&bytes_path).unwrap();
        assert_eq!(back.get("s").unwrap().as_f32().unwrap(), &[3.5]);
    }
}
