//! # FGMP — Fine-Grained Mixed-Precision Quantization
//!
//! Reproduction of *FGMP: Fine-Grained Mixed-Precision Weight and Activation
//! Quantization for Hardware-Accelerated LLM Inference* (Hooper et al., 2025)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the runtime: bit-exact NVFP4/FP8 codecs and the
//!   FGMP packed-tensor format ([`quant`]), the Fisher-weighted precision
//!   assignment policy with its baselines ([`policy`]), the co-designed
//!   hardware model — VMAC datapath, PPU, energy/area/memory ([`hwsim`]) —
//!   the execution runtimes — hermetic native by default, PJRT behind the
//!   `pjrt` feature ([`runtime`]) — the perplexity/downstream evaluation
//!   harness ([`eval`]) and an async serving coordinator ([`coordinator`]).
//! * **L2 (python/compile, build-time)** — JAX transformer families lowered
//!   once to HLO text (`make artifacts`).
//! * **L1 (python/compile/kernels, build-time)** — Pallas kernels for the
//!   FGMP quantize+matmul hot-spot, verified against a pure-jnp oracle.
//!
//! Python never runs on the request path — and since the hermetic native
//! runtime ([`runtime::native`] + [`model::forward`]) landed, it does not
//! need to run at *build* time either: [`io::synth`] generates manifest,
//! weights, calibration tensors, corpus, and task suites from a seeded RNG,
//! and the native executor reruns the transformer graphs in pure Rust. The
//! PJRT path remains available behind the off-by-default `pjrt` feature.

// Numeric-kernel idiom used throughout (indexed block loops, long argument
// lists on the hot paths, inherent to_string on the mini-JSON value).
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::inherent_to_string
)]

pub mod benchsuite;
pub mod coordinator;
pub mod eval;
pub mod hwsim;
pub mod io;
pub mod model;
pub mod policy;
pub mod quant;
pub mod runtime;
pub mod util;

/// The FGMP / NVFP4 / VMAC block size (paper §4: BS = 16 = vector length).
pub const BLOCK: usize = 16;

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
