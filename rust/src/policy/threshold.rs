//! Threshold calibration (paper §3.2, Eqs. 9–10).
//!
//! For a target FP4 fraction f, the threshold is the f-quantile of the
//! impact scores: blocks scoring above it stay FP8. The paper's key choice
//! is a **single global threshold** across all layers (Eq. 10) so that more
//! sensitive layers automatically retain more FP8 blocks; the per-layer
//! ("local", Eq. 9) variant is kept as the Fig. 6 ablation.

/// Global (one threshold across all tensors) vs local (per tensor/layer).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ThresholdMode {
    Global,
    Local,
}

/// Linear-interpolated quantile of unsorted data, q ∈ [0, 1]
/// (matches numpy's default 'linear' method used in calibrate.py).
pub fn percentile(scores: &[f64], q: f64) -> f64 {
    assert!(!scores.is_empty(), "percentile of empty score set");
    let mut v: Vec<f64> = scores.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    percentile_sorted(&v, q)
}

/// Quantile of already-sorted data.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    let n = sorted.len();
    if n == 1 {
        return sorted[0];
    }
    let pos = q.clamp(0.0, 1.0) * (n - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    let frac = pos - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Threshold such that ~`fp4_fraction` of blocks fall below (=> FP4) and the
/// rest above (=> FP8). `fp4_fraction` of 1.0 returns +inf (all FP4);
/// 0.0 returns -inf (all FP8) — the two single-format baselines.
pub fn threshold_for_fp4_fraction(scores: &[f64], fp4_fraction: f64) -> f64 {
    if fp4_fraction >= 1.0 {
        return f64::INFINITY;
    }
    if fp4_fraction <= 0.0 {
        return f64::NEG_INFINITY;
    }
    percentile(scores, fp4_fraction)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantile_basics() {
        let v = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&v, 0.0), 1.0);
        assert_eq!(percentile(&v, 1.0), 5.0);
        assert_eq!(percentile(&v, 0.5), 3.0);
        assert_eq!(percentile(&v, 0.25), 2.0);
    }

    #[test]
    fn interpolation() {
        let v = [0.0, 10.0];
        assert_eq!(percentile(&v, 0.3), 3.0);
    }

    #[test]
    fn extremes_give_infinite_thresholds() {
        let v = [1.0, 2.0];
        assert_eq!(threshold_for_fp4_fraction(&v, 1.0), f64::INFINITY);
        assert_eq!(threshold_for_fp4_fraction(&v, 0.0), f64::NEG_INFINITY);
    }

    #[test]
    fn achieved_fraction_tracks_target() {
        // 10k distinct scores: the realized FP4 fraction at the computed
        // threshold must be within 1% of target.
        let scores: Vec<f64> = (0..10_000).map(|i| (i as f64 * 0.7919).sin().abs() + i as f64 * 1e-6).collect();
        for target in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let t = threshold_for_fp4_fraction(&scores, target);
            let below = scores.iter().filter(|&&s| s <= t).count() as f64 / scores.len() as f64;
            assert!((below - target).abs() < 0.01, "target {target}, got {below}");
        }
    }

    #[test]
    fn unsorted_input_ok() {
        let v = [5.0, 1.0, 3.0, 2.0, 4.0];
        assert_eq!(percentile(&v, 0.5), 3.0);
    }
}
