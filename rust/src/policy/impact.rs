//! The sensitivity-weighted impact score (paper Eq. 8):
//!
//!   I'(v) = Σ_i w_i · (Q_fp4(v_i) − Q_fp8(v_i))²
//!
//! where w_i is the per-element weighting (Fisher g², ones, or channel
//! mean-square, depending on the [`super::Policy`]). Identical math to
//! `ref.block_impact` on the python side.

use crate::quant::nvfp4_scale;
use crate::util::kernels;
use crate::BLOCK;

/// Impact score of one block under element weighting `w`. Both format
/// images of the block are produced by the vectorized slice kernels; the
/// f64 error accumulation keeps its element order.
pub fn impact_score_block(x: &[f32], w: &[f32]) -> f64 {
    debug_assert_eq!(x.len(), BLOCK);
    let scale = nvfp4_scale(kernels::absmax(x));
    let mut q4 = [0.0f32; BLOCK];
    kernels::nvfp4_block(x, scale, &mut q4);
    let mut q8 = [0.0f32; BLOCK];
    kernels::e4m3_slice(x, &mut q8);
    let mut acc = 0.0f64;
    for i in 0..BLOCK {
        let d = (q4[i] - q8[i]) as f64;
        acc += w[i] as f64 * d * d;
    }
    acc
}

/// Impact scores for every block of a tensor (blocks tile the contiguous
/// last axis of length `k`; the weighting repeats per row).
///
/// `chan_weight` has length `k` (per-input-channel weighting shared by all
/// rows) — this is the activation-side formulation. For the weight side,
/// where the Fisher is per *element*, pass `elem_weight = Some(...)` with
/// the full tensor-sized weighting instead.
pub fn block_impact_scores(
    data: &[f32],
    k: usize,
    chan_weight: &[f32],
    elem_weight: Option<&[f32]>,
) -> Vec<f64> {
    assert_eq!(data.len() % k, 0);
    assert_eq!(k % BLOCK, 0);
    if let Some(ew) = elem_weight {
        assert_eq!(ew.len(), data.len());
    } else {
        assert_eq!(chan_weight.len(), k);
    }
    let blocks_per_row = k / BLOCK;
    let rows = data.len() / k;
    let mut out = Vec::with_capacity(rows * blocks_per_row);
    for r in 0..rows {
        for b in 0..blocks_per_row {
            let off = r * k + b * BLOCK;
            let xb = &data[off..off + BLOCK];
            let wb: &[f32] = match elem_weight {
                Some(ew) => &ew[off..off + BLOCK],
                None => &chan_weight[b * BLOCK..(b + 1) * BLOCK],
            };
            out.push(impact_score_block(xb, wb));
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn nonnegative() {
        let mut s = 5u64;
        for _ in 0..32 {
            let x: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut s) * 10.0).collect();
            let w: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut s).abs()).collect();
            assert!(impact_score_block(&x, &w) >= 0.0);
        }
    }

    #[test]
    fn linear_in_weighting() {
        let mut s = 6u64;
        let x: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut s) * 4.0).collect();
        let w: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut s).abs() + 0.1).collect();
        let w2: Vec<f32> = w.iter().map(|v| v * 3.0).collect();
        let a = impact_score_block(&x, &w);
        let b = impact_score_block(&x, &w2);
        // w2 = 3*w rounds in f32, so compare with a small relative tolerance
        assert!((b - 3.0 * a).abs() <= 1e-5 * (3.0 * a).abs() + 1e-18, "{b} vs {}", 3.0 * a);
    }

    #[test]
    fn zero_when_formats_agree() {
        // Values exactly representable in both formats at scale 1 (absmax 6
        // -> scale 1): impact must be 0.
        let x = [6.0f32, 0.5, 1.0, -1.5, 2.0, 3.0, -4.0, 0.0, 6.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 0.0];
        let w = [1.0f32; BLOCK];
        assert_eq!(impact_score_block(&x, &w), 0.0);
    }

    #[test]
    fn per_row_scores_count() {
        let mut s = 7u64;
        let k = 64;
        let data: Vec<f32> = (0..k * 3).map(|_| lcg(&mut s)).collect();
        let cw = vec![1.0f32; k];
        let scores = block_impact_scores(&data, k, &cw, None);
        assert_eq!(scores.len(), 3 * (k / BLOCK));
    }

    #[test]
    fn elem_weight_variant_matches_manual() {
        let mut s = 8u64;
        let k = 32;
        let data: Vec<f32> = (0..k * 2).map(|_| lcg(&mut s) * 5.0).collect();
        let ew: Vec<f32> = (0..k * 2).map(|_| lcg(&mut s).abs()).collect();
        let scores = block_impact_scores(&data, k, &[], Some(&ew));
        assert_eq!(scores.len(), 4);
        let manual = impact_score_block(&data[0..BLOCK], &ew[0..BLOCK]);
        assert_eq!(scores[0], manual);
    }
}
