//! Baseline precision-assignment policies (paper §3.4, Fig. 6 ablation).
//!
//! Both reuse the impact-score machinery with a different element weighting:
//!
//! * **Quantization Error** (Eq. 12): weighting ≡ 1 — rank blocks purely by
//!   the increase in quantization error.
//! * **Output Error** (Eq. 13): weight each input channel by the mean
//!   squared magnitude of the *other* tensor's corresponding channel, so the
//!   score approximates the layer-output error.
//!
//! In the paper both baselines use **per-layer dynamic** thresholds; the
//! sweep driver honours that by pairing them with `ThresholdMode::Local`.

/// Channel weighting for the Output-Error policy when quantizing a *weight*
/// tensor: mean over calibration tokens of X[·,k]² (supplied by the
/// calibration artifacts as `act_msq`).
pub fn oe_weighting_for_weights(act_msq: &[f32]) -> Vec<f32> {
    act_msq.to_vec()
}

/// Channel weighting for the Output-Error policy when quantizing an
/// *activation* tensor: mean over output channels of W[k,·]².
pub fn oe_weighting_for_acts(weight: &[f32], k: usize, n: usize) -> Vec<f32> {
    assert_eq!(weight.len(), k * n);
    let mut out = vec![0.0f32; k];
    for (ki, o) in out.iter_mut().enumerate() {
        let row = &weight[ki * n..(ki + 1) * n];
        *o = row.iter().map(|&w| w * w).sum::<f32>() / n as f32;
    }
    out
}

/// Uniform weighting for the Quantization-Error policy.
pub fn qe_weighting(k: usize) -> Vec<f32> {
    vec![1.0f32; k]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oe_acts_is_row_mean_square() {
        // W is 2x3 (k=2 input channels, n=3 outputs), row-major.
        let w = [1.0f32, 2.0, 3.0, 0.0, -1.0, 1.0];
        let cw = oe_weighting_for_acts(&w, 2, 3);
        assert!((cw[0] - (1.0 + 4.0 + 9.0) / 3.0).abs() < 1e-6);
        assert!((cw[1] - (0.0 + 1.0 + 1.0) / 3.0).abs() < 1e-6);
    }

    #[test]
    fn qe_is_ones() {
        assert!(qe_weighting(8).iter().all(|&v| v == 1.0));
    }
}
