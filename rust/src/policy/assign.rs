//! Per-tensor block assignment: scores + threshold → FP4/FP8 per block,
//! packaged for the packer ([`crate::quant::FgmpTensor`]) and the hardware
//! model ([`crate::hwsim`]).

use super::impact::block_impact_scores;
use crate::util::par_map;
use crate::quant::Precision;
use crate::BLOCK;

/// The result of assigning precisions to one tensor.
#[derive(Debug, Clone)]
pub struct Assignment {
    /// Per-block precision, block-row-major (blocks tile the last axis).
    pub precision: Vec<Precision>,
    /// The per-block scores the decision was based on.
    pub scores: Vec<f64>,
    /// Fraction of blocks kept in FP8.
    pub fp8_fraction: f64,
    /// Blocks per row (k / 16) — for visualization (paper Fig. 2b).
    pub blocks_per_row: usize,
}

/// Score a tensor and threshold it.
///
/// * `data`        — row-major tensor values, last axis length `k`.
/// * `chan_weight` — per-channel weighting (activation-side policies), or
/// * `elem_weight` — per-element weighting (weight-side Fisher), one of the
///   two must be provided per [`super::Policy`] semantics.
/// * `threshold`   — impact-score cut; above => FP8.
pub fn assign_tensor(
    data: &[f32],
    k: usize,
    chan_weight: &[f32],
    elem_weight: Option<&[f32]>,
    threshold: f64,
) -> Assignment {
    let scores = block_impact_scores(data, k, chan_weight, elem_weight);
    let precision: Vec<Precision> = scores
        .iter()
        .map(|&s| if s > threshold { Precision::Fp8 } else { Precision::Fp4 })
        .collect();
    let n_fp8 = precision.iter().filter(|p| **p == Precision::Fp8).count();
    Assignment {
        fp8_fraction: n_fp8 as f64 / precision.len().max(1) as f64,
        blocks_per_row: k / BLOCK,
        precision,
        scores,
    }
}

/// Score many tensors in parallel (the offline weight-quantization pass).
/// Each entry is (data, k, chan_weight, elem_weight, threshold).
pub fn assign_many<'a>(
    jobs: Vec<(&'a [f32], usize, &'a [f32], Option<&'a [f32]>, f64)>,
) -> Vec<Assignment> {
    par_map(&jobs, |(d, k, cw, ew, t)| assign_tensor(d, *k, cw, *ew, *t))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn threshold_extremes() {
        let mut s = 1u64;
        let k = 64;
        let data: Vec<f32> = (0..k * 4).map(|_| lcg(&mut s) * 3.0).collect();
        let cw = vec![1.0f32; k];
        let all4 = assign_tensor(&data, k, &cw, None, f64::INFINITY);
        assert_eq!(all4.fp8_fraction, 0.0);
        let all8 = assign_tensor(&data, k, &cw, None, f64::NEG_INFINITY);
        assert_eq!(all8.fp8_fraction, 1.0);
    }

    #[test]
    fn monotone_in_threshold() {
        let mut s = 2u64;
        let k = 64;
        let data: Vec<f32> = (0..k * 16).map(|_| lcg(&mut s) * 5.0).collect();
        let cw = vec![1.0f32; k];
        let mut last = 1.1f64;
        for t in [0.0, 1e-6, 1e-4, 1e-2, 1.0] {
            let a = assign_tensor(&data, k, &cw, None, t);
            assert!(a.fp8_fraction <= last + 1e-12);
            last = a.fp8_fraction;
        }
    }

    #[test]
    fn permutation_equivariant_rows() {
        // Swapping two rows swaps their assignments and nothing else.
        let mut s = 3u64;
        let k = 32;
        let mut data: Vec<f32> = (0..k * 2).map(|_| lcg(&mut s) * 4.0).collect();
        let cw: Vec<f32> = (0..k).map(|_| lcg(&mut s).abs() + 0.1).collect();
        let a1 = assign_tensor(&data, k, &cw, None, 1e-3);
        let (lo, hi) = data.split_at_mut(k);
        lo.swap_with_slice(hi);
        let a2 = assign_tensor(&data, k, &cw, None, 1e-3);
        let bpr = k / BLOCK;
        assert_eq!(&a1.precision[..bpr], &a2.precision[bpr..]);
        assert_eq!(&a1.precision[bpr..], &a2.precision[..bpr]);
    }

    #[test]
    fn assign_many_matches_single() {
        let mut s = 4u64;
        let k = 32;
        let d1: Vec<f32> = (0..k * 2).map(|_| lcg(&mut s)).collect();
        let d2: Vec<f32> = (0..k * 3).map(|_| lcg(&mut s)).collect();
        let cw = vec![1.0f32; k];
        let got = assign_many(vec![
            (&d1[..], k, &cw[..], None, 1e-4),
            (&d2[..], k, &cw[..], None, 1e-4),
        ]);
        assert_eq!(got[0].precision, assign_tensor(&d1, k, &cw, None, 1e-4).precision);
        assert_eq!(got[1].precision, assign_tensor(&d2, k, &cw, None, 1e-4).precision);
    }
}
