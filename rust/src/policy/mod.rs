//! Precision-assignment policies (paper §3.1–3.2, §3.4).
//!
//! [`impact`] implements the paper's Fisher-weighted impact score (Eq. 8);
//! [`baselines`] the Quantization-Error and Output-Error comparison policies
//! (Eqs. 12–13); [`threshold`] the global/local percentile calibration
//! (Eqs. 9–10); [`assign`] ties them together into per-tensor block
//! assignments consumed by the packer and the hardware model.

pub mod assign;
pub mod baselines;
pub mod impact;
pub mod threshold;

pub use assign::{assign_tensor, Assignment};
pub use impact::{block_impact_scores, impact_score_block};
pub use threshold::{percentile, threshold_for_fp4_fraction, ThresholdMode};

/// Which weighting enters the per-block score (paper Fig. 6 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Policy {
    /// Fisher-weighted (the paper's FGMP policy, Eq. 8).
    Fisher,
    /// Unweighted quantization error (Eq. 12).
    QuantError,
    /// Weighted by mean squared magnitude of the other tensor's
    /// corresponding input channels (Eq. 13).
    OutputError,
}

impl Policy {
    pub const ALL: [Policy; 3] = [Policy::Fisher, Policy::QuantError, Policy::OutputError];

    pub fn name(&self) -> &'static str {
        match self {
            Policy::Fisher => "fisher",
            Policy::QuantError => "qe",
            Policy::OutputError => "oe",
        }
    }
}
