//! Post-processing unit (PPU) model: the on-the-fly mixed-precision
//! activation quantizer (paper §4.2) plus its amortization/stall analysis
//! (§5.4.3).
//!
//! The functional model (what the PPU *computes*) lives in the quant/policy
//! modules — `fgmp_quant` in the L1 kernel and `assign_tensor` here in Rust;
//! this module models its *cost*: energy per block and the PE:PPU balance
//! condition under the paper's pipeline equation.

use super::datapath::DatapathConfig;
use crate::BLOCK;

/// PPU throughput/balance analysis for an (M×K)·(K×N) matmul.
#[derive(Debug, Clone)]
pub struct PpuBalance {
    /// Datapath time in cycles: M/L · K/BS · N/P.
    pub datapath_cycles: u64,
    /// PPU time in cycles: M/BS · N/U (one output block per cycle per PPU).
    pub ppu_cycles: u64,
    /// Whether the PPU keeps up (no stall) with this PE count.
    pub balanced: bool,
    /// Max PEs a single PPU sustains without stalling for this shape.
    pub max_pes_per_ppu: usize,
}

/// Evaluate the paper's balance equation for `u` PPUs.
pub fn ppu_balance(cfg: &DatapathConfig, m: usize, k: usize, n: usize, u: usize) -> PpuBalance {
    let bs = BLOCK as u64;
    let datapath = (m as u64).div_ceil(cfg.lanes as u64)
        * (k as u64 / bs)
        * (n as u64).div_ceil(cfg.pes as u64);
    let ppu = (m as u64).div_ceil(bs) * (n as u64).div_ceil(u as u64);
    // PPU keeps up iff ppu_cycles <= datapath_cycles; solve for the PE count
    // where equality holds (paper: 4096³ @ 16 lanes -> 256 PEs per PPU).
    // datapath ∝ 1/P  =>  P_max = floor(datapath(P=1) / ppu).
    let dp1 = (m as u64).div_ceil(cfg.lanes as u64) * (k as u64 / bs) * n as u64;
    let max_pes = if ppu == 0 { usize::MAX } else { (dp1 / ppu) as usize };
    PpuBalance {
        datapath_cycles: datapath,
        ppu_cycles: ppu,
        balanced: ppu <= datapath,
        max_pes_per_ppu: max_pes.max(1),
    }
}

/// PPU energy per output element (fJ/op), amortized over the reduction dim
/// — the paper's "0.20 fJ/op for K ≥ 4096" claim.
pub fn ppu_energy_per_op_fj(e_ppu_block_pj: f64, k: usize) -> f64 {
    // Each output block of BS elements required K/BS · BS · 2 = 2K ops per
    // element; the PPU quantizes the block once.
    let ops_per_block = 2.0 * k as f64 * BLOCK as f64;
    e_ppu_block_pj * 1000.0 / ops_per_block
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hwsim::energy::EnergyModel;

    #[test]
    fn paper_balance_point_256_pes() {
        // Paper §5.4.3: 4096³ matmul, 16-lane PEs -> one PPU supports up to
        // 256 PEs without stalling.
        let cfg = DatapathConfig { lanes: 16, pes: 256, freq_ghz: 1.0 };
        let b = ppu_balance(&cfg, 4096, 4096, 4096, 1);
        assert!(b.balanced);
        assert_eq!(b.max_pes_per_ppu, 256);
    }

    #[test]
    fn overprovisioned_pes_stall() {
        let cfg = DatapathConfig { lanes: 16, pes: 512, freq_ghz: 1.0 };
        let b = ppu_balance(&cfg, 4096, 4096, 4096, 1);
        assert!(!b.balanced);
    }

    #[test]
    fn more_ppus_restore_balance() {
        let cfg = DatapathConfig { lanes: 16, pes: 512, freq_ghz: 1.0 };
        let b = ppu_balance(&cfg, 4096, 4096, 4096, 2);
        assert!(b.balanced);
    }

    #[test]
    fn paper_point_two_tenths_fj_per_op() {
        // Paper §5.4.2: 25.7 pJ per block over K = 4096 -> ~0.20 fJ/op.
        let em = EnergyModel::default();
        let fj = ppu_energy_per_op_fj(em.e_ppu_block, 4096);
        assert!((fj - 0.196).abs() < 0.01, "got {fj}");
    }

    #[test]
    fn amortization_improves_with_k() {
        let em = EnergyModel::default();
        assert!(ppu_energy_per_op_fj(em.e_ppu_block, 8192)
            < ppu_energy_per_op_fj(em.e_ppu_block, 1024));
    }
}
