//! Trace-level datapath simulation: execute a matmul's weight-stationary
//! schedule with the *actual* per-block metadata bits (from a packed weight
//! tensor and a concrete activation precision mask), counting per-unit VMAC
//! activations exactly.
//!
//! This is the ground-truth check for the closed-form expectation model in
//! [`super::energy`]/[`super::datapath`] (which assumes independent
//! weight/activation metadata): the §4.3 energy pipeline is validated by
//! comparing the two on real assignments (tests below and
//! `examples/energy_sweep.rs`).

use std::collections::HashMap;

use super::datapath::{DatapathConfig, MatmulJob};
use super::energy::{DotUnit, EnergyModel};
use crate::quant::FgmpTensor;
use crate::util::kernels;
use crate::BLOCK;

/// Exact per-unit activation counts from one traced matmul.
#[derive(Debug, Clone, Default)]
pub struct TraceReport {
    /// VMACs issued per dot-product unit.
    pub unit_vmacs: HashMap<DotUnit, u64>,
    pub cycles: u64,
    pub dot_energy_pj: f64,
}

impl TraceReport {
    pub fn total_vmacs(&self) -> u64 {
        self.unit_vmacs.values().sum()
    }
    /// Fraction of VMACs on each unit.
    pub fn unit_fraction(&self, u: DotUnit) -> f64 {
        *self.unit_vmacs.get(&u).unwrap_or(&0) as f64 / self.total_vmacs().max(1) as f64
    }
}

/// Trace an (M×K)·(K×N) matmul given per-block precision bits.
///
/// * `weight_fp8[n][kb]` — metadata bit for the weight block feeding output
///   channel `n`, K-block `kb` (the packed layout: blocks along K).
/// * `act_fp8[m][kb]`    — metadata bit for activation row `m`, K-block `kb`
///   (what the PPU produced for the previous layer's output).
///
/// The schedule mirrors §4.1: A (weights) held stationary per lane group,
/// B (activation blocks) broadcast; every (m, kb, n) triple issues exactly
/// one BS-wide VMAC on the unit selected by the two metadata bits.
///
/// Counting is block-structured rather than element-at-a-time: each
/// metadata row is packed into `u64` words once ([`kernels::pack_mask_u64`])
/// and every (weight-row, act-row) pair resolves its four unit counts with
/// three popcounts — exact counts, `K/64`-wide inner loop.
pub fn trace_matmul(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    weight_fp8: &[Vec<bool>],
    act_fp8: &[Vec<bool>],
) -> TraceReport {
    let n_dim = weight_fp8.len();
    let m_dim = act_fp8.len();
    assert!(n_dim > 0 && m_dim > 0);
    let k_blocks = weight_fp8[0].len();
    assert!(weight_fp8.iter().all(|r| r.len() == k_blocks));
    assert!(act_fp8.iter().all(|r| r.len() == k_blocks));

    let wbits: Vec<Vec<u64>> = weight_fp8.iter().map(|r| kernels::pack_mask_u64(r)).collect();
    let abits: Vec<Vec<u64>> = act_fp8.iter().map(|r| kernels::pack_mask_u64(r)).collect();

    // Per-unit VMAC counts via popcounts on the packed metadata.
    let (mut c88, mut c84, mut c48) = (0u64, 0u64, 0u64);
    for wrow in &wbits {
        for arow in &abits {
            c88 += kernels::and_popcount(wrow, arow);
            c84 += kernels::andnot_popcount(wrow, arow);
            c48 += kernels::andnot_popcount(arow, wrow);
        }
    }
    let total = (n_dim * m_dim * k_blocks) as u64;
    let c44 = total - c88 - c84 - c48;

    let mut unit_vmacs: HashMap<DotUnit, u64> = HashMap::new();
    let mut energy = 0.0f64;
    for (unit, count) in [
        (DotUnit::select(true, true), c88),
        (DotUnit::select(true, false), c84),
        (DotUnit::select(false, true), c48),
        (DotUnit::select(false, false), c44),
    ] {
        if count > 0 {
            *unit_vmacs.entry(unit).or_insert(0) += count;
            energy += em.vmac_fgmp(unit) * count as f64;
        }
    }
    let cycles = (m_dim as u64).div_ceil(cfg.lanes as u64)
        * k_blocks as u64
        * (n_dim as u64).div_ceil(cfg.pes as u64);
    TraceReport { unit_vmacs, cycles, dot_energy_pj: energy }
}

/// Trace using a packed FGMP weight tensor (blocks along K per output
/// channel) and an activation mask.
pub fn trace_packed(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    weights: &FgmpTensor,
    k: usize,
    act_fp8: &[Vec<bool>],
) -> TraceReport {
    let kb = k / BLOCK;
    let n = weights.n_blocks / kb;
    let wmask: Vec<Vec<bool>> = (0..n)
        .map(|ni| (0..kb).map(|b| weights.is_fp8(ni * kb + b)).collect())
        .collect();
    trace_matmul(cfg, em, &wmask, act_fp8)
}

/// Relative error between the traced energy and the closed-form
/// expectation model for the same aggregate fractions.
pub fn expectation_gap(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    weight_fp8: &[Vec<bool>],
    act_fp8: &[Vec<bool>],
) -> f64 {
    let trace = trace_matmul(cfg, em, weight_fp8, act_fp8);
    let k_blocks = weight_fp8[0].len();
    let wf = frac(weight_fp8);
    let af = frac(act_fp8);
    let job = MatmulJob {
        m: act_fp8.len(),
        k: k_blocks * BLOCK,
        n: weight_fp8.len(),
        weight_fp8: wf,
        act_fp8: af,
    };
    let analytic = super::datapath::simulate_matmul(cfg, em, &job, false);
    (trace.dot_energy_pj - analytic.dot_energy_pj).abs() / analytic.dot_energy_pj
}

fn frac(mask: &[Vec<bool>]) -> f64 {
    let total: usize = mask.iter().map(|r| r.len()).sum();
    let set: usize = mask.iter().map(|r| r.iter().filter(|&&b| b).count()).sum();
    set as f64 / total.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn mask(rows: usize, kb: usize, p: f64, seed: u64) -> Vec<Vec<bool>> {
        let mut rng = Rng::new(seed);
        (0..rows).map(|_| (0..kb).map(|_| rng.f64() < p).collect()).collect()
    }

    #[test]
    fn vmac_count_exact() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let r = trace_matmul(&cfg, &em, &mask(32, 8, 0.3, 1), &mask(64, 8, 0.3, 2));
        assert_eq!(r.total_vmacs(), 32 * 64 * 8);
    }

    #[test]
    fn single_format_masks_use_one_unit() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let r = trace_matmul(&cfg, &em, &mask(8, 4, 1.1, 1), &mask(8, 4, 1.1, 2));
        assert_eq!(r.unit_fraction(DotUnit::Fp8Fp8), 1.0);
        let r = trace_matmul(&cfg, &em, &mask(8, 4, -0.1, 1), &mask(8, 4, -0.1, 2));
        assert_eq!(r.unit_fraction(DotUnit::Fp4Fp4), 1.0);
    }

    #[test]
    fn expectation_model_matches_trace_exactly_under_independence() {
        // With weight bits indexed by (n, kb) and act bits by (m, kb), the
        // cross product makes the *pairing* exactly independent per kb, so
        // the expectation model should agree to ~the mixing error of the
        // finite masks (<2% for these sizes).
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        for (pw, pa) in [(0.1, 0.3), (0.3, 0.3), (0.7, 0.2), (0.5, 0.5)] {
            let gap = expectation_gap(&cfg, &em,
                                      &mask(64, 16, pw, 42), &mask(128, 16, pa, 43));
            assert!(gap < 0.02, "gap {gap} at ({pw},{pa})");
        }
    }

    #[test]
    fn additive_unit_energies_make_expectation_exact_under_correlation() {
        // A finding the trace simulator surfaces: the paper's published
        // unit energies are *additive* in the two metadata bits (FP4
        // weights save 16%, FP4 activations 17%, both together 33%), so
        // E[energy] depends only on the marginal FP8 fractions — even
        // maximally correlated masks (weight and activation FP8 aligned on
        // the same K columns) match the independence model exactly. The
        // §4.3 clustered pipeline therefore carries no correlation error
        // for this datapath.
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let kb = 16;
        // both masks FP8 on the same first 4 kb columns only (max correlation)
        let w: Vec<Vec<bool>> = (0..64).map(|_| (0..kb).map(|b| b < 4).collect()).collect();
        let a: Vec<Vec<bool>> = (0..64).map(|_| (0..kb).map(|b| b < 4).collect()).collect();
        let trace = trace_matmul(&cfg, &em, &w, &a);
        let job = MatmulJob { m: 64, k: kb * BLOCK, n: 64, weight_fp8: 0.25, act_fp8: 0.25 };
        let analytic = super::super::datapath::simulate_matmul(&cfg, &em, &job, false);
        let gap = (trace.dot_energy_pj - analytic.dot_energy_pj).abs() / analytic.dot_energy_pj;
        assert!(gap < 1e-9, "additivity: gap {gap}");
        // ... and a hypothetical non-additive datapath would break this:
        // with super-additive FP4×FP4 savings, aligned masks over-represent
        // the cheap unit, so the trace comes in BELOW the expectation model.
        let mut em2 = em.clone();
        em2.e_fp4 *= 0.8;
        let trace2 = trace_matmul(&cfg, &em2, &w, &a);
        let analytic2 = super::super::datapath::simulate_matmul(&cfg, &em2, &job, false);
        assert!(trace2.dot_energy_pj < analytic2.dot_energy_pj * 0.99,
                "correlated masks must under-cost on a super-additive datapath");
    }

    #[test]
    fn packed_tensor_trace_consistent() {
        use crate::quant::Precision;
        let mut rng = Rng::new(7);
        let k = 64;
        let n = 8;
        let data: Vec<f32> = (0..n * k).map(|_| (rng.normal() * 3.0) as f32).collect();
        let prec: Vec<Precision> = (0..n * k / BLOCK)
            .map(|i| if i % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[n, k], &data, &prec, None);
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let act = mask(16, k / BLOCK, 0.5, 9);
        let r = trace_packed(&cfg, &em, &t, k, &act);
        assert_eq!(r.total_vmacs(), (16 * n * (k / BLOCK)) as u64);
        // fraction of weight-FP8-involving units equals the packed fraction
        let w8 = r.unit_fraction(DotUnit::Fp8Fp8) + r.unit_fraction(DotUnit::Fp8Fp4);
        assert!((w8 - t.fp8_fraction()).abs() < 1e-9);
    }
}
