//! Area model (paper Table 4, post-synthesis 5 nm, 16 lanes, BS = 16).
//!
//! Component areas are the paper's published numbers; the derived quantities
//! (overhead ratios, PPU amortization across PEs) are recomputed from them —
//! that recomputation is what `benches/table4_area.rs` regenerates.


/// Post-synthesis area in µm² for each datapath configuration (Table 4).
#[derive(Debug, Clone)]
pub struct AreaModel {
    pub fp8_datapath: f64,
    pub nvfp4_datapath: f64,
    /// FP8 weights × NVFP4 activations unit.
    pub fp8_nvfp4_datapath: f64,
    /// NVFP4 weights × FP8 activations unit.
    pub nvfp4_fp8_datapath: f64,
    /// The full four-unit FGMP datapath (16 lanes).
    pub fgmp_datapath: f64,
    /// The mixed-precision activation-quantization PPU.
    pub fgmp_ppu: f64,
}

impl Default for AreaModel {
    fn default() -> Self {
        AreaModel {
            fp8_datapath: 2995.0,
            nvfp4_datapath: 1811.0,
            fp8_nvfp4_datapath: 2669.0,
            nvfp4_fp8_datapath: 2630.0,
            fgmp_datapath: 10356.0,
            fgmp_ppu: 8848.0,
        }
    }
}

impl AreaModel {
    /// FGMP datapath overhead vs a standalone FP8 datapath (paper: 3.5×).
    pub fn overhead_vs_fp8(&self) -> f64 {
        self.fgmp_datapath / self.fp8_datapath
    }

    /// Overhead vs a coarse-grained mixed-precision datapath that has only
    /// the FP8 and FP4 units (paper: 2.2×).
    pub fn overhead_vs_coarse(&self) -> f64 {
        self.fgmp_datapath / (self.fp8_datapath + self.nvfp4_datapath)
    }

    /// PPU area overhead relative to the 16-lane FGMP datapath (paper: 85%).
    pub fn ppu_overhead(&self) -> f64 {
        self.fgmp_ppu / self.fgmp_datapath
    }

    /// PPU area overhead when one PPU is shared across `pes` PEs.
    pub fn ppu_overhead_amortized(&self, pes: usize) -> f64 {
        self.fgmp_ppu / (self.fgmp_datapath * pes as f64)
    }

    /// Sum of the four independent units — the FGMP datapath is slightly
    /// larger than this because of the per-lane muxing/accumulator sharing.
    pub fn sum_of_units(&self) -> f64 {
        self.fp8_datapath + self.nvfp4_datapath + self.fp8_nvfp4_datapath + self.nvfp4_fp8_datapath
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_overheads() {
        let a = AreaModel::default();
        assert!((a.overhead_vs_fp8() - 3.458).abs() < 0.01);
        assert!((a.overhead_vs_coarse() - 2.154).abs() < 0.01);
        assert!((a.ppu_overhead() - 0.854).abs() < 0.01);
    }

    #[test]
    fn amortized_ppu_negligible_at_256_pes() {
        let a = AreaModel::default();
        assert!(a.ppu_overhead_amortized(256) < 0.004);
    }

    #[test]
    fn fgmp_close_to_sum_of_units() {
        let a = AreaModel::default();
        let ratio = a.fgmp_datapath / a.sum_of_units();
        assert!(ratio > 0.95 && ratio < 1.1, "got {ratio}");
    }
}
