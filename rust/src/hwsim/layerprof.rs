//! Per-layer precision-mix profiles (paper Fig. 7) and the full-model
//! energy pipeline of §4.3: profile per-layer FP4/FP8 mixes → K-means into
//! representative configurations → cost each representative on the datapath
//! model → scale back to the real layer shapes.


use super::datapath::{simulate_matmul, DatapathConfig, MatmulJob, MatmulReport};
use super::energy::EnergyModel;
use super::kmeans::{kmeans, LayerConfig};

/// The measured precision mix for one linear layer.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    pub name: String,
    pub layer: usize,
    /// "qkv_proj" | "o_proj" | "fc1" | "fc2".
    pub kind: String,
    /// Matmul shape: (M tokens, K, N).
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fraction of weight blocks in FP8 (from the offline assignment).
    pub weight_fp8: f64,
    /// Fraction of activation blocks in FP8 (from the runtime PPU stats).
    pub act_fp8: f64,
}

/// Full-model energy report.
#[derive(Debug, Clone, Default)]
pub struct ModelEnergyReport {
    pub per_layer: Vec<(String, MatmulReport)>,
    pub total_pj: f64,
    /// Total under the all-FP8 single-format baseline.
    pub fp8_baseline_pj: f64,
    /// Total under the all-FP4 single-format baseline.
    pub fp4_baseline_pj: f64,
    pub n_clusters: usize,
}

impl ModelEnergyReport {
    /// Normalized energy vs the FP8 baseline (the Fig. 10 x-axis).
    pub fn normalized(&self) -> f64 {
        self.total_pj / self.fp8_baseline_pj
    }
    pub fn savings(&self) -> f64 {
        1.0 - self.normalized()
    }
}

/// Cost a whole model: exact per-layer simulation (the "ground truth" the
/// clustered estimate approximates).
pub fn model_energy_exact(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    profiles: &[LayerProfile],
) -> ModelEnergyReport {
    let mut rep = ModelEnergyReport::default();
    for p in profiles {
        let job = MatmulJob { m: p.m, k: p.k, n: p.n, weight_fp8: p.weight_fp8, act_fp8: p.act_fp8 };
        let r = simulate_matmul(cfg, em, &job, true);
        rep.total_pj += r.total_energy_pj();
        let r8 = simulate_matmul(cfg, em, &MatmulJob { weight_fp8: 1.0, act_fp8: 1.0, ..job.clone() }, true);
        // Single-format baselines don't pay the FGMP mux tax:
        rep.fp8_baseline_pj += r8.total_energy_pj() - em.e_mux_tax * r8.vmacs as f64;
        let r4 = simulate_matmul(cfg, em, &MatmulJob { weight_fp8: 0.0, act_fp8: 0.0, ..job.clone() }, true);
        rep.fp4_baseline_pj += r4.total_energy_pj() - em.e_mux_tax * r4.vmacs as f64;
        rep.per_layer.push((p.name.clone(), r));
    }
    rep.n_clusters = profiles.len();
    rep
}

/// Cost a whole model via the paper's §4.3 pipeline: K-means the per-layer
/// configurations into `k` representatives, cost one small kernel per
/// representative, scale up by each member layer's VMAC count.
pub fn model_energy_clustered(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    profiles: &[LayerProfile],
    k: usize,
) -> ModelEnergyReport {
    let pts: Vec<LayerConfig> = profiles
        .iter()
        .map(|p| LayerConfig { weight_fp8: p.weight_fp8, act_fp8: p.act_fp8 })
        .collect();
    let clus = kmeans(&pts, k, 100);

    // Cost one representative small kernel (256×256×256) per centroid and
    // derive the per-VMAC energy, as the paper replays small kernels on the
    // gate netlist and scales to layer shapes.
    let probe = |wc: f64, ac: f64| -> f64 {
        let job = MatmulJob { m: 256, k: 256, n: 256, weight_fp8: wc, act_fp8: ac };
        let r = simulate_matmul(cfg, em, &job, true);
        r.total_energy_pj() / r.vmacs as f64
    };
    let per_vmac: Vec<f64> = clus
        .centroids
        .iter()
        .map(|c| probe(c.weight_fp8, c.act_fp8))
        .collect();

    let mut rep = ModelEnergyReport::default();
    for (i, p) in profiles.iter().enumerate() {
        let job = MatmulJob { m: p.m, k: p.k, n: p.n, weight_fp8: p.weight_fp8, act_fp8: p.act_fp8 };
        let exact = simulate_matmul(cfg, em, &job, true); // for vmac count + baselines
        let scaled = per_vmac[clus.assignment[i]] * exact.vmacs as f64;
        rep.total_pj += scaled;
        let r8 = simulate_matmul(cfg, em, &MatmulJob { weight_fp8: 1.0, act_fp8: 1.0, ..job.clone() }, true);
        rep.fp8_baseline_pj += r8.total_energy_pj() - em.e_mux_tax * r8.vmacs as f64;
        let r4 = simulate_matmul(cfg, em, &MatmulJob { weight_fp8: 0.0, act_fp8: 0.0, ..job.clone() }, true);
        rep.fp4_baseline_pj += r4.total_energy_pj() - em.e_mux_tax * r4.vmacs as f64;
        rep.per_layer.push((p.name.clone(), exact));
    }
    rep.n_clusters = clus.centroids.len();
    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk_profiles(n: usize) -> Vec<LayerProfile> {
        (0..n)
            .map(|i| LayerProfile {
                name: format!("blk{i}.fc1"),
                layer: i,
                kind: "fc1".into(),
                m: 1024,
                k: 256,
                n: 512,
                weight_fp8: (i as f64 * 0.37).fract() * 0.5,
                act_fp8: (i as f64 * 0.61).fract() * 0.5,
            })
            .collect()
    }

    #[test]
    fn clustered_close_to_exact() {
        // Paper's methodology check: 100 clusters approximate the exact
        // per-layer costing to well under 1%.
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let profiles = mk_profiles(64);
        let exact = model_energy_exact(&cfg, &em, &profiles);
        let approx = model_energy_clustered(&cfg, &em, &profiles, 100);
        let rel = (approx.total_pj - exact.total_pj).abs() / exact.total_pj;
        assert!(rel < 0.01, "clustered estimate off by {rel}");
    }

    #[test]
    fn fewer_clusters_coarser_but_sane() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let profiles = mk_profiles(64);
        let exact = model_energy_exact(&cfg, &em, &profiles);
        let approx = model_energy_clustered(&cfg, &em, &profiles, 4);
        let rel = (approx.total_pj - exact.total_pj).abs() / exact.total_pj;
        assert!(rel < 0.10, "4-cluster estimate off by {rel}");
    }

    #[test]
    fn mostly_fp4_model_saves_vs_fp8() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let profiles = mk_profiles(16);
        let rep = model_energy_exact(&cfg, &em, &profiles);
        assert!(rep.normalized() < 1.0);
        assert!(rep.total_pj > rep.fp4_baseline_pj);
    }

    #[test]
    fn all_fp8_profile_slightly_above_baseline() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let profiles: Vec<LayerProfile> = mk_profiles(8)
            .into_iter()
            .map(|mut p| {
                p.weight_fp8 = 1.0;
                p.act_fp8 = 1.0;
                p
            })
            .collect();
        let rep = model_energy_exact(&cfg, &em, &profiles);
        assert!(rep.normalized() > 1.0, "mux tax must show up: {}", rep.normalized());
        assert!(rep.normalized() < 1.03);
    }
}
