//! Total inference-memory model including the KV cache.
//!
//! The paper's Fig. 1 compression rate assumes a 4K context, and its
//! footnote notes that some comparators (ATOM, OmniQuant) also quantize the
//! KV cache while FGMP targets the linear layers. This module makes that
//! accounting explicit: weight memory from the FGMP packing model plus KV
//! cache at a configurable precision and context length, so the
//! weights-only savings can be put in whole-inference context (and the
//! paper's "serve a larger model in the same budget" claim evaluated).

use super::memory::{fgmp_footprint, flat_footprint, MemoryReport};

/// Model dimensions relevant to KV sizing.
#[derive(Debug, Clone)]
pub struct KvModelDims {
    pub n_layers: usize,
    pub d_model: usize,
    /// Quantized linear-layer weight elements (manifest.quantized_elements).
    pub weight_elements: u64,
}

impl KvModelDims {
    /// Llama-2-7B, the paper's reference shape.
    pub fn llama2_7b() -> Self {
        KvModelDims {
            n_layers: 32,
            d_model: 4096,
            weight_elements: 32 * (4096 * 3 * 4096 + 4096 * 4096 + 2 * 4096 * 11008 + 11008 * 4096) as u64,
        }
    }
}

/// KV-cache bits for `tokens` of context at `bits_per_value` (16 = BF16,
/// the paper's setting; 8/4.5625 for quantized-cache comparators).
pub fn kv_cache_bits(dims: &KvModelDims, tokens: u64, bits_per_value: f64) -> u64 {
    // K and V, per layer, per token, d_model values each.
    let values = 2 * dims.n_layers as u64 * tokens * dims.d_model as u64;
    (values as f64 * bits_per_value) as u64
}

/// Whole-inference memory at one operating point.
#[derive(Debug, Clone)]
pub struct InferenceMemory {
    pub weights: MemoryReport,
    pub kv_bits: u64,
    pub context: u64,
}

impl InferenceMemory {
    pub fn total_bits(&self) -> u64 {
        self.weights.total_bits() + self.kv_bits
    }
    pub fn total_gib(&self) -> f64 {
        self.total_bits() as f64 / 8.0 / 1024.0 / 1024.0 / 1024.0
    }
}

/// FGMP (weights at `fp8_fraction`) with a BF16 KV cache, vs the all-FP8
/// weights + BF16 KV baseline. Returns (fgmp, fp8_baseline, savings).
pub fn inference_memory_report(
    dims: &KvModelDims,
    fp8_fraction: f64,
    context: u64,
) -> (InferenceMemory, InferenceMemory, f64) {
    let kv = kv_cache_bits(dims, context, 16.0);
    let fgmp = InferenceMemory {
        weights: fgmp_footprint(dims.weight_elements, fp8_fraction),
        kv_bits: kv,
        context,
    };
    let base = InferenceMemory {
        weights: flat_footprint(dims.weight_elements, 8),
        kv_bits: kv,
        context,
    };
    let savings = 1.0 - fgmp.total_bits() as f64 / base.total_bits() as f64;
    (fgmp, base, savings)
}

/// How many extra context tokens the FGMP weight savings buy at a fixed
/// total memory budget (the "serve a larger workload" framing).
pub fn extra_context_tokens(dims: &KvModelDims, fp8_fraction: f64, context: u64) -> u64 {
    let (fgmp, base, _) = inference_memory_report(dims, fp8_fraction, context);
    let freed = base.weights.total_bits() - fgmp.weights.total_bits();
    let bits_per_token = kv_cache_bits(dims, 1, 16.0);
    freed / bits_per_token.max(1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kv_scales_linearly_in_context() {
        let d = KvModelDims::llama2_7b();
        let a = kv_cache_bits(&d, 1024, 16.0);
        let b = kv_cache_bits(&d, 2048, 16.0);
        assert_eq!(b, 2 * a);
    }

    #[test]
    fn llama7b_kv_at_4k_is_about_2gib() {
        // 2 * 32 layers * 4096 tokens * 4096 dim * 2 bytes = 2 GiB.
        let d = KvModelDims::llama2_7b();
        let gib = kv_cache_bits(&d, 4096, 16.0) as f64 / 8.0 / (1u64 << 30) as f64;
        assert!((gib - 2.0).abs() < 0.01, "got {gib}");
    }

    #[test]
    fn whole_inference_savings_below_weight_only_savings() {
        // The BF16 KV cache dilutes the weight savings — the honest number
        // the module exists to report.
        let d = KvModelDims::llama2_7b();
        let (_, _, s) = inference_memory_report(&d, 0.30, 4096);
        assert!(s > 0.20 && s < 0.30, "diluted savings {s}");
        let (_, _, s0) = inference_memory_report(&d, 0.30, 0);
        assert!((s0 - 0.298).abs() < 0.005, "weights-only {s0}");
    }

    #[test]
    fn savings_shrink_with_context() {
        let d = KvModelDims::llama2_7b();
        let (_, _, s4k) = inference_memory_report(&d, 0.30, 4096);
        let (_, _, s32k) = inference_memory_report(&d, 0.30, 32768);
        assert!(s32k < s4k);
    }

    #[test]
    fn freed_memory_buys_context() {
        let d = KvModelDims::llama2_7b();
        let extra = extra_context_tokens(&d, 0.30, 4096);
        // ~1.84 GiB freed / 0.5 MiB per token ≈ 3.7k tokens
        assert!(extra > 3_000 && extra < 4_500, "extra {extra}");
    }
}
