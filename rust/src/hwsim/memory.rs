//! Weight-memory footprint model (paper Fig. 8).
//!
//! For each linear layer the packed FGMP size decomposes into payload,
//! microscale, and metadata bits — `FgmpTensor::footprint_bits` does the
//! exact per-tensor accounting; this module aggregates per model and
//! compares against the FP8 / BF16 baselines.


use crate::BLOCK;

/// Memory breakdown for one precision configuration (bits).
#[derive(Debug, Clone, Default)]
pub struct MemoryReport {
    pub payload_bits: u64,
    pub scale_bits: u64,
    pub meta_bits: u64,
    pub elements: u64,
}

impl MemoryReport {
    pub fn total_bits(&self) -> u64 {
        self.payload_bits + self.scale_bits + self.meta_bits
    }
    pub fn total_mib(&self) -> f64 {
        self.total_bits() as f64 / 8.0 / 1024.0 / 1024.0
    }
    /// Average bits per element (the compression-rate denominator of Fig. 1).
    pub fn bits_per_element(&self) -> f64 {
        self.total_bits() as f64 / self.elements.max(1) as f64
    }
    pub fn add(&mut self, other: &MemoryReport) {
        self.payload_bits += other.payload_bits;
        self.scale_bits += other.scale_bits;
        self.meta_bits += other.meta_bits;
        self.elements += other.elements;
    }
}

/// Analytic footprint of a tensor with `elements` values at the given FP8
/// block fraction (FGMP packing: FP8 block = 128b, FP4 block = 64b + 8b
/// scale; +1 metadata bit per block).
pub fn fgmp_footprint(elements: u64, fp8_fraction: f64) -> MemoryReport {
    assert!(elements % BLOCK as u64 == 0);
    let blocks = elements / BLOCK as u64;
    let fp8_blocks = (blocks as f64 * fp8_fraction).round() as u64;
    let fp4_blocks = blocks - fp8_blocks;
    MemoryReport {
        payload_bits: fp8_blocks * (BLOCK as u64) * 8 + fp4_blocks * (BLOCK as u64) * 4,
        scale_bits: fp4_blocks * 8,
        meta_bits: blocks,
        elements,
    }
}

/// Single-format baselines.
pub fn flat_footprint(elements: u64, bits: u64) -> MemoryReport {
    MemoryReport {
        payload_bits: elements * bits,
        scale_bits: 0,
        meta_bits: 0,
        elements,
    }
}

/// NVFP4-only footprint (scales, no FGMP metadata).
pub fn nvfp4_footprint(elements: u64) -> MemoryReport {
    let blocks = elements / BLOCK as u64;
    MemoryReport {
        payload_bits: elements * 4,
        scale_bits: blocks * 8,
        meta_bits: 0,
        elements,
    }
}

/// The Fig. 8 comparison for a model with `elements` quantized weights:
/// (FP8 baseline, FGMP @ fp8_fraction, savings fraction).
pub fn weight_memory_report(elements: u64, fp8_fraction: f64) -> (MemoryReport, MemoryReport, f64) {
    let fp8 = flat_footprint(elements, 8);
    let fgmp = fgmp_footprint(elements, fp8_fraction);
    let savings = 1.0 - fgmp.total_bits() as f64 / fp8.total_bits() as f64;
    (fp8, fgmp, savings)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_headline_savings() {
        // Paper §5.4.1: 30% savings at 70% FP4, 39% at 90% FP4 (vs FP8).
        let n = 16u64 * 1_000_000;
        let (_, _, s70) = weight_memory_report(n, 0.30);
        let (_, _, s90) = weight_memory_report(n, 0.10);
        assert!((s70 - 0.30).abs() < 0.02, "70% FP4 savings: {s70}");
        assert!((s90 - 0.39).abs() < 0.02, "90% FP4 savings: {s90}");
    }

    #[test]
    fn all_fp8_fgmp_costs_only_metadata_extra() {
        let n = 1600u64;
        let f = fgmp_footprint(n, 1.0);
        let base = flat_footprint(n, 8);
        assert_eq!(f.total_bits(), base.total_bits() + n / 16);
    }

    #[test]
    fn bits_per_element_bounds() {
        let n = 16_000u64;
        let all4 = fgmp_footprint(n, 0.0);
        // 4 bits + 8/16 scale + 1/16 meta = 4.5625
        assert!((all4.bits_per_element() - 4.5625).abs() < 1e-9);
        let all8 = fgmp_footprint(n, 1.0);
        assert!((all8.bits_per_element() - 8.0625).abs() < 1e-9);
    }

    #[test]
    fn monotone_in_fp8_fraction() {
        let n = 160_000u64;
        let mut last = 0u64;
        for i in 0..=10 {
            let f = fgmp_footprint(n, i as f64 / 10.0);
            assert!(f.total_bits() >= last);
            last = f.total_bits();
        }
    }

    #[test]
    fn report_add() {
        let mut a = fgmp_footprint(1600, 0.5);
        let b = fgmp_footprint(3200, 0.25);
        let t = a.total_bits() + b.total_bits();
        a.add(&b);
        assert_eq!(a.total_bits(), t);
        assert_eq!(a.elements, 4800);
    }
}
