//! Weight-stationary VMAC datapath cycle/energy model (paper §4.1, §5.4).
//!
//! The accelerator is P PEs × L lanes; each lane computes one BS-wide VMAC
//! per cycle. For an (M×K)·(K×N) matmul the datapath time is
//! `M/L · K/BS · N/P` cycles (paper §5.4.3), with the A tile held stationary
//! and B blocks broadcast — we account energy per VMAC from the per-unit
//! model plus amortized weight-load / broadcast costs.


use super::energy::{DotUnit, EnergyModel};
use crate::BLOCK;

/// Datapath geometry (defaults = the paper's prototype: L=16, BS=16).
#[derive(Debug, Clone)]
pub struct DatapathConfig {
    /// Vector lanes per PE.
    pub lanes: usize,
    /// Number of PEs.
    pub pes: usize,
    /// Clock frequency in GHz (paper: 1 GHz).
    pub freq_ghz: f64,
}

impl Default for DatapathConfig {
    fn default() -> Self {
        DatapathConfig { lanes: 16, pes: 16, freq_ghz: 1.0 }
    }
}

/// One matmul to simulate: dimensions plus the precision mix.
#[derive(Debug, Clone)]
pub struct MatmulJob {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Fraction of weight blocks in FP8.
    pub weight_fp8: f64,
    /// Fraction of activation blocks in FP8.
    pub act_fp8: f64,
}

/// Simulation result for one matmul.
#[derive(Debug, Clone, Default)]
pub struct MatmulReport {
    pub cycles: u64,
    pub vmacs: u64,
    pub ops: u64,
    /// Dot-product energy (pJ), FGMP mux tax included.
    pub dot_energy_pj: f64,
    /// Weight-load + broadcast energy (pJ).
    pub data_energy_pj: f64,
    /// PPU energy (pJ) for quantizing the output blocks.
    pub ppu_energy_pj: f64,
    pub runtime_us: f64,
}

impl MatmulReport {
    pub fn total_energy_pj(&self) -> f64 {
        self.dot_energy_pj + self.data_energy_pj + self.ppu_energy_pj
    }
    /// Energy per op (pJ) — the Fig. 9/10 unit.
    pub fn energy_per_op(&self) -> f64 {
        self.total_energy_pj() / self.ops.max(1) as f64
    }
}

/// Simulate one matmul on the FGMP datapath with the PPU quantizing the
/// (M×N) output to mixed precision (`quantize_output=false` for the final
/// LM head or any layer whose consumer wants high precision).
pub fn simulate_matmul(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    job: &MatmulJob,
    quantize_output: bool,
) -> MatmulReport {
    let bs = BLOCK;
    assert!(job.k % bs == 0, "K must tile into blocks");
    // Throughput is precision-independent (paper §4.1): ceil dims.
    let m_tiles = job.m.div_ceil(cfg.lanes) as u64;
    let k_blocks = (job.k / bs) as u64;
    let n_tiles = job.n.div_ceil(cfg.pes) as u64;
    let cycles = m_tiles * k_blocks * n_tiles;
    let vmacs = (job.m as u64) * k_blocks * (job.n as u64);
    let ops = vmacs * 2 * bs as u64;

    // Expected VMAC energy under the block-precision mix (independence of
    // weight/activation metadata bits — they are computed by independent
    // mechanisms, offline policy vs online PPU).
    let e_vmac = em.vmac_expected(job.weight_fp8, job.act_fp8);
    let dot_energy = e_vmac * vmacs as f64;

    // Weight-stationary: each weight block loaded once per N-tile pass;
    // activations broadcast once per M-tile row.
    let weight_blocks = (job.m as u64) * k_blocks;
    let act_blocks = k_blocks * (job.n as u64);
    let data_energy = em.e_weight_load_block * weight_blocks as f64
        + em.e_act_broadcast * act_blocks as f64 * (m_tiles as f64);

    // PPU: one quantization per 16-element output block (paper §5.4.2 —
    // invoked once per reduced output block, amortized over K).
    let out_blocks = (job.m as u64) * (job.n as u64).div_ceil(bs as u64);
    let ppu_energy = if quantize_output { em.e_ppu_block * out_blocks as f64 } else { 0.0 };

    MatmulReport {
        cycles,
        vmacs,
        ops,
        dot_energy_pj: dot_energy,
        data_energy_pj: data_energy,
        ppu_energy_pj: ppu_energy,
        runtime_us: cycles as f64 / (cfg.freq_ghz * 1e3),
    }
}

/// Single-format reference points (the four labelled boxes of Fig. 9): the
/// whole matmul runs on one dot-product unit with no mux tax.
pub fn simulate_single_format(
    cfg: &DatapathConfig,
    em: &EnergyModel,
    job: &MatmulJob,
    unit: DotUnit,
) -> MatmulReport {
    let mut r = simulate_matmul(cfg, em, job, false);
    r.dot_energy_pj = em.vmac_single(unit) * r.vmacs as f64;
    r
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(m: usize, k: usize, n: usize) -> MatmulJob {
        MatmulJob { m, k, n, weight_fp8: 0.3, act_fp8: 0.3 }
    }

    #[test]
    fn cycle_count_closed_form() {
        // Paper §5.4.3: M/L · K/16 · N/P for a 4096³ matmul, L=16, P=16.
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let r = simulate_matmul(&cfg, &em, &job(4096, 4096, 4096), true);
        assert_eq!(r.cycles, (4096 / 16) * (4096 / 16) * (4096 / 16));
    }

    #[test]
    fn throughput_independent_of_precision() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let mut j = job(512, 256, 512);
        let c1 = simulate_matmul(&cfg, &em, &j, true).cycles;
        j.weight_fp8 = 1.0;
        j.act_fp8 = 1.0;
        let c2 = simulate_matmul(&cfg, &em, &j, true).cycles;
        assert_eq!(c1, c2, "paper §4.1: same math throughput per cycle");
    }

    #[test]
    fn energy_monotone_in_fp8() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let mut last = 0.0;
        for i in 0..=4 {
            let f = i as f64 / 4.0;
            let r = simulate_matmul(&cfg, &em, &MatmulJob { weight_fp8: f, act_fp8: f, ..job(256, 256, 256) }, true);
            assert!(r.dot_energy_pj >= last);
            last = r.dot_energy_pj;
        }
    }

    #[test]
    fn fp4_saves_vs_fp8_single_format() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let j = job(256, 256, 256);
        let r8 = simulate_single_format(&cfg, &em, &j, DotUnit::Fp8Fp8);
        let r4 = simulate_single_format(&cfg, &em, &j, DotUnit::Fp4Fp4);
        let ratio = r4.dot_energy_pj / r8.dot_energy_pj;
        assert!((ratio - 0.67).abs() < 1e-9);
    }

    #[test]
    fn ppu_amortized_below_one_percent() {
        // Paper §5.4.2: for K >= 4096 the PPU is < 1% of dot-product energy.
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let r = simulate_matmul(&cfg, &em, &job(4096, 4096, 4096), true);
        assert!(r.ppu_energy_pj / r.dot_energy_pj < 0.01);
    }

    #[test]
    fn runtime_scales_with_cycles() {
        let cfg = DatapathConfig::default();
        let em = EnergyModel::default();
        let r = simulate_matmul(&cfg, &em, &job(256, 256, 256), false);
        assert!((r.runtime_us - r.cycles as f64 / 1e3).abs() < 1e-9);
    }
}
