//! Hardware model of the FGMP accelerator (paper §4, §5.4).
//!
//! The paper's system-level results (Figs. 8–10, Table 4) are *derived* from
//! component measurements of a 5 nm prototype (Catapult HLS → Fusion
//! Compiler → PrimePower). We reproduce the derivation: [`energy`] carries
//! the published per-unit energies (NVFP4 = 0.67× FP8, mixed ≈ 0.84×/0.83×,
//! mux tax, 25.7 pJ/block PPU), [`area`] the Table-4 areas, [`datapath`] the
//! weight-stationary VMAC cycle model, [`ppu`] the post-processing
//! activation quantizer with its amortization analysis, [`memory`] the
//! Fig.-8 footprint accounting, [`kmeans`] the §4.3 K-means clustering of
//! per-layer precision-mix configurations, and [`layerprof`] the per-layer
//! profile plumbing.

pub mod area;
pub mod datapath;
pub mod energy;
pub mod kmeans;
pub mod kvcache;
pub mod layerprof;
pub mod memory;
pub mod ppu;
pub mod trace;

pub use datapath::{DatapathConfig, MatmulJob, simulate_matmul};
pub use energy::{DotUnit, EnergyModel};
pub use layerprof::LayerProfile;
pub use memory::weight_memory_report;
