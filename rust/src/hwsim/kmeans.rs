//! K-means clustering of per-layer precision-mix configurations
//! (paper §4.3): measuring power for every (layer × global-ratio) point is
//! intractable, so the paper normalizes each configuration's features,
//! clusters them into K=100 representatives, measures those, and scales the
//! results back up to the real layer shapes. We reproduce that pipeline.

/// One layer configuration: the features the paper clusters on.
#[derive(Debug, Clone, PartialEq)]
pub struct LayerConfig {
    /// Fraction of weight blocks in FP8.
    pub weight_fp8: f64,
    /// Fraction of activation blocks in FP8.
    pub act_fp8: f64,
}

impl LayerConfig {
    fn as_vec(&self) -> [f64; 2] {
        [self.weight_fp8, self.act_fp8]
    }
}

/// K-means result: centroids and per-point assignment.
#[derive(Debug, Clone)]
pub struct Clustering {
    pub centroids: Vec<LayerConfig>,
    pub assignment: Vec<usize>,
}

fn dist2(a: &[f64; 2], b: &[f64; 2]) -> f64 {
    (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2)
}

/// Deterministic k-means (k-means++-style farthest-point seeding with a
/// fixed LCG, Lloyd iterations to convergence or `max_iter`).
pub fn kmeans(points: &[LayerConfig], k: usize, max_iter: usize) -> Clustering {
    assert!(!points.is_empty());
    let k = k.min(points.len());
    let xs: Vec<[f64; 2]> = points.iter().map(|p| p.as_vec()).collect();

    // Farthest-point seeding from a deterministic start.
    let mut centers: Vec<[f64; 2]> = vec![xs[0]];
    while centers.len() < k {
        let (mut best_i, mut best_d) = (0usize, -1.0f64);
        for (i, x) in xs.iter().enumerate() {
            let d = centers.iter().map(|c| dist2(x, c)).fold(f64::MAX, f64::min);
            if d > best_d {
                best_d = d;
                best_i = i;
            }
        }
        centers.push(xs[best_i]);
    }

    let mut assignment = vec![0usize; xs.len()];
    for _ in 0..max_iter {
        let mut changed = false;
        for (i, x) in xs.iter().enumerate() {
            let (mut bj, mut bd) = (0usize, f64::MAX);
            for (j, c) in centers.iter().enumerate() {
                let d = dist2(x, c);
                if d < bd {
                    bd = d;
                    bj = j;
                }
            }
            if assignment[i] != bj {
                assignment[i] = bj;
                changed = true;
            }
        }
        let mut sums = vec![[0.0f64; 2]; k];
        let mut counts = vec![0usize; k];
        for (i, &a) in assignment.iter().enumerate() {
            sums[a][0] += xs[i][0];
            sums[a][1] += xs[i][1];
            counts[a] += 1;
        }
        for j in 0..k {
            if counts[j] > 0 {
                centers[j] = [sums[j][0] / counts[j] as f64, sums[j][1] / counts[j] as f64];
            }
        }
        if !changed {
            break;
        }
    }
    Clustering {
        centroids: centers
            .into_iter()
            .map(|c| LayerConfig { weight_fp8: c[0], act_fp8: c[1] })
            .collect(),
        assignment,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn grid_points() -> Vec<LayerConfig> {
        let mut v = Vec::new();
        for i in 0..20 {
            for j in 0..20 {
                v.push(LayerConfig { weight_fp8: i as f64 / 19.0, act_fp8: j as f64 / 19.0 });
            }
        }
        v
    }

    #[test]
    fn assignment_is_partition() {
        let pts = grid_points();
        let c = kmeans(&pts, 16, 50);
        assert_eq!(c.assignment.len(), pts.len());
        assert!(c.assignment.iter().all(|&a| a < c.centroids.len()));
        // every centroid used
        for j in 0..c.centroids.len() {
            assert!(c.assignment.iter().any(|&a| a == j), "unused centroid {j}");
        }
    }

    #[test]
    fn assignment_is_nearest() {
        let pts = grid_points();
        let c = kmeans(&pts, 8, 50);
        for (i, p) in pts.iter().enumerate() {
            let my = &c.centroids[c.assignment[i]];
            let my_d = dist2(&p.as_vec(), &my.as_vec());
            for cent in &c.centroids {
                assert!(my_d <= dist2(&p.as_vec(), &cent.as_vec()) + 1e-12);
            }
        }
    }

    #[test]
    fn k_larger_than_points_clamps() {
        let pts = vec![
            LayerConfig { weight_fp8: 0.1, act_fp8: 0.2 },
            LayerConfig { weight_fp8: 0.9, act_fp8: 0.8 },
        ];
        let c = kmeans(&pts, 100, 10);
        assert_eq!(c.centroids.len(), 2);
    }

    #[test]
    fn deterministic() {
        let pts = grid_points();
        let a = kmeans(&pts, 10, 50);
        let b = kmeans(&pts, 10, 50);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn centroid_error_small_with_many_clusters() {
        // With K=100 over the 20x20 grid, mean quantization error is tiny —
        // the paper's justification for measuring only 100 representatives.
        let pts = grid_points();
        let c = kmeans(&pts, 100, 100);
        let mean_err: f64 = pts
            .iter()
            .enumerate()
            .map(|(i, p)| dist2(&p.as_vec(), &c.centroids[c.assignment[i]].as_vec()).sqrt())
            .sum::<f64>()
            / pts.len() as f64;
        assert!(mean_err < 0.05, "mean centroid error {mean_err}");
    }
}
