//! Per-operation energy model of the FGMP VMAC datapath.
//!
//! Calibrated to the paper's published component measurements (§5.4.2,
//! Fig. 9): with single-format stimulus the NVFP4 unit consumes 33% less
//! energy than FP8, and the FP4×FP8 / FP8×FP4 units 16% / 17% less; the
//! fine-grained muxing between the four dot-product units adds a small
//! "tax" so mostly-FP8 mixed traffic costs slightly more than pure FP8.
//! The PPU costs 25.7 pJ per quantized output block.
//!
//! Energies are expressed per BS-wide VMAC (one block dot-product +
//! accumulate) in picojoules. The absolute FP8 anchor is set so that the
//! *ratios* — all the paper reports — are exact; absolute numbers are only
//! used to form relative comparisons and are labelled "model pJ".


/// Which dot-product unit a block-pair activates (paper Fig. 3b).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DotUnit {
    /// FP8 weights × FP8 activations.
    Fp8Fp8,
    /// FP4 weights × FP4 activations (both NVFP4, two scale multiplies).
    Fp4Fp4,
    /// FP4 weights × FP8 activations.
    Fp4Fp8,
    /// FP8 weights × FP4 activations.
    Fp8Fp4,
}

impl DotUnit {
    /// Select the active unit from the two metadata bits.
    #[inline]
    pub fn select(weight_fp8: bool, act_fp8: bool) -> Self {
        match (weight_fp8, act_fp8) {
            (true, true) => DotUnit::Fp8Fp8,
            (false, false) => DotUnit::Fp4Fp4,
            (false, true) => DotUnit::Fp4Fp8,
            (true, false) => DotUnit::Fp8Fp4,
        }
    }
}

/// Energy parameters (pJ per BS-wide VMAC, 5 nm @ 0.67 V TT, 1 GHz —
/// the paper's measurement corner).
#[derive(Debug, Clone)]
pub struct EnergyModel {
    /// FP8×FP8 VMAC energy (anchor).
    pub e_fp8: f64,
    /// NVFP4×NVFP4 VMAC energy (paper: 33% below FP8).
    pub e_fp4: f64,
    /// FP4-weight × FP8-act (paper: 16% below FP8).
    pub e_fp4w_fp8a: f64,
    /// FP8-weight × FP4-act (paper: 17% below FP8).
    pub e_fp8w_fp4a: f64,
    /// Per-VMAC overhead of the fine-grained unit muxing + clock/data
    /// gating (the paper's "small tax" that makes mostly-FP8 mixed stimulus
    /// slightly costlier than pure FP8).
    pub e_mux_tax: f64,
    /// PPU energy per quantized output block (paper: 25.7 pJ).
    pub e_ppu_block: f64,
    /// Weight-collector reload energy per block (weight-stationary reuse
    /// means this is paid once per tile row, not per VMAC).
    pub e_weight_load_block: f64,
    /// Activation broadcast energy per block per lane row.
    pub e_act_broadcast: f64,
    /// On-chip KV-cache traffic energy per bit moved (decode reads the
    /// whole cache every step; anchored to `e_weight_load_block` ≈ 0.9 pJ
    /// per 128-bit FP8 block).
    pub e_kv_bit: f64,
}

impl Default for EnergyModel {
    fn default() -> Self {
        // FP8 anchor chosen at 8.0 pJ per 16-wide VMAC so the published
        // ratios land exactly; see DESIGN.md §2 (substitution table).
        let e_fp8 = 8.0;
        EnergyModel {
            e_fp8,
            e_fp4: e_fp8 * (1.0 - 0.33),
            e_fp4w_fp8a: e_fp8 * (1.0 - 0.16),
            e_fp8w_fp4a: e_fp8 * (1.0 - 0.17),
            e_mux_tax: e_fp8 * 0.015,
            e_ppu_block: 25.7,
            e_weight_load_block: 0.9,
            e_act_broadcast: 0.35,
            e_kv_bit: 0.9 / 128.0,
        }
    }
}

impl EnergyModel {
    /// Energy of one BS-wide VMAC on the given unit, *excluding* the mux
    /// tax (single-format operation, the labelled points of Fig. 9).
    pub fn vmac_single(&self, unit: DotUnit) -> f64 {
        match unit {
            DotUnit::Fp8Fp8 => self.e_fp8,
            DotUnit::Fp4Fp4 => self.e_fp4,
            DotUnit::Fp4Fp8 => self.e_fp4w_fp8a,
            DotUnit::Fp8Fp4 => self.e_fp8w_fp4a,
        }
    }

    /// Energy of one BS-wide VMAC in FGMP mode (mux tax applied — the
    /// datapath must inspect both metadata bits every cycle).
    pub fn vmac_fgmp(&self, unit: DotUnit) -> f64 {
        self.vmac_single(unit) + self.e_mux_tax
    }

    /// Expected FGMP VMAC energy given independent FP8 probabilities for
    /// weights (`pw8`) and activations (`pa8`) — the Fig. 9 surface.
    pub fn vmac_expected(&self, pw8: f64, pa8: f64) -> f64 {
        let p88 = pw8 * pa8;
        let p44 = (1.0 - pw8) * (1.0 - pa8);
        let p48 = (1.0 - pw8) * pa8; // FP4 weight, FP8 act
        let p84 = pw8 * (1.0 - pa8);
        p88 * self.vmac_fgmp(DotUnit::Fp8Fp8)
            + p44 * self.vmac_fgmp(DotUnit::Fp4Fp4)
            + p48 * self.vmac_fgmp(DotUnit::Fp4Fp8)
            + p84 * self.vmac_fgmp(DotUnit::Fp8Fp4)
    }

    /// Energy per *op* (2·BS ops per VMAC), the Fig. 9 y-axis unit.
    pub fn per_op(&self, vmac_energy: f64) -> f64 {
        vmac_energy / (2.0 * crate::BLOCK as f64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn published_ratios() {
        let m = EnergyModel::default();
        assert!((m.vmac_single(DotUnit::Fp4Fp4) / m.vmac_single(DotUnit::Fp8Fp8) - 0.67).abs() < 1e-9);
        assert!((m.vmac_single(DotUnit::Fp4Fp8) / m.vmac_single(DotUnit::Fp8Fp8) - 0.84).abs() < 1e-9);
        assert!((m.vmac_single(DotUnit::Fp8Fp4) / m.vmac_single(DotUnit::Fp8Fp8) - 0.83).abs() < 1e-9);
    }

    #[test]
    fn mostly_fp8_mixed_costs_more_than_pure_fp8() {
        // The paper's observed mux tax: ~100% FP8 under FGMP control is
        // slightly above the single-format FP8 point.
        let m = EnergyModel::default();
        assert!(m.vmac_expected(1.0, 1.0) > m.vmac_single(DotUnit::Fp8Fp8));
    }

    #[test]
    fn mostly_fp4_saves_energy() {
        let m = EnergyModel::default();
        assert!(m.vmac_expected(0.1, 0.1) < m.vmac_single(DotUnit::Fp8Fp8) * 0.75);
    }

    #[test]
    fn expected_energy_monotone_in_fp8_fraction() {
        let m = EnergyModel::default();
        let mut last = 0.0;
        for i in 0..=10 {
            let p = i as f64 / 10.0;
            let e = m.vmac_expected(p, p);
            assert!(e >= last);
            last = e;
        }
    }

    #[test]
    fn unit_selection() {
        assert_eq!(DotUnit::select(true, true), DotUnit::Fp8Fp8);
        assert_eq!(DotUnit::select(false, false), DotUnit::Fp4Fp4);
        assert_eq!(DotUnit::select(false, true), DotUnit::Fp4Fp8);
        assert_eq!(DotUnit::select(true, false), DotUnit::Fp8Fp4);
    }

    #[test]
    fn per_op_amortizes_block() {
        let m = EnergyModel::default();
        assert!((m.per_op(m.e_fp8) - 8.0 / 32.0).abs() < 1e-12);
    }
}
