//! Per-session KV cache for incremental decoding, backed either by owned
//! flat buffers or by a shared **paged arena** ([`KvPool`]).
//!
//! [`KvState`] holds one transformer session's cached keys and values: one
//! [`LayerKv`] per block, each an append-only `(tokens, d_model)` buffer of
//! post-RoPE keys and raw values (heads side by side, the layout
//! `model::forward` gathers per-head panels from). The buffers are
//! precision-aware: [`KvPrecision::Fp16`] stores exact f32 rows (standing in
//! for the paper's BF16 KV baseline), while [`KvPrecision::Fp8`] stores each
//! element as a real E4M3 byte via the [`crate::quant::fp8`] codec — half
//! the memory, mirroring the quantized-cache comparators the paper's Fig. 1
//! footnote discusses — and decodes on read, so decode steps attend over
//! exactly the values a byte-packed accelerator cache would hold.
//!
//! Storage comes in two shapes:
//!
//!  * **flat** ([`KvState::new`]) — each buffer owns a contiguous `Vec`,
//!    the PR 3 layout. Still the default for standalone `forward_*` use.
//!  * **paged** ([`KvState::new_paged`]) — buffers hold *page tables* into
//!    a shared [`KvPool`]: fixed-size pages of [`PAGE_TOKENS`] rows handed
//!    out from a free list. Admission cost and footprint are proportional
//!    to pages actually used (never the max window), pages return to the
//!    free list on retirement/clear/drop, and running out surfaces as the
//!    typed [`KvPoolExhausted`] backpressure error *before* any compute.
//!    Reads gather pages into a caller-provided scratch via the
//!    gather kernels in [`crate::util::kernels`] (decode-on-read for FP8).
//!
//! Pages are **refcounted and copy-on-write**: [`KvState::fork`] (the
//! speculative-decode draft primitive), paged [`Clone`], and prefix
//! mapping ([`KvState::map_prefix`]) all share pages by reference —
//! page-table copies + refcount bumps, O(page-table) — and the cache is
//! append-only, so the *only* write that can touch a shared page is an
//! append into a partially-filled shared tail. [`KvState::reserve`], which
//! precedes every append, unshares exactly that page (payload cloned onto
//! a fresh page) in the same all-or-nothing grab as its reservation.
//! [`KvPoolStats`] tracks logical vs unique pages; their ratio is the
//! pool's **sharing factor**, and exhaustion is charged on unique pages
//! only.
//!
//! With `Fp16` the cached rows are bit-identical to what the full-sequence
//! forward computes internally — flat or paged, since the gather is a pure
//! copy — which is what makes the prefill+step path bit-exact against full
//! recompute (property-tested in `tests/decode_props.rs`). With `Fp8` the
//! divergence is bounded by the E4M3 round-trip error on K/V (documented
//! tolerance in the same test).

use std::sync::{Arc, Mutex, MutexGuard};

use crate::model::forward::ModelArch;
use crate::quant::fp8::encode_e4m3;
use crate::util::kernels;

/// Effective stored bits per value of an FGMP-mixed block population:
/// FP8 blocks hold 8 bits/value, NVFP4 blocks 4.5625 (16×4-bit mantissas +
/// one 8-bit E4M3 scale + one precision flag bit per 16-element block) —
/// the same convention `hwsim::kvcache` documents for quantized-cache
/// comparators.
pub const FP8_BITS_PER_VALUE: f64 = 8.0;
pub const NVFP4_BITS_PER_VALUE: f64 = 4.5625;

/// Rows (tokens) per KV page — the granularity the paged arena allocates
/// and the unit precision/occupancy accounting works in. 16 matches the
/// FGMP quantization block size, so a page is also a whole number of
/// precision blocks for any future block-granular KV policy.
pub const PAGE_TOKENS: usize = 16;

/// Storage precision of a session's KV cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPrecision {
    /// Exact f32 rows (models the BF16/FP16 cache of the paper's setup).
    Fp16,
    /// E4M3 bytes per element — 8 bits/value, decoded on read.
    Fp8,
}

impl KvPrecision {
    /// Bits per cached value, the number `hwsim::kvcache::kv_cache_bits`
    /// charges for cache traffic and capacity at this precision.
    pub fn bits_per_value(&self) -> f64 {
        match self {
            KvPrecision::Fp16 => 16.0,
            KvPrecision::Fp8 => 8.0,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            KvPrecision::Fp16 => "fp16",
            KvPrecision::Fp8 => "fp8",
        }
    }

    /// Parse a CLI knob value ("fp16"/"bf16" or "fp8").
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "fp16" | "bf16" | "f32" => Ok(KvPrecision::Fp16),
            "fp8" | "e4m3" => Ok(KvPrecision::Fp8),
            other => anyhow::bail!("unknown KV precision '{other}' (have fp16, fp8)"),
        }
    }
}

// ---------------------------------------------------------------------------
// The shared page pool
// ---------------------------------------------------------------------------

/// Typed admission-backpressure error: a page reservation could not be
/// satisfied. Carried as the source of the `anyhow` error the forward/
/// engine paths return, so callers (the coordinator's admission loop)
/// recover it with `err.downcast_ref::<KvPoolExhausted>()` and defer the
/// request instead of failing it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvPoolExhausted {
    /// Pages the reservation asked for.
    pub requested: usize,
    /// Pages that were free at that moment.
    pub free: usize,
}

impl std::fmt::Display for KvPoolExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV page pool exhausted: requested {} page(s), {} free — \
             defer admission or grow --kv-pages",
            self.requested, self.free
        )
    }
}

impl std::error::Error for KvPoolExhausted {}

/// Point-in-time pool accounting (occupancy / fragmentation inputs).
#[derive(Debug, Clone, Copy, Default)]
pub struct KvPoolStats {
    pub total_pages: usize,
    pub free_pages: usize,
    /// **Unique** pages handed out (total − free): what physical capacity
    /// and exhaustion are measured against.
    pub in_use_pages: usize,
    /// **Logical** pages across every holder — Σ refcounts. With prefix
    /// sharing / copy-on-write forks this exceeds `in_use_pages`; the gap
    /// is the deduplicated storage.
    pub logical_pages: usize,
    /// High-water mark of `in_use_pages` over the pool's lifetime.
    pub peak_in_use: usize,
    pub page_tokens: usize,
    /// Bytes one page occupies in the arena (`PAGE_TOKENS × width ×
    /// element size`) — the unit `deduped_bytes` is priced in.
    pub page_bytes: usize,
    /// Failed reservations (each one a typed backpressure event).
    pub exhausted_events: u64,
    /// Copy-on-write page copies performed (a shared page diverged).
    pub cow_copies: u64,
}

impl KvPoolStats {
    /// Fraction of the pool currently handed out (unique pages).
    pub fn occupancy(&self) -> f64 {
        if self.total_pages == 0 {
            0.0
        } else {
            self.in_use_pages as f64 / self.total_pages as f64
        }
    }

    /// Logical pages per unique page — how many sessions each stored page
    /// serves on average (1.0 when nothing is shared or the pool is idle).
    pub fn sharing_factor(&self) -> f64 {
        if self.in_use_pages == 0 {
            1.0
        } else {
            self.logical_pages as f64 / self.in_use_pages as f64
        }
    }

    /// Arena bytes sharing saved right now: what the logical pages would
    /// occupy minus what the unique pages actually do.
    pub fn deduped_bytes(&self) -> u64 {
        (self.logical_pages.saturating_sub(self.in_use_pages) * self.page_bytes) as u64
    }
}

struct PoolInner {
    /// FP16 arena: `total_pages × PAGE_TOKENS × width` f32s (empty for FP8).
    f32_data: Vec<f32>,
    /// FP8 arena: one E4M3 byte per element (empty for FP16).
    u8_data: Vec<u8>,
    /// Free page ids, popped LIFO (hot pages get reused first).
    free: Vec<u32>,
    /// Per-page reference counts: 0 = free, 1 = uniquely owned, > 1 =
    /// shared (a prefix mapping or a copy-on-write fork). Shared pages are
    /// immutable until [`KvPool::cow_alloc`] unshares them.
    rc: Vec<u32>,
    /// Σ rc — logical pages across every holder.
    logical: usize,
    peak_in_use: usize,
    exhausted_events: u64,
    cow_copies: u64,
}

/// A shared, fixed-capacity KV page arena. One pool serves every session of
/// an engine: all buffers (K and V, every layer) share the same row width
/// (`d_model`), so pages are uniform and any buffer can use any page. The
/// pool hands out pages all-or-nothing per reservation and takes them back
/// on clear/drop; storage is allocated eagerly at construction so serving
/// capacity is a startup decision, not a decode-time reallocation.
pub struct KvPool {
    inner: Mutex<PoolInner>,
    precision: KvPrecision,
    width: usize,
    total_pages: usize,
}

impl std::fmt::Debug for KvPool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let s = self.stats();
        f.debug_struct("KvPool")
            .field("precision", &self.precision)
            .field("width", &self.width)
            .field("total_pages", &self.total_pages)
            .field("free_pages", &s.free_pages)
            .finish()
    }
}

impl KvPool {
    /// Build a pool of `pages` pages for `arch`-shaped caches at
    /// `precision`. Pages are `PAGE_TOKENS × d_model` values each.
    pub fn new(arch: &ModelArch, precision: KvPrecision, pages: usize) -> Arc<KvPool> {
        let elems = pages * PAGE_TOKENS * arch.d_model;
        let (f32_data, u8_data) = match precision {
            KvPrecision::Fp16 => (vec![0.0f32; elems], Vec::new()),
            KvPrecision::Fp8 => (Vec::new(), vec![0u8; elems]),
        };
        // LIFO pop order: page 0 first.
        let free: Vec<u32> = (0..pages as u32).rev().collect();
        Arc::new(KvPool {
            inner: Mutex::new(PoolInner {
                f32_data,
                u8_data,
                free,
                rc: vec![0; pages],
                logical: 0,
                peak_in_use: 0,
                exhausted_events: 0,
                cow_copies: 0,
            }),
            precision,
            width: arch.d_model,
            total_pages: pages,
        })
    }

    /// Pages one K-or-V buffer needs to hold `tokens` rows.
    pub fn pages_for_tokens(tokens: usize) -> usize {
        tokens.div_ceil(PAGE_TOKENS)
    }

    /// Pages a whole session (K+V, every layer) holding `tokens` needs.
    pub fn pages_for_session(n_layers: usize, tokens: usize) -> usize {
        2 * n_layers * Self::pages_for_tokens(tokens)
    }

    pub fn precision(&self) -> KvPrecision {
        self.precision
    }

    pub fn width(&self) -> usize {
        self.width
    }

    pub fn total_pages(&self) -> usize {
        self.total_pages
    }

    pub fn free_pages(&self) -> usize {
        self.inner.lock().unwrap().free.len()
    }

    pub fn stats(&self) -> KvPoolStats {
        let g = self.inner.lock().unwrap();
        let elem_bytes = match self.precision {
            KvPrecision::Fp16 => std::mem::size_of::<f32>(),
            KvPrecision::Fp8 => std::mem::size_of::<u8>(),
        };
        KvPoolStats {
            total_pages: self.total_pages,
            free_pages: g.free.len(),
            in_use_pages: self.total_pages - g.free.len(),
            logical_pages: g.logical,
            peak_in_use: g.peak_in_use,
            page_tokens: PAGE_TOKENS,
            page_bytes: PAGE_TOKENS * self.width * elem_bytes,
            exhausted_events: g.exhausted_events,
            cow_copies: g.cow_copies,
        }
    }

    /// Grab `n` pages, all-or-nothing. On failure the pool is untouched
    /// apart from the exhaustion counter. Each handed-out page starts at
    /// refcount 1.
    fn alloc(&self, n: usize) -> Result<Vec<u32>, KvPoolExhausted> {
        self.cow_alloc(&mut [], n)
    }

    /// Bump the refcount of each page — a new holder now shares it. The
    /// caller must already hold a reference to every page (sharing is
    /// always seeded from a live page table), so this cannot fail.
    /// `pub(crate)` for the prefix index (`runtime::prefix`), which holds
    /// strong page references of its own.
    pub(crate) fn retain(&self, pages: &[u32]) {
        if pages.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for &p in pages {
            debug_assert!(g.rc[p as usize] > 0, "retain of a free KV page");
            g.rc[p as usize] += 1;
        }
        g.logical += pages.len();
    }

    /// Drop one reference per page; pages reaching refcount 0 return to
    /// the free list.
    pub(crate) fn release(&self, pages: &[u32]) {
        if pages.is_empty() {
            return;
        }
        let mut g = self.inner.lock().unwrap();
        for &p in pages {
            let rc = &mut g.rc[p as usize];
            debug_assert!(*rc > 0, "double free into KV pool (page {p})");
            *rc -= 1;
            if *rc == 0 {
                g.free.push(p);
            }
        }
        g.logical -= pages.len().min(g.logical);
        debug_assert!(g.free.len() <= self.total_pages);
    }

    /// The copy-on-write hook + reservation, one all-or-nothing grab:
    /// every id in `tail` that is currently **shared** (rc > 1) is cloned
    /// onto a fresh page — arena payload copied at the pool's precision,
    /// the caller's table entry rewritten in place, one reference moved
    /// from the old page to the new — and `extra` additional fresh pages
    /// are handed out, all under one lock. If the free list cannot cover
    /// the divergence copies *plus* the extra pages, nothing changes and
    /// the typed backpressure error reports the combined demand. Pages
    /// already unique pass through untouched, which is what makes
    /// append-after-fork O(1) in the common unshared case.
    fn cow_alloc(&self, tail: &mut [u32], extra: usize) -> Result<Vec<u32>, KvPoolExhausted> {
        let mut g = self.inner.lock().unwrap();
        let shared = tail.iter().filter(|&&p| g.rc[p as usize] > 1).count();
        let need = extra + shared;
        // Every reservation funnels through here (`alloc` delegates), so
        // this one failpoint injects pool exhaustion for the whole arena:
        // same typed error, same all-or-nothing books as the real thing.
        let injected = g.free.len() >= need
            && need > 0
            && crate::util::faults::should_fail(crate::util::faults::KV_ALLOC);
        if g.free.len() < need || injected {
            g.exhausted_events += 1;
            return Err(KvPoolExhausted { requested: need, free: g.free.len() });
        }
        let pe = PAGE_TOKENS * self.width;
        for t in tail.iter_mut() {
            let old = *t as usize;
            if g.rc[old] > 1 {
                let fresh = g.free.pop().expect("counted above") as usize;
                match self.precision {
                    KvPrecision::Fp16 => {
                        g.f32_data.copy_within(old * pe..(old + 1) * pe, fresh * pe)
                    }
                    KvPrecision::Fp8 => {
                        g.u8_data.copy_within(old * pe..(old + 1) * pe, fresh * pe)
                    }
                }
                g.rc[old] -= 1;
                g.rc[fresh] = 1;
                g.cow_copies += 1;
                *t = fresh as u32;
            }
        }
        let at = g.free.len() - extra;
        let out = g.free.split_off(at);
        for &p in &out {
            g.rc[p as usize] = 1;
        }
        g.logical += extra; // a COW copy moves a reference; net logical 0
        let in_use = self.total_pages - g.free.len();
        g.peak_in_use = g.peak_in_use.max(in_use);
        Ok(out)
    }
}

// ---------------------------------------------------------------------------
// Per-buffer storage
// ---------------------------------------------------------------------------

/// Page-table storage of one K-or-V buffer: which pool pages hold its rows.
struct PagedStore {
    pool: Arc<KvPool>,
    pages: Vec<u32>,
    /// Live rows (tokens); `pages` may run ahead after a reservation.
    rows: usize,
}

impl PagedStore {
    fn release_all(&mut self) {
        self.pool.release(&self.pages);
        self.pages.clear();
        self.rows = 0;
    }

    /// `(arena base, element count)` of each page holding live rows, in
    /// token order (the last span may be a partial page). The one
    /// definition of the page walk shared by materialize and Clone.
    fn live_spans(&self, width: usize) -> Vec<(usize, usize)> {
        let pe = PAGE_TOKENS * width;
        let live = self.rows * width;
        let mut taken = 0usize;
        self.pages[..KvPool::pages_for_tokens(self.rows)]
            .iter()
            .map(|&pg| {
                let take = (live - taken).min(pe);
                taken += take;
                (pg as usize * pe, take)
            })
            .collect()
    }
}

impl Drop for PagedStore {
    fn drop(&mut self) {
        self.release_all();
    }
}

impl std::fmt::Debug for PagedStore {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("PagedStore")
            .field("rows", &self.rows)
            .field("pages", &self.pages.len())
            .finish()
    }
}

/// One append-only `(rows, width)` tensor at the cache precision.
#[derive(Debug)]
enum KvData {
    F32(Vec<f32>),
    Fp8(Vec<u8>),
    Paged(PagedStore),
}

/// A precision-aware K or V buffer for one layer.
#[derive(Debug)]
pub struct KvBuf {
    data: KvData,
    width: usize,
    /// Attention-PPU accounting: 16-element blocks the PPU kept at FP8
    /// out of all blocks it assigned while filling this buffer. Both stay
    /// zero when the attention threshold knob is off; aggregate counters
    /// (not per-row maps) because only the effective-bits ratio feeds the
    /// energy model.
    ppu_hi_blocks: u64,
    ppu_blocks: u64,
}

impl Clone for KvBuf {
    /// Flat buffers clone plainly. Cloning a *paged* buffer **shares** its
    /// live pages — a page-table copy plus refcount bumps, O(page-table),
    /// never a payload copy and never fallible (fixing the PR 4 snapshot-
    /// to-flat debt). The clone stays paged with identical bytes; writes
    /// on either side diverge through the copy-on-write hook in
    /// [`KvState::reserve`]. Reservation slack beyond the live rows is
    /// not inherited (same rule as [`KvState::fork`]), which keeps slack
    /// pages uniquely owned by their reserver.
    fn clone(&self) -> Self {
        match &self.data {
            KvData::F32(v) => KvBuf {
                data: KvData::F32(v.clone()),
                width: self.width,
                ppu_hi_blocks: self.ppu_hi_blocks,
                ppu_blocks: self.ppu_blocks,
            },
            KvData::Fp8(v) => KvBuf {
                data: KvData::Fp8(v.clone()),
                width: self.width,
                ppu_hi_blocks: self.ppu_hi_blocks,
                ppu_blocks: self.ppu_blocks,
            },
            KvData::Paged(_) => self.share_paged(),
        }
    }
}

impl KvBuf {
    fn new(prec: KvPrecision, width: usize) -> Self {
        let data = match prec {
            KvPrecision::Fp16 => KvData::F32(Vec::new()),
            KvPrecision::Fp8 => KvData::Fp8(Vec::new()),
        };
        KvBuf { data, width, ppu_hi_blocks: 0, ppu_blocks: 0 }
    }

    fn new_paged(pool: &Arc<KvPool>) -> Self {
        KvBuf {
            data: KvData::Paged(PagedStore { pool: pool.clone(), pages: Vec::new(), rows: 0 }),
            width: pool.width,
            ppu_hi_blocks: 0,
            ppu_blocks: 0,
        }
    }

    /// Cached rows (tokens).
    pub fn rows(&self) -> usize {
        match &self.data {
            KvData::F32(v) => v.len() / self.width,
            KvData::Fp8(v) => v.len() / self.width,
            KvData::Paged(p) => p.rows,
        }
    }

    /// Pages held (0 for flat buffers).
    pub fn pages(&self) -> usize {
        match &self.data {
            KvData::Paged(p) => p.pages.len(),
            _ => 0,
        }
    }

    /// The first `n` page ids of a paged buffer's table — what the prefix
    /// index records (and retains) after a prefill. Panics on flat buffers
    /// or `n` beyond the table.
    pub(crate) fn page_ids(&self, n: usize) -> &[u32] {
        match &self.data {
            KvData::Paged(p) => &p.pages[..n],
            _ => unreachable!("page_ids on a flat buffer"),
        }
    }

    /// Append one `width`-wide row, quantizing to the cache precision.
    /// Paged buffers write into pages reserved beforehand via
    /// [`KvState::reserve`]; pushing past the reservation is a logic error.
    /// The paged write takes the (engine-private, uncontended) pool lock
    /// once per row — cheap next to the `width`-float copy/encode; batch
    /// the lock per append span if engines ever share a pool across
    /// threads.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        match &mut self.data {
            KvData::F32(v) => v.extend_from_slice(row),
            KvData::Fp8(v) => v.extend(row.iter().map(|&x| encode_e4m3(x))),
            KvData::Paged(p) => {
                let page_idx = p.rows / PAGE_TOKENS;
                assert!(
                    page_idx < p.pages.len(),
                    "KV push_row past reservation (row {}, {} pages) — \
                     KvState::reserve must precede appends",
                    p.rows,
                    p.pages.len()
                );
                let pe = PAGE_TOKENS * self.width;
                let off = p.pages[page_idx] as usize * pe + (p.rows % PAGE_TOKENS) * self.width;
                let mut g = p.pool.inner.lock().unwrap();
                debug_assert_eq!(
                    g.rc[p.pages[page_idx] as usize],
                    1,
                    "write into a shared KV page — KvState::reserve's copy-on-write \
                     hook must unshare the tail before appends"
                );
                match p.pool.precision {
                    KvPrecision::Fp16 => {
                        g.f32_data[off..off + self.width].copy_from_slice(row);
                    }
                    KvPrecision::Fp8 => {
                        for (o, &x) in g.u8_data[off..off + self.width].iter_mut().zip(row) {
                            *o = encode_e4m3(x);
                        }
                    }
                }
                p.rows += 1;
            }
        }
    }

    /// Borrow the whole buffer as f32 rows. The flat FP16 cache is returned
    /// in place; the flat FP8 cache is decoded into `scratch`; paged caches
    /// gather their pages into `scratch` through the kernels in
    /// [`crate::util::kernels`] (a pure copy for FP16 — identical bits —
    /// and the table-lookup dequant for FP8). `scratch` is resized as
    /// needed and its capacity is reusable across calls.
    pub fn materialize<'a>(&'a self, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match &self.data {
            KvData::F32(v) => v,
            KvData::Fp8(v) => {
                // One contiguous "page" through the same LUT gather as the
                // paged path (no per-byte branchy decode).
                kernels::gather_e4m3_pages(&[v.as_slice()], scratch);
                scratch
            }
            KvData::Paged(p) => {
                let spans = p.live_spans(self.width);
                let g = p.pool.inner.lock().unwrap();
                match p.pool.precision {
                    KvPrecision::Fp16 => {
                        let views: Vec<&[f32]> =
                            spans.iter().map(|&(b, t)| &g.f32_data[b..b + t]).collect();
                        kernels::gather_f32_pages(&views, scratch);
                    }
                    KvPrecision::Fp8 => {
                        let views: Vec<&[u8]> =
                            spans.iter().map(|&(b, t)| &g.u8_data[b..b + t]).collect();
                        kernels::gather_e4m3_pages(&views, scratch);
                    }
                }
                scratch
            }
        }
    }

    /// Physical bits held for live tokens (excluding Vec capacity slack and
    /// page-tail slack — pool occupancy accounts for whole pages).
    pub fn stored_bits(&self) -> u64 {
        match &self.data {
            KvData::F32(v) => 32 * v.len() as u64,
            KvData::Fp8(v) => 8 * v.len() as u64,
            KvData::Paged(p) => {
                // Same physical accounting as the flat stores: f32 rows for
                // the FP16 arena, one byte per value for FP8.
                let values = (p.rows * self.width) as u64;
                match p.pool.precision {
                    KvPrecision::Fp16 => 32 * values,
                    KvPrecision::Fp8 => 8 * values,
                }
            }
        }
    }

    /// Record an attention-PPU block assignment made while quantizing rows
    /// pushed into this buffer: `hi` of `total` 16-element blocks were kept
    /// at FP8 (the rest went NVFP4).
    pub fn note_ppu(&mut self, hi: usize, total: usize) {
        self.ppu_hi_blocks += hi as u64;
        self.ppu_blocks += total as u64;
    }

    /// `(fp8_blocks, total_blocks)` the attention PPU assigned into this
    /// buffer — `(0, 0)` when the knob is off.
    pub fn ppu_counts(&self) -> (u64, u64) {
        (self.ppu_hi_blocks, self.ppu_blocks)
    }

    fn clear(&mut self) {
        match &mut self.data {
            KvData::F32(v) => v.clear(),
            KvData::Fp8(v) => v.clear(),
            KvData::Paged(p) => p.release_all(),
        }
        self.ppu_hi_blocks = 0;
        self.ppu_blocks = 0;
    }

    /// Share a *paged* buffer's live pages into a new buffer: page-table
    /// copy + refcount bump, no payload copies. Reservation slack is not
    /// inherited. This is the O(page-table) primitive behind paged
    /// [`Clone`], [`KvState::fork`], and prefix mapping.
    fn share_paged(&self) -> KvBuf {
        let p = match &self.data {
            KvData::Paged(p) => p,
            _ => unreachable!("share_paged on a flat buffer"),
        };
        let pages = p.pages[..KvPool::pages_for_tokens(p.rows)].to_vec();
        p.pool.retain(&pages);
        KvBuf {
            data: KvData::Paged(PagedStore { pool: p.pool.clone(), pages, rows: p.rows }),
            width: self.width,
            ppu_hi_blocks: self.ppu_hi_blocks,
            ppu_blocks: self.ppu_blocks,
        }
    }

    /// Fork a *paged* buffer onto freshly-allocated pages of the same pool:
    /// the caller hands in exactly `pages_for_tokens(rows)` page ids (from
    /// one grouped all-or-nothing grab) and the live spans are byte-copied
    /// arena-to-arena under the pool lock. This is the pre-COW deep fork,
    /// kept as the [`KvState::fork_copy`] bench baseline the
    /// `speedup_fork_cow_d512` gate measures the refcounted fork against.
    fn fork_paged(&self, pool: &Arc<KvPool>, pages: Vec<u32>) -> KvBuf {
        let (src_spans, rows) = match &self.data {
            KvData::Paged(p) => {
                debug_assert!(std::ptr::eq(Arc::as_ptr(&p.pool), Arc::as_ptr(pool)));
                (p.live_spans(self.width), p.rows)
            }
            _ => unreachable!("fork_paged on a flat buffer"),
        };
        debug_assert_eq!(pages.len(), KvPool::pages_for_tokens(rows));
        let dst = PagedStore { pool: pool.clone(), pages, rows };
        let dst_spans = dst.live_spans(self.width);
        let mut g = pool.inner.lock().unwrap();
        for (&(sb, st), &(db, dt)) in src_spans.iter().zip(&dst_spans) {
            debug_assert_eq!(st, dt, "fork spans walk the same page grid");
            // Freshly-allocated destination pages are disjoint from the
            // source's, so copy_within never overlaps.
            match pool.precision {
                KvPrecision::Fp16 => g.f32_data.copy_within(sb..sb + st, db),
                KvPrecision::Fp8 => g.u8_data.copy_within(sb..sb + st, db),
            }
        }
        KvBuf {
            data: KvData::Paged(dst),
            width: self.width,
            ppu_hi_blocks: self.ppu_hi_blocks,
            ppu_blocks: self.ppu_blocks,
        }
    }

    fn truncate_rows(&mut self, len: usize) {
        let before = self.rows();
        match &mut self.data {
            KvData::F32(v) => v.truncate(len * self.width),
            KvData::Fp8(v) => v.truncate(len * self.width),
            KvData::Paged(p) => {
                if len < p.rows {
                    p.rows = len;
                }
                let keep = KvPool::pages_for_tokens(p.rows);
                if keep < p.pages.len() {
                    let extra = p.pages.split_off(keep);
                    p.pool.release(&extra);
                }
            }
        }
        // Counters are aggregate, not per-row, so truncation scales them
        // proportionally — an approximation that is exact when block mix is
        // uniform across rows. Truncation only serves bench rollback and
        // failed-step unwind, never the accounting-bearing serve path.
        let after = self.rows();
        if after < before && self.ppu_blocks > 0 {
            let scale = after as f64 / before as f64;
            self.ppu_hi_blocks = (self.ppu_hi_blocks as f64 * scale).round() as u64;
            self.ppu_blocks = (self.ppu_blocks as f64 * scale).round() as u64;
        }
    }
}

// ---------------------------------------------------------------------------
// Zero-copy read views (attention at stored precision)
// ---------------------------------------------------------------------------

/// A borrowed, page-granular view of one K-or-V buffer **at its stored
/// precision**: f32 spans for FP16 caches, raw E4M3 byte spans for FP8.
/// Spans arrive in token order; the last may be a partial page. Flat
/// buffers view as a single span. This is what the attend kernels in
/// [`crate::util::kernels`] consume directly — no materialize scratch.
#[derive(Debug)]
pub enum KvView<'a> {
    F32 { pages: Vec<&'a [f32]> },
    Fp8 { pages: Vec<&'a [u8]> },
}

/// Read guards over every distinct [`KvPool`] a set of buffers lives on,
/// acquired once up front so per-page views borrow straight from the arena.
/// Holds raw pool pointers for identity only (never dereferenced); the
/// `Arc`s in the buffers keep the pools alive for `'p`.
pub struct PoolReadLock<'p> {
    guards: Vec<(*const KvPool, MutexGuard<'p, PoolInner>)>,
}

impl<'p> PoolReadLock<'p> {
    fn inner_for(&self, pool: *const KvPool) -> &PoolInner {
        self.guards
            .iter()
            .find(|(p, _)| *p == pool)
            .map(|(_, g)| &**g)
            .expect("KvBuf::view: buffer's pool not covered by this PoolReadLock")
    }
}

/// Lock every distinct pool behind `bufs` (deduplicated by pool identity —
/// the pool mutex is not reentrant, so each is taken exactly once). Flat
/// buffers need no lock and contribute nothing. Acquire this *after* all
/// appends for the step are done, then build [`KvBuf::view`]s against it;
/// the guard stays on the calling thread while the views (plain slices,
/// `Sync`) fan out across the attention heads.
pub fn lock_pools<'p, I>(bufs: I) -> PoolReadLock<'p>
where
    I: IntoIterator<Item = &'p KvBuf>,
{
    let mut guards: Vec<(*const KvPool, MutexGuard<'p, PoolInner>)> = Vec::new();
    for buf in bufs {
        if let KvData::Paged(p) = &buf.data {
            let ptr = Arc::as_ptr(&p.pool);
            if !guards.iter().any(|(q, _)| *q == ptr) {
                guards.push((ptr, p.pool.inner.lock().unwrap()));
            }
        }
    }
    PoolReadLock { guards }
}

impl KvBuf {
    /// Borrow this buffer's live rows at stored precision. Flat buffers
    /// return a single-span view of their own storage; paged buffers slice
    /// the pool arena through `lock` (which must have been built over a set
    /// of buffers including this one).
    pub fn view<'a>(&'a self, lock: &'a PoolReadLock<'_>) -> KvView<'a> {
        match &self.data {
            KvData::F32(v) => KvView::F32 { pages: vec![v.as_slice()] },
            KvData::Fp8(v) => KvView::Fp8 { pages: vec![v.as_slice()] },
            KvData::Paged(p) => {
                let spans = p.live_spans(self.width);
                let inner = lock.inner_for(Arc::as_ptr(&p.pool));
                match p.pool.precision {
                    KvPrecision::Fp16 => KvView::F32 {
                        pages: spans.iter().map(|&(b, t)| &inner.f32_data[b..b + t]).collect(),
                    },
                    KvPrecision::Fp8 => KvView::Fp8 {
                        pages: spans.iter().map(|&(b, t)| &inner.u8_data[b..b + t]).collect(),
                    },
                }
            }
        }
    }
}

/// One layer's cached keys and values.
#[derive(Debug, Clone)]
pub struct LayerKv {
    /// Post-RoPE keys, `(tokens, d_model)` row-major, heads side by side.
    pub k: KvBuf,
    /// Values, same layout.
    pub v: KvBuf,
}

/// A full per-session cache: one [`LayerKv`] per transformer block.
#[derive(Debug, Clone)]
pub struct KvState {
    pub layers: Vec<LayerKv>,
    pub precision: KvPrecision,
    /// Tokens currently cached (identical across layers).
    len: usize,
}

impl KvState {
    /// Flat (owned-buffer) cache — the PR 3 layout.
    pub fn new(arch: &ModelArch, precision: KvPrecision) -> Self {
        let layers = (0..arch.n_layers)
            .map(|_| LayerKv {
                k: KvBuf::new(precision, arch.d_model),
                v: KvBuf::new(precision, arch.d_model),
            })
            .collect();
        KvState { layers, precision, len: 0 }
    }

    /// Paged cache over a shared pool. Allocates **zero** pages up front —
    /// admission cost is deferred to [`KvState::reserve`], which sizes by
    /// tokens actually arriving, never by `max_seq`.
    pub fn new_paged(arch: &ModelArch, pool: &Arc<KvPool>) -> Self {
        assert_eq!(pool.width, arch.d_model, "KV pool width must match d_model");
        let layers = (0..arch.n_layers)
            .map(|_| LayerKv { k: KvBuf::new_paged(pool), v: KvBuf::new_paged(pool) })
            .collect();
        KvState { layers, precision: pool.precision, len: 0 }
    }

    /// Whether this cache lives on a shared page pool.
    pub fn is_paged(&self) -> bool {
        self.layers
            .first()
            .is_some_and(|l| matches!(l.k.data, KvData::Paged(_)))
    }

    /// Pages currently held across every layer's K and V (0 when flat).
    pub fn kv_pages(&self) -> usize {
        self.layers.iter().map(|l| l.k.pages() + l.v.pages()).sum()
    }

    /// Ensure capacity for `additional` more tokens in every buffer. Flat
    /// caches always succeed (Vecs grow). Paged caches reserve the missing
    /// pages from the pool in a single all-or-nothing grab — and, because
    /// every append lands here first, this is also the **copy-on-write
    /// seam**: a partially-filled tail page still shared with a fork,
    /// clone, or prefix mapping is unshared (payload cloned onto a fresh
    /// page) in the same grab, so [`KvBuf::push_row`] only ever writes
    /// uniquely-owned pages. On [`KvPoolExhausted`] nothing observable
    /// changed and no compute was spent — the typed error is the
    /// admission-backpressure signal, now covering divergence copies too.
    pub fn reserve(&mut self, additional: usize) -> Result<(), KvPoolExhausted> {
        if additional == 0 || !self.is_paged() {
            return Ok(());
        }
        let need = KvPool::pages_for_tokens(self.len + additional);
        // All buffers advance in lockstep, so they hold identical tables.
        let have = self.layers[0].k.pages();
        let delta = need.saturating_sub(have);
        // The page the next append writes into: only a partially-filled
        // tail can hold rows another holder still reads — full pages are
        // never rewritten (append-only), and fresh pages start unique.
        let tail_idx = (self.len % PAGE_TOKENS != 0).then(|| self.len / PAGE_TOKENS);
        if delta == 0 && tail_idx.is_none() {
            return Ok(());
        }
        let pool = match &self.layers[0].k.data {
            KvData::Paged(p) => p.pool.clone(),
            _ => unreachable!("is_paged checked above"),
        };
        let mut tail: Vec<u32> = Vec::new();
        if let Some(idx) = tail_idx {
            for l in &self.layers {
                for buf in [&l.k, &l.v] {
                    match &buf.data {
                        KvData::Paged(p) => tail.push(p.pages[idx]),
                        _ => unreachable!("paged state mixes storage kinds"),
                    }
                }
            }
        }
        let total = delta * 2 * self.layers.len();
        let mut grabbed = pool.cow_alloc(&mut tail, total)?;
        // Write back any tail ids the COW hook swapped for fresh pages.
        if let Some(idx) = tail_idx {
            let mut t = tail.iter();
            for l in &mut self.layers {
                for buf in [&mut l.k, &mut l.v] {
                    match &mut buf.data {
                        KvData::Paged(p) => p.pages[idx] = *t.next().expect("tail per buffer"),
                        _ => unreachable!("paged state mixes storage kinds"),
                    }
                }
            }
        }
        for l in &mut self.layers {
            for buf in [&mut l.k, &mut l.v] {
                match &mut buf.data {
                    KvData::Paged(p) => {
                        debug_assert_eq!(p.pages.len(), have, "page tables in lockstep");
                        p.pages.extend(grabbed.drain(..delta));
                    }
                    _ => unreachable!("paged state mixes storage kinds"),
                }
            }
        }
        debug_assert!(grabbed.is_empty());
        Ok(())
    }

    /// Tokens cached so far — the position the *next* token will occupy.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bump the token count after every layer appended one row. Asserts the
    /// per-layer buffers actually advanced in lockstep.
    pub(crate) fn advance(&mut self, rows: usize) {
        self.len += rows;
        debug_assert!(self.layers.iter().all(|l| l.k.rows() == self.len && l.v.rows() == self.len));
    }

    /// Fork this cache into an independent same-shape snapshot — the
    /// speculative-decode draft primitive ([`KvState::truncate`] is its
    /// rollback counterpart). Flat caches clone their buffers. Paged caches
    /// stay **paged** and the fork is a page-table copy + refcount bump —
    /// O(page-table), no payload copies, no new pages (reservation slack is
    /// not inherited). The fork shares every live page with its parent
    /// until one side appends into the shared tail, at which point
    /// [`KvState::reserve`]'s copy-on-write hook clones exactly that page.
    /// Allocation therefore cannot fail here; divergence is where pool
    /// pressure surfaces (typed, before compute, parent untouched). The
    /// `Result` stays for API stability with pre-COW callers that fell
    /// back to plain decode on exhaustion.
    pub fn fork(&self) -> Result<KvState, KvPoolExhausted> {
        if !self.is_paged() {
            return Ok(self.clone());
        }
        let layers = self
            .layers
            .iter()
            .map(|l| LayerKv { k: l.k.share_paged(), v: l.v.share_paged() })
            .collect();
        Ok(KvState { layers, precision: self.precision, len: self.len })
    }

    /// The pre-COW deep fork: fresh pages from the same pool in one
    /// grouped all-or-nothing grab, live spans byte-copied arena-to-arena.
    /// Kept as the baseline the `speedup_fork_cow_d512` bench gate
    /// measures [`KvState::fork`] against — O(tokens) vs O(page-table).
    pub fn fork_copy(&self) -> Result<KvState, KvPoolExhausted> {
        if !self.is_paged() {
            return Ok(self.clone());
        }
        let pool = match &self.layers[0].k.data {
            KvData::Paged(p) => p.pool.clone(),
            _ => unreachable!("is_paged checked above"),
        };
        let per_buf = KvPool::pages_for_tokens(self.len);
        let mut grabbed = pool.alloc(per_buf * 2 * self.layers.len())?;
        let layers = self
            .layers
            .iter()
            .map(|l| LayerKv {
                k: l.k.fork_paged(&pool, grabbed.drain(..per_buf).collect()),
                v: l.v.fork_paged(&pool, grabbed.drain(..per_buf).collect()),
            })
            .collect();
        debug_assert!(grabbed.is_empty());
        Ok(KvState { layers, precision: self.precision, len: self.len })
    }

    /// Map a shared prompt prefix into this **empty** paged cache: for
    /// each buffer (layer-major, K then V — the prefix index's order),
    /// adopt `rows / PAGE_TOKENS` fully-filled pages by reference. Pages
    /// are retained (refcount bump) — the index and every mapped session
    /// each hold a strong reference, so page ids can never be recycled
    /// under a reader. `ppu` seeds each buffer's attention-PPU counters
    /// with the prefix's cumulative `(hi, total)` block counts so
    /// [`KvState::effective_kv_bits`] prices the mapped rows like the
    /// prefill that produced them.
    pub fn map_prefix(&mut self, per_buf_pages: &[&[u32]], rows: usize, ppu: &[(u64, u64)]) {
        assert!(self.is_paged() && self.is_empty(), "map_prefix needs an empty paged cache");
        assert_eq!(rows % PAGE_TOKENS, 0, "prefix mapping is whole-page");
        assert_eq!(per_buf_pages.len(), 2 * self.layers.len(), "one page list per K/V buffer");
        assert_eq!(ppu.len(), per_buf_pages.len(), "one PPU seed per buffer");
        let pages_each = rows / PAGE_TOKENS;
        let mut it = per_buf_pages.iter().zip(ppu);
        for l in &mut self.layers {
            for buf in [&mut l.k, &mut l.v] {
                let (pages, &(hi, total)) = it.next().expect("length checked above");
                assert_eq!(pages.len(), pages_each, "prefix page table covers the rows");
                match &mut buf.data {
                    KvData::Paged(p) => {
                        p.pool.retain(pages);
                        p.pages = pages.to_vec();
                        p.rows = rows;
                    }
                    _ => unreachable!("paged state mixes storage kinds"),
                }
                buf.ppu_hi_blocks = hi;
                buf.ppu_blocks = total;
            }
        }
        self.len = rows;
    }

    /// Drop cached tokens beyond `len` (newest first) — the rollback seam
    /// decode benches and draft-session (speculative-decode) flows use.
    /// Paged caches release pages no longer holding live rows, including
    /// any reservation slack — so `truncate(self.len())` is the idiom for
    /// returning pages a reservation grabbed but a failed step never
    /// filled.
    pub fn truncate(&mut self, len: usize) {
        if len > self.len {
            return;
        }
        for l in &mut self.layers {
            l.k.truncate_rows(len);
            l.v.truncate_rows(len);
        }
        self.len = len;
    }

    /// Drop all cached tokens (the rolling re-prefill path). Paged caches
    /// return every page to the pool's free list.
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Physical bits this cache holds right now (live tokens).
    pub fn stored_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.k.stored_bits() + l.v.stored_bits()).sum()
    }

    /// Effective stored bits per KV value for the energy model. Without the
    /// attention PPU this is the precision's nominal width (16 or 8). With
    /// it, the FGMP mix prices FP8 blocks at 8 bits/value and NVFP4 blocks
    /// at 4.5625 (nibbles + per-block E4M3 scale + flag), weighted by the
    /// fraction `f` of blocks the PPU kept high:
    /// `8·f + 4.5625·(1−f)`.
    pub fn effective_kv_bits(&self) -> f64 {
        let (hi, total) = self.layers.iter().fold((0u64, 0u64), |(h, t), l| {
            let (hk, tk) = l.k.ppu_counts();
            let (hv, tv) = l.v.ppu_counts();
            (h + hk + hv, t + tk + tv)
        });
        if total == 0 {
            self.precision.bits_per_value()
        } else {
            let f = hi as f64 / total as f64;
            FP8_BITS_PER_VALUE * f + NVFP4_BITS_PER_VALUE * (1.0 - f)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{Act, NormKind, PosKind};
    use crate::quant::quant_e4m3;
    use crate::util::Rng;

    fn arch() -> ModelArch {
        ModelArch {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            act: Act::SwiGlu,
            norm: NormKind::Rms,
            pos: PosKind::Rope,
            max_seq: 8,
        }
    }

    #[test]
    fn fp16_cache_is_exact() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp16);
        let mut rng = Rng::new(1);
        let row = rng.normal_vec(a.d_model, 2.0);
        for l in &mut kv.layers {
            l.k.push_row(&row);
            l.v.push_row(&row);
        }
        kv.advance(1);
        assert_eq!(kv.len(), 1);
        let mut scratch = Vec::new();
        assert_eq!(kv.layers[0].k.materialize(&mut scratch), &row[..]);
        assert_eq!(kv.stored_bits(), (2 * 2 * a.d_model * 32) as u64);
    }

    #[test]
    fn fp8_cache_stores_bytes_and_decodes_on_the_e4m3_lattice() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp8);
        let mut rng = Rng::new(2);
        let row = rng.normal_vec(a.d_model, 3.0);
        kv.layers[0].k.push_row(&row);
        let mut scratch = Vec::new();
        let got = kv.layers[0].k.materialize(&mut scratch).to_vec();
        let want: Vec<f32> = row.iter().map(|&x| quant_e4m3(x)).collect();
        assert_eq!(got, want, "decode(encode(x)) must equal the round-trip");
        // Half the bits of the f32 cache for the same row count.
        assert_eq!(kv.layers[0].k.stored_bits(), (a.d_model * 8) as u64);
    }

    #[test]
    fn clear_resets_len_and_bits() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp8);
        let row = vec![1.0f32; a.d_model];
        for l in &mut kv.layers {
            l.k.push_row(&row);
            l.v.push_row(&row);
        }
        kv.advance(1);
        kv.clear();
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.stored_bits(), 0);
        assert!(kv.is_empty());
    }

    #[test]
    fn precision_knob_parses_and_prices() {
        assert_eq!(KvPrecision::parse("fp8").unwrap(), KvPrecision::Fp8);
        assert_eq!(KvPrecision::parse("fp16").unwrap(), KvPrecision::Fp16);
        assert!(KvPrecision::parse("int3").is_err());
        assert_eq!(KvPrecision::Fp8.bits_per_value(), 8.0);
        assert_eq!(KvPrecision::Fp16.bits_per_value(), 16.0);
    }

    // -- paged arena --------------------------------------------------------

    fn push_rows(kv: &mut KvState, rng: &mut Rng, n: usize, d: usize) {
        for _ in 0..n {
            let row = rng.normal_vec(d, 1.5);
            for l in &mut kv.layers {
                l.k.push_row(&row);
                l.v.push_row(&row);
            }
            kv.advance(1);
        }
    }

    #[test]
    fn paged_matches_flat_for_both_precisions() {
        let a = arch();
        for prec in [KvPrecision::Fp16, KvPrecision::Fp8] {
            let pool = KvPool::new(&a, prec, 64);
            let mut flat = KvState::new(&a, prec);
            let mut paged = KvState::new_paged(&a, &pool);
            assert!(paged.is_paged() && !flat.is_paged());
            assert_eq!(paged.kv_pages(), 0, "construction allocates nothing");

            // Cross a page boundary: PAGE_TOKENS + 3 rows.
            let n = PAGE_TOKENS + 3;
            paged.reserve(n).unwrap();
            let mut r1 = Rng::new(11);
            let mut r2 = Rng::new(11);
            push_rows(&mut flat, &mut r1, n, a.d_model);
            push_rows(&mut paged, &mut r2, n, a.d_model);

            let (mut s1, mut s2) = (Vec::new(), Vec::new());
            for l in 0..a.n_layers {
                let want = flat.layers[l].k.materialize(&mut s1).to_vec();
                let got = paged.layers[l].k.materialize(&mut s2).to_vec();
                assert_eq!(got.len(), want.len());
                for (g, w) in got.iter().zip(&want) {
                    assert_eq!(g.to_bits(), w.to_bits(), "{prec:?} layer {l}");
                }
            }
            assert_eq!(paged.stored_bits(), flat.stored_bits(), "{prec:?}");
            // 2 pages per buffer × 2 buffers × n_layers.
            assert_eq!(paged.kv_pages(), 2 * 2 * a.n_layers);

            // Clone stays paged and shares pages: no new unique pages,
            // logical count doubles, identical values.
            let before = pool.stats();
            let snap = paged.clone();
            assert!(snap.is_paged(), "paged clone stays paged (PR 4 debt fixed)");
            let after = pool.stats();
            assert_eq!(after.in_use_pages, before.in_use_pages, "clone copies no pages");
            assert_eq!(after.logical_pages, before.logical_pages + snap.kv_pages());
            assert!(after.sharing_factor() > 1.0);
            let (mut s3, mut s4) = (Vec::new(), Vec::new());
            assert_eq!(
                snap.layers[0].v.materialize(&mut s3),
                paged.layers[0].v.materialize(&mut s4)
            );
            drop(snap);
            assert_eq!(pool.stats().logical_pages, before.logical_pages);
        }
    }

    #[test]
    fn pool_alloc_free_reuse_under_interleaving() {
        // Property: over random interleaved reserve/clear/drop sequences the
        // pool conserves pages — in_use always equals the pages sessions
        // hold, every release makes them reallocatable, no page is ever
        // double-booked (checked via the free-list length invariant).
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp16, 48);
        let mut rng = Rng::new(0xA6ED_u64);
        let mut live: Vec<KvState> = Vec::new();
        for _ in 0..400 {
            let action = rng.below(3);
            if action == 0 || live.is_empty() {
                let mut kv = KvState::new_paged(&a, &pool);
                let want = 1 + rng.below(2 * PAGE_TOKENS);
                if kv.reserve(want).is_ok() {
                    live.push(kv);
                }
            } else if action == 1 {
                let i = rng.below(live.len());
                live.swap_remove(i); // drop returns pages
            } else {
                let i = rng.below(live.len());
                live[i].clear();
                let _ = live[i].reserve(1 + rng.below(PAGE_TOKENS));
            }
            let held: usize = live.iter().map(|kv| kv.kv_pages()).sum();
            let s = pool.stats();
            assert_eq!(s.in_use_pages, held, "pool accounting drifted");
            assert_eq!(s.free_pages + s.in_use_pages, s.total_pages);
            // Nothing here shares, so logical == unique and factor is 1.
            assert_eq!(s.logical_pages, held, "unshared logical == unique");
            assert_eq!(s.sharing_factor(), 1.0);
        }
        drop(live);
        assert_eq!(pool.stats().free_pages, 48, "all pages recycled");
        assert!(pool.stats().peak_in_use > 0);
    }

    #[test]
    fn exhaustion_is_typed_all_or_nothing_and_counted() {
        let a = arch();
        // 2 layers × 2 buffers: one token needs 4 pages; give the pool 3.
        let pool = KvPool::new(&a, KvPrecision::Fp16, 3);
        let mut kv = KvState::new_paged(&a, &pool);
        let err = kv.reserve(1).unwrap_err();
        assert_eq!(err, KvPoolExhausted { requested: 4, free: 3 });
        assert_eq!(kv.kv_pages(), 0, "failed reservation must not hold pages");
        assert_eq!(pool.free_pages(), 3, "all-or-nothing");
        assert_eq!(pool.stats().exhausted_events, 1);
        // The typed error survives anyhow conversion (the engine path).
        let any: anyhow::Error = err.into();
        assert!(any.downcast_ref::<KvPoolExhausted>().is_some());

        // Reserve slack is idempotent: a partially-filled page satisfies
        // further tokens without new pages.
        let pool2 = KvPool::new(&a, KvPrecision::Fp16, 8);
        let mut kv2 = KvState::new_paged(&a, &pool2);
        kv2.reserve(3).unwrap();
        assert_eq!(kv2.kv_pages(), 4);
        kv2.reserve(PAGE_TOKENS - 3).unwrap(); // still within page 0
        assert_eq!(kv2.kv_pages(), 4);
    }

    #[test]
    fn truncate_rolls_back_rows_and_pages() {
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp16, 64);
        let mut kv = KvState::new_paged(&a, &pool);
        let n = PAGE_TOKENS + 4;
        kv.reserve(n).unwrap();
        let mut rng = Rng::new(5);
        push_rows(&mut kv, &mut rng, n, a.d_model);
        assert_eq!(kv.kv_pages(), 2 * 2 * a.n_layers);

        kv.truncate(PAGE_TOKENS - 1); // back under one page
        assert_eq!(kv.len(), PAGE_TOKENS - 1);
        assert_eq!(kv.kv_pages(), 2 * a.n_layers, "second pages released");
        assert_eq!(pool.stats().in_use_pages, kv.kv_pages());
        // No-op when len > current.
        kv.truncate(PAGE_TOKENS);
        assert_eq!(kv.len(), PAGE_TOKENS - 1);
        // Reservation slack releases via truncate(len()) — the idiom for
        // returning pages a failed step reserved but never filled.
        kv.reserve(5).unwrap();
        assert_eq!(kv.kv_pages(), 2 * 2 * a.n_layers, "reserve ran ahead");
        kv.truncate(kv.len());
        assert_eq!(kv.kv_pages(), 2 * a.n_layers, "slack released");
        assert_eq!(kv.len(), PAGE_TOKENS - 1);
        // Flat caches truncate their vecs too.
        let mut flat = KvState::new(&a, KvPrecision::Fp8);
        push_rows(&mut flat, &mut rng, 3, a.d_model);
        flat.truncate(1);
        assert_eq!(flat.len(), 1);
        assert_eq!(flat.stored_bits(), (2 * a.n_layers * a.d_model * 8) as u64);
    }

    #[test]
    fn fork_is_paged_bit_identical_and_independent() {
        let a = arch();
        for prec in [KvPrecision::Fp16, KvPrecision::Fp8] {
            let pool = KvPool::new(&a, prec, 64);
            let mut kv = KvState::new_paged(&a, &pool);
            let n = PAGE_TOKENS + 5; // multi-page with a partial tail
            kv.reserve(n).unwrap();
            let mut rng = Rng::new(31);
            push_rows(&mut kv, &mut rng, n, a.d_model);
            kv.layers[0].k.note_ppu(3, 7);

            let held = pool.stats().in_use_pages;
            let fork = kv.fork().unwrap();
            assert!(fork.is_paged(), "fork keeps the paged shape");
            assert_eq!(fork.len(), kv.len());
            assert_eq!(fork.kv_pages(), kv.kv_pages(), "fork holds live-row pages only");
            // COW fork: zero new unique pages, logical count doubled.
            let s = pool.stats();
            assert_eq!(s.in_use_pages, held, "fork copies no pages up front");
            assert_eq!(s.logical_pages, held + fork.kv_pages());
            assert!((s.sharing_factor() - 2.0).abs() < 1e-12);
            assert_eq!(fork.layers[0].k.ppu_counts(), (3, 7), "PPU counters carried");

            // Values bit-identical, pages distinct.
            let (mut s1, mut s2) = (Vec::new(), Vec::new());
            for l in 0..a.n_layers {
                let want = kv.layers[l].v.materialize(&mut s1).to_vec();
                let got = fork.layers[l].v.materialize(&mut s2).to_vec();
                assert_eq!(want.len(), got.len());
                for (w, g) in want.iter().zip(&got) {
                    assert_eq!(w.to_bits(), g.to_bits(), "{prec:?} layer {l}");
                }
            }

            // Writes into the fork never reach the parent: the shared
            // partial tail diverges through the COW hook in reserve —
            // one fresh page per buffer, everything else still shared.
            let mut fork = fork;
            let before = kv.layers[1].k.materialize(&mut s1).to_vec();
            let row = vec![9.0f32; a.d_model];
            fork.reserve(1).unwrap();
            let s = pool.stats();
            assert_eq!(s.in_use_pages, held + 2 * a.n_layers, "one tail per buffer");
            assert_eq!(s.cow_copies, (2 * a.n_layers) as u64, "one COW copy per buffer");
            for l in &mut fork.layers {
                l.k.push_row(&row);
                l.v.push_row(&row);
            }
            fork.advance(1);
            assert_eq!(kv.layers[1].k.materialize(&mut s2), &before[..]);
            assert_eq!(kv.len(), n);

            // Dropping the fork returns every page it held (diverged tails
            // free; shared pages drop back to the parent's refcount).
            drop(fork);
            let s = pool.stats();
            assert_eq!(s.in_use_pages, held, "fork pages recycled");
            assert_eq!(s.logical_pages, held);

            // Flat forks stay flat and never touch a pool.
            let mut flat = KvState::new(&a, prec);
            push_rows(&mut flat, &mut rng, 3, a.d_model);
            let ff = flat.fork().unwrap();
            assert!(!ff.is_paged());
            assert_eq!(ff.len(), 3);
            assert_eq!(ff.stored_bits(), flat.stored_bits());
        }
    }

    #[test]
    fn cow_divergence_exhaustion_is_typed_and_leaves_parent_untouched() {
        let a = arch();
        // A session of PAGE_TOKENS+1 rows holds exactly 8 unique pages
        // (2 pages per buffer × 2 layers × K+V). Size the pool to exactly
        // that: the COW fork itself costs nothing — exhaustion moved from
        // fork time to *divergence* time, and bites on unique pages only.
        let pool = KvPool::new(&a, KvPrecision::Fp8, 8);
        let mut kv = KvState::new_paged(&a, &pool);
        let n = PAGE_TOKENS + 1;
        kv.reserve(n).unwrap();
        let mut rng = Rng::new(13);
        push_rows(&mut kv, &mut rng, n, a.d_model);
        assert_eq!(pool.free_pages(), 0);

        // The old deep fork (bench baseline) needs 8 fresh pages — typed
        // exhaustion, nothing leaked.
        let err = kv.fork_copy().unwrap_err();
        assert_eq!(err, KvPoolExhausted { requested: 8, free: 0 });
        assert_eq!(pool.stats().in_use_pages, 8, "all-or-nothing: no pages leaked");

        // The COW fork succeeds in a full pool: logical pages double while
        // unique pages (what exhaustion charges) stay put.
        let mut fork = kv.fork().unwrap();
        let s = pool.stats();
        assert_eq!(s.in_use_pages, 8);
        assert_eq!(s.logical_pages, 16);

        // Appending into the fork must first unshare its 4 tail pages —
        // which a full pool cannot host. Typed, all-or-nothing, and both
        // caches still readable afterwards.
        let err = fork.reserve(1).unwrap_err();
        assert_eq!(err, KvPoolExhausted { requested: 4, free: 0 });
        assert_eq!(fork.len(), n);
        assert_eq!(kv.len(), n);
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        assert_eq!(
            kv.layers[0].k.materialize(&mut s1),
            fork.layers[0].k.materialize(&mut s2),
            "failed divergence leaves the shared bytes intact"
        );

        // Dropping the fork restores headroom: the parent's own append
        // then needs no COW (its tail is unique again) and no new page.
        drop(fork);
        assert_eq!(pool.stats().logical_pages, 8);
        kv.reserve(1).unwrap();
        push_rows(&mut kv, &mut rng, 1, a.d_model);
        assert_eq!(kv.len(), n + 1);
        assert_eq!(pool.stats().cow_copies, 0, "no divergence ever completed");
    }

    #[test]
    fn cow_truncate_and_drop_interleavings_reconcile_accounting() {
        // Property: over random fork/clone/write/truncate/drop interleavings
        // with sharing, the pool conserves pages — logical == Σ live page
        // tables, unique + free == total, and everything recycles at the
        // end. The free list can never double-book because release only
        // frees at refcount 0.
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp16, 96);
        let mut rng = Rng::new(0xC0_57_u64);
        let mut live: Vec<KvState> = Vec::new();
        for _ in 0..500 {
            let action = rng.below(5);
            if action == 0 || live.is_empty() {
                let mut kv = KvState::new_paged(&a, &pool);
                let want = 1 + rng.below(2 * PAGE_TOKENS);
                if kv.reserve(want).is_ok() {
                    push_rows(&mut kv, &mut rng, want, a.d_model);
                    live.push(kv);
                }
            } else if action == 1 {
                // Fork (or clone — same sharing semantics) a random session.
                let i = rng.below(live.len());
                let forked =
                    if rng.below(2) == 0 { live[i].fork().unwrap() } else { live[i].clone() };
                live.push(forked);
            } else if action == 2 {
                // Diverge: append a row, COW-unsharing the tail if needed.
                let i = rng.below(live.len());
                if live[i].len() < 4 * PAGE_TOKENS && live[i].reserve(1).is_ok() {
                    push_rows(&mut live[i], &mut rng, 1, a.d_model);
                }
            } else if action == 3 {
                let i = rng.below(live.len());
                let to = rng.below(live[i].len() + 1);
                live[i].truncate(to);
            } else {
                let i = rng.below(live.len());
                live.swap_remove(i);
            }
            let held: usize = live.iter().map(|kv| kv.kv_pages()).sum();
            let s = pool.stats();
            assert_eq!(s.logical_pages, held, "logical pages == Σ page tables");
            assert_eq!(s.free_pages + s.in_use_pages, s.total_pages);
            assert!(s.in_use_pages <= s.logical_pages, "sharing never inflates uniques");
        }
        drop(live);
        let s = pool.stats();
        assert_eq!(s.free_pages, 96, "all pages recycled");
        assert_eq!(s.logical_pages, 0);
    }

    #[test]
    fn cow_map_prefix_shares_full_pages_and_seeds_ppu() {
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp8, 64);
        let mut parent = KvState::new_paged(&a, &pool);
        let n = 2 * PAGE_TOKENS; // two full pages per buffer
        parent.reserve(n).unwrap();
        let mut rng = Rng::new(0x9F);
        push_rows(&mut parent, &mut rng, n, a.d_model);
        parent.layers[0].k.note_ppu(5, 8);

        // Collect the parent's page tables buffer-major (the index order).
        let tables: Vec<Vec<u32>> = parent
            .layers
            .iter()
            .flat_map(|l| [&l.k, &l.v])
            .map(|b| match &b.data {
                KvData::Paged(p) => p.pages.clone(),
                _ => unreachable!(),
            })
            .collect();
        let refs: Vec<&[u32]> = tables.iter().map(|t| t.as_slice()).collect();
        let ppu: Vec<(u64, u64)> = parent
            .layers
            .iter()
            .flat_map(|l| [l.k.ppu_counts(), l.v.ppu_counts()])
            .collect();

        let held = pool.stats().in_use_pages;
        let mut mapped = KvState::new_paged(&a, &pool);
        mapped.map_prefix(&refs, n, &ppu);
        assert_eq!(mapped.len(), n);
        assert_eq!(mapped.kv_pages(), parent.kv_pages());
        assert_eq!(mapped.layers[0].k.ppu_counts(), (5, 8), "PPU seeded from prefix");
        let s = pool.stats();
        assert_eq!(s.in_use_pages, held, "mapping allocates nothing");
        assert_eq!(s.logical_pages, 2 * held);

        // Identical bytes; the mapped session then extends independently.
        let (mut s1, mut s2) = (Vec::new(), Vec::new());
        assert_eq!(
            parent.layers[1].v.materialize(&mut s1),
            mapped.layers[1].v.materialize(&mut s2)
        );
        mapped.reserve(1).unwrap();
        push_rows(&mut mapped, &mut rng, 1, a.d_model);
        assert_eq!(mapped.len(), n + 1);
        assert_eq!(parent.len(), n);
        // Full-page prefix: the append opens a fresh page, no COW copy.
        assert_eq!(pool.stats().cow_copies, 0, "whole-page sharing never diverges");
    }

    #[test]
    fn fork_drops_reservation_slack() {
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp16, 64);
        let mut kv = KvState::new_paged(&a, &pool);
        kv.reserve(3).unwrap();
        let mut rng = Rng::new(17);
        push_rows(&mut kv, &mut rng, 3, a.d_model);
        kv.reserve(2 * PAGE_TOKENS).unwrap(); // slack the fork must not copy
        assert_eq!(kv.kv_pages(), 3 * 2 * a.n_layers);
        let fork = kv.fork().unwrap();
        assert_eq!(fork.kv_pages(), 2 * a.n_layers, "fork sized by live rows");
        assert_eq!(fork.len(), 3);
    }

    #[test]
    fn views_cover_live_rows_at_stored_precision() {
        let a = arch();
        for prec in [KvPrecision::Fp16, KvPrecision::Fp8] {
            let pool = KvPool::new(&a, prec, 64);
            let mut flat = KvState::new(&a, prec);
            let mut paged = KvState::new_paged(&a, &pool);
            let n = PAGE_TOKENS + 5; // multi-span with a partial last page
            paged.reserve(n).unwrap();
            let mut r1 = Rng::new(77);
            let mut r2 = Rng::new(77);
            push_rows(&mut flat, &mut r1, n, a.d_model);
            push_rows(&mut paged, &mut r2, n, a.d_model);

            for kv in [&flat, &paged] {
                // Snapshot the oracle *before* taking the read lock (clone
                // of a paged buffer itself locks the pool).
                let mut scratch = Vec::new();
                let want = kv.layers[1].k.clone();
                let want = want.materialize(&mut scratch);
                let lkv = &kv.layers[1];
                let lock = lock_pools([&lkv.k, &lkv.v]);
                let kview = lkv.k.view(&lock);
                match (prec, &kview) {
                    (KvPrecision::Fp16, KvView::F32 { pages }) => {
                        let got: Vec<f32> = pages.concat();
                        assert_eq!(got.len(), n * a.d_model);
                        for (g, w) in got.iter().zip(want) {
                            assert_eq!(g.to_bits(), w.to_bits());
                        }
                    }
                    (KvPrecision::Fp8, KvView::Fp8 { pages }) => {
                        let bytes: Vec<u8> = pages.concat();
                        assert_eq!(bytes.len(), n * a.d_model);
                        let mut dec = Vec::new();
                        kernels::gather_e4m3_pages(&[&bytes], &mut dec);
                        for (g, w) in dec.iter().zip(want) {
                            assert_eq!(g.to_bits(), w.to_bits());
                        }
                    }
                    _ => panic!("view precision mismatch for {prec:?}"),
                }
            }
        }
    }

    #[test]
    fn shared_pool_locks_once_across_buffers() {
        // K and V of every layer share one pool: lock_pools must dedup or
        // this deadlocks (the pool mutex is not reentrant).
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp8, 64);
        let mut kv = KvState::new_paged(&a, &pool);
        kv.reserve(3).unwrap();
        let mut rng = Rng::new(9);
        push_rows(&mut kv, &mut rng, 3, a.d_model);
        let bufs: Vec<&KvBuf> =
            kv.layers.iter().flat_map(|l| [&l.k, &l.v]).collect();
        let lock = lock_pools(bufs.iter().copied());
        for b in &bufs {
            match b.view(&lock) {
                KvView::Fp8 { pages } => {
                    assert_eq!(pages.iter().map(|p| p.len()).sum::<usize>(), 3 * a.d_model)
                }
                _ => panic!("fp8 pool must view as bytes"),
            }
        }
    }

    #[test]
    fn effective_bits_follow_ppu_mix() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp8);
        assert_eq!(kv.effective_kv_bits(), 8.0, "no PPU data → nominal bits");
        // Half the blocks high: 0.5·8 + 0.5·4.5625.
        kv.layers[0].k.note_ppu(2, 4);
        assert!((kv.effective_kv_bits() - (0.5 * 8.0 + 0.5 * 4.5625)).abs() < 1e-12);
        assert_eq!(kv.layers[0].k.ppu_counts(), (2, 4));
        kv.clear();
        assert_eq!(kv.effective_kv_bits(), 8.0, "clear resets PPU counters");
        let mut kv16 = KvState::new(&a, KvPrecision::Fp16);
        assert_eq!(kv16.effective_kv_bits(), 16.0);
        kv16.layers[1].v.note_ppu(4, 4);
        assert_eq!(kv16.effective_kv_bits(), 8.0, "all-high mix prices FP8");
    }

    #[test]
    fn pages_for_session_math() {
        assert_eq!(KvPool::pages_for_tokens(0), 0);
        assert_eq!(KvPool::pages_for_tokens(1), 1);
        assert_eq!(KvPool::pages_for_tokens(PAGE_TOKENS), 1);
        assert_eq!(KvPool::pages_for_tokens(PAGE_TOKENS + 1), 2);
        assert_eq!(KvPool::pages_for_session(4, 17), 2 * 4 * 2);
    }

    // -- worker-sharded pools (tensor parallelism) --------------------------

    /// The sharded engine gives each worker its own pool over a head-slice
    /// arch. Page *geometry* is token-based (layers × tokens), so every
    /// worker pool's page accounting must mirror the single full-width pool
    /// exactly, while physical value capacity — pages × page width — tiles:
    /// summed across workers it equals the single pool's.
    #[test]
    fn worker_shard_pool_stats_mirror_single_pool() {
        let a = arch(); // d_model 16, 2 heads
        let shard = |d: usize, h: usize| ModelArch { d_model: d, n_heads: h, ..arch() };
        let shards = [shard(8, 1), shard(8, 1)]; // head-split: 8 + 8 = 16

        let pool = KvPool::new(&a, KvPrecision::Fp8, 64);
        let pools: Vec<_> =
            shards.iter().map(|sa| KvPool::new(sa, KvPrecision::Fp8, 64)).collect();

        let mut full = KvState::new_paged(&a, &pool);
        let mut halves: Vec<KvState> =
            shards.iter().zip(&pools).map(|(sa, p)| KvState::new_paged(sa, p)).collect();

        let n = PAGE_TOKENS + 3; // multi-page with a partial tail
        full.reserve(n).unwrap();
        for h in &mut halves {
            h.reserve(n).unwrap();
        }
        let mut rng = Rng::new(0x5A4D);
        for _ in 0..n {
            let row = rng.normal_vec(a.d_model, 1.5);
            for l in &mut full.layers {
                l.k.push_row(&row);
                l.v.push_row(&row);
            }
            full.advance(1);
            // Column-sliced rows into each worker's shard cache.
            let mut off = 0;
            for (h, sa) in halves.iter_mut().zip(&shards) {
                let cols = &row[off..off + sa.d_model];
                for l in &mut h.layers {
                    l.k.push_row(cols);
                    l.v.push_row(cols);
                }
                h.advance(1);
                off += sa.d_model;
            }
        }

        let s = pool.stats();
        let mut summed_bits = 0u64;
        let mut summed_values = 0usize;
        for ((h, p), sa) in halves.iter().zip(&pools).zip(&shards) {
            let ws = p.stats();
            // Per-worker page accounting is identical to the single pool.
            assert_eq!(ws.in_use_pages, s.in_use_pages, "page counts are token-based");
            assert_eq!(ws.total_pages, s.total_pages);
            assert_eq!(ws.page_tokens, s.page_tokens);
            assert_eq!(h.kv_pages(), full.kv_pages());
            assert_eq!(h.len(), full.len());
            summed_bits += h.stored_bits();
            summed_values += ws.in_use_pages * ws.page_tokens * sa.d_model;
        }
        // Physical capacity and live bits tile across the shard widths.
        assert_eq!(summed_bits, full.stored_bits(), "stored bits tile across workers");
        assert_eq!(summed_values, s.in_use_pages * s.page_tokens * a.d_model);

        // Retirement drains every pool independently.
        drop(halves);
        for p in &pools {
            assert_eq!(p.stats().in_use_pages, 0, "worker pool recycled");
        }
        drop(full);
        assert_eq!(pool.stats().in_use_pages, 0);
    }

    /// Attention-PPU pricing across worker shards: per-shard block totals
    /// are proportional to shard width, so the width-weighted mean of the
    /// shards' `effective_kv_bits` reproduces the single full-width cache's
    /// value — and `truncate` scales each shard's hi/total counters
    /// proportionally, leaving every shard's realized mix (and hence its
    /// energy price) unchanged.
    #[test]
    fn effective_bits_tile_and_truncate_scales_per_shard() {
        // 16-wide PPU blocks need shard widths that are block multiples.
        let a = ModelArch { d_model: 32, n_heads: 2, ..arch() };
        let shards =
            [ModelArch { d_model: 16, n_heads: 1, ..arch() }, ModelArch { d_model: 16, n_heads: 1, ..arch() }];

        let n = 8usize; // rows pushed per buffer
        let mut full = KvState::new(&a, KvPrecision::Fp8);
        let mut parts: Vec<KvState> =
            shards.iter().map(|sa| KvState::new(sa, KvPrecision::Fp8)).collect();
        let mut rng = Rng::new(0x9B1);
        // Shard 0 keeps most blocks high, shard 1 quantizes hard: the mixes
        // diverge, which is exactly when averaging (instead of
        // width-weighting) would misprice.
        let hi_per_row = [1usize, 0usize]; // of 1 block per 16-wide row
        for _ in 0..n {
            let row = rng.normal_vec(a.d_model, 1.0);
            let mut off = 0;
            let mut hi_row = 0;
            for ((part, sa), &hi) in parts.iter_mut().zip(&shards).zip(&hi_per_row) {
                let cols = &row[off..off + sa.d_model];
                let blocks = sa.d_model / 16;
                for l in &mut part.layers {
                    l.k.push_row(cols);
                    l.k.note_ppu(hi, blocks);
                    l.v.push_row(cols);
                    l.v.note_ppu(hi, blocks);
                }
                part.advance(1);
                off += sa.d_model;
                hi_row += hi;
            }
            let full_blocks = a.d_model / 16;
            for l in &mut full.layers {
                l.k.push_row(&row);
                l.k.note_ppu(hi_row, full_blocks);
                l.v.push_row(&row);
                l.v.note_ppu(hi_row, full_blocks);
            }
            full.advance(1);
        }

        // Width-weighted shard mix == full-width mix (t_w ∝ width makes the
        // algebra exact; FP evaluation agrees to rounding).
        let weighted: f64 = parts
            .iter()
            .zip(&shards)
            .map(|(p, sa)| p.effective_kv_bits() * sa.d_model as f64 / a.d_model as f64)
            .sum();
        let single = full.effective_kv_bits();
        assert!(
            (weighted - single).abs() < 1e-12,
            "width-weighted shard bits {weighted} vs full-width {single}"
        );
        // Divergent mixes: the *plain* mean over shards would misprice.
        let plain: f64 =
            parts.iter().map(|p| p.effective_kv_bits()).sum::<f64>() / parts.len() as f64;
        assert!((plain - single).abs() < 1e-12, "equal widths: plain mean happens to agree");
        assert!(
            (parts[0].effective_kv_bits() - parts[1].effective_kv_bits()).abs() > 1.0,
            "shard mixes must actually diverge for this test to bite"
        );

        // Truncate to half: every shard buffer scales hi and total counts
        // proportionally (rounded), so each shard's realized mix — and the
        // price its worker reports — is preserved.
        let before: Vec<(u64, u64)> =
            parts.iter().map(|p| p.layers[0].k.ppu_counts()).collect();
        let prices: Vec<f64> = parts.iter().map(|p| p.effective_kv_bits()).collect();
        for p in parts.iter_mut() {
            p.truncate(n / 2);
        }
        full.truncate(n / 2);
        for ((p, &(h0, t0)), &price) in parts.iter().zip(&before).zip(&prices) {
            let (h1, t1) = p.layers[0].k.ppu_counts();
            assert_eq!(h1, (h0 as f64 * 0.5).round() as u64, "hi scales with rows");
            assert_eq!(t1, (t0 as f64 * 0.5).round() as u64, "total scales with rows");
            assert!((p.effective_kv_bits() - price).abs() < 1e-12, "mix preserved");
        }
        assert!((full.effective_kv_bits() - single).abs() < 1e-12);
    }
}
