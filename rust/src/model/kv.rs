//! Per-session KV cache for incremental decoding.
//!
//! [`KvState`] holds one transformer session's cached keys and values: one
//! [`LayerKv`] per block, each an append-only `(tokens, d_model)` buffer of
//! post-RoPE keys and raw values (heads side by side, the layout
//! `model::forward` gathers per-head panels from). The buffers are
//! precision-aware: [`KvPrecision::Fp16`] stores exact f32 rows (standing in
//! for the paper's BF16 KV baseline), while [`KvPrecision::Fp8`] stores each
//! element as a real E4M3 byte via the [`crate::quant::fp8`] codec — half
//! the memory, mirroring the quantized-cache comparators the paper's Fig. 1
//! footnote discusses — and decodes on read, so decode steps attend over
//! exactly the values a byte-packed accelerator cache would hold.
//!
//! With `Fp16` the cached rows are bit-identical to what the full-sequence
//! forward computes internally, which is what makes the prefill+step path
//! bit-exact against full recompute (property-tested in
//! `tests/decode_props.rs`). With `Fp8` the divergence is bounded by the
//! E4M3 round-trip error on K/V (documented tolerance in the same test).

use crate::model::forward::ModelArch;
use crate::quant::fp8::{decode_e4m3, encode_e4m3};

/// Storage precision of a session's KV cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KvPrecision {
    /// Exact f32 rows (models the BF16/FP16 cache of the paper's setup).
    Fp16,
    /// E4M3 bytes per element — 8 bits/value, decoded on read.
    Fp8,
}

impl KvPrecision {
    /// Bits per cached value, the number `hwsim::kvcache::kv_cache_bits`
    /// charges for cache traffic and capacity at this precision.
    pub fn bits_per_value(&self) -> f64 {
        match self {
            KvPrecision::Fp16 => 16.0,
            KvPrecision::Fp8 => 8.0,
        }
    }

    pub fn label(&self) -> &'static str {
        match self {
            KvPrecision::Fp16 => "fp16",
            KvPrecision::Fp8 => "fp8",
        }
    }

    /// Parse a CLI knob value ("fp16"/"bf16" or "fp8").
    pub fn parse(s: &str) -> crate::Result<Self> {
        match s {
            "fp16" | "bf16" | "f32" => Ok(KvPrecision::Fp16),
            "fp8" | "e4m3" => Ok(KvPrecision::Fp8),
            other => anyhow::bail!("unknown KV precision '{other}' (have fp16, fp8)"),
        }
    }
}

/// One append-only `(rows, width)` tensor at the cache precision.
#[derive(Debug, Clone)]
enum KvData {
    F32(Vec<f32>),
    Fp8(Vec<u8>),
}

/// A precision-aware K or V buffer for one layer.
#[derive(Debug, Clone)]
pub struct KvBuf {
    data: KvData,
    width: usize,
}

impl KvBuf {
    fn new(prec: KvPrecision, width: usize) -> Self {
        let data = match prec {
            KvPrecision::Fp16 => KvData::F32(Vec::new()),
            KvPrecision::Fp8 => KvData::Fp8(Vec::new()),
        };
        KvBuf { data, width }
    }

    /// Cached rows (tokens).
    pub fn rows(&self) -> usize {
        match &self.data {
            KvData::F32(v) => v.len() / self.width,
            KvData::Fp8(v) => v.len() / self.width,
        }
    }

    /// Append one `width`-wide row, quantizing to the cache precision.
    pub fn push_row(&mut self, row: &[f32]) {
        debug_assert_eq!(row.len(), self.width);
        match &mut self.data {
            KvData::F32(v) => v.extend_from_slice(row),
            KvData::Fp8(v) => v.extend(row.iter().map(|&x| encode_e4m3(x))),
        }
    }

    /// Borrow the whole buffer as f32 rows. The FP16 cache is returned
    /// in place; the FP8 cache is decoded into `scratch` (resized as
    /// needed) — the read-side dequant a mixed-precision cache pays.
    pub fn materialize<'a>(&'a self, scratch: &'a mut Vec<f32>) -> &'a [f32] {
        match &self.data {
            KvData::F32(v) => v,
            KvData::Fp8(v) => {
                scratch.clear();
                scratch.extend(v.iter().map(|&b| decode_e4m3(b)));
                scratch
            }
        }
    }

    /// Physical bits held (excluding Vec capacity slack).
    pub fn stored_bits(&self) -> u64 {
        match &self.data {
            KvData::F32(v) => 32 * v.len() as u64,
            KvData::Fp8(v) => 8 * v.len() as u64,
        }
    }

    fn clear(&mut self) {
        match &mut self.data {
            KvData::F32(v) => v.clear(),
            KvData::Fp8(v) => v.clear(),
        }
    }
}

/// One layer's cached keys and values.
#[derive(Debug, Clone)]
pub struct LayerKv {
    /// Post-RoPE keys, `(tokens, d_model)` row-major, heads side by side.
    pub k: KvBuf,
    /// Values, same layout.
    pub v: KvBuf,
}

/// A full per-session cache: one [`LayerKv`] per transformer block.
#[derive(Debug, Clone)]
pub struct KvState {
    pub layers: Vec<LayerKv>,
    pub precision: KvPrecision,
    /// Tokens currently cached (identical across layers).
    len: usize,
}

impl KvState {
    pub fn new(arch: &ModelArch, precision: KvPrecision) -> Self {
        let layers = (0..arch.n_layers)
            .map(|_| LayerKv {
                k: KvBuf::new(precision, arch.d_model),
                v: KvBuf::new(precision, arch.d_model),
            })
            .collect();
        KvState { layers, precision, len: 0 }
    }

    /// Tokens cached so far — the position the *next* token will occupy.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bump the token count after every layer appended one row. Asserts the
    /// per-layer buffers actually advanced in lockstep.
    pub(crate) fn advance(&mut self, rows: usize) {
        self.len += rows;
        debug_assert!(self.layers.iter().all(|l| l.k.rows() == self.len && l.v.rows() == self.len));
    }

    /// Drop all cached tokens (the rolling re-prefill path).
    pub fn clear(&mut self) {
        for l in &mut self.layers {
            l.k.clear();
            l.v.clear();
        }
        self.len = 0;
    }

    /// Physical bits this cache holds right now.
    pub fn stored_bits(&self) -> u64 {
        self.layers.iter().map(|l| l.k.stored_bits() + l.v.stored_bits()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{Act, NormKind, PosKind};
    use crate::quant::quant_e4m3;
    use crate::util::Rng;

    fn arch() -> ModelArch {
        ModelArch {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            act: Act::SwiGlu,
            norm: NormKind::Rms,
            pos: PosKind::Rope,
            max_seq: 8,
        }
    }

    #[test]
    fn fp16_cache_is_exact() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp16);
        let mut rng = Rng::new(1);
        let row = rng.normal_vec(a.d_model, 2.0);
        for l in &mut kv.layers {
            l.k.push_row(&row);
            l.v.push_row(&row);
        }
        kv.advance(1);
        assert_eq!(kv.len(), 1);
        let mut scratch = Vec::new();
        assert_eq!(kv.layers[0].k.materialize(&mut scratch), &row[..]);
        assert_eq!(kv.stored_bits(), (2 * 2 * a.d_model * 32) as u64);
    }

    #[test]
    fn fp8_cache_stores_bytes_and_decodes_on_the_e4m3_lattice() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp8);
        let mut rng = Rng::new(2);
        let row = rng.normal_vec(a.d_model, 3.0);
        kv.layers[0].k.push_row(&row);
        let mut scratch = Vec::new();
        let got = kv.layers[0].k.materialize(&mut scratch).to_vec();
        let want: Vec<f32> = row.iter().map(|&x| quant_e4m3(x)).collect();
        assert_eq!(got, want, "decode(encode(x)) must equal the round-trip");
        // Half the bits of the f32 cache for the same row count.
        assert_eq!(kv.layers[0].k.stored_bits(), (a.d_model * 8) as u64);
    }

    #[test]
    fn clear_resets_len_and_bits() {
        let a = arch();
        let mut kv = KvState::new(&a, KvPrecision::Fp8);
        let row = vec![1.0f32; a.d_model];
        for l in &mut kv.layers {
            l.k.push_row(&row);
            l.v.push_row(&row);
        }
        kv.advance(1);
        kv.clear();
        assert_eq!(kv.len(), 0);
        assert_eq!(kv.stored_bits(), 0);
        assert!(kv.is_empty());
    }

    #[test]
    fn precision_knob_parses_and_prices() {
        assert_eq!(KvPrecision::parse("fp8").unwrap(), KvPrecision::Fp8);
        assert_eq!(KvPrecision::parse("fp16").unwrap(), KvPrecision::Fp16);
        assert!(KvPrecision::parse("int3").is_err());
        assert_eq!(KvPrecision::Fp8.bits_per_value(), 8.0);
        assert_eq!(KvPrecision::Fp16.bits_per_value(), 16.0);
    }
}
