//! Model-level plumbing: artifact loading, the offline weight-quantization
//! pipeline (policy → SW-Clip → packing), and quantization configuration.

pub mod config;
pub mod weights;

pub use config::{QuantConfig, RatioSpec};
pub use weights::{ModelArtifacts, QuantizedModel};
