//! Model-level plumbing: artifact loading, the offline weight-quantization
//! pipeline (policy → SW-Clip → packing), quantization configuration, and
//! the pure-Rust reference forward pass the native runtime executes.

pub mod config;
pub mod forward;
pub mod kv;
pub mod tp;
pub mod weights;

pub use config::{QuantConfig, RatioSpec};
pub use forward::{Act, ModelArch, NormKind, PosKind};
pub use kv::{KvPool, KvPoolExhausted, KvPoolStats, KvPrecision, KvState, PAGE_TOKENS};
pub use tp::{Collective, ShardPlan, ThreadCollective};
pub use weights::{ModelArtifacts, QuantizedModel, WeightMemory};
