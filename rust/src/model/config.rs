//! Quantization configuration: the knobs of every experiment in the paper.


use crate::policy::{Policy, ThresholdMode};

/// Target precision mix, expressed the way the paper labels its figures.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RatioSpec {
    /// Everything unquantized (the BF16 rows; uses the fwd_ref graph).
    Bf16,
    /// All blocks FP8 (threshold = -inf).
    AllFp8,
    /// All blocks NVFP4 (threshold = +inf).
    AllFp4,
    /// FGMP with the given fraction of blocks in FP4 (paper: "70% FP4").
    Fp4Fraction(f64),
}

impl RatioSpec {
    /// The FP4 fraction used for threshold calibration (None for Bf16).
    pub fn fp4_fraction(&self) -> Option<f64> {
        match self {
            RatioSpec::Bf16 => None,
            RatioSpec::AllFp8 => Some(0.0),
            RatioSpec::AllFp4 => Some(1.0),
            RatioSpec::Fp4Fraction(f) => Some(*f),
        }
    }

    pub fn label(&self) -> String {
        match self {
            RatioSpec::Bf16 => "BF16".into(),
            RatioSpec::AllFp8 => "FP8".into(),
            RatioSpec::AllFp4 => "FP4".into(),
            RatioSpec::Fp4Fraction(f) => format!("{:.0}% FP4", f * 100.0),
        }
    }
}

/// Full quantization configuration for one experiment point.
#[derive(Debug, Clone)]
pub struct QuantConfig {
    pub ratio: RatioSpec,
    /// Block-scoring policy (paper Fig. 6 ablation; Fisher = FGMP).
    pub policy: Policy,
    /// Global (paper) vs per-layer (ablation) thresholding.
    pub threshold_mode: ThresholdMode,
    /// Sensitivity-weighted clipping for FP4 weight blocks (§3.3).
    pub sw_clip: bool,
}

impl QuantConfig {
    /// The paper's headline configuration at a given FP4 fraction.
    pub fn fgmp(fp4_fraction: f64) -> Self {
        QuantConfig {
            ratio: RatioSpec::Fp4Fraction(fp4_fraction),
            policy: Policy::Fisher,
            threshold_mode: ThresholdMode::Global,
            sw_clip: true,
        }
    }

    pub fn all_fp8() -> Self {
        QuantConfig { ratio: RatioSpec::AllFp8, ..Self::fgmp(0.0) }
    }

    pub fn all_fp4() -> Self {
        QuantConfig { ratio: RatioSpec::AllFp4, ..Self::fgmp(1.0) }
    }

    pub fn label(&self) -> String {
        let mut s = format!("{}/{}", self.ratio.label(), self.policy.name());
        if matches!(self.threshold_mode, ThresholdMode::Local) {
            s.push_str("/local");
        }
        if self.sw_clip {
            s.push_str("/clip");
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn labels() {
        assert_eq!(RatioSpec::Fp4Fraction(0.7).label(), "70% FP4");
        assert_eq!(QuantConfig::all_fp8().ratio.fp4_fraction(), Some(0.0));
        assert!(QuantConfig::fgmp(0.7).label().contains("clip"));
    }
}
