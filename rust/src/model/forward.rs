//! Pure-Rust reference forward pass for the tiny transformer families.
//!
//! This is the native sibling of `python/compile/model.py`: embed → per-block
//! (norm → fused-QKV attention → norm → MLP) → final norm → tied LM head,
//! with every linear layer optionally routed through the FGMP activation
//! quantizer (the PPU, paper §4.2) exactly as `ref.fgmp_matmul_ref` does —
//! per 16-block impact scores against a threshold select FP8 vs NVFP4
//! round-trips, and the realized FP8 block fractions come back as in-graph
//! counters. Weights enter *already round-tripped* (the offline pipeline in
//! [`super::weights`] owns weight-side FGMP + SW-Clip), norms / embeddings /
//! attention internals stay in high precision — the paper's scope.
//!
//! The implementation is deterministic: parallelism ([`par_map`]) is over
//! independent output row tiles, each output accumulated in a fixed
//! (ascending-K / lane-interleaved) order by the shared blocked kernels in
//! [`crate::util::kernels`], so results do not depend on thread scheduling.

use std::collections::HashMap;

use crate::io::manifest::{LinearSpec, Manifest};
use crate::model::kv::{lock_pools, KvState, KvView, LayerKv};
use crate::model::tp::{
    concat_col_blocks, gather_qkv_cols, scatter_cols, split_range, Collective, Job, ShardPlan,
};
use crate::quant::PackedPanels;
use crate::util::kernels::MatmulScratch;
use crate::util::{kernels, par_map, Json};
use crate::{Result, BLOCK};

/// MLP activation family (mirrors `model.py`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Act {
    /// SwiGLU: FC1 fuses gate+up (2·d_ff outputs), silu(gate) ⊙ up.
    SwiGlu,
    /// GELU (tanh approximation, as `jax.nn.gelu`'s default).
    Gelu,
    /// Squared ReLU (Nemotron-style).
    Relu2,
}

/// Normalization family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NormKind {
    Rms,
    LayerNorm,
}

/// Positional-encoding family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PosKind {
    Rope,
    Learned,
}

/// Architecture descriptor — enough to rebuild the forward graph natively.
/// Serialized into `manifest.json` under the `arch` key by the synthetic
/// artifact builder; inferred from parameter shapes for older manifests.
#[derive(Debug, Clone)]
pub struct ModelArch {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub act: Act,
    pub norm: NormKind,
    pub pos: PosKind,
    pub max_seq: usize,
}

impl ModelArch {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// FC1 output width (SwiGLU fuses gate+up into one matmul).
    pub fn fc1_out(&self) -> usize {
        if self.act == Act::SwiGlu {
            2 * self.d_ff
        } else {
            self.d_ff
        }
    }

    /// The linear-layer inventory, in forward-execution order (= the order
    /// `model.py` threads them and the manifest records them).
    pub fn linears(&self) -> Vec<LinearSpec> {
        let d = self.d_model;
        let mut out = Vec::with_capacity(4 * self.n_layers);
        for l in 0..self.n_layers {
            out.push(spec(format!("blk{l}.qkv_proj"), l, "qkv_proj", d, 3 * d));
            out.push(spec(format!("blk{l}.o_proj"), l, "o_proj", d, d));
            out.push(spec(format!("blk{l}.fc1"), l, "fc1", d, self.fc1_out()));
            out.push(spec(format!("blk{l}.fc2"), l, "fc2", self.d_ff, d));
        }
        out
    }

    /// Ordered parameter list — this order is the graph argument order.
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["embed".to_string()];
        if self.pos == PosKind::Learned {
            names.push("pos_embed".into());
        }
        for l in 0..self.n_layers {
            names.push(format!("blk{l}.norm1"));
            names.push(format!("blk{l}.qkv_proj.w"));
            names.push(format!("blk{l}.o_proj.w"));
            names.push(format!("blk{l}.norm2"));
            names.push(format!("blk{l}.fc1.w"));
            names.push(format!("blk{l}.fc2.w"));
            if self.norm == NormKind::LayerNorm {
                names.push(format!("blk{l}.norm1.b"));
                names.push(format!("blk{l}.norm2.b"));
            }
        }
        names.push("final_norm".into());
        if self.norm == NormKind::LayerNorm {
            names.push("final_norm.b".into());
        }
        names
    }

    pub fn param_shape(&self, name: &str) -> Vec<usize> {
        let d = self.d_model;
        if name == "embed" {
            return vec![self.vocab, d];
        }
        if name == "pos_embed" {
            return vec![self.max_seq, d];
        }
        if name.ends_with("qkv_proj.w") {
            return vec![d, 3 * d];
        }
        if name.ends_with("o_proj.w") {
            return vec![d, d];
        }
        if name.ends_with("fc1.w") {
            return vec![d, self.fc1_out()];
        }
        if name.ends_with("fc2.w") {
            return vec![self.d_ff, d];
        }
        vec![d] // norms and biases
    }

    /// Serialize for the manifest's `arch` section.
    pub fn to_json(&self) -> Json {
        let mut m = std::collections::BTreeMap::new();
        m.insert("vocab".into(), Json::Num(self.vocab as f64));
        m.insert("d_model".into(), Json::Num(self.d_model as f64));
        m.insert("n_layers".into(), Json::Num(self.n_layers as f64));
        m.insert("n_heads".into(), Json::Num(self.n_heads as f64));
        m.insert("d_ff".into(), Json::Num(self.d_ff as f64));
        let act = match self.act {
            Act::SwiGlu => "swiglu",
            Act::Gelu => "gelu",
            Act::Relu2 => "relu2",
        };
        m.insert("act".into(), Json::Str(act.into()));
        let norm = match self.norm {
            NormKind::Rms => "rms",
            NormKind::LayerNorm => "ln",
        };
        m.insert("norm".into(), Json::Str(norm.into()));
        let pos = match self.pos {
            PosKind::Rope => "rope",
            PosKind::Learned => "learned",
        };
        m.insert("pos".into(), Json::Str(pos.into()));
        m.insert("max_seq".into(), Json::Num(self.max_seq as f64));
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let act = match v.get("act")?.as_str()? {
            "swiglu" => Act::SwiGlu,
            "gelu" => Act::Gelu,
            "relu2" => Act::Relu2,
            other => anyhow::bail!("unknown act '{other}'"),
        };
        let norm = match v.get("norm")?.as_str()? {
            "rms" => NormKind::Rms,
            "ln" => NormKind::LayerNorm,
            other => anyhow::bail!("unknown norm '{other}'"),
        };
        let pos = match v.get("pos")?.as_str()? {
            "rope" => PosKind::Rope,
            "learned" => PosKind::Learned,
            other => anyhow::bail!("unknown pos '{other}'"),
        };
        Ok(ModelArch {
            vocab: v.get("vocab")?.as_usize()?,
            d_model: v.get("d_model")?.as_usize()?,
            n_layers: v.get("n_layers")?.as_usize()?,
            n_heads: v.get("n_heads")?.as_usize()?,
            d_ff: v.get("d_ff")?.as_usize()?,
            act,
            norm,
            pos,
            max_seq: v.get("max_seq")?.as_usize()?,
        })
    }

    /// Best-effort reconstruction from parameter shapes, for manifests
    /// exported before the `arch` section existed (the python AOT path).
    /// Heads are not recoverable from shapes; assume 64-wide heads when the
    /// width divides evenly (the Llama convention), else 4 heads.
    pub fn infer(man: &Manifest) -> Result<Self> {
        let embed = man
            .param_shapes
            .get("embed")
            .ok_or_else(|| anyhow::anyhow!("manifest has no 'embed' shape"))?;
        anyhow::ensure!(embed.len() == 2, "embed shape {embed:?}");
        let (vocab, d_model) = (embed[0], embed[1]);
        let n_layers = man.linears.iter().map(|l| l.layer + 1).max().unwrap_or(0);
        anyhow::ensure!(n_layers > 0, "manifest lists no linear layers");
        let fc2 = man.linear("blk0.fc2")?;
        let fc1 = man.linear("blk0.fc1")?;
        let d_ff = fc2.k_in;
        let norm = if man.param_shapes.contains_key("final_norm.b") {
            NormKind::LayerNorm
        } else {
            NormKind::Rms
        };
        let pos = if man.param_shapes.contains_key("pos_embed") {
            PosKind::Learned
        } else {
            PosKind::Rope
        };
        let act = if fc1.n_out == 2 * d_ff {
            Act::SwiGlu
        } else if norm == NormKind::LayerNorm {
            Act::Gelu
        } else {
            Act::Relu2
        };
        let n_heads = if d_model % 64 == 0 { d_model / 64 } else { 4 };
        // Head count is a guess — wrong heads silently change attention
        // partitioning and the RoPE half-width, so be loud about it.
        eprintln!(
            "WARNING: manifest for '{}' has no 'arch' section; native runtime \
             inferred n_heads={n_heads} from d_model={d_model} — results are \
             wrong if the exporter used a different head count (re-export with \
             an arch section, or use the pjrt backend)",
            man.name
        );
        let max_seq = man
            .param_shapes
            .get("pos_embed")
            .map(|s| s[0])
            .unwrap_or(4 * man.seq.max(1));
        Ok(ModelArch {
            vocab,
            d_model,
            n_layers,
            n_heads,
            d_ff,
            act,
            norm,
            pos,
            max_seq,
        })
    }
}

fn spec(name: String, layer: usize, kind: &str, k_in: usize, n_out: usize) -> LinearSpec {
    LinearSpec { name, layer, kind: kind.to_string(), k_in, n_out }
}

/// One linear layer's weight in execution form.
#[derive(Clone, Copy)]
pub enum WeightView<'a> {
    /// Row-major `(K, N)` f32 — already-round-tripped (or raw) values.
    Dense(&'a [f32]),
    /// The k-panelized FGMP bits; the kernels decode blocks in-register.
    Packed(&'a PackedPanels),
}

/// The parameter set a forward pass executes against: dense f32 buffers
/// (embeddings, norms, unquantized weights) plus, per linear weight, an
/// optional **packed** FGMP tensor that takes precedence — the execution
/// format of the quantized datapath. Borrowed views, like the old
/// `HashMap<&str, &[f32]>` this replaces.
#[derive(Default)]
pub struct Params<'a> {
    dense: HashMap<&'a str, &'a [f32]>,
    packed: HashMap<&'a str, &'a PackedPanels>,
}

impl<'a> Params<'a> {
    pub fn new() -> Params<'a> {
        Params::default()
    }

    /// Wrap a plain name → f32 buffer map (the all-dense legacy layout).
    pub fn from_dense(dense: HashMap<&'a str, &'a [f32]>) -> Params<'a> {
        Params { dense, packed: HashMap::new() }
    }

    pub fn insert_dense(&mut self, name: &'a str, data: &'a [f32]) {
        self.dense.insert(name, data);
    }

    pub fn insert_packed(&mut self, name: &'a str, w: &'a PackedPanels) {
        self.packed.insert(name, w);
    }

    /// A parameter that must be dense (embeddings, norms, biases).
    pub fn dense(&self, name: &str) -> Result<&'a [f32]> {
        if let Some(&d) = self.dense.get(name) {
            return Ok(d);
        }
        if self.packed.contains_key(name) {
            anyhow::bail!("parameter '{name}' is packed; this consumer needs dense f32");
        }
        anyhow::bail!("missing parameter '{name}'")
    }

    /// A linear weight in whichever execution form is loaded (packed wins
    /// when both are present).
    pub fn weight(&self, name: &str) -> Result<WeightView<'a>> {
        if let Some(&p) = self.packed.get(name) {
            return Ok(WeightView::Packed(p));
        }
        if let Some(&d) = self.dense.get(name) {
            return Ok(WeightView::Dense(d));
        }
        anyhow::bail!("missing parameter '{name}'")
    }
}

/// Per-linear activation-quantization inputs (the fwd_quant graph tail).
pub struct QuantInputs<'a> {
    /// Per-linear per-input-channel weighting, each of length `k_in`.
    pub act_weights: Vec<&'a [f32]>,
    /// Per-linear impact-score thresholds.
    pub thresholds: &'a [f32],
    /// Attention-input PPU threshold (paper §4.2 applied to the attention
    /// datapath): when set, post-RoPE Q rows and every new K/V row are
    /// round-tripped block-wise to mixed FP8/NVFP4 (unit channel weighting)
    /// before use/storage, and the per-buffer high/low block mix feeds
    /// [`KvState::effective_kv_bits`]. `None` keeps attention inputs at
    /// full precision — the prior behavior, bit-for-bit. Requires
    /// `d_model % BLOCK == 0`.
    pub attn_threshold: Option<f32>,
}

/// Forward result.
pub struct ForwardOut {
    /// Row-major logits: `(B·S, V)`, or `(B, V)` when `last_only`.
    pub logits: Vec<f32>,
    /// Realized per-linear activation FP8 block fractions (quant mode only).
    pub act_fp8: Vec<f32>,
}

/// Dense `y = x·w` for row-major `x (M,K)`, `w (K,N)` — the cache-tiled,
/// register-blocked kernel from [`kernels`] (parallel over row tiles;
/// bit-identical to [`kernels::matmul_scalar`]).
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    kernels::matmul(x, w, m, k, n)
}

/// `y = x·wᵀ` for `x (M,K)` against row-major `wt (N,K)` — the tied LM
/// head, via the lane-parallel dot-product kernel.
pub fn matmul_transposed(x: &[f32], wt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    kernels::matmul_transposed(x, wt, m, k, n)
}

/// FGMP-quantized matmul: round-trip each activation row block-wise to mixed
/// FP8/NVFP4 per the impact score vs `threshold` (the PPU), then multiply
/// against already-round-tripped weights. Returns `(y, fp8_block_fraction)` —
/// the native equivalent of `ref.fgmp_matmul_ref`. Quantization and the
/// multiply both run block-structured: the PPU kernel round-trips whole
/// 16-blocks at a time and the product reuses the blocked matmul tiles.
/// `scratch` is a caller-held [`MatmulScratch`] pool the per-tile
/// quantize/output buffers are checked out of — thread one through a whole
/// forward pass so the 4·n_layers linears reuse the same allocations.
#[allow(clippy::too_many_arguments)]
pub fn fgmp_matmul(
    x: &[f32],
    w: &[f32],
    m: usize,
    k: usize,
    n: usize,
    chan_weight: &[f32],
    threshold: f32,
    scratch: &MatmulScratch,
) -> (Vec<f32>, f32) {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    assert_eq!(chan_weight.len(), k);
    assert_eq!(k % BLOCK, 0);
    fgmp_tiles(x, m, k, n, chan_weight, threshold, scratch, |xq, rows, tile| {
        kernels::matmul_rows(xq, w, rows, k, n, tile)
    })
}

/// [`fgmp_matmul`] straight off the packed bits: the PPU quantizes the new
/// activation rows exactly as the dense variant does, and the product runs
/// [`kernels::matmul_rows_packed`] — FGMP blocks decoded in-register inside
/// the tile loop, no resident dequantized weight copy anywhere. Bit-exact
/// against [`fgmp_matmul`] over [`PackedPanels::unpack_kn`].
pub fn fgmp_matmul_packed(
    x: &[f32],
    w: &PackedPanels,
    m: usize,
    chan_weight: &[f32],
    threshold: f32,
    scratch: &MatmulScratch,
) -> (Vec<f32>, f32) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    assert_eq!(chan_weight.len(), k);
    assert_eq!(k % BLOCK, 0);
    fgmp_tiles(x, m, k, n, chan_weight, threshold, scratch, |xq, rows, tile| {
        kernels::matmul_rows_packed(xq, w, rows, tile)
    })
}

/// Shared tile loop of the FGMP matmuls: PPU-quantize each MR-row tile of
/// `x` into pooled scratch, hand it to `mul` (dense or packed row kernel),
/// and collect tiles + FP8 block counts. Per-tile buffers come from (and
/// return to) `scratch`, so back-to-back calls stop reallocating.
#[allow(clippy::too_many_arguments)]
fn fgmp_tiles(
    x: &[f32],
    m: usize,
    k: usize,
    n: usize,
    chan_weight: &[f32],
    threshold: f32,
    scratch: &MatmulScratch,
    mul: impl Fn(&[f32], usize, &mut [f32]) + Sync,
) -> (Vec<f32>, f32) {
    let blocks_per_row = k / BLOCK;
    let tiles: Vec<usize> = (0..m.div_ceil(kernels::MR)).collect();
    let out = par_map(&tiles, |&t| {
        let r0 = t * kernels::MR;
        let rows = kernels::MR.min(m - r0);
        let mut xq = scratch.take();
        kernels::scratch_resize(&mut xq, rows * k);
        let mut n_fp8 = 0usize;
        for r in 0..rows {
            let xr = &x[(r0 + r) * k..(r0 + r + 1) * k];
            let xq_row = &mut xq[r * k..(r + 1) * k];
            n_fp8 += kernels::ppu_quantize_row(xr, chan_weight, threshold, xq_row);
        }
        let mut tile = scratch.take();
        kernels::scratch_resize(&mut tile, rows * n);
        mul(&xq, rows, &mut tile);
        // The quantize buffer is dead the moment the multiply returns —
        // hand it back immediately so in-flight copies stay bounded by
        // worker concurrency, not by the tile count.
        scratch.put(xq);
        (tile, n_fp8)
    });
    let total_fp8: usize = out.iter().map(|(_, f)| *f).sum();
    let mut flat = Vec::with_capacity(m * n);
    for (tile, _) in out {
        flat.extend_from_slice(&tile);
        scratch.put(tile);
    }
    let frac = total_fp8 as f32 / (m * blocks_per_row).max(1) as f32;
    (flat, frac)
}

/// One worker's shard of [`fgmp_matmul_packed`]: PPU-quantize the full-K
/// activation rows (per-16-block decisions are independent of the column
/// split, so every worker makes bit-identical choices) and multiply against
/// panels `[p0, p1)` only. Returns the `(m, cols-in-range)` partial product
/// plus the FP8 block count. Serial over row tiles — the tensor-parallel
/// driver already runs one thread per worker, so nesting [`par_map`] here
/// would oversubscribe the machine.
fn fgmp_matmul_packed_range(
    x: &[f32],
    w: &PackedPanels,
    m: usize,
    chan_weight: &[f32],
    threshold: f32,
    scratch: &MatmulScratch,
    p0: usize,
    p1: usize,
) -> (Vec<f32>, usize) {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    assert_eq!(chan_weight.len(), k);
    assert_eq!(k % BLOCK, 0);
    let ncols = (p1 * w.nr).min(n) - (p0 * w.nr).min(n);
    if ncols == 0 {
        return (Vec::new(), 0);
    }
    let mut out = vec![0.0f32; m * ncols];
    let mut n_fp8 = 0usize;
    let mut xq = scratch.take();
    for t in 0..m.div_ceil(kernels::MR) {
        let r0 = t * kernels::MR;
        let rows = kernels::MR.min(m - r0);
        kernels::scratch_resize(&mut xq, rows * k);
        for r in 0..rows {
            let xr = &x[(r0 + r) * k..(r0 + r + 1) * k];
            n_fp8 += kernels::ppu_quantize_row(xr, chan_weight, threshold, &mut xq[r * k..(r + 1) * k]);
        }
        kernels::matmul_rows_packed_range(
            &xq[..rows * k],
            w,
            rows,
            p0,
            p1,
            &mut out[r0 * ncols..(r0 + rows) * ncols],
        );
    }
    scratch.put(xq);
    (out, n_fp8)
}

fn norm_rows(kind: NormKind, x: &[f32], d: usize, g: &[f32], b: Option<&[f32]>) -> Vec<f32> {
    let mut out = vec![0.0f32; x.len()];
    for (xr, or) in x.chunks_exact(d).zip(out.chunks_exact_mut(d)) {
        match kind {
            NormKind::Rms => {
                let ss: f32 = xr.iter().map(|v| v * v).sum::<f32>() / d as f32;
                let inv = 1.0 / (ss + 1e-5).sqrt();
                for i in 0..d {
                    or[i] = xr[i] * inv * g[i];
                }
            }
            NormKind::LayerNorm => {
                let mu: f32 = xr.iter().sum::<f32>() / d as f32;
                let var: f32 = xr.iter().map(|v| (v - mu) * (v - mu)).sum::<f32>() / d as f32;
                let inv = 1.0 / (var + 1e-5).sqrt();
                let bias = b.expect("layer-norm bias");
                for i in 0..d {
                    or[i] = (xr[i] - mu) * inv * g[i] + bias[i];
                }
            }
        }
    }
    out
}

fn silu(x: f32) -> f32 {
    x / (1.0 + (-x).exp())
}

fn gelu_tanh(x: f32) -> f32 {
    // jax.nn.gelu(approximate=True)
    const C: f32 = 0.797_884_6; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

fn mlp_act(act: Act, f1: &[f32], m: usize, fc1_out: usize, d_ff: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; m * d_ff];
    match act {
        Act::SwiGlu => {
            for mi in 0..m {
                let row = &f1[mi * fc1_out..(mi + 1) * fc1_out];
                let o = &mut out[mi * d_ff..(mi + 1) * d_ff];
                for i in 0..d_ff {
                    o[i] = silu(row[i]) * row[d_ff + i];
                }
            }
        }
        Act::Gelu => {
            for (o, &v) in out.iter_mut().zip(f1) {
                *o = gelu_tanh(v);
            }
        }
        Act::Relu2 => {
            for (o, &v) in out.iter_mut().zip(f1) {
                let r = v.max(0.0);
                *o = r * r;
            }
        }
    }
    out
}

/// cos/sin for one rotary position — the single expression both the
/// full-sequence tables and the incremental decode path evaluate, so the
/// two agree bit-for-bit at every position.
fn rope_row(t: usize, half: usize, cos: &mut [f32], sin: &mut [f32]) {
    for i in 0..half {
        let freq = (-(10000.0f32.ln()) * i as f32 / half as f32).exp();
        let ang = t as f32 * freq;
        cos[i] = ang.cos();
        sin[i] = ang.sin();
    }
}

/// Rotary tables for positions `pos0..pos0+s`: `(cos, sin)`, each
/// `s × half`, row `si` holding position `pos0 + si` — `pos0 = 0` matches
/// `model.py::_rope`; nonzero starts serve the ragged cache-extension path,
/// evaluating the same [`rope_row`] expression the incremental decode step
/// uses, so the two agree bit-for-bit at every absolute position.
fn rope_tables(pos0: usize, s: usize, half: usize) -> (Vec<f32>, Vec<f32>) {
    let mut cos = vec![0.0f32; s * half];
    let mut sin = vec![0.0f32; s * half];
    for t in 0..s {
        rope_row(
            pos0 + t,
            half,
            &mut cos[t * half..(t + 1) * half],
            &mut sin[t * half..(t + 1) * half],
        );
    }
    (cos, sin)
}

/// Causal multi-head attention over fused qkv rows `(B·S, 3D)` → `(B·S, D)`.
fn attention(arch: &ModelArch, qkv: &[f32], b: usize, s: usize) -> Vec<f32> {
    let d = arch.d_model;
    let h = arch.n_heads;
    let dh = arch.head_dim();
    let half = dh / 2;
    let rope = arch.pos == PosKind::Rope;
    let (cos, sin) = if rope { rope_tables(0, s, half) } else { (Vec::new(), Vec::new()) };
    let scale = 1.0 / (dh as f32).sqrt();

    let pairs: Vec<(usize, usize)> =
        (0..b).flat_map(|bi| (0..h).map(move |hi| (bi, hi))).collect();
    let heads = par_map(&pairs, |&(bi, hi)| {
        // Gather this head's q/k/v as contiguous (S, dh) panels.
        let mut q = vec![0.0f32; s * dh];
        let mut k = vec![0.0f32; s * dh];
        let mut v = vec![0.0f32; s * dh];
        for si in 0..s {
            let row = &qkv[(bi * s + si) * 3 * d..(bi * s + si + 1) * 3 * d];
            q[si * dh..(si + 1) * dh].copy_from_slice(&row[hi * dh..(hi + 1) * dh]);
            k[si * dh..(si + 1) * dh].copy_from_slice(&row[d + hi * dh..d + (hi + 1) * dh]);
            v[si * dh..(si + 1) * dh].copy_from_slice(&row[2 * d + hi * dh..2 * d + (hi + 1) * dh]);
        }
        if rope {
            for si in 0..s {
                rotate(&mut q[si * dh..(si + 1) * dh], &cos[si * half..], &sin[si * half..], half);
                rotate(&mut k[si * dh..(si + 1) * dh], &cos[si * half..], &sin[si * half..], half);
            }
        }
        let mut o = vec![0.0f32; s * dh];
        let mut sc = vec![0.0f32; s];
        for si in 0..s {
            let qr = &q[si * dh..(si + 1) * dh];
            // Causal: only keys 0..=si contribute (the -1e30 mask + softmax
            // of model.py zeroes the rest exactly). The panels are (S, dh)
            // single-head buffers, hence d = dh, hi = 0.
            let or = &mut o[si * dh..(si + 1) * dh];
            attend_row(qr, &k, &v, si + 1, dh, 0, dh, scale, &mut sc, or);
        }
        o
    });

    // Scatter head panels back into (B·S, D).
    let mut out = vec![0.0f32; b * s * d];
    for (&(bi, hi), o) in pairs.iter().zip(&heads) {
        for si in 0..s {
            out[(bi * s + si) * d + hi * dh..(bi * s + si) * d + (hi + 1) * dh]
                .copy_from_slice(&o[si * dh..(si + 1) * dh]);
        }
    }
    out
}

/// Rotate one head row in place (rope half-split convention of model.py).
fn rotate(x: &mut [f32], cos: &[f32], sin: &[f32], half: usize) {
    for i in 0..half {
        let (a, b) = (x[i], x[i + half]);
        x[i] = a * cos[i] - b * sin[i];
        x[i + half] = a * sin[i] + b * cos[i];
    }
}

/// One causal attention output row: query `qr` (dh) against the first
/// `len` cached key/value rows of head `hi` in `(tokens, d)`-layout
/// buffers. Scores, softmax, and the weighted sum accumulate in exactly
/// [`attention`]'s per-position order, so cached attention is bit-identical
/// to full-sequence attention over the same K/V values.
#[allow(clippy::too_many_arguments)]
fn attend_row(
    qr: &[f32],
    kmat: &[f32],
    vmat: &[f32],
    len: usize,
    d: usize,
    hi: usize,
    dh: usize,
    scale: f32,
    sc: &mut [f32],
    or: &mut [f32],
) {
    let mut mx = f32::NEG_INFINITY;
    for (j, scj) in sc.iter_mut().enumerate().take(len) {
        let kr = &kmat[j * d + hi * dh..j * d + (hi + 1) * dh];
        let mut dot = 0.0f32;
        for (a, b2) in qr.iter().zip(kr) {
            dot += a * b2;
        }
        *scj = dot * scale;
        mx = mx.max(*scj);
    }
    let mut z = 0.0f32;
    for scj in sc.iter_mut().take(len) {
        *scj = (*scj - mx).exp();
        z += *scj;
    }
    or.fill(0.0);
    for j in 0..len {
        let p = sc[j] / z;
        if p == 0.0 {
            continue;
        }
        let vr = &vmat[j * d + hi * dh..j * d + (hi + 1) * dh];
        for (a, &vv) in or.iter_mut().zip(vr) {
            *a += p * vv;
        }
    }
}

/// One causal attention output row straight off a KV cache's stored pages:
/// dispatch on the view precision into the matching stored-precision kernel
/// from [`kernels`]. FP16 caches attend over their f32 spans (identical
/// arithmetic to [`attend_row`] over the materialized copy — a pure copy
/// elimination) and FP8 caches attend over raw E4M3 bytes with the decode
/// LUT inside the dot-product loops (bit-identical to materialize-then-dot
/// because `lut[b] == decode_e4m3(b)`; property-tested in
/// `tests/kernel_props.rs`).
#[allow(clippy::too_many_arguments)]
fn attend_view(
    qr: &[f32],
    kview: &KvView<'_>,
    vview: &KvView<'_>,
    len: usize,
    d: usize,
    hi: usize,
    dh: usize,
    scale: f32,
    sc: &mut [f32],
    or: &mut [f32],
) {
    match (kview, vview) {
        (KvView::F32 { pages: kp }, KvView::F32 { pages: vp }) => {
            kernels::attend_row_f32_pages(qr, kp, vp, len, d, hi, dh, scale, sc, or)
        }
        (KvView::Fp8 { pages: kp }, KvView::Fp8 { pages: vp }) => {
            kernels::attend_row_e4m3_pages(qr, kp, vp, len, d, hi, dh, scale, sc, or)
        }
        _ => unreachable!("K and V buffers of one layer share a precision"),
    }
}

/// Prefill/extend attention over `s` fused qkv rows `(s, 3D)` → `(s, D)`
/// (one sequence), appending every position's post-RoPE key and value to
/// `lkv` and attending over the cache *as stored* — FP8 caches are read as
/// raw E4M3 bytes through the LUT-in-loop kernels, never materialized to
/// f32 — so an FP8 cache sees its own round-tripped keys/values from the
/// first token, consistent with later decode steps. The new rows occupy
/// absolute positions `pos0..pos0+s`; `pos0 = 0` over an empty cache is
/// prefill (with an FP16 cache, bit-identical to [`attention`]), `pos0 =
/// rows-already-cached` extends a live session — row `si` rotates at
/// position `pos0+si` and attends over `pos0+si+1` cached rows, the exact
/// arithmetic `s` sequential [`attention_step`] calls would do (property:
/// the speculative verify pass rests on this agreement). `attn_ppu` is the
/// optional attention PPU threshold from [`QuantInputs::attn_threshold`].
fn attention_prefill(
    arch: &ModelArch,
    qkv: &[f32],
    s: usize,
    pos0: usize,
    lkv: &mut LayerKv,
    attn_ppu: Option<f32>,
) -> Vec<f32> {
    let d = arch.d_model;
    let h = arch.n_heads;
    let dh = arch.head_dim();
    let half = dh / 2;
    let rope = arch.pos == PosKind::Rope;
    let (cos, sin) = if rope { rope_tables(pos0, s, half) } else { (Vec::new(), Vec::new()) };
    let scale = 1.0 / (dh as f32).sqrt();
    debug_assert_eq!(lkv.k.rows(), pos0, "pos0 must continue the cached rows");

    // Split fused rows; rotate q and k per head; PPU-assign blocks when the
    // attention PPU is on; append k/v to the cache.
    let mut q = vec![0.0f32; s * d];
    let mut kbuf = vec![0.0f32; d];
    let (mut unit, mut ppu_tmp) = (Vec::new(), Vec::new());
    if attn_ppu.is_some() {
        unit = vec![1.0f32; d];
        ppu_tmp = vec![0.0f32; d];
    }
    let nb = d / BLOCK;
    for si in 0..s {
        let row = &qkv[si * 3 * d..(si + 1) * 3 * d];
        q[si * d..(si + 1) * d].copy_from_slice(&row[..d]);
        kbuf.copy_from_slice(&row[d..2 * d]);
        if rope {
            for hi in 0..h {
                let (c, sn) = (&cos[si * half..], &sin[si * half..]);
                rotate(&mut q[si * d + hi * dh..si * d + (hi + 1) * dh], c, sn, half);
                rotate(&mut kbuf[hi * dh..(hi + 1) * dh], c, sn, half);
            }
        }
        if let Some(t) = attn_ppu {
            // Q rows feed the datapath only (not stored): round-trip in
            // place, hi count uncounted.
            let qrow = &mut q[si * d..(si + 1) * d];
            kernels::ppu_quantize_row(qrow, &unit, t, &mut ppu_tmp);
            qrow.copy_from_slice(&ppu_tmp);
            let hi_k = kernels::ppu_quantize_row(&kbuf, &unit, t, &mut ppu_tmp);
            lkv.k.push_row(&ppu_tmp);
            lkv.k.note_ppu(hi_k, nb);
            let hi_v = kernels::ppu_quantize_row(&row[2 * d..], &unit, t, &mut ppu_tmp);
            lkv.v.push_row(&ppu_tmp);
            lkv.v.note_ppu(hi_v, nb);
        } else {
            lkv.k.push_row(&kbuf);
            lkv.v.push_row(&row[2 * d..]);
        }
    }

    // All appends are done: take the pool read lock once (a no-op for flat
    // caches) and attend over the stored pages directly.
    let lock = lock_pools([&lkv.k, &lkv.v]);
    let kview = lkv.k.view(&lock);
    let vview = lkv.v.view(&lock);

    let heads: Vec<usize> = (0..h).collect();
    let outs = par_map(&heads, |&hi| {
        let mut o = vec![0.0f32; s * dh];
        let mut sc = vec![0.0f32; pos0 + s];
        for si in 0..s {
            let qr = &q[si * d + hi * dh..si * d + (hi + 1) * dh];
            attend_view(
                qr,
                &kview,
                &vview,
                pos0 + si + 1,
                d,
                hi,
                dh,
                scale,
                &mut sc,
                &mut o[si * dh..(si + 1) * dh],
            );
        }
        o
    });

    let mut out = vec![0.0f32; s * d];
    for (hi, o) in outs.iter().enumerate() {
        for si in 0..s {
            out[si * d + hi * dh..si * d + (hi + 1) * dh]
                .copy_from_slice(&o[si * dh..(si + 1) * dh]);
        }
    }
    out
}

/// One decode step of attention for `n` independent sessions: fused qkv
/// rows `(n, 3D)`, one per session, each appended to its own cache at its
/// own position, then attended over that cache → `(n, D)`. Parallel over
/// (session, head) pairs like [`attention`] is over (batch, head). The
/// caches are read at stored precision (page views, LUT decode in-loop for
/// FP8) — no per-step materialize scratch exists on this path.
fn attention_step(
    arch: &ModelArch,
    qkv: &[f32],
    caches: &mut [&mut LayerKv],
    positions: &[usize],
    attn_ppu: Option<f32>,
) -> Vec<f32> {
    let n = positions.len();
    let d = arch.d_model;
    let h = arch.n_heads;
    let dh = arch.head_dim();
    let half = dh / 2;
    let rope = arch.pos == PosKind::Rope;
    let scale = 1.0 / (dh as f32).sqrt();

    let mut q = vec![0.0f32; n * d];
    let mut kbuf = vec![0.0f32; d];
    let (mut cos, mut sin) = (vec![0.0f32; half], vec![0.0f32; half]);
    let (mut unit, mut ppu_tmp) = (Vec::new(), Vec::new());
    if attn_ppu.is_some() {
        unit = vec![1.0f32; d];
        ppu_tmp = vec![0.0f32; d];
    }
    let nb = d / BLOCK;
    for i in 0..n {
        let row = &qkv[i * 3 * d..(i + 1) * 3 * d];
        q[i * d..(i + 1) * d].copy_from_slice(&row[..d]);
        kbuf.copy_from_slice(&row[d..2 * d]);
        if rope {
            rope_row(positions[i], half, &mut cos, &mut sin);
            for hi in 0..h {
                rotate(&mut q[i * d + hi * dh..i * d + (hi + 1) * dh], &cos, &sin, half);
                rotate(&mut kbuf[hi * dh..(hi + 1) * dh], &cos, &sin, half);
            }
        }
        if let Some(t) = attn_ppu {
            let qrow = &mut q[i * d..(i + 1) * d];
            kernels::ppu_quantize_row(qrow, &unit, t, &mut ppu_tmp);
            qrow.copy_from_slice(&ppu_tmp);
            let hi_k = kernels::ppu_quantize_row(&kbuf, &unit, t, &mut ppu_tmp);
            caches[i].k.push_row(&ppu_tmp);
            caches[i].k.note_ppu(hi_k, nb);
            let hi_v = kernels::ppu_quantize_row(&row[2 * d..], &unit, t, &mut ppu_tmp);
            caches[i].v.push_row(&ppu_tmp);
            caches[i].v.note_ppu(hi_v, nb);
        } else {
            caches[i].k.push_row(&kbuf);
            caches[i].v.push_row(&row[2 * d..]);
        }
    }

    // Appends done for every session: lock each distinct pool once (dedup —
    // engine sessions share one pool), build per-session stored-precision
    // views, then fan the (session, head) attention rows out across
    // threads. The guard stays on this thread; the views are plain slices.
    let caches_ro: Vec<&LayerKv> = caches.iter().map(|c| &**c).collect();
    let lock = lock_pools(caches_ro.iter().flat_map(|c| [&c.k, &c.v]));
    let views: Vec<(KvView<'_>, KvView<'_>)> =
        caches_ro.iter().map(|c| (c.k.view(&lock), c.v.view(&lock))).collect();

    let pairs: Vec<(usize, usize)> =
        (0..n).flat_map(|i| (0..h).map(move |hi| (i, hi))).collect();
    let rows = par_map(&pairs, |&(i, hi)| {
        let (kview, vview) = &views[i];
        let len = positions[i] + 1;
        let qr = &q[i * d + hi * dh..i * d + (hi + 1) * dh];
        let mut sc = vec![0.0f32; len];
        let mut o = vec![0.0f32; dh];
        attend_view(qr, kview, vview, len, d, hi, dh, scale, &mut sc, &mut o);
        o
    });

    let mut out = vec![0.0f32; n * d];
    for (&(i, hi), o) in pairs.iter().zip(&rows) {
        out[i * d + hi * dh..i * d + (hi + 1) * dh].copy_from_slice(o);
    }
    out
}

/// One linear application in execution order: optional calibration capture,
/// then the plain or FGMP-quantized matmul (`li` indexes the inventory),
/// off whichever weight form is loaded — dense f32 or the packed bits.
#[allow(clippy::too_many_arguments)]
fn apply_linear(
    linears: &[LinearSpec],
    params: &Params<'_>,
    quant: Option<&QuantInputs<'_>>,
    h: &[f32],
    rows: usize,
    li: usize,
    fracs: &mut [f32],
    capture: &mut Option<&mut Vec<Vec<f32>>>,
    scratch: &MatmulScratch,
) -> Result<Vec<f32>> {
    let spec = &linears[li];
    let wname = format!("{}.w", spec.name);
    let wview = params.weight(&wname)?;
    match wview {
        WeightView::Dense(w) => anyhow::ensure!(
            w.len() == spec.k_in * spec.n_out,
            "weight {} size {} != {}x{}",
            spec.name,
            w.len(),
            spec.k_in,
            spec.n_out
        ),
        WeightView::Packed(p) => anyhow::ensure!(
            p.k == spec.k_in && p.n == spec.n_out,
            "packed weight {} shape ({},{}) != ({},{})",
            spec.name,
            p.k,
            p.n,
            spec.k_in,
            spec.n_out
        ),
    }
    if let Some(cap) = capture.as_mut() {
        cap.push(h.to_vec());
    }
    if let Some(q) = quant {
        anyhow::ensure!(
            q.act_weights[li].len() == spec.k_in,
            "act weighting {} length",
            spec.name
        );
        let (y, frac) = match wview {
            WeightView::Dense(w) => fgmp_matmul(
                h,
                w,
                rows,
                spec.k_in,
                spec.n_out,
                q.act_weights[li],
                q.thresholds[li],
                scratch,
            ),
            WeightView::Packed(p) => {
                fgmp_matmul_packed(h, p, rows, q.act_weights[li], q.thresholds[li], scratch)
            }
        };
        fracs[li] = frac;
        Ok(y)
    } else {
        Ok(match wview {
            WeightView::Dense(w) => matmul(h, w, rows, spec.k_in, spec.n_out),
            WeightView::Packed(p) => kernels::matmul_packed(h, p, rows),
        })
    }
}

/// Tensor-parallel [`apply_linear`]: split the packed weight's NR-panel axis
/// into `coll.world()` contiguous byte ranges ([`split_range`]), run one
/// partial matmul per worker through the [`Collective`], and reassemble with
/// the fixed-order [`concat_col_blocks`] all-reduce. Every per-output-column
/// dot product stays whole on one worker, so the result is bit-for-bit the
/// single-worker product. Dense (non-packed) weights fall back to the
/// unsharded path — trivially bit-exact, and rare on the packed serving
/// path this exists for.
#[allow(clippy::too_many_arguments)]
fn apply_linear_tp<C: Collective>(
    linears: &[LinearSpec],
    params: &Params<'_>,
    quant: Option<&QuantInputs<'_>>,
    h: &[f32],
    rows: usize,
    li: usize,
    fracs: &mut [f32],
    scratch: &MatmulScratch,
    coll: &C,
) -> Result<Vec<f32>> {
    let spec = &linears[li];
    let wname = format!("{}.w", spec.name);
    let p = match params.weight(&wname)? {
        WeightView::Dense(_) => {
            return apply_linear(linears, params, quant, h, rows, li, fracs, &mut None, scratch)
        }
        WeightView::Packed(p) => p,
    };
    anyhow::ensure!(
        p.k == spec.k_in && p.n == spec.n_out,
        "packed weight {} shape ({},{}) != ({},{})",
        spec.name,
        p.k,
        p.n,
        spec.k_in,
        spec.n_out
    );
    let splits = split_range(p.n_panels(), coll.world());
    if let Some(q) = quant {
        anyhow::ensure!(
            q.act_weights[li].len() == spec.k_in,
            "act weighting {} length",
            spec.name
        );
        let (cw, th) = (q.act_weights[li], q.thresholds[li]);
        let jobs: Vec<Job<'_, (Vec<f32>, usize)>> = splits
            .iter()
            .map(|&(p0, p1)| {
                Box::new(move || fgmp_matmul_packed_range(h, p, rows, cw, th, scratch, p0, p1))
                    as Job<'_, (Vec<f32>, usize)>
            })
            .collect();
        let outs = coll.run(jobs);
        // Every worker PPU-quantizes the same full-K rows, so all non-empty
        // shards report the identical block count; `max` skips empty shards.
        let total_fp8 = outs.iter().map(|(_, c)| *c).max().unwrap_or(0);
        fracs[li] = total_fp8 as f32 / (rows * (p.k / BLOCK)).max(1) as f32;
        let blocks: Vec<Vec<f32>> = outs.into_iter().map(|(b, _)| b).collect();
        Ok(concat_col_blocks(rows, p.n, p.nr, &splits, &blocks))
    } else {
        let jobs: Vec<Job<'_, Vec<f32>>> = splits
            .iter()
            .map(|&(p0, p1)| {
                Box::new(move || kernels::matmul_packed_range(h, p, rows, p0, p1))
                    as Job<'_, Vec<f32>>
            })
            .collect();
        let blocks = coll.run(jobs);
        Ok(concat_col_blocks(rows, p.n, p.nr, &splits, &blocks))
    }
}

/// Run the transformer. `params` maps manifest parameter names to row-major
/// buffers; `quant` switches every linear onto the FGMP datapath; `capture`
/// (when given) receives each linear's input `(rows·k)` in execution order —
/// the calibration tap. `last_only` returns only the final position's logits
/// per batch row (the serving/generation graph).
pub fn forward(
    arch: &ModelArch,
    params: &Params<'_>,
    tokens: &[i32],
    b: usize,
    s: usize,
    quant: Option<&QuantInputs<'_>>,
    mut capture: Option<&mut Vec<Vec<f32>>>,
    last_only: bool,
) -> Result<ForwardOut> {
    let m = b * s;
    anyhow::ensure!(tokens.len() == m, "tokens length {} != B*S {}", tokens.len(), m);

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
    }
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];
    let positions: Vec<usize> = (0..m).map(|i| i % s).collect();
    let mut x = embed_rows(arch, params, tokens, &positions)?;
    let mut li = 0usize;
    let scratch = MatmulScratch::new();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear(&linears, params, quant, h, m, li, &mut fracs, &mut capture, &scratch)
    };

    for l in 0..arch.n_layers {
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| attention(arch, qkv, b, s))?;
    }

    let take: Vec<usize> = if last_only {
        // Only each batch row's final position feeds the LM head.
        (0..b).map(|bi| bi * s + s - 1).collect()
    } else {
        (0..m).collect()
    };
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Embed `tokens` into `(rows, d)` activations, adding the learned
/// positional rows `positions[i]` when the arch uses them.
fn embed_rows(
    arch: &ModelArch,
    params: &Params<'_>,
    tokens: &[i32],
    positions: &[usize],
) -> Result<Vec<f32>> {
    let d = arch.d_model;
    let embed = params.dense("embed")?;
    anyhow::ensure!(embed.len() == arch.vocab * d, "embed size mismatch");
    let mut x = vec![0.0f32; tokens.len() * d];
    for (i, &t) in tokens.iter().enumerate() {
        let t = t as usize;
        anyhow::ensure!(t < arch.vocab, "token {t} out of vocab {}", arch.vocab);
        x[i * d..(i + 1) * d].copy_from_slice(&embed[t * d..(t + 1) * d]);
    }
    if arch.pos == PosKind::Learned {
        let pe = params.dense("pos_embed")?;
        for (i, &pos) in positions.iter().enumerate() {
            anyhow::ensure!(pe.len() >= (pos + 1) * d, "pos_embed shorter than position {pos}");
            for (a, &p) in x[i * d..(i + 1) * d].iter_mut().zip(&pe[pos * d..(pos + 1) * d]) {
                *a += p;
            }
        }
    }
    Ok(x)
}

/// Run one transformer block (attention + MLP sublayers) over `rows`
/// activation rows in `x`, with `attn` supplying the attention mixing for
/// this layer's post-qkv rows and `lin` applying linear `li` of the
/// inventory to its input rows (single-engine callers close over
/// [`apply_linear`]; the tensor-parallel path closes over the sharded
/// variant). `li` is advanced past the four linears consumed. Shared
/// verbatim by the full-sequence, prefill, decode-step, and sharded paths —
/// the structural reason they agree bit-for-bit outside of attention's K/V
/// source.
fn block_forward(
    arch: &ModelArch,
    params: &Params<'_>,
    l: usize,
    x: &mut [f32],
    li: &mut usize,
    lin: &mut dyn FnMut(&[f32], usize) -> Result<Vec<f32>>,
    attn: impl FnOnce(&[f32]) -> Vec<f32>,
) -> Result<()> {
    let d = arch.d_model;
    let g1 = params.dense(&format!("blk{l}.norm1"))?;
    let b1 = if arch.norm == NormKind::LayerNorm {
        Some(params.dense(&format!("blk{l}.norm1.b"))?)
    } else {
        None
    };
    let h = norm_rows(arch.norm, x, d, g1, b1);
    let qkv = lin(&h, *li)?;
    *li += 1;
    let mixed = attn(&qkv);
    let o = lin(&mixed, *li)?;
    *li += 1;
    for (a, &v) in x.iter_mut().zip(&o) {
        *a += v;
    }

    let g2 = params.dense(&format!("blk{l}.norm2"))?;
    let b2 = if arch.norm == NormKind::LayerNorm {
        Some(params.dense(&format!("blk{l}.norm2.b"))?)
    } else {
        None
    };
    let h = norm_rows(arch.norm, x, d, g2, b2);
    let f1 = lin(&h, *li)?;
    *li += 1;
    let rows = f1.len() / arch.fc1_out();
    let act = mlp_act(arch.act, &f1, rows, arch.fc1_out(), arch.d_ff);
    let f2 = lin(&act, *li)?;
    *li += 1;
    for (a, &v) in x.iter_mut().zip(&f2) {
        *a += v;
    }
    Ok(())
}

/// Final norm + tied LM head over the selected `rows` of `x`, keeping only
/// the row indices in `take` (e.g. the last position for serving).
fn lm_head(
    arch: &ModelArch,
    params: &Params<'_>,
    x: &[f32],
    take: &[usize],
) -> Result<Vec<f32>> {
    let d = arch.d_model;
    let gf = params.dense("final_norm")?;
    let bf = if arch.norm == NormKind::LayerNorm {
        Some(params.dense("final_norm.b")?)
    } else {
        None
    };
    let xn = norm_rows(arch.norm, x, d, gf, bf);
    let mut sel = vec![0.0f32; take.len() * d];
    for (i, &r) in take.iter().enumerate() {
        sel[i * d..(i + 1) * d].copy_from_slice(&xn[r * d..(r + 1) * d]);
    }
    let embed = params.dense("embed")?;
    Ok(matmul_transposed(&sel, embed, take.len(), d, arch.vocab))
}

/// The attention PPU blocks whole rows of width `d_model`, so the knob
/// requires a block-aligned model width (every shipped preset satisfies
/// this; it fails loudly instead of mis-blocking otherwise).
fn ensure_attn_ppu_shape(arch: &ModelArch, q: &QuantInputs<'_>) -> Result<()> {
    if q.attn_threshold.is_some() {
        anyhow::ensure!(
            arch.d_model % BLOCK == 0,
            "attention PPU requires d_model % {BLOCK} == 0 (d_model {})",
            arch.d_model
        );
    }
    Ok(())
}

/// Prefill one session: run the full prompt through the transformer (one
/// sequence, `b = 1`), populating `kv` with every layer's post-RoPE K and V
/// rows, and return the **last position's** logits `(1, V)` — the serving
/// prefill. With an FP16 cache the logits are bit-identical to
/// `forward(..., last_only = true)`; with an FP8 cache the attention reads
/// the round-tripped K/V it stores, consistently with later decode steps
/// (tolerance documented in `tests/decode_props.rs`).
pub fn forward_prefill(
    arch: &ModelArch,
    params: &Params<'_>,
    tokens: &[i32],
    quant: Option<&QuantInputs<'_>>,
    kv: &mut KvState,
) -> Result<ForwardOut> {
    let s = tokens.len();
    anyhow::ensure!(s > 0, "prefill needs at least one token");
    anyhow::ensure!(s <= arch.max_seq, "prompt length {s} exceeds max_seq {}", arch.max_seq);
    anyhow::ensure!(kv.is_empty(), "prefill requires an empty KV cache");
    anyhow::ensure!(kv.layers.len() == arch.n_layers, "KV cache layer count");
    // Paged caches grab their pages here, before any compute — running out
    // surfaces as the typed KvPoolExhausted admission-backpressure error.
    // reserve() is also the copy-on-write hook: every append below goes
    // through it first, so a shared (forked/cloned/prefix-mapped) tail
    // page is unshared before push_row ever writes.
    kv.reserve(s)?;

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
        ensure_attn_ppu_shape(arch, q)?;
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];
    let positions: Vec<usize> = (0..s).collect();
    let mut x = embed_rows(arch, params, tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear(&linears, params, quant, h, s, li, &mut fracs, &mut None, &mm_scratch)
    };
    for (l, lkv) in kv.layers.iter_mut().enumerate() {
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            attention_prefill(arch, qkv, s, 0, lkv, attn_ppu)
        })?;
    }
    kv.advance(s);
    let logits = lm_head(arch, params, &x, &[s - 1])?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Prefill `n` independent sessions in one batched forward: the prompts'
/// rows are concatenated into a single `(Σsᵢ, d)` activation matrix so the
/// four linears of every block run as *one* blocked matmul over all
/// admitted prompts (the admission-amortization the serving coordinator
/// uses), while attention and the KV appends stay per-sequence. Returns the
/// last-position logits `(n, V)` in prompt order.
///
/// Per-row arithmetic is identical to [`forward_prefill`] — the blocked
/// kernels accumulate each output row independently of its tile mates — so
/// batched prefill is bit-exact against prefilling each prompt alone
/// (property-tested in `tests/decode_props.rs`). Page reservations happen
/// for every session before any compute; on [`KvPoolExhausted`] no session
/// has cached anything (earlier sessions may hold unused reservations —
/// dropping or clearing them returns the pages).
///
/// [`KvPoolExhausted`]: crate::model::kv::KvPoolExhausted
pub fn forward_prefill_batch(
    arch: &ModelArch,
    params: &Params<'_>,
    prompts: &[&[i32]],
    quant: Option<&QuantInputs<'_>>,
    kvs: &mut [&mut KvState],
) -> Result<ForwardOut> {
    let n = prompts.len();
    anyhow::ensure!(n > 0, "batched prefill needs at least one prompt");
    anyhow::ensure!(kvs.len() == n, "prompts/sessions length mismatch");
    for (i, p) in prompts.iter().enumerate() {
        anyhow::ensure!(!p.is_empty(), "prompt {i}: prefill needs at least one token");
        anyhow::ensure!(
            p.len() <= arch.max_seq,
            "prompt {i}: length {} exceeds max_seq {}",
            p.len(),
            arch.max_seq
        );
    }
    for (i, kv) in kvs.iter().enumerate() {
        anyhow::ensure!(kv.is_empty(), "session {i}: prefill requires an empty KV cache");
        anyhow::ensure!(kv.layers.len() == arch.n_layers, "session {i}: cache layer count");
    }
    for (kv, p) in kvs.iter_mut().zip(prompts) {
        kv.reserve(p.len())?;
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
        ensure_attn_ppu_shape(arch, q)?;
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];

    // Ragged layout: prompt i owns rows offs[i]..offs[i]+lens[i].
    let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    let mut offs = Vec::with_capacity(n);
    let mut tokens: Vec<i32> = Vec::new();
    let mut positions: Vec<usize> = Vec::new();
    let mut m = 0usize;
    for p in prompts {
        offs.push(m);
        tokens.extend_from_slice(p);
        positions.extend(0..p.len());
        m += p.len();
    }

    let mut x = embed_rows(arch, params, &tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let d = arch.d_model;
    let mut lin = |h: &[f32], li: usize| {
        apply_linear(&linears, params, quant, h, m, li, &mut fracs, &mut None, &mm_scratch)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<&mut LayerKv> = kvs.iter_mut().map(|kv| &mut kv.layers[l]).collect();
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            let mut out = vec![0.0f32; m * d];
            for (i, lkv) in caches.iter_mut().enumerate() {
                let (off, s_i) = (offs[i], lens[i]);
                let o = attention_prefill(
                    arch,
                    &qkv[off * 3 * d..(off + s_i) * 3 * d],
                    s_i,
                    0,
                    lkv,
                    attn_ppu,
                );
                out[off * d..(off + s_i) * d].copy_from_slice(&o);
            }
            out
        })?;
    }
    for (kv, &s_i) in kvs.iter_mut().zip(&lens) {
        kv.advance(s_i);
    }
    let take: Vec<usize> = (0..n).map(|i| offs[i] + lens[i] - 1).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// One incremental decode step for `n` independent sessions, batched: each
/// session contributes one new token at its own position, the four linears
/// of every block run as single `(n, K)` matmuls over the blocked kernels
/// (the PPU quantizes exactly the `n` new activation rows), and attention
/// reads each session's own cache. Returns the next-token logits `(n, V)`.
pub fn forward_step_batch(
    arch: &ModelArch,
    params: &Params<'_>,
    tokens: &[i32],
    kvs: &mut [&mut KvState],
    quant: Option<&QuantInputs<'_>>,
) -> Result<ForwardOut> {
    let n = tokens.len();
    anyhow::ensure!(n > 0, "decode step needs at least one session");
    anyhow::ensure!(kvs.len() == n, "tokens/sessions length mismatch");
    let positions: Vec<usize> = kvs.iter().map(|kv| kv.len()).collect();
    for (i, kv) in kvs.iter().enumerate() {
        anyhow::ensure!(!kv.is_empty(), "session {i}: decode before prefill");
        anyhow::ensure!(
            kv.len() < arch.max_seq,
            "session {i}: KV cache full at max_seq {} — roll before stepping",
            arch.max_seq
        );
        anyhow::ensure!(kv.layers.len() == arch.n_layers, "session {i}: cache layer count");
    }
    // Page reservations before any compute or cache mutation: a paged
    // session crossing a page boundary grabs its next page here, and an
    // exhausted pool surfaces as the typed error with every cache intact.
    // This is also where a forked session diverges: reserve() copy-on-
    // writes a shared tail page, so the append below never touches pages
    // the parent (or a prefix-index entry) still references.
    for kv in kvs.iter_mut() {
        kv.reserve(1)?;
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
        ensure_attn_ppu_shape(arch, q)?;
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];
    let mut x = embed_rows(arch, params, tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear(&linears, params, quant, h, n, li, &mut fracs, &mut None, &mm_scratch)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<&mut LayerKv> = kvs.iter_mut().map(|kv| &mut kv.layers[l]).collect();
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            attention_step(arch, qkv, &mut caches, &positions, attn_ppu)
        })?;
    }
    for kv in kvs.iter_mut() {
        kv.advance(1);
    }
    let take: Vec<usize> = (0..n).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Single-session convenience wrapper over [`forward_step_batch`].
pub fn forward_step(
    arch: &ModelArch,
    params: &Params<'_>,
    token: i32,
    kv: &mut KvState,
    quant: Option<&QuantInputs<'_>>,
) -> Result<ForwardOut> {
    forward_step_batch(arch, params, &[token], &mut [kv], quant)
}

/// Extend `n` live sessions by their drafted token chains in one batched
/// ragged forward — the speculative **verify pass**. Chain `i` appends
/// `chains[i].len()` rows to session `i`'s cache starting at its current
/// length, the four linears of every block run as one `(Σkᵢ, K)` blocked
/// matmul over all chains (the same admission-amortization batched prefill
/// gets), and attention extends each cache via [`attention_prefill`] with
/// `pos0 = kv.len()`. Returns logits for **every** row — `(Σkᵢ, V)` in
/// chain order, row `j` of chain `i` scoring the next token after
/// `chains[i][..=j]` — so one pass prices all k drafted positions.
///
/// Bit-exact against feeding the same tokens through `chains[i].len()`
/// sequential [`forward_step_batch`] calls: rotation, cache append order,
/// PPU decisions, and attention accumulation all evaluate the identical
/// per-position expressions (property-tested in `tests/decode_props.rs`;
/// the speculative decoder's exact-match acceptance rests on this).
/// Reservations happen for every session before any compute, so
/// [`KvPoolExhausted`] leaves all caches untouched (possibly with unused
/// reservation slack, which `truncate` returns).
///
/// [`KvPoolExhausted`]: crate::model::kv::KvPoolExhausted
pub fn forward_extend_batch(
    arch: &ModelArch,
    params: &Params<'_>,
    chains: &[&[i32]],
    kvs: &mut [&mut KvState],
    quant: Option<&QuantInputs<'_>>,
) -> Result<ForwardOut> {
    let n = chains.len();
    anyhow::ensure!(n > 0, "batched extend needs at least one chain");
    anyhow::ensure!(kvs.len() == n, "chains/sessions length mismatch");
    let starts: Vec<usize> = kvs.iter().map(|kv| kv.len()).collect();
    for (i, (c, kv)) in chains.iter().zip(kvs.iter()).enumerate() {
        anyhow::ensure!(!c.is_empty(), "chain {i}: extend needs at least one token");
        anyhow::ensure!(!kv.is_empty(), "session {i}: extend before prefill");
        anyhow::ensure!(
            starts[i] + c.len() <= arch.max_seq,
            "session {i}: extend to {} exceeds max_seq {}",
            starts[i] + c.len(),
            arch.max_seq
        );
        anyhow::ensure!(kv.layers.len() == arch.n_layers, "session {i}: cache layer count");
    }
    for (kv, c) in kvs.iter_mut().zip(chains) {
        kv.reserve(c.len())?;
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
        ensure_attn_ppu_shape(arch, q)?;
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];

    // Ragged layout: chain i owns rows offs[i]..offs[i]+lens[i], at
    // absolute positions starts[i]..starts[i]+lens[i].
    let lens: Vec<usize> = chains.iter().map(|c| c.len()).collect();
    let mut offs = Vec::with_capacity(n);
    let mut tokens: Vec<i32> = Vec::new();
    let mut positions: Vec<usize> = Vec::new();
    let mut m = 0usize;
    for (c, &st) in chains.iter().zip(&starts) {
        offs.push(m);
        tokens.extend_from_slice(c);
        positions.extend(st..st + c.len());
        m += c.len();
    }

    let mut x = embed_rows(arch, params, &tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let d = arch.d_model;
    let mut lin = |h: &[f32], li: usize| {
        apply_linear(&linears, params, quant, h, m, li, &mut fracs, &mut None, &mm_scratch)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<&mut LayerKv> = kvs.iter_mut().map(|kv| &mut kv.layers[l]).collect();
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            let mut out = vec![0.0f32; m * d];
            for (i, lkv) in caches.iter_mut().enumerate() {
                let (off, s_i) = (offs[i], lens[i]);
                let o = attention_prefill(
                    arch,
                    &qkv[off * 3 * d..(off + s_i) * 3 * d],
                    s_i,
                    starts[i],
                    lkv,
                    attn_ppu,
                );
                out[off * d..(off + s_i) * d].copy_from_slice(&o);
            }
            out
        })?;
    }
    for (kv, &s_i) in kvs.iter_mut().zip(&lens) {
        kv.advance(s_i);
    }
    // Every row feeds the LM head: the verify pass scores all k positions.
    let take: Vec<usize> = (0..m).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Shared validation for the tensor-parallel entry points: plan/shard-arch
/// consistency, and (when the attention PPU is on) that every active
/// worker's column range starts on a 16-block boundary — the per-row PPU
/// blocks width `d_model`, so shard boundaries must fall *between* blocks
/// for the sharded quantization decisions to match the unsharded ones
/// bit-for-bit.
fn ensure_tp_shapes(
    arch: &ModelArch,
    shard_arches: &[ModelArch],
    plan: &ShardPlan,
    quant: Option<&QuantInputs<'_>>,
) -> Result<()> {
    anyhow::ensure!(plan.heads.len() == plan.world, "shard plan heads/world mismatch");
    anyhow::ensure!(
        shard_arches.len() == plan.active(),
        "need one shard arch per active worker ({} != {})",
        shard_arches.len(),
        plan.active()
    );
    let dh = arch.head_dim();
    for (w, sa) in shard_arches.iter().enumerate() {
        let (h0, h1) = plan.heads[w];
        anyhow::ensure!(
            sa.n_heads == h1 - h0 && sa.d_model == (h1 - h0) * dh,
            "shard arch {w} does not match head range [{h0}, {h1})"
        );
    }
    if let Some(q) = quant {
        if q.attn_threshold.is_some() {
            ensure_attn_ppu_shape(arch, q)?;
            for (w, &(h0, _)) in plan.heads.iter().take(shard_arches.len()).enumerate() {
                anyhow::ensure!(
                    (h0 * dh) % BLOCK == 0,
                    "attention PPU requires worker boundaries on {BLOCK}-wide blocks; worker {w} \
                     starts at column {} (head {h0} x head_dim {dh}) — pick a worker count whose \
                     head split lands on block boundaries",
                    h0 * dh
                );
            }
        }
    }
    Ok(())
}

/// Tensor-parallel [`forward_prefill_batch`]: every linear runs
/// column-sharded across all `plan.world` workers ([`apply_linear_tp`]) and
/// attention fans out over the active workers' head-slices, each worker
/// appending post-RoPE K/V to its own shard of the session's KV state
/// (`kvs[session][worker]`). Per-column dot products and per-head attention
/// are untouched by the split, so logits are bit-for-bit the single-worker
/// batched prefill at any worker count.
#[allow(clippy::too_many_arguments)]
pub fn forward_prefill_batch_tp<C: Collective>(
    arch: &ModelArch,
    shard_arches: &[ModelArch],
    plan: &ShardPlan,
    params: &Params<'_>,
    coll: &C,
    prompts: &[&[i32]],
    quant: Option<&QuantInputs<'_>>,
    kvs: &mut [Vec<&mut KvState>],
) -> Result<ForwardOut> {
    let n = prompts.len();
    anyhow::ensure!(n > 0, "batched prefill needs at least one prompt");
    anyhow::ensure!(kvs.len() == n, "prompts/sessions length mismatch");
    anyhow::ensure!(coll.world() == plan.world, "collective world != shard plan world");
    ensure_tp_shapes(arch, shard_arches, plan, quant)?;
    let active = shard_arches.len();
    for (i, p) in prompts.iter().enumerate() {
        anyhow::ensure!(!p.is_empty(), "prompt {i}: prefill needs at least one token");
        anyhow::ensure!(
            p.len() <= arch.max_seq,
            "prompt {i}: length {} exceeds max_seq {}",
            p.len(),
            arch.max_seq
        );
    }
    for (i, shards) in kvs.iter().enumerate() {
        anyhow::ensure!(shards.len() == active, "session {i}: shard count != active workers");
        for (w, kv) in shards.iter().enumerate() {
            anyhow::ensure!(
                kv.is_empty(),
                "session {i} shard {w}: prefill requires an empty KV cache"
            );
            anyhow::ensure!(
                kv.layers.len() == arch.n_layers,
                "session {i} shard {w}: cache layer count"
            );
        }
    }
    for (shards, p) in kvs.iter_mut().zip(prompts) {
        for kv in shards.iter_mut() {
            kv.reserve(p.len())?;
        }
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];

    let lens: Vec<usize> = prompts.iter().map(|p| p.len()).collect();
    let mut offs = Vec::with_capacity(n);
    let mut tokens: Vec<i32> = Vec::new();
    let mut positions: Vec<usize> = Vec::new();
    let mut m = 0usize;
    for p in prompts {
        offs.push(m);
        tokens.extend_from_slice(p);
        positions.extend(0..p.len());
        m += p.len();
    }

    let mut x = embed_rows(arch, params, &tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let d = arch.d_model;
    let dh = arch.head_dim();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear_tp(&linears, params, quant, h, m, li, &mut fracs, &mm_scratch, coll)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<Vec<&mut LayerKv>> =
            (0..active).map(|_| Vec::with_capacity(n)).collect();
        for shards in kvs.iter_mut() {
            for (w, kv) in shards.iter_mut().enumerate() {
                caches[w].push(&mut kv.layers[l]);
            }
        }
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            let jobs: Vec<Job<'_, Vec<f32>>> = caches
                .into_iter()
                .enumerate()
                .map(|(w, mut cache_w)| {
                    let sarch = &shard_arches[w];
                    let (h0, _) = plan.heads[w];
                    let dw = sarch.d_model;
                    let qkv_w = gather_qkv_cols(qkv, m, d, h0 * dh, h0 * dh + dw);
                    let (offs, lens) = (&offs, &lens);
                    Box::new(move || {
                        let mut out_w = vec![0.0f32; m * dw];
                        for (i, lkv) in cache_w.iter_mut().enumerate() {
                            let (off, s_i) = (offs[i], lens[i]);
                            let o = attention_prefill(
                                sarch,
                                &qkv_w[off * 3 * dw..(off + s_i) * 3 * dw],
                                s_i,
                                0,
                                lkv,
                                attn_ppu,
                            );
                            out_w[off * dw..(off + s_i) * dw].copy_from_slice(&o);
                        }
                        out_w
                    }) as Job<'_, Vec<f32>>
                })
                .collect();
            let outs = coll.run(jobs);
            let mut mixed = vec![0.0f32; m * d];
            for (w, o) in outs.iter().enumerate() {
                let (h0, _) = plan.heads[w];
                scatter_cols(o, m, shard_arches[w].d_model, &mut mixed, d, h0 * dh);
            }
            mixed
        })?;
    }
    for (shards, &s_i) in kvs.iter_mut().zip(&lens) {
        for kv in shards.iter_mut() {
            kv.advance(s_i);
        }
    }
    let take: Vec<usize> = (0..n).map(|i| offs[i] + lens[i] - 1).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Tensor-parallel [`forward_step_batch`]: one decode step for `n` sessions
/// whose KV lives in per-worker shards (`kvs[session][worker]`, one entry
/// per *active* worker of `plan`). Bit-for-bit identical logits to the
/// single-worker step at any worker count.
pub fn forward_step_batch_tp<C: Collective>(
    arch: &ModelArch,
    shard_arches: &[ModelArch],
    plan: &ShardPlan,
    params: &Params<'_>,
    coll: &C,
    tokens: &[i32],
    kvs: &mut [Vec<&mut KvState>],
    quant: Option<&QuantInputs<'_>>,
) -> Result<ForwardOut> {
    let n = tokens.len();
    anyhow::ensure!(n > 0, "decode step needs at least one session");
    anyhow::ensure!(kvs.len() == n, "tokens/sessions length mismatch");
    anyhow::ensure!(coll.world() == plan.world, "collective world != shard plan world");
    ensure_tp_shapes(arch, shard_arches, plan, quant)?;
    let active = shard_arches.len();
    for (i, shards) in kvs.iter().enumerate() {
        anyhow::ensure!(shards.len() == active, "session {i}: shard count != active workers");
        let len0 = shards.first().map(|kv| kv.len()).unwrap_or(0);
        anyhow::ensure!(len0 > 0, "session {i}: decode before prefill");
        anyhow::ensure!(
            len0 < arch.max_seq,
            "session {i}: KV cache full at max_seq {} — roll before stepping",
            arch.max_seq
        );
        for (w, kv) in shards.iter().enumerate() {
            anyhow::ensure!(kv.len() == len0, "session {i} shard {w}: shard lengths diverged");
            anyhow::ensure!(
                kv.layers.len() == arch.n_layers,
                "session {i} shard {w}: cache layer count"
            );
        }
    }
    let positions: Vec<usize> = kvs.iter().map(|shards| shards[0].len()).collect();
    for shards in kvs.iter_mut() {
        for kv in shards.iter_mut() {
            kv.reserve(1)?;
        }
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];
    let mut x = embed_rows(arch, params, tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let d = arch.d_model;
    let dh = arch.head_dim();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear_tp(&linears, params, quant, h, n, li, &mut fracs, &mm_scratch, coll)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<Vec<&mut LayerKv>> =
            (0..active).map(|_| Vec::with_capacity(n)).collect();
        for shards in kvs.iter_mut() {
            for (w, kv) in shards.iter_mut().enumerate() {
                caches[w].push(&mut kv.layers[l]);
            }
        }
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            let jobs: Vec<Job<'_, Vec<f32>>> = caches
                .into_iter()
                .enumerate()
                .map(|(w, mut cache_w)| {
                    let sarch = &shard_arches[w];
                    let (h0, _) = plan.heads[w];
                    let dw = sarch.d_model;
                    let qkv_w = gather_qkv_cols(qkv, n, d, h0 * dh, h0 * dh + dw);
                    let positions = &positions;
                    Box::new(move || {
                        attention_step(sarch, &qkv_w, &mut cache_w, positions, attn_ppu)
                    }) as Job<'_, Vec<f32>>
                })
                .collect();
            let outs = coll.run(jobs);
            let mut mixed = vec![0.0f32; n * d];
            for (w, o) in outs.iter().enumerate() {
                let (h0, _) = plan.heads[w];
                scatter_cols(o, n, shard_arches[w].d_model, &mut mixed, d, h0 * dh);
            }
            mixed
        })?;
    }
    for shards in kvs.iter_mut() {
        for kv in shards.iter_mut() {
            kv.advance(1);
        }
    }
    let take: Vec<usize> = (0..n).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Tensor-parallel [`forward_extend_batch`]: the speculative verify pass
/// over per-worker KV shards (`kvs[session][worker]`). Column-sharded
/// linears + head-split cache extension, bit-for-bit the single-worker
/// ragged extend at any worker count (the same argument as the prefill and
/// step TP variants: per-column dot products and per-head attention are
/// untouched by the split).
#[allow(clippy::too_many_arguments)]
pub fn forward_extend_batch_tp<C: Collective>(
    arch: &ModelArch,
    shard_arches: &[ModelArch],
    plan: &ShardPlan,
    params: &Params<'_>,
    coll: &C,
    chains: &[&[i32]],
    kvs: &mut [Vec<&mut KvState>],
    quant: Option<&QuantInputs<'_>>,
) -> Result<ForwardOut> {
    let n = chains.len();
    anyhow::ensure!(n > 0, "batched extend needs at least one chain");
    anyhow::ensure!(kvs.len() == n, "chains/sessions length mismatch");
    anyhow::ensure!(coll.world() == plan.world, "collective world != shard plan world");
    ensure_tp_shapes(arch, shard_arches, plan, quant)?;
    let active = shard_arches.len();
    let mut starts = Vec::with_capacity(n);
    for (i, (c, shards)) in chains.iter().zip(kvs.iter()).enumerate() {
        anyhow::ensure!(!c.is_empty(), "chain {i}: extend needs at least one token");
        anyhow::ensure!(shards.len() == active, "session {i}: shard count != active workers");
        let len0 = shards.first().map(|kv| kv.len()).unwrap_or(0);
        anyhow::ensure!(len0 > 0, "session {i}: extend before prefill");
        anyhow::ensure!(
            len0 + c.len() <= arch.max_seq,
            "session {i}: extend to {} exceeds max_seq {}",
            len0 + c.len(),
            arch.max_seq
        );
        for (w, kv) in shards.iter().enumerate() {
            anyhow::ensure!(kv.len() == len0, "session {i} shard {w}: shard lengths diverged");
            anyhow::ensure!(
                kv.layers.len() == arch.n_layers,
                "session {i} shard {w}: cache layer count"
            );
        }
        starts.push(len0);
    }
    for (shards, c) in kvs.iter_mut().zip(chains) {
        for kv in shards.iter_mut() {
            kv.reserve(c.len())?;
        }
    }

    let linears = arch.linears();
    if let Some(q) = quant {
        anyhow::ensure!(q.act_weights.len() == linears.len(), "act_weights count");
        anyhow::ensure!(q.thresholds.len() == linears.len(), "thresholds count");
    }
    let attn_ppu = quant.and_then(|q| q.attn_threshold);
    let mut fracs = vec![0.0f32; if quant.is_some() { linears.len() } else { 0 }];

    let lens: Vec<usize> = chains.iter().map(|c| c.len()).collect();
    let mut offs = Vec::with_capacity(n);
    let mut tokens: Vec<i32> = Vec::new();
    let mut positions: Vec<usize> = Vec::new();
    let mut m = 0usize;
    for (c, &st) in chains.iter().zip(&starts) {
        offs.push(m);
        tokens.extend_from_slice(c);
        positions.extend(st..st + c.len());
        m += c.len();
    }

    let mut x = embed_rows(arch, params, &tokens, &positions)?;
    let mut li = 0usize;
    let mm_scratch = MatmulScratch::new();
    let d = arch.d_model;
    let dh = arch.head_dim();
    let mut lin = |h: &[f32], li: usize| {
        apply_linear_tp(&linears, params, quant, h, m, li, &mut fracs, &mm_scratch, coll)
    };
    for l in 0..arch.n_layers {
        let mut caches: Vec<Vec<&mut LayerKv>> =
            (0..active).map(|_| Vec::with_capacity(n)).collect();
        for shards in kvs.iter_mut() {
            for (w, kv) in shards.iter_mut().enumerate() {
                caches[w].push(&mut kv.layers[l]);
            }
        }
        block_forward(arch, params, l, &mut x, &mut li, &mut lin, |qkv| {
            let jobs: Vec<Job<'_, Vec<f32>>> = caches
                .into_iter()
                .enumerate()
                .map(|(w, mut cache_w)| {
                    let sarch = &shard_arches[w];
                    let (h0, _) = plan.heads[w];
                    let dw = sarch.d_model;
                    let qkv_w = gather_qkv_cols(qkv, m, d, h0 * dh, h0 * dh + dw);
                    let (offs, lens, starts) = (&offs, &lens, &starts);
                    Box::new(move || {
                        let mut out_w = vec![0.0f32; m * dw];
                        for (i, lkv) in cache_w.iter_mut().enumerate() {
                            let (off, s_i) = (offs[i], lens[i]);
                            let o = attention_prefill(
                                sarch,
                                &qkv_w[off * 3 * dw..(off + s_i) * 3 * dw],
                                s_i,
                                starts[i],
                                lkv,
                                attn_ppu,
                            );
                            out_w[off * dw..(off + s_i) * dw].copy_from_slice(&o);
                        }
                        out_w
                    }) as Job<'_, Vec<f32>>
                })
                .collect();
            let outs = coll.run(jobs);
            let mut mixed = vec![0.0f32; m * d];
            for (w, o) in outs.iter().enumerate() {
                let (h0, _) = plan.heads[w];
                scatter_cols(o, m, shard_arches[w].d_model, &mut mixed, d, h0 * dh);
            }
            mixed
        })?;
    }
    for (shards, &s_i) in kvs.iter_mut().zip(&lens) {
        for kv in shards.iter_mut() {
            kv.advance(s_i);
        }
    }
    let take: Vec<usize> = (0..m).collect();
    let logits = lm_head(arch, params, &x, &take)?;
    Ok(ForwardOut { logits, act_fp8: fracs })
}

/// Masked next-token NLL per batch row — `model.py::nll` semantics: position
/// `t ≥ 1` is scored iff `mask[t] = 1`, predicting `tokens[t]` from the
/// logits at `t−1`. Returns `(nll_sum (B,), ntok (B,))`.
pub fn masked_nll(
    logits: &[f32],
    tokens: &[i32],
    mask: &[f32],
    b: usize,
    s: usize,
    vocab: usize,
) -> (Vec<f32>, Vec<f32>) {
    assert_eq!(logits.len(), b * s * vocab);
    assert_eq!(tokens.len(), b * s);
    assert_eq!(mask.len(), b * s);
    let rows: Vec<usize> = (0..b).collect();
    let per_row = par_map(&rows, |&bi| {
        let mut nll = 0.0f32;
        let mut ntok = 0.0f32;
        for t in 0..s - 1 {
            let mw = mask[bi * s + t + 1];
            if mw == 0.0 {
                continue;
            }
            let row = &logits[(bi * s + t) * vocab..(bi * s + t + 1) * vocab];
            let tgt = tokens[bi * s + t + 1] as usize;
            let mx = row.iter().fold(f32::NEG_INFINITY, |a, &v| a.max(v));
            let z: f32 = row.iter().map(|&v| (v - mx).exp()).sum();
            let logp = row[tgt] - mx - z.ln();
            nll -= logp * mw;
            ntok += mw;
        }
        (nll, ntok)
    });
    (per_row.iter().map(|r| r.0).collect(), per_row.iter().map(|r| r.1).collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn tiny_arch() -> ModelArch {
        ModelArch {
            vocab: 32,
            d_model: 32,
            n_layers: 1,
            n_heads: 2,
            d_ff: 32,
            act: Act::SwiGlu,
            norm: NormKind::Rms,
            pos: PosKind::Rope,
            max_seq: 16,
        }
    }

    fn random_params(arch: &ModelArch, seed: u64) -> Vec<(String, Vec<f32>)> {
        let mut rng = Rng::new(seed);
        arch.param_names()
            .iter()
            .map(|n| {
                let shape = arch.param_shape(n);
                let len: usize = shape.iter().product();
                let data = if n.contains("norm") {
                    vec![1.0f32; len]
                } else {
                    rng.normal_vec(len, 0.05)
                };
                (n.clone(), data)
            })
            .collect()
    }

    fn param_map(params: &[(String, Vec<f32>)]) -> Params<'_> {
        Params::from_dense(params.iter().map(|(n, v)| (n.as_str(), v.as_slice())).collect())
    }

    #[test]
    fn matmul_matches_manual() {
        // (2,3)·(3,2)
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        let y = matmul(&x, &w, 2, 3, 2);
        assert_eq!(y, vec![4.0, 5.0, 10.0, 11.0]);
        // transposed variant: same product via wt = wᵀ (2,3)
        let wt = [1.0, 0.0, 1.0, 0.0, 1.0, 1.0];
        let yt = matmul_transposed(&x, &wt, 2, 3, 2);
        assert_eq!(yt, y);
    }

    #[test]
    fn fgmp_matmul_extreme_thresholds() {
        let mut rng = Rng::new(3);
        let (m, k, n) = (4, BLOCK * 2, 8);
        let x = rng.normal_vec(m * k, 2.0);
        let w = rng.normal_vec(k * n, 0.2);
        let cw = vec![1.0f32; k];
        let scratch = MatmulScratch::new();
        // threshold −1: every block FP8 (scores ≥ 0)
        let (y8, f8) = fgmp_matmul(&x, &w, m, k, n, &cw, -1.0, &scratch);
        assert_eq!(f8, 1.0);
        // matches an e4m3 pre-roundtrip + plain matmul
        let xq: Vec<f32> = x.iter().map(|&v| crate::quant::quant_e4m3(v)).collect();
        let want = matmul(&xq, &w, m, k, n);
        assert_eq!(y8, want);
        // +inf: every block NVFP4
        let (_, f4) = fgmp_matmul(&x, &w, m, k, n, &cw, f32::INFINITY, &scratch);
        assert_eq!(f4, 0.0);
    }

    #[test]
    fn forward_shapes_and_finiteness() {
        let arch = tiny_arch();
        let params = random_params(&arch, 7);
        let pm = param_map(&params);
        let (b, s) = (2, 8);
        let tokens: Vec<i32> = (0..b * s).map(|i| (i % arch.vocab) as i32).collect();
        let out = forward(&arch, &pm, &tokens, b, s, None, None, false).unwrap();
        assert_eq!(out.logits.len(), b * s * arch.vocab);
        assert!(out.logits.iter().all(|v| v.is_finite()));
        assert!(out.act_fp8.is_empty());
        let last = forward(&arch, &pm, &tokens, b, s, None, None, true).unwrap();
        assert_eq!(last.logits.len(), b * arch.vocab);
        // last_only rows equal the corresponding full-logit rows
        for bi in 0..b {
            let full = &out.logits[(bi * s + s - 1) * arch.vocab..(bi * s + s) * arch.vocab];
            let lo = &last.logits[bi * arch.vocab..(bi + 1) * arch.vocab];
            assert_eq!(full, lo);
        }
    }

    #[test]
    fn forward_is_causal() {
        // Changing the final token must not change earlier positions' logits.
        let arch = tiny_arch();
        let params = random_params(&arch, 11);
        let pm = param_map(&params);
        let (b, s) = (1, 8);
        let mut tokens: Vec<i32> = (0..s as i32).collect();
        let out1 = forward(&arch, &pm, &tokens, b, s, None, None, false).unwrap();
        tokens[s - 1] = 31;
        let out2 = forward(&arch, &pm, &tokens, b, s, None, None, false).unwrap();
        let v = arch.vocab;
        assert_eq!(&out1.logits[..(s - 1) * v], &out2.logits[..(s - 1) * v]);
        assert_ne!(&out1.logits[(s - 1) * v..], &out2.logits[(s - 1) * v..]);
    }

    #[test]
    fn quant_mode_counts_fractions_and_perturbs() {
        let arch = tiny_arch();
        let params = random_params(&arch, 13);
        let pm = param_map(&params);
        let (b, s) = (2, 8);
        let tokens: Vec<i32> = (0..b * s).map(|i| ((i * 5) % arch.vocab) as i32).collect();
        let linears = arch.linears();
        let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
        let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
        let thr_fp8 = vec![-1.0f32; linears.len()];
        let q = QuantInputs { act_weights: awr.clone(), thresholds: &thr_fp8, attn_threshold: None };
        let out8 = forward(&arch, &pm, &tokens, b, s, Some(&q), None, false).unwrap();
        assert!(out8.act_fp8.iter().all(|&f| f == 1.0));
        let thr_fp4 = vec![f32::INFINITY; linears.len()];
        let q4 = QuantInputs { act_weights: awr, thresholds: &thr_fp4, attn_threshold: None };
        let out4 = forward(&arch, &pm, &tokens, b, s, Some(&q4), None, false).unwrap();
        assert!(out4.act_fp8.iter().all(|&f| f == 0.0));
        assert_ne!(out8.logits, out4.logits);
    }

    #[test]
    fn capture_collects_linear_inputs() {
        let arch = tiny_arch();
        let params = random_params(&arch, 17);
        let pm = param_map(&params);
        let (b, s) = (1, 4);
        let tokens = vec![1i32; b * s];
        let mut caps: Vec<Vec<f32>> = Vec::new();
        forward(&arch, &pm, &tokens, b, s, None, Some(&mut caps), false).unwrap();
        let linears = arch.linears();
        assert_eq!(caps.len(), linears.len());
        for (c, l) in caps.iter().zip(&linears) {
            assert_eq!(c.len(), b * s * l.k_in, "capture width for {}", l.name);
        }
    }

    #[test]
    fn nll_masks_and_normalizes() {
        // Uniform logits → nll per scored token = ln(V).
        let (b, s, v) = (1, 4, 8);
        let logits = vec![0.0f32; b * s * v];
        let tokens = vec![3i32; b * s];
        let mut mask = vec![1.0f32; b * s];
        mask[1] = 0.0; // drop one scored position
        let (nll, ntok) = masked_nll(&logits, &tokens, &mask, b, s, v);
        assert_eq!(ntok[0], 2.0); // positions 2 and 3 (t=1 masked, t=0 never scored)
        let want = 2.0 * (v as f32).ln();
        assert!((nll[0] - want).abs() < 1e-5, "{} vs {want}", nll[0]);
    }

    #[test]
    fn arch_roundtrips_through_json() {
        let arch = tiny_arch();
        let j = arch.to_json();
        let back = ModelArch::from_json(&j).unwrap();
        assert_eq!(back.d_model, arch.d_model);
        assert_eq!(back.act, arch.act);
        assert_eq!(back.norm, arch.norm);
        assert_eq!(back.pos, arch.pos);
        assert_eq!(back.param_names(), arch.param_names());
    }

    #[test]
    fn extend_batch_matches_sequential_steps() {
        use crate::model::kv::KvPrecision;
        use crate::quant::{FgmpTensor, Precision};

        // The speculative verify pass in miniature: a ragged batched extend
        // over two live sessions must produce, row for row, the exact logits
        // that stepping the same tokens one at a time would — across both KV
        // precisions, over packed weights, with the attention PPU on.
        let arch = ModelArch { n_layers: 2, ..tiny_arch() };
        let dense = random_params(&arch, 41);
        let linears = arch.linears();
        let mut rng = Rng::new(43);
        let packed: Vec<(String, PackedPanels)> = linears
            .iter()
            .map(|l| {
                let kb = l.k_in / BLOCK;
                let w = rng.normal_vec(l.n_out * l.k_in, 0.1);
                let prec: Vec<Precision> = (0..l.n_out * kb)
                    .map(|i| if i % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 })
                    .collect();
                let t = FgmpTensor::pack(&[l.n_out, l.k_in], &w, &prec, None);
                (format!("{}.w", l.name), PackedPanels::from_tensor(&t, kernels::NR))
            })
            .collect();
        let mut pm = Params::new();
        for (n, v) in &dense {
            if !n.contains("qkv_proj") && !n.contains("o_proj") && !n.contains("fc") {
                pm.insert_dense(n, v);
            }
        }
        for (n, p) in &packed {
            pm.insert_packed(n, p);
        }
        let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
        let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
        let thr = vec![0.3f32; linears.len()];
        let q = QuantInputs { act_weights: awr, thresholds: &thr, attn_threshold: Some(0.5) };

        let prompts: Vec<Vec<i32>> = vec![(1..6).collect(), (2..9).collect()];
        let prefs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let chains: Vec<Vec<i32>> = vec![vec![4, 9, 2], vec![7, 1]];
        let crefs: Vec<&[i32]> = chains.iter().map(|c| c.as_slice()).collect();

        for precision in [KvPrecision::Fp16, KvPrecision::Fp8] {
            // Oracle: prefill, then feed each chain token one step at a time.
            let mut kv_seq: Vec<KvState> =
                prompts.iter().map(|_| KvState::new(&arch, precision)).collect();
            {
                let mut kvs: Vec<&mut KvState> = kv_seq.iter_mut().collect();
                forward_prefill_batch(&arch, &pm, &prefs, Some(&q), &mut kvs).unwrap();
            }
            let mut want: Vec<Vec<Vec<f32>>> = Vec::new(); // [session][step] -> logits row
            for (kv, chain) in kv_seq.iter_mut().zip(&chains) {
                let mut rows = Vec::new();
                for &t in chain.iter() {
                    let out = forward_step(&arch, &pm, t, kv, Some(&q)).unwrap();
                    rows.push(out.logits);
                }
                want.push(rows);
            }

            // Extend: same tokens in one ragged batched pass.
            let mut kv_ext: Vec<KvState> =
                prompts.iter().map(|_| KvState::new(&arch, precision)).collect();
            {
                let mut kvs: Vec<&mut KvState> = kv_ext.iter_mut().collect();
                forward_prefill_batch(&arch, &pm, &prefs, Some(&q), &mut kvs).unwrap();
                let out = forward_extend_batch(&arch, &pm, &crefs, &mut kvs, Some(&q)).unwrap();
                let v = arch.vocab;
                let mut off = 0usize;
                for (i, chain) in chains.iter().enumerate() {
                    for (j, row) in want[i].iter().enumerate() {
                        let got = &out.logits[(off + j) * v..(off + j + 1) * v];
                        assert_eq!(got, row.as_slice(), "chain {i} step {j} {precision:?}");
                    }
                    off += chain.len();
                }
            }
            // Caches end bit-identical to the sequential path.
            for (i, (a, b)) in kv_ext.iter().zip(&kv_seq).enumerate() {
                assert_eq!(a.len(), b.len(), "session {i} len");
                assert_eq!(a.stored_bits(), b.stored_bits(), "session {i} stored bits");
                assert_eq!(
                    a.effective_kv_bits(),
                    b.effective_kv_bits(),
                    "session {i} effective bits"
                );
            }
        }
    }

    #[test]
    fn tp_forward_bit_exact_vs_single_worker() {
        use crate::model::kv::KvPrecision;
        use crate::model::tp::{shard_arch, ThreadCollective};
        use crate::quant::{FgmpTensor, Precision};

        // Two layers + PPU attention over packed linears — the full sharded
        // datapath (column-split matmuls, head-split attention, per-shard
        // KV) against the unsharded oracle, bit for bit.
        let arch = ModelArch { n_layers: 2, ..tiny_arch() };
        let dense = random_params(&arch, 23);
        let linears = arch.linears();
        let mut rng = Rng::new(29);
        let packed: Vec<(String, PackedPanels)> = linears
            .iter()
            .map(|l| {
                let kb = l.k_in / BLOCK;
                let w = rng.normal_vec(l.n_out * l.k_in, 0.1);
                let prec: Vec<Precision> = (0..l.n_out * kb)
                    .map(|i| if i % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 })
                    .collect();
                let t = FgmpTensor::pack(&[l.n_out, l.k_in], &w, &prec, None);
                (format!("{}.w", l.name), PackedPanels::from_tensor(&t, kernels::NR))
            })
            .collect();
        let mut pm = Params::new();
        for (n, v) in &dense {
            if !n.contains("qkv_proj") && !n.contains("o_proj") && !n.contains("fc") {
                pm.insert_dense(n, v);
            }
        }
        for (n, p) in &packed {
            pm.insert_packed(n, p);
        }
        let aw: Vec<Vec<f32>> = linears.iter().map(|l| vec![1.0f32; l.k_in]).collect();
        let awr: Vec<&[f32]> = aw.iter().map(|v| v.as_slice()).collect();
        let thr = vec![0.3f32; linears.len()];
        let q = QuantInputs { act_weights: awr, thresholds: &thr, attn_threshold: Some(0.5) };

        let prompts: Vec<Vec<i32>> = vec![(1..7).collect(), (3..11).collect()];
        let prefs: Vec<&[i32]> = prompts.iter().map(|p| p.as_slice()).collect();
        let steps = 4usize;

        for precision in [KvPrecision::Fp16, KvPrecision::Fp8] {
            // Single-worker oracle.
            let mut kv_ref: Vec<KvState> =
                prompts.iter().map(|_| KvState::new(&arch, precision)).collect();
            let mut want = Vec::new();
            {
                let mut kvs: Vec<&mut KvState> = kv_ref.iter_mut().collect();
                let out =
                    forward_prefill_batch(&arch, &pm, &prefs, Some(&q), &mut kvs).unwrap();
                want.push((out.logits, out.act_fp8));
                for st in 0..steps {
                    let toks: Vec<i32> = (0..prompts.len()).map(|i| (st * 3 + i) as i32).collect();
                    let out = forward_step_batch(&arch, &pm, &toks, &mut kvs, Some(&q)).unwrap();
                    want.push((out.logits, out.act_fp8));
                }
            }

            for world in [1usize, 2, 4] {
                let plan = ShardPlan::new(&arch, world).unwrap();
                let arches: Vec<ModelArch> = plan
                    .heads
                    .iter()
                    .filter(|(h0, h1)| h1 > h0)
                    .map(|&(h0, h1)| shard_arch(&arch, h0, h1))
                    .collect();
                let coll = ThreadCollective { world };
                let mut shards: Vec<Vec<KvState>> = prompts
                    .iter()
                    .map(|_| arches.iter().map(|sa| KvState::new(sa, precision)).collect())
                    .collect();
                let mut kvs: Vec<Vec<&mut KvState>> =
                    shards.iter_mut().map(|s| s.iter_mut().collect()).collect();
                let out = forward_prefill_batch_tp(
                    &arch, &arches, &plan, &pm, &coll, &prefs, Some(&q), &mut kvs,
                )
                .unwrap();
                assert_eq!(out.logits, want[0].0, "prefill logits world={world}");
                assert_eq!(out.act_fp8, want[0].1, "prefill fracs world={world}");
                for st in 0..steps {
                    let toks: Vec<i32> = (0..prompts.len()).map(|i| (st * 3 + i) as i32).collect();
                    let out = forward_step_batch_tp(
                        &arch, &arches, &plan, &pm, &coll, &toks, &mut kvs, Some(&q),
                    )
                    .unwrap();
                    assert_eq!(out.logits, want[st + 1].0, "step {st} logits world={world}");
                    assert_eq!(out.act_fp8, want[st + 1].1, "step {st} fracs world={world}");
                }
                // The shards jointly hold exactly the oracle's rows.
                for (sess, refkv) in shards.iter().zip(&kv_ref) {
                    assert_eq!(sess.iter().map(|s| s.len()).max().unwrap(), refkv.len());
                    let bits: u64 = sess.iter().map(|s| s.stored_bits()).sum();
                    assert_eq!(bits, refkv.stored_bits(), "stored bits world={world}");
                }
            }
        }
    }
}
