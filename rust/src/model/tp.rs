//! Tensor-parallel sharding primitives for the multi-worker engine.
//!
//! The serving-side tensor parallelism (DESIGN.md / ROADMAP "tensor-parallel
//! serving") is column parallelism over the *stored* FGMP layout: each packed
//! linear is split along its NR-panel axis into contiguous byte ranges
//! (`PackedPanels::panel_range` — no re-pack, no decode), and attention is
//! split along the head axis so each worker owns a head-slice of the KV pool.
//! Both splits keep every per-output-column dot product whole on exactly one
//! worker, so the combine step is a *fixed-order concatenation* of disjoint
//! column blocks — pure data movement, never floating-point summation — and
//! sharded logits are bit-for-bit identical to the single-worker engine at
//! any worker count.
//!
//! The worker-communication boundary is the [`Collective`] trait. The
//! in-process [`ThreadCollective`] runs one scoped thread per worker
//! ([`crate::util::par_run_once`]); a process- or RPC-backed transport can
//! slot in later by implementing the same scatter/join contract. Under
//! shared memory "broadcast" is free (workers capture shared slices) and the
//! all-reduce is [`concat_col_blocks`]; a remote transport would make both
//! explicit sends.

use anyhow::Result;

use super::forward::ModelArch;
use crate::util::par_run_once;

/// One worker's unit of work: runs once on that worker, returns its shard
/// result. Boxed so a [`Collective`] can ship heterogeneous closures.
pub type Job<'env, R> = Box<dyn FnOnce() -> R + Send + 'env>;

/// Scatter/join boundary between the tensor-parallel driver and its workers.
///
/// `run` hands job `w` to worker `w` and returns the results in worker order
/// (the deterministic "fixed order" the bit-exactness guarantee leans on).
/// Implementations must run all jobs to completion even when they block on
/// each other — see [`par_run_once`].
pub trait Collective: Sync {
    /// Number of workers jobs are split across.
    fn world(&self) -> usize;
    /// Execute one job per worker; results in worker (input) order.
    fn run<R: Send>(&self, jobs: Vec<Job<'_, R>>) -> Vec<R>;
}

/// In-process transport: one scoped thread per worker, job 0 inline.
///
/// Worker panics do not abort the process: [`par_run_once`] catches each
/// job's panic, joins every worker, and re-raises the first failure as a
/// typed [`crate::util::parallel::WorkerPanic`] payload that the engine's
/// step boundary converts into `EngineError::WorkerFailed`. The
/// [`crate::util::faults::WORKER_PANIC`] failpoint exercises exactly that
/// path: when it fires, one job (the last worker — its lane picked on the
/// calling thread so the seeded schedule stays off the racy worker
/// threads) panics instead of running.
#[derive(Debug, Clone, Copy)]
pub struct ThreadCollective {
    pub world: usize,
}

impl Collective for ThreadCollective {
    fn world(&self) -> usize {
        self.world
    }
    fn run<R: Send>(&self, jobs: Vec<Job<'_, R>>) -> Vec<R> {
        let mut jobs = jobs;
        if !jobs.is_empty() && crate::util::faults::should_fail(crate::util::faults::WORKER_PANIC) {
            let victim = jobs.len() - 1;
            jobs[victim] = Box::new(|| panic!("injected fault: tp.worker_panic"));
        }
        par_run_once(jobs)
    }
}

/// Split `0..n` into `world` contiguous ranges, the first `n % world` of
/// them one longer. Ranges may be empty when `world > n`; they always tile
/// `0..n` in order.
pub fn split_range(n: usize, world: usize) -> Vec<(usize, usize)> {
    assert!(world >= 1, "worker count must be >= 1");
    let base = n / world;
    let extra = n % world;
    let mut out = Vec::with_capacity(world);
    let mut at = 0;
    for w in 0..world {
        let len = base + usize::from(w < extra);
        out.push((at, at + len));
        at += len;
    }
    debug_assert_eq!(at, n);
    out
}

/// How a model is carved across `world` workers.
///
/// Linears are split `world` ways along the packed NR-panel axis regardless
/// of head layout; attention is split along heads, so when
/// `world > n_heads` the trailing workers own zero heads (their linear
/// shards still run — only the "active" prefix participates in attention).
#[derive(Debug, Clone)]
pub struct ShardPlan {
    pub world: usize,
    /// Per-worker head ranges `[h0, h1)`; trailing ranges may be empty.
    pub heads: Vec<(usize, usize)>,
}

impl ShardPlan {
    pub fn new(arch: &ModelArch, world: usize) -> Result<ShardPlan> {
        anyhow::ensure!(world >= 1, "worker count must be >= 1, got {world}");
        Ok(ShardPlan { world, heads: split_range(arch.n_heads, world) })
    }

    /// Number of workers that own at least one attention head.
    pub fn active(&self) -> usize {
        self.heads.iter().filter(|(h0, h1)| h1 > h0).count()
    }
}

/// The architecture one attention worker sees: its head-slice presented as a
/// self-contained model (`n_heads = h1 - h0`, `d_model` shrunk to match) so
/// the existing single-engine attention kernels run unchanged over the
/// shard. RoPE depends only on position and head_dim — both preserved — so
/// per-head numerics are identical to the unsharded pass.
pub fn shard_arch(arch: &ModelArch, h0: usize, h1: usize) -> ModelArch {
    debug_assert!(h0 < h1 && h1 <= arch.n_heads);
    let dh = arch.head_dim();
    ModelArch { n_heads: h1 - h0, d_model: (h1 - h0) * dh, ..arch.clone() }
}

/// Gather one worker's fused-QKV column slice: rows of `[q | k | v]` at full
/// width `d` become rows of `[q[c0..c1] | k[c0..c1] | v[c0..c1]]`, the fused
/// layout `attention_prefill`/`attention_step` expect at shard width.
pub fn gather_qkv_cols(qkv: &[f32], rows: usize, d: usize, c0: usize, c1: usize) -> Vec<f32> {
    debug_assert!(c0 <= c1 && c1 <= d);
    debug_assert_eq!(qkv.len(), rows * 3 * d);
    let w = c1 - c0;
    let mut out = vec![0.0f32; rows * 3 * w];
    for r in 0..rows {
        let src = &qkv[r * 3 * d..(r + 1) * 3 * d];
        let dst = &mut out[r * 3 * w..(r + 1) * 3 * w];
        dst[..w].copy_from_slice(&src[c0..c1]);
        dst[w..2 * w].copy_from_slice(&src[d + c0..d + c1]);
        dst[2 * w..].copy_from_slice(&src[2 * d + c0..2 * d + c1]);
    }
    out
}

/// Scatter one worker's `rows x wcols` output block into columns
/// `[c0, c0 + wcols)` of the full-width `rows x d` buffer.
pub fn scatter_cols(block: &[f32], rows: usize, wcols: usize, out: &mut [f32], d: usize, c0: usize) {
    debug_assert_eq!(block.len(), rows * wcols);
    debug_assert!(out.len() >= rows * d && c0 + wcols <= d);
    for r in 0..rows {
        out[r * d + c0..r * d + c0 + wcols].copy_from_slice(&block[r * wcols..(r + 1) * wcols]);
    }
}

/// The deterministic all-reduce of the column-parallel matmul: concatenate
/// per-worker column blocks (panel ranges `splits`, panel width `nr`) back
/// into the full `rows x n` product, in fixed worker order. Because ranges
/// are disjoint this is a pure copy — no summation — which is what makes
/// sharded logits bit-exact.
pub fn concat_col_blocks(
    rows: usize,
    n: usize,
    nr: usize,
    splits: &[(usize, usize)],
    blocks: &[Vec<f32>],
) -> Vec<f32> {
    debug_assert_eq!(splits.len(), blocks.len());
    let mut out = vec![0.0f32; rows * n];
    for (&(p0, p1), block) in splits.iter().zip(blocks) {
        let c0 = (p0 * nr).min(n);
        let c1 = (p1 * nr).min(n);
        let w = c1 - c0;
        if w == 0 {
            continue;
        }
        debug_assert_eq!(block.len(), rows * w);
        for r in 0..rows {
            out[r * n + c0..r * n + c1].copy_from_slice(&block[r * w..(r + 1) * w]);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{Act, NormKind, PosKind};

    fn arch(d: usize, h: usize) -> ModelArch {
        ModelArch {
            vocab: 11,
            d_model: d,
            n_layers: 2,
            n_heads: h,
            d_ff: 2 * d,
            act: Act::SwiGlu,
            norm: NormKind::Rms,
            pos: PosKind::Rope,
            max_seq: 16,
        }
    }

    #[test]
    fn split_range_tiles_in_order() {
        for n in [0usize, 1, 3, 7, 16] {
            for world in 1..=5usize {
                let s = split_range(n, world);
                assert_eq!(s.len(), world);
                assert_eq!(s[0].0, 0);
                assert_eq!(s[world - 1].1, n);
                for w in 1..world {
                    assert_eq!(s[w].0, s[w - 1].1, "contiguous at {w}");
                }
                // Longest-first by at most one, so shards stay balanced.
                let lens: Vec<usize> = s.iter().map(|(a, b)| b - a).collect();
                assert!(lens.iter().max().unwrap() - lens.iter().min().unwrap() <= 1);
            }
        }
    }

    #[test]
    fn plan_handles_more_workers_than_heads() {
        let a = arch(96, 3);
        let plan = ShardPlan::new(&a, 4).unwrap();
        assert_eq!(plan.world, 4);
        assert_eq!(plan.heads, vec![(0, 1), (1, 2), (2, 3), (3, 3)]);
        assert_eq!(plan.active(), 3);
        assert!(ShardPlan::new(&a, 0).is_err());
    }

    #[test]
    fn shard_arch_keeps_head_dim() {
        let a = arch(96, 3);
        let s = shard_arch(&a, 1, 3);
        assert_eq!(s.n_heads, 2);
        assert_eq!(s.d_model, 64);
        assert_eq!(s.head_dim(), a.head_dim());
        assert_eq!(s.max_seq, a.max_seq);
    }

    #[test]
    fn gather_scatter_roundtrip_tiles_qkv() {
        let (rows, d) = (3usize, 8usize);
        let qkv: Vec<f32> = (0..rows * 3 * d).map(|i| i as f32).collect();
        let splits = split_range(d, 3);
        // Gathering every column range and scattering the q/k/v thirds back
        // reconstructs the original fused buffer exactly.
        let mut back = vec![0.0f32; rows * 3 * d];
        for &(c0, c1) in &splits {
            let w = c1 - c0;
            let g = gather_qkv_cols(&qkv, rows, d, c0, c1);
            for part in 0..3 {
                let mut third = vec![0.0f32; rows * w];
                for r in 0..rows {
                    third[r * w..(r + 1) * w]
                        .copy_from_slice(&g[r * 3 * w + part * w..r * 3 * w + (part + 1) * w]);
                }
                // Scatter into the matching q/k/v stripe of each fused row.
                for r in 0..rows {
                    back[r * 3 * d + part * d + c0..r * 3 * d + part * d + c1]
                        .copy_from_slice(&third[r * w..(r + 1) * w]);
                }
            }
        }
        assert_eq!(back, qkv);
        // scatter_cols places a block at its column offset.
        let mut out = vec![0.0f32; rows * d];
        scatter_cols(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], rows, 2, &mut out, d, 4);
        assert_eq!(out[4..6], [1.0, 2.0]);
        assert_eq!(out[d + 4..d + 6], [3.0, 4.0]);
        assert_eq!(out[2 * d + 4..2 * d + 6], [5.0, 6.0]);
    }

    #[test]
    fn concat_col_blocks_reassembles_product() {
        let (rows, n, nr) = (2usize, 11usize, 4usize);
        let full: Vec<f32> = (0..rows * n).map(|i| (i * 7 % 13) as f32).collect();
        let np = n.div_ceil(nr);
        for world in 1..=4usize {
            let splits = split_range(np, world);
            let blocks: Vec<Vec<f32>> = splits
                .iter()
                .map(|&(p0, p1)| {
                    let c0 = (p0 * nr).min(n);
                    let c1 = (p1 * nr).min(n);
                    let mut b = Vec::new();
                    for r in 0..rows {
                        b.extend_from_slice(&full[r * n + c0..r * n + c1]);
                    }
                    b
                })
                .collect();
            assert_eq!(concat_col_blocks(rows, n, nr, &splits, &blocks), full, "world {world}");
        }
    }

    #[test]
    fn thread_collective_runs_jobs_in_worker_order() {
        let coll = ThreadCollective { world: 3 };
        assert_eq!(coll.world(), 3);
        let jobs: Vec<Job<'_, usize>> =
            (0..3).map(|w| Box::new(move || w * 10) as Job<'_, usize>).collect();
        assert_eq!(coll.run(jobs), vec![0, 10, 20]);
    }
}
