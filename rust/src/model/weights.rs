//! Checkpoint loading and the offline weight-quantization pipeline:
//! score blocks (policy weighting) → calibrate threshold (global or local)
//! → assign precisions → SW-Clip the FP4 blocks → pack + panelize.
//!
//! The packed bits are the **execution format**: each linear carries its
//! k-panelized [`PackedPanels`] layout, which the native kernels decode
//! in-register ([`crate::util::kernels::matmul_rows_packed`]) — no resident
//! dequantized f32 copy. The PJRT/export path materializes one on demand
//! via [`QuantizedLinear::dequant`] (numerically exactly what the FGMP
//! datapath consumes). The storage-format [`FgmpTensor`] feeds the memory
//! model; the per-layer FP8 fractions feed the energy model.

use std::path::{Path, PathBuf};
use std::sync::Arc;


use crate::hwsim::LayerProfile;
use crate::io::{Manifest, TensorFile};
use crate::model::config::{QuantConfig, RatioSpec};
use crate::policy::baselines::{oe_weighting_for_acts, qe_weighting};
use crate::policy::{
    assign_tensor, block_impact_scores, threshold_for_fp4_fraction, Assignment, Policy,
    ThresholdMode,
};
use crate::quant::{sw_clip_tensor, FgmpTensor, PackedPanels};
use crate::util::kernels;
use crate::Result;

/// Everything `make artifacts` produced for one model.
pub struct ModelArtifacts {
    pub dir: PathBuf,
    pub manifest: Manifest,
    pub weights: TensorFile,
    pub fisher_w: TensorFile,
    pub act_fisher: TensorFile,
    pub act_msq: TensorFile,
    pub act_quantiles: TensorFile,
}

impl ModelArtifacts {
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        Ok(ModelArtifacts {
            manifest: Manifest::load(dir.join("manifest.json"))?,
            weights: TensorFile::load(dir.join("weights.fgtn"))?,
            fisher_w: TensorFile::load(dir.join("fisher_w.fgtn"))?,
            act_fisher: TensorFile::load(dir.join("act_fisher.fgtn"))?,
            act_msq: TensorFile::load(dir.join("act_msq.fgtn"))?,
            act_quantiles: TensorFile::load(dir.join("act_score_quantiles.fgtn"))?,
            dir,
        })
    }

    /// Per-channel weighting for the *activation* side of `linear` under a
    /// policy (Fisher: calibrated g²; QE: ones; OE: mean-square of the
    /// weight's corresponding input channels).
    pub fn act_weighting(&self, linear: &str, policy: Policy) -> Result<Vec<f32>> {
        let spec = self.manifest.linear(linear)?;
        Ok(match policy {
            Policy::Fisher => self.act_fisher.get(linear)?.as_f32()?.to_vec(),
            Policy::QuantError => qe_weighting(spec.k_in),
            Policy::OutputError => {
                let w = self.weights.get(&format!("{linear}.w"))?.as_f32()?;
                oe_weighting_for_acts(w, spec.k_in, spec.n_out)
            }
        })
    }

    /// Activation threshold(s) for a config, from the calibrated quantile
    /// tables (one entry per linear). Global mode returns the same value
    /// everywhere; the all-FP8/FP4 extremes return ∓inf sentinels.
    pub fn act_thresholds(&self, cfg: &QuantConfig) -> Result<Vec<f32>> {
        let nl = self.manifest.num_linears;
        let f = match cfg.ratio {
            RatioSpec::Bf16 => return Ok(vec![f32::NEG_INFINITY; nl]),
            r => r.fp4_fraction().unwrap(),
        };
        if f <= 0.0 {
            return Ok(vec![-1.0; nl]); // all FP8 (scores are >= 0)
        }
        if f >= 1.0 {
            return Ok(vec![f32::INFINITY; nl]);
        }
        // Quantile tables hold q = 0.01..0.99 in steps of 0.01.
        let qi = ((f * 100.0).round() as usize).clamp(1, 99) - 1;
        match cfg.threshold_mode {
            ThresholdMode::Global => {
                let table = self.act_quantiles.get(&format!("{}.global", cfg.policy.name()))?;
                let t = table.as_f32()?[qi];
                Ok(vec![t; nl])
            }
            ThresholdMode::Local => {
                let table = self.act_quantiles.get(&format!("{}.local", cfg.policy.name()))?;
                let v = table.as_f32()?;
                ensure_shape(&table.shape, nl)?;
                Ok((0..nl).map(|l| v[l * 99 + qi]).collect())
            }
        }
    }
}

fn ensure_shape(shape: &[usize], nl: usize) -> Result<()> {
    anyhow::ensure!(
        shape.len() == 2 && shape[0] == nl && shape[1] == 99,
        "quantile table shape {shape:?}, want [{nl}, 99]"
    );
    Ok(())
}

/// One quantized linear layer. Holds only packed forms — the storage-order
/// tensor for footprint accounting and the k-panelized execution layout
/// the native kernels run on. No dequantized f32 copy stays resident.
pub struct QuantizedLinear {
    pub name: String,
    pub packed: FgmpTensor,
    /// The execution format: the same bits panel-reordered for the blocked
    /// matmul (shared behind `Arc` so argument tails clone cheaply).
    pub panels: Arc<PackedPanels>,
    pub assignment: Assignment,
}

impl QuantizedLinear {
    /// On-demand dequantized values (row-major K×N) for the PJRT/export
    /// path — bit-identical to what the packed kernels decode in-register.
    pub fn dequant(&self) -> Vec<f32> {
        self.panels.unpack_kn()
    }
}

/// Resident weight-memory accounting across a model's packed linears.
#[derive(Debug, Clone, Copy, Default)]
pub struct WeightMemory {
    /// Bytes the packed execution tensors keep resident (payload + scales
    /// + meta bits + panel tables).
    pub packed_bytes: usize,
    /// Bytes the same linears would occupy as dequantized f32.
    pub f32_equiv_bytes: usize,
    /// Number of linears counted.
    pub linears: usize,
}

impl WeightMemory {
    /// Fractional saving vs a resident f32 copy (0.30 = 30% smaller).
    pub fn saving_vs_f32(&self) -> f64 {
        1.0 - self.packed_bytes as f64 / (self.f32_equiv_bytes as f64).max(1.0)
    }
}

/// A fully weight-quantized model.
pub struct QuantizedModel {
    pub config: QuantConfig,
    pub linears: Vec<QuantizedLinear>,
    /// Weight-side threshold actually used (per linear; global repeats).
    pub thresholds: Vec<f64>,
}

impl QuantizedModel {
    /// Run the full offline pipeline on a checkpoint.
    ///
    /// Weight tensors are stored (K, N) row-major; FGMP blocks run along K,
    /// i.e. down columns. We therefore score/pack the *transposed* (N, K)
    /// layout so blocks are contiguous, exactly as the datapath streams
    /// them (one output channel's K-dim blocks at a time).
    pub fn quantize(arts: &ModelArtifacts, cfg: &QuantConfig) -> Result<Self> {
        let fp4_target = cfg.ratio.fp4_fraction().unwrap_or(0.0);

        // Gather per-linear transposed data + element weighting.
        struct Job {
            name: String,
            k: usize,
            n: usize,
            data_t: Vec<f32>,   // (N, K) — blocks contiguous along K
            weight_t: Vec<f32>, // per-element weighting, same layout
        }
        let jobs: Vec<Job> = arts
            .manifest
            .linears
            .iter()
            .map(|spec| -> Result<Job> {
                let w = arts.weights.get(&format!("{}.w", spec.name))?.as_f32()?;
                let (k, n) = (spec.k_in, spec.n_out);
                let mut data_t = vec![0.0f32; w.len()];
                for ki in 0..k {
                    for ni in 0..n {
                        data_t[ni * k + ki] = w[ki * n + ni];
                    }
                }
                let weight_t = match cfg.policy {
                    Policy::Fisher => {
                        let f = arts.fisher_w.get(&format!("{}.w.fisher", spec.name))?.as_f32()?;
                        let mut t = vec![0.0f32; f.len()];
                        for ki in 0..k {
                            for ni in 0..n {
                                t[ni * k + ki] = f[ki * n + ni];
                            }
                        }
                        t
                    }
                    Policy::QuantError => vec![1.0f32; w.len()],
                    Policy::OutputError => {
                        // avg squared magnitude of X's channel k, broadcast
                        let msq = arts.act_msq.get(&spec.name)?.as_f32()?;
                        let mut t = vec![0.0f32; w.len()];
                        for ni in 0..n {
                            t[ni * k..(ni + 1) * k].copy_from_slice(msq);
                        }
                        t
                    }
                };
                Ok(Job { name: spec.name.clone(), k, n, data_t, weight_t })
            })
            .collect::<Result<_>>()?;

        // Score all blocks (parallel over linears).
        let all_scores: Vec<Vec<f64>> = crate::util::par_map(&jobs, |j| {
            block_impact_scores(&j.data_t, j.k, &[], Some(&j.weight_t))
        });

        // Thresholds: global percentile over the concatenation, or local.
        let thresholds: Vec<f64> = match cfg.threshold_mode {
            ThresholdMode::Global => {
                let mut flat: Vec<f64> =
                    all_scores.iter().flat_map(|s| s.iter().copied()).collect();
                flat.sort_by(|a, b| a.partial_cmp(b).unwrap());
                let t = if fp4_target >= 1.0 {
                    f64::INFINITY
                } else if fp4_target <= 0.0 {
                    f64::NEG_INFINITY
                } else {
                    crate::policy::threshold::percentile_sorted(&flat, fp4_target)
                };
                vec![t; jobs.len()]
            }
            ThresholdMode::Local => all_scores
                .iter()
                .map(|s| threshold_for_fp4_fraction(s, fp4_target))
                .collect(),
        };

        // Assign + clip + pack (parallel over linears).
        let jobs_t: Vec<(&Job, f64)> = jobs.iter().zip(thresholds.iter().copied()).collect();
        let linears: Vec<QuantizedLinear> = crate::util::par_map(&jobs_t, |&(j, t)| {
                let assignment = assign_tensor(&j.data_t, j.k, &[], Some(&j.weight_t), t);
                let clip_scales = if cfg.sw_clip {
                    // Clip every block; the packer indexes FP4 blocks by
                    // position so we filter to the FP4 subset in order.
                    let all = sw_clip_tensor(&j.data_t, &j.weight_t);
                    let fp4_scales: Vec<f32> = all
                        .iter()
                        .zip(&assignment.precision)
                        .filter(|(_, p)| **p == crate::quant::Precision::Fp4)
                        .map(|(s, _)| *s)
                        .collect();
                    Some(fp4_scales)
                } else {
                    None
                };
                let packed = FgmpTensor::pack(
                    &[j.n, j.k],
                    &j.data_t,
                    &assignment.precision,
                    clip_scales.as_deref(),
                );
                // Panel-reorder the same bits into the execution layout —
                // the transpose to (K, N) happens in-register at use.
                let panels = Arc::new(PackedPanels::from_tensor(&packed, kernels::NR));
                QuantizedLinear { name: j.name.clone(), packed, panels, assignment }
            });

        Ok(QuantizedModel { config: cfg.clone(), linears, thresholds })
    }

    /// Resident weight bytes of the packed **execution** tensors vs their
    /// f32 equivalents — the number the engine/serve reports print (an
    /// engine built from the argument tail holds exactly these bytes,
    /// `Arc`-shared). The quantize/report CLIs additionally keep the
    /// storage-order [`FgmpTensor`] alive for the Fig-8 footprint model
    /// and the precision maps; that copy is the same packed bits and is
    /// accounted by `footprint_bits`, not here.
    pub fn weight_memory(&self) -> WeightMemory {
        self.linears.iter().fold(WeightMemory::default(), |mut m, l| {
            m.packed_bytes += l.panels.resident_bytes();
            m.f32_equiv_bytes += l.panels.f32_equiv_bytes();
            m.linears += 1;
            m
        })
    }

    /// Overall FP8 block fraction across all weight tensors.
    pub fn weight_fp8_fraction(&self) -> f64 {
        let (fp8, total) = self
            .linears
            .iter()
            .fold((0usize, 0usize), |(a, b), l| (a + l.packed.n_fp8, b + l.packed.n_blocks));
        fp8 as f64 / total.max(1) as f64
    }

    /// Per-layer hwsim profiles (activation fractions filled by the caller
    /// from the runtime PPU stats; `m` = tokens per forward).
    pub fn layer_profiles(&self, manifest: &Manifest, m: usize, act_fp8: &[f64]) -> Vec<LayerProfile> {
        self.linears
            .iter()
            .enumerate()
            .map(|(i, l)| {
                let spec = &manifest.linears[i];
                LayerProfile {
                    name: l.name.clone(),
                    layer: spec.layer,
                    kind: spec.kind.clone(),
                    m,
                    k: spec.k_in,
                    n: spec.n_out,
                    weight_fp8: l.packed.fp8_fraction(),
                    act_fp8: act_fp8.get(i).copied().unwrap_or(0.0),
                }
            })
            .collect()
    }
}
