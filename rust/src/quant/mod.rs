//! Bit-exact quantization codecs and the FGMP packed-tensor format.
//!
//! The numerics here mirror `python/compile/kernels/ref.py` to the last ULP
//! (same quantum-based round-to-nearest-ties-to-even algorithm); the golden
//! fixture test `tests/quant_golden.rs` replays python-generated vectors to
//! pin the two implementations together.

pub mod clip;
pub mod fp4;
pub mod fp8;
pub mod nvfp4;
pub mod pack;

pub use clip::{sw_clip_block, sw_clip_tensor};
pub use fp4::{quant_e2m1, E2M1_MAX};
pub use fp8::{encode_e4m3, decode_e4m3, quant_e4m3, E4M3_MAX};
pub use nvfp4::{nvfp4_roundtrip, nvfp4_scale, NvFp4Block};
pub use pack::{FgmpTensor, PackedPanels, PanelRangeView, Precision};
