//! FP8 E4M3 codec (OCP "FN" variant): bias 7, max 448, no infinities.
//!
//! `quant_e4m3` is the round-trip used throughout the policy math (identical
//! to `ref.quant_e4m3`); `encode_e4m3`/`decode_e4m3` are the true byte codec
//! used by the packed-tensor storage format.

/// Largest finite E4M3 magnitude.
pub const E4M3_MAX: f32 = 448.0;
/// Smallest normal E4M3 magnitude (2^-6).
pub const E4M3_MIN_NORMAL: f32 = 0.015625;
/// Subnormal spacing (2^-9).
pub const E4M3_QUANTUM_SUBNORMAL: f32 = 0.001953125;

/// Round-trip f32 -> E4M3 -> f32 (saturating, round-to-nearest ties-to-even).
///
/// The in-binade quantum 2^(e-3) is built directly from the exponent field
/// (subtract 3 from the biased exponent) instead of `powi` — this is the
/// inner loop of impact scoring, packing, and SW-Clip (§Perf change 1).
#[inline]
pub fn quant_e4m3(x: f32) -> f32 {
    let ax = x.abs();
    if ax == 0.0 {
        return 0.0;
    }
    let quantum = if ax < E4M3_MIN_NORMAL {
        E4M3_QUANTUM_SUBNORMAL
    } else {
        // biased exponent of ax, minus 3 -> 2^(e-3); ax >= 2^-6 keeps the
        // result normal, and the mantissa bits are cleared by the shift.
        f32::from_bits(((ax.to_bits() >> 23) - 3) << 23)
    };
    let q = (x / quantum).round_ties_even() * quantum;
    q.clamp(-E4M3_MAX, E4M3_MAX)
}

/// Encode a (pre-rounded or arbitrary) f32 into an E4M3 byte.
/// Encoding quantizes first, so `decode(encode(x)) == quant_e4m3(x)`.
pub fn encode_e4m3(x: f32) -> u8 {
    let q = quant_e4m3(x);
    let aq = q.abs();
    if aq == 0.0 {
        return 0; // canonical +0 (negative zero carries no information)
    }
    let sign = if q.is_sign_negative() { 0x80u8 } else { 0 };
    if aq < E4M3_MIN_NORMAL {
        // subnormal: mantissa counts 2^-9 steps
        let m = (aq / E4M3_QUANTUM_SUBNORMAL).round() as u8;
        return sign | m;
    }
    // aq is already on the E4M3 grid: exponent/mantissa drop out of the
    // f32 bit pattern directly (top 3 mantissa bits; §Perf change 2).
    let bits = aq.to_bits();
    let e = ((bits >> 23) as i32) - 127; // in [-6, 8]
    let m = ((bits >> 20) & 0x7) as u8;
    sign | (((e + 7) as u8) << 3) | m
}

/// Decode an E4M3 byte to f32. The NaN encodings (0x7f/0xff) decode to the
/// max magnitude — they never occur in data we produce (saturating encode).
pub fn decode_e4m3(b: u8) -> f32 {
    let sign = if b & 0x80 != 0 { -1.0f32 } else { 1.0 };
    let e = ((b >> 3) & 0x0f) as i32;
    let m = (b & 0x07) as f32;
    let mag = if e == 0 {
        m * E4M3_QUANTUM_SUBNORMAL
    } else {
        (1.0 + m / 8.0) * (2.0f32).powi(e - 7)
    };
    sign * mag.min(E4M3_MAX)
}

/// Vectorized round-trip: the branch-free slice kernel from
/// [`crate::util::kernels`] (same lattice as [`quant_e4m3`], asserted in
/// `tests/kernel_props.rs`).
pub fn quant_e4m3_slice(xs: &[f32], out: &mut [f32]) {
    crate::util::kernels::e4m3_slice(xs, out)
}

/// All 126 non-negative finite E4M3 values in ascending order (used by the
/// SW-Clip brute-force scale search, paper §3.3).
pub fn e4m3_grid() -> Vec<f32> {
    let mut v = vec![0.0f32];
    for m in 1..8 {
        v.push(m as f32 * E4M3_QUANTUM_SUBNORMAL);
    }
    for e in -6..=8i32 {
        for m in 0..8 {
            let x = (1.0 + m as f32 / 8.0) * (2.0f32).powi(e);
            if x <= E4M3_MAX {
                v.push(x);
            }
        }
    }
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_fixed_points() {
        for g in e4m3_grid() {
            assert_eq!(quant_e4m3(g), g, "grid value {g} must be fixed");
            assert_eq!(quant_e4m3(-g), -g);
        }
    }

    #[test]
    fn saturation() {
        assert_eq!(quant_e4m3(1e9), 448.0);
        assert_eq!(quant_e4m3(-1e9), -448.0);
        assert_eq!(quant_e4m3(449.0), 448.0);
    }

    #[test]
    fn subnormals() {
        assert_eq!(quant_e4m3(E4M3_QUANTUM_SUBNORMAL), E4M3_QUANTUM_SUBNORMAL);
        assert_eq!(quant_e4m3(E4M3_QUANTUM_SUBNORMAL * 0.49), 0.0);
        assert_eq!(quant_e4m3(E4M3_QUANTUM_SUBNORMAL * 0.51), E4M3_QUANTUM_SUBNORMAL);
    }

    #[test]
    fn ties_to_even() {
        // midpoint between 1.0 (mantissa 0, even) and 1.125 (mantissa 1, odd)
        assert_eq!(quant_e4m3(1.0625), 1.0);
        // midpoint between 1.125 and 1.25 -> 1.25 (even mantissa 2)
        assert_eq!(quant_e4m3(1.1875), 1.25);
    }

    #[test]
    fn encode_decode_roundtrip_all_bytes() {
        // decode(encode(decode(b))) == decode(b) for every non-NaN byte
        for b in 0u16..=255 {
            let b = b as u8;
            if (b & 0x7f) == 0x7f {
                continue; // NaN encodings
            }
            let x = decode_e4m3(b);
            assert_eq!(decode_e4m3(encode_e4m3(x)), x, "byte {b:#x}");
        }
    }

    #[test]
    fn encode_matches_quant() {
        let rs: Vec<f32> = (0..4096)
            .map(|i| ((i as f32 * 0.7311).sin() * 300.0) + (i as f32 * 0.017).cos())
            .collect();
        for x in rs {
            assert_eq!(decode_e4m3(encode_e4m3(x)), quant_e4m3(x), "x={x}");
        }
    }

    #[test]
    fn grid_count() {
        // 1 zero + 7 subnormals + (15 binades * 8 mantissas - 1 cut above
        // 448) = 127 non-negative finite values
        assert_eq!(e4m3_grid().len(), 127);
    }
}
