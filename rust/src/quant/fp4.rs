//! FP4 E2M1 codec: bias 1, grid {0, 0.5, 1, 1.5, 2, 3, 4, 6} with sign.
//!
//! Values are always stored *pre-scaled* (NVFP4 divides by the per-block
//! E4M3 scale first); this module only handles the 4-bit grid itself.


/// Largest E2M1 magnitude.
pub const E2M1_MAX: f32 = 6.0;
/// Smallest normal E2M1 magnitude.
pub const E2M1_MIN_NORMAL: f32 = 1.0;
/// Subnormal spacing.
pub const E2M1_QUANTUM_SUBNORMAL: f32 = 0.5;

/// The eight non-negative E2M1 values.
pub const E2M1_GRID: [f32; 8] = [0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];

/// Round-trip f32 -> E2M1 -> f32 (saturating, round-to-nearest ties-to-even).
///
/// Quantum 2^(e-1) built from the exponent field (no `powi`) — this is the
/// innermost operation of the SW-Clip search (§Perf change 1).
#[inline]
pub fn quant_e2m1(x: f32) -> f32 {
    let ax = x.abs();
    if ax == 0.0 {
        return 0.0;
    }
    let quantum = if ax < E2M1_MIN_NORMAL {
        E2M1_QUANTUM_SUBNORMAL
    } else {
        f32::from_bits(((ax.to_bits() >> 23) - 1) << 23)
    };
    let q = (x / quantum).round_ties_even() * quantum;
    q.clamp(-E2M1_MAX, E2M1_MAX)
}

/// Vectorized round-trip: the branch-free slice kernel from
/// [`crate::util::kernels`] (same lattice as [`quant_e2m1`], asserted in
/// `tests/kernel_props.rs`).
pub fn quant_e2m1_slice(xs: &[f32], out: &mut [f32]) {
    crate::util::kernels::e2m1_slice(xs, out)
}

/// Encode into a 4-bit code (low nibble): sign | exp(2b) | mantissa(1b).
/// The code index is derived arithmetically from the quantized value's
/// exponent/mantissa (no grid search; §Perf change 2).
pub fn encode_e2m1(x: f32) -> u8 {
    let q = quant_e2m1(x);
    if q == 0.0 {
        return 0; // canonical +0 (negative zero carries no information)
    }
    let sign = if q.is_sign_negative() { 0x8u8 } else { 0 };
    let a = q.abs();
    let idx = if a < 1.0 {
        1 // 0.5, the sole subnormal
    } else {
        // a = (1 + m/2) * 2^e with e in 0..=2, m in {0,1}
        let e = ((a.to_bits() >> 23) as i32 - 127) as u32;
        let m = (a.to_bits() >> 22) & 1; // top mantissa bit
        (2 + 2 * e + m) as u8
    };
    debug_assert_eq!(E2M1_GRID[idx as usize], a, "arithmetic code agrees with grid");
    sign | idx
}

/// Decode a 4-bit code (low nibble) to f32.
#[inline]
pub fn decode_e2m1(code: u8) -> f32 {
    let mag = E2M1_GRID[(code & 0x7) as usize];
    if code & 0x8 != 0 {
        -mag
    } else {
        mag
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grid_fixed_points() {
        for g in E2M1_GRID {
            assert_eq!(quant_e2m1(g), g);
            assert_eq!(quant_e2m1(-g), -g);
        }
    }

    #[test]
    fn nearest_with_ties_to_even() {
        // (input, expected) — ties resolve to the even mantissa code.
        let cases = [
            (0.24, 0.0),
            (0.25, 0.0),  // tie 0 vs 0.5 -> 0 (even)
            (0.26, 0.5),
            (0.75, 1.0),  // tie 0.5 vs 1.0 -> 1.0 (even subnormal count)
            (1.25, 1.0),  // tie -> even mantissa (1.0)
            (1.75, 2.0),  // tie -> 2.0
            (2.5, 2.0),   // tie 2 vs 3 -> 2 (even)
            (3.5, 4.0),   // tie 3 vs 4 -> 4
            (5.0, 4.0),   // tie 4 vs 6 -> 4 (even)
            (5.1, 6.0),
            (7.0, 6.0),   // saturate
            (-1.3, -1.5),
        ];
        for (x, want) in cases {
            assert_eq!(quant_e2m1(x), want, "x={x}");
        }
    }

    #[test]
    fn encode_decode_all_codes() {
        for c in 0u8..16 {
            let x = decode_e2m1(c);
            // -0.0 encodes to 0x8 and decodes to -0.0 == 0.0
            assert_eq!(decode_e2m1(encode_e2m1(x)), x);
        }
    }

    #[test]
    fn encode_matches_quant() {
        for i in 0..4096 {
            let x = ((i as f32) * 0.0137).sin() * 8.0;
            assert_eq!(decode_e2m1(encode_e2m1(x)), quant_e2m1(x), "x={x}");
        }
    }
}
