//! The FGMP packed-tensor storage format (paper §4: payload + per-block
//! E4M3 microscale for FP4 blocks + **one metadata bit per block**).
//!
//! Layout per tensor (blocks run along the contiguous K axis):
//!   * `meta`    — 1 bit/block, 1 = FP8 block, 0 = NVFP4 block
//!   * `payload` — FP8 blocks: 16 E4M3 bytes; FP4 blocks: 8 bytes (two E2M1
//!     nibbles each, low nibble first)
//!   * `scales`  — one E4M3 byte per FP4 block (FP8 blocks carry none)
//!
//! This is exactly the memory-footprint accounting of the paper's Fig. 8:
//! FP4 block = 64 + 8 (scale) + 1 (meta) bits, FP8 block = 128 + 1 bits.

use crate::BLOCK;

use super::fp4::{decode_e2m1, encode_e2m1};
use super::fp8::{decode_e4m3, encode_e4m3};
use super::nvfp4::nvfp4_scale;

/// Per-block precision assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp4,
    Fp8,
}

/// A tensor stored in the FGMP packed format.
#[derive(Debug, Clone)]
pub struct FgmpTensor {
    /// Logical shape (row-major; blocks tile the last axis).
    pub shape: Vec<usize>,
    /// 1 bit per block, LSB-first within each byte; 1 = FP8.
    pub meta: Vec<u8>,
    /// Mixed payload, in block order.
    pub payload: Vec<u8>,
    /// E4M3 scale byte per FP4 block, in FP4-block order.
    pub scales: Vec<u8>,
    /// Number of blocks.
    pub n_blocks: usize,
    /// Number of FP8 blocks (for stats / footprint accounting).
    pub n_fp8: usize,
}

impl FgmpTensor {
    /// Pack `data` given a per-block precision assignment and optional
    /// per-FP4-block scale override (from SW-Clip); `None` = dynamic-max.
    pub fn pack(
        shape: &[usize],
        data: &[f32],
        precision: &[Precision],
        clip_scales: Option<&[f32]>,
    ) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len());
        assert_eq!(n % BLOCK, 0, "last axis must tile into {BLOCK}-blocks");
        let n_blocks = n / BLOCK;
        assert_eq!(precision.len(), n_blocks);

        let mut meta = vec![0u8; n_blocks.div_ceil(8)];
        let mut payload = Vec::with_capacity(n);
        let mut scales = Vec::new();
        let mut n_fp8 = 0;
        let mut fp4_idx = 0;

        for (bi, xb) in data.chunks_exact(BLOCK).enumerate() {
            match precision[bi] {
                Precision::Fp8 => {
                    meta[bi / 8] |= 1 << (bi % 8);
                    n_fp8 += 1;
                    payload.extend(xb.iter().map(|&v| encode_e4m3(v)));
                }
                Precision::Fp4 => {
                    let s = match clip_scales {
                        Some(cs) => cs[fp4_idx],
                        None => {
                            let absmax = xb.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                            nvfp4_scale(absmax)
                        }
                    };
                    fp4_idx += 1;
                    scales.push(encode_e4m3(s));
                    let sdec = decode_e4m3(encode_e4m3(s));
                    let safe = if sdec > 0.0 { sdec } else { 1.0 };
                    for pair in xb.chunks_exact(2) {
                        let lo = encode_e2m1(pair[0] / safe);
                        let hi = encode_e2m1(pair[1] / safe);
                        payload.push(lo | (hi << 4));
                    }
                }
            }
        }
        FgmpTensor { shape: shape.to_vec(), meta, payload, scales, n_blocks, n_fp8 }
    }

    /// Is block `bi` stored in FP8?
    #[inline]
    pub fn is_fp8(&self, bi: usize) -> bool {
        self.meta[bi / 8] & (1 << (bi % 8)) != 0
    }

    /// Unpack to dequantized f32 (the values the datapath consumes).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_blocks * BLOCK);
        let mut off = 0usize;
        let mut fp4_idx = 0usize;
        for bi in 0..self.n_blocks {
            if self.is_fp8(bi) {
                for j in 0..BLOCK {
                    out.push(decode_e4m3(self.payload[off + j]));
                }
                off += BLOCK;
            } else {
                let s = decode_e4m3(self.scales[fp4_idx]);
                fp4_idx += 1;
                let s = if s > 0.0 { s } else { 0.0 };
                for j in 0..BLOCK / 2 {
                    let b = self.payload[off + j];
                    out.push(decode_e2m1(b & 0x0f) * s);
                    out.push(decode_e2m1(b >> 4) * s);
                }
                off += BLOCK / 2;
            }
        }
        out
    }

    /// Storage size in bits, split into (payload, scales, metadata) — the
    /// three bars of the paper's Fig. 8 breakdown.
    pub fn footprint_bits(&self) -> (usize, usize, usize) {
        let n_fp4 = self.n_blocks - self.n_fp8;
        let payload = self.n_fp8 * BLOCK * 8 + n_fp4 * BLOCK * 4;
        let scales = n_fp4 * 8;
        let meta = self.n_blocks;
        (payload, scales, meta)
    }

    /// Fraction of blocks kept in FP8.
    pub fn fp8_fraction(&self) -> f64 {
        self.n_fp8 as f64 / self.n_blocks.max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quant_e4m3, nvfp4::nvfp4_roundtrip};

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    fn data(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n).map(|_| lcg(&mut s) * scale).collect()
    }

    #[test]
    fn all_fp8_roundtrip_equals_e4m3() {
        let x = data(BLOCK * 10, 20.0, 1);
        let t = FgmpTensor::pack(&[10, BLOCK], &x, &vec![Precision::Fp8; 10], None);
        let back = t.unpack();
        for (a, &b) in back.iter().zip(&x) {
            assert_eq!(*a, quant_e4m3(b));
        }
        assert_eq!(t.n_fp8, 10);
        assert!(t.scales.is_empty());
    }

    #[test]
    fn all_fp4_roundtrip_equals_nvfp4() {
        let x = data(BLOCK * 10, 5.0, 2);
        let t = FgmpTensor::pack(&[10, BLOCK], &x, &vec![Precision::Fp4; 10], None);
        let back = t.unpack();
        let mut want = vec![0.0; x.len()];
        nvfp4_roundtrip(&x, &mut want);
        for (a, b) in back.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(t.scales.len(), 10);
    }

    #[test]
    fn mixed_blocks_select_correct_codec() {
        let x = data(BLOCK * 4, 3.0, 3);
        let prec = vec![Precision::Fp4, Precision::Fp8, Precision::Fp8, Precision::Fp4];
        let t = FgmpTensor::pack(&[4, BLOCK], &x, &prec, None);
        assert!(!t.is_fp8(0) && t.is_fp8(1) && t.is_fp8(2) && !t.is_fp8(3));
        assert_eq!(t.n_fp8, 2);
        let back = t.unpack();
        // FP8 blocks match e4m3
        for j in BLOCK..3 * BLOCK {
            assert_eq!(back[j], quant_e4m3(x[j]));
        }
    }

    #[test]
    fn footprint_accounting() {
        let x = data(BLOCK * 8, 1.0, 4);
        let prec: Vec<Precision> = (0..8)
            .map(|i| if i < 2 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[8, BLOCK], &x, &prec, None);
        let (p, s, m) = t.footprint_bits();
        assert_eq!(p, 2 * 128 + 6 * 64);
        assert_eq!(s, 6 * 8);
        assert_eq!(m, 8);
        assert_eq!(t.payload.len(), 2 * 16 + 6 * 8);
    }

    #[test]
    fn explicit_clip_scales_respected() {
        let x = data(BLOCK, 4.0, 5);
        let t = FgmpTensor::pack(&[1, BLOCK], &x, &[Precision::Fp4], Some(&[0.25]));
        assert_eq!(decode_e4m3(t.scales[0]), 0.25);
        let back = t.unpack();
        for &v in &back {
            assert!(v.abs() <= 6.0 * 0.25 + 1e-6);
        }
    }
}
