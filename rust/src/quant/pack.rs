//! The FGMP packed-tensor storage format (paper §4: payload + per-block
//! E4M3 microscale for FP4 blocks + **one metadata bit per block**).
//!
//! Layout per tensor (blocks run along the contiguous K axis):
//!   * `meta`    — 1 bit/block, 1 = FP8 block, 0 = NVFP4 block
//!   * `payload` — FP8 blocks: 16 E4M3 bytes; FP4 blocks: 8 bytes (two E2M1
//!     nibbles each, low nibble first)
//!   * `scales`  — one E4M3 byte per FP4 block (FP8 blocks carry none)
//!
//! This is exactly the memory-footprint accounting of the paper's Fig. 8:
//! FP4 block = 64 + 8 (scale) + 1 (meta) bits, FP8 block = 128 + 1 bits.

use std::sync::OnceLock;

use crate::BLOCK;

use super::fp4::{decode_e2m1, encode_e2m1};
use super::fp8::{decode_e4m3, encode_e4m3};
use super::nvfp4::nvfp4_scale;

/// Per-block precision assignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp4,
    Fp8,
}

/// A tensor stored in the FGMP packed format.
#[derive(Debug, Clone)]
pub struct FgmpTensor {
    /// Logical shape (row-major; blocks tile the last axis).
    pub shape: Vec<usize>,
    /// 1 bit per block, LSB-first within each byte; 1 = FP8.
    pub meta: Vec<u8>,
    /// Mixed payload, in block order.
    pub payload: Vec<u8>,
    /// E4M3 scale byte per FP4 block, in FP4-block order.
    pub scales: Vec<u8>,
    /// Number of blocks.
    pub n_blocks: usize,
    /// Number of FP8 blocks (for stats / footprint accounting).
    pub n_fp8: usize,
}

impl FgmpTensor {
    /// Pack `data` given a per-block precision assignment and optional
    /// per-FP4-block scale override (from SW-Clip); `None` = dynamic-max.
    pub fn pack(
        shape: &[usize],
        data: &[f32],
        precision: &[Precision],
        clip_scales: Option<&[f32]>,
    ) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len());
        assert_eq!(n % BLOCK, 0, "last axis must tile into {BLOCK}-blocks");
        let n_blocks = n / BLOCK;
        assert_eq!(precision.len(), n_blocks);

        let mut meta = vec![0u8; n_blocks.div_ceil(8)];
        let mut payload = Vec::with_capacity(n);
        let mut scales = Vec::new();
        let mut n_fp8 = 0;
        let mut fp4_idx = 0;

        for (bi, xb) in data.chunks_exact(BLOCK).enumerate() {
            match precision[bi] {
                Precision::Fp8 => {
                    meta[bi / 8] |= 1 << (bi % 8);
                    n_fp8 += 1;
                    payload.extend(xb.iter().map(|&v| encode_e4m3(v)));
                }
                Precision::Fp4 => {
                    let s = match clip_scales {
                        Some(cs) => cs[fp4_idx],
                        None => {
                            let absmax = xb.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                            nvfp4_scale(absmax)
                        }
                    };
                    fp4_idx += 1;
                    scales.push(encode_e4m3(s));
                    let sdec = decode_e4m3(encode_e4m3(s));
                    let safe = if sdec > 0.0 { sdec } else { 1.0 };
                    for pair in xb.chunks_exact(2) {
                        let lo = encode_e2m1(pair[0] / safe);
                        let hi = encode_e2m1(pair[1] / safe);
                        payload.push(lo | (hi << 4));
                    }
                }
            }
        }
        FgmpTensor { shape: shape.to_vec(), meta, payload, scales, n_blocks, n_fp8 }
    }

    /// Is block `bi` stored in FP8?
    #[inline]
    pub fn is_fp8(&self, bi: usize) -> bool {
        self.meta[bi / 8] & (1 << (bi % 8)) != 0
    }

    /// Unpack to dequantized f32 (the values the datapath consumes).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.n_blocks * BLOCK);
        let mut off = 0usize;
        let mut fp4_idx = 0usize;
        for bi in 0..self.n_blocks {
            if self.is_fp8(bi) {
                for j in 0..BLOCK {
                    out.push(decode_e4m3(self.payload[off + j]));
                }
                off += BLOCK;
            } else {
                let s = decode_e4m3(self.scales[fp4_idx]);
                fp4_idx += 1;
                let s = if s > 0.0 { s } else { 0.0 };
                for j in 0..BLOCK / 2 {
                    let b = self.payload[off + j];
                    out.push(decode_e2m1(b & 0x0f) * s);
                    out.push(decode_e2m1(b >> 4) * s);
                }
                off += BLOCK / 2;
            }
        }
        out
    }

    /// Payload byte offset and FP4-scale index of every block, by block
    /// index — the random-access tables the panelizer walks with (the
    /// payload stride is 16 bytes for FP8 blocks, 8 for FP4).
    fn block_offsets(&self) -> (Vec<usize>, Vec<usize>) {
        let mut pay = Vec::with_capacity(self.n_blocks);
        let mut sc = Vec::with_capacity(self.n_blocks);
        let (mut po, mut so) = (0usize, 0usize);
        for bi in 0..self.n_blocks {
            pay.push(po);
            sc.push(so);
            if self.is_fp8(bi) {
                po += BLOCK;
            } else {
                po += BLOCK / 2;
                so += 1;
            }
        }
        (pay, sc)
    }

    /// Storage size in bits, split into (payload, scales, metadata) — the
    /// three bars of the paper's Fig. 8 breakdown.
    pub fn footprint_bits(&self) -> (usize, usize, usize) {
        let n_fp4 = self.n_blocks - self.n_fp8;
        let payload = self.n_fp8 * BLOCK * 8 + n_fp4 * BLOCK * 4;
        let scales = n_fp4 * 8;
        let meta = self.n_blocks;
        (payload, scales, meta)
    }

    /// Fraction of blocks kept in FP8.
    pub fn fp8_fraction(&self) -> f64 {
        self.n_fp8 as f64 / self.n_blocks.max(1) as f64
    }
}

/// The k-panelized **execution** layout of a packed weight tensor: the same
/// bits as [`FgmpTensor`] (1 meta bit, E4M3 bytes / E2M1 nibbles, E4M3
/// scale byte per FP4 block) reordered to the blocked matmul's panel walk,
/// so the kernel streams them front-to-back while it tiles.
///
/// The source tensor is the offline pipeline's transposed `(N, K)` pack —
/// output channel `n`'s K-dim blocks contiguous, exactly as the datapath
/// consumes them. The walk regroups those blocks panel-major:
///
/// ```text
///   for panel p over output columns [p·NR, p·NR+width):   // width ≤ NR
///     for k-block kb in 0..K/BLOCK:
///       for column j in 0..width:   block (p·NR+j, kb)
/// ```
///
/// which is the exact order `matmul_rows_packed` decodes — one cursor, no
/// index arithmetic in the hot loop, and the transpose to the executor's
/// `(K, N)` orientation happens in-register (fc2 included: no dequantized
/// f32 copy is ever materialized). Per-panel start offsets keep edge
/// panels addressable and let callers parallelize over panels if needed.
#[derive(Debug, Clone)]
pub struct PackedPanels {
    /// Input (reduction) dimension — a multiple of [`BLOCK`].
    pub k: usize,
    /// Output dimension (panel axis).
    pub n: usize,
    /// Panel width the layout was built for (the matmul kernel's NR).
    pub nr: usize,
    /// 1 bit per block in walk order, LSB-first; 1 = FP8.
    pub meta: Vec<u8>,
    /// Mixed payload in walk order (16 bytes per FP8 block, 8 per FP4).
    pub payload: Vec<u8>,
    /// E4M3 scale byte per FP4 block, in walk order.
    pub scales: Vec<u8>,
    /// Per-panel start offset into `payload`.
    pub panel_payload_off: Vec<usize>,
    /// Per-panel start index into `scales`.
    pub panel_scale_off: Vec<usize>,
    /// Per-panel start block index (into the walk-order meta bits).
    pub panel_block_off: Vec<usize>,
    pub n_blocks: usize,
    pub n_fp8: usize,
    /// Lazily-materialized dense `(K, N)` copy for the lowering paths that
    /// need f32 (PJRT literals). Deduped per tensor: every clone of a
    /// `ServerConfig`/arg-tail shares the same `Arc<PackedPanels>`, so the
    /// dequantize runs once per weight instead of once per executable
    /// build.
    dense_cache: OnceLock<Vec<f32>>,
}

impl PackedPanels {
    /// Reorder a transposed-layout `(N, K)` [`FgmpTensor`] into the panel
    /// walk for `nr`-wide output tiles. Pure byte shuffling — no value is
    /// decoded or re-encoded, so the bits (and therefore the dequantized
    /// lattice) are exactly the storage tensor's.
    pub fn from_tensor(t: &FgmpTensor, nr: usize) -> Self {
        assert_eq!(t.shape.len(), 2, "panelizer wants a (N, K) tensor, got {:?}", t.shape);
        let (n, k) = (t.shape[0], t.shape[1]);
        assert!(nr > 0);
        assert_eq!(k % BLOCK, 0, "K={k} must tile into {BLOCK}-blocks");
        let kb_count = k / BLOCK;
        let (pay_off, sc_off) = t.block_offsets();

        let n_panels = n.div_ceil(nr);
        let mut out = PackedPanels {
            k,
            n,
            nr,
            meta: vec![0u8; t.n_blocks.div_ceil(8)],
            payload: Vec::with_capacity(t.payload.len()),
            scales: Vec::with_capacity(t.scales.len()),
            panel_payload_off: Vec::with_capacity(n_panels),
            panel_scale_off: Vec::with_capacity(n_panels),
            panel_block_off: Vec::with_capacity(n_panels),
            n_blocks: t.n_blocks,
            n_fp8: t.n_fp8,
            dense_cache: OnceLock::new(),
        };
        let mut widx = 0usize; // walk-order block index
        for p in 0..n_panels {
            let nc = p * nr;
            let width = nr.min(n - nc);
            out.panel_payload_off.push(out.payload.len());
            out.panel_scale_off.push(out.scales.len());
            out.panel_block_off.push(widx);
            for kb in 0..kb_count {
                for j in 0..width {
                    let bi = (nc + j) * kb_count + kb;
                    if t.is_fp8(bi) {
                        out.meta[widx / 8] |= 1 << (widx % 8);
                        out.payload
                            .extend_from_slice(&t.payload[pay_off[bi]..pay_off[bi] + BLOCK]);
                    } else {
                        out.payload
                            .extend_from_slice(&t.payload[pay_off[bi]..pay_off[bi] + BLOCK / 2]);
                        out.scales.push(t.scales[sc_off[bi]]);
                    }
                    widx += 1;
                }
            }
        }
        out
    }

    /// Is walk-order block `widx` stored in FP8?
    #[inline]
    pub fn is_fp8_walk(&self, widx: usize) -> bool {
        self.meta[widx / 8] & (1 << (widx % 8)) != 0
    }

    /// Number of `nr`-wide panels.
    pub fn n_panels(&self) -> usize {
        self.n.div_ceil(self.nr)
    }

    /// Dequantize into the executor's `(K, N)` row-major orientation — the
    /// on-demand materializer for the PJRT/export path (value-identical to
    /// transposing [`FgmpTensor::unpack`], property-tested).
    pub fn unpack_kn(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.k * self.n];
        let kb_count = self.k / BLOCK;
        for p in 0..self.n_panels() {
            let nc = p * self.nr;
            let width = self.nr.min(self.n - nc);
            let mut off = self.panel_payload_off[p];
            let mut sci = self.panel_scale_off[p];
            let mut widx = self.panel_block_off[p];
            for kb in 0..kb_count {
                for j in 0..width {
                    let col = nc + j;
                    if self.is_fp8_walk(widx) {
                        for kk in 0..BLOCK {
                            out[(kb * BLOCK + kk) * self.n + col] =
                                decode_e4m3(self.payload[off + kk]);
                        }
                        off += BLOCK;
                    } else {
                        let s = decode_e4m3(self.scales[sci]);
                        sci += 1;
                        let s = if s > 0.0 { s } else { 0.0 };
                        for kk2 in 0..BLOCK / 2 {
                            let b = self.payload[off + kk2];
                            out[(kb * BLOCK + 2 * kk2) * self.n + col] = decode_e2m1(b & 0x0f) * s;
                            out[(kb * BLOCK + 2 * kk2 + 1) * self.n + col] =
                                decode_e2m1(b >> 4) * s;
                        }
                        off += BLOCK / 2;
                    }
                    widx += 1;
                }
            }
        }
        out
    }

    /// [`Self::unpack_kn`], memoized: the first call dequantizes and every
    /// later call on the same tensor returns the cached slice. Intended for
    /// shared `Arc<PackedPanels>` handles whose dense form is requested
    /// repeatedly (e.g. re-lowering the same weights into several
    /// executables); the one-shot native path should keep calling
    /// `unpack_kn` and let the copy drop.
    pub fn unpack_kn_cached(&self) -> &[f32] {
        self.dense_cache.get_or_init(|| self.unpack_kn())
    }

    /// Bytes this tensor keeps resident for execution: payload + scales +
    /// meta bits + the per-panel offset tables. This is the number the
    /// engine/serve weight-memory report compares against `4·K·N`.
    pub fn resident_bytes(&self) -> usize {
        let tables =
            self.panel_payload_off.len() + self.panel_scale_off.len() + self.panel_block_off.len();
        self.payload.len()
            + self.scales.len()
            + self.meta.len()
            + tables * std::mem::size_of::<usize>()
    }

    /// The f32 bytes a dequantized resident copy would occupy.
    pub fn f32_equiv_bytes(&self) -> usize {
        self.k * self.n * 4
    }

    /// Resident bytes the [`Self::to_all_fp4`] draft view of this tensor
    /// occupies, computed without building it: every block at the uniform
    /// NVFP4 stride (8 payload bytes + 1 scale byte), with the meta bits
    /// and per-panel offset tables unchanged. Lets reports price the
    /// speculative draft view's memory without re-quantizing.
    pub fn all_fp4_resident_bytes(&self) -> usize {
        let tables =
            self.panel_payload_off.len() + self.panel_scale_off.len() + self.panel_block_off.len();
        self.n_blocks * (BLOCK / 2)
            + self.n_blocks
            + self.meta.len()
            + tables * std::mem::size_of::<usize>()
    }

    /// The all-NVFP4 **draft view** of this tensor: every FP8 block is
    /// re-quantized to one NVFP4 block (decode the 16 E4M3 bytes, derive a
    /// dynamic-max scale, re-encode as E2M1 nibbles — the exact
    /// [`FgmpTensor::pack`] recipe), FP4 blocks are copied byte-for-byte.
    /// The panel walk, grid and `nr` are unchanged, so the existing
    /// LUT-decode packed matmul kernels execute it as-is; only the payload
    /// strides become uniform (8 + 1 bytes per block), shrinking
    /// weight-read bytes to the all-low-precision floor. This is the
    /// self-speculative decoder's draft model: the same network, one
    /// precision rung down, no second artifact.
    pub fn to_all_fp4(&self) -> PackedPanels {
        let kb_count = self.k / BLOCK;
        let n_panels = self.n_panels();
        let mut out = PackedPanels {
            k: self.k,
            n: self.n,
            nr: self.nr,
            meta: vec![0u8; self.n_blocks.div_ceil(8)],
            payload: Vec::with_capacity(self.n_blocks * (BLOCK / 2)),
            scales: Vec::with_capacity(self.n_blocks),
            panel_payload_off: Vec::with_capacity(n_panels),
            panel_scale_off: Vec::with_capacity(n_panels),
            panel_block_off: Vec::with_capacity(n_panels),
            n_blocks: self.n_blocks,
            n_fp8: 0,
            dense_cache: OnceLock::new(),
        };
        for p in 0..n_panels {
            let nc = p * self.nr;
            let width = self.nr.min(self.n - nc);
            let mut off = self.panel_payload_off[p];
            let mut sci = self.panel_scale_off[p];
            let mut widx = self.panel_block_off[p];
            out.panel_payload_off.push(out.payload.len());
            out.panel_scale_off.push(out.scales.len());
            out.panel_block_off.push(widx);
            for _kb in 0..kb_count {
                for _j in 0..width {
                    if self.is_fp8_walk(widx) {
                        let mut vals = [0.0f32; BLOCK];
                        for (kk, v) in vals.iter_mut().enumerate() {
                            *v = decode_e4m3(self.payload[off + kk]);
                        }
                        off += BLOCK;
                        let absmax = vals.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
                        let s = nvfp4_scale(absmax);
                        out.scales.push(encode_e4m3(s));
                        let sdec = decode_e4m3(encode_e4m3(s));
                        let safe = if sdec > 0.0 { sdec } else { 1.0 };
                        for pair in vals.chunks_exact(2) {
                            let lo = encode_e2m1(pair[0] / safe);
                            let hi = encode_e2m1(pair[1] / safe);
                            out.payload.push(lo | (hi << 4));
                        }
                    } else {
                        out.payload.extend_from_slice(&self.payload[off..off + BLOCK / 2]);
                        off += BLOCK / 2;
                        out.scales.push(self.scales[sci]);
                        sci += 1;
                    }
                    widx += 1;
                }
            }
        }
        out
    }

    /// Zero-copy view of the contiguous panel range `[p0, p1)` — the unit
    /// a tensor-parallel worker owns. Because the walk is panel-major, a
    /// panel range is a single contiguous byte-range of `payload` and
    /// `scales` plus a walk-order block interval: sharding a linear across
    /// workers is pure pointer arithmetic over the per-panel offset
    /// tables, no re-pack and no copied bytes.
    pub fn panel_range(&self, p0: usize, p1: usize) -> PanelRangeView<'_> {
        let np = self.n_panels();
        assert!(p0 <= p1 && p1 <= np, "panel range [{p0}, {p1}) out of {np} panels");
        let pay0 = self.panel_payload_off.get(p0).copied().unwrap_or(self.payload.len());
        let pay1 = if p1 < np { self.panel_payload_off[p1] } else { self.payload.len() };
        let sc0 = self.panel_scale_off.get(p0).copied().unwrap_or(self.scales.len());
        let sc1 = if p1 < np { self.panel_scale_off[p1] } else { self.scales.len() };
        let b0 = self.panel_block_off.get(p0).copied().unwrap_or(self.n_blocks);
        let b1 = if p1 < np { self.panel_block_off[p1] } else { self.n_blocks };
        PanelRangeView {
            p0,
            p1,
            col0: (p0 * self.nr).min(self.n),
            col1: (p1 * self.nr).min(self.n),
            payload: &self.payload[pay0..pay1],
            scales: &self.scales[sc0..sc1],
            block0: b0,
            block1: b1,
        }
    }
}

/// Borrowed byte-range of a [`PackedPanels`] covering panels `[p0, p1)`
/// (output columns `[col0, col1)`) — see [`PackedPanels::panel_range`].
#[derive(Debug, Clone, Copy)]
pub struct PanelRangeView<'a> {
    pub p0: usize,
    pub p1: usize,
    /// First output column owned by the range.
    pub col0: usize,
    /// One past the last output column owned by the range.
    pub col1: usize,
    /// The range's contiguous payload bytes.
    pub payload: &'a [u8],
    /// The range's contiguous FP4 scale bytes.
    pub scales: &'a [u8],
    /// First walk-order block index of the range.
    pub block0: usize,
    /// One past the last walk-order block index of the range.
    pub block1: usize,
}

impl PanelRangeView<'_> {
    /// Output columns owned by this range.
    pub fn cols(&self) -> usize {
        self.col1 - self.col0
    }

    /// Bytes a worker holding only this range would keep resident
    /// (payload + scales + its share of the meta bits, byte-rounded).
    pub fn resident_bytes(&self) -> usize {
        self.payload.len() + self.scales.len() + (self.block1 - self.block0).div_ceil(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quant_e4m3, nvfp4::nvfp4_roundtrip};

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    fn data(n: usize, scale: f32, seed: u64) -> Vec<f32> {
        let mut s = seed;
        (0..n).map(|_| lcg(&mut s) * scale).collect()
    }

    #[test]
    fn all_fp8_roundtrip_equals_e4m3() {
        let x = data(BLOCK * 10, 20.0, 1);
        let t = FgmpTensor::pack(&[10, BLOCK], &x, &vec![Precision::Fp8; 10], None);
        let back = t.unpack();
        for (a, &b) in back.iter().zip(&x) {
            assert_eq!(*a, quant_e4m3(b));
        }
        assert_eq!(t.n_fp8, 10);
        assert!(t.scales.is_empty());
    }

    #[test]
    fn all_fp4_roundtrip_equals_nvfp4() {
        let x = data(BLOCK * 10, 5.0, 2);
        let t = FgmpTensor::pack(&[10, BLOCK], &x, &vec![Precision::Fp4; 10], None);
        let back = t.unpack();
        let mut want = vec![0.0; x.len()];
        nvfp4_roundtrip(&x, &mut want);
        for (a, b) in back.iter().zip(&want) {
            assert!((a - b).abs() < 1e-6, "{a} vs {b}");
        }
        assert_eq!(t.scales.len(), 10);
    }

    #[test]
    fn mixed_blocks_select_correct_codec() {
        let x = data(BLOCK * 4, 3.0, 3);
        let prec = vec![Precision::Fp4, Precision::Fp8, Precision::Fp8, Precision::Fp4];
        let t = FgmpTensor::pack(&[4, BLOCK], &x, &prec, None);
        assert!(!t.is_fp8(0) && t.is_fp8(1) && t.is_fp8(2) && !t.is_fp8(3));
        assert_eq!(t.n_fp8, 2);
        let back = t.unpack();
        // FP8 blocks match e4m3
        for j in BLOCK..3 * BLOCK {
            assert_eq!(back[j], quant_e4m3(x[j]));
        }
    }

    #[test]
    fn footprint_accounting() {
        let x = data(BLOCK * 8, 1.0, 4);
        let prec: Vec<Precision> = (0..8)
            .map(|i| if i < 2 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[8, BLOCK], &x, &prec, None);
        let (p, s, m) = t.footprint_bits();
        assert_eq!(p, 2 * 128 + 6 * 64);
        assert_eq!(s, 6 * 8);
        assert_eq!(m, 8);
        assert_eq!(t.payload.len(), 2 * 16 + 6 * 8);
    }

    #[test]
    fn panelized_unpack_matches_tensor_unpack_transposed() {
        // (N, K) tensors with N off the panel grid and mixed assignments:
        // the panel walk must be a pure reordering of the same bits.
        const CASES: &[(usize, usize, usize, u64)] = &[
            (1, 1, 8, 10),
            (5, 2, 8, 11),
            (8, 3, 8, 12),
            (9, 1, 8, 13),
            (23, 4, 8, 14),
            (16, 2, 4, 15),
        ];
        for &(n, kb, nr, seed) in CASES {
            let k = kb * BLOCK;
            let x = data(n * k, 6.0, seed);
            let prec: Vec<Precision> = (0..n * kb)
                .map(|i| {
                    if (i * 7 + seed as usize) % 3 == 0 {
                        Precision::Fp8
                    } else {
                        Precision::Fp4
                    }
                })
                .collect();
            let t = FgmpTensor::pack(&[n, k], &x, &prec, None);
            let p = PackedPanels::from_tensor(&t, nr);
            assert_eq!(p.n_blocks, t.n_blocks);
            assert_eq!(p.n_fp8, t.n_fp8);
            assert_eq!(p.payload.len(), t.payload.len());
            assert_eq!(p.scales.len(), t.scales.len());
            let deq_nk = t.unpack(); // (N, K)
            let deq_kn = p.unpack_kn(); // (K, N)
            for ni in 0..n {
                for ki in 0..k {
                    assert_eq!(
                        deq_kn[ki * n + ni].to_bits(),
                        deq_nk[ni * k + ki].to_bits(),
                        "(n={n},k={k},nr={nr}) elem ({ni},{ki})"
                    );
                }
            }
        }
    }

    #[test]
    fn unpack_kn_cached_memoizes_per_tensor() {
        let (n, kb) = (9usize, 2usize);
        let k = kb * BLOCK;
        let x = data(n * k, 4.0, 33);
        let prec: Vec<Precision> =
            (0..n * kb).map(|i| if i % 2 == 0 { Precision::Fp8 } else { Precision::Fp4 }).collect();
        let t = FgmpTensor::pack(&[n, k], &x, &prec, None);
        let p = PackedPanels::from_tensor(&t, 8);
        let fresh = p.unpack_kn();
        let a = p.unpack_kn_cached();
        assert_eq!(a, fresh.as_slice(), "cached dense copy must equal unpack_kn");
        let b = p.unpack_kn_cached();
        assert_eq!(a.as_ptr(), b.as_ptr(), "second call must reuse the cached allocation");
        // A clone carries an independent cache with the same values.
        let q = p.clone();
        assert_eq!(q.unpack_kn_cached(), fresh.as_slice());
    }

    #[test]
    fn panelized_resident_bytes_beat_f32() {
        let (n, kb) = (24usize, 4usize);
        let k = kb * BLOCK;
        let x = data(n * k, 3.0, 21);
        // 30% FP8 / 70% FP4 — the paper's headline mix.
        let prec: Vec<Precision> =
            (0..n * kb).map(|i| if i % 10 < 3 { Precision::Fp8 } else { Precision::Fp4 }).collect();
        let t = FgmpTensor::pack(&[n, k], &x, &prec, None);
        let p = PackedPanels::from_tensor(&t, 8);
        assert!(
            (p.resident_bytes() as f64) < 0.25 * p.f32_equiv_bytes() as f64,
            "packed {} B vs f32 {} B",
            p.resident_bytes(),
            p.f32_equiv_bytes()
        );
    }

    #[test]
    fn panel_ranges_tile_the_packed_arrays() {
        // Consecutive panel ranges must partition payload, scales, blocks
        // and columns exactly — the invariant worker sharding rests on.
        for &(n, kb, nr, seed) in
            &[(23usize, 4usize, 8usize, 14u64), (9, 2, 8, 13), (16, 3, 4, 15)]
        {
            let k = kb * BLOCK;
            let x = data(n * k, 6.0, seed);
            let prec: Vec<Precision> = (0..n * kb)
                .map(|i| {
                    if (i * 7 + seed as usize) % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 }
                })
                .collect();
            let t = FgmpTensor::pack(&[n, k], &x, &prec, None);
            let p = PackedPanels::from_tensor(&t, nr);
            let np = p.n_panels();
            for world in 1..=4usize {
                let base = np / world;
                let extra = np % world;
                let mut p0 = 0usize;
                let (mut pay, mut sc) = (Vec::new(), Vec::new());
                let (mut blocks, mut cols, mut bytes) = (0usize, 0usize, 0usize);
                for w in 0..world {
                    let take = base + usize::from(w < extra);
                    let v = p.panel_range(p0, p0 + take);
                    assert_eq!(v.col0, (p0 * nr).min(n));
                    pay.extend_from_slice(v.payload);
                    sc.extend_from_slice(v.scales);
                    blocks += v.block1 - v.block0;
                    cols += v.cols();
                    bytes += v.resident_bytes();
                    p0 += take;
                }
                assert_eq!(pay, p.payload, "payload tiles (n={n} world={world})");
                assert_eq!(sc, p.scales, "scales tile (n={n} world={world})");
                assert_eq!(blocks, p.n_blocks);
                assert_eq!(cols, n);
                // Byte-rounding of per-range meta can only add, never lose.
                assert!(bytes >= p.payload.len() + p.scales.len() + p.meta.len());
            }
            // Degenerate empty range at either end is well-formed.
            let e = p.panel_range(np, np);
            assert_eq!(e.cols(), 0);
            assert!(e.payload.is_empty() && e.scales.is_empty());
        }
    }

    #[test]
    fn to_all_fp4_rewrites_fp8_blocks_and_copies_fp4_blocks() {
        for &(n, kb, nr, seed) in &[(23usize, 4usize, 8usize, 14u64), (9, 2, 8, 13), (16, 3, 4, 15)]
        {
            let k = kb * BLOCK;
            let x = data(n * k, 6.0, seed);
            let prec: Vec<Precision> = (0..n * kb)
                .map(|i| {
                    if (i * 7 + seed as usize) % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 }
                })
                .collect();
            let t = FgmpTensor::pack(&[n, k], &x, &prec, None);
            let p = PackedPanels::from_tensor(&t, nr);
            let d = p.to_all_fp4();
            // Same walk grid, zero FP8 blocks, uniform 8+1-byte strides.
            assert_eq!((d.k, d.n, d.nr, d.n_blocks), (p.k, p.n, p.nr, p.n_blocks));
            assert_eq!(d.n_fp8, 0);
            assert!(d.meta.iter().all(|&b| b == 0));
            assert_eq!(d.payload.len(), d.n_blocks * (BLOCK / 2));
            assert_eq!(d.scales.len(), d.n_blocks);
            assert_eq!(d.panel_block_off, p.panel_block_off);
            for (pi, &b0) in d.panel_block_off.iter().enumerate() {
                assert_eq!(d.panel_payload_off[pi], b0 * (BLOCK / 2));
                assert_eq!(d.panel_scale_off[pi], b0);
            }
            assert!(d.resident_bytes() < p.resident_bytes());
            assert_eq!(d.resident_bytes(), p.all_fp4_resident_bytes());
            // Block-by-block: FP4 blocks byte-identical; FP8 blocks equal
            // the pack recipe applied to their decoded values.
            let kb_count = k / BLOCK;
            for pi in 0..p.n_panels() {
                let nc = pi * nr;
                let width = nr.min(n - nc);
                let mut po = p.panel_payload_off[pi];
                let mut ps = p.panel_scale_off[pi];
                let mut widx = p.panel_block_off[pi];
                let mut qo = d.panel_payload_off[pi];
                let mut qs = d.panel_scale_off[pi];
                for _ in 0..kb_count * width {
                    if p.is_fp8_walk(widx) {
                        let vals: Vec<f32> =
                            (0..BLOCK).map(|kk| decode_e4m3(p.payload[po + kk])).collect();
                        let r = FgmpTensor::pack(&[1, BLOCK], &vals, &[Precision::Fp4], None);
                        assert_eq!(&d.payload[qo..qo + BLOCK / 2], &r.payload[..]);
                        assert_eq!(d.scales[qs], r.scales[0]);
                        po += BLOCK;
                    } else {
                        assert_eq!(&d.payload[qo..qo + BLOCK / 2], &p.payload[po..po + BLOCK / 2]);
                        assert_eq!(d.scales[qs], p.scales[ps]);
                        po += BLOCK / 2;
                        ps += 1;
                    }
                    qo += BLOCK / 2;
                    qs += 1;
                    widx += 1;
                }
            }
        }
    }

    #[test]
    fn to_all_fp4_lossless_on_fp4_lattice() {
        // FP8 blocks whose values already sit on the NVFP4 lattice with a
        // power-of-two scale (absmax pinned at 6·2^e so the dynamic-max
        // scale lands exactly on 2^e) re-quantize losslessly: the draft
        // view decodes to bit-identical f32 weights. This is the property
        // the 100%-accept speculative bench fixture rests on.
        let (n, kb, nr) = (8usize, 2usize, 8usize);
        let k = kb * BLOCK;
        let lat = [0.0f32, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0];
        let mut seed = 77u64;
        let mut x = vec![0.0f32; n * k];
        for b in x.chunks_exact_mut(BLOCK) {
            let e = ((lcg(&mut seed) * 8.0) as i32).clamp(-2, 2);
            let s = (2.0f32).powi(e);
            for v in b.iter_mut() {
                let m = lat[((lcg(&mut seed) + 0.5) * 8.0) as usize % 8];
                let sign = if lcg(&mut seed) > 0.0 { 1.0 } else { -1.0 };
                *v = sign * m * s;
            }
            b[0] = 6.0 * s;
        }
        let t = FgmpTensor::pack(&[n, k], &x, &vec![Precision::Fp8; n * kb], None);
        let p = PackedPanels::from_tensor(&t, nr);
        let d = p.to_all_fp4();
        let a = p.unpack_kn();
        let b = d.unpack_kn();
        for (i, (u, v)) in a.iter().zip(&b).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "elem {i}: {u} vs {v}");
        }
        // And the draft really is smaller: all-FP8 16 B/block down to 8.5.
        assert!(d.payload.len() * 2 == p.payload.len());
    }

    #[test]
    fn explicit_clip_scales_respected() {
        let x = data(BLOCK, 4.0, 5);
        let t = FgmpTensor::pack(&[1, BLOCK], &x, &[Precision::Fp4], Some(&[0.25]));
        assert_eq!(decode_e4m3(t.scales[0]), 0.25);
        let back = t.unpack();
        for &v in &back {
            assert!(v.abs() <= 6.0 * 0.25 + 1e-6);
        }
    }
}
