//! Sensitivity-weighted clipping (SW-Clip, paper §3.3).
//!
//! For each weight block destined for NVFP4, brute-force over the E4M3 scale
//! candidates to minimize the Fisher-weighted squared quantization error
//! (Eq. 11). The search space is the E4M3 grid restricted to a neighbourhood
//! of the dynamic-max scale (scales above it only lose resolution without
//! expanding range; scales far below clip everything), which matches the
//! paper's "brute-force search over possible values for s".

use super::fp8::e4m3_grid;
use super::nvfp4::nvfp4_scale;
use crate::util::kernels;
use crate::BLOCK;

thread_local! {
    /// Candidate scales, built once per thread (ascending E4M3 grid).
    static GRID: Vec<f32> = e4m3_grid();
}

/// Fisher-weighted squared error of quantizing `x` with scale `s`,
/// abandoning early once the running sum exceeds `abandon_above`
/// (the brute-force search only needs errors below the incumbent;
/// §Perf change 3). The E2M1 round-trip of the whole block is computed
/// up-front by the vectorized slice kernel; the f64 error accumulation
/// (and its per-element abandon checkpoints) keeps the original order.
#[inline]
fn weighted_err(x: &[f32], g2: &[f32], s: f32, abandon_above: f64) -> f64 {
    if s <= 0.0 {
        return x.iter().zip(g2).map(|(&v, &g)| (g as f64) * (v as f64) * (v as f64)).sum();
    }
    let inv_s = 1.0 / s;
    let mut qbuf = [0.0f32; BLOCK];
    let mut acc = 0.0f64;
    for (xc, gc) in x.chunks(BLOCK).zip(g2.chunks(BLOCK)) {
        let q = &mut qbuf[..xc.len()];
        kernels::e2m1_scaled_slice(xc, inv_s, s, q);
        for ((&v, &qv), &g) in xc.iter().zip(q.iter()).zip(gc) {
            let d = (qv - v) as f64;
            acc += g as f64 * d * d;
            if acc > abandon_above {
                return f64::INFINITY;
            }
        }
    }
    acc
}

/// Search the per-block scale minimizing the sensitivity-weighted error.
/// `g2` is the per-element Fisher weighting (ones = plain MSE clipping).
/// Returns (best scale, its weighted error).
pub fn sw_clip_block(x: &[f32], g2: &[f32]) -> (f32, f64) {
    debug_assert_eq!(x.len(), g2.len());
    let s_dyn = nvfp4_scale(kernels::absmax(x));
    if s_dyn == 0.0 {
        return (0.0, 0.0);
    }
    let mut best_s = s_dyn;
    let mut best_e = weighted_err(x, g2, s_dyn, f64::INFINITY);
    // Candidates: every non-zero E4M3 grid value up to s_dyn (the paper's
    // brute-force over possible scale values). Scales above s_dyn strictly
    // coarsen the lattice with no added range (absmax/s_dyn already maps to
    // the top code), so they never reduce the error. Candidates are walked
    // top-down so the incumbent tightens fast and early-abandon prunes the
    // deep-clip tail.
    GRID.with(|grid| {
        for &s in grid.iter().rev() {
            if s >= s_dyn || s == 0.0 {
                continue;
            }
            let e = weighted_err(x, g2, s, best_e);
            if e < best_e {
                best_e = e;
                best_s = s;
            }
        }
    });
    (best_s, best_e)
}

/// SW-Clip an entire tensor (blocks along the last axis). Returns per-FP4
/// block scales aligned with *all* blocks (callers index by block id).
pub fn sw_clip_tensor(data: &[f32], fisher: &[f32]) -> Vec<f32> {
    assert_eq!(data.len(), fisher.len());
    assert_eq!(data.len() % BLOCK, 0);
    data.chunks_exact(BLOCK)
        .zip(fisher.chunks_exact(BLOCK))
        .map(|(xb, gb)| sw_clip_block(xb, gb).0)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::nvfp4::nvfp4_roundtrip_block;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn never_worse_than_dynamic_max() {
        let mut seed = 42u64;
        for _ in 0..64 {
            let x: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut seed) * 4.0).collect();
            let g2: Vec<f32> = (0..BLOCK).map(|_| lcg(&mut seed).abs() + 0.01).collect();
            let absmax = x.iter().fold(0.0f32, |m, &v| m.max(v.abs()));
            let s_dyn = nvfp4_scale(absmax);
            let (s_best, e_best) = sw_clip_block(&x, &g2);
            let e_dyn = {
                let mut out = [0.0f32; BLOCK];
                nvfp4_roundtrip_block(&x, s_dyn, &mut out);
                x.iter()
                    .zip(out.iter())
                    .zip(&g2)
                    .map(|((&v, &q), &g)| (g as f64) * ((q - v) as f64).powi(2))
                    .sum::<f64>()
            };
            assert!(e_best <= e_dyn + 1e-12, "clip must not increase error");
            assert!(s_best > 0.0);
        }
    }

    #[test]
    fn clipping_helps_outlier_block() {
        // One huge outlier with tiny Fisher + 15 sensitive small values:
        // clipping the range (smaller s) must win.
        let mut x = [0.1f32; BLOCK];
        x[0] = 60.0;
        let mut g2 = [10.0f32; BLOCK];
        g2[0] = 1e-6;
        let absmax = 60.0f32;
        let s_dyn = nvfp4_scale(absmax);
        let (s_best, _) = sw_clip_block(&x, &g2);
        assert!(s_best < s_dyn, "expected clipped scale, got {s_best} >= {s_dyn}");
    }

    #[test]
    fn zero_block_gets_zero_scale() {
        let x = [0.0f32; BLOCK];
        let g2 = [1.0f32; BLOCK];
        assert_eq!(sw_clip_block(&x, &g2), (0.0, 0.0));
    }

    #[test]
    fn tensor_api_len() {
        let mut seed = 9u64;
        let x: Vec<f32> = (0..BLOCK * 7).map(|_| lcg(&mut seed)).collect();
        let g: Vec<f32> = (0..BLOCK * 7).map(|_| lcg(&mut seed).abs()).collect();
        assert_eq!(sw_clip_tensor(&x, &g).len(), 7);
    }
}
