//! NVFP4 block quantizer: 16 E2M1 values + one E4M3 scale per block.
//!
//! Matches `ref.quant_nvfp4`: dynamic-max scale = round_e4m3(absmax/6), or an
//! explicit (clipped) scale from the SW-Clip search.

use super::fp8::quant_e4m3;
use crate::util::kernels;
use crate::BLOCK;

/// Largest representable E2M1 magnitude (re-exported for scale math).
pub use super::fp4::E2M1_MAX;

/// One quantized 16-element block: dequantized values + the scale used.
#[derive(Debug, Clone, PartialEq)]
pub struct NvFp4Block {
    pub values: [f32; BLOCK],
    pub scale: f32,
}

/// Dynamic-max per-block scale (paper's online activation path).
#[inline]
pub fn nvfp4_scale(absmax: f32) -> f32 {
    quant_e4m3(absmax / E2M1_MAX)
}

/// Round-trip one block through NVFP4 with an explicit scale.
/// `scale` must be an E4M3 value (callers pass `nvfp4_scale` output or a
/// grid value from the clip search). A zero scale maps the block to zeros.
pub fn nvfp4_roundtrip_block(x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    kernels::nvfp4_block(x, scale, out)
}

/// Round-trip a whole tensor (blocks along the contiguous last axis) using
/// dynamic-max scales. Returns the per-block scales. Each block runs
/// through the vectorized slice kernels (absmax + E2M1 round-trip) rather
/// than element-at-a-time.
pub fn nvfp4_roundtrip(x: &[f32], out: &mut [f32]) -> Vec<f32> {
    assert_eq!(x.len() % BLOCK, 0, "length must be a multiple of {BLOCK}");
    assert_eq!(x.len(), out.len());
    let mut scales = Vec::with_capacity(x.len() / BLOCK);
    for (xb, ob) in x.chunks_exact(BLOCK).zip(out.chunks_exact_mut(BLOCK)) {
        let s = nvfp4_scale(kernels::absmax(xb));
        kernels::nvfp4_block(xb, s, ob);
        scales.push(s);
    }
    scales
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lcg(seed: &mut u64) -> f32 {
        *seed = seed.wrapping_mul(6364136223846793005).wrapping_add(1);
        ((*seed >> 33) as f32 / (1u64 << 31) as f32) - 0.5
    }

    #[test]
    fn zero_block() {
        let x = [0.0f32; BLOCK];
        let mut out = [1.0f32; BLOCK];
        let s = nvfp4_roundtrip(&x, &mut out);
        assert_eq!(s, vec![0.0]);
        assert!(out.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn dequantized_bounded_by_six_scale() {
        let mut seed = 7u64;
        let x: Vec<f32> = (0..BLOCK * 32).map(|_| lcg(&mut seed) * 100.0).collect();
        let mut out = vec![0.0; x.len()];
        let scales = nvfp4_roundtrip(&x, &mut out);
        for (ob, &s) in out.chunks_exact(BLOCK).zip(&scales) {
            for &v in ob {
                assert!(v.abs() <= 6.0 * s + 1e-6);
            }
        }
    }

    #[test]
    fn block_independence() {
        let mut seed = 3u64;
        let mut x: Vec<f32> = (0..BLOCK * 2).map(|_| lcg(&mut seed) * 4.0).collect();
        let mut out1 = vec![0.0; x.len()];
        nvfp4_roundtrip(&x, &mut out1);
        for v in &mut x[BLOCK..] {
            *v *= 50.0;
        }
        let mut out2 = vec![0.0; x.len()];
        nvfp4_roundtrip(&x, &mut out2);
        assert_eq!(&out1[..BLOCK], &out2[..BLOCK]);
    }

    #[test]
    fn idempotent() {
        let mut seed = 11u64;
        let x: Vec<f32> = (0..BLOCK * 8).map(|_| lcg(&mut seed) * 10.0).collect();
        let mut once = vec![0.0; x.len()];
        nvfp4_roundtrip(&x, &mut once);
        let mut twice = vec![0.0; x.len()];
        nvfp4_roundtrip(&once, &mut twice);
        // Not exactly idempotent in general (scale re-derivation), but the
        // values must stay on the representable lattice: error of the second
        // pass is zero when absmax is preserved, which dynamic-max guarantees
        // (the max element round-trips to ±6·s exactly when it sets absmax).
        for (a, b) in once.iter().zip(&twice) {
            assert!((a - b).abs() <= f32::EPSILON * 8.0 * a.abs().max(1.0));
        }
    }
}
