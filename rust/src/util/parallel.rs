//! Scoped parallel map over a slice — replaces rayon for the offline
//! weight-quantization pipeline (embarrassingly parallel over linears).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Panic payload [`par_run_once`] re-raises after every worker has joined:
/// worker `worker`'s job panicked with `reason`. Engines catch this at the
/// step boundary (`runtime::catch_worker`) and convert it into the typed
/// `EngineError::WorkerFailed`, so one lost worker fails the step instead
/// of killing the serving process.
#[derive(Debug, Clone)]
pub struct WorkerPanic {
    pub worker: usize,
    pub reason: String,
}

/// Best-effort stringification of a caught panic payload.
fn panic_reason(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Map `f` over `items` using up to `std::thread::available_parallelism()`
/// worker threads; results come back in input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.iter().map(&f).collect();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = tx.send((i, f(&items[i])));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|o| o.expect("worker filled slot")).collect()
    })
}

/// Run a set of one-shot jobs concurrently on scoped threads, returning
/// their results in input order. Unlike [`par_map`] this gives every job
/// its own thread (no work-stealing index): it is the fork/join primitive
/// of the tensor-parallel engine, where each job *is* one worker's whole
/// shard step and must run even when `jobs.len()` exceeds the core count
/// (a worker blocking would deadlock a collective). Job 0 runs inline on
/// the calling thread, so a single-worker "fleet" costs no spawn at all.
///
/// Worker panics are caught per job (`AssertUnwindSafe`: a panicked job
/// may leave its captures — e.g. a KV shard — partially appended, which
/// the engine restores with `KvState::truncate` before retrying). Every
/// worker is joined first, then the *first* failure is re-raised on the
/// calling thread as a [`WorkerPanic`] payload — a plain unwinding panic
/// after the scope has fully quiesced, never a double-panic abort.
pub fn par_run_once<'env, R: Send>(jobs: Vec<Box<dyn FnOnce() -> R + Send + 'env>>) -> Vec<R> {
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let mut it = jobs.into_iter();
    let first = it.next().expect("n >= 1");
    let rest: Vec<_> = it.collect();
    let results: Vec<std::thread::Result<R>> = std::thread::scope(|s| {
        let handles: Vec<_> =
            rest.into_iter().map(|j| s.spawn(move || catch_unwind(AssertUnwindSafe(j)))).collect();
        let mut out: Vec<std::thread::Result<R>> = Vec::with_capacity(n);
        out.push(catch_unwind(AssertUnwindSafe(first)));
        for h in handles {
            // The closure caught its own panic, so join only fails if the
            // runtime killed the thread some other way — fold it in too.
            out.push(h.join().unwrap_or_else(Err));
        }
        out
    });
    let mut out = Vec::with_capacity(n);
    for (worker, r) in results.into_iter().enumerate() {
        match r {
            Ok(v) => out.push(v),
            Err(payload) => {
                let reason = panic_reason(payload.as_ref());
                std::panic::panic_any(WorkerPanic { worker, reason });
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(&e, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], |&x| x + 1), vec![6]);
    }

    #[test]
    fn run_once_ordered_and_handles_empty() {
        let empty: Vec<Box<dyn FnOnce() -> u32 + Send>> = vec![];
        assert!(par_run_once(empty).is_empty());
        let data = vec![10u32, 20, 30, 40, 50];
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send + '_>> = data
            .iter()
            .enumerate()
            .map(|(i, &v)| Box::new(move || v + i as u32) as Box<dyn FnOnce() -> u32 + Send>)
            .collect();
        assert_eq!(par_run_once(jobs), vec![10, 21, 32, 43, 54]);
    }

    #[test]
    fn run_once_joins_all_then_raises_typed_worker_panic() {
        // Worker 2 panics; workers 0/1/3 must still run to completion
        // before the calling thread sees a WorkerPanic payload naming the
        // failed lane (the engine's recovery contract).
        let ran = AtomicUsize::new(0);
        let jobs: Vec<Box<dyn FnOnce() -> u32 + Send + '_>> = (0..4)
            .map(|i| {
                let ran = &ran;
                Box::new(move || {
                    if i == 2 {
                        panic!("boom in worker 2");
                    }
                    ran.fetch_add(1, Ordering::Relaxed);
                    i as u32
                }) as Box<dyn FnOnce() -> u32 + Send>
            })
            .collect();
        let err = catch_unwind(AssertUnwindSafe(|| par_run_once(jobs)))
            .expect_err("a panicked worker must fail the run");
        let wp = err.downcast_ref::<WorkerPanic>().expect("typed WorkerPanic payload");
        assert_eq!(wp.worker, 2);
        assert!(wp.reason.contains("boom"), "reason carries the panic message: {}", wp.reason);
        assert_eq!(ran.load(Ordering::Relaxed), 3, "surviving workers all joined");
    }

    #[test]
    fn actually_parallel_work() {
        let items: Vec<u64> = (0..64).collect();
        let out = par_map(&items, |&x| (0..10_000u64).fold(x, |a, b| a.wrapping_add(b * b)));
        assert_eq!(out.len(), 64);
        assert_eq!(out[0], (0..10_000u64).fold(0u64, |a, b| a.wrapping_add(b * b)));
    }
}
