//! Scoped parallel map over a slice — replaces rayon for the offline
//! weight-quantization pipeline (embarrassingly parallel over linears).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Map `f` over `items` using up to `std::thread::available_parallelism()`
/// worker threads; results come back in input order.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(&T) -> R + Sync,
{
    let n = items.len();
    if n <= 1 {
        return items.iter().map(&f).collect();
    }
    let workers = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(4).min(n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|s| {
        for _ in 0..workers {
            let next = &next;
            let f = &f;
            let tx = tx.clone();
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                let _ = tx.send((i, f(&items[i])));
            });
        }
        drop(tx);
        let mut slots: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            slots[i] = Some(r);
        }
        slots.into_iter().map(|o| o.expect("worker filled slot")).collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |&x| x * 2);
        assert_eq!(out, (0..1000).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn empty_and_single() {
        let e: Vec<u32> = vec![];
        assert!(par_map(&e, |&x| x).is_empty());
        assert_eq!(par_map(&[5u32], |&x| x + 1), vec![6]);
    }

    #[test]
    fn actually_parallel_work() {
        let items: Vec<u64> = (0..64).collect();
        let out = par_map(&items, |&x| (0..10_000u64).fold(x, |a, b| a.wrapping_add(b * b)));
        assert_eq!(out.len(), 64);
        assert_eq!(out[0], (0..10_000u64).fold(0u64, |a, b| a.wrapping_add(b * b)));
    }
}
