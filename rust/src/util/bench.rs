//! Micro-bench timer — replaces criterion for the hotpath benches (offline
//! build). Warmup + N timed iterations, reports mean/p50/min and
//! throughput; plain text output, machine-greppable.

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<36} iters {:>4}  mean {:>12?}  p50 {:>12?}  min {:>12?}",
            self.name, self.iters, self.mean, self.median, self.min
        );
        if let Some(e) = self.elements {
            let eps = e as f64 / self.mean.as_secs_f64();
            s.push_str(&format!("  {:>10.1} Melem/s", eps / 1e6));
        }
        s
    }
}

/// Time `f` with automatic iteration count targeting ~`budget` total.
pub fn bench<R>(name: &str, elements: Option<u64>, budget: Duration, mut f: impl FnMut() -> R) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = (budget.as_secs_f64() / once.as_secs_f64()).clamp(5.0, 1000.0) as usize;

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        median: samples[iters / 2],
        min: samples[0],
        elements,
    }
}

/// Re-export of the standard black_box for bench bodies.
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench("noop_sum", Some(1000), Duration::from_millis(20), || {
            (0..1000u64).sum::<u64>()
        });
        assert!(r.iters >= 5);
        assert!(r.min <= r.mean);
        assert!(r.report().contains("noop_sum"));
    }
}
