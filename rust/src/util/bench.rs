//! Micro-bench harness — replaces criterion for the hotpath benches
//! (offline build). Warmup + N timed iterations, reporting mean/p50/min/max
//! and throughput, plus a machine-readable suite format: every bench run
//! can be collected into a [`BenchSuite`] and written as `BENCH_<name>.json`
//! via [`crate::util::json`], the one output format shared by
//! `cargo bench --bench hotpath`, the `fgmp bench` CLI, and the CI
//! perf-regression gate ([`BenchSuite::check_regressions`]).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::{Duration, Instant};

use crate::util::Json;
use crate::Result;

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
    pub max: Duration,
    /// Optional elements-per-iteration for throughput reporting.
    pub elements: Option<u64>,
}

impl BenchResult {
    pub fn report(&self) -> String {
        let mut s = format!(
            "{:<36} iters {:>4}  mean {:>12?}  p50 {:>12?}  min {:>12?}  max {:>12?}",
            self.name, self.iters, self.mean, self.median, self.min, self.max
        );
        if let Some(e) = self.elements {
            let eps = e as f64 / self.mean.as_secs_f64();
            s.push_str(&format!("  {:>10.1} Melem/s", eps / 1e6));
        }
        s
    }

    /// Peak throughput in Melem/s (elements over the *minimum* iteration
    /// time — the noise-robust statistic the CI gate compares).
    pub fn melem_per_s(&self) -> Option<f64> {
        self.elements.map(|e| e as f64 / self.min.as_secs_f64().max(1e-12) / 1e6)
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iters".to_string(), Json::Num(self.iters as f64));
        m.insert("mean_ns".to_string(), Json::Num(self.mean.as_nanos() as f64));
        m.insert("median_ns".to_string(), Json::Num(self.median.as_nanos() as f64));
        m.insert("min_ns".to_string(), Json::Num(self.min.as_nanos() as f64));
        m.insert("max_ns".to_string(), Json::Num(self.max.as_nanos() as f64));
        if let Some(e) = self.elements {
            m.insert("elements".to_string(), Json::Num(e as f64));
            m.insert("melem_per_s".to_string(), Json::Num(self.melem_per_s().unwrap_or(0.0)));
        }
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<BenchResult> {
        let dur = |key: &str| -> Result<Duration> {
            Ok(Duration::from_nanos(v.get(key)?.as_f64()? as u64))
        };
        Ok(BenchResult {
            name: v.get("name")?.as_str()?.to_string(),
            iters: v.get("iters")?.as_usize()?,
            mean: dur("mean_ns")?,
            median: dur("median_ns")?,
            min: dur("min_ns")?,
            max: dur("max_ns")?,
            elements: match v.opt("elements") {
                Some(e) => Some(e.as_f64()? as u64),
                None => None,
            },
        })
    }
}

/// A named collection of bench results plus derived scalar metrics
/// (speedup ratios etc.), serializable to `BENCH_<name>.json`.
#[derive(Debug, Clone, Default)]
pub struct BenchSuite {
    pub name: String,
    pub results: Vec<BenchResult>,
    /// Derived metrics, e.g. `"speedup_matmul_d512" -> 3.4`. In a baseline
    /// file these act as *floors* the current run must meet.
    pub derived: BTreeMap<String, f64>,
    /// Free-form run metadata carried into `BENCH_<name>.json` — the
    /// engine configuration the workloads ran under (workers, KV
    /// precision, speculation depth, budget, filter). Never compared by
    /// the regression gate; omitted from the JSON when empty so baseline
    /// files without it keep loading.
    pub meta: BTreeMap<String, String>,
}

impl BenchSuite {
    pub fn new(name: &str) -> BenchSuite {
        BenchSuite {
            name: name.to_string(),
            results: Vec::new(),
            derived: BTreeMap::new(),
            meta: BTreeMap::new(),
        }
    }

    pub fn push(&mut self, r: BenchResult) {
        self.results.push(r);
    }

    pub fn derive(&mut self, key: &str, value: f64) {
        self.derived.insert(key.to_string(), value);
    }

    pub fn set_meta(&mut self, key: &str, value: impl Into<String>) {
        self.meta.insert(key.to_string(), value.into());
    }

    pub fn get(&self, name: &str) -> Option<&BenchResult> {
        self.results.iter().find(|r| r.name == name)
    }

    /// The sub-suite of results and derived metrics whose names contain
    /// `substr` (metadata and suite name carry over). A `--filter` run
    /// gates against the matching slice of the full baseline through
    /// this, instead of failing on every bench it deliberately skipped.
    pub fn filtered(&self, substr: &str) -> BenchSuite {
        BenchSuite {
            name: self.name.clone(),
            results: self.results.iter().filter(|r| r.name.contains(substr)).cloned().collect(),
            derived: self
                .derived
                .iter()
                .filter(|(k, _)| k.contains(substr))
                .map(|(k, &v)| (k.clone(), v))
                .collect(),
            meta: self.meta.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("suite".to_string(), Json::Str(self.name.clone()));
        m.insert(
            "results".to_string(),
            Json::Arr(self.results.iter().map(|r| r.to_json()).collect()),
        );
        let derived: BTreeMap<String, Json> =
            self.derived.iter().map(|(k, &v)| (k.clone(), Json::Num(v))).collect();
        m.insert("derived".to_string(), Json::Obj(derived));
        if !self.meta.is_empty() {
            let meta: BTreeMap<String, Json> =
                self.meta.iter().map(|(k, v)| (k.clone(), Json::Str(v.clone()))).collect();
            m.insert("meta".to_string(), Json::Obj(meta));
        }
        Json::Obj(m)
    }

    pub fn from_json(v: &Json) -> Result<BenchSuite> {
        let results = v
            .get("results")?
            .as_arr()?
            .iter()
            .map(BenchResult::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut derived = BTreeMap::new();
        if let Some(d) = v.opt("derived") {
            for (k, x) in d.as_obj()? {
                derived.insert(k.clone(), x.as_f64()?);
            }
        }
        let mut meta = BTreeMap::new();
        if let Some(d) = v.opt("meta") {
            for (k, x) in d.as_obj()? {
                meta.insert(k.clone(), x.as_str()?.to_string());
            }
        }
        Ok(BenchSuite { name: v.get("suite")?.as_str()?.to_string(), results, derived, meta })
    }

    pub fn load(path: impl AsRef<Path>) -> Result<BenchSuite> {
        let text = std::fs::read_to_string(path.as_ref())?;
        BenchSuite::from_json(&Json::parse(&text)?)
    }

    /// Write `BENCH_<name>.json` into `dir`; returns the path written.
    pub fn write(&self, dir: impl AsRef<Path>) -> Result<PathBuf> {
        let dir = dir.as_ref();
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json().to_string())?;
        Ok(path)
    }

    /// The CI perf gate: compare this run against a baseline suite and
    /// return one message per regression. A result regresses when its
    /// min-time throughput falls below `baseline / max_slowdown` (or, for
    /// unthroughputed benches, its min time exceeds `baseline ·
    /// max_slowdown`). Baseline `derived` entries are floors the current
    /// run's derived metrics must meet. Benches present only in the
    /// current run are ignored (new benches don't need a baseline yet);
    /// benches present only in the baseline are reported (a silent rename
    /// must not disable the gate).
    pub fn check_regressions(&self, baseline: &BenchSuite, max_slowdown: f64) -> Vec<String> {
        let mut fails = Vec::new();
        for base in &baseline.results {
            let Some(cur) = self.get(&base.name) else {
                fails.push(format!("bench '{}' in baseline but not in this run", base.name));
                continue;
            };
            match (cur.melem_per_s(), base.melem_per_s()) {
                (Some(c), Some(b)) => {
                    if c * max_slowdown < b {
                        fails.push(format!(
                            "'{}' throughput {:.1} Melem/s < baseline {:.1} / {:.1}x",
                            base.name, c, b, max_slowdown
                        ));
                    }
                }
                _ => {
                    let (c, b) = (cur.min.as_secs_f64(), base.min.as_secs_f64());
                    if c > b * max_slowdown {
                        fails.push(format!(
                            "'{}' min time {:.3}ms > baseline {:.3}ms x {:.1}",
                            base.name,
                            c * 1e3,
                            b * 1e3,
                            max_slowdown
                        ));
                    }
                }
            }
        }
        for (k, &floor) in &baseline.derived {
            match self.derived.get(k) {
                None => {
                    fails.push(format!("derived metric '{k}' missing (baseline floor {floor})"))
                }
                Some(&v) if v < floor => {
                    fails.push(format!("derived metric '{k}' = {v:.2} below floor {floor:.2}"))
                }
                Some(_) => {}
            }
        }
        fails
    }
}

/// Time `f` with automatic iteration count targeting ~`budget` total.
/// A zero budget is smoke mode: one timed iteration (used by tests that
/// only need the suite structure, not stable timings).
pub fn bench<R>(
    name: &str,
    elements: Option<u64>,
    budget: Duration,
    mut f: impl FnMut() -> R,
) -> BenchResult {
    // Warmup + calibration.
    let t0 = Instant::now();
    std::hint::black_box(f());
    let once = t0.elapsed().max(Duration::from_nanos(50));
    let iters = if budget.is_zero() {
        1
    } else {
        (budget.as_secs_f64() / once.as_secs_f64()).clamp(5.0, 1000.0) as usize
    };

    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        std::hint::black_box(f());
        samples.push(t.elapsed());
    }
    samples.sort();
    let mean = samples.iter().sum::<Duration>() / iters as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        median: samples[iters / 2],
        min: samples[0],
        max: samples[iters - 1],
        elements,
    }
}

/// Per-iteration time budget, overridable with `FGMP_BENCH_BUDGET_MS`
/// (the CI perf job uses a short budget to bound wall-clock).
pub fn budget_from_env(default_ms: u64) -> Duration {
    let ms = std::env::var("FGMP_BENCH_BUDGET_MS")
        .ok()
        .and_then(|v| v.parse::<u64>().ok())
        .unwrap_or(default_ms);
    Duration::from_millis(ms)
}

/// Re-export of the standard black_box for bench bodies.
pub use std::hint::black_box;

#[cfg(test)]
mod tests {
    use super::*;

    fn quick(name: &str, elements: Option<u64>) -> BenchResult {
        bench(name, elements, Duration::from_millis(10), || (0..1000u64).sum::<u64>())
    }

    #[test]
    fn bench_runs_and_reports() {
        let r = quick("noop_sum", Some(1000));
        assert!(r.iters >= 5);
        assert!(r.min <= r.mean);
        assert!(r.mean <= r.max);
        assert!(r.report().contains("noop_sum"));
        assert!(r.melem_per_s().unwrap() > 0.0);
    }

    #[test]
    fn suite_roundtrips_through_json() {
        let mut s = BenchSuite::new("unit");
        s.push(quick("a", Some(1000)));
        s.push(quick("b", None));
        s.derive("speedup_a_over_b", 2.5);
        s.set_meta("workers", "2");
        s.set_meta("spec.k", "4");
        let back = BenchSuite::from_json(&s.to_json()).unwrap();
        assert_eq!(back.name, "unit");
        assert_eq!(back.results.len(), 2);
        assert_eq!(back.results[0].name, "a");
        assert_eq!(back.results[0].elements, Some(1000));
        assert_eq!(back.results[1].elements, None);
        assert_eq!(back.derived["speedup_a_over_b"], 2.5);
        assert_eq!(back.meta["workers"], "2");
        assert_eq!(back.meta["spec.k"], "4");
        // durations survive to nanosecond precision
        assert_eq!(back.results[0].min, s.results[0].min);
    }

    #[test]
    fn suite_without_meta_omits_key_and_still_loads() {
        // Baselines checked in before metadata existed have no "meta" key;
        // both directions must keep working.
        let s = {
            let mut s = BenchSuite::new("plain");
            s.push(quick("a", Some(10)));
            s
        };
        let j = s.to_json();
        assert!(j.opt("meta").is_none(), "empty meta must not be serialized");
        let back = BenchSuite::from_json(&j).unwrap();
        assert!(back.meta.is_empty());
    }

    #[test]
    fn filtered_restricts_results_and_derived_by_substring() {
        let mut s = BenchSuite::new("full");
        s.push(quick("decode_step_spec_x", Some(4)));
        s.push(quick("matmul_blocked", Some(100)));
        s.derive("speedup_decode_spec_x", 1.8);
        s.derive("speedup_matmul", 3.0);
        s.set_meta("spec.k", "4");
        let f = s.filtered("spec");
        assert_eq!(f.results.len(), 1);
        assert_eq!(f.results[0].name, "decode_step_spec_x");
        assert_eq!(f.derived.len(), 1);
        assert!(f.derived.contains_key("speedup_decode_spec_x"));
        assert_eq!(f.meta["spec.k"], "4", "metadata carries over");

        // A filtered current run gates cleanly against the matching slice
        // of a full baseline — and still fails on a real regression in it.
        let cur = s.filtered("spec");
        assert!(cur.check_regressions(&s.filtered("spec"), 2.0).is_empty());
        let mut base = s.filtered("spec");
        base.derive("speedup_decode_spec_x", 99.0);
        assert_eq!(cur.check_regressions(&base, 2.0).len(), 1);
    }

    #[test]
    fn suite_writes_bench_json_file() {
        let dir = std::env::temp_dir().join("fgmp_bench_suite_test");
        let _ = std::fs::remove_dir_all(&dir);
        let mut s = BenchSuite::new("unitfile");
        s.push(quick("a", Some(64)));
        let path = s.write(&dir).unwrap();
        assert!(path.ends_with("BENCH_unitfile.json"));
        let back = BenchSuite::load(&path).unwrap();
        assert_eq!(back.name, "unitfile");
    }

    #[test]
    fn regression_gate_fires_on_2x_loss() {
        let mk = |name: &str, min_ns: u64, elements: Option<u64>| BenchResult {
            name: name.to_string(),
            iters: 10,
            mean: Duration::from_nanos(min_ns * 2),
            median: Duration::from_nanos(min_ns * 2),
            min: Duration::from_nanos(min_ns),
            max: Duration::from_nanos(min_ns * 3),
            elements,
        };
        let mut base = BenchSuite::new("b");
        base.push(mk("tput", 1000, Some(1_000_000)));
        base.push(mk("wall", 1000, None));
        base.derive("speedup", 2.0);

        // identical run: clean
        let mut cur = base.clone();
        assert!(cur.check_regressions(&base, 2.0).is_empty());

        // 3x slower on both + derived below floor + missing bench
        cur.results[0].min = Duration::from_nanos(3000);
        cur.results[1].min = Duration::from_nanos(3000);
        cur.derive("speedup", 1.0);
        let fails = cur.check_regressions(&base, 2.0);
        assert_eq!(fails.len(), 3, "{fails:?}");

        // bench missing from current run is reported
        cur.results.clear();
        let fails = cur.check_regressions(&base, 2.0);
        assert!(fails.iter().any(|f| f.contains("not in this run")));
    }

    #[test]
    fn budget_env_default_and_override() {
        // Robust whether or not FGMP_BENCH_BUDGET_MS is set in the test
        // environment: compute the expectation the same way users do.
        let want = std::env::var("FGMP_BENCH_BUDGET_MS")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .unwrap_or(123);
        assert_eq!(budget_from_env(123), Duration::from_millis(want));
    }
}
