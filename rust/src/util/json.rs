//! Minimal JSON parser/serializer — enough for the manifest, the task
//! suites, the golden fixtures, and report emission. Strict on structure,
//! permissive on whitespace; numbers parse as f64.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use anyhow::{bail, Context, Result};

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at byte {}", p.i);
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key).with_context(|| format!("missing key '{key}'")),
            _ => bail!("not an object (wanted key '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(n) => Ok(*n),
            _ => bail!("not a number"),
        }
    }
    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }
    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => bail!("not a string"),
        }
    }
    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => bail!("not an array"),
        }
    }
    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => bail!("not an object"),
        }
    }

    pub fn usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }
    pub fn i32_vec(&self) -> Result<Vec<i32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as i32)).collect()
    }
    pub fn f32_vec(&self) -> Result<Vec<f32>> {
        self.as_arr()?.iter().map(|v| Ok(v.as_f64()? as f32)).collect()
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, s: &mut String) {
        match self {
            Json::Null => s.push_str("null"),
            Json::Bool(b) => s.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(s, "{}", *n as i64);
                } else {
                    let _ = write!(s, "{n}");
                }
            }
            Json::Str(t) => {
                s.push('"');
                for c in t.chars() {
                    match c {
                        '"' => s.push_str("\\\""),
                        '\\' => s.push_str("\\\\"),
                        '\n' => s.push_str("\\n"),
                        '\t' => s.push_str("\\t"),
                        '\r' => s.push_str("\\r"),
                        c if (c as u32) < 0x20 => {
                            let _ = write!(s, "\\u{:04x}", c as u32);
                        }
                        c => s.push(c),
                    }
                }
                s.push('"');
            }
            Json::Arr(v) => {
                s.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    x.write(s);
                }
                s.push(']');
            }
            Json::Obj(m) => {
                s.push('{');
                for (i, (k, x)) in m.iter().enumerate() {
                    if i > 0 {
                        s.push(',');
                    }
                    Json::Str(k.clone()).write(s);
                    s.push(':');
                    x.write(s);
                }
                s.push('}');
            }
        }
    }
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.b.get(self.i).copied().context("unexpected end of input")
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek()? != c {
            bail!("expected '{}' at byte {}, found '{}'", c as char, self.i, self.peek()? as char);
        }
        self.i += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>().with_context(|| format!("bad number '{s}'"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek()?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => bail!("bad escape at byte {}", self.i),
                    }
                }
                c => {
                    // copy UTF-8 continuation bytes verbatim
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let len = if c >= 0xf0 { 4 } else if c >= 0xe0 { 3 } else { 2 };
                        let bytes = &self.b[self.i - 1..self.i - 1 + len];
                        out.push_str(std::str::from_utf8(bytes)?);
                        self.i += len - 1;
                    }
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b']' => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                c => bail!("expected ',' or ']' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            self.eat(b':')?;
            out.insert(key, self.value()?);
            self.ws();
            match self.peek()? {
                b',' => {
                    self.i += 1;
                }
                b'}' => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                c => bail!("expected ',' or '}}' at byte {}, found '{}'", self.i, c as char),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let src = r#"{"a": [1, 2.5, -3e2], "b": {"c": "hi\nthere"}, "d": true, "e": null}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("a").unwrap().f32_vec().unwrap(), vec![1.0, 2.5, -300.0]);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str().unwrap(), "hi\nthere");
        let re = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, re);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn nested_arrays() {
        let v = Json::parse("[[1,2],[3,4]]").unwrap();
        let rows = v.as_arr().unwrap();
        assert_eq!(rows[1].i32_vec().unwrap(), vec![3, 4]);
    }

    #[test]
    fn unicode_and_escapes() {
        let v = Json::parse(r#""A\t\\ ünïcode""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "A\t\\ ünïcode");
    }

    #[test]
    fn integer_formatting() {
        assert_eq!(Json::Num(42.0).to_string(), "42");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }
}
