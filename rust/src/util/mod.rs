//! Self-contained utilities replacing external crates for the fully-offline
//! build (DESIGN.md §Deps): a minimal JSON codec, a seeded RNG, a scoped
//! parallel map, and a micro-bench timer.

pub mod bench;
pub mod json;
pub mod parallel;
pub mod rng;

pub use json::Json;
pub use parallel::par_map;
pub use rng::Rng;
