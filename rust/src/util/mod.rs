//! Self-contained utilities replacing external crates for the fully-offline
//! build (DESIGN.md §Deps): a minimal JSON codec, a seeded RNG, a scoped
//! parallel map, the shared blocked/SIMD compute kernels, a micro-bench
//! harness with machine-readable `BENCH_*.json` suites, and the seeded
//! failpoint registry behind the chaos tests.

pub mod bench;
pub mod faults;
pub mod json;
pub mod kernels;
pub mod parallel;
pub mod rng;

pub use json::Json;
pub use parallel::{par_map, par_run_once};
pub use rng::Rng;
