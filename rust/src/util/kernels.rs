//! Shared blocked / autovectorizer-friendly compute kernels.
//!
//! One home for the innermost loops of the native forward pass
//! ([`crate::model::forward`]), the quantization codecs ([`crate::quant`]),
//! the PPU impact scoring ([`crate::policy`]), and the hwsim trace costing
//! ([`crate::hwsim::trace`]).
//!
//! Design rules:
//!  * every fast kernel has a scalar sibling (`*_scalar`) with the **same
//!    per-output accumulation order**, so fast == scalar holds bit-exactly
//!    (property-tested in `tests/kernel_props.rs`);
//!  * matmul register tiles are `MR × NR` with the K loop kept sequential
//!    ascending — tiling changes *which* outputs are in flight, never the
//!    per-output accumulation order, which is what makes blocking safe to
//!    verify exactly;
//!  * quantizers are written branch-free (selects plus the `1.5·2²³`
//!    round-to-nearest-ties-to-even trick) so LLVM can if-convert and
//!    vectorize them at the SSE2 baseline — no `round_ties_even` libcall
//!    in the hot loops.

use std::sync::Mutex;

use crate::quant::fp4::{E2M1_MAX, E2M1_MIN_NORMAL, E2M1_QUANTUM_SUBNORMAL};
use crate::quant::fp8::{E4M3_MAX, E4M3_MIN_NORMAL, E4M3_QUANTUM_SUBNORMAL};
use crate::quant::nvfp4_scale;
use crate::quant::pack::PackedPanels;
use crate::util::par_map;
use crate::BLOCK;

/// Row-tile height of the blocked matmul: rows of `x` that share one
/// streaming pass over a `w` panel (cuts weight traffic by `MR×`).
pub const MR: usize = 4;
/// Column-tile width of the blocked matmul register kernel (accumulators
/// stay in registers across the whole K loop).
pub const NR: usize = 8;
/// Partial-sum lanes of the transposed (dot-product) kernel.
pub const LANES: usize = 16;

// ---------------------------------------------------------------------------
// The f32x8 microkernel vector type (shared by the f32 and packed matmuls)
// ---------------------------------------------------------------------------

// The register kernels assume one accumulator vector spans a full NR panel.
const _: () = assert!(NR == 8, "F32x8 microkernel is written for NR = 8");

/// Explicit SSE build of the 8-lane vector (feature `simd` on x86_64): two
/// `__m128` halves, loads/mul/add as single instructions. `mul_acc` is a
/// separate IEEE multiply then add per lane — **not** an FMA — so results
/// are bit-identical to the autovectorized array form and to the scalar
/// references.
#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod vec8 {
    use core::arch::x86_64::*;

    /// 8 f32 lanes the MR×NR microkernel accumulates in.
    #[derive(Clone, Copy)]
    pub struct F32x8(__m128, __m128);

    impl F32x8 {
        #[inline(always)]
        pub fn zero() -> F32x8 {
            // SSE2 is part of the x86_64 baseline; these intrinsics are
            // unconditionally available.
            unsafe { F32x8(_mm_setzero_ps(), _mm_setzero_ps()) }
        }

        #[inline(always)]
        pub fn splat(v: f32) -> F32x8 {
            unsafe { F32x8(_mm_set1_ps(v), _mm_set1_ps(v)) }
        }

        #[inline(always)]
        pub fn load(s: &[f32]) -> F32x8 {
            assert!(s.len() >= 8);
            unsafe { F32x8(_mm_loadu_ps(s.as_ptr()), _mm_loadu_ps(s.as_ptr().add(4))) }
        }

        /// `self + a·b`, lanewise (multiply then add — no FMA contraction).
        #[inline(always)]
        pub fn mul_acc(self, a: F32x8, b: F32x8) -> F32x8 {
            unsafe {
                F32x8(
                    _mm_add_ps(self.0, _mm_mul_ps(a.0, b.0)),
                    _mm_add_ps(self.1, _mm_mul_ps(a.1, b.1)),
                )
            }
        }

        #[inline(always)]
        pub fn store(self, d: &mut [f32]) {
            assert!(d.len() >= 8);
            unsafe {
                _mm_storeu_ps(d.as_mut_ptr(), self.0);
                _mm_storeu_ps(d.as_mut_ptr().add(4), self.1);
            }
        }
    }
}

/// Portable build: an 8-wide array with lanewise loops LLVM can
/// autovectorize at the SSE2 baseline. Same per-lane operations in the
/// same order as the intrinsics build, so the two are bit-identical.
#[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
mod vec8 {
    /// 8 f32 lanes the MR×NR microkernel accumulates in.
    #[derive(Clone, Copy)]
    pub struct F32x8([f32; 8]);

    impl F32x8 {
        #[inline(always)]
        pub fn zero() -> F32x8 {
            F32x8([0.0; 8])
        }

        #[inline(always)]
        pub fn splat(v: f32) -> F32x8 {
            F32x8([v; 8])
        }

        #[inline(always)]
        pub fn load(s: &[f32]) -> F32x8 {
            let a: &[f32; 8] = s[..8].try_into().unwrap();
            F32x8(*a)
        }

        /// `self + a·b`, lanewise (multiply then add — no FMA contraction).
        #[inline(always)]
        pub fn mul_acc(self, a: F32x8, b: F32x8) -> F32x8 {
            let mut out = self.0;
            for ((o, &av), &bv) in out.iter_mut().zip(&a.0).zip(&b.0) {
                *o += av * bv;
            }
            F32x8(out)
        }

        #[inline(always)]
        pub fn store(self, d: &mut [f32]) {
            d[..8].copy_from_slice(&self.0);
        }
    }
}

pub use vec8::F32x8;

// ---------------------------------------------------------------------------
// Branch-free scalar quantizers (the vector lanes of the slice kernels)
// ---------------------------------------------------------------------------

/// `1.5·2²³`: adding and subtracting snaps a float to the integer grid
/// with round-to-nearest-ties-to-even, exactly, for `|y| < 2²²`. All
/// quotients fed to it here are `< 16` in magnitude by construction
/// (mantissa-over-quantum ratios), and ±inf/NaN pass through unchanged.
const ROUND_MAGIC: f32 = 12_582_912.0;

#[inline(always)]
fn round_nearest_even_small(y: f32) -> f32 {
    (y + ROUND_MAGIC) - ROUND_MAGIC
}

/// Branch-free E4M3 round-trip on the same lattice as
/// [`crate::quant::quant_e4m3`] (equality is property-tested); the only
/// representational difference is that results that round to zero come
/// back as `+0.0` rather than `-0.0` for negative inputs.
#[inline(always)]
pub fn e4m3(x: f32) -> f32 {
    let ax = x.abs();
    // 2^(e-3) built from the exponent field. When ax is subnormal or zero
    // the wrapped bit pattern is garbage, but the select below discards it.
    let normal_q = f32::from_bits((ax.to_bits() >> 23).wrapping_sub(3) << 23);
    let quantum = if ax < E4M3_MIN_NORMAL { E4M3_QUANTUM_SUBNORMAL } else { normal_q };
    let q = round_nearest_even_small(x / quantum) * quantum;
    q.clamp(-E4M3_MAX, E4M3_MAX)
}

/// Branch-free E2M1 round-trip on the same lattice as
/// [`crate::quant::quant_e2m1`] (equality is property-tested).
#[inline(always)]
pub fn e2m1(x: f32) -> f32 {
    let ax = x.abs();
    let normal_q = f32::from_bits((ax.to_bits() >> 23).wrapping_sub(1) << 23);
    let quantum = if ax < E2M1_MIN_NORMAL { E2M1_QUANTUM_SUBNORMAL } else { normal_q };
    let q = round_nearest_even_small(x / quantum) * quantum;
    q.clamp(-E2M1_MAX, E2M1_MAX)
}

// ---------------------------------------------------------------------------
// Slice / block quantization kernels
// ---------------------------------------------------------------------------

/// `out[i] = e4m3(x[i])` over a whole slice (vectorized).
pub fn e4m3_slice(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = e4m3(v);
    }
}

/// `out[i] = e2m1(x[i])` over a whole slice (vectorized).
pub fn e2m1_slice(x: &[f32], out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = e2m1(v);
    }
}

/// NVFP4 round-trip of one block with an explicit E4M3 scale:
/// `out = e2m1(x / s) · s`. Division (not reciprocal multiply) keeps the
/// values on the reference lattice of `ref.quant_nvfp4`. A non-positive
/// scale maps the block to zeros.
pub fn nvfp4_block(x: &[f32], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    if scale <= 0.0 {
        out.fill(0.0);
        return;
    }
    for (o, &v) in out.iter_mut().zip(x) {
        *o = e2m1(v / scale) * scale;
    }
}

/// SW-Clip inner round-trip: `out = e2m1(x · inv_s) · s`. The clip search
/// pre-computes the reciprocal once per candidate scale — this kernel keeps
/// exactly that numerics (multiply, not divide).
pub fn e2m1_scaled_slice(x: &[f32], inv_s: f32, s: f32, out: &mut [f32]) {
    debug_assert_eq!(x.len(), out.len());
    for (o, &v) in out.iter_mut().zip(x) {
        *o = e2m1(v * inv_s) * s;
    }
}

/// `max |x_i|` over a slice (`0.0` for empty) — the dynamic-max scale input.
pub fn absmax(x: &[f32]) -> f32 {
    x.iter().fold(0.0f32, |m, &v| m.max(v.abs()))
}

// ---------------------------------------------------------------------------
// Paged KV gather kernels (decode-on-read over non-contiguous pages)
// ---------------------------------------------------------------------------

/// The 256-entry E4M3 decode table, built once from the scalar codec — so
/// the lattice is identical to [`crate::quant::fp8::decode_e4m3`] by
/// construction, and a lookup per byte keeps the gather loops memory-bound.
fn e4m3_lut() -> &'static [f32; 256] {
    static LUT: std::sync::OnceLock<[f32; 256]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| std::array::from_fn(|b| crate::quant::fp8::decode_e4m3(b as u8)))
}

/// Gather non-contiguous f32 page slices into one contiguous row buffer —
/// the FP16 paged-KV read path. Pages arrive in token order; the last page
/// may be partial (the caller slices it to the live rows).
pub fn gather_f32_pages(pages: &[&[f32]], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(pages.iter().map(|p| p.len()).sum());
    for p in pages {
        out.extend_from_slice(p);
    }
}

/// Gather + decode E4M3 byte pages into contiguous f32 rows — the FP8
/// KV read path, flat (one page spanning the buffer) or paged (one
/// table-lookup pass per page, appended directly: no zero-fill of the
/// scratch before the overwrite).
pub fn gather_e4m3_pages(pages: &[&[u8]], out: &mut Vec<f32>) {
    out.clear();
    out.reserve(pages.iter().map(|p| p.len()).sum());
    let lut = e4m3_lut();
    for p in pages {
        out.extend(p.iter().map(|&b| lut[b as usize]));
    }
}

// ---------------------------------------------------------------------------
// Attention at stored precision (LUT-decode inside the dot-product loop)
// ---------------------------------------------------------------------------
//
// These two kernels compute one causal attention output row straight off
// the KV cache's page spans — f32 spans for FP16 caches, raw E4M3 byte
// spans for FP8, where the 256-entry decode LUT moves *inside* the QK^T
// and AV loops (decode-in-register, no materialized f32 copy of the
// cache). Accumulation order is exactly `model::forward::attend_row`'s:
// scores ascending-j with a sequential dot, running max, stable softmax,
// then the ascending-j weighted value sum with the `p == 0.0` skip. Since
// `lut[b] == decode_e4m3(b)` by construction, the E4M3 kernel is
// bit-identical to gathering the pages to f32 first and attending over the
// copy (property-tested in `tests/kernel_props.rs`).

/// One attention row over f32 KV page spans (FP16 caches, flat or paged):
/// query `qr` (dh) against the first `len` cached rows of head `hi`, pages
/// in token order with `d`-wide rows, last span possibly partial. `sc` is
/// caller scratch of at least `len`; the output row lands in `or` (dh).
#[allow(clippy::too_many_arguments)]
pub fn attend_row_f32_pages(
    qr: &[f32],
    k_pages: &[&[f32]],
    v_pages: &[&[f32]],
    len: usize,
    d: usize,
    hi: usize,
    dh: usize,
    scale: f32,
    sc: &mut [f32],
    or: &mut [f32],
) {
    debug_assert!(sc.len() >= len);
    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'score: for kp in k_pages {
        for r in 0..kp.len() / d {
            if j >= len {
                break 'score;
            }
            let kr = &kp[r * d + hi * dh..r * d + (hi + 1) * dh];
            let mut dot = 0.0f32;
            for (a, b2) in qr.iter().zip(kr) {
                dot += a * b2;
            }
            sc[j] = dot * scale;
            mx = mx.max(sc[j]);
            j += 1;
        }
    }
    debug_assert_eq!(j, len, "pages hold fewer than len rows");
    let mut z = 0.0f32;
    for scj in sc.iter_mut().take(len) {
        *scj = (*scj - mx).exp();
        z += *scj;
    }
    or.fill(0.0);
    let mut j = 0usize;
    'av: for vp in v_pages {
        for r in 0..vp.len() / d {
            if j >= len {
                break 'av;
            }
            let p = sc[j] / z;
            j += 1;
            if p == 0.0 {
                continue;
            }
            let vr = &vp[r * d + hi * dh..r * d + (hi + 1) * dh];
            for (a, &vv) in or.iter_mut().zip(vr) {
                *a += p * vv;
            }
        }
    }
}

/// One attention row over E4M3 byte KV page spans (FP8 caches, flat or
/// paged): identical accumulation order to [`attend_row_f32_pages`], with
/// each key/value byte decoded through the 256-entry LUT at the moment it
/// enters the dot product — the cache is never materialized to f32.
#[allow(clippy::too_many_arguments)]
pub fn attend_row_e4m3_pages(
    qr: &[f32],
    k_pages: &[&[u8]],
    v_pages: &[&[u8]],
    len: usize,
    d: usize,
    hi: usize,
    dh: usize,
    scale: f32,
    sc: &mut [f32],
    or: &mut [f32],
) {
    debug_assert!(sc.len() >= len);
    let lut = e4m3_lut();
    let mut mx = f32::NEG_INFINITY;
    let mut j = 0usize;
    'score: for kp in k_pages {
        for r in 0..kp.len() / d {
            if j >= len {
                break 'score;
            }
            let kr = &kp[r * d + hi * dh..r * d + (hi + 1) * dh];
            let mut dot = 0.0f32;
            for (a, &b2) in qr.iter().zip(kr) {
                dot += a * lut[b2 as usize];
            }
            sc[j] = dot * scale;
            mx = mx.max(sc[j]);
            j += 1;
        }
    }
    debug_assert_eq!(j, len, "pages hold fewer than len rows");
    let mut z = 0.0f32;
    for scj in sc.iter_mut().take(len) {
        *scj = (*scj - mx).exp();
        z += *scj;
    }
    or.fill(0.0);
    let mut j = 0usize;
    'av: for vp in v_pages {
        for r in 0..vp.len() / d {
            if j >= len {
                break 'av;
            }
            let p = sc[j] / z;
            j += 1;
            if p == 0.0 {
                continue;
            }
            let vr = &vp[r * d + hi * dh..r * d + (hi + 1) * dh];
            for (a, &vv) in or.iter_mut().zip(vr) {
                *a += p * lut[vv as usize];
            }
        }
    }
}

/// The PPU (paper §4.2) on one activation row: round-trip each 16-block to
/// FP8 or NVFP4 per the impact score (Eq. 8) against `threshold`, writing
/// dequantized values to `out`. Returns the FP8 block count. Identical
/// numerics to `policy::impact_score_block` + the per-branch round-trips,
/// but each block's E4M3/NVFP4 images are computed once, vectorized.
pub fn ppu_quantize_row(xr: &[f32], chan_weight: &[f32], threshold: f32, out: &mut [f32]) -> usize {
    debug_assert_eq!(xr.len(), out.len());
    debug_assert_eq!(xr.len(), chan_weight.len());
    debug_assert_eq!(xr.len() % BLOCK, 0);
    let mut n_fp8 = 0usize;
    for (bi, (xb, ob)) in xr.chunks_exact(BLOCK).zip(out.chunks_exact_mut(BLOCK)).enumerate() {
        let cb = &chan_weight[bi * BLOCK..(bi + 1) * BLOCK];
        let mut q8 = [0.0f32; BLOCK];
        e4m3_slice(xb, &mut q8);
        let s = nvfp4_scale(absmax(xb));
        let mut q4 = [0.0f32; BLOCK];
        nvfp4_block(xb, s, &mut q4);
        // Impact score, same f64 accumulation order as impact_score_block.
        let mut score = 0.0f64;
        for j in 0..BLOCK {
            let d = (q4[j] - q8[j]) as f64;
            score += cb[j] as f64 * d * d;
        }
        if score > threshold as f64 {
            n_fp8 += 1;
            ob.copy_from_slice(&q8);
        } else {
            ob.copy_from_slice(&q4);
        }
    }
    n_fp8
}

// ---------------------------------------------------------------------------
// Blocked matmul
// ---------------------------------------------------------------------------

/// Dense `y = x·w` for row-major `x (M,K)`, `w (K,N)`: parallel over
/// `MR`-row tiles, register-blocked `MR × NR` inner kernel. Per-output
/// accumulation is ascending-K, so the result equals [`matmul_scalar`]
/// bit-for-bit.
pub fn matmul(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let tiles: Vec<usize> = (0..m.div_ceil(MR)).collect();
    let out = par_map(&tiles, |&t| {
        let r0 = t * MR;
        let rows = MR.min(m - r0);
        let mut tile = vec![0.0f32; rows * n];
        matmul_rows(&x[r0 * k..(r0 + rows) * k], w, rows, k, n, &mut tile);
        tile
    });
    flatten(out, m * n)
}

/// Scalar reference matmul — the pre-blocking kernel, kept as the
/// bit-exactness oracle and fallback path. Each output element accumulates
/// its products in ascending-K order (no zero-skipping, so the order
/// statement is unconditional).
pub fn matmul_scalar(x: &[f32], w: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(w.len(), k * n);
    let rows: Vec<usize> = (0..m).collect();
    let out = par_map(&rows, |&mi| {
        let mut acc = vec![0.0f32; n];
        let xr = &x[mi * k..(mi + 1) * k];
        for (ki, &xv) in xr.iter().enumerate() {
            let wr = &w[ki * n..(ki + 1) * n];
            for (a, &wv) in acc.iter_mut().zip(wr) {
                *a += xv * wv;
            }
        }
        acc
    });
    flatten(out, m * n)
}

/// Multiply `rows ≤ MR` rows of `x (rows,K)` against `w (K,N)` into
/// `out (rows,N)`, register-tiling N in NR-wide panels.
pub fn matmul_rows(x: &[f32], w: &[f32], rows: usize, k: usize, n: usize, out: &mut [f32]) {
    debug_assert!(rows <= MR);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * n);
    let mut nc = 0usize;
    while nc + NR <= n {
        if rows == MR {
            kernel_full(x, w, k, n, nc, out);
        } else {
            kernel_edge(x, w, rows, k, n, nc, NR, out);
        }
        nc += NR;
    }
    if nc < n {
        kernel_edge(x, w, rows, k, n, nc, n - nc, out);
    }
}

/// The `MR × NR` register microkernel: accumulators live in [`F32x8`]
/// vectors for the whole K loop; each `w` panel row is loaded once and
/// reused by all MR rows of `x`. The packed-weight kernel accumulates with
/// the same vector ops over its decoded tiles, so the two paths share one
/// microkernel definition.
#[inline(always)]
fn kernel_full(x: &[f32], w: &[f32], k: usize, n: usize, nc: usize, out: &mut [f32]) {
    let mut acc = [F32x8::zero(); MR];
    for ki in 0..k {
        let base = ki * n + nc;
        let wv = F32x8::load(&w[base..base + NR]);
        for (r, a) in acc.iter_mut().enumerate() {
            *a = a.mul_acc(F32x8::splat(x[r * k + ki]), wv);
        }
    }
    for (r, a) in acc.iter().enumerate() {
        a.store(&mut out[r * n + nc..r * n + nc + NR]);
    }
}

/// Generic edge kernel for bottom row tiles (`rows < MR`) and the N
/// remainder (`width < NR`). Same ascending-K per-output order.
fn kernel_edge(
    x: &[f32],
    w: &[f32],
    rows: usize,
    k: usize,
    n: usize,
    nc: usize,
    width: usize,
    out: &mut [f32],
) {
    debug_assert!(width <= NR);
    let mut acc = [[0.0f32; NR]; MR];
    for ki in 0..k {
        let wr = &w[ki * n + nc..ki * n + nc + width];
        for (r, accr) in acc.iter_mut().enumerate().take(rows) {
            let xv = x[r * k + ki];
            for (a, &wv) in accr[..width].iter_mut().zip(wr) {
                *a += xv * wv;
            }
        }
    }
    for (r, accr) in acc.iter().enumerate().take(rows) {
        out[r * n + nc..r * n + nc + width].copy_from_slice(&accr[..width]);
    }
}

// ---------------------------------------------------------------------------
// Packed-weight matmul: decode FGMP blocks in-register inside the tile loop
// ---------------------------------------------------------------------------

/// The 16-entry E2M1 nibble decode table, built once from the scalar codec
/// — identical lattice to [`crate::quant::fp4::decode_e2m1`] by
/// construction. One lookup + one scale multiply per weight is the whole
/// NVFP4 decode.
fn e2m1_lut() -> &'static [f32; 16] {
    static LUT: std::sync::OnceLock<[f32; 16]> = std::sync::OnceLock::new();
    LUT.get_or_init(|| std::array::from_fn(|n| crate::quant::fp4::decode_e2m1(n as u8)))
}

/// Streaming cursor over one panel of a [`PackedPanels`] tensor.
struct PanelCursor {
    widx: usize,
    pay: usize,
    sc: usize,
}

/// Decode the `width` blocks of one k-panel row (k-block `kb`, all panel
/// columns) into a `(BLOCK, NR)` row-major register tile: `wtile[kk·NR+j]`
/// is weight `(kb·BLOCK+kk, nc+j)`. E4M3 bytes go through the 256-entry
/// LUT, NVFP4 nibbles through the 16-entry LUT times the block's decoded
/// E4M3 scale — exactly [`FgmpTensor::unpack`]'s numerics (`s > 0` guard
/// included), so the packed product is bit-identical to multiplying the
/// dequantized copy.
///
/// [`FgmpTensor::unpack`]: crate::quant::FgmpTensor::unpack
#[inline(always)]
fn decode_panel_kblock(
    w: &PackedPanels,
    cur: &mut PanelCursor,
    width: usize,
    wtile: &mut [f32; BLOCK * NR],
) {
    let lut8 = e4m3_lut();
    let lut4 = e2m1_lut();
    for j in 0..width {
        if w.is_fp8_walk(cur.widx) {
            for kk in 0..BLOCK {
                wtile[kk * NR + j] = lut8[w.payload[cur.pay + kk] as usize];
            }
            cur.pay += BLOCK;
        } else {
            let s = lut8[w.scales[cur.sc] as usize];
            cur.sc += 1;
            let s = if s > 0.0 { s } else { 0.0 };
            for kk2 in 0..BLOCK / 2 {
                let b = w.payload[cur.pay + kk2];
                wtile[(2 * kk2) * NR + j] = lut4[(b & 0x0f) as usize] * s;
                wtile[(2 * kk2 + 1) * NR + j] = lut4[(b >> 4) as usize] * s;
            }
            cur.pay += BLOCK / 2;
        }
        cur.widx += 1;
    }
}

/// Multiply `rows ≤ MR` rows of `x (rows,K)` against a panelized packed
/// weight tensor into `out (rows,N)`, decoding each `BLOCK × NR` weight
/// tile in-register as the K loop walks the panel — the forward path never
/// touches a dequantized f32 weight buffer. Per-output accumulation is
/// ascending-K, so the result equals [`matmul_scalar`] over
/// [`PackedPanels::unpack_kn`] bit-for-bit; full tiles accumulate through
/// the same [`F32x8`] microkernel ops as the dense [`matmul_rows`].
pub fn matmul_rows_packed(x: &[f32], w: &PackedPanels, rows: usize, out: &mut [f32]) {
    matmul_rows_packed_range(x, w, rows, 0, w.n_panels(), out)
}

/// [`matmul_rows_packed`] restricted to the panel range `[p0, p1)` — the
/// per-worker kernel of the tensor-parallel path. `out` is `(rows, cols)`
/// where `cols = min(p1·NR, N) − p0·NR`: output columns are written
/// relative to the range's first column, so a worker's partial product is
/// a dense block the driver can splice into the full output by pure copy.
/// The walk, decode and ascending-K accumulation order are identical to
/// the full kernel, so concatenating every worker's block reproduces the
/// single-worker result bit-for-bit.
pub fn matmul_rows_packed_range(
    x: &[f32],
    w: &PackedPanels,
    rows: usize,
    p0: usize,
    p1: usize,
    out: &mut [f32],
) {
    debug_assert!(rows <= MR);
    // Hard check: the panel walk below hardcodes NR-wide panels, so a
    // layout built for any other width would silently desync the decode
    // cursor in release builds if this were only a debug assert.
    assert_eq!(w.nr, NR, "panel layout width {} != kernel NR {NR}", w.nr);
    let (k, n) = (w.k, w.n);
    debug_assert!(p0 <= p1 && p1 <= w.n_panels());
    let ncols = (p1 * NR).min(n) - (p0 * NR).min(n);
    debug_assert_eq!(x.len(), rows * k);
    debug_assert_eq!(out.len(), rows * ncols);
    let kb_count = k / BLOCK;
    let mut wtile = [0.0f32; BLOCK * NR];
    for p in p0..p1 {
        // Column offset inside this range's output block.
        let nc = p * NR - p0 * NR;
        let width = NR.min(n - p * NR);
        let mut cur = PanelCursor {
            widx: w.panel_block_off[p],
            pay: w.panel_payload_off[p],
            sc: w.panel_scale_off[p],
        };
        if rows == MR && width == NR {
            // Full tile: F32x8 accumulators across the whole K loop.
            let mut acc = [F32x8::zero(); MR];
            for kb in 0..kb_count {
                decode_panel_kblock(w, &mut cur, width, &mut wtile);
                for kk in 0..BLOCK {
                    let ki = kb * BLOCK + kk;
                    let wv = F32x8::load(&wtile[kk * NR..kk * NR + NR]);
                    for (r, a) in acc.iter_mut().enumerate() {
                        *a = a.mul_acc(F32x8::splat(x[r * k + ki]), wv);
                    }
                }
            }
            for (r, a) in acc.iter().enumerate() {
                a.store(&mut out[r * ncols + nc..r * ncols + nc + NR]);
            }
        } else {
            // Edge panel / bottom row tile: same ascending-K order, scalar
            // lanes over the live width.
            let mut acc = [[0.0f32; NR]; MR];
            for kb in 0..kb_count {
                decode_panel_kblock(w, &mut cur, width, &mut wtile);
                for kk in 0..BLOCK {
                    let ki = kb * BLOCK + kk;
                    let wr = &wtile[kk * NR..kk * NR + width];
                    for (r, accr) in acc.iter_mut().enumerate().take(rows) {
                        let xv = x[r * k + ki];
                        for (a, &wv) in accr[..width].iter_mut().zip(wr) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            for (r, accr) in acc.iter().enumerate().take(rows) {
                out[r * ncols + nc..r * ncols + nc + width].copy_from_slice(&accr[..width]);
            }
        }
    }
}

/// Dense-activation × packed-weight product `y = x·W` for row-major
/// `x (M,K)` against a panelized packed tensor `(K,N)`: parallel over
/// `MR`-row tiles of [`matmul_rows_packed`]. Bit-identical to
/// [`matmul`] over the dequantized copy.
pub fn matmul_packed(x: &[f32], w: &PackedPanels, m: usize) -> Vec<f32> {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    let tiles: Vec<usize> = (0..m.div_ceil(MR)).collect();
    let out = par_map(&tiles, |&t| {
        let r0 = t * MR;
        let rows = MR.min(m - r0);
        let mut tile = vec![0.0f32; rows * n];
        matmul_rows_packed(&x[r0 * k..(r0 + rows) * k], w, rows, &mut tile);
        tile
    });
    flatten(out, m * n)
}

/// [`matmul_packed`] restricted to the panel range `[p0, p1)`: one
/// worker's partial product, a dense `(M, cols)` block of the full output
/// columns `[p0·NR, min(p1·NR, N))`. Runs the tile loop serially — the
/// tensor-parallel driver already owns one thread per worker, and nesting
/// `par_map` inside it would oversubscribe.
pub fn matmul_packed_range(x: &[f32], w: &PackedPanels, m: usize, p0: usize, p1: usize) -> Vec<f32> {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    let ncols = (p1 * NR).min(n) - (p0 * NR).min(n);
    let mut out = vec![0.0f32; m * ncols];
    for t in 0..m.div_ceil(MR) {
        let r0 = t * MR;
        let rows = MR.min(m - r0);
        matmul_rows_packed_range(
            &x[r0 * k..(r0 + rows) * k],
            w,
            rows,
            p0,
            p1,
            &mut out[r0 * ncols..(r0 + rows) * ncols],
        );
    }
    out
}

/// Scalar reference sibling of [`matmul_packed`]: walks the same panel
/// order with the same LUT decode, accumulating each output element in
/// ascending-K order one product at a time — no register tiles. The
/// bit-exactness oracle for the packed kernel (and itself equal to
/// [`matmul_scalar`] over the dequantized copy).
pub fn matmul_packed_scalar(x: &[f32], w: &PackedPanels, m: usize) -> Vec<f32> {
    let (k, n) = (w.k, w.n);
    assert_eq!(x.len(), m * k);
    let kb_count = k / BLOCK;
    let rows: Vec<usize> = (0..m).collect();
    let lut8 = e4m3_lut();
    let lut4 = e2m1_lut();
    let out = par_map(&rows, |&mi| {
        let xr = &x[mi * k..(mi + 1) * k];
        let mut acc = vec![0.0f32; n];
        let mut wb = [0.0f32; BLOCK];
        for p in 0..w.n_panels() {
            let nc = p * w.nr;
            let width = w.nr.min(n - nc);
            let mut widx = w.panel_block_off[p];
            let mut pay = w.panel_payload_off[p];
            let mut sci = w.panel_scale_off[p];
            for kb in 0..kb_count {
                for j in 0..width {
                    if w.is_fp8_walk(widx) {
                        for kk in 0..BLOCK {
                            wb[kk] = lut8[w.payload[pay + kk] as usize];
                        }
                        pay += BLOCK;
                    } else {
                        let s = lut8[w.scales[sci] as usize];
                        sci += 1;
                        let s = if s > 0.0 { s } else { 0.0 };
                        for kk2 in 0..BLOCK / 2 {
                            let b = w.payload[pay + kk2];
                            wb[2 * kk2] = lut4[(b & 0x0f) as usize] * s;
                            wb[2 * kk2 + 1] = lut4[(b >> 4) as usize] * s;
                        }
                        pay += BLOCK / 2;
                    }
                    widx += 1;
                    let a = &mut acc[nc + j];
                    for (kk, &wv) in wb.iter().enumerate() {
                        *a += xr[kb * BLOCK + kk] * wv;
                    }
                }
            }
        }
        acc
    });
    flatten(out, m * n)
}

// ---------------------------------------------------------------------------
// Reusable matmul tile scratch
// ---------------------------------------------------------------------------

/// A pool of scratch buffers shared across the tile-parallel matmul calls
/// of one forward pass. [`crate::util::par_map`] spawns fresh scoped
/// threads per call, so per-thread storage cannot persist — instead each
/// in-flight tile checks buffers out of the pool and returns them as soon
/// as it is done with them (the quantize buffer right after the multiply,
/// so live copies stay bounded by worker concurrency; output tiles after
/// they are flattened), and the pool itself is threaded through the whole
/// pass as one long-lived allocation. Capacity is paid once per
/// (shape × concurrency) instead of once per tile per linear.
#[derive(Default)]
pub struct MatmulScratch {
    free: Mutex<Vec<Vec<f32>>>,
}

impl MatmulScratch {
    pub fn new() -> MatmulScratch {
        MatmulScratch::default()
    }

    /// Check a buffer out of the pool (empty when the pool has none —
    /// first use at each concurrency level allocates). The returned buffer
    /// may carry a stale length/contents; size it with [`scratch_resize`].
    pub fn take(&self) -> Vec<f32> {
        self.free.lock().map(|mut v| v.pop()).ok().flatten().unwrap_or_default()
    }

    /// Return a buffer for the next tile to reuse (contents kept — no
    /// clear, so re-sizing to the same shape costs nothing).
    pub fn put(&self, buf: Vec<f32>) {
        if let Ok(mut v) = self.free.lock() {
            v.push(buf);
        }
    }
}

/// Size `buf` to exactly `len` elements. Only newly grown capacity is
/// zero-filled (`Vec::resize` semantics); retained elements keep their
/// stale values — every kernel fed from this scratch overwrites all of
/// them, so no full memset is paid on reuse.
#[inline]
pub fn scratch_resize(buf: &mut Vec<f32>, len: usize) {
    buf.resize(len, 0.0);
}

// ---------------------------------------------------------------------------
// Transposed matmul (the tied LM head): lane-parallel dot products
// ---------------------------------------------------------------------------

/// `y = x·wᵀ` for `x (M,K)` against row-major `wt (N,K)`. Each output is a
/// K-length dot product accumulated in [`LANES`] interleaved partial sums
/// (then reduced lane 0→15) — same order as [`matmul_transposed_scalar`].
pub fn matmul_transposed(x: &[f32], wt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(wt.len(), n * k);
    let rows: Vec<usize> = (0..m).collect();
    let out = par_map(&rows, |&mi| {
        let xr = &x[mi * k..(mi + 1) * k];
        let mut acc = vec![0.0f32; n];
        for (ni, a) in acc.iter_mut().enumerate() {
            *a = dot_lanes(xr, &wt[ni * k..(ni + 1) * k]);
        }
        acc
    });
    flatten(out, m * n)
}

/// Scalar reference for [`matmul_transposed`]: element-at-a-time with the
/// same lane-interleaved accumulation order, so the two agree bit-exactly.
pub fn matmul_transposed_scalar(x: &[f32], wt: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
    assert_eq!(x.len(), m * k);
    assert_eq!(wt.len(), n * k);
    let rows: Vec<usize> = (0..m).collect();
    let out = par_map(&rows, |&mi| {
        let xr = &x[mi * k..(mi + 1) * k];
        let mut acc = vec![0.0f32; n];
        for (ni, a) in acc.iter_mut().enumerate() {
            *a = dot_lanes_scalar(xr, &wt[ni * k..(ni + 1) * k]);
        }
        acc
    });
    flatten(out, m * n)
}

/// Lane-parallel dot product: LANES partial sums over ascending chunks,
/// the `< LANES` remainder into lanes `0..rem`, then a sequential lane
/// reduction. This is the canonical accumulation order for dot products.
#[inline(always)]
fn dot_lanes(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut lanes = [0.0f32; LANES];
    let mut ac = a.chunks_exact(LANES);
    let mut bc = b.chunks_exact(LANES);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for j in 0..LANES {
            lanes[j] += av[j] * bv[j];
        }
    }
    for (j, (&av, &bv)) in ac.remainder().iter().zip(bc.remainder()).enumerate() {
        lanes[j] += av * bv;
    }
    lanes.iter().fold(0.0f32, |s, &l| s + l)
}

/// Element-at-a-time transcription of [`dot_lanes`]'s accumulation order.
fn dot_lanes_scalar(a: &[f32], b: &[f32]) -> f32 {
    let mut lanes = [0.0f32; LANES];
    let full = a.len() - a.len() % LANES;
    for i in 0..full {
        lanes[i % LANES] += a[i] * b[i];
    }
    for i in full..a.len() {
        lanes[i - full] += a[i] * b[i];
    }
    let mut s = 0.0f32;
    for l in lanes {
        s += l;
    }
    s
}

fn flatten(rows: Vec<Vec<f32>>, cap: usize) -> Vec<f32> {
    let mut flat = Vec::with_capacity(cap);
    for r in rows {
        flat.extend_from_slice(&r);
    }
    flat
}

// ---------------------------------------------------------------------------
// Bitset block-mask kernels (hwsim trace costing)
// ---------------------------------------------------------------------------

/// Pack a per-block boolean precision mask into `u64` words, LSB-first —
/// the block-metadata representation the trace simulator counts with.
pub fn pack_mask_u64(mask: &[bool]) -> Vec<u64> {
    let mut out = vec![0u64; mask.len().div_ceil(64)];
    for (i, &b) in mask.iter().enumerate() {
        if b {
            out[i / 64] |= 1u64 << (i % 64);
        }
    }
    out
}

/// `popcount(a & b)` — blocks where both metadata bits are set.
pub fn and_popcount(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(&x, &y)| (x & y).count_ones() as u64).sum()
}

/// `popcount(a & !b)` — blocks set in `a` but clear in `b`.
pub fn andnot_popcount(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(&x, &y)| (x & !y).count_ones() as u64).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quant_e2m1, quant_e4m3};
    use crate::util::Rng;

    #[test]
    fn branch_free_codecs_match_scalar_on_edge_cases() {
        let cases = [
            0.0,
            -0.0,
            1.0625,
            1.1875,
            -1.3,
            0.25,
            0.75,
            2.5,
            3.5,
            5.0,
            447.9,
            448.0,
            449.0,
            1e9,
            -1e9,
            1e-9,
            E4M3_QUANTUM_SUBNORMAL * 0.49,
            E4M3_QUANTUM_SUBNORMAL * 0.51,
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            f32::MIN_POSITIVE / 2.0, // f32 subnormal
        ];
        for x in cases {
            assert_eq!(e4m3(x), quant_e4m3(x), "e4m3({x})");
            assert_eq!(e2m1(x), quant_e2m1(x), "e2m1({x})");
        }
        assert!(e4m3(f32::NAN).is_nan());
        assert!(e2m1(f32::NAN).is_nan());
    }

    #[test]
    fn branch_free_codecs_match_scalar_on_dense_sweep() {
        // Dense magnitude sweep across every binade both formats touch,
        // plus a random sweep — the vector lanes must be the exact lattice.
        let mut rng = Rng::new(99);
        for i in 0..200_000 {
            let x = if i % 2 == 0 {
                (rng.normal() as f32) * 10f32.powf((rng.f32() - 0.5) * 10.0)
            } else {
                rng.f32() * 1000.0 - 500.0
            };
            assert_eq!(e4m3(x), quant_e4m3(x), "e4m3({x})");
            assert_eq!(e2m1(x), quant_e2m1(x), "e2m1({x})");
        }
    }

    #[test]
    fn blocked_matmul_matches_scalar_small() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0, 6.0];
        let w = [1.0, 0.0, 0.0, 1.0, 1.0, 1.0];
        assert_eq!(matmul(&x, &w, 2, 3, 2), matmul_scalar(&x, &w, 2, 3, 2));
        assert_eq!(matmul(&x, &w, 2, 3, 2), vec![4.0, 5.0, 10.0, 11.0]);
    }

    #[test]
    fn transposed_matches_its_scalar_reference() {
        let mut rng = Rng::new(5);
        for (m, k, n) in [(1, 1, 1), (3, 17, 5), (4, 64, 9), (2, 100, 33)] {
            let x = rng.normal_vec(m * k, 1.0);
            let wt = rng.normal_vec(n * k, 1.0);
            assert_eq!(
                matmul_transposed(&x, &wt, m, k, n),
                matmul_transposed_scalar(&x, &wt, m, k, n),
                "shape ({m},{k},{n})"
            );
        }
    }

    #[test]
    fn mask_popcounts() {
        let a: Vec<bool> = (0..130).map(|i| i % 3 == 0).collect();
        let b: Vec<bool> = (0..130).map(|i| i % 2 == 0).collect();
        let (pa, pb) = (pack_mask_u64(&a), pack_mask_u64(&b));
        let both = a.iter().zip(&b).filter(|(&x, &y)| x && y).count() as u64;
        let only_a = a.iter().zip(&b).filter(|(&x, &y)| x && !y).count() as u64;
        assert_eq!(and_popcount(&pa, &pb), both);
        assert_eq!(andnot_popcount(&pa, &pb), only_a);
    }

    #[test]
    fn e4m3_gather_matches_scalar_codec_on_all_bytes() {
        let bytes: Vec<u8> = (0..=255u8).collect();
        let mut out = Vec::new();
        gather_e4m3_pages(&[bytes.as_slice()], &mut out);
        assert_eq!(out.len(), 256);
        for (b, &got) in bytes.iter().zip(&out) {
            let want = crate::quant::fp8::decode_e4m3(*b);
            assert_eq!(got.to_bits(), want.to_bits(), "byte {b:#x}");
        }
    }

    #[test]
    fn page_gathers_concatenate_in_order() {
        let mut rng = Rng::new(7);
        let flat = rng.normal_vec(40, 1.0);
        let pages: Vec<&[f32]> = vec![&flat[..16], &flat[16..32], &flat[32..]];
        let mut out = Vec::new();
        gather_f32_pages(&pages, &mut out);
        assert_eq!(out, flat);

        let bytes: Vec<u8> = (0..40u8).map(|i| i.wrapping_mul(37)).collect();
        let bpages: Vec<&[u8]> = vec![&bytes[..16], &bytes[16..32], &bytes[32..]];
        let mut fout = Vec::new();
        gather_e4m3_pages(&bpages, &mut fout);
        let want: Vec<f32> = bytes.iter().map(|&b| crate::quant::fp8::decode_e4m3(b)).collect();
        assert_eq!(fout, want);
        // Scratch is reusable: a second gather into the same Vec resizes.
        gather_f32_pages(&pages[..1], &mut out);
        assert_eq!(out, &flat[..16]);
    }

    #[test]
    fn f32x8_matches_scalar_lanes_bit_exact() {
        let a: Vec<f32> = (0..8).map(|i| i as f32 * 0.3 - 1.0).collect();
        let b: Vec<f32> = (0..8).map(|i| 2.5 - i as f32 * 0.7).collect();
        let acc = F32x8::splat(0.25).mul_acc(F32x8::load(&a), F32x8::load(&b));
        let mut out = [0.0f32; 8];
        acc.store(&mut out);
        for j in 0..8 {
            let want = 0.25f32 + a[j] * b[j];
            assert_eq!(out[j].to_bits(), want.to_bits(), "lane {j}");
        }
    }

    #[test]
    fn packed_matmul_smoke_against_dense_on_unpacked() {
        use crate::quant::{FgmpTensor, Precision};
        let mut rng = Rng::new(0x9001);
        let (m, k, n) = (5usize, 2 * BLOCK, 11usize);
        let x = rng.normal_vec(m * k, 1.5);
        // Transposed (N, K) pack with a mixed assignment.
        let w = rng.normal_vec(k * n, 0.4);
        let mut data_t = vec![0.0f32; k * n];
        for ki in 0..k {
            for ni in 0..n {
                data_t[ni * k + ki] = w[ki * n + ni];
            }
        }
        let kb = k / BLOCK;
        let prec: Vec<Precision> = (0..n * kb)
            .map(|i| if i % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[n, k], &data_t, &prec, None);
        let p = PackedPanels::from_tensor(&t, NR);
        let deq = p.unpack_kn();
        let want = matmul_scalar(&x, &deq, m, k, n);
        assert_eq!(matmul_packed(&x, &p, m), want);
        assert_eq!(matmul_packed_scalar(&x, &p, m), want);
    }

    #[test]
    fn packed_range_blocks_splice_into_full_product() {
        use crate::quant::{FgmpTensor, Precision};
        let mut rng = Rng::new(0x9002);
        // N off the panel grid (edge panel) to exercise the partial tail.
        let (m, k, n) = (6usize, 3 * BLOCK, 23usize);
        let x = rng.normal_vec(m * k, 1.0);
        let w = rng.normal_vec(n * k, 0.4);
        let kb = k / BLOCK;
        let prec: Vec<Precision> = (0..n * kb)
            .map(|i| if i % 3 == 0 { Precision::Fp8 } else { Precision::Fp4 })
            .collect();
        let t = FgmpTensor::pack(&[n, k], &w, &prec, None);
        let p = PackedPanels::from_tensor(&t, NR);
        let full = matmul_packed(&x, &p, m);
        let np = p.n_panels();
        for world in 1..=4usize {
            let mut spliced = vec![0.0f32; m * n];
            let (base, extra) = (np / world, np % world);
            let mut p0 = 0usize;
            for wi in 0..world {
                let p1 = p0 + base + usize::from(wi < extra);
                let c0 = (p0 * NR).min(n);
                let c1 = (p1 * NR).min(n);
                let block = matmul_packed_range(&x, &p, m, p0, p1);
                assert_eq!(block.len(), m * (c1 - c0));
                for r in 0..m {
                    spliced[r * n + c0..r * n + c1]
                        .copy_from_slice(&block[r * (c1 - c0)..(r + 1) * (c1 - c0)]);
                }
                p0 = p1;
            }
            for (a, b) in spliced.iter().zip(&full) {
                assert_eq!(a.to_bits(), b.to_bits(), "world={world}");
            }
        }
    }

    #[test]
    fn matmul_scratch_pool_reuses_buffers() {
        let pool = MatmulScratch::new();
        let mut b = pool.take();
        scratch_resize(&mut b, 128);
        b[0] = 7.0;
        let cap = b.capacity();
        pool.put(b);
        // LIFO: the next take hands the same allocation back (stale
        // contents included — scratch_resize does not re-zero it).
        let mut b2 = pool.take();
        assert!(b2.capacity() >= cap, "pooled capacity must persist");
        assert_eq!(b2[0], 7.0);
        scratch_resize(&mut b2, 64);
        assert_eq!(b2.len(), 64);
        assert!(pool.take().is_empty(), "pool empty again after the re-take");
    }

    #[test]
    fn ppu_row_extreme_thresholds() {
        let mut rng = Rng::new(3);
        let k = BLOCK * 3;
        let x = rng.normal_vec(k, 2.0);
        let cw = vec![1.0f32; k];
        let mut out = vec![0.0f32; k];
        // threshold −1: every block FP8 (scores ≥ 0)
        let n8 = ppu_quantize_row(&x, &cw, -1.0, &mut out);
        assert_eq!(n8, 3);
        let mut want = vec![0.0f32; k];
        e4m3_slice(&x, &mut want);
        assert_eq!(out, want);
        // +inf: every block NVFP4
        let n8 = ppu_quantize_row(&x, &cw, f32::INFINITY, &mut out);
        assert_eq!(n8, 0);
    }
}
