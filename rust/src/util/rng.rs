//! Deterministic splittable RNG (splitmix64 core) — replaces the `rand`
//! crate in tests, benches, and synthetic-stimulus generation.

/// Small, fast, deterministic RNG.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(1) }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // splitmix64
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        (self.f64() * n as f64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Vec of standard-normal f32 scaled by `scale`.
    pub fn normal_vec(&mut self, n: usize, scale: f32) -> Vec<f32> {
        (0..n).map(|_| self.normal() as f32 * scale).collect()
    }

    /// Fork an independent stream (for parallel determinism).
    pub fn split(&mut self) -> Rng {
        Rng::new(self.next_u64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_range() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
