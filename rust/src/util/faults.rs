//! Seeded, deterministic fault injection for the serving stack.
//!
//! A process-global registry of named **failpoints** wired at the seams
//! that can actually fail in production — KV page allocation
//! ([`KV_ALLOC`]), tensor-parallel worker execution ([`WORKER_PANIC`]),
//! and the engine's prefill/decode steps ([`ENGINE_PREFILL`],
//! [`ENGINE_DECODE`], [`ENGINE_SLOW`]). The registry is **inert by
//! default**: every [`should_fail`] call first reads one relaxed atomic
//! and returns `false` without taking any lock, so a disarmed process
//! pays a single predictable branch per failpoint — no allocation, no
//! contention, no behavior change.
//!
//! Armed ([`arm`] with a seed), each failpoint fires with its configured
//! probability ([`set`]) from one shared splitmix64 stream
//! ([`crate::util::Rng`]), so a fixed seed plus a fixed call sequence
//! replays the exact same fault schedule — the chaos soak test's
//! determinism contract. Calls from concurrent worker threads serialize
//! on the registry lock; their interleaving (and hence which *thread*
//! absorbs a given draw) may vary across runs, which is why the chaos
//! invariants (no lost streams, books reconcile, bit-exact tokens) are
//! written to hold under *any* schedule the seed produces.
//!
//! Tests within one binary share the process-global registry: arm/disarm
//! around the faulted region and serialize fault-using tests on a lock
//! (see `tests/fault_props.rs`), or use test-private failpoint names —
//! a name with no configured probability never fires.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;

use super::Rng;

/// Failpoint in `KvPool::cow_alloc`: the reservation reports typed
/// [`crate::model::kv::KvPoolExhausted`] backpressure as if the free list
/// had run dry (all-or-nothing, nothing allocated, nothing leaked).
pub const KV_ALLOC: &str = "kv.alloc";

/// Failpoint in `ThreadCollective::run`: one worker's job panics instead
/// of running — the engine recovers it as a typed `WorkerFailed` error.
pub const WORKER_PANIC: &str = "tp.worker_panic";

/// Failpoint at the top of engine prefill: the batch fails cleanly before
/// any session state exists, as a typed retryable error.
pub const ENGINE_PREFILL: &str = "engine.prefill";

/// Failpoint at the top of engine decode steps: the step fails cleanly
/// before consuming tokens or touching any cache, as a typed retryable
/// error.
pub const ENGINE_DECODE: &str = "engine.decode";

/// Failpoint in the decode step that injects latency instead of failure
/// (a slow worker / noisy-neighbor stand-in): the step sleeps
/// [`SLOW_STEP_MS`] and then proceeds normally.
pub const ENGINE_SLOW: &str = "engine.slow_step";

/// Milliseconds an [`ENGINE_SLOW`] firing stalls the step.
pub const SLOW_STEP_MS: u64 = 2;

/// The zero-cost gate: disarmed processes never touch the registry lock.
static ARMED: AtomicBool = AtomicBool::new(false);

/// Total firings across all failpoints since the last [`arm`].
static INJECTED: AtomicU64 = AtomicU64::new(0);

static REGISTRY: Mutex<Registry> = Mutex::new(Registry { rng: None, points: Vec::new() });

struct Registry {
    /// Seeded on [`arm`]; `None` while disarmed.
    rng: Option<Rng>,
    /// `(name, probability, fire count)` per configured failpoint.
    points: Vec<(String, f64, u64)>,
}

fn lock() -> std::sync::MutexGuard<'static, Registry> {
    // A panic while holding the registry lock (e.g. an injected worker
    // panic unwinding through a test) must not wedge every later test.
    REGISTRY.lock().unwrap_or_else(|e| e.into_inner())
}

/// Arm the registry with a fresh seeded schedule. Clears every previously
/// configured failpoint and zeroes all counters; configure probabilities
/// with [`set`] afterwards.
pub fn arm(seed: u64) {
    let mut g = lock();
    g.rng = Some(Rng::new(seed));
    g.points.clear();
    INJECTED.store(0, Ordering::Relaxed);
    ARMED.store(true, Ordering::Relaxed);
}

/// Disarm the registry: every [`should_fail`] reverts to the zero-cost
/// `false` path. Configured probabilities and fire counts are kept
/// readable ([`fires`], [`injected`]) until the next [`arm`].
pub fn disarm() {
    ARMED.store(false, Ordering::Relaxed);
}

/// Whether the registry is currently armed.
pub fn armed() -> bool {
    ARMED.load(Ordering::Relaxed)
}

/// Set `name`'s fire probability (clamped to `[0, 1]`). Unconfigured
/// failpoints never fire, so arming with only test-private names leaves
/// the production seams untouched.
pub fn set(name: &str, probability: f64) {
    let mut g = lock();
    let p = probability.clamp(0.0, 1.0);
    if let Some(e) = g.points.iter_mut().find(|(n, _, _)| n == name) {
        e.1 = p;
    } else {
        g.points.push((name.to_string(), p, 0));
    }
}

/// Draw `name`'s failpoint: `true` means the caller should fail here.
/// Disarmed, this is one relaxed atomic load and `false`.
#[inline]
pub fn should_fail(name: &str) -> bool {
    if !ARMED.load(Ordering::Relaxed) {
        return false;
    }
    should_fail_armed(name)
}

#[cold]
fn should_fail_armed(name: &str) -> bool {
    let mut g = lock();
    let Some(i) = g.points.iter().position(|(n, _, _)| n == name) else {
        return false;
    };
    let p = g.points[i].1;
    if p <= 0.0 {
        return false;
    }
    let fire = match g.rng.as_mut() {
        Some(rng) => p >= 1.0 || rng.f64() < p,
        None => false,
    };
    if fire {
        g.points[i].2 += 1;
        INJECTED.fetch_add(1, Ordering::Relaxed);
    }
    fire
}

/// Times `name` has fired since the last [`arm`].
pub fn fires(name: &str) -> u64 {
    lock().points.iter().find(|(n, _, _)| n == name).map_or(0, |(_, _, c)| *c)
}

/// Total firings across all failpoints since the last [`arm`].
pub fn injected() -> u64 {
    INJECTED.load(Ordering::Relaxed)
}

#[cfg(test)]
mod tests {
    use super::*;

    // These unit tests only ever configure test-private failpoint names,
    // so arming the process-global registry here cannot perturb other
    // tests running concurrently in this binary (production seams draw on
    // names this module never sets).

    #[test]
    fn disarmed_is_inert_and_unconfigured_names_never_fire() {
        disarm();
        assert!(!should_fail("test.faults.unit_inert"));
        assert_eq!(fires("test.faults.unit_inert"), 0);

        arm(7);
        assert!(armed());
        // Armed but unconfigured: still never fires, and draws no rng.
        for _ in 0..100 {
            assert!(!should_fail("test.faults.unit_unset"));
        }
        assert_eq!(injected(), 0);
        disarm();
        assert!(!armed());
    }

    #[test]
    fn probabilities_are_deterministic_for_a_seed() {
        arm(42);
        set("test.faults.unit_p1", 1.0);
        set("test.faults.unit_p0", 0.0);
        set("test.faults.unit_half", 0.5);
        let mut pattern = Vec::new();
        for _ in 0..64 {
            assert!(should_fail("test.faults.unit_p1"));
            assert!(!should_fail("test.faults.unit_p0"));
            pattern.push(should_fail("test.faults.unit_half"));
        }
        assert_eq!(fires("test.faults.unit_p1"), 64);
        assert_eq!(fires("test.faults.unit_p0"), 0);
        let half = fires("test.faults.unit_half");
        assert!(half > 0 && half < 64, "p=0.5 fired {half}/64");
        assert_eq!(injected(), 64 + half);

        // Same seed, same call sequence → the same schedule bit-for-bit.
        arm(42);
        set("test.faults.unit_p1", 1.0);
        set("test.faults.unit_p0", 0.0);
        set("test.faults.unit_half", 0.5);
        let mut replay = Vec::new();
        for _ in 0..64 {
            assert!(should_fail("test.faults.unit_p1"));
            assert!(!should_fail("test.faults.unit_p0"));
            replay.push(should_fail("test.faults.unit_half"));
        }
        assert_eq!(pattern, replay, "seeded schedule must replay exactly");
        disarm();
    }

    #[test]
    fn rearm_resets_counters_and_set_updates_in_place() {
        arm(3);
        set("test.faults.unit_reset", 1.0);
        assert!(should_fail("test.faults.unit_reset"));
        assert_eq!(fires("test.faults.unit_reset"), 1);
        set("test.faults.unit_reset", 0.0);
        assert!(!should_fail("test.faults.unit_reset"));
        assert_eq!(fires("test.faults.unit_reset"), 1, "p=0 stops new fires");
        arm(3);
        assert_eq!(fires("test.faults.unit_reset"), 0, "re-arm clears points");
        assert_eq!(injected(), 0);
        disarm();
    }
}
