//! Request types and the front-door router.
//!
//! Thread-based implementation (the offline build has no async runtime):
//! bounded `sync_channel` queues give the same backpressure semantics, and
//! each request carries its own reply channel.

use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TrySendError};
use std::time::Instant;

use crate::Result;

/// What the client wants.
#[derive(Debug, Clone, PartialEq)]
pub enum RequestKind {
    /// Masked NLL scoring of one row (perplexity / option scoring).
    Score { tokens: Vec<i32>, mask: Vec<f32> },
    /// Greedy generation of `n_tokens` continuing `prompt`.
    Generate { prompt: Vec<i32>, n_tokens: usize },
}

/// One in-flight request.
#[derive(Debug)]
pub struct Request {
    pub id: u64,
    pub kind: RequestKind,
    pub submitted_at: Instant,
    pub reply: std::sync::mpsc::Sender<Response>,
}

impl Request {
    /// Build a request plus the receiver for its response.
    pub fn new(id: u64, kind: RequestKind) -> (Self, std::sync::mpsc::Receiver<Response>) {
        let (tx, rx) = std::sync::mpsc::channel();
        (Request { id, kind, submitted_at: Instant::now(), reply: tx }, rx)
    }
}

/// Why a request came back without a payload. `None` on the response
/// means success; the typed variants let clients distinguish a blown
/// deadline (retry with a longer budget, or shed) from an execution
/// failure (the request itself may be at fault).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Rejection {
    /// The request sat past its `--deadline-ms` budget (queued, parked,
    /// or mid-decode) and was cancelled; any pages it held were returned.
    DeadlineExceeded,
    /// Execution failed (engine error, malformed request, shutdown).
    Failed,
}

/// What the client gets back.
#[derive(Debug, Clone)]
pub struct Response {
    pub id: u64,
    /// Score requests: (nll_sum, token_count).
    pub nll: Option<(f64, f64)>,
    /// Generate requests: the produced tokens.
    pub generated: Option<Vec<i32>>,
    pub latency: std::time::Duration,
    /// `None` on success; the typed reason when the payload is missing.
    pub rejection: Option<Rejection>,
}

/// Fans requests into per-kind bounded queues. Conservation (every accepted
/// request reaches exactly one queue and gets exactly one response or a
/// dropped channel) is exercised by tests/coordinator_props.rs.
pub struct Router {
    score_tx: SyncSender<Request>,
    gen_tx: SyncSender<Request>,
}

impl Router {
    pub fn new(depth: usize) -> (Self, Receiver<Request>, Receiver<Request>) {
        let (score_tx, score_rx) = sync_channel(depth);
        let (gen_tx, gen_rx) = sync_channel(depth);
        (Router { score_tx, gen_tx }, score_rx, gen_rx)
    }

    fn queue_for(&self, kind: &RequestKind) -> &SyncSender<Request> {
        match kind {
            RequestKind::Score { .. } => &self.score_tx,
            RequestKind::Generate { .. } => &self.gen_tx,
        }
    }

    /// Route one request; blocks (backpressure) when the queue is full.
    pub fn submit(&self, req: Request) -> Result<()> {
        self.queue_for(&req.kind)
            .send(req)
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))
    }

    /// Non-blocking submit; fails fast when the queue is full (explicit
    /// load-shedding instead of silent unbounded growth).
    pub fn try_submit(&self, req: Request) -> Result<()> {
        match self.queue_for(&req.kind).try_send(req) {
            Ok(()) => Ok(()),
            Err(TrySendError::Full(_)) => anyhow::bail!("queue full"),
            Err(TrySendError::Disconnected(_)) => anyhow::bail!("coordinator stopped"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_by_kind() {
        let (router, score_rx, gen_rx) = Router::new(4);
        let (r1, _rx1) = Request::new(1, RequestKind::Score { tokens: vec![1], mask: vec![1.0] });
        let (r2, _rx2) = Request::new(2, RequestKind::Generate { prompt: vec![1], n_tokens: 1 });
        router.submit(r1).unwrap();
        router.submit(r2).unwrap();
        assert_eq!(score_rx.try_recv().unwrap().id, 1);
        assert_eq!(gen_rx.try_recv().unwrap().id, 2);
    }

    #[test]
    fn try_submit_sheds_load_when_full() {
        let (router, _score_rx, _gen_rx) = Router::new(1);
        let (r1, _a) = Request::new(1, RequestKind::Score { tokens: vec![], mask: vec![] });
        let (r2, _b) = Request::new(2, RequestKind::Score { tokens: vec![], mask: vec![] });
        router.try_submit(r1).unwrap();
        assert!(router.try_submit(r2).is_err());
    }

    #[test]
    fn closed_queue_errors() {
        let (router, score_rx, _gen_rx) = Router::new(1);
        drop(score_rx);
        let (r, _rx) = Request::new(1, RequestKind::Score { tokens: vec![], mask: vec![] });
        assert!(router.submit(r).is_err());
    }
}
