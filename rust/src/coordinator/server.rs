//! The serving loop: batcher → executor → per-request responses, with hwsim
//! energy accounting per batch. Thread-based (DESIGN.md §Deps): one worker
//! thread per request kind, each owning its queue.
//!
//! Scoring runs the stateless one-shot graph as before. Generation runs a
//! **continuous-batching decode loop** over the stateful [`Engine`]: new
//! requests are admitted from the batcher *between* decode steps (up to the
//! decode batch capacity **and** the KV pool's committed-pages budget of
//! per-request worst cases — requests the pool cannot hold yet are
//! deferred back to the batcher, FIFO, instead of failed), every admitted
//! prompt of a round is
//! prefilled in one batched forward ([`Engine::prefill_batch`]), all live
//! sessions advance one token per step as a single batched forward over
//! the blocked kernels, and finished sessions retire immediately —
//! returning their KV pages to the pool's free list, which is what unparks
//! deferred admissions. Per-step energy includes the KV-cache read traffic
//! at the sessions' KV precision via
//! [`crate::hwsim::kvcache::kv_cache_bits`] — pooled pages are charged
//! identically to flat buffers (live tokens × bits/value). Pool occupancy,
//! page fill, and deferral counts land in [`Metrics`].
//!
//! **Robustness.** The generation loop is chaos-ready: per-request
//! deadlines ([`ServerConfig::deadline_ms`]) cancel queued, parked, or
//! mid-decode requests past budget with a typed
//! [`Rejection::DeadlineExceeded`]; transient engine failures (injected
//! faults, tensor-parallel worker panics typed as
//! [`EngineError::WorkerFailed`]) are retried in place with bounded
//! attempts — the engines restore session caches on every failed step, so
//! a retry is bit-exact; and sustained pool pressure (a deferred head aged
//! past [`ServerConfig::promote_after_ms`] that still cannot fit) preempts
//! the youngest live session — its computed prefix is donated to the
//! prefix index when one exists, its pages return to the pool, and the
//! request parks with exponential backoff. The resume re-prefills the
//! preserved context (mirroring the engine's roll normalization), so the
//! emitted stream is bit-identical to an uninterrupted run. Preemptions,
//! resumes, deadline rejections, batch retries, worker failures, and
//! injected-fault counts all land in [`Metrics`].

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::hwsim::energy::EnergyModel;
use crate::hwsim::kvcache::{kv_cache_bits, KvModelDims};
use crate::hwsim::{simulate_matmul, DatapathConfig, LayerProfile, MatmulJob};
use crate::model::kv::KvPrecision;
use crate::runtime::{
    build_engine, ArgValue, EngineError, EngineOptions, ExecSpec, Executable, InferenceEngine,
    Runtime, Session,
};
use crate::util::faults;
use crate::Result;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::router::{Rejection, Request, RequestKind, Response, Router};

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub batch: usize,
    pub seq: usize,
    pub policy: BatchPolicy,
    /// Per-layer shapes + weight FP8 fractions for the energy accounting
    /// (activation fractions are read per batch from the graph outputs).
    pub layer_shapes: Vec<LayerProfile>,
    pub queue_depth: usize,
    /// KV-cache storage precision of generation sessions.
    pub kv_precision: KvPrecision,
    /// Max live sessions the decode loop advances per step (continuous-
    /// batching capacity; independent of the score graph's frozen B).
    pub decode_batch: usize,
    /// KV page-pool capacity of the generation engine, in pages
    /// ([`crate::model::kv::PAGE_TOKENS`] tokens each); `None` keeps the
    /// engine default. The serve `--kv-pages` flag.
    pub kv_pages: Option<usize>,
    /// Accelerator energy model both workers price batches against
    /// (previously hardcoded to `EnergyModel::default()` inside the
    /// energy helpers — now an explicit serving-config decision).
    pub energy: EnergyModel,
    /// Attention-input PPU threshold forwarded to the engine
    /// ([`EngineOptions::attn_threshold`]); `None` keeps attention inputs
    /// full-precision.
    pub attn_threshold: Option<f32>,
    /// Tensor-parallel worker count of the generation engine; > 1 serves
    /// over a [`crate::runtime::ShardedEngine`] (bit-identical streams,
    /// the serve `--workers` flag).
    pub workers: usize,
    /// Self-speculative decoding chain length (`--spec k`): `Some(k >= 2)`
    /// wraps the generation engine in a
    /// [`SpecEngine`](crate::runtime::SpecEngine) drafting `k-1` tokens
    /// per round through the all-NVFP4 draft view. Streams stay bit-exact;
    /// the accept rate lands in [`Metrics`].
    pub spec: Option<usize>,
    /// Prefix sharing (`--prefix-share`): the generation engine keeps a
    /// prefix trie over prefilled prompts and maps already-cached whole KV
    /// pages into new sessions instead of re-prefilling them
    /// ([`EngineOptions::prefix_share`]). Admission then charges each
    /// request its *discounted* worst case
    /// ([`InferenceEngine::kv_pages_worst_for_prompt`]) plus the index's
    /// held pages, multiplying live-session capacity by the sharing
    /// factor on shared-prefix traffic. Single-worker engines only (the
    /// sharded engine ignores the flag).
    pub prefix_share: bool,
    /// Per-request deadline for generation (`--deadline-ms`): a request
    /// that has not completed this long after submission — whether still
    /// queued, parked by preemption, or mid-decode — is cancelled with a
    /// typed [`Rejection::DeadlineExceeded`], returning every page it
    /// held. `None` disables deadlines.
    pub deadline_ms: Option<u64>,
    /// Starvation bound for the deferred queue, in ms. While the oldest
    /// deferred request is younger than this, later arrivals that fit the
    /// pool may bypass it (better utilization); once it ages past the
    /// bound, admission reverts to strict head-of-line and sustained
    /// pressure preempts the youngest live session to make room. `0`
    /// disables both bypass and preemption (strict FIFO throughout).
    pub promote_after_ms: u64,
}

/// A running coordinator instance.
pub struct Server {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the score and generate workers.
    ///
    /// Workers receive graph *specs*, not executables: executables may not
    /// be Send (the PJRT backend's handles are Rc-based), so each worker
    /// thread builds its own runtime + executable/engine from the spec.
    /// The arg tails (plain data: weights, weightings, thresholds) cross
    /// threads freely.
    pub fn start(
        cfg: ServerConfig,
        fwd_spec: ExecSpec,
        fwd_args_tail: Vec<ArgValue>,
        logits_spec: ExecSpec,
        logits_args_tail: Vec<ArgValue>,
    ) -> Result<Self> {
        let (router, score_rx, gen_rx) = Router::new(cfg.queue_depth);
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();

        {
            let (cfg, metrics) = (cfg.clone(), metrics.clone());
            handles.push(std::thread::spawn(move || {
                let rt = Runtime::cpu().expect("runtime (score worker)");
                let exe = rt.load_spec(&fwd_spec).expect("load fwd_quant");
                score_worker(cfg, exe, fwd_args_tail, score_rx, metrics)
            }));
        }
        {
            let (cfg, metrics) = (cfg.clone(), metrics.clone());
            handles.push(std::thread::spawn(move || {
                let rt = Runtime::cpu().expect("runtime (gen worker)");
                let opts = EngineOptions::default()
                    .kv(cfg.kv_precision)
                    .pages(cfg.kv_pages)
                    .attn(cfg.attn_threshold)
                    .workers(cfg.workers)
                    .spec(cfg.spec)
                    .prefix_share(cfg.prefix_share);
                match build_engine(&rt, &logits_spec, logits_args_tail, opts) {
                    Ok(engine) => generate_worker(cfg, engine.as_ref(), gen_rx, metrics),
                    Err(e) => {
                        eprintln!("gen worker: engine init failed: {e}");
                        while let Ok(req) = gen_rx.recv() {
                            fail_request(req);
                        }
                    }
                }
            }));
        }

        Ok(Server { router: Arc::new(router), metrics, handles })
    }

    /// Close the intake (drop the router) and wait for workers to drain.
    pub fn shutdown(self) {
        let Server { router, handles, .. } = self;
        drop(router);
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Simulated accelerator energy of one forward over `m` token rows under
/// `em`: (fgmp_pj, all-fp8 baseline pj).
pub fn batch_energy(
    shapes: &[LayerProfile],
    act_fp8: &[f32],
    m: usize,
    em: &EnergyModel,
) -> (f64, f64) {
    let dp = DatapathConfig::default();
    let mut fgmp = 0.0;
    let mut fp8 = 0.0;
    for (i, p) in shapes.iter().enumerate() {
        let job = MatmulJob {
            m,
            k: p.k,
            n: p.n,
            weight_fp8: p.weight_fp8,
            act_fp8: act_fp8.get(i).copied().unwrap_or(0.0) as f64,
        };
        fgmp += simulate_matmul(&dp, em, &job, true).total_energy_pj();
        let j8 = MatmulJob { weight_fp8: 1.0, act_fp8: 1.0, ..job };
        let r8 = simulate_matmul(&dp, em, &j8, true);
        fp8 += r8.total_energy_pj() - em.e_mux_tax * r8.vmacs as f64;
    }
    (fgmp, fp8)
}

/// Simulated accelerator energy of `m` **draft** token rows: the same
/// datapath as [`batch_energy`]'s FGMP side but with every weight read
/// priced at NVFP4 width (`weight_fp8 = 0`) — the all-NVFP4 draft view of
/// a speculative round reads no E4M3 weight blocks, which is exactly where
/// its speedup and energy advantage come from. Activation fractions reuse
/// the round's realized per-linear mix.
pub fn draft_energy(
    shapes: &[LayerProfile],
    act_fp8: &[f32],
    m: usize,
    em: &EnergyModel,
) -> f64 {
    if m == 0 {
        return 0.0;
    }
    let dp = DatapathConfig::default();
    shapes
        .iter()
        .enumerate()
        .map(|(i, p)| {
            let job = MatmulJob {
                m,
                k: p.k,
                n: p.n,
                weight_fp8: 0.0,
                act_fp8: act_fp8.get(i).copied().unwrap_or(0.0) as f64,
            };
            simulate_matmul(&dp, em, &job, true).total_energy_pj()
        })
        .sum()
}

/// KV-sizing dims recovered from the serving layer profiles (n_layers from
/// the layer indices, d_model from the qkv input width). Malformed or
/// empty profiles are an **error** — previously they silently produced
/// zeroed dims, making every energy report claim zero KV/attention
/// traffic; callers must either propagate or log-and-degrade explicitly.
pub fn kv_dims_from_profiles(shapes: &[LayerProfile]) -> Result<KvModelDims> {
    anyhow::ensure!(
        !shapes.is_empty(),
        "no layer profiles: cannot size the KV model (energy would report zero cache traffic)"
    );
    let n_layers = shapes.iter().map(|p| p.layer + 1).max().unwrap_or(0);
    let d_model = shapes
        .iter()
        .find(|p| p.kind == "qkv_proj")
        .map(|p| p.k)
        .or_else(|| shapes.first().map(|p| p.k))
        .unwrap_or(0);
    anyhow::ensure!(
        n_layers > 0 && d_model > 0,
        "malformed layer profiles (n_layers {n_layers}, d_model {d_model}): \
         KV traffic would be charged as zero"
    );
    let weight_elements = shapes.iter().map(|p| (p.k * p.n) as u64).sum();
    Ok(KvModelDims { n_layers, d_model, weight_elements })
}

/// Simulated energy of one decode step under `em`: the datapath compute
/// over `rows` new token rows **plus** the KV-cache read traffic — every
/// step streams each live session's whole cache (`kv_tokens` tokens in
/// total) through the attention units at `kv_bits_per_value`, the *stored*
/// precision the attend kernels actually read (8-bit E4M3 bytes for FP8
/// caches, or the PPU's realized FGMP mix). The baseline is all-FP8
/// compute with the paper's 16-bit KV cache, so a quantized cache's
/// traffic savings show up in `energy_savings` alongside the datapath's.
#[allow(clippy::too_many_arguments)]
pub fn decode_step_energy(
    shapes: &[LayerProfile],
    act_fp8: &[f32],
    rows: usize,
    dims: &KvModelDims,
    kv_tokens: u64,
    kv_bits_per_value: f64,
    em: &EnergyModel,
) -> (f64, f64) {
    let (fgmp, fp8) = batch_energy(shapes, act_fp8, rows, em);
    let kv = kv_cache_bits(dims, kv_tokens, kv_bits_per_value) as f64 * em.e_kv_bit;
    let kv16 = kv_cache_bits(dims, kv_tokens, 16.0) as f64 * em.e_kv_bit;
    (fgmp + kv, fp8 + kv16)
}

/// Tensor-parallel variant of [`decode_step_energy`]: each worker streams
/// the same `kv_tokens` tokens but at its **own** shard width and its own
/// realized precision mix, so its traffic must be priced per worker and
/// summed — averaging the mixes first and multiplying by the full width
/// over-charges workers whose shard quantized harder (and under-charges the
/// rest) whenever per-worker mixes diverge. The all-FP8 baseline keeps the
/// single 16-bit full-width cache read (worker widths tile `d_model`, so
/// the totals are comparable). With a single-entry mix this reduces exactly
/// to [`decode_step_energy`].
pub fn decode_step_energy_tp(
    shapes: &[LayerProfile],
    act_fp8: &[f32],
    rows: usize,
    dims: &KvModelDims,
    kv_tokens: u64,
    kv_mix: &[(usize, f64)],
    em: &EnergyModel,
) -> (f64, f64) {
    let (fgmp, fp8) = batch_energy(shapes, act_fp8, rows, em);
    let kv: f64 = kv_mix
        .iter()
        .map(|&(width, bits)| {
            let wdims = KvModelDims { d_model: width, ..dims.clone() };
            kv_cache_bits(&wdims, kv_tokens, bits) as f64 * em.e_kv_bit
        })
        .sum();
    let kv16 = kv_cache_bits(dims, kv_tokens, 16.0) as f64 * em.e_kv_bit;
    (fgmp + kv, fp8 + kv16)
}

fn fail_request(req: Request) {
    let _ = req.reply.send(Response {
        id: req.id,
        nll: None,
        generated: None,
        latency: req.submitted_at.elapsed(),
        rejection: Some(Rejection::Failed),
    });
}

/// Cancel one request for blowing its deadline — typed, so clients can
/// tell a timeout from [`fail_request`]'s execution failure.
fn reject_deadline(req: Request) {
    let _ = req.reply.send(Response {
        id: req.id,
        nll: None,
        generated: None,
        latency: req.submitted_at.elapsed(),
        rejection: Some(Rejection::DeadlineExceeded),
    });
}

fn score_worker(
    cfg: ServerConfig,
    exe: Executable,
    tail: Vec<ArgValue>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(cfg.policy.clone(), rx);
    while let Some(mut batch) = batcher.next_batch() {
        batcher.drain_ready(&mut batch);
        let (b, s) = (cfg.batch, cfg.seq);
        let mut tokens = vec![0i32; b * s];
        let mut mask = vec![0.0f32; b * s];
        for (row, req) in batch.iter().enumerate() {
            if let RequestKind::Score { tokens: t, mask: m } = &req.kind {
                let n = t.len().min(s);
                tokens[row * s..row * s + n].copy_from_slice(&t[..n]);
                mask[row * s..row * s + n].copy_from_slice(&m[..n]);
            }
        }
        let mut args = vec![
            ArgValue::I32 { shape: vec![b, s], data: tokens },
            ArgValue::F32 { shape: vec![b, s], data: mask },
        ];
        args.extend(tail.iter().cloned());

        let t0 = Instant::now();
        let out = exe.run(&args);
        let busy = t0.elapsed();

        match out {
            Ok(out) => {
                let (nll, ntok, act_fp8) = (&out[0], &out[1], &out[2]);
                let rows = batch.len();
                let tokens_scored: f64 = ntok.iter().map(|&v| v as f64).sum();
                let (e, e8) = batch_energy(&cfg.layer_shapes, act_fp8, b * s, &cfg.energy);
                let now = Instant::now();
                let lats: Vec<_> =
                    batch.iter().map(|r| now.duration_since(r.submitted_at)).collect();
                metrics.record_batch(rows, b, tokens_scored, &lats, busy, e, e8);
                for (row, req) in batch.into_iter().enumerate() {
                    let _ = req.reply.send(Response {
                        id: req.id,
                        nll: Some((nll[row] as f64, ntok[row] as f64)),
                        generated: None,
                        latency: now.duration_since(req.submitted_at),
                        rejection: None,
                    });
                }
            }
            Err(_) => {
                for req in batch {
                    fail_request(req);
                }
            }
        }
    }
}

/// One generation request being decoded.
struct LiveGen {
    req: Request,
    sess: Session,
    want: usize,
    produced: Vec<i32>,
    /// Worst-case pool pages this session was admitted against
    /// ([`InferenceEngine::kv_pages_worst_for_prompt`] — discounted by any
    /// prefix pages it mapped instead of allocating) — released from the
    /// committed budget at retirement.
    worst_pages: usize,
    /// Times this request has been preempted (drives the resume backoff).
    attempt: u32,
}

/// A preempted generation request waiting out its backoff. Holds no pool
/// pages and no committed budget — only the tokens needed to resume the
/// stream exactly where it stopped.
struct Parked {
    req: Request,
    /// Resume context: the victim's session tokens (roll-normalized when
    /// the cache sat at capacity) plus the one produced-but-not-yet-
    /// consumed token, so a fresh prefill reconstructs the exact causal
    /// state the next decode step would have seen.
    prompt: Vec<i32>,
    /// Tokens still to produce (`want_total` minus produced so far).
    remaining: usize,
    produced: Vec<i32>,
    want_total: usize,
    attempt: u32,
    resume_at: Instant,
}

/// Bounded in-place retries of a transient prefill failure.
const PREFILL_RETRIES: u32 = 3;
/// Bounded *consecutive* transient decode-step retries before the round
/// is failed (a sustained fault storm, not an injected blip).
const MAX_STEP_RETRIES: u32 = 32;

/// Exponential preemption backoff: 1 ms doubling per attempt, capped at
/// 128 ms so a repeatedly-preempted request keeps probing for pages.
fn backoff_for(attempt: u32) -> Duration {
    Duration::from_millis(1u64 << attempt.min(7))
}

/// Preempt the youngest live session (most recent submission — least sunk
/// cost): donate its computed prefix to the prefix index when one exists
/// (the resume then maps those pages back instead of recomputing them),
/// release its budget and pages, and park the request for a backed-off
/// resume. Returns `false` with nothing live to preempt.
fn preempt_youngest<E: InferenceEngine + ?Sized>(
    engine: &E,
    live: &mut Vec<LiveGen>,
    parked: &mut Vec<Parked>,
    committed: &mut usize,
) -> bool {
    if live.is_empty() {
        return false;
    }
    let mut vi = 0;
    for (i, lg) in live.iter().enumerate() {
        if lg.req.submitted_at > live[vi].req.submitted_at {
            vi = i;
        }
    }
    let lg = live.swap_remove(vi);
    engine.preempt_donate(&lg.sess);
    *committed = committed.saturating_sub(lg.worst_pages);
    // Rebuild the exact causal context the next step would have seen. The
    // session holds `prompt ++ produced[..n-1]` (the last produced token
    // is not yet consumed). An uninterrupted run whose cache sat at
    // capacity would roll down to the trailing half-window before
    // consuming it, so the resume context mirrors that roll — the stream
    // stays bit-exact either way.
    let max_seq = engine.arch().max_seq;
    let mut prompt = lg.sess.tokens.clone();
    if prompt.len() >= max_seq {
        let keep = (max_seq / 2).max(1);
        prompt.drain(..prompt.len() - keep);
    }
    prompt.push(*lg.produced.last().expect("live sessions hold >= 1 produced token"));
    let remaining = lg.want.saturating_sub(lg.produced.len()).max(1);
    let attempt = lg.attempt + 1;
    parked.push(Parked {
        req: lg.req,
        prompt,
        remaining,
        produced: lg.produced,
        want_total: lg.want,
        attempt,
        resume_at: Instant::now() + backoff_for(attempt),
    });
    // Dropping the session here returns its pages to the pool (donated
    // prefix pages stay alive through the index's references).
    true
}

/// Prefill with bounded retries on *transient* failures (injected faults,
/// caught worker panics). A failed attempt leaves nothing behind — the
/// engines build fresh session state only on success — so an immediate
/// retry is safe and bit-exact.
fn prefill_with_retry<E: InferenceEngine + ?Sized>(
    engine: &E,
    prompts: &[Vec<i32>],
    metrics: &Metrics,
) -> Result<Vec<Session>> {
    let mut attempts = 0u32;
    loop {
        match engine.prefill_batch(prompts) {
            Ok(sessions) => return Ok(sessions),
            Err(e) if EngineError::is_transient(&e) && attempts < PREFILL_RETRIES => {
                attempts += 1;
                if matches!(EngineError::classify(&e), Some(EngineError::WorkerFailed { .. })) {
                    metrics.record_worker_failure();
                }
                metrics.record_batch_retry();
            }
            Err(e) => return Err(e),
        }
    }
}

/// Cancel live sessions past their deadline: drop the session (returning
/// its pages), release its budget, and answer with the typed rejection.
fn cancel_expired_live(
    live: &mut Vec<LiveGen>,
    deadline: Duration,
    committed: &mut usize,
    metrics: &Metrics,
) {
    let mut i = 0;
    while i < live.len() {
        if live[i].req.submitted_at.elapsed() >= deadline {
            let lg = live.swap_remove(i);
            *committed = committed.saturating_sub(lg.worst_pages);
            metrics.record_deadline_rejection();
            reject_deadline(lg.req);
        } else {
            i += 1;
        }
    }
}

/// Send responses for every session that has produced its token budget,
/// removing it from the live set (continuous retirement) and releasing
/// its worst-case pages from the admission budget.
fn retire_finished(live: &mut Vec<LiveGen>, metrics: &Metrics, committed: &mut usize) {
    let mut i = 0;
    while i < live.len() {
        if live[i].produced.len() >= live[i].want {
            let lg = live.swap_remove(i);
            *committed = committed.saturating_sub(lg.worst_pages);
            metrics.record_generated(lg.want as u64);
            let _ = lg.req.reply.send(Response {
                id: lg.req.id,
                nll: None,
                generated: Some(lg.produced[..lg.want].to_vec()),
                latency: lg.req.submitted_at.elapsed(),
                rejection: None,
            });
        } else {
            i += 1;
        }
    }
}

/// One KV pool sample: pages in use / total (with the pool's exact
/// high-water mark), plus live-token slot fill of the allocated pages.
/// No-op on the windowed fallback, which has no pool.
fn sample_pool<E: InferenceEngine + ?Sized>(
    engine: &E,
    metrics: &Metrics,
    live: &[LiveGen],
    slots_per_token: u64,
) {
    if let Some(stats) = engine.pool_stats() {
        let used_slots: u64 =
            live.iter().map(|lg| lg.sess.cached_tokens() as u64).sum::<u64>() * slots_per_token;
        let cap_slots = (stats.in_use_pages * stats.page_tokens) as u64;
        metrics.record_pool(
            stats.in_use_pages,
            stats.total_pages,
            stats.logical_pages,
            stats.deduped_bytes(),
            stats.peak_in_use,
            used_slots,
            cap_slots,
        );
    }
}

/// The continuous-batching decode loop. Each iteration: admit waiting
/// requests into free session slots (blocking only when no session is
/// live), deferring any the KV page pool cannot hold yet back to the
/// batcher (FIFO — retirement frees pages and unparks them), prefill the
/// whole admitted round as **one batched forward** (TTFT ends here — every
/// first token's logits exist), retire anything already satisfied, then
/// advance every live session one token in a single batched
/// [`InferenceEngine::decode_step`], sampling pool occupancy alongside.
/// Generic over the engine surface: the single-worker [`crate::runtime::Engine`]
/// and the tensor-parallel [`crate::runtime::ShardedEngine`] drive the same
/// loop.
///
/// Robustness (see the module docs): parked requests resume ahead of new
/// admissions, deadlines cancel expired work at every stage, transient
/// step failures retry in place against the engines' restored session
/// state, and an aged deferred head that cannot fit preempts the
/// youngest live session for a backed-off bit-exact resume.
fn generate_worker<E: InferenceEngine + ?Sized>(
    cfg: ServerConfig,
    engine: &E,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let cap = cfg.decode_batch.max(1);
    // Admission shares the score path's deadline policy but is capped by
    // the decode batch, not the score graph's B.
    let policy = BatchPolicy { max_batch: cap, ..cfg.policy.clone() };
    let mut batcher = Batcher::new(policy, rx);
    // Malformed profiles degrade loudly: warn once and charge no KV
    // traffic, instead of the old silent zeroed dims.
    let kv_dims = match kv_dims_from_profiles(&cfg.layer_shapes) {
        Ok(dims) => dims,
        Err(e) => {
            eprintln!("gen worker: {e}; KV/attention traffic will not be charged");
            KvModelDims { n_layers: 0, d_model: 0, weight_elements: 0 }
        }
    };
    // Admission budget: Σ per-request worst-case pages of live sessions —
    // plus, under prefix sharing, the index's own held pages — stays
    // within the pool, so prefill/decode/roll can never hit an exhausted
    // pool mid-stream (None = windowed fallback, unbounded). With a
    // prefix index each request is charged its *discounted* worst case
    // (shared whole pages it will map rather than allocate), which is
    // what lets shared-prefix traffic admit more live sessions than the
    // pool could hold at full per-session cost.
    let pool_total: Option<usize> = engine.pool_stats().map(|s| s.total_pages);
    let slots_per_token = 2 * engine.arch().n_layers as u64;
    let deadline = cfg.deadline_ms.map(Duration::from_millis);
    let promote_after = Duration::from_millis(cfg.promote_after_ms);
    let aging = cfg.promote_after_ms > 0;
    let mut live: Vec<LiveGen> = Vec::new();
    let mut parked: Vec<Parked> = Vec::new();
    let mut committed: usize = 0;
    let mut step_retries = 0u32;
    let mut faults_seen = faults::injected();
    let mut cooldowns_seen = engine.spec_cooldowns().unwrap_or(0);

    // Worst-case pages a request commits at admission (0 when unbounded).
    let worst_for = |req: &Request| -> usize {
        match &req.kind {
            RequestKind::Generate { prompt, n_tokens } => {
                engine.kv_pages_worst_for_prompt(prompt, *n_tokens)
            }
            _ => 0,
        }
    };

    loop {
        // Fold any failpoint fires since the last sample into the metrics
        // (stays 0 unless a chaos harness armed the registry), and any
        // speculative draft-cooldown trips alongside.
        let inj = faults::injected();
        if inj > faults_seen {
            metrics.record_faults_injected(inj - faults_seen);
        }
        faults_seen = inj;
        if let Some(c) = engine.spec_cooldowns() {
            if c > cooldowns_seen {
                metrics.record_spec_cooldowns(c - cooldowns_seen);
            }
            cooldowns_seen = c;
        }

        // Pages the prefix index holds this round: they back the
        // discounted per-request bounds, so the budget must charge them
        // once, on top of the per-session worst cases (0 with no index).
        let index_held = engine.prefix_stats().map_or(0, |s| s.pages_held);

        // Parked requests first: cancel any past deadline, then resume
        // those whose backoff elapsed and whose worst case fits again —
        // they are the oldest work, so budget goes to them before new
        // admissions.
        let mut resumes: Vec<(Parked, usize)> = Vec::new();
        if !parked.is_empty() {
            let now = Instant::now();
            let mut i = 0;
            while i < parked.len() {
                if deadline.is_some_and(|d| parked[i].req.submitted_at.elapsed() >= d) {
                    let p = parked.remove(i);
                    metrics.record_deadline_rejection();
                    reject_deadline(p.req);
                    continue;
                }
                if parked[i].resume_at <= now && live.len() + resumes.len() < cap {
                    let p = &parked[i];
                    let worst = engine.kv_pages_worst_for_prompt(&p.prompt, p.remaining);
                    let fits =
                        pool_total.map(|t| committed + index_held + worst <= t).unwrap_or(true);
                    // With nothing live the budget can only free up via
                    // index eviction, which prefill performs under real
                    // pressure — force the resume rather than deadlock.
                    if fits || (live.is_empty() && resumes.is_empty()) {
                        committed += worst;
                        resumes.push((parked.remove(i), worst));
                        continue;
                    }
                }
                i += 1;
            }
        }
        if !resumes.is_empty() {
            let prompts: Vec<Vec<i32>> = resumes.iter().map(|(p, _)| p.prompt.clone()).collect();
            match prefill_with_retry(engine, &prompts, &metrics) {
                Ok(sessions) => {
                    for ((p, worst), sess) in resumes.into_iter().zip(sessions) {
                        metrics.record_preempt_resume();
                        let mut lg = LiveGen {
                            req: p.req,
                            sess,
                            want: p.want_total,
                            produced: p.produced,
                            worst_pages: worst,
                            attempt: p.attempt,
                        };
                        // The resume context ends on the produced-but-not-
                        // consumed token, so these logits are exactly the
                        // ones the preempted stream was about to read.
                        lg.produced.push(lg.sess.next_token());
                        live.push(lg);
                    }
                    sample_pool(engine, &metrics, &live, slots_per_token);
                }
                Err(e) => {
                    // Typed failures (exhaustion, a still-failing worker)
                    // re-park with a longer backoff; anything untyped
                    // fails the request.
                    let repark = EngineError::classify(&e).is_some();
                    for (mut p, worst) in resumes {
                        committed = committed.saturating_sub(worst);
                        if repark {
                            p.attempt += 1;
                            p.resume_at = Instant::now() + backoff_for(p.attempt);
                            parked.push(p);
                        } else {
                            fail_request(p.req);
                        }
                    }
                }
            }
        }

        // Admit new work between steps. While the deferred head is young
        // (under the promotion bound) later requests that fit may bypass
        // it; once it ages past the bound admission turns strictly
        // head-of-line — only the head is pulled — and sustained pressure
        // preempts below. With aging disabled (promote_after_ms = 0) the
        // drain is gated on the head fitting, the previous strict-FIFO
        // behavior.
        let head_aged =
            aging && batcher.head_deferred_age().is_some_and(|age| age >= promote_after);
        let mut admitted = Vec::new();
        if live.is_empty() && parked.is_empty() {
            match batcher.next_batch() {
                Some(batch) => admitted = batch,
                None => break, // queue closed and drained; nothing live or parked
            }
        } else {
            let room = cap.saturating_sub(live.len());
            let head_fits = match (pool_total, batcher.peek_deferred()) {
                (Some(total), Some(head)) => committed + index_held + worst_for(head) <= total,
                _ => true,
            };
            if room > 0 {
                if head_fits || (aging && !head_aged) {
                    batcher.drain_ready_capped(&mut admitted, room);
                } else if head_aged {
                    // Strict head-of-line: pull exactly the aged head so
                    // it is placed first (or triggers preemption below).
                    batcher.drain_ready_capped(&mut admitted, 1);
                }
            }
        }

        // Place in arrival order against the pool budget. The first
        // request whose worst case does not fit *yet* blocks everything
        // behind it (head-of-line: deferral never reorders) — unless
        // aging allows a bounded bypass; only requests that could never
        // fit even an empty pool are failed.
        let bypass_ok = aging && !head_aged;
        let mut ready: Vec<(Request, usize, usize)> = Vec::new();
        let mut prompts: Vec<Vec<i32>> = Vec::new();
        let mut deferred: Vec<Request> = Vec::new();
        for req in admitted {
            if deadline.is_some_and(|d| req.submitted_at.elapsed() >= d) {
                batcher.note_admitted(req.id);
                metrics.record_deadline_rejection();
                reject_deadline(req);
                continue;
            }
            let (prompt, want) = match &req.kind {
                RequestKind::Generate { prompt, n_tokens } => (prompt.clone(), *n_tokens),
                // The router partitions by kind; anything else is a bug —
                // fail it rather than wedge the loop.
                _ => {
                    batcher.note_admitted(req.id);
                    fail_request(req);
                    continue;
                }
            };
            // The satisfiability check stays on the *undiscounted* bound:
            // a request that only fits thanks to index-held pages must
            // defer (eviction could reclaim them), not fail.
            if pool_total.is_some_and(|t| engine.kv_pages_worst_for(prompt.len(), want) > t) {
                batcher.note_admitted(req.id);
                fail_request(req); // never satisfiable, even in an empty pool
                continue;
            }
            let worst = engine.kv_pages_worst_for_prompt(&prompt, want);
            let fits =
                pool_total.map(|total| committed + index_held + worst <= total).unwrap_or(true);
            // An aged head that still does not fit with nothing live to
            // preempt is force-placed: decode-time exhaustion is now
            // survivable (preemption) and prefill evicts index pages under
            // real pressure, so refusing forever would starve it.
            let force = head_aged && live.is_empty() && ready.is_empty() && deferred.is_empty();
            if (fits || force) && (deferred.is_empty() || bypass_ok) {
                committed += worst;
                batcher.note_admitted(req.id);
                ready.push((req, want, worst));
                prompts.push(prompt);
            } else {
                deferred.push(req);
            }
        }
        if !deferred.is_empty() {
            metrics.record_deferred(deferred.len() as u64);
            batcher.defer(deferred);
        }

        // Sustained pressure: the deferred head has aged past the
        // promotion bound and still cannot fit while sessions are live —
        // preempt the youngest (one per iteration) so its pages unblock
        // the head next time round.
        if head_aged {
            let pressure = match (pool_total, batcher.peek_deferred()) {
                (Some(total), Some(head)) => committed + index_held + worst_for(head) > total,
                _ => false,
            };
            if pressure && preempt_youngest(engine, &mut live, &mut parked, &mut committed) {
                metrics.record_preemption();
            }
        }

        // Batched prefill: every admitted prompt in one forward, with
        // bounded retries on transient faults.
        if !ready.is_empty() {
            match prefill_with_retry(engine, &prompts, &metrics) {
                Ok(sessions) => {
                    for ((req, want, worst_pages), sess) in ready.into_iter().zip(sessions) {
                        metrics.record_ttft(req.submitted_at.elapsed());
                        let mut lg = LiveGen {
                            req,
                            sess,
                            want,
                            produced: Vec::with_capacity(want),
                            worst_pages,
                            attempt: 0,
                        };
                        lg.produced.push(lg.sess.next_token());
                        live.push(lg);
                    }
                    // Sample pool occupancy while the admitted sessions
                    // still hold their pages (a gen-tokens=1 request
                    // retires before any decode step would sample).
                    sample_pool(engine, &metrics, &live, slots_per_token);
                }
                Err(e) if EngineError::is_exhausted(&e) => {
                    // The budget said fit but the pool disagreed (a forced
                    // placement, or index-held pages): hand the round back
                    // to the batcher and let retirement/preemption drain
                    // the pressure instead of failing the requests.
                    let mut back = Vec::new();
                    for (req, _, worst) in ready {
                        committed = committed.saturating_sub(worst);
                        back.push(req);
                    }
                    metrics.record_deferred(back.len() as u64);
                    batcher.defer(back);
                }
                Err(_) => {
                    for (req, _, worst) in ready {
                        committed = committed.saturating_sub(worst);
                        fail_request(req);
                    }
                }
            }
        }
        retire_finished(&mut live, &metrics, &mut committed);
        if let Some(d) = deadline {
            cancel_expired_live(&mut live, d, &mut committed, &metrics);
        }
        if live.is_empty() {
            // Nothing to step. Don't spin the admission loop hot while
            // parked requests wait out their backoff.
            if !parked.is_empty() {
                std::thread::sleep(Duration::from_micros(200));
            }
            continue;
        }

        // Step every live session by one token, batched.
        let t0 = Instant::now();
        let stepped = {
            let mut sessions: Vec<&mut Session> =
                live.iter_mut().map(|lg| &mut lg.sess).collect();
            engine.decode_step(&mut sessions)
        };
        let busy = t0.elapsed();
        match stepped {
            Ok(step) => {
                step_retries = 0;
                // KV traffic priced at the *stored* bits the attend
                // kernels actually read this step (precision nominal, or
                // the attention PPU's realized FGMP mix). Sharded steps
                // report one mix entry per worker and each worker's reads
                // are priced at its own shard width and realized mix.
                let (mut e, mut e8) = if step.kv_mix.len() > 1 {
                    decode_step_energy_tp(
                        &cfg.layer_shapes,
                        &step.act_fp8,
                        step.rows,
                        &kv_dims,
                        step.kv_tokens,
                        &step.kv_mix,
                        &cfg.energy,
                    )
                } else {
                    decode_step_energy(
                        &cfg.layer_shapes,
                        &step.act_fp8,
                        step.rows,
                        &kv_dims,
                        step.kv_tokens,
                        step.kv_bits_per_value,
                        &cfg.energy,
                    )
                };
                // A speculative round does compute the plain-step pricing
                // misses: the verify pass scores the `drafted` chain rows
                // on top of the `rows` a plain step would, and the draft
                // forward reads weights at NVFP4 width. The all-FP8
                // baseline is charged the extra single-token steps it
                // would need to produce the same `accepted` tokens.
                if step.drafted > 0 {
                    let (ev, _) = batch_energy(
                        &cfg.layer_shapes,
                        &step.act_fp8,
                        step.drafted as usize,
                        &cfg.energy,
                    );
                    e += ev
                        + draft_energy(
                            &cfg.layer_shapes,
                            &step.act_fp8,
                            step.drafted as usize,
                            &cfg.energy,
                        );
                    let (_, eb) = batch_energy(
                        &cfg.layer_shapes,
                        &step.act_fp8,
                        step.accepted as usize,
                        &cfg.energy,
                    );
                    e8 += eb;
                    metrics.record_spec(step.drafted, step.accepted);
                }
                metrics.record_decode_step(step.rows, cap, busy, e, e8);
                metrics.record_kv_traffic(step.kv_tokens, step.kv_bits_per_value);
                for lg in &mut live {
                    // Speculative rounds accept extra tokens beyond the
                    // usual one-per-step; they precede the current logits'
                    // next_token in the stream (bit-exact greedy order).
                    lg.produced.extend(lg.sess.take_accepted());
                    lg.produced.push(lg.sess.next_token());
                }
                // Pool occupancy sample for this step (paged engines).
                sample_pool(engine, &metrics, &live, slots_per_token);
            }
            Err(e) => {
                let classified = EngineError::classify(&e);
                let is_worker = matches!(&classified, Some(EngineError::WorkerFailed { .. }));
                match classified {
                    // The pool genuinely ran dry mid-step (a roll's
                    // transient double residency, or an earlier forced
                    // placement). The failed step restored every session,
                    // so preempt the youngest to free pages and retry.
                    Some(EngineError::KvPoolExhausted(_)) => {
                        if preempt_youngest(engine, &mut live, &mut parked, &mut committed) {
                            metrics.record_preemption();
                        } else {
                            committed = 0;
                            for lg in live.drain(..) {
                                fail_request(lg.req);
                            }
                        }
                    }
                    // Transient: the engines restore session caches on a
                    // failed step, so retrying in place is bit-exact.
                    // Bounded, so a sustained fault storm still fails.
                    Some(EngineError::WorkerFailed { .. }) | Some(EngineError::Injected { .. }) => {
                        if is_worker {
                            metrics.record_worker_failure();
                        }
                        metrics.record_batch_retry();
                        step_retries += 1;
                        if step_retries > MAX_STEP_RETRIES {
                            step_retries = 0;
                            committed = 0;
                            for lg in live.drain(..) {
                                fail_request(lg.req);
                            }
                        }
                    }
                    // Untyped failures stay fatal for the round: parked
                    // requests hold no budget, so zeroing `committed`
                    // after draining every live session is exact.
                    _ => {
                        committed = 0;
                        for lg in live.drain(..) {
                            fail_request(lg.req);
                        }
                    }
                }
            }
        }
        retire_finished(&mut live, &metrics, &mut committed);
    }
}
