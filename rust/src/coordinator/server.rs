//! The serving loop: batcher → executor → per-request responses, with hwsim
//! energy accounting per batch. Thread-based (DESIGN.md §Deps): one worker
//! thread per request kind, each owning its queue.

use std::sync::mpsc::Receiver;
use std::sync::Arc;
use std::time::Instant;

use crate::hwsim::energy::EnergyModel;
use crate::hwsim::{simulate_matmul, DatapathConfig, LayerProfile, MatmulJob};
use crate::runtime::{ArgValue, ExecSpec, Executable, Runtime};
use crate::Result;

use super::batcher::{BatchPolicy, Batcher};
use super::metrics::Metrics;
use super::router::{Request, RequestKind, Response, Router};

/// Server configuration.
#[derive(Clone)]
pub struct ServerConfig {
    pub batch: usize,
    pub seq: usize,
    pub policy: BatchPolicy,
    /// Per-layer shapes + weight FP8 fractions for the energy accounting
    /// (activation fractions are read per batch from the graph outputs).
    pub layer_shapes: Vec<LayerProfile>,
    pub queue_depth: usize,
}

/// A running coordinator instance.
pub struct Server {
    pub router: Arc<Router>,
    pub metrics: Arc<Metrics>,
    handles: Vec<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Start the score and generate workers.
    ///
    /// Workers receive graph *specs*, not executables: executables may not
    /// be Send (the PJRT backend's handles are Rc-based), so each worker
    /// thread builds its own runtime + executable from the spec. The arg
    /// tails (plain data: weights, weightings, thresholds) cross threads
    /// freely.
    pub fn start(
        cfg: ServerConfig,
        fwd_spec: ExecSpec,
        fwd_args_tail: Vec<ArgValue>,
        logits_spec: ExecSpec,
        logits_args_tail: Vec<ArgValue>,
    ) -> Result<Self> {
        let (router, score_rx, gen_rx) = Router::new(cfg.queue_depth);
        let metrics = Arc::new(Metrics::new());
        let mut handles = Vec::new();

        {
            let (cfg, metrics) = (cfg.clone(), metrics.clone());
            handles.push(std::thread::spawn(move || {
                let rt = Runtime::cpu().expect("runtime (score worker)");
                let exe = rt.load_spec(&fwd_spec).expect("load fwd_quant");
                score_worker(cfg, exe, fwd_args_tail, score_rx, metrics)
            }));
        }
        {
            let (cfg, metrics) = (cfg.clone(), metrics.clone());
            handles.push(std::thread::spawn(move || {
                let rt = Runtime::cpu().expect("runtime (gen worker)");
                let exe = rt.load_spec(&logits_spec).expect("load logits_quant");
                generate_worker(cfg, exe, logits_args_tail, gen_rx, metrics)
            }));
        }

        Ok(Server { router: Arc::new(router), metrics, handles })
    }

    /// Close the intake (drop the router) and wait for workers to drain.
    pub fn shutdown(self) {
        let Server { router, handles, .. } = self;
        drop(router);
        for h in handles {
            let _ = h.join();
        }
    }
}

/// Simulated accelerator energy of one forward over `m` token rows:
/// (fgmp_pj, all-fp8 baseline pj).
pub fn batch_energy(shapes: &[LayerProfile], act_fp8: &[f32], m: usize) -> (f64, f64) {
    let dp = DatapathConfig::default();
    let em = EnergyModel::default();
    let mut fgmp = 0.0;
    let mut fp8 = 0.0;
    for (i, p) in shapes.iter().enumerate() {
        let job = MatmulJob {
            m,
            k: p.k,
            n: p.n,
            weight_fp8: p.weight_fp8,
            act_fp8: act_fp8.get(i).copied().unwrap_or(0.0) as f64,
        };
        fgmp += simulate_matmul(&dp, &em, &job, true).total_energy_pj();
        let j8 = MatmulJob { weight_fp8: 1.0, act_fp8: 1.0, ..job };
        let r8 = simulate_matmul(&dp, &em, &j8, true);
        fp8 += r8.total_energy_pj() - em.e_mux_tax * r8.vmacs as f64;
    }
    (fgmp, fp8)
}

fn score_worker(
    cfg: ServerConfig,
    exe: Executable,
    tail: Vec<ArgValue>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    let mut batcher = Batcher::new(cfg.policy.clone(), rx);
    while let Some(mut batch) = batcher.next_batch() {
        batcher.drain_ready(&mut batch);
        let (b, s) = (cfg.batch, cfg.seq);
        let mut tokens = vec![0i32; b * s];
        let mut mask = vec![0.0f32; b * s];
        for (row, req) in batch.iter().enumerate() {
            if let RequestKind::Score { tokens: t, mask: m } = &req.kind {
                let n = t.len().min(s);
                tokens[row * s..row * s + n].copy_from_slice(&t[..n]);
                mask[row * s..row * s + n].copy_from_slice(&m[..n]);
            }
        }
        let mut args = vec![
            ArgValue::I32 { shape: vec![b, s], data: tokens },
            ArgValue::F32 { shape: vec![b, s], data: mask },
        ];
        args.extend(tail.iter().cloned());

        let t0 = Instant::now();
        let out = exe.run(&args);
        let busy = t0.elapsed();

        match out {
            Ok(out) => {
                let (nll, ntok, act_fp8) = (&out[0], &out[1], &out[2]);
                let rows = batch.len();
                let tokens_scored: f64 = ntok.iter().map(|&v| v as f64).sum();
                let (e, e8) = batch_energy(&cfg.layer_shapes, act_fp8, b * s);
                let now = Instant::now();
                let lats: Vec<_> =
                    batch.iter().map(|r| now.duration_since(r.submitted_at)).collect();
                metrics.record_batch(rows, b, tokens_scored, &lats, busy, e, e8);
                for (row, req) in batch.into_iter().enumerate() {
                    let _ = req.reply.send(Response {
                        id: req.id,
                        nll: Some((nll[row] as f64, ntok[row] as f64)),
                        generated: None,
                        latency: now.duration_since(req.submitted_at),
                    });
                }
            }
            Err(_) => {
                for req in batch {
                    let _ = req.reply.send(Response {
                        id: req.id,
                        nll: None,
                        generated: None,
                        latency: req.submitted_at.elapsed(),
                    });
                }
            }
        }
    }
}

fn generate_worker(
    cfg: ServerConfig,
    exe: Executable,
    tail: Vec<ArgValue>,
    rx: Receiver<Request>,
    metrics: Arc<Metrics>,
) {
    // Greedy decode, one request at a time (tiny models; generation is the
    // demo path — scoring is the serving hot path).
    while let Ok(req) = rx.recv() {
        if let RequestKind::Generate { prompt, n_tokens } = &req.kind {
            let (b, s) = (cfg.batch, cfg.seq);
            let mut ctx = prompt.clone();
            let mut produced = Vec::with_capacity(*n_tokens);
            let mut failed = false;
            for _ in 0..*n_tokens {
                // Right-align the context into the fixed window.
                let mut tokens = vec![0i32; b * s];
                let start = ctx.len().saturating_sub(s);
                let window = &ctx[start..];
                let off = s - window.len();
                tokens[off..s].copy_from_slice(window);
                // Other rows stay zero; we read row 0's logits only.
                let mut args = vec![ArgValue::I32 { shape: vec![b, s], data: tokens }];
                args.extend(tail.iter().cloned());
                match exe.run(&args) {
                    Ok(out) => {
                        let vocab = out[0].len() / b;
                        let row0 = &out[0][..vocab];
                        let next = row0
                            .iter()
                            .enumerate()
                            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                            .map(|(i, _)| i as i32)
                            .unwrap_or(0);
                        ctx.push(next);
                        produced.push(next);
                    }
                    Err(_) => {
                        failed = true;
                        break;
                    }
                }
            }
            metrics.record_generated(produced.len() as u64);
            let _ = req.reply.send(Response {
                id: req.id,
                nll: None,
                generated: if failed { None } else { Some(produced) },
                latency: req.submitted_at.elapsed(),
            });
        }
    }
}
