//! Serving metrics: latency percentiles, throughput, and the hwsim energy
//! accounting that turns batch stats into the paper's joules story.

use std::sync::Mutex;
use std::time::Duration;

/// Aggregated serving metrics (thread-safe; one per server).
#[derive(Debug, Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    latencies_us: Vec<u64>,
    batches: u64,
    rows: u64,
    padded_rows: u64,
    tokens_scored: f64,
    generated: u64,
    energy_pj: f64,
    energy_fp8_pj: f64,
    busy: Duration,
    // Decode-loop (continuous batching) accounting.
    ttft_us: Vec<u64>,
    decode_steps: u64,
    decode_rows: u64,
    decode_slot_rows: u64,
    decode_busy: Duration,
    // KV page-pool accounting (paged engines).
    pool_samples: u64,
    pool_total_pages: u64,
    pool_in_use_sum: u64,
    pool_logical_sum: u64,
    pool_deduped_bytes_peak: u64,
    pool_peak_pages: u64,
    kv_slots_used_sum: u64,
    kv_slots_cap_sum: u64,
    deferred_admissions: u64,
    // KV read traffic at stored precision (attention inputs).
    kv_read_tokens: u64,
    kv_bits_weighted: f64,
    // Speculative decoding (draft/verify rounds).
    spec_drafted: u64,
    spec_accepted: u64,
    spec_cooldowns: u64,
    // Robustness: preemption, deadlines, fault recovery.
    preemptions: u64,
    preempt_resumes: u64,
    deadline_rejections: u64,
    batch_retries: u64,
    worker_failures: u64,
    faults_injected: u64,
}

/// A point-in-time snapshot.
#[derive(Debug, Clone)]
pub struct Snapshot {
    pub requests: u64,
    pub batches: u64,
    pub mean_batch_fill: f64,
    pub tokens_scored: f64,
    pub generated_tokens: u64,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    pub mean_ms: f64,
    /// Simulated accelerator energy (J) under the served precision mix.
    pub energy_j: f64,
    /// Same workload on the all-FP8 datapath.
    pub energy_fp8_j: f64,
    pub energy_savings: f64,
    pub executor_busy_s: f64,
    // --- decode loop (continuous batching) ---
    /// Batched decode steps taken.
    pub decode_steps: u64,
    /// Mean live sessions per decode step (batch occupancy, rows).
    pub mean_decode_occupancy: f64,
    /// Occupancy as a fraction of the decode batch capacity.
    pub decode_fill: f64,
    /// Decode-produced tokens (one per live session per step) per second
    /// of decode-loop busy time — prefill-produced first tokens and
    /// prefill time are both excluded.
    pub decode_tok_per_s: f64,
    /// Time-to-first-token: submit → prefilled logits, p50 / p95 (ms).
    pub ttft_p50_ms: f64,
    pub ttft_p95_ms: f64,
    // --- KV page pool (paged engines; zeros on the windowed fallback) ---
    /// Pool capacity in pages.
    pub kv_pool_pages: u64,
    /// High-water pages in use over the run.
    pub kv_pool_peak_pages: u64,
    /// Mean fraction of the pool handed out, sampled once per decode step.
    pub kv_pool_occupancy: f64,
    /// Mean fraction of *allocated* page slots holding live tokens — the
    /// internal-fragmentation complement (1.0 = no page-tail waste).
    pub kv_page_fill: f64,
    /// Mean logical-over-unique page ratio across pool samples: how many
    /// page references each physical page serves on average (1.0 = no
    /// sharing; ≥ 2 when refcounted COW pages — forks, clones, prefix
    /// mappings — let sessions share storage). Zero with no samples.
    pub kv_sharing_factor: f64,
    /// High-water bytes deduplication saved, in MiB: `(logical − unique)`
    /// pages × page bytes at the moment the gap peaked.
    pub kv_deduped_mib_peak: f64,
    /// Admissions deferred because the pool could not hold the session yet.
    pub deferred_admissions: u64,
    /// Token-weighted mean bits/value the attention kernels read from the
    /// KV cache across decode steps — the *stored* precision (FP16, FP8,
    /// or the attention PPU's realized FGMP mix), not the compute width.
    pub kv_read_bits_per_value: f64,
    // --- speculative decoding (zeros on non-speculative engines) ---
    /// Draft tokens proposed through the all-NVFP4 draft view.
    pub spec_drafted: u64,
    /// Drafted tokens the mixed-precision verify pass accepted.
    pub spec_accepted: u64,
    /// Aggregate accept rate (`accepted / drafted`) — a live accuracy
    /// proxy for how closely the all-NVFP4 weight assignment tracks the
    /// served FGMP mix, reported alongside the latency/energy numbers.
    pub spec_accept_rate: f64,
    /// Times the speculative engine disabled drafting for a cooldown
    /// after repeated pool-exhaustion fallbacks (0 on non-speculative
    /// engines or uncontended pools).
    pub spec_cooldowns: u64,
    // --- robustness (zeros on a fault-free, unpressured run) ---
    /// Live sessions preempted under sustained pool pressure (pages
    /// released, request parked for a backed-off bit-exact resume).
    pub preemptions: u64,
    /// Parked requests successfully resumed (each resume re-prefills the
    /// preserved stream context, reusing donated prefix pages when a
    /// prefix index is enabled).
    pub preempt_resumes: u64,
    /// Requests rejected with [`Rejection::DeadlineExceeded`]
    /// (queued, parked, or mid-decode past `--deadline-ms`).
    ///
    /// [`Rejection::DeadlineExceeded`]: super::Rejection::DeadlineExceeded
    pub deadline_rejections: u64,
    /// Prefill/decode batches retried after a transient engine failure
    /// (injected fault or worker panic).
    pub batch_retries: u64,
    /// Tensor-parallel worker panics caught and typed as
    /// `EngineError::WorkerFailed` instead of killing the server.
    pub worker_failures: u64,
    /// Faults fired by the [`util::faults`](crate::util::faults) registry
    /// over the run (0 unless a chaos harness armed it).
    pub faults_injected: u64,
}

impl Metrics {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_batch(
        &self,
        rows: usize,
        capacity: usize,
        tokens: f64,
        latencies: &[Duration],
        busy: Duration,
        energy_pj: f64,
        energy_fp8_pj: f64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.batches += 1;
        m.rows += rows as u64;
        m.padded_rows += (capacity - rows) as u64;
        m.tokens_scored += tokens;
        m.busy += busy;
        m.energy_pj += energy_pj;
        m.energy_fp8_pj += energy_fp8_pj;
        for l in latencies {
            m.latencies_us.push(l.as_micros() as u64);
        }
    }

    pub fn record_generated(&self, n: u64) {
        self.inner.lock().unwrap().generated += n;
    }

    /// A generate request's prompt finished prefill — its first token's
    /// logits exist. `ttft` is measured from request submission.
    pub fn record_ttft(&self, ttft: Duration) {
        self.inner.lock().unwrap().ttft_us.push(ttft.as_micros() as u64);
    }

    /// One batched decode step: `rows` live sessions advanced out of
    /// `capacity` slots in `busy` executor time, costing the simulated
    /// `energy_pj` (vs the all-FP8 `energy_fp8_pj` baseline) including KV
    /// traffic.
    pub fn record_decode_step(
        &self,
        rows: usize,
        capacity: usize,
        busy: Duration,
        energy_pj: f64,
        energy_fp8_pj: f64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.decode_steps += 1;
        m.decode_rows += rows as u64;
        m.decode_slot_rows += capacity as u64;
        m.decode_busy += busy;
        m.energy_pj += energy_pj;
        m.energy_fp8_pj += energy_fp8_pj;
    }

    /// One KV pool sample (taken at admission and after each decode step):
    /// `in_use` unique pages of `total` serving `logical` page references
    /// (`logical ≥ in_use`; the gap is COW sharing worth `deduped_bytes`
    /// of storage), with the pool's exact high-water mark `peak`, plus the
    /// live-token slot fill of the allocated pages (`used_slots` tokens
    /// cached out of `cap_slots` page-slot capacity).
    #[allow(clippy::too_many_arguments)]
    pub fn record_pool(
        &self,
        in_use: usize,
        total: usize,
        logical: usize,
        deduped_bytes: u64,
        peak: usize,
        used_slots: u64,
        cap_slots: u64,
    ) {
        let mut m = self.inner.lock().unwrap();
        m.pool_samples += 1;
        m.pool_total_pages = total as u64;
        m.pool_in_use_sum += in_use as u64;
        m.pool_logical_sum += logical as u64;
        m.pool_deduped_bytes_peak = m.pool_deduped_bytes_peak.max(deduped_bytes);
        m.pool_peak_pages = m.pool_peak_pages.max(peak as u64).max(in_use as u64);
        m.kv_slots_used_sum += used_slots;
        m.kv_slots_cap_sum += cap_slots;
    }

    /// `n` admissions were deferred for lack of KV pages this round.
    pub fn record_deferred(&self, n: u64) {
        self.inner.lock().unwrap().deferred_admissions += n;
    }

    /// One speculative round drafted `drafted` tokens and accepted
    /// `accepted` of them (per
    /// [`StepOut::drafted`](crate::runtime::StepOut) counters); the
    /// running ratio is the serve report's accept rate.
    pub fn record_spec(&self, drafted: u64, accepted: u64) {
        if drafted == 0 {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        m.spec_drafted += drafted;
        m.spec_accepted += accepted;
    }

    /// One decode step read `kv_tokens` cached tokens at a stored width of
    /// `bits_per_value` bits per cached value (token-weighted when the
    /// step's sessions mix precisions).
    pub fn record_kv_traffic(&self, kv_tokens: u64, bits_per_value: f64) {
        if kv_tokens == 0 {
            return;
        }
        let mut m = self.inner.lock().unwrap();
        m.kv_read_tokens += kv_tokens;
        m.kv_bits_weighted += bits_per_value * kv_tokens as f64;
    }

    /// One live session was preempted (pages released, request parked).
    pub fn record_preemption(&self) {
        self.inner.lock().unwrap().preemptions += 1;
    }

    /// One parked request resumed decoding from its preserved context.
    pub fn record_preempt_resume(&self) {
        self.inner.lock().unwrap().preempt_resumes += 1;
    }

    /// One request was rejected for blowing its deadline.
    pub fn record_deadline_rejection(&self) {
        self.inner.lock().unwrap().deadline_rejections += 1;
    }

    /// One prefill/decode batch was retried after a transient failure.
    pub fn record_batch_retry(&self) {
        self.inner.lock().unwrap().batch_retries += 1;
    }

    /// One tensor-parallel worker panic was caught and typed.
    pub fn record_worker_failure(&self) {
        self.inner.lock().unwrap().worker_failures += 1;
    }

    /// `n` more faults fired since the last sample of the failpoint
    /// registry's process-wide counter.
    pub fn record_faults_injected(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.inner.lock().unwrap().faults_injected += n;
    }

    /// `n` more draft-cooldown trips since the last sample of the
    /// speculative engine's counter.
    pub fn record_spec_cooldowns(&self, n: u64) {
        if n == 0 {
            return;
        }
        self.inner.lock().unwrap().spec_cooldowns += n;
    }

    pub fn snapshot(&self) -> Snapshot {
        let m = self.inner.lock().unwrap();
        let mut lats = m.latencies_us.clone();
        lats.sort_unstable();
        let pct_of = |sorted: &[u64], q: f64| -> f64 {
            if sorted.is_empty() {
                return 0.0;
            }
            let i = ((sorted.len() - 1) as f64 * q).round() as usize;
            sorted[i] as f64 / 1000.0
        };
        let pct = |q: f64| pct_of(&lats, q);
        let mean = if lats.is_empty() {
            0.0
        } else {
            lats.iter().sum::<u64>() as f64 / lats.len() as f64 / 1000.0
        };
        let mut ttfts = m.ttft_us.clone();
        ttfts.sort_unstable();
        Snapshot {
            requests: m.rows,
            batches: m.batches,
            mean_batch_fill: if m.batches == 0 {
                0.0
            } else {
                m.rows as f64 / (m.rows + m.padded_rows) as f64
            },
            tokens_scored: m.tokens_scored,
            generated_tokens: m.generated,
            p50_ms: pct(0.50),
            p95_ms: pct(0.95),
            p99_ms: pct(0.99),
            mean_ms: mean,
            energy_j: m.energy_pj * 1e-12,
            energy_fp8_j: m.energy_fp8_pj * 1e-12,
            energy_savings: if m.energy_fp8_pj > 0.0 {
                1.0 - m.energy_pj / m.energy_fp8_pj
            } else {
                0.0
            },
            executor_busy_s: m.busy.as_secs_f64(),
            decode_steps: m.decode_steps,
            mean_decode_occupancy: if m.decode_steps == 0 {
                0.0
            } else {
                m.decode_rows as f64 / m.decode_steps as f64
            },
            decode_fill: if m.decode_slot_rows == 0 {
                0.0
            } else {
                m.decode_rows as f64 / m.decode_slot_rows as f64
            },
            decode_tok_per_s: if m.decode_busy.is_zero() {
                0.0
            } else {
                // Speculative rounds produce their accepted tokens on top
                // of the one-per-row a plain step yields.
                (m.decode_rows + m.spec_accepted) as f64 / m.decode_busy.as_secs_f64()
            },
            ttft_p50_ms: pct_of(&ttfts, 0.50),
            ttft_p95_ms: pct_of(&ttfts, 0.95),
            kv_pool_pages: m.pool_total_pages,
            kv_pool_peak_pages: m.pool_peak_pages,
            kv_pool_occupancy: if m.pool_samples == 0 || m.pool_total_pages == 0 {
                0.0
            } else {
                m.pool_in_use_sum as f64 / (m.pool_samples * m.pool_total_pages) as f64
            },
            kv_page_fill: if m.kv_slots_cap_sum == 0 {
                0.0
            } else {
                m.kv_slots_used_sum as f64 / m.kv_slots_cap_sum as f64
            },
            kv_sharing_factor: if m.pool_in_use_sum == 0 {
                0.0
            } else {
                m.pool_logical_sum as f64 / m.pool_in_use_sum as f64
            },
            kv_deduped_mib_peak: m.pool_deduped_bytes_peak as f64 / (1024.0 * 1024.0),
            deferred_admissions: m.deferred_admissions,
            kv_read_bits_per_value: if m.kv_read_tokens == 0 {
                0.0
            } else {
                m.kv_bits_weighted / m.kv_read_tokens as f64
            },
            spec_drafted: m.spec_drafted,
            spec_accepted: m.spec_accepted,
            spec_accept_rate: if m.spec_drafted == 0 {
                0.0
            } else {
                m.spec_accepted as f64 / m.spec_drafted as f64
            },
            spec_cooldowns: m.spec_cooldowns,
            preemptions: m.preemptions,
            preempt_resumes: m.preempt_resumes,
            deadline_rejections: m.deadline_rejections,
            batch_retries: m.batch_retries,
            worker_failures: m.worker_failures,
            faults_injected: m.faults_injected,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accounting_sums() {
        let m = Metrics::new();
        m.record_batch(6, 8, 600.0, &[Duration::from_millis(10); 6],
                       Duration::from_millis(30), 100.0, 140.0);
        m.record_batch(8, 8, 800.0, &[Duration::from_millis(20); 8],
                       Duration::from_millis(40), 100.0, 140.0);
        let s = m.snapshot();
        assert_eq!(s.requests, 14);
        assert_eq!(s.batches, 2);
        assert!((s.tokens_scored - 1400.0).abs() < 1e-9);
        assert!((s.mean_batch_fill - 14.0 / 16.0).abs() < 1e-9);
        assert!((s.energy_savings - (1.0 - 200.0 / 280.0)).abs() < 1e-9);
        assert!(s.p95_ms >= s.p50_ms);
    }

    #[test]
    fn empty_snapshot_is_zeroes() {
        let s = Metrics::new().snapshot();
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99_ms, 0.0);
        assert_eq!(s.decode_steps, 0);
        assert_eq!(s.mean_decode_occupancy, 0.0);
        assert_eq!(s.decode_tok_per_s, 0.0);
        assert_eq!(s.ttft_p50_ms, 0.0);
        assert_eq!(s.kv_pool_pages, 0);
        assert_eq!(s.kv_pool_occupancy, 0.0);
        assert_eq!(s.kv_page_fill, 0.0);
        assert_eq!(s.kv_sharing_factor, 0.0);
        assert_eq!(s.kv_deduped_mib_peak, 0.0);
        assert_eq!(s.deferred_admissions, 0);
        assert_eq!(s.kv_read_bits_per_value, 0.0);
        assert_eq!(s.spec_drafted, 0);
        assert_eq!(s.spec_accept_rate, 0.0);
        assert_eq!(s.spec_cooldowns, 0);
        assert_eq!(s.preemptions, 0);
        assert_eq!(s.preempt_resumes, 0);
        assert_eq!(s.deadline_rejections, 0);
        assert_eq!(s.batch_retries, 0);
        assert_eq!(s.worker_failures, 0);
        assert_eq!(s.faults_injected, 0);
    }

    #[test]
    fn robustness_counters_accumulate() {
        let m = Metrics::new();
        m.record_preemption();
        m.record_preemption();
        m.record_preempt_resume();
        m.record_deadline_rejection();
        m.record_batch_retry();
        m.record_batch_retry();
        m.record_batch_retry();
        m.record_worker_failure();
        m.record_faults_injected(5);
        m.record_faults_injected(0); // no-op sample
        m.record_spec_cooldowns(2);
        m.record_spec_cooldowns(0); // no-op sample
        let s = m.snapshot();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.preempt_resumes, 1);
        assert_eq!(s.deadline_rejections, 1);
        assert_eq!(s.batch_retries, 3);
        assert_eq!(s.worker_failures, 1);
        assert_eq!(s.faults_injected, 5);
        assert_eq!(s.spec_cooldowns, 2);
    }

    #[test]
    fn spec_accept_rate_aggregates_across_rounds() {
        let m = Metrics::new();
        // Two rounds: 6 drafted / 4 accepted, then 6 / 2 → 6/12 overall.
        m.record_spec(6, 4);
        m.record_spec(6, 2);
        m.record_spec(0, 0); // non-speculative step: ignored
        let s = m.snapshot();
        assert_eq!(s.spec_drafted, 12);
        assert_eq!(s.spec_accepted, 6);
        assert!((s.spec_accept_rate - 0.5).abs() < 1e-9);
    }

    #[test]
    fn accepted_tokens_count_toward_decode_throughput() {
        let m = Metrics::new();
        // One step advancing 2 sessions in 1s that also accepted 3 drafted
        // tokens → 5 decode-produced tokens per second.
        m.record_decode_step(2, 4, Duration::from_secs(1), 10.0, 20.0);
        m.record_spec(6, 3);
        let s = m.snapshot();
        assert!((s.decode_tok_per_s - 5.0).abs() < 1e-9);
    }

    #[test]
    fn kv_traffic_is_token_weighted() {
        let m = Metrics::new();
        // 100 tokens read at FP16, 300 at FP8 → (100·16 + 300·8) / 400.
        m.record_kv_traffic(100, 16.0);
        m.record_kv_traffic(300, 8.0);
        m.record_kv_traffic(0, 4.0); // empty step: ignored
        let s = m.snapshot();
        assert!((s.kv_read_bits_per_value - 10.0).abs() < 1e-9);
    }

    #[test]
    fn pool_accounting_reconciles() {
        let m = Metrics::new();
        // Two samples over a 10-page pool: 4 then 6 unique pages in use
        // (pool high-water 7, seen between samples), serving 8 then 12
        // logical references — COW sharing factor 2 — with the larger
        // sample's dedup gap worth 6 MiB, and live-token slot fill 32/64
        // then 80/96.
        m.record_pool(4, 10, 8, 4 << 20, 4, 32, 64);
        m.record_pool(6, 10, 12, 6 << 20, 7, 80, 96);
        m.record_deferred(3);
        let s = m.snapshot();
        assert_eq!(s.kv_pool_pages, 10);
        assert_eq!(s.kv_pool_peak_pages, 7);
        assert!((s.kv_pool_occupancy - 0.5).abs() < 1e-9);
        assert!((s.kv_page_fill - 112.0 / 160.0).abs() < 1e-9);
        assert!((s.kv_sharing_factor - 2.0).abs() < 1e-9);
        assert!((s.kv_deduped_mib_peak - 6.0).abs() < 1e-9);
        assert_eq!(s.deferred_admissions, 3);
    }

    #[test]
    fn decode_accounting_reconciles() {
        let m = Metrics::new();
        m.record_ttft(Duration::from_millis(4));
        m.record_ttft(Duration::from_millis(8));
        // 3 steps at occupancy 4, 2, 2 of capacity 4 → 8 decode-produced
        // tokens over 2s of decode busy time.
        m.record_decode_step(4, 4, Duration::from_millis(500), 10.0, 20.0);
        m.record_decode_step(2, 4, Duration::from_millis(750), 10.0, 20.0);
        m.record_decode_step(2, 4, Duration::from_millis(750), 10.0, 20.0);
        m.record_generated(8);
        let s = m.snapshot();
        assert_eq!(s.decode_steps, 3);
        assert!((s.mean_decode_occupancy - 8.0 / 3.0).abs() < 1e-9);
        assert!((s.decode_fill - 8.0 / 12.0).abs() < 1e-9);
        assert!((s.decode_tok_per_s - 4.0).abs() < 1e-9);
        assert!(s.ttft_p50_ms >= 4.0 && s.ttft_p95_ms >= s.ttft_p50_ms);
        // Decode energy folds into the shared energy accounting.
        assert!((s.energy_savings - 0.5).abs() < 1e-9);
    }
}
