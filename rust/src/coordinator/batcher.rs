//! Dynamic batcher: packs queued score rows into fixed-shape device batches
//! under a (max size, max wait) policy — the standard dynamic-batching
//! trade-off between padding waste and queueing latency. A deferred queue
//! in front of the channel supports admission backpressure: requests the
//! executor cannot place yet (e.g. the KV page pool is exhausted) are
//! handed back via [`Batcher::defer`] and re-offered, oldest first, before
//! any newer arrival — deferral never reorders.

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use super::router::Request;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Device batch size (the graph's frozen B).
    pub max_batch: usize,
    /// Max time the first queued request may wait before we flush a
    /// partial batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) }
    }
}

/// Collects rows from a queue into batches.
pub struct Batcher {
    pub policy: BatchPolicy,
    rx: Receiver<Request>,
    /// Requests handed back by the executor (admission backpressure),
    /// re-offered ahead of the channel in their original order.
    deferred: VecDeque<Request>,
    /// When each deferred request was *first* deferred. The stamp survives
    /// drain/re-defer bounces (a request that keeps failing admission keeps
    /// aging) and is cleared only by [`Batcher::note_admitted`], so the
    /// executor can bound how long head-of-line bypass may starve a big
    /// request.
    deferred_since: HashMap<u64, Instant>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, rx: Receiver<Request>) -> Self {
        Batcher { policy, rx, deferred: VecDeque::new(), deferred_since: HashMap::new() }
    }

    /// Block for the next batch: returns `None` when the queue is closed
    /// and drained (deferred included). Invariants (exercised by
    /// tests/coordinator_props.rs):
    ///  * 1 <= len <= max_batch
    ///  * arrival order is preserved within and across batches (deferred
    ///    requests are older than anything in the channel)
    ///  * once a request heads the batch, it waits at most ~max_wait.
    /// Deferred requests are already past their wait, so a non-empty
    /// deferred queue yields a batch immediately (topped up with whatever
    /// the channel has ready) rather than blocking.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        if !self.deferred.is_empty() {
            let mut batch = Vec::new();
            self.drain_ready(&mut batch);
            return Some(batch);
        }
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break, // flush partial
                Err(RecvTimeoutError::Disconnected) => break, // flush remnants
            }
        }
        Some(batch)
    }

    /// Drain everything immediately available, up to max_batch (used by the
    /// greedy inner loop when the executor is already hot).
    pub fn drain_ready(&mut self, batch: &mut Vec<Request>) {
        self.drain_ready_capped(batch, self.policy.max_batch)
    }

    /// Drain immediately-available requests until `batch` holds `cap`
    /// entries — the continuous-batching admission path: the decode loop
    /// calls this between steps with `cap = free session slots`, so a
    /// waiting request is picked up within one decode step of capacity
    /// opening (never parked past its deadline while slots are free;
    /// exercised by tests/coordinator_props.rs). Deferred requests go
    /// first — they are the oldest waiting work.
    pub fn drain_ready_capped(&mut self, batch: &mut Vec<Request>, cap: usize) {
        while batch.len() < cap {
            if let Some(req) = self.deferred.pop_front() {
                batch.push(req);
                continue;
            }
            match self.rx.try_recv() {
                Ok(req) => batch.push(req),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }

    /// Hand requests back to the *front* of the queue, preserving their
    /// relative order — the admission-backpressure path: the decode loop
    /// defers admits the KV page pool cannot hold yet and re-drains them,
    /// still FIFO, once retirement frees pages.
    pub fn defer(&mut self, reqs: Vec<Request>) {
        let now = Instant::now();
        for req in reqs.into_iter().rev() {
            self.deferred_since.entry(req.id).or_insert(now);
            self.deferred.push_front(req);
        }
    }

    /// Forget a request's deferral stamp: call when it is finally admitted
    /// (or otherwise resolved — failed, deadline-rejected) so the age map
    /// stays bounded by the number of genuinely waiting requests.
    pub fn note_admitted(&mut self, id: u64) {
        self.deferred_since.remove(&id);
    }

    /// How long the request at the *front* of the deferred queue has been
    /// waiting since it was first deferred. `None` when nothing is parked.
    /// This is the executor's starvation signal: once the head's age passes
    /// the promotion bound, admission reverts to strict head-of-line.
    pub fn head_deferred_age(&self) -> Option<Duration> {
        let head = self.deferred.front()?;
        self.deferred_since.get(&head.id).map(|t| t.elapsed())
    }

    /// Age of a specific deferred request (first-deferral stamp), whether it
    /// is currently parked or mid-bounce in the executor's hands.
    pub fn deferred_age(&self, id: u64) -> Option<Duration> {
        self.deferred_since.get(&id).map(|t| t.elapsed())
    }

    /// Requests currently parked by [`Batcher::defer`].
    pub fn deferred_len(&self) -> usize {
        self.deferred.len()
    }

    /// The oldest parked request, if any — the admission gate inspects it
    /// to avoid pulling work it cannot place yet (head-of-line semantics:
    /// deferral is strictly FIFO, so nothing behind the head may run
    /// before it).
    pub fn peek_deferred(&self) -> Option<&Request> {
        self.deferred.front()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::{Request, RequestKind};
    use std::sync::mpsc::sync_channel;

    fn req(id: u64) -> Request {
        Request::new(id, RequestKind::Score { tokens: vec![0], mask: vec![1.0] }).0
    }

    #[test]
    fn full_batch_when_queue_deep() {
        let (tx, rx) = sync_channel(64);
        for i in 0..20 {
            tx.send(req(i)).unwrap();
        }
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }, rx);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 8);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>(), "order preserved");
    }

    #[test]
    fn partial_batch_on_deadline() {
        let (tx, rx) = sync_channel(64);
        tx.send(req(0)).unwrap();
        tx.send(req(1)).unwrap();
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) }, rx);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn closed_queue_flushes_then_ends() {
        let (tx, rx) = sync_channel(64);
        tx.send(req(0)).unwrap();
        drop(tx);
        let mut b = Batcher::new(BatchPolicy::default(), rx);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn deferred_requests_lead_and_keep_order() {
        let (tx, rx) = sync_channel(64);
        for i in 0..6 {
            tx.send(req(i)).unwrap();
        }
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }, rx);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0, 1, 2, 3]);
        // Executor could only place id 0; 1..3 bounce back.
        let bounced: Vec<Request> = batch.into_iter().skip(1).collect();
        b.defer(bounced);
        assert_eq!(b.deferred_len(), 3);
        // Deferred lead the next drain, ahead of channel ids 4, 5.
        let mut again = Vec::new();
        b.drain_ready_capped(&mut again, 4);
        assert_eq!(again.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        assert_eq!(b.deferred_len(), 0);
        // next_batch with deferred work returns immediately (no blocking).
        b.defer(again);
        drop(tx);
        let flush = b.next_batch().unwrap();
        assert_eq!(flush.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1, 2, 3, 4]);
        let last = b.next_batch().unwrap();
        assert_eq!(last.iter().map(|r| r.id).collect::<Vec<_>>(), vec![5]);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn deferral_stamp_persists_across_bounces_until_admitted() {
        let (tx, rx) = sync_channel(64);
        tx.send(req(7)).unwrap();
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }, rx);
        assert!(b.head_deferred_age().is_none(), "nothing parked yet");
        let batch = b.next_batch().unwrap();
        b.defer(batch);
        let first = b.deferred_age(7).expect("stamped on first defer");
        // Bounce: drain and re-defer. The stamp must survive (same origin
        // instant), so the age only grows.
        std::thread::sleep(Duration::from_millis(2));
        let mut again = Vec::new();
        b.drain_ready_capped(&mut again, 4);
        assert!(b.deferred_age(7).is_some(), "stamp outlives the drain");
        b.defer(again);
        let later = b.deferred_age(7).unwrap();
        assert!(later >= first, "age is monotone across bounces");
        assert!(later >= Duration::from_millis(2));
        assert!(b.head_deferred_age().is_some(), "id 7 heads the deferred queue");
        // Admission clears the stamp.
        let mut fin = Vec::new();
        b.drain_ready_capped(&mut fin, 4);
        b.note_admitted(7);
        assert!(b.deferred_age(7).is_none(), "admitted requests stop aging");
        assert!(b.head_deferred_age().is_none());
    }

    #[test]
    fn drain_ready_caps_at_max_batch() {
        let (tx, rx) = sync_channel(64);
        for i in 0..20 {
            tx.send(req(i)).unwrap();
        }
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }, rx);
        let mut batch = vec![];
        b.drain_ready(&mut batch);
        assert_eq!(batch.len(), 4);
    }
}
