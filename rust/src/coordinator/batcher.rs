//! Dynamic batcher: packs queued score rows into fixed-shape device batches
//! under a (max size, max wait) policy — the standard dynamic-batching
//! trade-off between padding waste and queueing latency.

use std::sync::mpsc::{Receiver, RecvTimeoutError, TryRecvError};
use std::time::{Duration, Instant};

use super::router::Request;

/// Batching policy.
#[derive(Debug, Clone)]
pub struct BatchPolicy {
    /// Device batch size (the graph's frozen B).
    pub max_batch: usize,
    /// Max time the first queued request may wait before we flush a
    /// partial batch.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(20) }
    }
}

/// Collects rows from a queue into batches.
pub struct Batcher {
    pub policy: BatchPolicy,
    rx: Receiver<Request>,
}

impl Batcher {
    pub fn new(policy: BatchPolicy, rx: Receiver<Request>) -> Self {
        Batcher { policy, rx }
    }

    /// Block for the next batch: returns `None` when the queue is closed
    /// and drained. Invariants (exercised by tests/coordinator_props.rs):
    ///  * 1 <= len <= max_batch
    ///  * arrival order is preserved within and across batches
    ///  * once a request heads the batch, it waits at most ~max_wait.
    pub fn next_batch(&mut self) -> Option<Vec<Request>> {
        let first = self.rx.recv().ok()?;
        let mut batch = vec![first];
        let deadline = Instant::now() + self.policy.max_wait;
        while batch.len() < self.policy.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match self.rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => break, // flush partial
                Err(RecvTimeoutError::Disconnected) => break, // flush remnants
            }
        }
        Some(batch)
    }

    /// Drain everything immediately available, up to max_batch (used by the
    /// greedy inner loop when the executor is already hot).
    pub fn drain_ready(&mut self, batch: &mut Vec<Request>) {
        self.drain_ready_capped(batch, self.policy.max_batch)
    }

    /// Drain immediately-available requests until `batch` holds `cap`
    /// entries — the continuous-batching admission path: the decode loop
    /// calls this between steps with `cap = free session slots`, so a
    /// waiting request is picked up within one decode step of capacity
    /// opening (never parked past its deadline while slots are free;
    /// exercised by tests/coordinator_props.rs).
    pub fn drain_ready_capped(&mut self, batch: &mut Vec<Request>, cap: usize) {
        while batch.len() < cap {
            match self.rx.try_recv() {
                Ok(req) => batch.push(req),
                Err(TryRecvError::Empty) | Err(TryRecvError::Disconnected) => break,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::router::{Request, RequestKind};
    use std::sync::mpsc::sync_channel;

    fn req(id: u64) -> Request {
        Request::new(id, RequestKind::Score { tokens: vec![0], mask: vec![1.0] }).0
    }

    #[test]
    fn full_batch_when_queue_deep() {
        let (tx, rx) = sync_channel(64);
        for i in 0..20 {
            tx.send(req(i)).unwrap();
        }
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(5) }, rx);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 8);
        let ids: Vec<u64> = batch.iter().map(|r| r.id).collect();
        assert_eq!(ids, (0..8).collect::<Vec<_>>(), "order preserved");
    }

    #[test]
    fn partial_batch_on_deadline() {
        let (tx, rx) = sync_channel(64);
        tx.send(req(0)).unwrap();
        tx.send(req(1)).unwrap();
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 8, max_wait: Duration::from_millis(10) }, rx);
        let t0 = Instant::now();
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 2);
        assert!(t0.elapsed() < Duration::from_millis(500));
    }

    #[test]
    fn closed_queue_flushes_then_ends() {
        let (tx, rx) = sync_channel(64);
        tx.send(req(0)).unwrap();
        drop(tx);
        let mut b = Batcher::new(BatchPolicy::default(), rx);
        let batch = b.next_batch().unwrap();
        assert_eq!(batch.len(), 1);
        assert!(b.next_batch().is_none());
    }

    #[test]
    fn drain_ready_caps_at_max_batch() {
        let (tx, rx) = sync_channel(64);
        for i in 0..20 {
            tx.send(req(i)).unwrap();
        }
        let mut b = Batcher::new(
            BatchPolicy { max_batch: 4, max_wait: Duration::from_millis(1) }, rx);
        let mut batch = vec![];
        b.drain_ready(&mut batch);
        assert_eq!(batch.len(), 4);
    }
}
