//! The L3 serving coordinator: request router, dynamic batcher, executor
//! worker, and metrics. Requests are scoring (masked NLL, the eval/serving
//! primitive) or generation (iterated last-token logits); both ride the
//! AOT-compiled quantized graphs — python is never on this path.
//!
//! Shape: `Router` fans requests into per-kind queues → `Batcher` packs
//! score rows into fixed-shape device batches under a deadline → a blocking
//! executor thread runs the one-shot executable → responses resolve
//! per-request oneshots. Generation instead runs a continuous-batching
//! decode loop over the stateful `runtime::Engine`: requests are admitted
//! between decode steps (bounded by the engine's shared KV **page pool** —
//! admits the pool cannot hold yet are deferred back to the batcher FIFO,
//! not failed), prefilled **as one batched forward** into paged KV
//! sessions, stepped together, and retired individually — returning their
//! pages to the pool. Energy accounting per batch/step comes from the
//! hwsim model — including KV-cache traffic at the session KV precision —
//! and `Metrics` adds pool occupancy / page fill / deferral counts, so the
//! serving report carries the paper's joules-per-token story plus the
//! arena's utilization.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use router::{Rejection, Request, RequestKind, Response, Router};
pub use server::{
    decode_step_energy, decode_step_energy_tp, kv_dims_from_profiles, Server, ServerConfig,
};
