//! The L3 serving coordinator: request router, dynamic batcher, executor
//! worker, and metrics. Requests are scoring (masked NLL, the eval/serving
//! primitive) or generation (iterated last-token logits); both ride the
//! AOT-compiled quantized graphs — python is never on this path.
//!
//! Shape: `Router` fans requests into per-kind queues → `Batcher` packs
//! rows into fixed-shape device batches under a deadline → a blocking
//! executor thread runs the PJRT executable → responses resolve per-request
//! oneshots. Energy accounting per batch comes from the hwsim model, so the
//! serving report carries the paper's joules-per-token story.

pub mod batcher;
pub mod metrics;
pub mod router;
pub mod server;

pub use batcher::{BatchPolicy, Batcher};
pub use metrics::Metrics;
pub use router::{Request, RequestKind, Response, Router};
pub use server::{Server, ServerConfig};
