//! Downstream-task evaluation (Tables 2–3): 4-way multiple-choice cloze,
//! scored lm-eval style (argmax of mean per-token logprob over the
//! continuation), via the fwd_quant/fwd_ref graphs with continuation masks.

use std::path::Path;

use crate::runtime::{ArgValue, Executable};
use crate::util::Json;
use crate::Result;

/// One task item as emitted by python -m compile.tasks.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub context: Vec<i32>,
    pub options: Vec<Vec<i32>>,
    pub answer: usize,
}

/// A loaded suite.
#[derive(Debug, Clone)]
pub struct TaskSuite {
    pub name: String,
    pub ctx_len: usize,
    pub cont_len: usize,
    pub items: Vec<TaskItem>,
}

impl TaskSuite {
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())?;
        Self::from_json(&text)
    }

    pub fn from_json(text: &str) -> Result<Self> {
        let v = Json::parse(text)?;
        let items = v
            .get("items")?
            .as_arr()?
            .iter()
            .map(|it| {
                Ok(TaskItem {
                    context: it.get("context")?.i32_vec()?,
                    options: it
                        .get("options")?
                        .as_arr()?
                        .iter()
                        .map(|o| o.i32_vec())
                        .collect::<Result<_>>()?,
                    answer: it.get("answer")?.as_usize()?,
                })
            })
            .collect::<Result<_>>()?;
        Ok(TaskSuite {
            name: v.get("name")?.as_str()?.to_string(),
            ctx_len: v.get("ctx_len")?.as_usize()?,
            cont_len: v.get("cont_len")?.as_usize()?,
            items,
        })
    }
}

/// Anything that can run the masked-NLL graph: the PJRT executable in
/// production, a closure in tests (so the packing/masking/argmax logic is
/// unit-testable without artifacts).
pub trait NllRunner {
    fn run_nll(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>>;
}

impl NllRunner for Executable {
    fn run_nll(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        self.run(args)
    }
}

impl<F: Fn(&[ArgValue]) -> Result<Vec<Vec<f32>>>> NllRunner for F {
    fn run_nll(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        self(args)
    }
}

/// Score a suite with a compiled nll graph: each option becomes one row
/// (context ++ option, right-padded), masked so only option tokens score;
/// the predicted answer is the option with the highest mean logprob.
///
/// `arg_tail` is the parameter/weighting/threshold tail from the Evaluator
/// (quant or ref). Returns accuracy in [0,1].
pub fn score_suite(
    exe: &impl NllRunner,
    arg_tail: &[ArgValue],
    suite: &TaskSuite,
    batch: usize,
    seq: usize,
    max_items: usize,
) -> Result<f64> {
    assert!(batch % 4 == 0, "batch must hold whole items (4 options)");
    let items_per_batch = batch / 4;
    let n_items = suite.items.len().min(max_items);
    let mut correct = 0usize;
    let mut scored = 0usize;

    let mut idx = 0;
    while idx < n_items {
        let chunk: Vec<&TaskItem> =
            suite.items[idx..(idx + items_per_batch).min(n_items)].iter().collect();
        idx += chunk.len();

        let mut tokens = vec![0i32; batch * seq];
        let mut mask = vec![0.0f32; batch * seq];
        for (ci, item) in chunk.iter().enumerate() {
            for (oi, opt) in item.options.iter().enumerate() {
                let row = ci * 4 + oi;
                let base = row * seq;
                let clen = item.context.len();
                tokens[base..base + clen].copy_from_slice(&item.context);
                tokens[base + clen..base + clen + opt.len()].copy_from_slice(opt);
                for t in 0..opt.len() {
                    mask[base + clen + t] = 1.0;
                }
            }
        }
        let mut args = vec![
            ArgValue::I32 { shape: vec![batch, seq], data: tokens },
            ArgValue::F32 { shape: vec![batch, seq], data: mask },
        ];
        args.extend(arg_tail.iter().cloned());
        let out = exe.run_nll(&args)?;
        let nll = &out[0];
        let ntok = &out[1];
        for (ci, item) in chunk.iter().enumerate() {
            let mut best = (f64::MAX, 0usize);
            for oi in 0..4 {
                let row = ci * 4 + oi;
                let mean_nll = nll[row] as f64 / (ntok[row] as f64).max(1.0);
                if mean_nll < best.0 {
                    best = (mean_nll, oi);
                }
            }
            if best.1 == item.answer {
                correct += 1;
            }
            scored += 1;
        }
    }
    Ok(correct as f64 / scored.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn item(ctx: &[i32], opts: [&[i32]; 4], answer: usize) -> TaskItem {
        TaskItem {
            context: ctx.to_vec(),
            options: opts.iter().map(|o| o.to_vec()).collect(),
            answer,
        }
    }

    /// Fake runner: nll of a row = sum over masked positions of the token
    /// value (so "smaller tokens" are "more likely"); checks that the row
    /// packing put context+option in the right places.
    fn fake_runner(batch: usize, seq: usize)
        -> impl Fn(&[ArgValue]) -> Result<Vec<Vec<f32>>> {
        move |args: &[ArgValue]| {
            let (tokens, mask) = match (&args[0], &args[1]) {
                (ArgValue::I32 { data: t, .. }, ArgValue::F32 { data: m, .. }) => (t, m),
                _ => anyhow::bail!("bad args"),
            };
            let mut nll = vec![0.0f32; batch];
            let mut ntok = vec![0.0f32; batch];
            for r in 0..batch {
                for s_i in 0..seq {
                    let idx = r * seq + s_i;
                    if mask[idx] > 0.0 {
                        nll[r] += tokens[idx] as f32;
                        ntok[r] += 1.0;
                    }
                }
            }
            Ok(vec![nll, ntok, vec![0.0; 1]])
        }
    }

    #[test]
    fn scoring_picks_lowest_mean_nll_option() {
        // options: [5,5] (mean 5) is the answer; distractors have larger
        // tokens -> larger fake-nll -> correct pick.
        let suite = TaskSuite {
            name: "t".into(),
            ctx_len: 2,
            cont_len: 2,
            items: vec![
                item(&[9, 9], [&[5, 5], &[50, 50], &[60, 60], &[70, 70]], 0),
                item(&[9, 9], [&[80, 80], &[3, 3], &[90, 90], &[99, 99]], 1),
            ],
        };
        let acc = score_suite(&fake_runner(8, 16), &[], &suite, 8, 16, 10).unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn scoring_mean_not_sum() {
        // A longer option with small mean must beat a shorter one with a
        // smaller sum but larger mean (lm-eval length normalization).
        let suite = TaskSuite {
            name: "t".into(),
            ctx_len: 1,
            cont_len: 4,
            items: vec![item(&[1], [&[2, 2, 2, 2], &[3, 0, 0, 0], &[9, 9, 9, 9], &[9, 9, 9, 9]], 0)],
        };
        // option 1 sums to 3 (mean 0.75) vs option 0 sums 8 (mean 2) ->
        // the scorer prefers option 1, which is WRONG here -> acc 0.
        // This documents mean-normalized scoring explicitly.
        let acc = score_suite(&fake_runner(4, 16), &[], &suite, 4, 16, 10).unwrap();
        assert_eq!(acc, 0.0);
    }

    #[test]
    fn context_not_scored() {
        // Huge context tokens must not affect the option ranking.
        let suite = TaskSuite {
            name: "t".into(),
            ctx_len: 3,
            cont_len: 1,
            items: vec![item(&[500, 500, 500], [&[1], &[2], &[3], &[4]], 0)],
        };
        let acc = score_suite(&fake_runner(4, 8), &[], &suite, 4, 8, 10).unwrap();
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn suite_parses() {
        let json = r#"{"name":"t","ctx_len":2,"cont_len":2,
            "items":[{"context":[1,2],"options":[[3,4],[5,6],[7,8],[9,10]],"answer":2}]}"#;
        let s = TaskSuite::from_json(json).unwrap();
        assert_eq!(s.items[0].answer, 2);
        assert_eq!(s.items[0].options.len(), 4);
    }
}
