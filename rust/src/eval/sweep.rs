//! The sweep driver: for each quantization configuration — quantize the
//! weights, evaluate perplexity, and cost the run on the hardware model.
//! This is the engine behind Figs. 1, 5, 6 and 10.


use crate::hwsim::energy::EnergyModel;
use crate::hwsim::layerprof::model_energy_clustered;
use crate::hwsim::memory::fgmp_footprint;
use crate::hwsim::DatapathConfig;
use crate::model::{QuantConfig, QuantizedModel, RatioSpec};
use crate::runtime::{build_engine, EngineOptions, ExecSpec, GraphKind, Runtime, Session};
use crate::Result;

use super::perplexity::Evaluator;

/// One row of a sweep (one point on a figure).
#[derive(Debug, Clone)]
pub struct SweepRow {
    pub label: String,
    pub ppl: f64,
    /// Perplexity degradation vs the BF16 reference (Fig. 1/5 y-axis).
    pub ppl_delta_bf16: f64,
    /// ... and vs the FP8 baseline (the paper's headline "<1%" metric).
    pub ppl_delta_fp8: f64,
    pub weight_fp8: f64,
    pub act_fp8: f64,
    /// Average weight bits/element (packed FGMP).
    pub weight_bits_per_elem: f64,
    /// Compression rate = 16 / average W+A bit width (Fig. 1 x-axis).
    pub compression_rate: f64,
    /// Dot-product energy normalized to the all-FP8 datapath (Fig. 10).
    pub energy_norm: f64,
}

/// Run a list of configs. BF16/FP8 baselines are computed once and shared
/// for the delta columns (both must be present in `configs` or are added).
pub fn run_sweep(
    ev: &Evaluator,
    configs: &[QuantConfig],
    max_batches: usize,
) -> Result<Vec<SweepRow>> {
    let (bf16_cfg, fp8_cfg, _) = Evaluator::baseline_configs();

    let bf16 = ev.perplexity(&bf16_cfg, None, max_batches)?;
    let qm8 = QuantizedModel::quantize(&ev.arts, &fp8_cfg)?;
    let fp8 = ev.perplexity(&fp8_cfg, Some(&qm8), max_batches)?;

    let dp = DatapathConfig::default();
    let em = EnergyModel::default();
    let tokens_per_fwd = ev.batch * ev.seq;

    let mut rows = Vec::with_capacity(configs.len());
    for cfg in configs {
        let row = if matches!(cfg.ratio, RatioSpec::Bf16) {
            SweepRow {
                label: "BF16".into(),
                ppl: bf16.ppl,
                ppl_delta_bf16: 0.0,
                ppl_delta_fp8: bf16.ppl - fp8.ppl,
                weight_fp8: 0.0,
                act_fp8: 0.0,
                weight_bits_per_elem: 16.0,
                compression_rate: 1.0,
                energy_norm: f64::NAN, // no BF16 datapath in the prototype
            }
        } else {
            let qm = QuantizedModel::quantize(&ev.arts, cfg)?;
            let rep = ev.perplexity(cfg, Some(&qm), max_batches)?;
            let profiles = qm.layer_profiles(&ev.arts.manifest, tokens_per_fwd, &rep.act_fp8);
            let energy = model_energy_clustered(&dp, &em, &profiles, 100);

            let w_fp8 = qm.weight_fp8_fraction();
            let mem = fgmp_footprint(ev.arts.manifest.quantized_elements(), w_fp8);
            let w_bits = mem.bits_per_element();
            // Activations: same packed format online (payload+scale+meta).
            let a_fp8 = rep.mean_act_fp8();
            let a_bits = a_fp8 * 8.0 + (1.0 - a_fp8) * 4.5 + 0.0625;
            SweepRow {
                label: cfg.label(),
                ppl: rep.ppl,
                ppl_delta_bf16: rep.ppl - bf16.ppl,
                ppl_delta_fp8: rep.ppl - fp8.ppl,
                weight_fp8: w_fp8,
                act_fp8: a_fp8,
                weight_bits_per_elem: w_bits,
                compression_rate: 16.0 / ((w_bits + a_bits) / 2.0),
                energy_norm: energy.normalized(),
            }
        };
        rows.push(row);
    }
    Ok(rows)
}

/// One point of the speculative-acceptance sweep: a Fisher-policy
/// operating point and the accept rate the self-speculative decoder
/// realizes there. The draft view is always all-NVFP4, so the sweep
/// answers "how far can the target's high-precision fraction drop before
/// the draft stops agreeing with it" — the quality/throughput trade the
/// paper's Fisher policy navigates, seen from the decoder's side.
#[derive(Debug, Clone)]
pub struct AcceptRow {
    pub label: String,
    /// High-precision (FP8) weight-block fraction actually realized.
    pub weight_fp8: f64,
    /// Tokens the draft view proposed across all sessions and rounds.
    pub drafted: u64,
    /// Proposals the target verified and accepted.
    pub accepted: u64,
    /// `accepted / drafted` (0.0 when nothing was drafted).
    pub accept_rate: f64,
}

/// Sweep speculative accept rate over Fisher-policy high-precision
/// fractions: for each `--fp4` fraction, quantize the target, wrap it in
/// the self-speculative engine at draft depth `k`, decode `n_tokens` per
/// session over deterministic corpus prompts, and report how many drafted
/// tokens the target accepted. Streams stay bit-exact to plain decode by
/// construction, so accept rate is purely a throughput statistic.
pub fn run_accept_sweep(
    rt: &Runtime,
    ev: &Evaluator,
    dir: &str,
    model: &str,
    fractions: &[f64],
    k: usize,
    n_tokens: usize,
) -> Result<Vec<AcceptRow>> {
    let spec = ExecSpec::new(dir, model, GraphKind::LogitsQuant);
    let prompt_len = 16.min(ev.test_stream.len().max(1));
    let prompts: Vec<Vec<i32>> = (0..4)
        .map(|i| {
            let off = (i * prompt_len) % ev.test_stream.len().saturating_sub(prompt_len).max(1);
            ev.test_stream[off..off + prompt_len].to_vec()
        })
        .collect();

    let mut rows = Vec::with_capacity(fractions.len());
    for &f in fractions {
        let cfg = QuantConfig::fgmp(f);
        let qm = QuantizedModel::quantize(&ev.arts, &cfg)?;
        let tail = ev.quant_arg_tail(&cfg, &qm)?;
        let engine = build_engine(rt, &spec, tail, EngineOptions::default().spec(Some(k)))?;

        let mut sessions = engine.prefill_batch(&prompts)?;
        // Count emitted tokens (prefill token + accepted + one per round)
        // and retire sessions at their budget, like the serve decode loop.
        let mut produced: Vec<usize> = vec![1; sessions.len()];
        while produced.iter().any(|&n| n < n_tokens) {
            let idx: Vec<usize> =
                (0..sessions.len()).filter(|&i| produced[i] < n_tokens).collect();
            let mut stepping: Vec<&mut Session> = sessions
                .iter_mut()
                .enumerate()
                .filter(|(i, _)| produced[*i] < n_tokens)
                .map(|(_, s)| s)
                .collect();
            engine.decode_step(&mut stepping)?;
            for (slot, &i) in idx.iter().enumerate() {
                produced[i] += stepping[slot].take_accepted().len() + 1;
            }
        }

        let drafted: u64 = sessions.iter().map(|s| s.spec_drafted_total).sum();
        let accepted: u64 = sessions.iter().map(|s| s.spec_accepted_total).sum();
        rows.push(AcceptRow {
            label: cfg.label(),
            weight_fp8: qm.weight_fp8_fraction(),
            drafted,
            accepted,
            accept_rate: if drafted > 0 { accepted as f64 / drafted as f64 } else { 0.0 },
        });
    }
    Ok(rows)
}

/// Pretty-print the accept sweep as an aligned table.
pub fn format_accept_rows(k: usize, rows: &[AcceptRow]) -> String {
    let mut s = format!("speculative accept sweep (k={k}, all-NVFP4 draft view)\n");
    s.push_str(&format!(
        "{:<28} {:>7} {:>9} {:>9} {:>8}\n",
        "config", "W-fp8%", "drafted", "accepted", "accept%"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<28} {:>7.1} {:>9} {:>9} {:>8.1}\n",
            r.label,
            r.weight_fp8 * 100.0,
            r.drafted,
            r.accepted,
            r.accept_rate * 100.0
        ));
    }
    s
}

/// Pretty-print rows as the aligned table the benches emit.
pub fn format_rows(rows: &[SweepRow]) -> String {
    let mut s = String::new();
    s.push_str(&format!(
        "{:<28} {:>8} {:>9} {:>9} {:>7} {:>7} {:>7} {:>7} {:>8}\n",
        "config", "ppl", "dPPL/bf16", "dPPL/fp8", "W-fp8%", "A-fp8%", "bits/w", "comp", "E/fp8"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<28} {:>8.4} {:>9.4} {:>9.4} {:>7.1} {:>7.1} {:>7.3} {:>7.2} {:>8.3}\n",
            r.label,
            r.ppl,
            r.ppl_delta_bf16,
            r.ppl_delta_fp8,
            r.weight_fp8 * 100.0,
            r.act_fp8 * 100.0,
            r.weight_bits_per_elem,
            r.compression_rate,
            r.energy_norm,
        ));
    }
    s
}
