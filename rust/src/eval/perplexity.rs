//! Perplexity evaluation through the fwd_quant / fwd_ref graphs (native or
//! PJRT — the evaluator is backend-agnostic via [`ExecSpec`]).

use std::path::Path;

use crate::io::TensorFile;
use crate::model::{ModelArtifacts, QuantConfig, QuantizedModel, RatioSpec};
use crate::policy::Policy;
use crate::runtime::{ArgValue, ExecSpec, Executable, GraphKind, Runtime};
use crate::Result;

/// Result of one perplexity run.
#[derive(Debug, Clone)]
pub struct PerplexityReport {
    pub ppl: f64,
    pub nll_sum: f64,
    pub tokens: f64,
    /// Mean per-linear activation FP8 block fraction (from the in-graph PPU
    /// counters), empty for the fwd_ref path.
    pub act_fp8: Vec<f64>,
    pub batches: usize,
}

impl PerplexityReport {
    pub fn mean_act_fp8(&self) -> f64 {
        if self.act_fp8.is_empty() {
            return 0.0;
        }
        self.act_fp8.iter().sum::<f64>() / self.act_fp8.len() as f64
    }
}

/// Drives the compiled graphs for one model.
pub struct Evaluator {
    pub arts: ModelArtifacts,
    pub fwd_quant: Executable,
    pub fwd_ref: Executable,
    pub test_stream: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
}

impl Evaluator {
    /// Load artifacts + materialize graphs for `model` under `artifacts_dir`.
    pub fn load(rt: &Runtime, artifacts_dir: impl AsRef<Path>, model: &str) -> Result<Self> {
        let dir = artifacts_dir.as_ref();
        let arts = ModelArtifacts::load(dir.join(model))?;
        let fwd_quant = rt.load_spec(&ExecSpec::new(dir, model, GraphKind::FwdQuant))?;
        let fwd_ref = rt.load_spec(&ExecSpec::new(dir, model, GraphKind::FwdRef))?;
        let corpus = TensorFile::load(dir.join("corpus.fgtn"))?;
        let test_stream = corpus.get("test")?.as_i32()?.to_vec();
        let (batch, seq) = (arts.manifest.batch, arts.manifest.seq);
        Ok(Evaluator { arts, fwd_quant, fwd_ref, test_stream, batch, seq })
    }

    /// The non-tokens argument tail of the fwd_quant graph for a config:
    /// params (quantized weights substituted **in packed execution form**),
    /// act weightings, thresholds. The native backend runs the packed bits
    /// directly; PJRT materializes them at literal conversion.
    pub fn quant_arg_tail(&self, cfg: &QuantConfig, qm: &QuantizedModel) -> Result<Vec<ArgValue>> {
        let m = &self.arts.manifest;
        let mut args = Vec::with_capacity(m.param_names.len() + m.num_linears + 1);
        // Parameters in manifest order, with each linear's weight replaced
        // by its packed FGMP tensor (Arc-shared — tail clones stay cheap).
        for name in &m.param_names {
            let shape = m.param_shapes[name].clone();
            if let Some(qlin) = name
                .strip_suffix(".w")
                .and_then(|base| qm.linears.iter().find(|l| l.name == base))
            {
                args.push(ArgValue::PackedW { shape, panels: qlin.panels.clone() });
            } else {
                let data = self.arts.weights.get(name)?.as_f32()?.to_vec();
                args.push(ArgValue::F32 { shape, data });
            }
        }
        // Per-linear activation channel weightings for the PPU score.
        for spec in &m.linears {
            let w = self.arts.act_weighting(&spec.name, cfg.policy)?;
            args.push(ArgValue::vec_f32(w));
        }
        // Per-linear thresholds.
        args.push(ArgValue::vec_f32(self.arts.act_thresholds(cfg)?));
        Ok(args)
    }

    /// Argument tail for fwd_ref (raw parameters only).
    pub fn ref_arg_tail(&self) -> Result<Vec<ArgValue>> {
        let m = &self.arts.manifest;
        m.param_names
            .iter()
            .map(|name| {
                Ok(ArgValue::F32 {
                    shape: m.param_shapes[name].clone(),
                    data: self.arts.weights.get(name)?.as_f32()?.to_vec(),
                })
            })
            .collect()
    }

    /// fwd_ref tail with FGMP-quantized weights substituted: *weight-only*
    /// quantization with BF16 activations (paper Table 1 regime). Weights
    /// travel packed here too — the unquantized graph multiplies them the
    /// same way, just without the PPU on the activation side.
    pub fn ref_arg_tail_with(&self, qm: &QuantizedModel) -> Result<Vec<ArgValue>> {
        let m = &self.arts.manifest;
        m.param_names
            .iter()
            .map(|name| {
                if let Some(qlin) = name
                    .strip_suffix(".w")
                    .and_then(|base| qm.linears.iter().find(|l| l.name == base))
                {
                    return Ok(ArgValue::PackedW {
                        shape: m.param_shapes[name].clone(),
                        panels: qlin.panels.clone(),
                    });
                }
                let data = self.arts.weights.get(name)?.as_f32()?.to_vec();
                Ok(ArgValue::F32 { shape: m.param_shapes[name].clone(), data })
            })
            .collect()
    }

    /// Weight-only perplexity: quantized weights through the unquantized
    /// (BF16-activation) graph.
    pub fn perplexity_weight_only(&self, qm: &QuantizedModel, max_batches: usize)
                                  -> Result<PerplexityReport> {
        let tail = self.ref_arg_tail_with(qm)?;
        self.run_nll(&self.fwd_ref, &tail, max_batches, false)
    }

    /// Deterministic non-overlapping eval windows over the test stream.
    pub fn eval_windows(&self, max_batches: usize) -> Vec<Vec<i32>> {
        let n_windows = (self.test_stream.len() - 1) / self.seq;
        let n_batches = (n_windows / self.batch).min(max_batches);
        (0..n_batches)
            .map(|b| {
                let mut toks = Vec::with_capacity(self.batch * self.seq);
                for r in 0..self.batch {
                    let off = (b * self.batch + r) * self.seq;
                    toks.extend_from_slice(&self.test_stream[off..off + self.seq]);
                }
                toks
            })
            .collect()
    }

    /// Perplexity of a quantization config (BF16 routes to fwd_ref).
    pub fn perplexity(&self, cfg: &QuantConfig, qm: Option<&QuantizedModel>,
                      max_batches: usize) -> Result<PerplexityReport> {
        let is_bf16 = matches!(cfg.ratio, RatioSpec::Bf16);
        let tail = if is_bf16 {
            self.ref_arg_tail()?
        } else {
            self.quant_arg_tail(cfg, qm.expect("quantized model required"))?
        };
        let exe = if is_bf16 { &self.fwd_ref } else { &self.fwd_quant };
        self.run_nll(exe, &tail, max_batches, !is_bf16)
    }

    /// Shared NLL loop over the deterministic eval windows.
    pub fn run_nll(&self, exe: &Executable, tail: &[ArgValue], max_batches: usize,
                   has_fracs: bool) -> Result<PerplexityReport> {
        let mask = vec![1.0f32; self.batch * self.seq];
        let mut nll_sum = 0.0f64;
        let mut tok_sum = 0.0f64;
        let nl = self.arts.manifest.num_linears;
        let mut frac_sum = vec![0.0f64; nl];
        let windows = self.eval_windows(max_batches);
        let batches = windows.len();
        anyhow::ensure!(batches > 0, "test stream too short for one batch");
        for toks in windows {
            let mut args = vec![
                ArgValue::I32 { shape: vec![self.batch, self.seq], data: toks },
                ArgValue::F32 { shape: vec![self.batch, self.seq], data: mask.clone() },
            ];
            args.extend(tail.iter().cloned());
            let out = exe.run(&args)?;
            nll_sum += out[0].iter().map(|&v| v as f64).sum::<f64>();
            tok_sum += out[1].iter().map(|&v| v as f64).sum::<f64>();
            if has_fracs {
                for (i, &f) in out[2].iter().enumerate() {
                    frac_sum[i] += f as f64;
                }
            }
        }
        Ok(PerplexityReport {
            ppl: (nll_sum / tok_sum).exp(),
            nll_sum,
            tokens: tok_sum,
            act_fp8: if has_fracs {
                frac_sum.iter().map(|f| f / batches as f64).collect()
            } else {
                vec![]
            },
            batches,
        })
    }

    /// Convenience: the standard baselines used all over the figures.
    pub fn baseline_configs() -> (QuantConfig, QuantConfig, QuantConfig) {
        (
            QuantConfig { ratio: RatioSpec::Bf16, policy: Policy::Fisher,
                          threshold_mode: crate::policy::ThresholdMode::Global, sw_clip: false },
            QuantConfig::all_fp8(),
            QuantConfig::all_fp4(),
        )
    }
}
