//! Evaluation harness: perplexity (the paper's Wikitext-103 metric on our
//! tiny-corpus substrate), downstream 4-way cloze suites (Tables 2–3), and
//! the ratio/policy sweep driver behind Figs. 1, 5, 6 and 10.

pub mod perplexity;
pub mod sweep;
pub mod tasks;

pub use perplexity::{Evaluator, PerplexityReport};
pub use sweep::{run_accept_sweep, run_sweep, AcceptRow, SweepRow};
pub use tasks::{score_suite, TaskSuite};
