//! Tensor-parallel multi-worker serving: [`ShardedEngine`] and the engine
//! surface both engines share ([`InferenceEngine`]).
//!
//! A sharded engine carves one loaded model across `workers` in-process
//! workers (a [`ThreadCollective`] of scoped threads — the
//! [`Collective`](crate::model::tp::Collective) boundary keeps the door open
//! for process/RPC transports later):
//!
//!  * every packed linear is **column-sharded along its NR-panel axis** —
//!    contiguous byte ranges of the stored FGMP payload
//!    ([`crate::quant::PackedPanels::panel_range`]), no re-pack, no decode —
//!    and the per-worker partial products are recombined by fixed-order
//!    concatenation of disjoint column blocks (pure data movement, never
//!    floating-point summation);
//!  * attention is **head-sharded**: each worker owns a head-slice of the KV
//!    state backed by its *own* page pool at shard width, so KV reads,
//!    page accounting, and the attention PPU all run per worker exactly as
//!    they do on the single engine.
//!
//! Both splits keep every dot product whole on exactly one worker, which is
//! the determinism guarantee: logits — and therefore greedy decode streams —
//! are **bit-for-bit identical** to the single-worker [`Engine`] at any
//! worker count (property-tested in `tests/decode_props.rs`).
//!
//! [`build_engine`] is the one entry point callers should use: it returns a
//! boxed [`InferenceEngine`] — an [`Engine`] for `workers <= 1`, a
//! [`ShardedEngine`] otherwise — so the coordinator's generate worker and
//! the CLI drive either engine through the same surface.

use std::sync::Arc;

use crate::model::forward::{
    forward_extend_batch_tp, forward_prefill_batch_tp, forward_step_batch_tp, ForwardOut,
    ModelArch, Params, QuantInputs,
};
use crate::model::kv::{KvPool, KvPoolStats, KvPrecision, KvState};
use crate::model::tp::{shard_arch, Collective, ShardPlan, ThreadCollective};
use crate::model::WeightMemory;
use crate::util::faults;
use crate::{Result, BLOCK};

use super::args::ArgValue;
use super::engine::{
    params_map, params_weight_memory, parse_tail, ParamData, DEFAULT_POOL_SESSIONS,
};
use super::error::{catch_worker, EngineError};
use super::prefix::PrefixIndexStats;
use super::{Engine, EngineOptions, ExecSpec, Executable, GraphKind, Runtime, Session, StepOut};

/// The engine surface the serving stack programs against, implemented by
/// the single-worker [`Engine`] and the tensor-parallel [`ShardedEngine`].
///
/// Object-safe on purpose: the coordinator's generate worker and the
/// `fgmp generate` CLI hold a `Box<dyn InferenceEngine>` from
/// [`build_engine`] and never know which concrete engine they drive.
pub trait InferenceEngine {
    /// The model architecture.
    fn arch(&self) -> &ModelArch;

    /// Whether sessions run a KV-cached incremental path (vs windowed
    /// recompute).
    fn is_cached(&self) -> bool;

    /// KV storage precision of new sessions.
    fn kv_precision(&self) -> KvPrecision;

    /// Tensor-parallel worker count (1 on the single-worker engine).
    fn workers(&self) -> usize;

    /// Run one prompt to completion; the returned session's logits already
    /// predict the first generated token.
    fn prefill(&self, prompt: &[i32]) -> Result<Session>;

    /// Prefill many prompts as one batched forward (bit-identical to
    /// [`InferenceEngine::prefill`] one at a time).
    fn prefill_batch(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>>;

    /// Advance every session by one token in a single batched forward.
    fn decode_step(&self, sessions: &mut [&mut Session]) -> Result<StepOut>;

    /// Resident weight-memory accounting of the loaded model.
    fn weight_memory(&self) -> WeightMemory;

    /// Live accounting of the engine's KV page pool (`None` when the
    /// engine holds no cache). On a sharded engine every worker's pool has
    /// identical capacity and identical page usage — page counts depend on
    /// layers and tokens, not row width — so worker 0's stats stand for
    /// the fleet.
    fn pool_stats(&self) -> Option<KvPoolStats>;

    /// Worst-case pages one session can ever hold (per worker pool on a
    /// sharded engine — every pool sees the same count).
    fn kv_pages_per_session(&self) -> usize;

    /// Sessions the pool sustains at worst case (coarse admission bound).
    fn max_live_sessions(&self) -> usize;

    /// Sound per-request worst-case page bound for admission control.
    fn kv_pages_worst_for(&self, prompt_len: usize, want: usize) -> usize;

    /// Speculative chain length `k` (`None` on non-speculative engines —
    /// the default).
    fn spec_k(&self) -> Option<usize> {
        None
    }

    /// Resident bytes of the all-NVFP4 draft weight view a speculative
    /// engine holds alongside the packed target weights (`None` on
    /// non-speculative engines). The serve report prints this next to the
    /// packed-vs-f32 accounting so the extra draft copy is visible.
    fn spec_draft_bytes(&self) -> Option<u64> {
        None
    }

    /// Prefix-sharing index counters (`None` when no index is enabled —
    /// the default; today only the single-worker cached [`Engine`] built
    /// with [`EngineOptions::prefix_share`] carries one).
    fn prefix_stats(&self) -> Option<PrefixIndexStats> {
        None
    }

    /// Prompt-aware admission bound: like
    /// [`InferenceEngine::kv_pages_worst_for`] but may discount whole KV
    /// pages the engine's prefix index already holds for this exact
    /// prompt — prefill maps those (shared, append-only, never COW-copied)
    /// instead of allocating them. Callers charging the discounted bound
    /// must budget the index's held pages separately
    /// ([`PrefixIndexStats::pages_held`]). Defaults to the length-based
    /// bound.
    fn kv_pages_worst_for_prompt(&self, prompt: &[i32], want: usize) -> usize {
        self.kv_pages_worst_for(prompt.len(), want)
    }

    /// Donate a session's cache to the engine's prefix index just before
    /// preempting it, so the request's eventual resume maps the
    /// already-computed prefix back in by reference instead of
    /// re-prefilling it. Returns whether anything was registered; `false`
    /// (the default — no index) is never an error, resume then recomputes
    /// the prefix and the stream stays bit-exact either way.
    fn preempt_donate(&self, _sess: &Session) -> bool {
        false
    }

    /// Cooldown windows a speculative engine has entered after repeated
    /// draft-fork exhaustion fallbacks (`None` on non-speculative engines
    /// — the default). The serve report surfaces this next to the accept
    /// rate.
    fn spec_cooldowns(&self) -> Option<u64> {
        None
    }
}

impl InferenceEngine for Engine {
    fn arch(&self) -> &ModelArch {
        Engine::arch(self)
    }
    fn is_cached(&self) -> bool {
        Engine::is_cached(self)
    }
    fn kv_precision(&self) -> KvPrecision {
        Engine::kv_precision(self)
    }
    fn workers(&self) -> usize {
        1
    }
    fn prefill(&self, prompt: &[i32]) -> Result<Session> {
        Engine::prefill(self, prompt)
    }
    fn prefill_batch(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>> {
        Engine::prefill_batch(self, prompts)
    }
    fn decode_step(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        Engine::decode_step(self, sessions)
    }
    fn weight_memory(&self) -> WeightMemory {
        Engine::weight_memory(self)
    }
    fn pool_stats(&self) -> Option<KvPoolStats> {
        Engine::pool_stats(self)
    }
    fn kv_pages_per_session(&self) -> usize {
        Engine::kv_pages_per_session(self)
    }
    fn max_live_sessions(&self) -> usize {
        Engine::max_live_sessions(self)
    }
    fn kv_pages_worst_for(&self, prompt_len: usize, want: usize) -> usize {
        Engine::kv_pages_worst_for(self, prompt_len, want)
    }
    fn prefix_stats(&self) -> Option<PrefixIndexStats> {
        Engine::prefix_stats(self)
    }
    fn kv_pages_worst_for_prompt(&self, prompt: &[i32], want: usize) -> usize {
        Engine::kv_pages_worst_for_prompt(self, prompt, want)
    }
    fn preempt_donate(&self, sess: &Session) -> bool {
        Engine::preempt_donate(self, sess)
    }
}

/// The tensor-parallel engine: one model, `world` workers, per-worker KV
/// pools at shard width. Always the cached native path — there is no
/// windowed fallback to shard.
pub struct ShardedEngine<C: Collective = ThreadCollective> {
    arch: ModelArch,
    params: Vec<(String, ParamData)>,
    act_weights: Vec<Vec<f32>>,
    thresholds: Vec<f32>,
    kv: KvPrecision,
    attn_threshold: Option<f32>,
    plan: ShardPlan,
    /// One arch per *active* worker (workers owning >= 1 attention head).
    shard_arches: Vec<ModelArch>,
    /// One page pool per active worker, at that worker's shard width. Same
    /// page count each — page geometry depends on layers/tokens, not width
    /// — so total KV memory across pools matches the single-engine pool.
    pools: Vec<Arc<KvPool>>,
    coll: C,
}

impl ShardedEngine<ThreadCollective> {
    /// Build a sharded engine over the in-process thread transport. Same
    /// spec/tail contract as [`Engine::with_options`]; requires the native
    /// backend (there is nothing to shard inside an opaque executable).
    pub fn with_options(
        rt: &Runtime,
        spec: &ExecSpec,
        tail: Vec<ArgValue>,
        opts: EngineOptions,
    ) -> Result<Self> {
        let world = opts.workers.max(1);
        Self::with_collective(rt, spec, tail, opts, ThreadCollective { world })
    }
}

impl<C: Collective> ShardedEngine<C> {
    /// Build over an explicit transport (the seam a process/RPC-backed
    /// [`Collective`] slots into).
    pub fn with_collective(
        rt: &Runtime,
        spec: &ExecSpec,
        tail: Vec<ArgValue>,
        opts: EngineOptions,
        coll: C,
    ) -> Result<Self> {
        anyhow::ensure!(
            spec.kind == GraphKind::LogitsQuant,
            "ShardedEngine drives the logits_quant graph, got {:?}",
            spec.kind
        );
        anyhow::ensure!(!opts.windowed, "the windowed fallback cannot be sharded");
        let world = opts.workers.max(1);
        anyhow::ensure!(
            coll.world() == world,
            "collective world {} != requested workers {world}",
            coll.world()
        );
        let exe = rt.load_spec(spec)?;
        let g = match exe {
            Executable::Native(g) => g,
            #[cfg(feature = "pjrt")]
            Executable::Pjrt(_) => {
                anyhow::bail!("sharded serving requires the native backend")
            }
        };
        let (params, act_weights, thresholds) = parse_tail(g.manifest(), &tail)?;
        let arch = g.arch().clone();
        let plan = ShardPlan::new(&arch, world)?;
        let shard_arches: Vec<ModelArch> = plan
            .heads
            .iter()
            .filter(|(h0, h1)| h1 > h0)
            .map(|&(h0, h1)| shard_arch(&arch, h0, h1))
            .collect();
        if opts.attn_threshold.is_some() {
            // Fail at construction, not at the first prefill: the attention
            // PPU quantizes 16-wide blocks, so every active worker's column
            // range must start on a block boundary.
            let dh = arch.head_dim();
            for (w, &(h0, _)) in plan.heads.iter().take(shard_arches.len()).enumerate() {
                anyhow::ensure!(
                    (h0 * dh) % BLOCK == 0,
                    "attention PPU requires worker boundaries on {BLOCK}-wide blocks; worker \
                     {w} would start at column {} — pick a worker count whose head split lands \
                     on block boundaries",
                    h0 * dh
                );
            }
        }
        let pages = opts.kv_pages.unwrap_or_else(|| {
            DEFAULT_POOL_SESSIONS * KvPool::pages_for_session(arch.n_layers, arch.max_seq)
        });
        let pools: Vec<Arc<KvPool>> =
            shard_arches.iter().map(|sa| KvPool::new(sa, opts.kv, pages)).collect();
        Ok(ShardedEngine {
            arch,
            params,
            act_weights,
            thresholds,
            kv: opts.kv,
            attn_threshold: opts.attn_threshold,
            plan,
            shard_arches,
            pools,
            coll,
        })
    }

    fn param_map(&self) -> Params<'_> {
        params_map(&self.params)
    }

    fn quant_inputs(&self) -> QuantInputs<'_> {
        QuantInputs {
            act_weights: self.act_weights.iter().map(|v| v.as_slice()).collect(),
            thresholds: &self.thresholds,
            attn_threshold: self.attn_threshold,
        }
    }

    /// Fresh per-worker KV shards for one new session; page reservations
    /// happen inside prefill, and dropping the shards releases them.
    fn new_shards(&self) -> Vec<KvState> {
        self.shard_arches
            .iter()
            .zip(&self.pools)
            .map(|(sa, pool)| KvState::new_paged(sa, pool))
            .collect()
    }

    fn prefill_batch_impl(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>> {
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        if faults::should_fail(faults::ENGINE_PREFILL) {
            return Err(EngineError::Injected { point: faults::ENGINE_PREFILL }.into());
        }
        let kept: Vec<&[i32]> = prompts
            .iter()
            .map(|p| {
                if p.is_empty() {
                    &[0i32][..]
                } else {
                    &p[p.len() - p.len().min(self.arch.max_seq)..]
                }
            })
            .collect();
        let mut shards_owned: Vec<Vec<KvState>> =
            (0..kept.len()).map(|_| self.new_shards()).collect();
        let pm = self.param_map();
        let quant = self.quant_inputs();
        let out = {
            let mut kv_refs: Vec<Vec<&mut KvState>> =
                shards_owned.iter_mut().map(|s| s.iter_mut().collect()).collect();
            // On error shards_owned drops → reserved pages released;
            // catch_worker turns a panicked worker into the typed
            // WorkerFailed the coordinator retries on.
            catch_worker(|| {
                forward_prefill_batch_tp(
                    &self.arch,
                    &self.shard_arches,
                    &self.plan,
                    &pm,
                    &self.coll,
                    &kept,
                    Some(&quant),
                    &mut kv_refs,
                )
            })?
        };
        let vocab = self.arch.vocab;
        Ok(shards_owned
            .into_iter()
            .enumerate()
            .map(|(i, shards)| Session {
                tokens: kept[i].to_vec(),
                last_logits: out.logits[i * vocab..(i + 1) * vocab].to_vec(),
                steps: 0,
                kv: None,
                kv_shards: shards,
                spec_accepted: Vec::new(),
                spec_drafted_total: 0,
                spec_accepted_total: 0,
            })
            .collect())
    }

    fn decode_step_impl(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        if sessions.is_empty() {
            return Ok(StepOut::default());
        }
        // Same failpoint placement as the single-worker engine: before any
        // session mutation, so injected failures are retryable as-is.
        if faults::should_fail(faults::ENGINE_DECODE) {
            return Err(EngineError::Injected { point: faults::ENGINE_DECODE }.into());
        }
        if faults::should_fail(faults::ENGINE_SLOW) {
            std::thread::sleep(std::time::Duration::from_millis(faults::SLOW_STEP_MS));
        }
        let active = self.shard_arches.len();
        // Validate and roll before consuming any token, mirroring the
        // single-worker engine's step semantics exactly.
        for (i, sess) in sessions.iter().enumerate() {
            anyhow::ensure!(
                sess.kv.is_none() && sess.kv_shards.len() == active,
                "session {i} was not prefilled on this sharded engine"
            );
        }
        let pm = self.param_map();
        let quant = self.quant_inputs();
        let w = (self.arch.max_seq / 2).max(1);
        let mut roll_idx: Vec<usize> = Vec::new();
        let mut roll_prompts: Vec<Vec<i32>> = Vec::new();
        for (i, sess) in sessions.iter().enumerate() {
            if sess.kv_shards[0].len() >= self.arch.max_seq {
                roll_idx.push(i);
                roll_prompts.push(sess.tokens[sess.tokens.len().saturating_sub(w)..].to_vec());
            }
        }
        if !roll_idx.is_empty() {
            // Rebuild rolled caches in FRESH per-worker shards and swap on
            // success, exactly like the single-worker roll: a mid-roll
            // failure (exhaustion, injected fault, worker panic) leaves
            // every live shard bit-identical to its pre-roll state and the
            // partial rebuild's pages release when `fresh` drops.
            let mut fresh: Vec<Vec<KvState>> =
                roll_idx.iter().map(|_| self.new_shards()).collect();
            {
                let mut kv_refs: Vec<Vec<&mut KvState>> =
                    fresh.iter_mut().map(|s| s.iter_mut().collect()).collect();
                let prompts: Vec<&[i32]> = roll_prompts.iter().map(|p| p.as_slice()).collect();
                catch_worker(|| {
                    forward_prefill_batch_tp(
                        &self.arch,
                        &self.shard_arches,
                        &self.plan,
                        &pm,
                        &self.coll,
                        &prompts,
                        Some(&quant),
                        &mut kv_refs,
                    )
                })?;
            }
            for ((&i, kept), shards) in roll_idx.iter().zip(roll_prompts).zip(fresh) {
                sessions[i].tokens = kept;
                sessions[i].kv_shards = shards;
            }
        }
        let inputs: Vec<i32> = sessions.iter().map(|s| s.next_token()).collect();
        for (sess, &t) in sessions.iter_mut().zip(&inputs) {
            sess.tokens.push(t);
        }
        let pre_lens: Vec<usize> = sessions.iter().map(|s| s.cached_tokens()).collect();
        let mut kvs: Vec<Vec<&mut KvState>> =
            sessions.iter_mut().map(|s| s.kv_shards.iter_mut().collect()).collect();
        let out = match catch_worker(|| {
            forward_step_batch_tp(
                &self.arch,
                &self.shard_arches,
                &self.plan,
                &pm,
                &self.coll,
                &inputs,
                &mut kvs,
                Some(&quant),
            )
        }) {
            Ok(out) => out,
            Err(e) => {
                // Restore every session's pre-step state: pop the consumed
                // input and trim any physical rows the failed forward (or a
                // panicked worker's surviving peers) appended past the
                // un-advanced length, returning their pages — the step is
                // then safe to retry.
                for (sess, &len) in sessions.iter_mut().zip(&pre_lens) {
                    sess.tokens.pop();
                    for kv in sess.kv_shards.iter_mut() {
                        kv.truncate(len);
                    }
                }
                return Err(e);
            }
        };
        let vocab = self.arch.vocab;
        let mut kv_tokens = 0u64;
        for (i, sess) in sessions.iter_mut().enumerate() {
            sess.last_logits = out.logits[i * vocab..(i + 1) * vocab].to_vec();
            sess.steps += 1;
            kv_tokens += sess.cached_tokens() as u64;
        }
        // Per-worker KV traffic: each worker attends over the same token
        // count at its own shard width and its own realized precision mix.
        // The mix is reported per worker so energy accounting can price each
        // worker's traffic at its own stored width — a token-weighted
        // average across shards would misprice mixed-precision shards.
        let d = self.arch.d_model as f64;
        let mut kv_mix: Vec<(usize, f64)> = Vec::with_capacity(active);
        let mut global = 0.0f64;
        for (wi, sa) in self.shard_arches.iter().enumerate() {
            let mut weighted = 0.0f64;
            for sess in sessions.iter() {
                let t = sess.cached_tokens() as u64;
                weighted += sess.kv_shards[wi].effective_kv_bits() * t as f64;
            }
            let bits_w = if kv_tokens > 0 {
                weighted / kv_tokens as f64
            } else {
                self.kv.bits_per_value()
            };
            kv_mix.push((sa.d_model, bits_w));
            global += bits_w * sa.d_model as f64 / d;
        }
        let kv_bits_per_value = if kv_tokens > 0 { global } else { self.kv.bits_per_value() };
        Ok(StepOut {
            rows: sessions.len(),
            act_fp8: out.act_fp8,
            kv_tokens,
            kv_bits_per_value,
            kv_mix,
            drafted: 0,
            accepted: 0,
        })
    }

    /// Owned parameters — the speculative decoder builds its all-NVFP4
    /// draft view from these.
    pub(crate) fn params(&self) -> &[(String, ParamData)] {
        &self.params
    }

    /// The engine's activation-quantization inputs (shared by the real and
    /// draft datapaths — the draft differs only in its weight bits).
    pub(crate) fn quant(&self) -> QuantInputs<'_> {
        self.quant_inputs()
    }

    /// One batched decode step over *explicit* per-session KV shards with
    /// an *explicit* parameter map: the speculative draft path runs the
    /// all-NVFP4 view over forked caches through the exact TP machinery
    /// the real step uses. No session bookkeeping happens here.
    pub(crate) fn step_shards_with(
        &self,
        pm: &Params<'_>,
        quant: &QuantInputs<'_>,
        tokens: &[i32],
        kvs: &mut [Vec<&mut KvState>],
    ) -> Result<ForwardOut> {
        catch_worker(|| {
            forward_step_batch_tp(
                &self.arch,
                &self.shard_arches,
                &self.plan,
                pm,
                &self.coll,
                tokens,
                kvs,
                Some(quant),
            )
        })
    }

    /// The speculative **verify pass** over per-worker KV shards: extend
    /// every session's shards by its drafted chain in one ragged batched
    /// TP forward and return logits for all chain rows (`(Σkᵢ, V)` in
    /// session order). The caller owns acceptance and rollback.
    pub(crate) fn extend_batch(
        &self,
        sessions: &mut [&mut Session],
        chains: &[&[i32]],
    ) -> Result<ForwardOut> {
        let active = self.shard_arches.len();
        for (i, sess) in sessions.iter().enumerate() {
            anyhow::ensure!(
                sess.kv.is_none() && sess.kv_shards.len() == active,
                "session {i} was not prefilled on this sharded engine"
            );
        }
        let pm = self.param_map();
        let quant = self.quant_inputs();
        let mut kvs: Vec<Vec<&mut KvState>> =
            sessions.iter_mut().map(|s| s.kv_shards.iter_mut().collect()).collect();
        catch_worker(|| {
            forward_extend_batch_tp(
                &self.arch,
                &self.shard_arches,
                &self.plan,
                &pm,
                &self.coll,
                chains,
                &mut kvs,
                Some(&quant),
            )
        })
    }

    /// KV-traffic accounting over the sessions' *current* cache state —
    /// the same token-weighted per-worker mix [`Self::decode_step`]
    /// reports, reused by the speculative round after acceptance/rollback.
    /// Returns `(kv_tokens, kv_bits_per_value, kv_mix)`.
    pub(crate) fn kv_step_stats(&self, sessions: &[&mut Session]) -> (u64, f64, Vec<(usize, f64)>) {
        let mut kv_tokens = 0u64;
        for sess in sessions.iter() {
            kv_tokens += sess.cached_tokens() as u64;
        }
        let d = self.arch.d_model as f64;
        let mut kv_mix: Vec<(usize, f64)> = Vec::with_capacity(self.shard_arches.len());
        let mut global = 0.0f64;
        for (wi, sa) in self.shard_arches.iter().enumerate() {
            let mut weighted = 0.0f64;
            for sess in sessions.iter() {
                let t = sess.cached_tokens() as u64;
                weighted += sess.kv_shards[wi].effective_kv_bits() * t as f64;
            }
            let bits_w = if kv_tokens > 0 {
                weighted / kv_tokens as f64
            } else {
                self.kv.bits_per_value()
            };
            kv_mix.push((sa.d_model, bits_w));
            global += bits_w * sa.d_model as f64 / d;
        }
        let kv_bits_per_value = if kv_tokens > 0 { global } else { self.kv.bits_per_value() };
        (kv_tokens, kv_bits_per_value, kv_mix)
    }
}

impl<C: Collective> InferenceEngine for ShardedEngine<C> {
    fn arch(&self) -> &ModelArch {
        &self.arch
    }
    fn is_cached(&self) -> bool {
        true
    }
    fn kv_precision(&self) -> KvPrecision {
        self.kv
    }
    fn workers(&self) -> usize {
        self.plan.world
    }
    fn prefill(&self, prompt: &[i32]) -> Result<Session> {
        let mut v = self.prefill_batch_impl(&[prompt.to_vec()])?;
        Ok(v.pop().expect("one session per prompt"))
    }
    fn prefill_batch(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>> {
        self.prefill_batch_impl(prompts)
    }
    fn decode_step(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        self.decode_step_impl(sessions)
    }
    fn weight_memory(&self) -> WeightMemory {
        params_weight_memory(&self.params)
    }
    fn pool_stats(&self) -> Option<KvPoolStats> {
        self.pools.first().map(|p| p.stats())
    }
    fn kv_pages_per_session(&self) -> usize {
        KvPool::pages_for_session(self.arch.n_layers, self.arch.max_seq)
    }
    fn max_live_sessions(&self) -> usize {
        let per = self.kv_pages_per_session().max(1);
        self.pools.first().map(|p| p.total_pages() / per).unwrap_or(0)
    }
    fn kv_pages_worst_for(&self, prompt_len: usize, want: usize) -> usize {
        let kept = prompt_len.min(self.arch.max_seq).max(1);
        let peak = (kept + want).min(self.arch.max_seq);
        KvPool::pages_for_session(self.arch.n_layers, peak)
    }
}

/// Build the engine a worker-count asks for: a plain [`Engine`] for
/// `workers <= 1` (or when the windowed fallback is forced — there is
/// nothing to shard in a recompute loop), a [`ShardedEngine`] otherwise.
/// When [`EngineOptions::spec`] requests a chain length `k >= 2`, the
/// target engine is wrapped in a
/// [`SpecEngine`](crate::runtime::spec::SpecEngine) that drafts through
/// the all-NVFP4 view and verifies in batched ragged passes (the windowed
/// fallback holds no cache to fork, so it stays unwrapped). Callers hold
/// the trait object and never branch on the concrete type.
/// [`EngineOptions::prefix`] routes only to the single-worker cached
/// engine: the sharded engine's per-worker pools would each need their
/// own coordinated trie, so it ignores the flag (ROADMAP debt) and
/// reports no [`InferenceEngine::prefix_stats`].
pub fn build_engine(
    rt: &Runtime,
    spec: &ExecSpec,
    tail: Vec<ArgValue>,
    opts: EngineOptions,
) -> Result<Box<dyn InferenceEngine>> {
    let spec_k = opts.spec.filter(|&k| k >= 2);
    if opts.workers > 1 && !opts.windowed {
        let eng = ShardedEngine::with_options(rt, spec, tail, opts)?;
        if let Some(k) = spec_k {
            return Ok(Box::new(super::spec::SpecEngine::over_sharded(eng, k)));
        }
        Ok(Box::new(eng))
    } else {
        let eng = Engine::with_options(rt, spec, tail, opts)?;
        if let Some(k) = spec_k {
            if eng.is_cached() {
                return Ok(Box::new(super::spec::SpecEngine::over_engine(eng, k)));
            }
        }
        Ok(Box::new(eng))
    }
}
