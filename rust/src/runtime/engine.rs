//! The stateful inference engine: KV-cached prefill/decode sessions over a
//! loaded model.
//!
//! [`Engine`] is the serving-side facade the coordinator's generate path
//! builds on. It owns one model's parameters (parsed once from the same
//! argument tail the one-shot [`Executable::run`] API takes) and exposes
//!
//!  * [`Engine::prefill`] — run a prompt once, populating a per-session
//!    [`KvState`], and return a [`Session`] whose logits already predict
//!    the first generated token (time-to-first-token ends here);
//!  * [`Engine::decode_step`] — advance *many* sessions by one token each
//!    in a single batched forward over the blocked kernels (continuous
//!    batching: the session set may change between steps), attending over
//!    each session's cached K/V and PPU-quantizing only the new rows.
//!
//! On the native backend this is the cached incremental path
//! ([`crate::model::forward::forward_prefill`] /
//! [`forward_step_batch`](crate::model::forward::forward_step_batch)); on
//! any other backend (PJRT) sessions transparently fall back to windowed
//! full-sequence recompute through the one-shot executable, so
//! `Runtime`/`ExecSpec`/`GraphKind` keep working everywhere. The cached
//! path is bit-identical to recompute with an FP16 cache (see
//! `tests/decode_props.rs`) and rolls — re-prefilling the trailing half
//! window — when a session outgrows `max_seq`.
//!
//! KV storage is **paged**: the engine owns a shared
//! [`KvPool`](crate::model::kv::KvPool) and sessions hold page tables into
//! it instead of privately grown buffers, so admission cost is proportional
//! to tokens actually cached, retirement returns pages to the free list,
//! and an exhausted pool surfaces as the typed
//! [`KvPoolExhausted`](crate::model::kv::KvPoolExhausted) backpressure
//! error before any compute. [`Engine::prefill_batch`] amortizes the
//! blocked matmuls across every prompt admitted in one round. Pool capacity
//! comes from [`EngineOptions::kv_pages`] (the serve `--kv-pages` flag).

use std::sync::{Arc, Mutex};

use crate::io::Manifest;
use crate::model::forward::{
    forward_extend_batch, forward_prefill, forward_prefill_batch, forward_step_batch, ForwardOut,
    ModelArch, Params, QuantInputs,
};
use crate::model::kv::{KvPool, KvPoolExhausted, KvPoolStats, KvPrecision, KvState};
use crate::model::WeightMemory;
use crate::quant::PackedPanels;
use crate::util::faults;
use crate::Result;

use super::args::ArgValue;
use super::error::EngineError;
use super::prefix::{PrefixIndex, PrefixIndexStats};
use super::{ExecSpec, Executable, GraphKind, Runtime};

/// One live generation session: the token context, the latest next-token
/// logits, and (on the cached path) the per-layer KV cache.
#[derive(Debug, Clone)]
pub struct Session {
    /// Full context: the (possibly truncated-on-roll) prompt plus every
    /// token consumed by decode steps.
    pub tokens: Vec<i32>,
    /// Next-token logits at the current position `(V,)`.
    pub last_logits: Vec<f32>,
    /// Decode steps taken since prefill.
    pub steps: usize,
    /// Single-engine cached KV (`None` on the windowed fallback and on
    /// sharded sessions).
    pub(crate) kv: Option<KvState>,
    /// Per-active-worker KV shards of a sharded-engine session (empty on
    /// single-engine sessions). Shards advance in lockstep, so shard 0's
    /// length is the session's cached-token count.
    pub(crate) kv_shards: Vec<KvState>,
    /// Extra tokens a speculative round accepted beyond the one token a
    /// plain decode step yields, not yet drained by the caller. Producers
    /// must emit these (in order) *before* [`Session::next_token`] of the
    /// post-round logits — [`Session::take_accepted`] drains them. Always
    /// empty on non-speculative engines.
    pub(crate) spec_accepted: Vec<i32>,
    /// Lifetime draft tokens proposed for this session (speculative
    /// engines only) — with [`Session::spec_accepted_total`], the
    /// per-request accept rate.
    pub spec_drafted_total: u64,
    /// Lifetime draft tokens accepted for this session.
    pub spec_accepted_total: u64,
}

impl Session {
    /// Greedy argmax over the current logits — the token a decode step
    /// will consume next (same tie-breaking as the legacy recompute loop:
    /// the last maximum wins under `max_by`).
    pub fn next_token(&self) -> i32 {
        self.last_logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32)
            .unwrap_or(0)
    }

    /// Tokens currently held in the KV cache (0 on the windowed fallback,
    /// which caches nothing).
    pub fn cached_tokens(&self) -> usize {
        if let Some(kv) = &self.kv {
            return kv.len();
        }
        self.kv_shards.first().map(|kv| kv.len()).unwrap_or(0)
    }

    /// Physical bits the session's cache holds right now (summed across
    /// worker shards on a sharded session).
    pub fn kv_bits(&self) -> u64 {
        if let Some(kv) = &self.kv {
            return kv.stored_bits();
        }
        self.kv_shards.iter().map(|kv| kv.stored_bits()).sum()
    }

    /// Pool pages the session's cache holds (0 on the windowed fallback;
    /// summed across the per-worker pools on a sharded session). Pages
    /// return to the engine's free list(s) when the session drops.
    pub fn kv_pages(&self) -> usize {
        if let Some(kv) = &self.kv {
            return kv.kv_pages();
        }
        self.kv_shards.iter().map(|kv| kv.kv_pages()).sum()
    }

    /// Drain the tokens the last speculative round accepted beyond the
    /// usual one-per-step. Callers that stream tokens must emit these (in
    /// order) before the [`Session::next_token`] of the current logits —
    /// together the two reproduce the non-speculative greedy stream
    /// exactly. Always empty on non-speculative engines.
    pub fn take_accepted(&mut self) -> Vec<i32> {
        std::mem::take(&mut self.spec_accepted)
    }

    /// Fork this session into an independent draft session: same tokens,
    /// logits, and step count, with every KV buffer (single-engine or
    /// per-worker shards) forked via [`KvState::fork`] — a page-table copy
    /// plus refcount bumps, O(page-table), no payload copies. The draft
    /// shares every cached page with its parent until one side appends
    /// into the shared tail, where the copy-on-write hook clones exactly
    /// that page; pool pressure therefore surfaces at *divergence* (typed
    /// [`KvPoolExhausted`] out of `reserve`), not here. The `Result`
    /// remains so speculative callers keep their decode-plain fallback.
    pub fn fork(&self) -> std::result::Result<Session, KvPoolExhausted> {
        let kv = match &self.kv {
            Some(kv) => Some(kv.fork()?),
            None => None,
        };
        let mut kv_shards = Vec::with_capacity(self.kv_shards.len());
        for shard in &self.kv_shards {
            kv_shards.push(shard.fork()?);
        }
        Ok(Session {
            tokens: self.tokens.clone(),
            last_logits: self.last_logits.clone(),
            steps: self.steps,
            kv,
            kv_shards,
            spec_accepted: Vec::new(),
            spec_drafted_total: 0,
            spec_accepted_total: 0,
        })
    }
}

/// Engine construction knobs.
#[derive(Debug, Clone, Copy)]
pub struct EngineOptions {
    /// KV-cache storage precision of new sessions.
    pub kv: KvPrecision,
    /// KV pool capacity in pages ([`crate::model::kv::PAGE_TOKENS`] tokens
    /// each). `None` sizes for [`DEFAULT_POOL_SESSIONS`] full-window
    /// sessions — a startup decision, like a device's HBM carve-out.
    pub kv_pages: Option<usize>,
    /// Attention-input PPU threshold
    /// ([`QuantInputs::attn_threshold`]): when set, Q rows and new K/V
    /// rows are block-assigned to FP8/NVFP4 on the fly and the realized
    /// mix prices KV traffic in [`StepOut::kv_bits_per_value`]. `None`
    /// (the default) keeps attention inputs full-precision.
    pub attn_threshold: Option<f32>,
    /// Tensor-parallel worker count. [`Engine`] itself is always
    /// single-worker and ignores this; the engine builder
    /// ([`build_engine`](crate::runtime::sharded::build_engine)) returns a
    /// [`ShardedEngine`](crate::runtime::sharded::ShardedEngine) when it
    /// is > 1.
    pub workers: usize,
    /// Force the windowed-recompute fallback regardless of backend (the
    /// PJRT path always takes it; tests use it as the parity oracle).
    pub windowed: bool,
    /// Self-speculative decoding chain length `k`: when `Some(k >= 2)`,
    /// the engine builder wraps the target engine in a
    /// [`SpecEngine`](crate::runtime::spec::SpecEngine) that drafts `k-1`
    /// greedy tokens per round through the all-NVFP4 draft view and
    /// verifies them in one ragged batched pass. `None` (and `Some(k < 2)`,
    /// which cannot draft anything) run plain decode. Ignored by
    /// [`Engine::with_options`] itself — like `workers`, it is a builder
    /// routing knob.
    pub spec: Option<usize>,
    /// Prefix sharing: when true the cached engine keeps a
    /// [`PrefixIndex`] over its pool and [`Engine::prefill`] /
    /// [`Engine::prefill_batch`] map fully-matching shared prompt pages
    /// into new sessions by reference, prefilling only the divergent
    /// suffix. Bit-exact vs plain prefill (causal attention makes shared
    /// prefixes' KV independent of what follows); multiplies effective
    /// session capacity by the pool's sharing factor on shared-prefix
    /// traffic. Single-worker engines only — the sharded engine ignores
    /// it (its per-worker pools have no shared index yet; see ROADMAP).
    pub prefix: bool,
}

impl EngineOptions {
    /// Chainable setter for [`EngineOptions::kv`].
    pub fn kv(mut self, kv: KvPrecision) -> Self {
        self.kv = kv;
        self
    }

    /// Chainable setter for [`EngineOptions::kv_pages`].
    pub fn pages(mut self, pages: Option<usize>) -> Self {
        self.kv_pages = pages;
        self
    }

    /// Chainable setter for [`EngineOptions::attn_threshold`].
    pub fn attn(mut self, threshold: Option<f32>) -> Self {
        self.attn_threshold = threshold;
        self
    }

    /// Chainable setter for [`EngineOptions::workers`].
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers;
        self
    }

    /// Chainable setter for [`EngineOptions::windowed`].
    pub fn windowed(mut self, windowed: bool) -> Self {
        self.windowed = windowed;
        self
    }

    /// Chainable setter for [`EngineOptions::spec`].
    pub fn spec(mut self, k: Option<usize>) -> Self {
        self.spec = k;
        self
    }

    /// Chainable setter for [`EngineOptions::prefix`].
    pub fn prefix_share(mut self, on: bool) -> Self {
        self.prefix = on;
        self
    }
}

impl Default for EngineOptions {
    fn default() -> Self {
        EngineOptions {
            kv: KvPrecision::Fp16,
            kv_pages: None,
            attn_threshold: None,
            workers: 1,
            windowed: false,
            spec: None,
            prefix: false,
        }
    }
}

/// Default pool sizing: full-window worst case for this many sessions.
pub const DEFAULT_POOL_SESSIONS: usize = 16;

/// Per-step report for metrics/energy accounting.
#[derive(Debug, Clone, Default)]
pub struct StepOut {
    /// Sessions advanced this step (the decode batch occupancy).
    pub rows: usize,
    /// Realized per-linear activation FP8 fractions over the new rows
    /// (empty on the windowed fallback, which reports none).
    pub act_fp8: Vec<f32>,
    /// Total KV-cache tokens attended over this step (Σ per-session
    /// context) — the cache-traffic input to the energy report.
    pub kv_tokens: u64,
    /// Effective stored bits per KV value attended this step
    /// (token-weighted across sessions): the precision's nominal width
    /// (16/8), or the FGMP mix `8·f + 4.5625·(1−f)` when the attention
    /// PPU assigned the blocks. 0 on the empty step; 16 on the windowed
    /// fallback (recompute reads activations, priced as the FP16 cache
    /// baseline).
    pub kv_bits_per_value: f64,
    /// Per-worker KV traffic mix: `(kv width in values per token-layer,
    /// effective stored bits per value)` for each worker that attended this
    /// step. Single entry `(d_model, kv_bits_per_value)` on the cached
    /// single-worker path; one entry per active worker under tensor
    /// parallelism, where each worker reads `kv_tokens` tokens at its own
    /// width and its own realized precision mix (the energy model must
    /// price each worker's traffic at its own width — not an average);
    /// empty on the windowed fallback.
    pub kv_mix: Vec<(usize, f64)>,
    /// Draft tokens proposed this step (0 on non-speculative engines):
    /// each drafted token is one session×position forward through the
    /// all-NVFP4 draft view, so the energy model prices these rows at
    /// NVFP4 weight-read width (`weight_fp8 = 0`).
    pub drafted: u64,
    /// Drafted tokens the verify pass accepted — extra tokens this step
    /// produced beyond the one a plain decode step yields. The aggregate
    /// `accepted / drafted` is the speculative accept rate, a live proxy
    /// for how close the all-NVFP4 assignment tracks the mixed model.
    pub accepted: u64,
}

/// One owned parameter of the cached engine: dense f32, or the packed
/// FGMP execution tensor (no resident dequantized copy).
pub(crate) enum ParamData {
    Dense(Vec<f32>),
    Packed(Arc<PackedPanels>),
}

/// Build a borrow-map over owned engine parameters (shared by the cached
/// and sharded engines).
pub(crate) fn params_map(params: &[(String, ParamData)]) -> Params<'_> {
    let mut p = Params::new();
    for (n, d) in params {
        match d {
            ParamData::Dense(v) => p.insert_dense(n, v),
            ParamData::Packed(pw) => p.insert_packed(n, pw),
        }
    }
    p
}

/// Resident-vs-f32 weight accounting over owned engine parameters.
pub(crate) fn params_weight_memory(params: &[(String, ParamData)]) -> WeightMemory {
    params.iter().fold(WeightMemory::default(), |mut m, (_, d)| {
        if let ParamData::Packed(pw) = d {
            m.packed_bytes += pw.resident_bytes();
            m.f32_equiv_bytes += pw.f32_equiv_bytes();
            m.linears += 1;
        }
        m
    })
}

/// The model-owning state of the cached native path (shared with the
/// sharded engine, which swaps the single pool for per-worker pools).
pub(crate) struct CachedEngine {
    pub(crate) arch: ModelArch,
    pub(crate) params: Vec<(String, ParamData)>,
    pub(crate) act_weights: Vec<Vec<f32>>,
    pub(crate) thresholds: Vec<f32>,
    pub(crate) kv: KvPrecision,
    pub(crate) attn_threshold: Option<f32>,
    /// The shared page arena every session of this engine draws from.
    pub(crate) pool: Arc<KvPool>,
    /// Prefix-sharing admission index ([`EngineOptions::prefix`]); `None`
    /// when the knob is off. The mutex guards trie structure only — page
    /// lifetime is the pool's refcounts.
    pub(crate) prefix: Option<Mutex<PrefixIndex>>,
}

impl CachedEngine {
    pub(crate) fn param_map(&self) -> Params<'_> {
        params_map(&self.params)
    }

    pub(crate) fn weight_memory(&self) -> WeightMemory {
        params_weight_memory(&self.params)
    }

    pub(crate) fn quant_inputs(&self) -> QuantInputs<'_> {
        QuantInputs {
            act_weights: self.act_weights.iter().map(|v| v.as_slice()).collect(),
            thresholds: &self.thresholds,
            attn_threshold: self.attn_threshold,
        }
    }

    /// Prefill through the prefix index: look up each (already
    /// window-trimmed) prompt, map fully-matching shared pages into its
    /// fresh cache by reference, prefill misses as one batch and hit
    /// suffixes as one ragged extend, then register every resulting cache
    /// so later prompts share it. Bit-exact vs plain prefill: attention is
    /// causal, so the KV rows of a shared prefix are independent of what
    /// follows them, and the extend path computes suffix rows at the same
    /// positions with the same PPU decisions plain prefill would. Runs
    /// under the index lock end to end — mapped pages can't be evicted
    /// before the session retains them — and on pool exhaustion evicts
    /// LRU index subtrees and retries before giving up (index pages are
    /// cache; admissions are load).
    fn prefill_shared(&self, kept: &[&[i32]]) -> Result<Vec<Session>> {
        let ix = self.prefix.as_ref().expect("prefill_shared needs the prefix index");
        let mut g = ix.lock().unwrap();
        let pm = self.param_map();
        let quant = self.quant_inputs();
        let vocab = self.arch.vocab;
        let mut kvs: Vec<KvState> =
            kept.iter().map(|_| KvState::new_paged(&self.arch, &self.pool)).collect();
        let mut hit_rows = vec![0usize; kept.len()];
        for (i, p) in kept.iter().enumerate() {
            if let Some(hit) = g.lookup(p) {
                kvs[i].map_prefix(&hit.per_buf_refs(), hit.rows, &hit.ppu);
                hit_rows[i] = hit.rows;
            }
        }
        let miss: Vec<usize> = (0..kept.len()).filter(|&i| hit_rows[i] == 0).collect();
        let hits: Vec<usize> = (0..kept.len()).filter(|&i| hit_rows[i] > 0).collect();
        let mut logits = vec![Vec::new(); kept.len()];
        if !miss.is_empty() {
            let prompts: Vec<&[i32]> = miss.iter().map(|&i| kept[i]).collect();
            let out = loop {
                let mut refs: Vec<&mut KvState> = kvs
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| hit_rows[*i] == 0)
                    .map(|(_, kv)| kv)
                    .collect();
                match forward_prefill_batch(&self.arch, &pm, &prompts, Some(&quant), &mut refs) {
                    Ok(out) => break out,
                    // Reservations are idempotent (pages kept so far carry
                    // over), so freeing index pages and retrying is safe
                    // and monotone. The typed error propagates unwrapped —
                    // the coordinator classifies it for deferral.
                    Err(e) if EngineError::is_exhausted(&e) => {
                        if g.evict_lru() == 0 {
                            return Err(e);
                        }
                    }
                    Err(e) => return Err(e),
                }
            };
            for (j, &i) in miss.iter().enumerate() {
                logits[i] = out.logits[j * vocab..(j + 1) * vocab].to_vec();
            }
        }
        if !hits.is_empty() {
            let chains: Vec<&[i32]> = hits.iter().map(|&i| &kept[i][hit_rows[i]..]).collect();
            let out = loop {
                let mut refs: Vec<&mut KvState> = kvs
                    .iter_mut()
                    .enumerate()
                    .filter(|(i, _)| hit_rows[*i] > 0)
                    .map(|(_, kv)| kv)
                    .collect();
                match forward_extend_batch(&self.arch, &pm, &chains, &mut refs, Some(&quant)) {
                    Ok(out) => break out,
                    Err(e) if EngineError::is_exhausted(&e) => {
                        if g.evict_lru() == 0 {
                            return Err(e);
                        }
                    }
                    Err(e) => return Err(e),
                }
            };
            // Extend returns logits for *every* chain row; the session's
            // next-token logits are each chain's last row.
            let mut off = 0usize;
            for (j, &i) in hits.iter().enumerate() {
                let base = (off + chains[j].len() - 1) * vocab;
                logits[i] = out.logits[base..base + vocab].to_vec();
                off += chains[j].len();
            }
        }
        for (i, kv) in kvs.iter().enumerate() {
            g.register(kept[i], kv);
        }
        drop(g);
        Ok(kvs
            .into_iter()
            .enumerate()
            .map(|(i, kv)| Session {
                tokens: kept[i].to_vec(),
                last_logits: std::mem::take(&mut logits[i]),
                steps: 0,
                kv: Some(kv),
                kv_shards: Vec::new(),
                spec_accepted: Vec::new(),
                spec_drafted_total: 0,
                spec_accepted_total: 0,
            })
            .collect())
    }
}

/// The windowed-recompute fallback: one-shot logits graph, fixed (B, S).
struct WindowedEngine {
    exe: Executable,
    tail: Vec<ArgValue>,
    arch: ModelArch,
    batch: usize,
    seq: usize,
}

enum Inner {
    Cached(CachedEngine),
    Windowed(WindowedEngine),
}

/// A loaded model plus the session machinery. Built per worker thread
/// (like executables, engines are not shared across threads).
pub struct Engine {
    inner: Inner,
}

impl Engine {
    /// Build an engine for a `logits_quant` graph from its [`ExecSpec`] and
    /// the same argument tail (params, activation weightings, thresholds)
    /// the one-shot API takes. The native backend gets the KV-cached
    /// incremental path at `kv` precision; other backends fall back to
    /// windowed recompute.
    pub fn new(
        rt: &Runtime,
        spec: &ExecSpec,
        tail: Vec<ArgValue>,
        kv: KvPrecision,
    ) -> Result<Self> {
        Engine::with_options(rt, spec, tail, EngineOptions::default().kv(kv))
    }

    /// The one real constructor — [`Engine::new`] and
    /// [`Engine::new_windowed`] are thin delegates. `opts.workers` is
    /// ignored here (an [`Engine`] is always single-worker); route through
    /// [`build_engine`](crate::runtime::sharded::build_engine) to get a
    /// sharded engine for `workers > 1`.
    pub fn with_options(
        rt: &Runtime,
        spec: &ExecSpec,
        tail: Vec<ArgValue>,
        opts: EngineOptions,
    ) -> Result<Self> {
        anyhow::ensure!(
            spec.kind == GraphKind::LogitsQuant,
            "Engine drives the logits_quant graph, got {:?}",
            spec.kind
        );
        let exe = rt.load_spec(spec)?;
        if opts.windowed {
            return Engine::windowed_from(spec, exe, tail);
        }
        match exe {
            Executable::Native(g) => {
                let (params, act_weights, thresholds) = parse_tail(g.manifest(), &tail)?;
                let arch = g.arch().clone();
                let pages = opts.kv_pages.unwrap_or_else(|| {
                    DEFAULT_POOL_SESSIONS
                        * KvPool::pages_for_session(arch.n_layers, arch.max_seq)
                });
                let pool = KvPool::new(&arch, opts.kv, pages);
                let prefix = opts
                    .prefix
                    .then(|| Mutex::new(PrefixIndex::new(pool.clone(), arch.n_layers)));
                Ok(Engine {
                    inner: Inner::Cached(CachedEngine {
                        arch,
                        params,
                        act_weights,
                        thresholds,
                        kv: opts.kv,
                        attn_threshold: opts.attn_threshold,
                        pool,
                        prefix,
                    }),
                })
            }
            #[cfg(feature = "pjrt")]
            exe @ Executable::Pjrt(_) => Engine::windowed_from(spec, exe, tail),
        }
    }

    /// Force the windowed-recompute fallback regardless of backend (the
    /// PJRT path always takes this; tests use it as the parity oracle).
    pub fn new_windowed(rt: &Runtime, spec: &ExecSpec, tail: Vec<ArgValue>) -> Result<Self> {
        Engine::with_options(rt, spec, tail, EngineOptions::default().windowed(true))
    }

    fn windowed_from(spec: &ExecSpec, exe: Executable, tail: Vec<ArgValue>) -> Result<Self> {
        let manifest = Manifest::load(spec.model_dir().join("manifest.json"))?;
        let arch = manifest.arch()?;
        let (batch, seq) = (manifest.batch, manifest.seq);
        Ok(Engine { inner: Inner::Windowed(WindowedEngine { exe, tail, arch, batch, seq }) })
    }

    /// Whether sessions run the cached incremental path (vs windowed
    /// recompute).
    pub fn is_cached(&self) -> bool {
        matches!(self.inner, Inner::Cached(_))
    }

    /// The model architecture.
    pub fn arch(&self) -> &ModelArch {
        match &self.inner {
            Inner::Cached(ce) => &ce.arch,
            Inner::Windowed(we) => &we.arch,
        }
    }

    /// KV storage precision of new sessions (the fallback holds no cache;
    /// it reports FP16, the recompute activations' precision).
    pub fn kv_precision(&self) -> KvPrecision {
        match &self.inner {
            Inner::Cached(ce) => ce.kv,
            Inner::Windowed(_) => KvPrecision::Fp16,
        }
    }

    /// Run one prompt to completion, returning a session whose logits
    /// predict the first generated token. Prompts longer than the model's
    /// context are truncated to the trailing window; an empty prompt is
    /// treated as the single token 0 (matching the legacy zero-padded
    /// window). The session's KV pages come from the engine's shared pool
    /// — proportional to the prompt's length, never the max window — and a
    /// full pool fails *before* any compute with a
    /// [`crate::model::kv::KvPoolExhausted`]-sourced error the caller can
    /// downcast and treat as admission backpressure.
    pub fn prefill(&self, prompt: &[i32]) -> Result<Session> {
        // Failpoint fires before any allocation or compute, so an injected
        // prefill failure is indistinguishable from a pre-admission error.
        if faults::should_fail(faults::ENGINE_PREFILL) {
            return Err(EngineError::Injected { point: faults::ENGINE_PREFILL }.into());
        }
        let prompt = if prompt.is_empty() { &[0i32][..] } else { prompt };
        match &self.inner {
            Inner::Cached(ce) => {
                let keep = prompt.len().min(ce.arch.max_seq);
                let kept = &prompt[prompt.len() - keep..];
                if ce.prefix.is_some() {
                    let mut out = ce.prefill_shared(&[kept])?;
                    return Ok(out.pop().expect("one session per prompt"));
                }
                // Pages are reserved inside forward_prefill; dropping the
                // state on any error releases them.
                let mut kv = KvState::new_paged(&ce.arch, &ce.pool);
                let quant = ce.quant_inputs();
                let out = forward_prefill(&ce.arch, &ce.param_map(), kept, Some(&quant), &mut kv)?;
                Ok(Session {
                    tokens: kept.to_vec(),
                    last_logits: out.logits,
                    steps: 0,
                    kv: Some(kv),
                    kv_shards: Vec::new(),
                    spec_accepted: Vec::new(),
                    spec_drafted_total: 0,
                    spec_accepted_total: 0,
                })
            }
            Inner::Windowed(we) => {
                let mut sess = Session {
                    tokens: prompt.to_vec(),
                    last_logits: Vec::new(),
                    steps: 0,
                    kv: None,
                    kv_shards: Vec::new(),
                    spec_accepted: Vec::new(),
                    spec_drafted_total: 0,
                    spec_accepted_total: 0,
                };
                {
                    let mut refs = [&mut sess];
                    we.refresh_logits(&mut refs)?;
                }
                Ok(sess)
            }
        }
    }

    /// Prefill many prompts as **one batched forward**: the blocked matmuls
    /// of every layer run once over all prompts' concatenated rows
    /// ([`forward_prefill_batch`]), amortizing admission cost across the
    /// round — per-prompt logits and caches are bit-identical to
    /// [`Engine::prefill`] one at a time. All page reservations happen
    /// before any compute; on pool exhaustion nothing is cached and the
    /// typed error propagates (the windowed fallback prefills serially).
    pub fn prefill_batch(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>> {
        if prompts.is_empty() {
            return Ok(Vec::new());
        }
        if faults::should_fail(faults::ENGINE_PREFILL) {
            return Err(EngineError::Injected { point: faults::ENGINE_PREFILL }.into());
        }
        match &self.inner {
            Inner::Cached(ce) => {
                let kept: Vec<&[i32]> = prompts
                    .iter()
                    .map(|p| {
                        if p.is_empty() {
                            &[0i32][..]
                        } else {
                            &p[p.len() - p.len().min(ce.arch.max_seq)..]
                        }
                    })
                    .collect();
                if ce.prefix.is_some() {
                    return ce.prefill_shared(&kept);
                }
                let mut kvs_owned: Vec<KvState> =
                    (0..kept.len()).map(|_| KvState::new_paged(&ce.arch, &ce.pool)).collect();
                let pm = ce.param_map();
                let quant = ce.quant_inputs();
                let out = {
                    let mut kv_refs: Vec<&mut KvState> = kvs_owned.iter_mut().collect();
                    // On error kvs_owned drops → reserved pages released.
                    forward_prefill_batch(&ce.arch, &pm, &kept, Some(&quant), &mut kv_refs)?
                };
                let vocab = ce.arch.vocab;
                Ok(kvs_owned
                    .into_iter()
                    .enumerate()
                    .map(|(i, kv)| Session {
                        tokens: kept[i].to_vec(),
                        last_logits: out.logits[i * vocab..(i + 1) * vocab].to_vec(),
                        steps: 0,
                        kv: Some(kv),
                        kv_shards: Vec::new(),
                        spec_accepted: Vec::new(),
                        spec_drafted_total: 0,
                        spec_accepted_total: 0,
                    })
                    .collect())
            }
            Inner::Windowed(_) => prompts.iter().map(|p| self.prefill(p)).collect(),
        }
    }

    /// Resident weight-memory accounting of the loaded model: bytes the
    /// packed execution tensors actually hold vs the f32 bytes a
    /// dequantized copy would need. Zero-linears on the windowed fallback
    /// (whose weights live inside the one-shot executable's tail).
    pub fn weight_memory(&self) -> WeightMemory {
        match &self.inner {
            Inner::Cached(ce) => ce.weight_memory(),
            Inner::Windowed(_) => WeightMemory::default(),
        }
    }

    /// Live accounting of the engine's KV page pool (None on the windowed
    /// fallback, which holds no cache).
    pub fn pool_stats(&self) -> Option<KvPoolStats> {
        match &self.inner {
            Inner::Cached(ce) => Some(ce.pool.stats()),
            Inner::Windowed(_) => None,
        }
    }

    /// Prefix-sharing index counters (None unless
    /// [`EngineOptions::prefix`] built an index).
    pub fn prefix_stats(&self) -> Option<PrefixIndexStats> {
        match &self.inner {
            Inner::Cached(ce) => ce.prefix.as_ref().map(|ix| ix.lock().unwrap().stats()),
            Inner::Windowed(_) => None,
        }
    }

    /// Donate a session's cache to the prefix index just before preempting
    /// it: registering `tokens → pages` lets the request's eventual resume
    /// map the already-computed prefix back in by reference instead of
    /// re-prefilling it (the pages stay alive under the index's refcounts
    /// after the session drops). Requires the cached path with prefix
    /// sharing on and a cache covering exactly the session's tokens — the
    /// between-steps invariant. Returns whether anything was registered;
    /// `false` is never an error (resume then recomputes, still
    /// bit-exact).
    pub fn preempt_donate(&self, sess: &Session) -> bool {
        let Inner::Cached(ce) = &self.inner else { return false };
        let Some(ix) = &ce.prefix else { return false };
        let Some(kv) = &sess.kv else { return false };
        if kv.is_empty() || kv.len() != sess.tokens.len() {
            return false;
        }
        ix.lock().unwrap().register(&sess.tokens, kv);
        true
    }

    /// Worst-case pages one session can ever hold (a full `max_seq`
    /// window; rolling re-prefill shrinks usage back below this).
    pub fn kv_pages_per_session(&self) -> usize {
        match &self.inner {
            Inner::Cached(ce) => KvPool::pages_for_session(ce.arch.n_layers, ce.arch.max_seq),
            Inner::Windowed(_) => 0,
        }
    }

    /// Sessions the pool sustains at worst case — the coarse admission
    /// bound (unbounded on the windowed fallback). The coordinator uses
    /// the tighter per-request bound [`Engine::kv_pages_worst_for`].
    pub fn max_live_sessions(&self) -> usize {
        match &self.inner {
            Inner::Cached(ce) => ce.pool.total_pages() / self.kv_pages_per_session().max(1),
            Inner::Windowed(_) => usize::MAX,
        }
    }

    /// Sound per-request worst-case page bound: a request admitted with
    /// this many tokens of prompt and a `want`-token budget can never hold
    /// more pages than this at any point of its life (context is capped by
    /// `max_seq`, rolls only shrink it, and the session retires once
    /// `want` tokens exist). Admitting only while Σ worst-cases of live
    /// sessions stays within the pool guarantees prefill, decode, and roll
    /// can never hit an exhausted pool (0 on the windowed fallback).
    pub fn kv_pages_worst_for(&self, prompt_len: usize, want: usize) -> usize {
        match &self.inner {
            Inner::Cached(ce) => {
                let kept = prompt_len.min(ce.arch.max_seq).max(1);
                let peak = (kept + want).min(ce.arch.max_seq);
                KvPool::pages_for_session(ce.arch.n_layers, peak)
            }
            Inner::Windowed(_) => 0,
        }
    }

    /// Prompt-aware variant of [`Engine::kv_pages_worst_for`]: discounts
    /// the whole shared pages the prefix index currently holds for this
    /// prompt's longest registered prefix, which prefill maps into the
    /// session instead of allocating. The discount is sound because mapped
    /// prefix pages are append-only *whole* pages — copy-on-write can
    /// never turn them into private copies, so the session's own demand is
    /// exactly its suffix pages. Callers charging this discounted bound
    /// must budget the index's held pages separately
    /// ([`PrefixIndexStats::pages_held`]), as the coordinator's generate
    /// worker does. Without an index this is the length-based bound.
    pub fn kv_pages_worst_for_prompt(&self, prompt: &[i32], want: usize) -> usize {
        let base = self.kv_pages_worst_for(prompt.len(), want);
        let Inner::Cached(ce) = &self.inner else { return base };
        let Some(ix) = &ce.prefix else { return base };
        if prompt.is_empty() {
            return base;
        }
        let kept = &prompt[prompt.len() - prompt.len().min(ce.arch.max_seq)..];
        // probe's cap (< kept pages) keeps the discount strictly below the
        // pages `base` budgets for the kept prompt — no underflow.
        base - 2 * ce.arch.n_layers * ix.lock().unwrap().probe(kept)
    }

    /// Advance every session by one token: each consumes its own greedy
    /// next token, all new rows run as one batched forward (cached path),
    /// and each session's logits then predict the following token.
    /// Sessions whose cache has reached `max_seq` are rolled first: the
    /// cache is rebuilt from the trailing half window (the same truncation
    /// semantics as the windowed fallback, paid once per half window
    /// instead of every step).
    pub fn decode_step(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        if sessions.is_empty() {
            return Ok(StepOut::default());
        }
        // Both failpoints sit before any session mutation: an injected
        // failure is retryable as-is, and a slow step only stretches
        // wall-clock (deadline pressure) without changing any token.
        if faults::should_fail(faults::ENGINE_DECODE) {
            return Err(EngineError::Injected { point: faults::ENGINE_DECODE }.into());
        }
        if faults::should_fail(faults::ENGINE_SLOW) {
            std::thread::sleep(std::time::Duration::from_millis(faults::SLOW_STEP_MS));
        }
        match &self.inner {
            Inner::Cached(ce) => {
                // Validate and roll *before* consuming any token, so a
                // pre-check failure leaves every session untouched.
                for (i, sess) in sessions.iter().enumerate() {
                    anyhow::ensure!(sess.kv.is_some(), "session {i} was not prefilled cached");
                }
                let pm = ce.param_map();
                let quant = ce.quant_inputs();
                // Roll every session whose cache hit max_seq as ONE ragged
                // re-prefill batch: each cache is rebuilt from the trailing
                // half window of its already-consumed context, with the
                // blocked matmuls amortized across all rolled sessions
                // (bit-exact vs rolling one at a time — batched prefill
                // accumulates each row independently). The prefill logits
                // are discarded: the next input token comes from the
                // pre-roll `last_logits`, exactly like the serial roll did.
                let w = (ce.arch.max_seq / 2).max(1);
                let mut roll_idx: Vec<usize> = Vec::new();
                let mut roll_prompts: Vec<Vec<i32>> = Vec::new();
                for (i, sess) in sessions.iter().enumerate() {
                    if sess.kv.as_ref().expect("checked above").len() >= ce.arch.max_seq {
                        roll_idx.push(i);
                        roll_prompts
                            .push(sess.tokens[sess.tokens.len().saturating_sub(w)..].to_vec());
                    }
                }
                if !roll_idx.is_empty() {
                    // Rebuild each rolled cache in a FRESH paged state and
                    // swap it in only once the batched re-prefill succeeds:
                    // a mid-roll failure (exhaustion, injected fault) leaves
                    // every live cache bit-identical to its pre-roll state,
                    // and the partial rebuild's pages release when `fresh`
                    // drops. The cost is transiently holding old + new pages
                    // for the rolled sessions — pressure the coordinator
                    // relieves by preempting a victim and retrying the step.
                    let mut fresh: Vec<KvState> = roll_idx
                        .iter()
                        .map(|_| KvState::new_paged(&ce.arch, &ce.pool))
                        .collect();
                    {
                        let mut kv_refs: Vec<&mut KvState> = fresh.iter_mut().collect();
                        let prompts: Vec<&[i32]> =
                            roll_prompts.iter().map(|p| p.as_slice()).collect();
                        forward_prefill_batch(&ce.arch, &pm, &prompts, Some(&quant), &mut kv_refs)?;
                    }
                    for ((&i, kept), kv) in roll_idx.iter().zip(roll_prompts).zip(fresh) {
                        sessions[i].tokens = kept;
                        sessions[i].kv = Some(kv);
                    }
                }
                let inputs: Vec<i32> = sessions.iter().map(|s| s.next_token()).collect();
                for (sess, &t) in sessions.iter_mut().zip(&inputs) {
                    sess.tokens.push(t);
                }
                let pre_lens: Vec<usize> = sessions.iter().map(|s| s.cached_tokens()).collect();
                let mut kvs: Vec<&mut KvState> =
                    sessions.iter_mut().map(|s| s.kv.as_mut().expect("checked above")).collect();
                let out = match forward_step_batch(&ce.arch, &pm, &inputs, &mut kvs, Some(&quant))
                {
                    Ok(out) => out,
                    Err(e) => {
                        // Restore every session to its pre-step state: a
                        // failed forward never advanced any cache length,
                        // but may have pushed physical rows into some
                        // layers — truncate trims those and returns their
                        // pages, and popping the input restores the token
                        // view, so the same step can simply be retried.
                        for (sess, &len) in sessions.iter_mut().zip(&pre_lens) {
                            sess.tokens.pop();
                            if let Some(kv) = sess.kv.as_mut() {
                                kv.truncate(len);
                            }
                        }
                        return Err(e);
                    }
                };
                let vocab = ce.arch.vocab;
                let mut kv_tokens = 0u64;
                let mut bits_weighted = 0.0f64;
                for (i, sess) in sessions.iter_mut().enumerate() {
                    sess.last_logits = out.logits[i * vocab..(i + 1) * vocab].to_vec();
                    sess.steps += 1;
                    let t = sess.cached_tokens() as u64;
                    kv_tokens += t;
                    let bits = sess
                        .kv
                        .as_ref()
                        .map(|kv| kv.effective_kv_bits())
                        .unwrap_or_else(|| ce.kv.bits_per_value());
                    bits_weighted += bits * t as f64;
                }
                let kv_bits_per_value = if kv_tokens > 0 {
                    bits_weighted / kv_tokens as f64
                } else {
                    ce.kv.bits_per_value()
                };
                Ok(StepOut {
                    rows: sessions.len(),
                    act_fp8: out.act_fp8,
                    kv_tokens,
                    kv_bits_per_value,
                    kv_mix: vec![(ce.arch.d_model, kv_bits_per_value)],
                    drafted: 0,
                    accepted: 0,
                })
            }
            Inner::Windowed(we) => {
                let inputs: Vec<i32> = sessions.iter().map(|s| s.next_token()).collect();
                for (sess, &t) in sessions.iter_mut().zip(&inputs) {
                    sess.tokens.push(t);
                }
                if let Err(e) = we.refresh_logits(sessions) {
                    for sess in sessions.iter_mut() {
                        sess.tokens.pop();
                    }
                    return Err(e);
                }
                for sess in sessions.iter_mut() {
                    sess.steps += 1;
                }
                Ok(StepOut {
                    rows: sessions.len(),
                    act_fp8: Vec::new(),
                    kv_tokens: 0,
                    kv_bits_per_value: 16.0,
                    kv_mix: Vec::new(),
                    drafted: 0,
                    accepted: 0,
                })
            }
        }
    }

    /// The cached-engine state, when this engine runs the cached path
    /// (`None` on the windowed fallback). The speculative decoder builds
    /// its draft view from these parameters and drives the draft forward
    /// with the same activation weightings/thresholds.
    pub(crate) fn cached(&self) -> Option<&CachedEngine> {
        match &self.inner {
            Inner::Cached(ce) => Some(ce),
            Inner::Windowed(_) => None,
        }
    }

    /// The speculative **verify pass**: extend every session's cache by its
    /// drafted token chain in one ragged batched forward
    /// ([`forward_extend_batch`]) and return logits for *all* chain rows —
    /// `(Σkᵢ, V)` in session order. Touches only KV and returns raw logits;
    /// the caller owns token bookkeeping, acceptance, and rollback (via
    /// [`KvState::truncate`] on the session's cache). Cached path only.
    pub(crate) fn extend_batch(
        &self,
        sessions: &mut [&mut Session],
        chains: &[&[i32]],
    ) -> Result<ForwardOut> {
        match &self.inner {
            Inner::Cached(ce) => {
                for (i, sess) in sessions.iter().enumerate() {
                    anyhow::ensure!(sess.kv.is_some(), "session {i} was not prefilled cached");
                }
                let pm = ce.param_map();
                let quant = ce.quant_inputs();
                let mut kvs: Vec<&mut KvState> = sessions
                    .iter_mut()
                    .map(|s| s.kv.as_mut().expect("checked above"))
                    .collect();
                forward_extend_batch(&ce.arch, &pm, chains, &mut kvs, Some(&quant))
            }
            Inner::Windowed(_) => {
                anyhow::bail!("windowed engine holds no cache to extend (speculative verify)")
            }
        }
    }

    /// KV-traffic accounting over the sessions' *current* cache state —
    /// the same token-weighted mix [`Engine::decode_step`] reports, reused
    /// by the speculative round after acceptance/rollback. Returns
    /// `(kv_tokens, kv_bits_per_value, kv_mix)`.
    pub(crate) fn kv_step_stats(&self, sessions: &[&mut Session]) -> (u64, f64, Vec<(usize, f64)>) {
        let ce = match &self.inner {
            Inner::Cached(ce) => ce,
            Inner::Windowed(_) => return (0, 16.0, Vec::new()),
        };
        let mut kv_tokens = 0u64;
        let mut bits_weighted = 0.0f64;
        for sess in sessions.iter() {
            let t = sess.cached_tokens() as u64;
            kv_tokens += t;
            let bits = sess
                .kv
                .as_ref()
                .map(|kv| kv.effective_kv_bits())
                .unwrap_or_else(|| ce.kv.bits_per_value());
            bits_weighted += bits * t as f64;
        }
        let kv_bits_per_value = if kv_tokens > 0 {
            bits_weighted / kv_tokens as f64
        } else {
            ce.kv.bits_per_value()
        };
        (kv_tokens, kv_bits_per_value, vec![(ce.arch.d_model, kv_bits_per_value)])
    }
}

impl WindowedEngine {
    /// Recompute next-token logits for each session from its trailing
    /// window, packing up to `batch` sessions per one-shot run (the
    /// fixed-shape graph batch).
    fn refresh_logits(&self, sessions: &mut [&mut Session]) -> Result<()> {
        for chunk in sessions.chunks_mut(self.batch) {
            let (b, s) = (self.batch, self.seq);
            let mut tokens = vec![0i32; b * s];
            for (row, sess) in chunk.iter().enumerate() {
                let ctx = &sess.tokens;
                let start = ctx.len().saturating_sub(s);
                let window = &ctx[start..];
                let off = s - window.len();
                tokens[row * s + off..(row + 1) * s].copy_from_slice(window);
            }
            let mut args = vec![ArgValue::I32 { shape: vec![b, s], data: tokens }];
            args.extend(self.tail.iter().cloned());
            let out = self.exe.run(&args)?;
            let vocab = out[0].len() / b;
            for (row, sess) in chunk.iter_mut().enumerate() {
                sess.last_logits = out[0][row * vocab..(row + 1) * vocab].to_vec();
            }
        }
        Ok(())
    }
}

/// Split a `logits_quant` argument tail into owned (params, activation
/// weightings, thresholds) following the manifest's parameter inventory —
/// the same layout `NativeGraph::run` consumes positionally. Packed weight
/// arguments stay packed (`Arc`-shared with the caller's tail): the engine
/// holds no dequantized f32 weight copy.
#[allow(clippy::type_complexity)]
pub(crate) fn parse_tail(
    man: &Manifest,
    tail: &[ArgValue],
) -> Result<(Vec<(String, ParamData)>, Vec<Vec<f32>>, Vec<f32>)> {
    let np = man.param_names.len();
    let nl = man.num_linears;
    anyhow::ensure!(
        tail.len() == np + nl + 1,
        "logits tail has {} args, expected {np} params + {nl} weightings + thresholds",
        tail.len()
    );
    let mut params = Vec::with_capacity(np);
    for (i, name) in man.param_names.iter().enumerate() {
        let want: usize = man.param_shapes[name].iter().product();
        let a = &tail[i];
        anyhow::ensure!(
            a.elements() == want,
            "parameter '{name}' has {} elements, want {want}",
            a.elements()
        );
        let data = match a {
            ArgValue::PackedW { panels, .. } => ParamData::Packed(panels.clone()),
            other => ParamData::Dense(other.as_f32()?.to_vec()),
        };
        params.push((name.clone(), data));
    }
    let mut act_weights = Vec::with_capacity(nl);
    for i in 0..nl {
        act_weights.push(tail[np + i].as_f32()?.to_vec());
    }
    let thresholds = tail[np + nl].as_f32()?.to_vec();
    anyhow::ensure!(thresholds.len() == nl, "thresholds length");
    Ok((params, act_weights, thresholds))
}
