//! Backend-neutral host-side tensor arguments.
//!
//! `ArgValue` is what the evaluator and the serving coordinator traffic in:
//! plain shaped `Vec<f32>` / `Vec<i32>` buffers. The native backend consumes
//! them directly; the PJRT backend (feature `pjrt`) converts them to
//! `xla::Literal`s in the feature-gated `literal` module.

/// A host-side argument value.
#[derive(Debug, Clone)]
pub enum ArgValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl ArgValue {
    pub fn scalar_f32(v: f32) -> Self {
        ArgValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn vec_f32(data: Vec<f32>) -> Self {
        ArgValue::F32 { shape: vec![data.len()], data }
    }

    /// Logical element count.
    pub fn elements(&self) -> usize {
        match self {
            ArgValue::F32 { data, .. } => data.len(),
            ArgValue::I32 { data, .. } => data.len(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            ArgValue::F32 { shape, .. } => shape,
            ArgValue::I32 { shape, .. } => shape,
        }
    }

    /// Borrow as f32 data, or error with the argument's position context.
    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            ArgValue::F32 { data, .. } => Ok(data),
            ArgValue::I32 { .. } => anyhow::bail!("expected f32 argument, got i32"),
        }
    }

    /// Borrow as i32 data.
    pub fn as_i32(&self) -> crate::Result<&[i32]> {
        match self {
            ArgValue::I32 { data, .. } => Ok(data),
            ArgValue::F32 { .. } => anyhow::bail!("expected i32 argument, got f32"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let s = ArgValue::scalar_f32(2.5);
        assert_eq!(s.elements(), 1);
        assert!(s.shape().is_empty());
        let v = ArgValue::vec_f32(vec![1.0, 2.0]);
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(v.as_i32().is_err());
    }
}
