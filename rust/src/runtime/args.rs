//! Backend-neutral host-side tensor arguments.
//!
//! `ArgValue` is what the evaluator and the serving coordinator traffic in:
//! plain shaped `Vec<f32>` / `Vec<i32>` buffers, plus **packed** FGMP
//! weight tensors in their k-panelized execution layout. The native
//! backend consumes dense buffers directly and runs packed weights
//! straight off their bits; the PJRT backend (feature `pjrt`) converts
//! dense values to `xla::Literal`s in the feature-gated `literal` module
//! and materializes packed weights on demand there (the only place a
//! dequantized f32 copy ever exists).

use std::sync::Arc;

use crate::quant::PackedPanels;

/// A host-side argument value.
#[derive(Debug, Clone)]
pub enum ArgValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
    /// A linear weight in the packed FGMP execution format. `shape` is the
    /// logical dense shape `[k_in, n_out]`; the `Arc` makes tail clones
    /// (one per worker / per batch) byte-cheap.
    PackedW { shape: Vec<usize>, panels: Arc<PackedPanels> },
}

impl ArgValue {
    pub fn scalar_f32(v: f32) -> Self {
        ArgValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn vec_f32(data: Vec<f32>) -> Self {
        ArgValue::F32 { shape: vec![data.len()], data }
    }

    /// Logical element count. For packed weights this is the panels'
    /// actual `k·n` (not the self-reported shape), so the load-time size
    /// checks in the engine/native graph compare real tensor dimensions
    /// against the manifest — exactly as `data.len()` does for dense.
    pub fn elements(&self) -> usize {
        match self {
            ArgValue::F32 { data, .. } => data.len(),
            ArgValue::I32 { data, .. } => data.len(),
            ArgValue::PackedW { panels, .. } => panels.k * panels.n,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            ArgValue::F32 { shape, .. } => shape,
            ArgValue::I32 { shape, .. } => shape,
            ArgValue::PackedW { shape, .. } => shape,
        }
    }

    /// Borrow as f32 data, or error with the argument's position context.
    /// Packed weights refuse: consumers either execute off the bits
    /// (native) or materialize explicitly (PJRT literal conversion).
    pub fn as_f32(&self) -> crate::Result<&[f32]> {
        match self {
            ArgValue::F32 { data, .. } => Ok(data),
            ArgValue::I32 { .. } => anyhow::bail!("expected f32 argument, got i32"),
            ArgValue::PackedW { .. } => {
                anyhow::bail!("expected f32 argument, got packed weight (materialize explicitly)")
            }
        }
    }

    /// Borrow as i32 data.
    pub fn as_i32(&self) -> crate::Result<&[i32]> {
        match self {
            ArgValue::I32 { data, .. } => Ok(data),
            _ => anyhow::bail!("expected i32 argument"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constructors_and_accessors() {
        let s = ArgValue::scalar_f32(2.5);
        assert_eq!(s.elements(), 1);
        assert!(s.shape().is_empty());
        let v = ArgValue::vec_f32(vec![1.0, 2.0]);
        assert_eq!(v.shape(), &[2]);
        assert_eq!(v.as_f32().unwrap(), &[1.0, 2.0]);
        assert!(v.as_i32().is_err());
    }
}
