//! The PJRT CPU client and compiled-executable handles (feature `pjrt`).
//!
//! Interchange is HLO *text* (see python/compile/aot.py):
//! `HloModuleProto::from_text_file` reparses and reassigns instruction ids,
//! sidestepping the 64-bit-id protos that xla_extension 0.5.1 rejects.
//! Graphs are lowered with return_tuple=True, so outputs arrive as one tuple
//! literal we decompose here.
//!
//! The `xla` crate is not vendored; compiling with `--features pjrt`
//! requires supplying it (path override / [patch]). The default build never
//! touches this module — the native backend covers every test and CLI path.

use std::path::Path;
use std::sync::Arc;

use anyhow::Context;

use crate::Result;

use super::args::ArgValue;

/// Shared PJRT CPU client.
#[derive(Clone)]
pub struct PjrtRuntime {
    client: Arc<xla::PjRtClient>,
}

impl PjrtRuntime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(PjrtRuntime { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text file into an executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<PjrtExecutable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(PjrtExecutable { exe: Arc::new(exe), name: path.display().to_string() })
    }
}

/// One compiled graph. Cheap to clone; `run` is synchronous. Not `Send`
/// (xla's PJRT handles are Rc-based) — each worker thread builds its own.
#[derive(Clone)]
pub struct PjrtExecutable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

impl PjrtExecutable {
    /// Execute with host args; returns the flattened f32 elements of each
    /// tuple field (all our graph outputs are f32).
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let fields = out.to_tuple().context("decomposing result tuple")?;
        fields
            .into_iter()
            .map(|l| {
                let v = l.to_vec::<f32>().context("reading f32 output")?;
                Ok(v)
            })
            .collect()
    }
}
