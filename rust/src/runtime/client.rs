//! The PJRT CPU client and compiled-executable handles.
//!
//! Interchange is HLO *text* (see python/compile/aot.py and
//! /opt/xla-example/README.md): `HloModuleProto::from_text_file` reparses
//! and reassigns instruction ids, sidestepping the 64-bit-id protos that
//! xla_extension 0.5.1 rejects. Graphs are lowered with return_tuple=True,
//! so outputs arrive as one tuple literal we decompose here.

use std::path::Path;
use std::sync::Arc;

use anyhow::Context;

use crate::Result;

use super::literal::ArgValue;

/// Shared PJRT CPU client.
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an HLO-text file into an executable.
    pub fn load_hlo(&self, path: impl AsRef<Path>) -> Result<Executable> {
        let path = path.as_ref();
        let proto = xla::HloModuleProto::from_text_file(path.to_str().unwrap())
            .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe: Arc::new(exe), name: path.display().to_string() })
    }
}

/// One compiled graph. Cheap to clone; `execute` is synchronous.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
    pub name: String,
}

/// One output tensor, flattened.
#[derive(Debug, Clone)]
pub struct OutValue {
    pub data: Vec<f32>,
}

impl Executable {
    /// Execute with host args; returns the flattened f32 elements of each
    /// tuple field (all our graph outputs are f32).
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        let literals: Vec<xla::Literal> =
            args.iter().map(|a| a.to_literal()).collect::<Result<_>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.name))?;
        let out = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let fields = out.to_tuple().context("decomposing result tuple")?;
        fields
            .into_iter()
            .map(|l| {
                let v = l.to_vec::<f32>().context("reading f32 output")?;
                Ok(v)
            })
            .collect()
    }
}
