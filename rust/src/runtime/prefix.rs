//! Prefix-trie admission index: prefill shared prompt prefixes **once**.
//!
//! Real chat traffic serves a handful of system/few-shot prompts to huge
//! user populations; without sharing, two sessions with identical prompt
//! prefixes prefill and store identical KV pages twice. [`PrefixIndex`]
//! keys a trie on whole [`PAGE_TOKENS`]-token chunks of the prompt: each
//! node covers one chunk and holds the page ids (one per K/V buffer,
//! layer-major K then V — the [`KvState::map_prefix`] order) that a prior
//! prefill produced for exactly those tokens, plus the cumulative
//! attention-PPU block counts up to that depth. The index holds **strong**
//! refcounts on its pages (page ids are recycled by the pool, so weak
//! references would be unsound);
//! [`Engine::prefill`](crate::runtime::Engine::prefill) consults it, maps
//! the deepest fully-matching chain of pages into the new session's table
//! by reference, and prefills only the divergent suffix. The matched depth is
//! capped below the full prompt so the suffix is never empty — the session
//! always computes its own last-token logits.
//!
//! Under pool pressure the engine evicts the least-recently-used root
//! subtree ([`PrefixIndex::evict_lru`]) and retries: index pages are a
//! cache, sessions are load, and load wins. Everything here is
//! engine-private behind a `Mutex` — the pool's own refcounts make the
//! sharing itself thread-safe, the lock only guards the trie structure.

use std::collections::HashMap;
use std::sync::Arc;

use crate::model::kv::{KvPool, KvState, PAGE_TOKENS};

/// One trie node: a single prompt chunk's pages plus subtree.
struct Node {
    /// Page ids holding this chunk's `PAGE_TOKENS` rows, one per K/V
    /// buffer (layer-major K then V). Strongly retained by the index.
    pages: Vec<u32>,
    /// Cumulative PPU `(fp8_blocks, total_blocks)` per buffer covering
    /// chunks `0..=this` — the seed [`KvState::map_prefix`] installs so
    /// mapped rows price like the prefill that produced them. Scaled
    /// proportionally from the registering session's aggregate counters
    /// (the same approximation `KvState::truncate` applies).
    ppu: Vec<(u64, u64)>,
    children: HashMap<Vec<i32>, Node>,
    /// Logical timestamp of the last lookup that traversed this node
    /// (ticks, not wall time — deterministic). Eviction takes the root
    /// subtree with the smallest value.
    last_used: u64,
}

impl Node {
    /// Pages held by this node and every descendant.
    fn subtree_pages(&self, out: &mut Vec<u32>) {
        out.extend_from_slice(&self.pages);
        for c in self.children.values() {
            c.subtree_pages(out);
        }
    }
}

/// A successful prefix match: everything [`KvState::map_prefix`] needs.
/// Valid only while the index lock is held — eviction could otherwise
/// release the pages before the session retains them.
pub struct PrefixHit {
    /// Matched whole-chunk rows (`depth × PAGE_TOKENS`), always less than
    /// the looked-up prompt length.
    pub rows: usize,
    /// Per-buffer page chains (layer-major K then V), one id per chunk.
    pub per_buf: Vec<Vec<u32>>,
    /// Cumulative PPU seed per buffer at the matched depth.
    pub ppu: Vec<(u64, u64)>,
}

impl PrefixHit {
    /// Borrow the page chains in the `&[&[u32]]` shape `map_prefix` takes.
    pub fn per_buf_refs(&self) -> Vec<&[u32]> {
        self.per_buf.iter().map(|v| v.as_slice()).collect()
    }
}

/// Running counters for the serve report.
#[derive(Debug, Clone, Copy, Default)]
pub struct PrefixIndexStats {
    /// Lookups that mapped at least one chunk.
    pub hits: u64,
    /// Lookups that matched nothing (including too-short prompts).
    pub misses: u64,
    /// Whole-page tokens lookups mapped by reference instead of
    /// re-prefilling — the compute the index saved.
    pub tokens_reused: u64,
    /// Pages the index itself currently holds references on.
    pub pages_held: usize,
    /// Root subtrees evicted under pool pressure.
    pub evictions: u64,
}

/// The trie. One per [`Engine`](crate::runtime::Engine), guarding the
/// shared pool's prefix pages.
pub struct PrefixIndex {
    pool: Arc<KvPool>,
    /// K/V buffers per session (`2 × n_layers`) — every node's `pages`
    /// and `ppu` have exactly this many entries.
    bufs: usize,
    roots: HashMap<Vec<i32>, Node>,
    tick: u64,
    pages_held: usize,
    hits: u64,
    misses: u64,
    tokens_reused: u64,
    evictions: u64,
}

impl PrefixIndex {
    pub fn new(pool: Arc<KvPool>, n_layers: usize) -> Self {
        PrefixIndex {
            pool,
            bufs: 2 * n_layers,
            roots: HashMap::new(),
            tick: 0,
            pages_held: 0,
            hits: 0,
            misses: 0,
            tokens_reused: 0,
            evictions: 0,
        }
    }

    pub fn stats(&self) -> PrefixIndexStats {
        PrefixIndexStats {
            hits: self.hits,
            misses: self.misses,
            tokens_reused: self.tokens_reused,
            pages_held: self.pages_held,
            evictions: self.evictions,
        }
    }

    /// Walk the deepest chain of whole chunks of `prompt` the trie covers,
    /// capped at `(prompt.len() − 1) / PAGE_TOKENS` chunks so the unshared
    /// suffix is never empty. Returns `None` on no match. The returned
    /// pages stay alive through the *index's* refcounts — map them into a
    /// session (which retains its own references) before releasing the
    /// index lock.
    pub fn lookup(&mut self, prompt: &[i32]) -> Option<PrefixHit> {
        self.tick += 1;
        let max_chunks = prompt.len().saturating_sub(1) / PAGE_TOKENS;
        let mut per_buf: Vec<Vec<u32>> = vec![Vec::new(); self.bufs];
        let mut ppu: Vec<(u64, u64)> = Vec::new();
        let mut depth = 0;
        let mut level = &mut self.roots;
        while depth < max_chunks {
            let key = &prompt[depth * PAGE_TOKENS..(depth + 1) * PAGE_TOKENS];
            let Some(node) = level.get_mut(key) else { break };
            node.last_used = self.tick;
            for (chain, &pg) in per_buf.iter_mut().zip(&node.pages) {
                chain.push(pg);
            }
            ppu.clone_from(&node.ppu);
            depth += 1;
            level = &mut node.children;
        }
        if depth == 0 {
            self.misses += 1;
            return None;
        }
        self.hits += 1;
        let rows = depth * PAGE_TOKENS;
        self.tokens_reused += rows as u64;
        Some(PrefixHit { rows, per_buf, ppu })
    }

    /// Non-mutating depth probe: how many whole chunks of `prompt` the
    /// trie currently covers (same cap as [`PrefixIndex::lookup`], without
    /// touching hit/miss counters or LRU ticks). Admission control uses
    /// this to discount a request's worst-case page bound ahead of the
    /// prefill that actually maps the pages.
    pub fn probe(&self, prompt: &[i32]) -> usize {
        let max_chunks = prompt.len().saturating_sub(1) / PAGE_TOKENS;
        let mut depth = 0;
        let mut level = &self.roots;
        while depth < max_chunks {
            let key = &prompt[depth * PAGE_TOKENS..(depth + 1) * PAGE_TOKENS];
            let Some(node) = level.get(key) else { break };
            depth += 1;
            level = &node.children;
        }
        depth
    }

    /// Record a freshly-prefilled session's whole pages under its prompt:
    /// every complete `PAGE_TOKENS` chunk of `prompt` gets (or already
    /// has) a node, new nodes retaining that chunk's page per buffer.
    /// `kv` must be the paged cache holding exactly `prompt`'s rows.
    pub fn register(&mut self, prompt: &[i32], kv: &KvState) {
        if !kv.is_paged() {
            return;
        }
        debug_assert_eq!(kv.len(), prompt.len(), "register after a full prefill");
        let whole = kv.len() / PAGE_TOKENS;
        if whole == 0 {
            return;
        }
        // Aggregate PPU counters per buffer (layer-major K then V), scaled
        // to each depth below.
        let buf_ppu: Vec<(u64, u64)> = kv
            .layers
            .iter()
            .flat_map(|l| [l.k.ppu_counts(), l.v.ppu_counts()])
            .collect();
        debug_assert_eq!(buf_ppu.len(), self.bufs);
        let tables: Vec<&[u32]> = kv
            .layers
            .iter()
            .flat_map(|l| [&l.k, &l.v])
            .map(|b| b.page_ids(whole))
            .collect();
        self.tick += 1;
        let mut level = &mut self.roots;
        for depth in 0..whole {
            let key = prompt[depth * PAGE_TOKENS..(depth + 1) * PAGE_TOKENS].to_vec();
            let node = level.entry(key).or_insert_with(|| {
                let pages: Vec<u32> = tables.iter().map(|t| t[depth]).collect();
                self.pool.retain(&pages);
                self.pages_held += pages.len();
                let scale = ((depth + 1) * PAGE_TOKENS) as f64 / kv.len() as f64;
                let ppu = buf_ppu
                    .iter()
                    .map(|&(hi, total)| {
                        (
                            (hi as f64 * scale).round() as u64,
                            (total as f64 * scale).round() as u64,
                        )
                    })
                    .collect();
                Node { pages, ppu, children: HashMap::new(), last_used: 0 }
            });
            node.last_used = self.tick;
            level = &mut node.children;
        }
    }

    /// Evict the least-recently-used **root subtree**, releasing every
    /// page it held, and return how many references were dropped (0 when
    /// the index is empty). Root granularity matches the workload: each
    /// root is one system prompt's tree, and half-evicted trees would keep
    /// their most-shared (earliest) pages unreachable anyway.
    pub fn evict_lru(&mut self) -> usize {
        let Some(key) =
            self.roots.iter().min_by_key(|(_, n)| n.last_used).map(|(k, _)| k.clone())
        else {
            return 0;
        };
        let node = self.roots.remove(&key).expect("key found above");
        let mut pages = Vec::new();
        node.subtree_pages(&mut pages);
        self.pool.release(&pages);
        self.pages_held -= pages.len();
        self.evictions += 1;
        pages.len()
    }

    /// Drop every cached prefix (release all held pages).
    pub fn clear(&mut self) {
        while self.evict_lru() > 0 {}
        self.evictions = 0;
    }
}

impl Drop for PrefixIndex {
    fn drop(&mut self) {
        let mut pages = Vec::new();
        for n in self.roots.values() {
            n.subtree_pages(&mut pages);
        }
        self.pool.release(&pages);
        self.roots.clear();
        self.pages_held = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::forward::{Act, ModelArch, NormKind, PosKind};
    use crate::model::kv::KvPrecision;
    use crate::util::Rng;

    fn arch() -> ModelArch {
        ModelArch {
            vocab: 32,
            d_model: 16,
            n_layers: 2,
            n_heads: 2,
            d_ff: 32,
            act: Act::SwiGlu,
            norm: NormKind::Rms,
            pos: PosKind::Rope,
            max_seq: 128,
        }
    }

    /// A prompt of `n` tokens and a paged cache "prefilled" with one row
    /// per token (synthetic rows — the index never reads payloads).
    fn fake_prefill(a: &ModelArch, pool: &Arc<KvPool>, prompt: &[i32]) -> KvState {
        let mut kv = KvState::new_paged(a, pool);
        kv.reserve(prompt.len()).unwrap();
        let mut rng = Rng::new(7);
        for _ in 0..prompt.len() {
            let row = rng.normal_vec(a.d_model, 1.0);
            for l in &mut kv.layers {
                l.k.push_row(&row);
                l.v.push_row(&row);
            }
            kv.advance(1);
        }
        kv
    }

    fn prompt(seed: i32, n: usize) -> Vec<i32> {
        (0..n as i32).map(|i| seed * 1000 + i).collect()
    }

    #[test]
    fn prefix_trie_matches_whole_chunks_and_caps_below_prompt_len() {
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp8, 256);
        let mut ix = PrefixIndex::new(pool.clone(), a.n_layers);

        // Register a 2.5-page prompt: 2 whole chunks enter the trie, each
        // holding one page per K/V buffer.
        let p = prompt(1, 2 * PAGE_TOKENS + 8);
        let kv = fake_prefill(&a, &pool, &p);
        let before = pool.stats();
        ix.register(&p, &kv);
        let s = pool.stats();
        assert_eq!(ix.stats().pages_held, 2 * 2 * a.n_layers);
        assert_eq!(s.in_use_pages, before.in_use_pages, "the index allocates nothing");
        assert_eq!(s.logical_pages, before.logical_pages + ix.stats().pages_held);
        // Re-registering the same prompt adds nothing.
        ix.register(&p, &kv);
        assert_eq!(ix.stats().pages_held, 2 * 2 * a.n_layers);

        // The identical prompt matches both whole chunks (8 tokens of
        // suffix remain); PPU seeds arrive per buffer.
        let hit = ix.lookup(&p).expect("registered prefix must hit");
        assert_eq!(hit.rows, 2 * PAGE_TOKENS);
        assert_eq!(hit.per_buf.len(), 2 * a.n_layers);
        assert!(hit.per_buf.iter().all(|c| c.len() == 2));
        assert_eq!(hit.ppu.len(), 2 * a.n_layers);

        // A prompt of exactly the registered whole pages is capped one
        // chunk short — the divergent suffix is never empty.
        let exact = &p[..2 * PAGE_TOKENS];
        let hit = ix.lookup(exact).expect("shorter prefix still hits");
        assert_eq!(hit.rows, PAGE_TOKENS, "cap keeps the last chunk unshared");

        // A prompt diverging inside chunk 2 matches chunk 1 only; one
        // diverging inside chunk 1 misses entirely.
        let mut div = p.clone();
        div[PAGE_TOKENS + 3] += 1;
        assert_eq!(ix.lookup(&div).unwrap().rows, PAGE_TOKENS);
        let mut div0 = p.clone();
        div0[2] += 1;
        assert!(ix.lookup(&div0).is_none());
        assert!(ix.lookup(&p[..PAGE_TOKENS]).is_none(), "too short to share");

        // The mapped-into-session flow: pages stay valid because both the
        // index and the session hold references.
        let mut mapped = KvState::new_paged(&a, &pool);
        let hit = ix.lookup(&p).unwrap();
        mapped.map_prefix(&hit.per_buf_refs(), hit.rows, &hit.ppu);
        assert_eq!(mapped.len(), 2 * PAGE_TOKENS);
        drop(kv); // the registering session retires; index still holds pages
        assert_eq!(pool.stats().logical_pages, ix.stats().pages_held + mapped.kv_pages());
    }

    #[test]
    fn prefix_eviction_is_lru_at_root_granularity_and_releases_pages() {
        let a = arch();
        let pool = KvPool::new(&a, KvPrecision::Fp16, 256);
        let mut ix = PrefixIndex::new(pool.clone(), a.n_layers);

        let p1 = prompt(1, PAGE_TOKENS + 4);
        let p2 = prompt(2, PAGE_TOKENS + 4);
        let kv1 = fake_prefill(&a, &pool, &p1);
        let kv2 = fake_prefill(&a, &pool, &p2);
        ix.register(&p1, &kv1);
        ix.register(&p2, &kv2);
        drop(kv1);
        drop(kv2);
        // Only the index holds the 2 × (1 page per buffer) now.
        assert_eq!(pool.stats().in_use_pages, 2 * 2 * a.n_layers);
        assert_eq!(pool.stats().logical_pages, ix.stats().pages_held);

        // Touch p2 so p1 becomes LRU, then evict once.
        let _ = ix.lookup(&p2);
        let freed = ix.evict_lru();
        assert_eq!(freed, 2 * a.n_layers);
        assert!(ix.lookup(&p1).is_none(), "p1's subtree is gone");
        assert!(ix.lookup(&p2).is_some(), "p2 survived eviction");
        assert_eq!(pool.stats().in_use_pages, 2 * a.n_layers);

        // clear() then drains the rest; dropped index releases nothing
        // twice (free list bounded — debug asserts in the pool).
        ix.clear();
        assert_eq!(ix.stats().pages_held, 0);
        assert_eq!(pool.stats().in_use_pages, 0);
        drop(ix);
        assert_eq!(pool.stats().free_pages, 256);
    }
}
