//! PJRT runtime: load AOT-compiled HLO text and execute it on the CPU
//! client. This is the only place the `xla` crate is touched; everything
//! above works with plain `Vec<f32>`/`Vec<i32>` tensors.

pub mod client;
pub mod literal;

pub use client::{Executable, Runtime};
pub use literal::{lit_f32, lit_i32, ArgValue};
