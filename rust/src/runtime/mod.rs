//! Execution runtimes behind one backend-agnostic API.
//!
//! * **native** (default, hermetic) — [`native::NativeGraph`] reruns the
//!   manifest-described transformer in pure Rust; no HLO files, no PJRT, no
//!   Python anywhere. This is what `Runtime::cpu()` gives you.
//! * **pjrt** (feature `pjrt`) — the original XLA path: AOT-lowered HLO text
//!   compiled by the PJRT CPU client (the feature-gated `client` module).
//!   Select it at runtime with `FGMP_BACKEND=pjrt` once the feature (and the
//!   `xla` crate) is compiled in.
//!
//! Callers describe *what* to run with an [`ExecSpec`] (artifacts dir, model
//! name, [`GraphKind`]); the runtime decides *how*. `ExecSpec` is plain data
//! and crosses threads freely, which is what the serving coordinator's
//! worker threads rely on.
//!
//! Two execution styles sit on top:
//! * one-shot [`Executable::run`] — stateless, the Score/eval path;
//! * stateful [`Engine`] sessions — KV-cached prefill/decode for
//!   generation ([`engine`]), falling back to windowed recompute through
//!   the one-shot API on backends without the native cached path.

pub mod args;
pub mod engine;
pub mod error;
pub mod native;
pub mod prefix;
pub mod sharded;
pub mod spec;

#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(feature = "pjrt")]
pub mod literal;

use std::path::{Path, PathBuf};

pub use args::ArgValue;
pub use engine::{Engine, EngineOptions, Session, StepOut};
pub use error::{catch_worker, EngineError};
pub use prefix::{PrefixIndex, PrefixIndexStats};
pub use sharded::{build_engine, InferenceEngine, ShardedEngine};
pub use spec::SpecEngine;
#[cfg(feature = "pjrt")]
pub use client::PjrtRuntime;
#[cfg(feature = "pjrt")]
pub use literal::{lit_f32, lit_i32};

use crate::io::Manifest;
use crate::Result;

/// Which exported graph to run (signatures in `manifest.json`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    /// `(tokens, mask, *params, *act_weights, thresholds)` →
    /// `(nll_sum[B], ntok[B], fp8_frac[NL])`.
    FwdQuant,
    /// `(tokens, mask, *params)` → `(nll_sum[B], ntok[B])`.
    FwdRef,
    /// `(tokens, *params, *act_weights, thresholds)` → `(last_logits[B,V])`.
    LogitsQuant,
}

impl GraphKind {
    /// Manifest/graph-file stem.
    pub fn stem(&self) -> &'static str {
        match self {
            GraphKind::FwdQuant => "fwd_quant",
            GraphKind::FwdRef => "fwd_ref",
            GraphKind::LogitsQuant => "logits_quant",
        }
    }
}

/// A graph to load: where, which model, which kind. Plain data — `Send`,
/// `Clone` — so coordinator workers can each materialize their own
/// executable from it.
#[derive(Debug, Clone)]
pub struct ExecSpec {
    pub artifacts: PathBuf,
    pub model: String,
    pub kind: GraphKind,
}

impl ExecSpec {
    pub fn new(artifacts: impl AsRef<Path>, model: &str, kind: GraphKind) -> Self {
        ExecSpec { artifacts: artifacts.as_ref().to_path_buf(), model: model.to_string(), kind }
    }

    /// The model directory holding manifest.json (and HLO text for pjrt).
    pub fn model_dir(&self) -> PathBuf {
        self.artifacts.join(&self.model)
    }

    /// The AOT HLO text path (pjrt backend).
    pub fn hlo_path(&self) -> PathBuf {
        self.model_dir().join(format!("{}.hlo.txt", self.kind.stem()))
    }
}

/// Backend selector.
#[derive(Clone)]
enum Backend {
    Native,
    #[cfg(feature = "pjrt")]
    Pjrt(client::PjrtRuntime),
}

/// A runtime handle. `cpu()` picks the hermetic native backend unless the
/// `pjrt` feature is compiled in *and* `FGMP_BACKEND=pjrt` is set.
#[derive(Clone)]
pub struct Runtime {
    backend: Backend,
}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        #[cfg(feature = "pjrt")]
        if std::env::var("FGMP_BACKEND").as_deref() == Ok("pjrt") {
            return Ok(Runtime { backend: Backend::Pjrt(client::PjrtRuntime::cpu()?) });
        }
        Ok(Runtime { backend: Backend::Native })
    }

    /// Force the native backend (tests).
    pub fn native() -> Self {
        Runtime { backend: Backend::Native }
    }

    pub fn platform(&self) -> String {
        match &self.backend {
            Backend::Native => "native-cpu".to_string(),
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => rt.platform(),
        }
    }

    /// Load one graph of one model.
    pub fn load_spec(&self, spec: &ExecSpec) -> Result<Executable> {
        match &self.backend {
            Backend::Native => {
                let manifest = Manifest::load(spec.model_dir().join("manifest.json"))?;
                Ok(Executable::Native(native::NativeGraph::new(manifest, spec.kind)?))
            }
            #[cfg(feature = "pjrt")]
            Backend::Pjrt(rt) => Ok(Executable::Pjrt(rt.load_hlo(spec.hlo_path())?)),
        }
    }
}

/// One loaded graph, whatever the backend. Cheap to clone.
#[derive(Clone)]
pub enum Executable {
    Native(native::NativeGraph),
    #[cfg(feature = "pjrt")]
    Pjrt(client::PjrtExecutable),
}

impl Executable {
    /// Execute with host args; returns the flattened f32 elements of each
    /// output tuple field.
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        match self {
            Executable::Native(g) => g.run(args),
            #[cfg(feature = "pjrt")]
            Executable::Pjrt(e) => e.run(args),
        }
    }

    pub fn name(&self) -> &str {
        match self {
            Executable::Native(g) => g.name(),
            #[cfg(feature = "pjrt")]
            Executable::Pjrt(e) => &e.name,
        }
    }
}
