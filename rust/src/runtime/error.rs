//! Typed engine failure conditions.
//!
//! The engines speak `anyhow` (`crate::Result`) at their public surface,
//! but the coordinator's recovery logic needs to *distinguish* failures:
//! pool exhaustion is survivable backpressure (defer or preempt), a lost
//! worker is transient (retry the batch), a blown deadline is a typed
//! client-visible rejection — and anything else still fails the batch.
//! [`EngineError`] is the one enum those decisions branch on, and
//! [`EngineError::classify`] is the one place the ad-hoc `downcast_ref`
//! chains were consolidated into. Because the vendored anyhow shim's
//! blanket `From` captures any `std::error::Error + Send + Sync +
//! 'static` as the error's source (and context layers preserve it),
//! raising `EngineError` with `?`/`.into()` composes unchanged and
//! classification survives `.context(...)` plumbing.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::model::kv::KvPoolExhausted;
use crate::util::parallel::WorkerPanic;
use crate::Result;

/// A typed engine failure the coordinator can branch on.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum EngineError {
    /// KV page reservation failed — survivable backpressure: defer the
    /// admission, evict prefix pages, or preempt a victim session.
    KvPoolExhausted(KvPoolExhausted),
    /// A tensor-parallel worker panicked mid-step. The engine restored
    /// every session's cache to its pre-step state, so the batch is safe
    /// to retry.
    WorkerFailed { worker: usize, reason: String },
    /// A request sat past its `--deadline-ms` budget and was rejected.
    DeadlineExceeded { waited_ms: u64, deadline_ms: u64 },
    /// A failpoint fired (`util::faults`): the step failed cleanly before
    /// touching any state. Transient by construction — retry.
    Injected { point: &'static str },
}

impl std::fmt::Display for EngineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EngineError::KvPoolExhausted(e) => write!(f, "{e}"),
            EngineError::WorkerFailed { worker, reason } => {
                write!(f, "tensor-parallel worker {worker} failed: {reason}")
            }
            EngineError::DeadlineExceeded { waited_ms, deadline_ms } => {
                write!(f, "request deadline exceeded: waited {waited_ms} ms > {deadline_ms} ms")
            }
            EngineError::Injected { point } => write!(f, "injected fault at failpoint {point}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<KvPoolExhausted> for EngineError {
    fn from(e: KvPoolExhausted) -> Self {
        EngineError::KvPoolExhausted(e)
    }
}

impl EngineError {
    /// Recover the typed condition an `anyhow` error carries, through any
    /// number of context layers: either an [`EngineError`] raised as such,
    /// or a bare [`KvPoolExhausted`] from the pool/forward seams. Returns
    /// `None` for untyped (non-recoverable) failures.
    pub fn classify(err: &anyhow::Error) -> Option<EngineError> {
        if let Some(e) = err.downcast_ref::<EngineError>() {
            return Some(e.clone());
        }
        if let Some(e) = err.downcast_ref::<KvPoolExhausted>() {
            return Some(EngineError::KvPoolExhausted(*e));
        }
        None
    }

    /// Whether `err` is typed KV pool exhaustion (either raised bare or
    /// wrapped in an [`EngineError`]) — the predicate the evict-and-retry
    /// and draft-fallback paths branch on.
    pub fn is_exhausted(err: &anyhow::Error) -> bool {
        matches!(Self::classify(err), Some(EngineError::KvPoolExhausted(_)))
    }

    /// Whether `err` is transient — the step left engine state restored
    /// and the same call can simply be retried. Pool exhaustion is *not*
    /// transient (retrying without freeing pages can't succeed); it is
    /// survivable via deferral/preemption instead.
    pub fn is_transient(err: &anyhow::Error) -> bool {
        matches!(
            Self::classify(err),
            Some(EngineError::WorkerFailed { .. }) | Some(EngineError::Injected { .. })
        )
    }
}

/// Run `f`, converting a [`WorkerPanic`] unwinding out of a collective
/// (see `util::par_run_once`) into the typed
/// [`EngineError::WorkerFailed`]. Any other panic is not ours to swallow
/// and resumes unwinding. This is the engine-side half of worker-failure
/// recovery; callers restore session caches on the `Err` path.
pub fn catch_worker<T>(f: impl FnOnce() -> Result<T>) -> Result<T> {
    match catch_unwind(AssertUnwindSafe(f)) {
        Ok(r) => r,
        Err(payload) => match payload.downcast_ref::<WorkerPanic>() {
            Some(wp) => Err(EngineError::WorkerFailed {
                worker: wp.worker,
                reason: wp.reason.clone(),
            }
            .into()),
            None => std::panic::resume_unwind(payload),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use anyhow::Context;

    #[test]
    fn classify_sees_through_context_layers() {
        let bare: anyhow::Error = KvPoolExhausted { requested: 4, free: 1 }.into();
        assert!(EngineError::is_exhausted(&bare));
        let wrapped = bare.context("prefill").context("serve");
        assert_eq!(
            EngineError::classify(&wrapped),
            Some(EngineError::KvPoolExhausted(KvPoolExhausted { requested: 4, free: 1 }))
        );
        assert!(!EngineError::is_transient(&wrapped));

        let worker: anyhow::Error =
            EngineError::WorkerFailed { worker: 2, reason: "boom".into() }.into();
        assert!(EngineError::is_transient(&worker));
        assert!(!EngineError::is_exhausted(&worker));
        let injected: anyhow::Error = EngineError::Injected { point: "engine.decode" }.into();
        assert!(EngineError::is_transient(&injected));

        let plain = anyhow::anyhow!("some other failure");
        assert_eq!(EngineError::classify(&plain), None);
        assert!(!EngineError::is_transient(&plain));
    }

    #[test]
    fn catch_worker_types_worker_panics_and_passes_results() {
        assert_eq!(catch_worker(|| Ok(7u32)).unwrap(), 7);
        let err = catch_worker::<u32>(|| {
            std::panic::panic_any(WorkerPanic { worker: 1, reason: "lost".into() })
        })
        .unwrap_err();
        match EngineError::classify(&err) {
            Some(EngineError::WorkerFailed { worker, reason }) => {
                assert_eq!(worker, 1);
                assert_eq!(reason, "lost");
            }
            other => panic!("expected WorkerFailed, got {other:?}"),
        }
        // Plain Err results pass through untouched (still classifiable).
        let err = catch_worker::<u32>(|| Err(KvPoolExhausted { requested: 1, free: 0 }.into()))
            .unwrap_err();
        assert!(EngineError::is_exhausted(&err));
    }
}
