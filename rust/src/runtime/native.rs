//! The hermetic native executor: runs the manifest-described transformer
//! graphs ([`crate::model::forward`]) directly on host `Vec<f32>` buffers —
//! no HLO, no PJRT, no Python. Argument order and output tuples match the
//! AOT graph signatures recorded in `manifest.json`, so the evaluator and
//! the serving coordinator are backend-agnostic.

use std::sync::Arc;

use crate::io::Manifest;
use crate::model::forward::{forward, masked_nll, ModelArch, Params, QuantInputs};
use crate::Result;

use super::args::ArgValue;
use super::GraphKind;

/// One native "compiled" graph: the architecture plus the graph kind.
/// Cheap to clone and `Send` — worker threads share it freely.
#[derive(Clone)]
pub struct NativeGraph {
    manifest: Arc<Manifest>,
    arch: ModelArch,
    kind: GraphKind,
    name: String,
}

impl NativeGraph {
    pub fn new(manifest: Manifest, kind: GraphKind) -> Result<Self> {
        let arch = manifest.arch()?;
        let expect = arch.linears().len();
        anyhow::ensure!(
            manifest.num_linears == expect,
            "manifest lists {} linears but the {} arch implies {expect}",
            manifest.num_linears,
            manifest.name
        );
        let name = format!("{}:{}", manifest.name, kind.stem());
        Ok(NativeGraph { manifest: Arc::new(manifest), arch, kind, name })
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// The manifest this graph was materialized from (the [`super::engine`]
    /// facade reuses it to parse argument tails into owned parameters).
    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The native architecture descriptor.
    pub fn arch(&self) -> &ModelArch {
        &self.arch
    }

    /// Execute with host args; returns the graph's output tuple flattened to
    /// f32 — exactly the shape contract of the PJRT executables.
    pub fn run(&self, args: &[ArgValue]) -> Result<Vec<Vec<f32>>> {
        let man = &self.manifest;
        let (b, s) = (man.batch, man.seq);
        let np = man.param_names.len();
        let nl = man.num_linears;
        let has_mask = !matches!(self.kind, GraphKind::LogitsQuant);
        let has_quant = !matches!(self.kind, GraphKind::FwdRef);
        let expected =
            1 + usize::from(has_mask) + np + if has_quant { nl + 1 } else { 0 };
        anyhow::ensure!(
            args.len() == expected,
            "{}: got {} args, expected {expected}",
            self.name,
            args.len()
        );

        let tokens = args[0].as_i32()?;
        anyhow::ensure!(tokens.len() == b * s, "{}: tokens length", self.name);
        let mask = if has_mask { Some(args[1].as_f32()?) } else { None };
        let poff = 1 + usize::from(has_mask);

        let mut params = Params::new();
        for (i, pname) in man.param_names.iter().enumerate() {
            let want: usize = man.param_shapes[pname].iter().product();
            let a = &args[poff + i];
            anyhow::ensure!(
                a.elements() == want,
                "{}: parameter '{pname}' has {} elements, want {want}",
                self.name,
                a.elements()
            );
            // Packed weights execute straight off their bits — the native
            // backend never materializes a dequantized copy.
            match a {
                ArgValue::PackedW { panels, .. } => params.insert_packed(pname, panels),
                other => params.insert_dense(pname, other.as_f32()?),
            }
        }

        let quant = if has_quant {
            let aw: Vec<&[f32]> = (0..nl)
                .map(|i| args[poff + np + i].as_f32())
                .collect::<Result<_>>()?;
            let thresholds = args[poff + np + nl].as_f32()?;
            anyhow::ensure!(thresholds.len() == nl, "{}: thresholds length", self.name);
            Some(QuantInputs { act_weights: aw, thresholds, attn_threshold: None })
        } else {
            None
        };

        let last_only = matches!(self.kind, GraphKind::LogitsQuant);
        let out = forward(&self.arch, &params, tokens, b, s, quant.as_ref(), None, last_only)?;

        match self.kind {
            GraphKind::FwdQuant => {
                let (nll, ntok) =
                    masked_nll(&out.logits, tokens, mask.unwrap(), b, s, self.arch.vocab);
                Ok(vec![nll, ntok, out.act_fp8])
            }
            GraphKind::FwdRef => {
                let (nll, ntok) =
                    masked_nll(&out.logits, tokens, mask.unwrap(), b, s, self.arch.vocab);
                Ok(vec![nll, ntok])
            }
            GraphKind::LogitsQuant => Ok(vec![out.logits]),
        }
    }
}
