//! Self-speculative decoding off the quantization ladder.
//!
//! FGMP's packed weight tensor — blocks individually assigned FP8 or NVFP4
//! by the Fisher-weighted sensitivity policy — contains its own draft
//! model: re-quantize just the hi (E4M3) blocks down to NVFP4 nibbles
//! ([`PackedPanels::to_all_fp4`]) and the *same* network becomes a cheaper
//! forward of itself, with the same panel layout the LUT-decode packed
//! kernels already execute. No second model artifact, no distillation.
//!
//! [`SpecEngine`] wraps a target engine (single-worker [`Engine`] or
//! tensor-parallel [`ShardedEngine`]) and turns each decode step into a
//! **draft/verify round**:
//!
//!  1. every session is forked ([`Session::fork`] — an O(page-table)
//!     refcount bump sharing the parent's pages; the fork's first append
//!     copy-on-writes only its partial tail page);
//!  2. the forks decode `k−1` tokens greedily through the all-NVFP4 draft
//!     view (weight-read bytes ≈ 4.56/8 of the hi blocks — the speedup
//!     source);
//!  3. one batched **ragged verify pass** extends the *real* caches by the
//!     whole k-token chain (`[next_token, g₁ … g_{k−1}]`) and scores all k
//!     positions with the mixed-precision weights
//!     ([`forward_extend_batch`](crate::model::forward::forward_extend_batch));
//!  4. the longest prefix of guesses agreeing with the verify argmaxes is
//!     accepted; rejected rows roll back via [`KvState::truncate`]
//!     (`crate::model::kv::KvState::truncate`), and the draft forks drop —
//!     their pages return to the pool.
//!
//! Acceptance is **exact match**, so the emitted greedy stream is
//! bit-for-bit the non-speculative stream at any `k` (property-tested in
//! `tests/decode_props.rs`): a round always lands on a state some number
//! of sequential [`Engine::decode_step`] calls would have produced. The
//! **accept rate** (accepted / drafted, from [`StepOut::drafted`] /
//! [`StepOut::accepted`]) is therefore a live, per-request proxy for how
//! closely the all-NVFP4 assignment tracks the mixed model — the serving
//! counterpart of the paper's <1%-degradation accuracy claim.
//!
//! Rounds degrade gracefully: when any session is within `k` tokens of
//! `max_seq` (a roll is near) or a draft fork hits pool exhaustion, the
//! round falls through to the target's plain decode step. Sustained
//! exhaustion disables drafting entirely for a **cooldown** window
//! ([`COOLDOWN_AFTER`] consecutive exhaustion fallbacks →
//! [`COOLDOWN_ROUNDS`] plain rounds): a full pool will not drain in one
//! round, and repeatedly forking into it just burns the failed forks'
//! copy-on-write work.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use crate::model::forward::{forward_step_batch, ForwardOut, ModelArch, Params, QuantInputs};
use crate::model::kv::{KvPoolStats, KvPrecision, KvState};
use crate::model::WeightMemory;
use crate::quant::PackedPanels;
use crate::Result;

use super::engine::ParamData;
use super::error::EngineError;
use super::prefix::PrefixIndexStats;
use super::sharded::InferenceEngine;
use super::{Engine, Session, ShardedEngine, StepOut};

/// Consecutive exhaustion fallbacks that trigger a draft cooldown.
pub const COOLDOWN_AFTER: u32 = 3;
/// Plain-decode rounds one cooldown window lasts.
pub const COOLDOWN_ROUNDS: u32 = 16;

/// The concrete engine a [`SpecEngine`] drafts for. Concrete (not a trait
/// object) because the draft/verify passes reach the engines' internal
/// forward machinery, not just the public session surface.
enum Target {
    Single(Engine),
    Sharded(ShardedEngine),
}

impl Target {
    fn as_dyn(&self) -> &dyn InferenceEngine {
        match self {
            Target::Single(e) => e,
            Target::Sharded(e) => e,
        }
    }
}

/// Speculative wrapper engine: drives draft/verify rounds over a wrapped
/// target engine. Implements [`InferenceEngine`], so the coordinator's
/// continuous-batching loop and the CLI drive it unchanged — the only
/// observable differences are multi-token steps ([`Session::take_accepted`])
/// and the drafted/accepted counters on [`StepOut`].
pub struct SpecEngine {
    target: Target,
    /// Chain length per round: 1 real token + `k-1` drafted guesses.
    k: usize,
    /// The all-NVFP4 draft view, built once at construction: for every
    /// packed linear, the same panel grid with hi blocks re-quantized to
    /// NVFP4 ([`PackedPanels::to_all_fp4`]). Dense parameters (norms,
    /// embeddings) are shared with the target, not duplicated.
    draft: HashMap<String, Arc<PackedPanels>>,
    /// Resident bytes the draft view adds on top of the target weights.
    draft_bytes: u64,
    /// Plain-decode rounds remaining before drafting resumes (0 = active).
    cooldown: AtomicU32,
    /// Consecutive rounds that fell back on pool exhaustion; reset by any
    /// round whose drafts survive to the verify pass.
    exhaust_streak: AtomicU32,
    /// Lifetime cooldown windows entered ([`InferenceEngine::spec_cooldowns`]).
    cooldowns_total: AtomicU64,
}

fn draft_view(params: &[(String, ParamData)]) -> (HashMap<String, Arc<PackedPanels>>, u64) {
    let mut map = HashMap::new();
    let mut bytes = 0u64;
    for (name, data) in params {
        if let ParamData::Packed(p) = data {
            let f4 = Arc::new(p.to_all_fp4());
            bytes += f4.resident_bytes() as u64;
            map.insert(name.clone(), f4);
        }
    }
    (map, bytes)
}

/// Parameter map for a draft forward: dense entries borrow the target's
/// buffers, packed entries swap in the all-NVFP4 view.
fn draft_params_map<'a>(
    params: &'a [(String, ParamData)],
    draft: &'a HashMap<String, Arc<PackedPanels>>,
) -> Params<'a> {
    let mut pm = Params::new();
    for (name, data) in params {
        match data {
            ParamData::Dense(v) => pm.insert_dense(name, v),
            ParamData::Packed(orig) => match draft.get(name) {
                Some(f4) => pm.insert_packed(name, f4),
                None => pm.insert_packed(name, orig),
            },
        }
    }
    pm
}

/// Greedy argmax with [`Session::next_token`]'s exact tie-breaking (the
/// last maximum wins under `max_by`) — acceptance compares draft and
/// verify argmaxes, so all three must break ties identically.
fn argmax(row: &[f32]) -> i32 {
    row.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

impl SpecEngine {
    /// Wrap a single-worker [`Engine`]. The draft view is re-quantized
    /// here, once — construction cost proportional to the packed payload.
    pub fn over_engine(target: Engine, k: usize) -> SpecEngine {
        let (draft, draft_bytes) = match target.cached() {
            Some(ce) => draft_view(&ce.params),
            None => (HashMap::new(), 0),
        };
        SpecEngine {
            target: Target::Single(target),
            k: k.max(2),
            draft,
            draft_bytes,
            cooldown: AtomicU32::new(0),
            exhaust_streak: AtomicU32::new(0),
            cooldowns_total: AtomicU64::new(0),
        }
    }

    /// Wrap a tensor-parallel [`ShardedEngine`]. The draft view is shared
    /// by all workers exactly like the target weights are — drafts run
    /// column-sharded through the same collective.
    pub fn over_sharded(target: ShardedEngine, k: usize) -> SpecEngine {
        let (draft, draft_bytes) = draft_view(target.params());
        SpecEngine {
            target: Target::Sharded(target),
            k: k.max(2),
            draft,
            draft_bytes,
            cooldown: AtomicU32::new(0),
            exhaust_streak: AtomicU32::new(0),
            cooldowns_total: AtomicU64::new(0),
        }
    }

    /// The configured chain length `k`.
    pub fn k(&self) -> usize {
        self.k
    }

    /// Resident bytes of the all-NVFP4 draft view.
    pub fn draft_resident_bytes(&self) -> u64 {
        self.draft_bytes
    }

    /// One more round fell back on pool exhaustion; a long enough streak
    /// enters a cooldown window of plain decode.
    fn note_exhausted(&self) {
        let streak = self.exhaust_streak.fetch_add(1, Ordering::Relaxed) + 1;
        if streak >= COOLDOWN_AFTER {
            self.exhaust_streak.store(0, Ordering::Relaxed);
            self.cooldown.store(COOLDOWN_ROUNDS, Ordering::Relaxed);
            self.cooldowns_total.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// One batched draft decode step over the forked sessions, through the
    /// all-NVFP4 weight view. No token/step bookkeeping — the forks only
    /// exist to grow their caches along the guessed chain.
    fn draft_step(&self, inputs: &[i32], drafts: &mut [Session]) -> Result<ForwardOut> {
        match &self.target {
            Target::Single(eng) => {
                let ce = eng.cached().expect("speculative target runs the cached path");
                let pm = draft_params_map(&ce.params, &self.draft);
                let quant: QuantInputs<'_> = ce.quant_inputs();
                let mut kvs: Vec<&mut KvState> = drafts
                    .iter_mut()
                    .map(|d| d.kv.as_mut().expect("forked from a cached session"))
                    .collect();
                forward_step_batch(&ce.arch, &pm, inputs, &mut kvs, Some(&quant))
            }
            Target::Sharded(eng) => {
                let pm = draft_params_map(eng.params(), &self.draft);
                let quant = eng.quant();
                let mut kvs: Vec<Vec<&mut KvState>> =
                    drafts.iter_mut().map(|d| d.kv_shards.iter_mut().collect()).collect();
                eng.step_shards_with(&pm, &quant, inputs, &mut kvs)
            }
        }
    }

    /// The verify pass: one ragged batched extend of the *real* caches by
    /// each session's full k-token chain, scored with the target's
    /// mixed-precision weights.
    fn target_extend(
        &self,
        sessions: &mut [&mut Session],
        chains: &[&[i32]],
    ) -> Result<ForwardOut> {
        match &self.target {
            Target::Single(eng) => eng.extend_batch(sessions, chains),
            Target::Sharded(eng) => eng.extend_batch(sessions, chains),
        }
    }

    fn kv_step_stats(&self, sessions: &[&mut Session]) -> (u64, f64, Vec<(usize, f64)>) {
        match &self.target {
            Target::Single(eng) => eng.kv_step_stats(sessions),
            Target::Sharded(eng) => eng.kv_step_stats(sessions),
        }
    }

    /// One draft/verify round. See the module docs for the protocol; the
    /// invariant is that on return every session sits in a state some
    /// `1 + accepted_i` sequential plain decode steps would have produced,
    /// with the extra tokens queued in [`Session::take_accepted`].
    fn spec_round(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        let arch = self.target.as_dyn().arch();
        let (max_seq, vocab) = (arch.max_seq, arch.vocab);
        let n = sessions.len();

        // Chain length this round: every session must fit k new cache rows
        // (the verify pass extends all of them by the full chain). Within
        // k of max_seq — or when a roll is due — fall back to the plain
        // step, which owns the roll machinery.
        let mut k_round = self.k;
        for sess in sessions.iter() {
            k_round = k_round.min(max_seq.saturating_sub(sess.cached_tokens()));
        }
        if k_round < 2 || !self.target.as_dyn().is_cached() {
            return self.target.as_dyn().decode_step(sessions);
        }
        // In a cooldown window drafting is disabled outright: burn one
        // round off the window and decode plainly. (Relaxed ordering —
        // the counters are heuristics, not synchronization.)
        if self.cooldown.load(Ordering::Relaxed) > 0 {
            self.cooldown.fetch_sub(1, Ordering::Relaxed);
            return self.target.as_dyn().decode_step(sessions);
        }

        // Fork every session into a draft: an O(page-table) refcount bump
        // — no payload copies, no allocation, so forking itself no longer
        // fails under pool pressure. The pressure surfaces later, when a
        // draft's first append copy-on-writes its partial tail page.
        let mut drafts: Vec<Session> = Vec::with_capacity(n);
        for sess in sessions.iter() {
            match sess.fork() {
                Ok(d) => drafts.push(d),
                Err(_) => {
                    self.note_exhausted();
                    return self.target.as_dyn().decode_step(sessions);
                }
            }
        }

        // Chain head: the token a plain step would consume right now.
        // Guesses follow from k-1 greedy all-NVFP4 draft steps.
        let firsts: Vec<i32> = sessions.iter().map(|s| s.next_token()).collect();
        let mut chains: Vec<Vec<i32>> = firsts.iter().map(|&t| vec![t]).collect();
        let mut inputs = firsts;
        for _ in 0..k_round - 1 {
            // COW moved the fork-time allocation to first-append
            // divergence, so a full pool now surfaces here instead of at
            // fork(). It is still backpressure, not an error: drop the
            // drafts (parents are untouched — drafts own their caches)
            // and decode plainly this round.
            let out = match self.draft_step(&inputs, &mut drafts) {
                Ok(out) => out,
                Err(e) if EngineError::is_exhausted(&e) => {
                    drop(drafts);
                    self.note_exhausted();
                    return self.target.as_dyn().decode_step(sessions);
                }
                Err(e) => return Err(e),
            };
            for (i, chain) in chains.iter_mut().enumerate() {
                let g = argmax(&out.logits[i * vocab..(i + 1) * vocab]);
                chain.push(g);
                inputs[i] = g;
            }
        }
        // The drafts' pages go back to the pool before the verify pass
        // reserves the real caches' new rows. The drafts survived, so the
        // exhaustion streak breaks here.
        drop(drafts);
        self.exhaust_streak.store(0, Ordering::Relaxed);

        let chain_refs: Vec<&[i32]> = chains.iter().map(|c| c.as_slice()).collect();
        let out = self.target_extend(sessions, &chain_refs)?;

        // Accept the longest agreeing prefix per session; roll the rest
        // back. Verify row j scores the next token after chains[..=j], so
        // guess j+1 is accepted iff it equals row j's argmax — exactly the
        // token the plain greedy stream would have consumed next.
        let mut accepted_total = 0u64;
        for (i, sess) in sessions.iter_mut().enumerate() {
            let base = i * k_round;
            let chain = &chains[i];
            let mut m = 0usize;
            while m + 1 < k_round {
                let row = &out.logits[(base + m) * vocab..(base + m + 1) * vocab];
                if chain[m + 1] == argmax(row) {
                    m += 1;
                } else {
                    break;
                }
            }
            let new_len = sess.cached_tokens() - k_round + 1 + m;
            if let Some(kv) = sess.kv.as_mut() {
                kv.truncate(new_len);
            }
            for shard in sess.kv_shards.iter_mut() {
                shard.truncate(new_len);
            }
            sess.tokens.extend_from_slice(&chain[..=m]);
            let row = &out.logits[(base + m) * vocab..(base + m + 1) * vocab];
            sess.last_logits = row.to_vec();
            sess.steps += 1 + m;
            sess.spec_accepted.extend_from_slice(&chain[1..=m]);
            sess.spec_drafted_total += (k_round - 1) as u64;
            sess.spec_accepted_total += m as u64;
            accepted_total += m as u64;
        }

        let (kv_tokens, kv_bits_per_value, kv_mix) = self.kv_step_stats(sessions);
        Ok(StepOut {
            rows: n,
            act_fp8: out.act_fp8,
            kv_tokens,
            kv_bits_per_value,
            kv_mix,
            drafted: (n * (k_round - 1)) as u64,
            accepted: accepted_total,
        })
    }
}

impl InferenceEngine for SpecEngine {
    fn arch(&self) -> &ModelArch {
        self.target.as_dyn().arch()
    }
    fn is_cached(&self) -> bool {
        self.target.as_dyn().is_cached()
    }
    fn kv_precision(&self) -> KvPrecision {
        self.target.as_dyn().kv_precision()
    }
    fn workers(&self) -> usize {
        self.target.as_dyn().workers()
    }
    fn prefill(&self, prompt: &[i32]) -> Result<Session> {
        self.target.as_dyn().prefill(prompt)
    }
    fn prefill_batch(&self, prompts: &[Vec<i32>]) -> Result<Vec<Session>> {
        self.target.as_dyn().prefill_batch(prompts)
    }
    fn decode_step(&self, sessions: &mut [&mut Session]) -> Result<StepOut> {
        if sessions.is_empty() {
            return Ok(StepOut::default());
        }
        self.spec_round(sessions)
    }
    fn weight_memory(&self) -> WeightMemory {
        self.target.as_dyn().weight_memory()
    }
    fn pool_stats(&self) -> Option<KvPoolStats> {
        self.target.as_dyn().pool_stats()
    }
    fn kv_pages_per_session(&self) -> usize {
        self.target.as_dyn().kv_pages_per_session()
    }
    /// Draft forks transiently hold extra pages, but fork failure degrades
    /// to a plain step instead of erroring — so admission bounds stay the
    /// target's, and speculation simply pauses under pool pressure.
    fn max_live_sessions(&self) -> usize {
        self.target.as_dyn().max_live_sessions()
    }
    fn kv_pages_worst_for(&self, prompt_len: usize, want: usize) -> usize {
        self.target.as_dyn().kv_pages_worst_for(prompt_len, want)
    }
    fn prefix_stats(&self) -> Option<PrefixIndexStats> {
        self.target.as_dyn().prefix_stats()
    }
    fn kv_pages_worst_for_prompt(&self, prompt: &[i32], want: usize) -> usize {
        self.target.as_dyn().kv_pages_worst_for_prompt(prompt, want)
    }
    fn spec_k(&self) -> Option<usize> {
        Some(self.k)
    }
    fn spec_draft_bytes(&self) -> Option<u64> {
        Some(self.draft_bytes)
    }
    fn preempt_donate(&self, sess: &Session) -> bool {
        self.target.as_dyn().preempt_donate(sess)
    }
    fn spec_cooldowns(&self) -> Option<u64> {
        Some(self.cooldowns_total.load(Ordering::Relaxed))
    }
}
