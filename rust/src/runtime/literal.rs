//! Tensor ⇄ xla::Literal conversion helpers.

use xla::Literal;

use crate::Result;

/// A host-side argument value (what the coordinator traffics in).
#[derive(Debug, Clone)]
pub enum ArgValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl ArgValue {
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            ArgValue::F32 { shape, data } => lit_f32(data, shape),
            ArgValue::I32 { shape, data } => lit_i32(data, shape),
        }
    }

    pub fn scalar_f32(v: f32) -> Self {
        ArgValue::F32 { shape: vec![], data: vec![v] }
    }
    pub fn vec_f32(data: Vec<f32>) -> Self {
        ArgValue::F32 { shape: vec![data.len()], data }
    }
}

/// Build an f32 literal with the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Build an i32 literal with the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}
