//! Tensor ⇄ xla::Literal conversion helpers (feature `pjrt`).

use xla::Literal;

use crate::Result;

use super::args::ArgValue;

impl ArgValue {
    /// Convert to an XLA literal (pjrt backend only).
    pub fn to_literal(&self) -> Result<Literal> {
        match self {
            ArgValue::F32 { shape, data } => lit_f32(data, shape),
            ArgValue::I32 { shape, data } => lit_i32(data, shape),
            // PJRT consumes dense tensors: materialize the packed weight
            // on demand, memoized per shared `Arc<PackedPanels>` so
            // re-lowering the same weights (rebuilds, multi-executable
            // servers) dequantizes each tensor once, not per literal.
            ArgValue::PackedW { shape, panels } => lit_f32(panels.unpack_kn_cached(), shape),
        }
    }
}

/// Build an f32 literal with the given shape.
pub fn lit_f32(data: &[f32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}

/// Build an i32 literal with the given shape.
pub fn lit_i32(data: &[i32], shape: &[usize]) -> Result<Literal> {
    let n: usize = shape.iter().product();
    anyhow::ensure!(n == data.len(), "shape {shape:?} != len {}", data.len());
    let flat = Literal::vec1(data);
    if shape.len() == 1 {
        return Ok(flat);
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(flat.reshape(&dims)?)
}
